#!/usr/bin/env bash
# Kick-the-tires perf runner: release build, the gp_hotpath and
# space_build benches, and their BENCH_*.json files refreshed at the repo
# root.
#
#   scripts/bench.sh            # full grids (17956 & 200k candidates)
#   scripts/bench.sh --smoke    # tiny grids, seconds — sanity check only
#
# After a full run, copy the ms/iter and ms/build numbers into
# EXPERIMENTS.md §Perf.
set -euo pipefail

cd "$(dirname "$0")/.."
ROOT="$(pwd)"

GP_OUT="$ROOT/BENCH_gp_hotpath.json"
SPACE_OUT="$ROOT/BENCH_space_build.json"
SURR_OUT="$ROOT/BENCH_surrogate_fit.json"
SESSION_OUT="$ROOT/BENCH_session_step.json"
SCALE_OUT="$ROOT/BENCH_space_scale.json"
for arg in "$@"; do
  # A smoke run must not overwrite the tracked full-grid trajectory files.
  if [ "$arg" = "--smoke" ]; then
    GP_OUT="$ROOT/BENCH_gp_hotpath.smoke.json"
    SPACE_OUT="$ROOT/BENCH_space_build.smoke.json"
    SURR_OUT="$ROOT/BENCH_surrogate_fit.smoke.json"
    SESSION_OUT="$ROOT/BENCH_session_step.smoke.json"
    SCALE_OUT="$ROOT/BENCH_space_scale.smoke.json"
  fi
done

cd rust
cargo build --release
cargo bench --bench gp_hotpath -- --out "$GP_OUT" "$@"
cargo bench --bench space_build -- --out "$SPACE_OUT" "$@"
cargo bench --bench surrogate_fit -- --out "$SURR_OUT" "$@"
cargo bench --bench session_step -- --out "$SESSION_OUT" "$@"
cargo bench --bench space_scale -- --out "$SCALE_OUT" "$@"

echo
echo "perf records: $GP_OUT"
echo "              $SPACE_OUT"
echo "              $SURR_OUT"
echo "              $SESSION_OUT"
echo "              $SCALE_OUT (update EXPERIMENTS.md §Perf after full runs)"
