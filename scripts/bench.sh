#!/usr/bin/env bash
# Kick-the-tires perf runner: release build, gp_hotpath bench, and
# BENCH_gp_hotpath.json refreshed at the repo root.
#
#   scripts/bench.sh            # full grid (17956 & 200k candidates)
#   scripts/bench.sh --smoke    # tiny grid, seconds — sanity check only
#
# After a full run, copy the ms/iter numbers into EXPERIMENTS.md §Perf.
set -euo pipefail

cd "$(dirname "$0")/.."
ROOT="$(pwd)"

OUT="$ROOT/BENCH_gp_hotpath.json"
for arg in "$@"; do
  # A smoke run must not overwrite the tracked full-grid trajectory file.
  [ "$arg" = "--smoke" ] && OUT="$ROOT/BENCH_gp_hotpath.smoke.json"
done

cd rust
cargo build --release
cargo bench --bench gp_hotpath -- --out "$OUT" "$@"

echo
echo "perf records: $OUT (update EXPERIMENTS.md §Perf after full runs)"
