#!/usr/bin/env bash
# Local mirror of .github/workflows/ci.yml — run before pushing to
# reproduce a red pipeline with one command:
#
#   scripts/ci-check.sh          # everything the CI jobs run
#   scripts/ci-check.sh --fast   # skip the smoke bench + sweep tier
#
# Steps (same order as CI): fmt, clippy, release build, tests, docs, the
# ktbo-lint determinism audit, then the smoke bench and smoke sweep with
# the artifact sanity checks the CI `smoke` job gates on.
set -euo pipefail

cd "$(dirname "$0")/.."

FAST=0
for arg in "$@"; do
  [ "$arg" = "--fast" ] && FAST=1
done

step() { printf '\n== %s ==\n' "$*"; }

step "cargo fmt --check"
cargo fmt --all --check

step "cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

step "cargo build --release"
cargo build --release

step "cargo test -q"
cargo test -q

step "cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --workspace --no-deps

step "ktbo-lint (determinism audit vs lint/baseline.json)"
cargo run --release -p ktbo-lint -- --workspace --baseline lint/baseline.json

if [ "$FAST" = "1" ]; then
  printf '\nci-check: core checks green (smoke tier skipped via --fast)\n'
  exit 0
fi

step "smoke bench (gp_hotpath + space_build + surrogate_fit + session_step + space_scale)"
scripts/bench.sh --smoke

step "smoke sweep (orchestrator; bo_rf surrogate cell + faulted sa cells; telemetry on)"
cargo run --release -p ktbo -- sweep --smoke --fresh --out results --telemetry

step "telemetry export + ktbo report"
test -s results/SWEEP_smoke.telemetry.jsonl
# Versioned meta head line, then at least one real event.
head -n1 results/SWEEP_smoke.telemetry.jsonl | grep -q '"schema_version"'
[ "$(wc -l < results/SWEEP_smoke.telemetry.jsonl)" -gt 1 ]
REPORT_OUT="$(cargo run --release -p ktbo -- report results/SWEEP_smoke.telemetry.jsonl)"
echo "$REPORT_OUT" | head -n 30
# The per-phase table must render with real spans for the ask phase.
echo "$REPORT_OUT" | grep -q 'ask'

step "smoke sweep on a JSON-defined space"
cargo run --release -p ktbo -- sweep --smoke --fresh --out results \
  --tag smoke-space --strategies random --budget 20 --space examples/spaces/adding.json

step "lazy tune smoke (TPE on the billion-scale implicit space, no enumeration)"
LAZY_OUT="$(cargo run --release -p ktbo -- tune gemm titanx --strategy tpe --budget 25 --seed 7 \
  --space examples/spaces/megakernel_1g.json --pool-size 64)"
echo "$LAZY_OUT"
echo "$LAZY_OUT" | grep -q 'mode=lazy'
echo "$LAZY_OUT" | grep -q 'evaluations=25'

step "serve smoke (daemon + scripted 2-session client vs offline tune)"
mkdir -p results
SERVE_ADDR=127.0.0.1:47923
cargo run --release -p ktbo -- serve --listen "$SERVE_ADDR" \
  --cache-file results/serve-cache.jsonl >results/serve.log 2>&1 &
SERVE_PID=$!
trap 'kill "$SERVE_PID" 2>/dev/null || true' EXIT
for _ in $(seq 1 50); do
  grep -q 'listening' results/serve.log 2>/dev/null && break
  sleep 0.2
done
CLIENT_OUT="$(cargo run --release -p ktbo -- client --addr "$SERVE_ADDR" \
  --sessions 2 --kernel adding --gpu a100 --strategy random --budget 40 --seed 7)"
echo "$CLIENT_OUT"
# The daemon's metrics registry must have counted the session traffic;
# the metrics query also delivers the shutdown.
METRICS_OUT="$(cargo run --release -p ktbo -- client --addr "$SERVE_ADDR" --metrics --shutdown)"
echo "$METRICS_OUT"
echo "$METRICS_OUT" | grep -qF '"serve.sessions.created":{"type":"counter","value":2}'
echo "$METRICS_OUT" | grep -qF '"serve.requests.ask"'
wait "$SERVE_PID"
trap - EXIT
TUNE_BEST="$(cargo run --release -p ktbo -- tune adding a100 --strategy random --budget 40 --seed 7 \
  | grep -o 'best=[0-9.]*' | head -n1)"
echo "offline tune: $TUNE_BEST"
# Both served sessions evaluate client-side against the same table and
# seed, so their best must match the offline run exactly.
[ "$(echo "$CLIENT_OUT" | grep -cF -- "$TUNE_BEST")" = "2" ]
test -s results/serve-cache.jsonl

step "artifact sanity"
test -s BENCH_gp_hotpath.smoke.json
test -s BENCH_space_build.smoke.json
test -s BENCH_surrogate_fit.smoke.json
test -s BENCH_session_step.smoke.json
test -s BENCH_space_scale.smoke.json
test -s results/SWEEP_smoke.jsonl
test -s results/SWEEP_smoke.results.jsonl
grep -q '"type":"outcome"' results/SWEEP_smoke.results.jsonl
# The non-GP surrogate path must be exercised on every push.
grep -q '"strategy":"bo_rf"' results/SWEEP_smoke.results.jsonl
# The fault-injection + resilience layers must be exercised on every
# push: sa cells run under examples/faults/smoke.json and carry a
# fault-accounting block, and still aggregate to an outcome.
grep -q '"faults"' results/SWEEP_smoke.jsonl
grep -q '"strategy":"simulated_annealing"' results/SWEEP_smoke.results.jsonl
test -s results/SWEEP_smoke-space.results.jsonl

printf '\nci-check: all green\n'
