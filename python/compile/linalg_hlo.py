"""Pure-HLO dense linear algebra for the AOT path.

`jax.scipy.linalg.cholesky` / `solve_triangular` lower on CPU to LAPACK
custom-calls with the typed-FFI API (`lapack_spotrf_ffi`, …) that the
runtime's xla_extension 0.5.1 cannot execute ("Unknown custom-call API
version enum value: 4"). These replacements express the same factorization
and substitutions as `lax.fori_loop` + dense contractions, which lower to
plain HLO (While + Dot) and run on any PJRT backend.

Shapes are tiny on the factorization side (N ≤ 256), and the O(N²·C)
substitution against the candidate block is exactly the work the math
requires — no asymptotic penalty vs LAPACK.
"""

import jax
import jax.numpy as jnp


def cholesky_hlo(a, jitter: float = 0.0):
    """Lower-triangular Cholesky factor via the left-looking column
    algorithm: one fori_loop step per column, each a masked matvec."""
    n = a.shape[0]
    idx = jnp.arange(n)

    def step(j, l):
        # c = a[:, j] − L · L[j, :]ᵀ, restricted to columns < j.
        lj = jnp.where(idx < j, l[j, :], 0.0)
        c = a[:, j] - l @ lj
        d = jnp.sqrt(jnp.maximum(c[j] + jitter, 1e-12))
        col = jnp.where(idx >= j, c / d, 0.0)
        return l.at[:, j].set(col)

    return jax.lax.fori_loop(0, n, step, jnp.zeros_like(a))


def solve_lower_hlo(l, b):
    """Forward substitution L·w = b for b of shape [n] or [n, c]."""
    n = l.shape[0]

    def step(i, w):
        # w rows ≥ i are still zero, so l[i, :] @ w only sees solved rows.
        s = l[i, :] @ w
        return w.at[i].set((b[i] - s) / l[i, i])

    return jax.lax.fori_loop(0, n, step, jnp.zeros_like(b))
