"""Layer 1 — a *tunable* Pallas GEMM kernel: the real-workload objective
for the end-to-end example (`examples/tune_pallas_gemm.rs`).

This is the reproduction's stand-in for the paper's CLBlast GEMM: a tiled
matrix multiplication whose tile sizes (block_m, block_n, block_k) are the
tunable parameters. `make artifacts` AOT-lowers a grid of variants to HLO;
the Rust BO tuner executes them through PJRT and wall-clocks each variant —
a genuine (CPU-backed) auto-tuning loop across all three layers.

Restriction (spec stage, like CLBlast's): every block size must divide the
matrix dimension.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Problem size of the e2e example (kept small: interpret-mode CPU).
M = N = K = 256


def _gemm_body(x_ref, y_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o += x_tile @ y_tile.

    The output BlockSpec ignores the k grid axis, so the same output tile
    stays resident across the k loop and serves as the accumulator."""
    del n_k

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def tunable_gemm(x, y, *, block_m: int = 64, block_n: int = 64, block_k: int = 64):
    """z = x @ y with a (block_m, block_n, block_k) tiling schedule."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        f"blocks ({block_m},{block_n},{block_k}) must divide ({m},{n},{k})"
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_gemm_body, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))


def gemm_ref(x, y):
    return (x.astype(jnp.float32) @ y.astype(jnp.float32)).astype(jnp.float32)


def variant_grid():
    """The e2e example's search space: blocks dividing 256."""
    blocks = (32, 64, 128)
    ks = (32, 128)
    return [(bm, bn, bk) for bm in blocks for bn in blocks for bk in ks]
