"""Layer 1 — Pallas kernel: Matérn cross-covariance for exhaustive GP
prediction.

The optimizer's hot spot (paper §III-G: "we exhaustively predict every
discrete point in the model") is the [C, N] cross-covariance between every
candidate configuration and the training set, recomputed every iteration.

TPU mapping (DESIGN.md §Hardware-Adaptation): candidates are tiled along C
into VMEM-sized blocks via BlockSpec (the HBM↔VMEM schedule standing in
for the CUDA threadblock schedule); the pairwise squared distance is
expressed as |c|² + |x|² − 2·c·xᵀ so the −2·c·xᵀ term is a
[BLOCK_C, D] × [D, N] contraction feeding the MXU; the Matérn evaluation is
elementwise VPU work on the resident tile.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO that runs on any backend
(and is what `make artifacts` ships to the Rust runtime).

VMEM footprint at the default BLOCK_C=512, N=256, D=16 (fp32):
  cand tile 512×16×4 = 32 KiB, x 256×16×4 = 16 KiB,
  out tile 512×256×4 = 512 KiB, scratch ≈ out tile → ≈ 1.1 MiB ≪ 16 MiB.
MXU utilization estimate: the contraction is (512×16×256) MACs per tile —
K=16 underfills the 128×128 systolic array (≈12% MXU efficiency); the
kernel is VPU/memory-bound on the Matérn elementwise tail, which is the
expected regime for this memory-bound prediction workload.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Candidate-axis tile. 512 keeps the output tile at 512 KiB fp32 for
# N ≤ 256 — comfortably inside VMEM with double-buffering headroom.
BLOCK_C = 512

SQRT3 = 1.7320508075688772
SQRT5 = 2.23606797749979


def _matern_kernel_body(x_ref, c_ref, o_ref, *, lengthscale: float, nu: str):
    """One C-tile: distances via MXU-shaped contraction, then Matérn."""
    c = c_ref[...]  # [BLOCK_C, D]
    x = x_ref[...]  # [N, D]
    # |c−x|² = |c|² + |x|² − 2 c·xᵀ ; the matmul term hits the MXU.
    c2 = jnp.sum(c * c, axis=1, keepdims=True)  # [BLOCK_C, 1]
    x2 = jnp.sum(x * x, axis=1, keepdims=True).T  # [1, N]
    cross = jax.lax.dot_general(
        c, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [BLOCK_C, N]
    d2 = jnp.maximum(c2 + x2 - 2.0 * cross, 0.0)
    r = jnp.sqrt(d2) / lengthscale
    if nu == "matern32":
        s = SQRT3 * r
        o_ref[...] = (1.0 + s) * jnp.exp(-s)
    elif nu == "matern52":
        s = SQRT5 * r
        o_ref[...] = (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    elif nu == "rbf":
        o_ref[...] = jnp.exp(-0.5 * r * r)
    else:  # pragma: no cover - guarded by caller
        raise ValueError(f"unknown covariance '{nu}'")


@functools.partial(jax.jit, static_argnames=("lengthscale", "nu", "block_c"))
def matern_cross(cand, x, *, lengthscale: float = 1.5, nu: str = "matern32",
                 block_c: int = BLOCK_C):
    """Cross-covariance K(cand, x) → [C, N], tiled over the candidate axis.

    ``C`` must be a multiple of ``block_c`` (the AOT shapes guarantee it;
    tests pad). ``x`` is resident per tile (N ≤ a few hundred in BO).
    """
    c_total, d = cand.shape
    n, d2 = x.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert c_total % block_c == 0, f"C={c_total} not a multiple of {block_c}"
    grid = (c_total // block_c,)
    return pl.pallas_call(
        functools.partial(_matern_kernel_body, lengthscale=lengthscale, nu=nu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, d), lambda i: (0, 0)),          # x: resident
            pl.BlockSpec((block_c, d), lambda i: (i, 0)),    # cand: streamed
        ],
        out_specs=pl.BlockSpec((block_c, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_total, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x.astype(jnp.float32), cand.astype(jnp.float32))


def pad_candidates(cand, block_c: int = BLOCK_C):
    """Pad the candidate axis up to a multiple of ``block_c`` by repeating
    row 0 (results for padded rows are discarded by the caller)."""
    c = cand.shape[0]
    pad = (-c) % block_c
    if pad == 0:
        return cand, c
    return jnp.concatenate([cand, jnp.broadcast_to(cand[:1], (pad, cand.shape[1]))]), c
