"""Pure-jnp oracles for the Pallas kernel and the full GP fit+predict
graph. This is the CORE correctness signal: every artifact shipped to the
Rust runtime is validated against these references by pytest at build
time (and the Rust-native GP is cross-checked against the same math in
`rust/src/gp`)."""

import jax.numpy as jnp
from jax.scipy.linalg import cholesky, solve_triangular

SQRT3 = 1.7320508075688772
SQRT5 = 2.23606797749979


def cov(r, lengthscale: float, nu: str):
    """Stationary covariance at distance r (unit signal variance)."""
    s = r / lengthscale
    if nu == "matern32":
        t = SQRT3 * s
        return (1.0 + t) * jnp.exp(-t)
    if nu == "matern52":
        t = SQRT5 * s
        return (1.0 + t + t * t / 3.0) * jnp.exp(-t)
    if nu == "rbf":
        return jnp.exp(-0.5 * s * s)
    raise ValueError(f"unknown covariance '{nu}'")


def cdist(a, b):
    """Pairwise Euclidean distances [A, B] (stable direct form)."""
    diff = a[:, None, :] - b[None, :, :]
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))


def matern_cross_ref(cand, x, *, lengthscale: float = 1.5, nu: str = "matern32"):
    """Reference for kernels.gp_predict.matern_cross."""
    return cov(cdist(cand.astype(jnp.float32), x.astype(jnp.float32)),
               lengthscale, nu).astype(jnp.float32)


def gp_fit_predict_ref(x, yc, mask, cand, *, lengthscale: float = 1.5,
                       nu: str = "matern32", noise: float = 1e-6):
    """Reference masked-padded GP fit+predict (same contract as the
    artifact: yc centered with zeros on padding; returns centered mu)."""
    n = x.shape[0]
    k = cov(cdist(x, x), lengthscale, nu)
    k = k * (mask[:, None] * mask[None, :])
    k = k + jnp.diag(noise * mask + (1.0 - mask))
    chol = cholesky(k, lower=True)
    w = solve_triangular(chol, yc * mask, lower=True)
    ks = cov(cdist(cand, x), lengthscale, nu) * mask[None, :]
    v = solve_triangular(chol, ks.T, lower=True)  # [N, C]
    mu = v.T @ w
    var = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    return mu.astype(jnp.float32), var.astype(jnp.float32)


def dense_gp_ref(x, y, cand, *, lengthscale: float = 1.5, nu: str = "matern32",
                 noise: float = 1e-6):
    """Unpadded textbook GP (centered internally) — ground truth for the
    masking logic."""
    y_mean = jnp.mean(y)
    n = x.shape[0]
    k = cov(cdist(x, x), lengthscale, nu) + noise * jnp.eye(n)
    chol = cholesky(k, lower=True)
    w = solve_triangular(chol, y - y_mean, lower=True)
    ks = cov(cdist(cand, x), lengthscale, nu)
    v = solve_triangular(chol, ks.T, lower=True)
    mu = y_mean + v.T @ w
    var = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    return mu, var
