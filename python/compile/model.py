"""Layer 2 — the JAX GP fit+predict graph served to the Rust coordinator.

One jitted function per (N, C) padding bucket: fit a fixed-lengthscale
Matérn GP on up to N (masked) observations and predict mean/variance over
C candidate configurations. The cross-covariance hot spot calls the
Layer-1 Pallas kernel so it lowers into the same HLO module.

Interface contract with `rust/src/runtime/artifacts.rs`:
  inputs  (f32): x[N,16], yc[N] (centered, 0 on padding), mask[N] (1/0),
                 cand[C,16]
  outputs (f32): tuple (mu[C] in centered units, var[C])

Padded rows are neutralized algebraically (no branching in the graph):
masked K rows/cols collapse to identity rows, so the Cholesky factor of
the padded system embeds the factor of the real system exactly.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import gp_predict
from compile.kernels.ref import cdist, cov
from compile.linalg_hlo import cholesky_hlo, solve_lower_hlo

# Padding contract shared with the Rust side (runtime/artifacts.rs D_PAD).
D_PAD = 16
N_BUCKETS = (32, 64, 128, 256)
C_CHUNK = 4096


@functools.partial(jax.jit, static_argnames=("lengthscale", "nu", "noise"))
def gp_fit_predict(x, yc, mask, cand, *, lengthscale: float = 1.5,
                   nu: str = "matern32", noise: float = 1e-6):
    """Masked GP fit + exhaustive prediction (see module docstring)."""
    x = x.astype(jnp.float32)
    yc = yc.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    cand = cand.astype(jnp.float32)

    # K over the padded training block (N is small: plain jnp, not Pallas).
    k = cov(cdist(x, x), lengthscale, nu)
    k = k * (mask[:, None] * mask[None, :])
    k = k + jnp.diag(noise * mask + (1.0 - mask))
    # Pure-HLO factorization/substitution: the LAPACK custom-calls that
    # jax.scipy.linalg would emit are not executable by the runtime's
    # xla_extension (see compile/linalg_hlo.py).
    chol = cholesky_hlo(k)
    w = solve_lower_hlo(chol, yc * mask)

    # Cross-covariance over all candidates — the Pallas hot path.
    ks = gp_predict.matern_cross(cand, x, lengthscale=lengthscale, nu=nu)
    ks = ks * mask[None, :]

    v = solve_lower_hlo(chol, ks.T)  # [N, C]
    mu = v.T @ w
    var = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-12)
    return mu, var


def example_args(n: int, c: int = C_CHUNK, d: int = D_PAD):
    """Shape specs for AOT lowering of one bucket."""
    f = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, d), f),   # x
        jax.ShapeDtypeStruct((n,), f),     # yc
        jax.ShapeDtypeStruct((n,), f),     # mask
        jax.ShapeDtypeStruct((c, d), f),   # cand
    )
