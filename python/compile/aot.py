"""AOT compile path: lower the Layer-2 graphs (which embed the Layer-1
Pallas kernels) to HLO **text** artifacts for the Rust PJRT runtime.

Run once by `make artifacts`; Python never runs on the tuning path.

HLO text — NOT `lowered.compile()`/serialized protos — is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
the runtime's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and resources/aot_recipe.md).

Artifacts:
  gp_fitpredict_n{N}_c{C}.hlo.txt   GP surrogate buckets (runtime contract
                                    in rust/src/runtime/artifacts.rs)
  pallas_gemm_m{BM}_n{BN}_k{BK}.hlo.txt
                                    tunable-GEMM variants for the e2e
                                    example (examples/tune_pallas_gemm.rs)
"""

import argparse
import functools
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import tunable_gemm


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(path: str, lowered) -> None:
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def emit_gp_buckets(out_dir: str, lengthscale: float, nu: str, noise: float) -> None:
    for n in model.N_BUCKETS:
        fn = functools.partial(model.gp_fit_predict,
                               lengthscale=lengthscale, nu=nu, noise=noise)
        lowered = jax.jit(fn).lower(*model.example_args(n))
        emit(os.path.join(out_dir, f"gp_fitpredict_n{n}_c{model.C_CHUNK}.hlo.txt"),
             lowered)


def emit_gemm_variants(out_dir: str) -> None:
    spec = jax.ShapeDtypeStruct((tunable_gemm.M, tunable_gemm.K), jnp.float32)
    spec2 = jax.ShapeDtypeStruct((tunable_gemm.K, tunable_gemm.N), jnp.float32)
    for bm, bn, bk in tunable_gemm.variant_grid():
        fn = functools.partial(tunable_gemm.tunable_gemm,
                               block_m=bm, block_n=bn, block_k=bk)
        lowered = jax.jit(fn).lower(spec, spec2)
        emit(os.path.join(out_dir, f"pallas_gemm_m{bm}_n{bn}_k{bk}.hlo.txt"), lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--lengthscale", type=float, default=1.5,
                    help="Matérn lengthscale (Table I CV default)")
    ap.add_argument("--nu", default="matern32",
                    choices=["matern32", "matern52", "rbf"])
    ap.add_argument("--noise", type=float, default=1e-6)
    ap.add_argument("--skip-gemm", action="store_true",
                    help="only emit the GP surrogate buckets")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    emit_gp_buckets(args.out, args.lengthscale, args.nu, args.noise)
    if not args.skip_gemm:
        emit_gemm_variants(args.out)
    print("AOT artifacts complete", file=sys.stderr)


if __name__ == "__main__":
    main()
