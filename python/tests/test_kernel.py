"""L1 correctness: the Pallas Matérn cross-covariance kernel vs the
pure-jnp oracle, including hypothesis sweeps over shapes, dtypes, and
covariance hyperparameters."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gp_predict import matern_cross, pad_candidates
from compile.kernels.ref import matern_cross_ref

RNG = np.random.default_rng(1234)


def rand(shape, dtype=np.float32, scale=1.0):
    return (RNG.random(shape) * scale).astype(dtype)


@pytest.mark.parametrize("nu", ["matern32", "matern52", "rbf"])
def test_matches_ref_all_covariances(nu):
    cand = rand((512, 16))
    x = rand((64, 16))
    got = matern_cross(jnp.array(cand), jnp.array(x), lengthscale=1.5, nu=nu,
                       block_c=256)
    want = matern_cross_ref(jnp.array(cand), jnp.array(x), lengthscale=1.5, nu=nu)
    np.testing.assert_allclose(got, want, atol=5e-6)


def test_unit_diagonal_at_zero_distance():
    x = rand((32, 16))
    got = matern_cross(jnp.array(x[:32]), jnp.array(x), block_c=32)
    # k(x_i, x_i) = 1.
    np.testing.assert_allclose(np.diag(np.asarray(got)), 1.0, atol=1e-5)


def test_values_in_unit_interval():
    cand = rand((256, 16), scale=3.0)
    x = rand((16, 16), scale=3.0)
    got = np.asarray(matern_cross(jnp.array(cand), jnp.array(x), block_c=128))
    assert got.min() >= 0.0 and got.max() <= 1.0 + 1e-6


@settings(max_examples=25, deadline=None)
@given(
    c_tiles=st.integers(min_value=1, max_value=4),
    block_c=st.sampled_from([32, 64, 128]),
    n=st.integers(min_value=1, max_value=96),
    d=st.integers(min_value=1, max_value=16),
    ls=st.floats(min_value=0.3, max_value=4.0),
    nu=st.sampled_from(["matern32", "matern52", "rbf"]),
)
def test_hypothesis_shape_sweep(c_tiles, block_c, n, d, ls, nu):
    """The kernel must agree with the oracle for any tile count, training
    size, dimensionality, lengthscale, and covariance family."""
    c = c_tiles * block_c
    cand = rand((c, d))
    x = rand((n, d))
    got = matern_cross(jnp.array(cand), jnp.array(x), lengthscale=float(ls),
                       nu=nu, block_c=block_c)
    want = matern_cross_ref(jnp.array(cand), jnp.array(x), lengthscale=float(ls),
                            nu=nu)
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from([np.float32, np.float64, np.float16]))
def test_hypothesis_dtype_sweep(dtype):
    """Inputs of any float dtype are accepted and produce f32 outputs."""
    cand = rand((128, 8)).astype(dtype)
    x = rand((16, 8)).astype(dtype)
    got = matern_cross(jnp.array(cand), jnp.array(x), block_c=64)
    assert got.dtype == jnp.float32
    want = matern_cross_ref(jnp.array(cand, jnp.float32), jnp.array(x, jnp.float32))
    np.testing.assert_allclose(got, want, atol=5e-3 if dtype == np.float16 else 1e-5)


def test_pad_candidates_roundtrip():
    cand = jnp.array(rand((100, 4)))
    padded, real = pad_candidates(cand, block_c=64)
    assert real == 100
    assert padded.shape == (128, 4)
    np.testing.assert_array_equal(np.asarray(padded[:100]), np.asarray(cand))
    # Padding repeats row 0 (valid inputs, discarded outputs).
    np.testing.assert_array_equal(np.asarray(padded[100:]),
                                  np.tile(np.asarray(cand[:1]), (28, 1)))


def test_rejects_non_multiple_block():
    with pytest.raises(AssertionError):
        matern_cross(jnp.zeros((100, 4)), jnp.zeros((8, 4)), block_c=64)
