"""L1 correctness: the tunable Pallas GEMM (e2e example objective) vs
jnp matmul across its whole variant grid, plus hypothesis sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.tunable_gemm import gemm_ref, tunable_gemm, variant_grid, M, N, K

RNG = np.random.default_rng(7)


def test_variant_grid_is_valid():
    g = variant_grid()
    assert len(g) == 18
    for bm, bn, bk in g:
        assert M % bm == 0 and N % bn == 0 and K % bk == 0


@pytest.mark.parametrize("bm,bn,bk", variant_grid())
def test_every_variant_matches_ref(bm, bn, bk):
    x = jnp.array(RNG.standard_normal((M, K)), jnp.float32)
    y = jnp.array(RNG.standard_normal((K, N)), jnp.float32)
    z = tunable_gemm(x, y, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(z, gemm_ref(x, y), atol=1e-3, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([16, 32, 64]),
    bn=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    scale=st.floats(min_value=0.1, max_value=10.0),
)
def test_hypothesis_small_matrices(bm, bn, bk, scale):
    m, n, k = 64, 64, 64
    x = jnp.array(RNG.standard_normal((m, k)) * scale, jnp.float32)
    y = jnp.array(RNG.standard_normal((k, n)) * scale, jnp.float32)
    z = tunable_gemm(x, y, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(z, gemm_ref(x, y), atol=2e-2 * scale * scale,
                               rtol=1e-3)


def test_rejects_non_dividing_blocks():
    x = jnp.zeros((64, 64))
    with pytest.raises(AssertionError):
        tunable_gemm(x, x, block_m=48, block_n=64, block_k=64)
