"""L2 correctness: the masked/padded GP fit+predict graph vs the textbook
dense GP, plus the padding-neutrality invariant the Rust runtime relies
on."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import dense_gp_ref, gp_fit_predict_ref

RNG = np.random.default_rng(99)


def make_case(n_real, n_pad, c, d=16):
    x = np.zeros((n_pad, d), np.float32)
    x[:n_real] = RNG.random((n_real, d))
    y = RNG.random(n_real).astype(np.float32) * 10.0 + 3.0
    yc = np.zeros(n_pad, np.float32)
    yc[:n_real] = y - y.mean()
    mask = np.zeros(n_pad, np.float32)
    mask[:n_real] = 1.0
    cand = RNG.random((c, d)).astype(np.float32)
    return x, y, yc, mask, cand


def test_matches_masked_reference():
    x, _, yc, mask, cand = make_case(40, 64, 512)
    mu, var = model.gp_fit_predict(jnp.array(x), jnp.array(yc), jnp.array(mask),
                                   jnp.array(cand))
    mu_r, var_r = gp_fit_predict_ref(jnp.array(x), jnp.array(yc),
                                     jnp.array(mask), jnp.array(cand))
    np.testing.assert_allclose(mu, mu_r, atol=2e-5)
    np.testing.assert_allclose(var, var_r, atol=2e-5)


def test_padding_is_neutral():
    """The runtime contract: padding to the bucket must not change the
    posterior — compare against the dense unpadded GP."""
    x, y, yc, mask, cand = make_case(30, 64, 512)
    mu, var = model.gp_fit_predict(jnp.array(x), jnp.array(yc), jnp.array(mask),
                                   jnp.array(cand))
    mu_d, var_d = dense_gp_ref(jnp.array(x[:30]), jnp.array(y), jnp.array(cand))
    np.testing.assert_allclose(np.asarray(mu) + y.mean(), mu_d, atol=5e-4)
    np.testing.assert_allclose(var, var_d, atol=5e-4)


def test_variance_properties():
    x, _, yc, mask, cand = make_case(20, 32, 512)
    # Include the training points themselves among the candidates.
    cand[:20] = x[:20]
    mu, var = model.gp_fit_predict(jnp.array(x), jnp.array(yc), jnp.array(mask),
                                   jnp.array(cand))
    var = np.asarray(var)
    assert var.min() > 0.0
    assert var.max() <= 1.0 + 1e-5
    # Variance at training points ≈ noise (tiny), far away ≈ prior (1).
    assert var[:20].max() < 1e-3
    far = np.full((512, 16), 50.0, np.float32)
    _, var_far = model.gp_fit_predict(jnp.array(x), jnp.array(yc),
                                      jnp.array(mask), jnp.array(far))
    assert np.asarray(var_far).min() > 0.99


@settings(max_examples=8, deadline=None)
@given(
    n_real=st.integers(min_value=2, max_value=64),
    bucket=st.sampled_from([64, 128]),
    nu=st.sampled_from(["matern32", "matern52"]),
    ls=st.floats(min_value=0.5, max_value=3.0),
)
def test_hypothesis_bucket_sweep(n_real, bucket, nu, ls):
    if n_real > bucket:
        n_real = bucket
    x, y, yc, mask, cand = make_case(n_real, bucket, 512)
    mu, var = model.gp_fit_predict(jnp.array(x), jnp.array(yc), jnp.array(mask),
                                   jnp.array(cand), lengthscale=float(ls), nu=nu)
    mu_d, var_d = dense_gp_ref(jnp.array(x[:n_real]), jnp.array(y),
                               jnp.array(cand), lengthscale=float(ls), nu=nu)
    np.testing.assert_allclose(np.asarray(mu) + y.mean(), mu_d, atol=2e-3)
    np.testing.assert_allclose(var, var_d, atol=2e-3)


def test_example_args_shapes():
    args = model.example_args(64)
    assert args[0].shape == (64, model.D_PAD)
    assert args[1].shape == (64,)
    assert args[3].shape == (model.C_CHUNK, model.D_PAD)


@pytest.mark.parametrize("n", model.N_BUCKETS)
def test_all_buckets_lower_to_hlo(n):
    """Every artifact bucket must lower to parseable HLO text."""
    import functools
    import jax
    from compile.aot import to_hlo_text

    fn = functools.partial(model.gp_fit_predict)
    lowered = jax.jit(fn).lower(*model.example_args(n))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and len(text) > 1000
