//! End-to-end driver across ALL THREE LAYERS on a real workload:
//!
//!   Layer 1 (Pallas)  — `python/compile/kernels/tunable_gemm.py` defines a
//!                       tiled GEMM whose block sizes are tunable;
//!   Layer 2 (JAX/AOT) — `make artifacts` lowers every variant of the
//!                       (block_m, block_n, block_k) grid to HLO text;
//!   Layer 3 (Rust)    — this binary loads the variants through PJRT,
//!                       *wall-clocks real executions* as the objective,
//!                       and lets the paper's BO strategy tune the tiling.
//!
//! This is the reproduction's analogue of tuning the paper's CLBlast GEMM
//! on a live device (CPU-backed via interpret-mode Pallas). Results are
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example tune_pallas_gemm

use std::sync::Mutex;

use ktbo::bo::{Acq, BoConfig, BoStrategy};
use ktbo::objective::{Eval, Objective};
use ktbo::space::{Param, SearchSpace};
use ktbo::strategies::registry::by_name;
use ktbo::strategies::Strategy;
use ktbo::util::rng::Rng;

const M: usize = 256;

struct Inner {
    exes: Vec<xla::PjRtLoadedExecutable>,
    x: xla::Literal,
    y: xla::Literal,
    /// Measured medians (ms) per variant, for the final report.
    measured: Vec<Option<f64>>,
}

/// Objective = real PJRT execution time of the variant's artifact.
struct PjrtGemmObjective {
    space: SearchSpace,
    inner: Mutex<Inner>,
}

// SAFETY: all PJRT handles live behind the Mutex; the underlying PJRT CPU
// objects are thread-safe (same argument as runtime::XlaContext).
unsafe impl Send for PjrtGemmObjective {}
unsafe impl Sync for PjrtGemmObjective {}

impl PjrtGemmObjective {
    fn load(dir: &str) -> anyhow::Result<Self> {
        let space = SearchSpace::build(
            "pallas_gemm",
            vec![
                Param::ints("block_m", &[32, 64, 128]),
                Param::ints("block_n", &[32, 64, 128]),
                Param::ints("block_k", &[32, 128]),
            ],
            &[],
        );
        let client = xla::PjRtClient::cpu()?;
        let mut exes = Vec::with_capacity(space.len());
        for i in 0..space.len() {
            let a = space.assignment(i);
            let path = format!(
                "{dir}/pallas_gemm_m{}_n{}_k{}.hlo.txt",
                a.i("block_m"),
                a.i("block_n"),
                a.i("block_k")
            );
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            exes.push(client.compile(&xla::XlaComputation::from_proto(&proto))?);
        }
        // Fixed operands for every measurement.
        let n = M * M;
        let xs: Vec<f32> = (0..n).map(|i| ((i % 311) as f32) * 0.01 - 1.5).collect();
        let ys: Vec<f32> = (0..n).map(|i| ((i % 197) as f32) * 0.013 - 1.2).collect();
        let x = xla::Literal::vec1(&xs).reshape(&[M as i64, M as i64])?;
        let y = xla::Literal::vec1(&ys).reshape(&[M as i64, M as i64])?;
        let measured = vec![None; space.len()];
        Ok(PjrtGemmObjective { space, inner: Mutex::new(Inner { exes, x, y, measured }) })
    }

    fn report(&self) {
        let inner = self.inner.lock().unwrap();
        println!("\nmeasured variants:");
        for i in 0..self.space.len() {
            if let Some(ms) = inner.measured[i] {
                println!("  {:<44} {:8.3} ms", self.space.describe(i), ms);
            }
        }
    }
}

impl Objective for PjrtGemmObjective {
    fn space(&self) -> &SearchSpace {
        &self.space
    }

    fn evaluate(&self, idx: usize, _rng: &mut Rng) -> Eval {
        let mut inner = self.inner.lock().unwrap();
        // Median of 5 timed executions (1 warm-up), like Kernel Tuner's
        // repeated benchmarking of each configuration.
        let mut times = Vec::with_capacity(5);
        for rep in 0..6 {
            let t0 = std::time::Instant::now();
            let x = inner.x.clone();
            let y = inner.y.clone();
            let result = match inner.exes[idx].execute::<xla::Literal>(&[x, y]) {
                Ok(r) => r,
                Err(_) => return Eval::RuntimeError,
            };
            let _ = result[0][0].to_literal_sync();
            if rep > 0 {
                times.push(t0.elapsed().as_secs_f64() * 1e3);
            }
        }
        let ms = ktbo::util::linalg::median(&times);
        inner.measured[idx] = Some(ms);
        Eval::Valid(ms)
    }
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("loading + compiling Pallas GEMM variants from {dir}/ ...");
    let obj = PjrtGemmObjective::load(&dir)?;
    println!("{} variants over parameters (block_m, block_n, block_k)", obj.space().len());

    // Tune with BO (small space → small budget: 6 init + 6 BO steps),
    // then exhaustively measure the rest to verify BO's pick.
    let mut cfg = BoConfig::single(Acq::Ei);
    cfg.init_samples = 6;
    let bo = BoStrategy::new("ei", cfg);
    let mut rng = Rng::new(2021);
    let t0 = std::time::Instant::now();
    let trace = bo.run(&obj, 12, &mut rng);
    let (best_idx, best_ms) = trace.best().expect("tuning found a valid config");
    println!(
        "\nBO picked {} -> {:.3} ms ({} real PJRT evaluations, wall {:.2?})",
        obj.space().describe(best_idx),
        best_ms,
        trace.len(),
        t0.elapsed()
    );

    // Ground truth: measure everything.
    let random = by_name("random").unwrap();
    let mut rng2 = Rng::new(1);
    let _ = random.run(&obj, obj.space().len(), &mut rng2);
    obj.report();

    let inner_best = {
        let best = obj
            .inner
            .lock()
            .unwrap()
            .measured
            .iter()
            .enumerate()
            .filter_map(|(i, m)| m.map(|v| (i, v)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        best
    };
    println!(
        "\nexhaustive optimum: {} -> {:.3} ms; BO best within {:.1}% after {} evals",
        obj.space().describe(inner_best.0),
        inner_best.1,
        100.0 * (best_ms / inner_best.1 - 1.0).max(0.0),
        trace.len(),
    );
    Ok(())
}
