//! Bring your own kernel: the paper's "unseen kernels" scenario (§IV-E)
//! from the user's side. Defines a brand-new tunable kernel — a fused
//! softmax-attention row kernel — against the `KernelModel` trait with a
//! declarative `SpaceSpec` (builder API + restriction DSL), then loads
//! the *same* space from a JSON file (`examples/spaces/
//! softmax_attention_row.json`) and tunes on the file-defined twin:
//! new scenarios need zero Rust code once a model exists, and value sets
//! or restrictions can be varied from a file alone
//! (`ktbo tune <kernel> <gpu> --space file.json` does the same for the
//! built-in kernels). Nothing in the library knows this kernel;
//! everything (restrictions, invalidity staging, roofline timing, BO)
//! composes.
//!
//!     cargo run --release --example custom_kernel

use ktbo::gpusim::device::Device;
use ktbo::gpusim::kernels::KernelModel;
use ktbo::gpusim::occupancy::Resources;
use ktbo::gpusim::timing::WorkEstimate;
use ktbo::gpusim::SimulatedSpace;
use ktbo::objective::{Objective, TableObjective};
use ktbo::space::{Assignment, Expr, SpaceSpec};
use ktbo::strategies::registry::by_name;
use ktbo::util::rng::Rng;

/// Rows × head-dim of the attention problem.
const ROWS: usize = 16384;
const HEAD: usize = 128;

struct SoftmaxAttentionRow;

impl KernelModel for SoftmaxAttentionRow {
    fn name(&self) -> &'static str {
        "softmax_attention_row"
    }

    fn id(&self) -> u64 {
        0x50f7
    }

    fn spec(&self, _dev: &Device) -> SpaceSpec {
        SpaceSpec::new("softmax_attention_row")
            .ints("block_size_x", &[32, 64, 128, 256, 512, 1024])
            .ints("rows_per_block", &[1, 2, 4, 8, 16])
            .ints("vector_width", &[1, 2, 4])
            .bools("use_online_softmax")
            .bools("stage_kv_in_smem")
            .restrict_named(
                "one warp per row minimum",
                Expr::var("block_size_x").div(Expr::var("rows_per_block")).ge(Expr::lit(32)),
            )
    }

    fn resources(&self, a: &Assignment, _dev: &Device) -> Resources {
        let bsx = a.i("block_size_x") as usize;
        let rpb = a.i("rows_per_block") as usize;
        let smem = if a.b("stage_kv_in_smem") { rpb * HEAD * 2 * 4 } else { 0 };
        let regs = 28 + 4 * a.i("vector_width") as usize + if a.b("use_online_softmax") { 10 } else { 0 };
        Resources {
            threads_per_block: bsx,
            smem_bytes: smem,
            regs_per_thread: regs,
            grid_blocks: ROWS.div_ceil(rpb),
        }
    }

    fn work(&self, a: &Assignment, _dev: &Device) -> WorkEstimate {
        let cells = (ROWS * HEAD) as f64;
        // Two passes without online softmax, one with (more flops/pass).
        let (passes, ops) = if a.b("use_online_softmax") { (1.0, 14.0) } else { (2.0, 9.0) };
        let vw_eff: f64 = match a.i("vector_width") {
            1 => 0.8,
            2 => 0.95,
            _ => 1.0,
        };
        WorkEstimate {
            flops: cells * ops * passes,
            dram_bytes: cells * 4.0 * (passes + 1.0) / if a.b("stage_kv_in_smem") { 1.6 } else { 1.0 },
            compute_efficiency: (0.85 * vw_eff).clamp(0.05, 1.0),
            memory_efficiency: 0.9,
            ..Default::default()
        }
    }
}

fn main() {
    let device = Device::a100();

    // The builder-defined space (what `KernelModel::spec` declares)…
    let built_in = SoftmaxAttentionRow.spec(&device).build();

    // …and its file-defined twin, parsed from JSON at run time. The two
    // must agree exactly: spaces are data now.
    let spec = SpaceSpec::parse(include_str!("spaces/softmax_attention_row.json"))
        .expect("parse space file");
    let from_file = spec.build();
    assert_eq!(
        from_file.len(),
        built_in.len(),
        "file-defined space must restrict to the builder-defined size"
    );
    println!(
        "space '{}' from examples/spaces/softmax_attention_row.json: \
         {} params, Cartesian {}, restricted {} (matches builder: yes)",
        from_file.name,
        from_file.dims(),
        from_file.cartesian_size,
        from_file.len(),
    );

    // Simulate the file-defined space through the kernel's analytical
    // model and tune it with the strategy zoo — end to end from a file.
    let sim = SimulatedSpace::build_with_space(&SoftmaxAttentionRow, &device, from_file);
    println!(
        "custom kernel '{}' on {}: {} configs, {} invalid, min {:.4} ms",
        sim.kernel_name,
        device.name,
        sim.space.len(),
        sim.invalid_count(),
        sim.global_minimum().1
    );
    let obj = TableObjective::from_sim(sim);
    let global = obj.known_minimum().unwrap();

    println!("\n{:<22} {:>10} {:>12}", "strategy", "best (ms)", "vs optimum");
    for name in ["advanced_multi", "multi", "ei", "genetic_algorithm", "mls", "simulated_annealing", "random"] {
        let s = by_name(name).unwrap();
        let mut rng = Rng::new(7);
        let trace = s.run(&obj, 120, &mut rng);
        let best = trace.best().map(|(_, v)| v).unwrap_or(f64::NAN);
        println!("{:<22} {:>10.4} {:>11.2}%", name, best, 100.0 * (best / global - 1.0));
    }
}
