//! Bring your own kernel: the paper's "unseen kernels" scenario (§IV-E)
//! from the user's side. Defines a brand-new tunable kernel — a fused
//! softmax-attention row kernel — against the `KernelModel` trait,
//! simulates its search space on the A100, and tunes it with the full
//! strategy zoo. Nothing in the library knows this kernel; everything
//! (restrictions, invalidity staging, roofline timing, BO) composes.
//!
//!     cargo run --release --example custom_kernel

use ktbo::gpusim::device::Device;
use ktbo::gpusim::kernels::KernelModel;
use ktbo::gpusim::occupancy::Resources;
use ktbo::gpusim::timing::WorkEstimate;
use ktbo::gpusim::SimulatedSpace;
use ktbo::objective::{Objective, TableObjective};
use ktbo::space::{Assignment, Param, Restriction};
use ktbo::strategies::registry::by_name;
use ktbo::util::rng::Rng;

/// Rows × head-dim of the attention problem.
const ROWS: usize = 16384;
const HEAD: usize = 128;

struct SoftmaxAttentionRow;

impl KernelModel for SoftmaxAttentionRow {
    fn name(&self) -> &'static str {
        "softmax_attention_row"
    }

    fn id(&self) -> u64 {
        0x50f7
    }

    fn params(&self) -> Vec<Param> {
        vec![
            Param::ints("block_size_x", &[32, 64, 128, 256, 512, 1024]),
            Param::ints("rows_per_block", &[1, 2, 4, 8, 16]),
            Param::ints("vector_width", &[1, 2, 4]),
            Param::bools("use_online_softmax"),
            Param::bools("stage_kv_in_smem"),
        ]
    }

    fn restrictions(&self, _dev: &Device) -> Vec<Restriction> {
        vec![Restriction::new("one warp per row minimum", |a| {
            a.i("block_size_x") / a.i("rows_per_block") >= 32
        })]
    }

    fn resources(&self, a: &Assignment, _dev: &Device) -> Resources {
        let bsx = a.i("block_size_x") as usize;
        let rpb = a.i("rows_per_block") as usize;
        let smem = if a.b("stage_kv_in_smem") { rpb * HEAD * 2 * 4 } else { 0 };
        let regs = 28 + 4 * a.i("vector_width") as usize + if a.b("use_online_softmax") { 10 } else { 0 };
        Resources {
            threads_per_block: bsx,
            smem_bytes: smem,
            regs_per_thread: regs,
            grid_blocks: ROWS.div_ceil(rpb),
        }
    }

    fn work(&self, a: &Assignment, _dev: &Device) -> WorkEstimate {
        let cells = (ROWS * HEAD) as f64;
        // Two passes without online softmax, one with (more flops/pass).
        let (passes, ops) = if a.b("use_online_softmax") { (1.0, 14.0) } else { (2.0, 9.0) };
        let vw_eff: f64 = match a.i("vector_width") {
            1 => 0.8,
            2 => 0.95,
            _ => 1.0,
        };
        WorkEstimate {
            flops: cells * ops * passes,
            dram_bytes: cells * 4.0 * (passes + 1.0) / if a.b("stage_kv_in_smem") { 1.6 } else { 1.0 },
            compute_efficiency: (0.85 * vw_eff).clamp(0.05, 1.0),
            memory_efficiency: 0.9,
            ..Default::default()
        }
    }
}

fn main() {
    let device = Device::a100();
    let sim = SimulatedSpace::build(&SoftmaxAttentionRow, &device);
    println!(
        "custom kernel '{}' on {}: {} configs, {} invalid, min {:.4} ms",
        sim.kernel_name,
        device.name,
        sim.space.len(),
        sim.invalid_count(),
        sim.global_minimum().1
    );
    let obj = TableObjective::from_sim(sim);
    let global = obj.known_minimum().unwrap();

    println!("\n{:<22} {:>10} {:>12}", "strategy", "best (ms)", "vs optimum");
    for name in ["advanced_multi", "multi", "ei", "genetic_algorithm", "mls", "simulated_annealing", "random"] {
        let s = by_name(name).unwrap();
        let mut rng = Rng::new(7);
        let trace = s.run(&obj, 120, &mut rng);
        let best = trace.best().map(|(_, v)| v).unwrap_or(f64::NAN);
        println!("{:<22} {:>10.4} {:>11.2}%", name, best, 100.0 * (best / global - 1.0));
    }
}
