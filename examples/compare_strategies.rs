//! Strategy shoot-out on one kernel — a miniature of the paper's Fig. 1b:
//! the 2D Convolution kernel on the GTX Titan X, all seven strategies,
//! repeated runs, MAE + mean-deviation summary.
//!
//!     cargo run --release --example compare_strategies [-- --repeats N]

use std::sync::Arc;

use ktbo::harness::figures::objective_for;
use ktbo::harness::metrics::mean_deviation_factor;
use ktbo::harness::runner::run_strategy;
use ktbo::gpusim::device::Device;
use ktbo::objective::Objective;
use ktbo::util::cli::Args;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let repeats = args.usize_or("repeats", 7);
    let device = Device::gtx_titan_x();
    let obj = objective_for("convolution", &device);
    println!(
        "Convolution on {}: {} configs, minimum {:.3} ms, {repeats} repeats each\n",
        device.name,
        obj.space().len(),
        obj.known_minimum().unwrap()
    );

    let strategies =
        ["ei", "multi", "advanced_multi", "random", "simulated_annealing", "mls", "genetic_algorithm"];
    let mut maes = Vec::new();
    println!("{:<22} {:>10} {:>10} {:>12}", "strategy", "MAE", "±std", "final best");
    for s in strategies {
        let out = run_strategy(&Arc::clone(&obj), s, 220, repeats, 99, 0);
        let final_best = out.mean_curve[out.mean_curve.len() - 1];
        println!("{:<22} {:>10.4} {:>10.4} {:>12.4}", s, out.mae.mean, out.mae.std, final_best);
        maes.push(out.mae.mean);
    }
    let mdf = mean_deviation_factor(&[maes]);
    println!("\ndeviation factors (lower is better):");
    for (s, (m, _)) in strategies.iter().zip(mdf) {
        println!("  {s:<22} {m:.3}");
    }
}
