//! Strategy shoot-out on one kernel — a miniature of the paper's Fig. 1b:
//! the 2D Convolution kernel on the GTX Titan X, all seven strategies,
//! repeated runs, MAE + mean-deviation summary.
//!
//! Runs through the sweep orchestrator: every (strategy, repeat) cell is
//! an independent session interleaved on one shared worker pool, and the
//! per-cell seeding matches `ktbo sweep`, so the numbers below line up
//! with sweep records for the same seed.
//!
//!     cargo run --release --example compare_strategies [-- --repeat-scale F --threads N]

use ktbo::gpusim::device::Device;
use ktbo::harness::figures::objective_for;
use ktbo::harness::metrics::mean_deviation_factor;
use ktbo::harness::runner::{objective_id, run_comparison};
use ktbo::objective::Objective;
use ktbo::util::cli::Args;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let repeat_scale = args.f64_or("repeat-scale", 0.2);
    let threads = args.usize_or("threads", ktbo::util::pool::default_threads());
    let device = Device::gtx_titan_x();
    let obj = objective_for("convolution", &device);
    let obj_id = objective_id("convolution", device.name);
    println!(
        "Convolution on {}: {} configs, minimum {:.3} ms, repeat scale {repeat_scale}\n",
        device.name,
        obj.space().len(),
        obj.known_minimum().unwrap()
    );

    let strategies =
        ["ei", "multi", "advanced_multi", "random", "simulated_annealing", "mls", "genetic_algorithm"];
    let outcomes = run_comparison(&obj, &obj_id, &strategies, 220, repeat_scale, 99, threads);
    println!("{:<22} {:>8} {:>10} {:>10} {:>12}", "strategy", "repeats", "MAE", "±std", "final best");
    let mut maes = Vec::new();
    for o in &outcomes {
        let final_best = o.mean_curve[o.mean_curve.len() - 1];
        println!(
            "{:<22} {:>8} {:>10.4} {:>10.4} {:>12.4}",
            o.name,
            o.maes.len(),
            o.mae.mean,
            o.mae.std,
            final_best
        );
        maes.push(o.mae.mean);
    }
    let mdf = mean_deviation_factor(&[maes]);
    println!("\ndeviation factors (lower is better):");
    for (s, (m, _)) in strategies.iter().zip(mdf) {
        println!("  {s:<22} {m:.3}");
    }
}
