//! Quickstart: tune the CLBlast-style GEMM kernel on the simulated
//! GTX Titan X with the paper's best strategy (`advanced multi`).
//!
//!     cargo run --release --example quickstart
//!
//! This is the 30-second tour of the public API: build a simulated search
//! space (Kernel Tuner "simulation mode"), pick a strategy from the
//! registry, run it with the paper's §IV-A budget, inspect the result.

use ktbo::gpusim::device::Device;
use ktbo::gpusim::kernels::kernel_by_name;
use ktbo::gpusim::SimulatedSpace;
use ktbo::objective::{Objective, TableObjective};
use ktbo::strategies::registry::by_name;
use ktbo::util::rng::Rng;

fn main() {
    // 1. A tunable kernel + a device = a search space and an objective.
    let kernel = kernel_by_name("gemm").unwrap();
    let device = Device::gtx_titan_x();
    let sim = SimulatedSpace::build(kernel.as_ref(), &device);
    println!(
        "GEMM on {}: {} configurations ({} invalid), global minimum {:.3} ms",
        device.name,
        sim.space.len(),
        sim.invalid_count(),
        sim.global_minimum().1
    );
    let objective = TableObjective::from_sim(sim);

    // 2. Pick a strategy and run with the paper's budget: 20 initial
    //    samples + 200 optimization evaluations.
    let strategy = by_name("advanced_multi").unwrap();
    let mut rng = Rng::new(2021);
    let t0 = std::time::Instant::now();
    let trace = strategy.run(&objective, 220, &mut rng);

    // 3. Inspect.
    let (best_idx, best) = trace.best().expect("found a valid configuration");
    let global = objective.known_minimum().unwrap();
    println!(
        "advanced multi: best {:.3} ms after {} evaluations ({:.1}% above optimum, {:?})",
        best,
        trace.len(),
        100.0 * (best / global - 1.0),
        t0.elapsed()
    );
    println!("best configuration: {}", objective.space().describe(best_idx));

    // Best-found curve at the paper's checkpoints.
    let curve = trace.best_curve();
    print!("best-found curve:");
    for cp in ktbo::harness::metrics::checkpoints() {
        print!("  {}:{:.2}", cp, curve[cp - 1]);
    }
    println!();
}
