//! The scanning engine: token-pattern rule matching over non-test code,
//! with inline `allow(<rule>): <reason>` suppression.
//!
//! Pipeline per file:
//!
//! 1. lex ([`crate::lexer`]);
//! 2. compute the *active mask* — tokens under `#[cfg(test)]` / `#[test]`
//!    items are masked out (the rules police shipping code, not tests);
//! 3. run each in-scope rule's token matcher over the active stream;
//! 4. apply suppression directives (same-line / next-line `allow`,
//!    whole-file `allow-file`), tracking which directives actually
//!    suppressed something so dead allows can be reported.
//!
//! Files reached only through a `#[cfg(test)] mod name;` declaration are
//! skipped entirely by [`scan_workspace`] — the mask is per-file, so the
//! declaring file reports the gated module name upward.

use crate::lexer::{lex, Tok, Token};
use crate::rules::{self, LINT_DIRECTIVE};
use std::path::{Path, PathBuf};

/// One finding, post-suppression.
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: String,
    /// Workspace-relative path, `/`-separated.
    pub file: String,
    pub line: u32,
    /// What fired, e.g. "`Instant::now()` wall-clock read".
    pub message: String,
    /// Trimmed source line, for human diagnostics.
    pub excerpt: String,
}

/// Result of scanning one file.
#[derive(Default)]
pub struct FileScan {
    pub violations: Vec<Violation>,
    /// `(rule, directive line)` for allow-comments that suppressed
    /// nothing — stale escapes worth deleting.
    pub unused_allows: Vec<(String, u32)>,
    /// Module names declared as `#[cfg(test)] mod <name>;` — their
    /// backing files are test-only and must be skipped by the caller.
    pub test_gated_mods: Vec<String>,
}

/// Aggregate over a workspace walk.
pub struct WorkspaceScan {
    pub violations: Vec<Violation>,
    pub unused_allows: Vec<(String, String, u32)>, // (file, rule, line)
    pub files_scanned: usize,
}

fn ident<'a>(t: &'a Token) -> Option<&'a str> {
    match &t.tok {
        Tok::Ident(s) => Some(s.as_str()),
        _ => None,
    }
}

fn is_punct(t: &Token, c: char) -> bool {
    matches!(t.tok, Tok::Punct(p) if p == c)
}

/// Mask out tokens belonging to `#[test]` / `#[cfg(test)]` items, and
/// collect `#[cfg(test)] mod name;` declarations.
fn active_mask(tokens: &[Token], gated_mods: &mut Vec<String>) -> Vec<bool> {
    let n = tokens.len();
    let mut mask = vec![true; n];
    let mut i = 0usize;
    while i < n {
        if !is_punct(&tokens[i], '#') {
            i += 1;
            continue;
        }
        let attr_start = i;
        let mut j = i + 1;
        let inner = j < n && is_punct(&tokens[j], '!');
        if inner {
            j += 1;
        }
        if j >= n || !is_punct(&tokens[j], '[') {
            i += 1;
            continue;
        }
        let (attr_end, is_test) = consume_attr(tokens, j);
        if inner || !is_test {
            i = attr_end + 1;
            continue;
        }
        // Swallow any further attributes stacked on the same item.
        let mut k = attr_end + 1;
        while k + 1 < n && is_punct(&tokens[k], '#') && is_punct(&tokens[k + 1], '[') {
            let (e, _) = consume_attr(tokens, k + 1);
            k = e + 1;
        }
        if let Some(name) = gated_mod_decl(tokens, k) {
            gated_mods.push(name);
        }
        let end = item_end(tokens, k);
        for m in attr_start..=end.min(n - 1) {
            mask[m] = false;
        }
        i = end + 1;
    }
    mask
}

/// Consume a `[ ... ]` attribute body starting at the `[`; returns
/// (index of closing `]`, whether it gates on test builds).
fn consume_attr(tokens: &[Token], open: usize) -> (usize, bool) {
    let n = tokens.len();
    let mut depth = 0usize;
    let mut ids: Vec<&str> = Vec::new();
    let mut k = open;
    while k < n {
        match &tokens[k].tok {
            Tok::Punct('[') => depth += 1,
            Tok::Punct(']') => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            Tok::Ident(s) => ids.push(s.as_str()),
            _ => {}
        }
        k += 1;
    }
    // `#[test]`, `#[cfg(test)]`, `#[cfg(all(test, ...))]` gate the item
    // to test builds; `#[cfg(not(test))]` and `#[cfg_attr(test, ...)]`
    // do not remove it from the shipping build.
    let is_test = ids.contains(&"test")
        && !ids.contains(&"not")
        && matches!(ids.first(), Some(&"test") | Some(&"cfg"));
    (k.min(n.saturating_sub(1)), is_test)
}

/// Recognize `pub? mod <name> ;` starting at `k`; returns the name.
fn gated_mod_decl(tokens: &[Token], mut k: usize) -> Option<String> {
    let n = tokens.len();
    if k < n && ident(&tokens[k]) == Some("pub") {
        k += 1;
        // `pub(crate)` etc.
        if k < n && is_punct(&tokens[k], '(') {
            while k < n && !is_punct(&tokens[k], ')') {
                k += 1;
            }
            k += 1;
        }
    }
    if k + 2 < n
        && ident(&tokens[k]) == Some("mod")
        && is_punct(&tokens[k + 2], ';')
    {
        return ident(&tokens[k + 1]).map(str::to_string);
    }
    None
}

/// Index of the last token of the item starting at `k`: the matching
/// `}` of its first brace block, or the first top-level `;`.
fn item_end(tokens: &[Token], mut k: usize) -> usize {
    let n = tokens.len();
    while k < n {
        match tokens[k].tok {
            Tok::Punct('{') => {
                let mut depth = 1usize;
                k += 1;
                while k < n && depth > 0 {
                    match tokens[k].tok {
                        Tok::Punct('{') => depth += 1,
                        Tok::Punct('}') => depth -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                return k.saturating_sub(1);
            }
            Tok::Punct(';') => return k,
            _ => k += 1,
        }
    }
    n.saturating_sub(1)
}

/// Run every in-scope rule's matcher over the active token stream.
fn match_rules(path: &str, act: &[&Token], out: &mut Vec<(String, u32, String)>) {
    let scoped = |id: &str| rules::in_scope(id, path);
    let wall = scoped(rules::NO_WALL_CLOCK);
    let clock = scoped(rules::NO_UNTRACKED_CLOCK);
    let hash = scoped(rules::NO_HASH_ORDER);
    let rng = scoped(rules::RNG_DISCIPLINE);
    let wire = scoped(rules::NO_PANIC_ON_WIRE);
    let sort = scoped(rules::STABLE_SORT_TIEBREAK);
    if !(wall || clock || hash || rng || wire || sort) {
        return;
    }
    let at = |k: usize| act.get(k).copied();
    let id_at = |k: usize| at(k).and_then(ident);
    let punct_at = |k: usize, c: char| at(k).is_some_and(|t| is_punct(t, c));

    for k in 0..act.len() {
        let t = act[k];
        if let Some(id) = ident(t) {
            if wall || clock {
                // One matcher, two rules: `no-wall-clock` bans timing on
                // the trace path outright; `no-untracked-clock` routes it
                // workspace-wide through `telemetry::clock::Clock`.
                if id == "Instant" && punct_at(k + 1, ':') && punct_at(k + 2, ':')
                    && id_at(k + 3) == Some("now")
                {
                    if wall {
                        out.push((rules::NO_WALL_CLOCK.into(), t.line, "`Instant::now()` wall-clock read".into()));
                    }
                    if clock {
                        out.push((
                            rules::NO_UNTRACKED_CLOCK.into(),
                            t.line,
                            "`Instant::now()` outside `telemetry::clock`".into(),
                        ));
                    }
                }
                if id == "SystemTime" {
                    if wall {
                        out.push((rules::NO_WALL_CLOCK.into(), t.line, "`SystemTime` wall-clock read".into()));
                    }
                    if clock {
                        out.push((
                            rules::NO_UNTRACKED_CLOCK.into(),
                            t.line,
                            "`SystemTime` outside `telemetry::clock`".into(),
                        ));
                    }
                }
            }
            if hash && (id == "HashMap" || id == "HashSet") {
                out.push((
                    rules::NO_HASH_ORDER.into(),
                    t.line,
                    format!("`{id}` in a trace-path module (unstable iteration order)"),
                ));
            }
            if rng {
                if id == "thread_rng" {
                    out.push((rules::RNG_DISCIPLINE.into(), t.line, "`thread_rng()` is nondeterministic".into()));
                }
                if id == "rand" && punct_at(k + 1, ':') && punct_at(k + 2, ':')
                    && id_at(k + 3) == Some("random")
                {
                    out.push((rules::RNG_DISCIPLINE.into(), t.line, "`rand::random()` is nondeterministic".into()));
                }
                if id == "Rng" && punct_at(k + 1, ':') && punct_at(k + 2, ':') {
                    if let Some(ctor @ ("new" | "with_stream" | "from_entropy" | "seed_from_u64")) =
                        id_at(k + 3)
                    {
                        out.push((
                            rules::RNG_DISCIPLINE.into(),
                            t.line,
                            format!("ad-hoc `Rng::{ctor}` — derive from the parent stream instead"),
                        ));
                    }
                }
            }
            if wire {
                if punct_at(k + 1, '!')
                    && matches!(id, "panic" | "unreachable" | "todo" | "unimplemented")
                {
                    out.push((
                        rules::NO_PANIC_ON_WIRE.into(),
                        t.line,
                        format!("`{id}!` in the serve layer"),
                    ));
                }
                if (id == "unwrap" || id == "expect")
                    && k > 0
                    && is_punct(act[k - 1], '.')
                    && punct_at(k + 1, '(')
                {
                    out.push((
                        rules::NO_PANIC_ON_WIRE.into(),
                        t.line,
                        format!("`.{id}()` on the serve path — reply with a protocol error"),
                    ));
                }
            }
            if sort && id.starts_with("sort_unstable") && k > 0 && is_punct(act[k - 1], '.') {
                out.push((
                    rules::STABLE_SORT_TIEBREAK.into(),
                    t.line,
                    format!("`.{id}` in ranking code — equal scores land in unstable order"),
                ));
            }
        } else if wire && is_punct(t, '[') && k > 0 {
            // Slice/array indexing: `expr[...]` — previous token closes
            // an expression. (`#[...]` attributes have `#` before the
            // bracket and don't match.)
            let prev = act[k - 1];
            let indexing = matches!(&prev.tok, Tok::Ident(_))
                || is_punct(prev, ')')
                || is_punct(prev, ']');
            if indexing {
                out.push((
                    rules::NO_PANIC_ON_WIRE.into(),
                    t.line,
                    "indexing can panic on wire-derived data — use `.get(..)`".into(),
                ));
            }
        }
    }
}

/// Scan one file's source. `path_rel` is the workspace-relative path
/// used for scoping (e.g. `rust/src/serve/server.rs`).
pub fn scan_source(path_rel: &str, src: &str) -> FileScan {
    let lexed = lex(src);
    let mut out = FileScan::default();
    let mask = active_mask(&lexed.tokens, &mut out.test_gated_mods);
    let act: Vec<&Token> = lexed
        .tokens
        .iter()
        .zip(&mask)
        .filter(|(_, m)| **m)
        .map(|(t, _)| t)
        .collect();

    let mut raw: Vec<(String, u32, String)> = Vec::new();
    match_rules(path_rel, &act, &mut raw);

    for (line, msg) in &lexed.malformed {
        raw.push((LINT_DIRECTIVE.into(), *line, msg.clone()));
    }
    for d in &lexed.directives {
        if rules::rule(&d.rule).is_none() {
            raw.push((
                LINT_DIRECTIVE.into(),
                d.line,
                format!("allow names unknown rule `{}`", d.rule),
            ));
        }
    }

    // Resolve each line-targeted directive to the line it covers: its
    // own line if that line holds code, else the next line that does.
    let active_lines: Vec<u32> = {
        let mut v: Vec<u32> = act.iter().map(|t| t.line).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    let all_lines: Vec<u32> = {
        let mut v: Vec<u32> = lexed.tokens.iter().map(|t| t.line).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    struct Allow {
        rule: String,
        file_wide: bool,
        target: Option<u32>,
        decl_line: u32,
        used: bool,
    }
    let mut allows: Vec<Allow> = lexed
        .directives
        .iter()
        .filter(|d| rules::rule(&d.rule).is_some() && d.rule != LINT_DIRECTIVE)
        .map(|d| Allow {
            rule: d.rule.clone(),
            file_wide: d.file_wide,
            target: if d.file_wide {
                None
            } else {
                all_lines.iter().copied().find(|&l| l >= d.line)
            },
            decl_line: d.line,
            used: false,
        })
        .collect();

    let src_lines: Vec<&str> = src.lines().collect();
    for (rule, line, message) in raw {
        let suppressed = allows.iter_mut().any(|a| {
            let hit = rule != LINT_DIRECTIVE
                && a.rule == rule
                && (a.file_wide || a.target == Some(line));
            if hit {
                a.used = true;
            }
            hit
        });
        if suppressed {
            continue;
        }
        let excerpt = src_lines
            .get(line as usize - 1)
            .map(|l| l.trim().to_string())
            .unwrap_or_default();
        out.violations.push(Violation { rule, file: path_rel.to_string(), line, message, excerpt });
    }

    // Dead allows: only warn when the directive points at shipping code
    // (a directive buried in a test mod guards nothing by design).
    for a in &allows {
        let points_at_active =
            a.file_wide || a.target.is_none_or(|t| active_lines.binary_search(&t).is_ok());
        if !a.used && points_at_active {
            out.unused_allows.push((a.rule.clone(), a.decl_line));
        }
    }

    out.violations.sort_by(|x, y| (x.line, &x.rule).cmp(&(y.line, &y.rule)));
    out
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Scan the workspace's shipping source roots under `root`.
///
/// Only `src/` trees are walked: `tests/`, `benches/`, and `examples/`
/// are test-tier code where the determinism rules don't apply.
pub fn scan_workspace(root: &Path) -> Result<WorkspaceScan, String> {
    let mut files: Vec<PathBuf> = Vec::new();
    for sub in ["rust/src", "lint/src"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk(&dir, &mut files)?;
        }
    }

    // Pass 1: read + scan everything, remembering cfg(test)-gated mods.
    let mut scans: Vec<(String, FileScan)> = Vec::new();
    let mut gated_prefixes: Vec<String> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .map_err(|_| format!("{} escapes workspace root", path.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path).map_err(|e| format!("read {rel}: {e}"))?;
        let scan = scan_source(&rel, &src);
        if !scan.test_gated_mods.is_empty() {
            let dir = match rel.rfind('/') {
                Some(cut) => &rel[..cut + 1],
                None => "",
            };
            for m in &scan.test_gated_mods {
                gated_prefixes.push(format!("{dir}{m}.rs"));
                gated_prefixes.push(format!("{dir}{m}/"));
            }
        }
        scans.push((rel, scan));
    }

    // Pass 2: drop files reachable only through a test-gated mod.
    let gated = |rel: &str| gated_prefixes.iter().any(|g| rel == g || rel.starts_with(g.as_str()));
    let mut ws = WorkspaceScan {
        violations: Vec::new(),
        unused_allows: Vec::new(),
        files_scanned: 0,
    };
    for (rel, scan) in scans {
        ws.files_scanned += 1;
        if gated(&rel) {
            continue;
        }
        for (rule, line) in scan.unused_allows {
            ws.unused_allows.push((rel.clone(), rule, line));
        }
        ws.violations.extend(scan.violations);
    }
    ws.violations
        .sort_by(|x, y| (&x.file, x.line, &x.rule).cmp(&(&y.file, y.line, &y.rule)));
    Ok(ws)
}
