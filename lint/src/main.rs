//! `ktbo-lint` CLI.
//!
//! ```text
//! ktbo-lint --workspace [--root DIR] [--baseline lint/baseline.json]
//!           [--json] [--write-baseline]
//! ```
//!
//! Exit codes: `0` clean (stale baseline entries and unused allows are
//! warnings), `1` fresh violations, `2` usage / IO error.

use ktbo::util::cli::Args;
use ktbo::util::json::Json;
use ktbo_lint::baseline::{diff, Baseline};
use ktbo_lint::rules;
use ktbo_lint::scan::{scan_workspace, Violation, WorkspaceScan};
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args = Args::from_env();
    match run(&args) {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("ktbo-lint: error: {msg}");
            ExitCode::from(2)
        }
    }
}

fn run(args: &Args) -> Result<ExitCode, String> {
    let root = args.str_or("root", ".");
    let ws = scan_workspace(Path::new(&root))?;

    let baseline_path = args.get("baseline").map(|p| Path::new(&root).join(p));

    if args.flag("write-baseline") {
        let path = baseline_path.ok_or("--write-baseline requires --baseline <file>")?;
        let base = Baseline::from_violations(&ws.violations);
        std::fs::write(&path, base.render())
            .map_err(|e| format!("write {}: {e}", path.display()))?;
        println!(
            "ktbo-lint: wrote {} ({} entries, {} findings) from {} files",
            path.display(),
            base.entries.len(),
            ws.violations.len(),
            ws.files_scanned
        );
        return Ok(ExitCode::SUCCESS);
    }

    let base = match &baseline_path {
        Some(p) => Baseline::load(p)?,
        None => Baseline::empty(),
    };
    let d = diff(&ws.violations, &base);

    if args.flag("json") {
        println!("{}", json_report(&ws, &d.fresh, &d.stale).render());
    } else {
        human_report(&ws, &d.fresh, &d.stale);
    }
    Ok(if d.fresh.is_empty() { ExitCode::SUCCESS } else { ExitCode::FAILURE })
}

fn human_report(
    ws: &WorkspaceScan,
    fresh: &[Violation],
    stale: &[(String, String, usize, usize)],
) {
    for v in fresh {
        println!("{}:{} [{}] {}", v.file, v.line, v.rule, v.message);
        if !v.excerpt.is_empty() {
            println!("    > {}", v.excerpt);
        }
        if let Some(r) = rules::rule(&v.rule) {
            println!("    hint: {}", r.hint);
        }
    }
    for (rule, file, recorded, current) in stale {
        println!(
            "warning: stale baseline entry {rule} @ {file}: recorded {recorded}, now {current} \
             — refresh with --write-baseline"
        );
    }
    for (file, rule, line) in &ws.unused_allows {
        println!("warning: unused allow({rule}) at {file}:{line} — delete it");
    }
    let grandfathered = ws.violations.len() - fresh.len();
    if fresh.is_empty() {
        println!(
            "ktbo-lint: clean — {} files scanned, {} grandfathered finding(s), {} stale \
             baseline entr(y/ies), {} unused allow(s)",
            ws.files_scanned,
            grandfathered,
            stale.len(),
            ws.unused_allows.len()
        );
    } else {
        println!(
            "ktbo-lint: FAILED — {} fresh violation(s) over baseline ({} files scanned, \
             {} grandfathered)",
            fresh.len(),
            ws.files_scanned,
            grandfathered
        );
    }
}

fn violation_json(v: &Violation) -> Json {
    let hint = rules::rule(&v.rule).map(|r| r.hint).unwrap_or("");
    Json::obj()
        .set("rule", v.rule.as_str())
        .set("file", v.file.as_str())
        .set("line", i64::from(v.line))
        .set("message", v.message.as_str())
        .set("excerpt", v.excerpt.as_str())
        .set("hint", hint)
}

fn json_report(
    ws: &WorkspaceScan,
    fresh: &[Violation],
    stale: &[(String, String, usize, usize)],
) -> Json {
    Json::obj()
        .set("ok", fresh.is_empty())
        .set("files_scanned", ws.files_scanned)
        .set("fresh", Json::Arr(fresh.iter().map(violation_json).collect()))
        .set(
            "grandfathered",
            Json::Arr(
                ws.violations
                    .iter()
                    .filter(|v| {
                        !fresh
                            .iter()
                            .any(|f| f.file == v.file && f.rule == v.rule && f.line == v.line)
                    })
                    .map(violation_json)
                    .collect(),
            ),
        )
        .set(
            "stale_baseline",
            Json::Arr(
                stale
                    .iter()
                    .map(|(rule, file, recorded, current)| {
                        Json::obj()
                            .set("rule", rule.as_str())
                            .set("file", file.as_str())
                            .set("recorded", *recorded)
                            .set("current", *current)
                    })
                    .collect(),
            ),
        )
        .set(
            "unused_allows",
            Json::Arr(
                ws.unused_allows
                    .iter()
                    .map(|(file, rule, line)| {
                        Json::obj()
                            .set("file", file.as_str())
                            .set("rule", rule.as_str())
                            .set("line", i64::from(*line))
                    })
                    .collect(),
            ),
        )
}
