//! The determinism rule set: ids, module scopes, and fix hints.
//!
//! Every rule is *module-scoped*: it only fires for files whose
//! workspace-relative path starts with one of the rule's scope
//! prefixes. Scopes encode the repo's trace-path map — the modules
//! whose behavior feeds the bit-identical sweep traces and the serve
//! layer's offline-equivalence proofs (see EXPERIMENTS.md §Methodology).

/// A single lint rule.
pub struct Rule {
    /// Stable identifier used in diagnostics, allow-comments, and the
    /// baseline file.
    pub id: &'static str,
    /// Workspace-relative path prefixes the rule applies to.
    pub scopes: &'static [&'static str],
    /// Path prefixes carved *out* of the scopes — for workspace-wide
    /// rules with a sanctioned implementation module (e.g. the clock
    /// rule excludes `telemetry/clock`, where the real reads live).
    pub excludes: &'static [&'static str],
    /// One-line description of what the rule bans.
    pub summary: &'static str,
    /// Actionable remediation, printed with every finding.
    pub hint: &'static str,
}

/// Modules on the deterministic trace path: everything whose outputs
/// feed strategy decisions, sweep records, or checkpoints.
const TRACE_CORE: &[&str] = &[
    "rust/src/bo/",
    "rust/src/gp/",
    "rust/src/strategies/",
    "rust/src/space/",
    "rust/src/surrogate/",
    "rust/src/objective/",
];

pub const NO_WALL_CLOCK: &str = "no-wall-clock";
pub const NO_HASH_ORDER: &str = "no-hash-order";
pub const RNG_DISCIPLINE: &str = "rng-discipline";
pub const NO_PANIC_ON_WIRE: &str = "no-panic-on-wire";
pub const STABLE_SORT_TIEBREAK: &str = "stable-sort-tiebreak";
pub const NO_UNTRACKED_CLOCK: &str = "no-untracked-clock";
/// Pseudo-rule for malformed suppression comments; always in scope and
/// never eligible for suppression (a broken directive must be fixed).
pub const LINT_DIRECTIVE: &str = "lint-directive";

pub const RULES: &[Rule] = &[
    Rule {
        id: NO_WALL_CLOCK,
        scopes: TRACE_CORE,
        excludes: &[],
        summary: "wall-clock reads (`Instant::now`, `SystemTime`) in trace-path modules",
        hint: "thread simulated time / budgets through instead; timing belongs in \
               harness benches or `WallClockBudget` (allow with a reason if this *is* \
               the budget clock)",
    },
    Rule {
        id: NO_HASH_ORDER,
        scopes: &[
            "rust/src/bo/",
            "rust/src/gp/",
            "rust/src/strategies/",
            "rust/src/space/",
            "rust/src/surrogate/",
            "rust/src/objective/",
            "rust/src/harness/",
            "rust/src/serve/",
        ],
        excludes: &[],
        summary: "`HashMap`/`HashSet` in trace-path modules (iteration order is unstable)",
        hint: "use `BTreeMap`/`BTreeSet`, a packed-key index, or drain through a \
               sorted Vec before anything order-sensitive",
    },
    Rule {
        id: RNG_DISCIPLINE,
        scopes: &[
            "rust/src/bo/",
            "rust/src/gp/",
            "rust/src/strategies/",
            "rust/src/space/",
            "rust/src/surrogate/",
            "rust/src/objective/",
            "rust/src/serve/",
        ],
        excludes: &[],
        summary: "ad-hoc RNG construction outside the blessed derivation tree",
        hint: "derive from the parent stream: `rng.split(tag)`, `cell_rng(...)`, or a \
               seed carried by `SessionConfig`; never `thread_rng`/`rand::random`, \
               and `Rng::new`/`Rng::with_stream` only at an owned root (allow with \
               a reason)",
    },
    Rule {
        id: NO_PANIC_ON_WIRE,
        scopes: &["rust/src/serve/"],
        excludes: &[],
        summary: "panic paths (`unwrap`/`expect`/`panic!`/indexing) in the serve layer",
        hint: "the daemon must answer a protocol error, not die: return \
               `protocol::err(...)`, propagate a `Result`, or use checked indexing",
    },
    Rule {
        id: STABLE_SORT_TIEBREAK,
        scopes: &["rust/src/bo/", "rust/src/strategies/", "rust/src/space/"],
        excludes: &[],
        summary: "`sort_unstable*` in ranking code (equal f32 scores land in \
                  platform-dependent order)",
        hint: "use stable `sort_by` or add a deterministic tiebreak key \
               (config index) to the comparator",
    },
    Rule {
        id: NO_UNTRACKED_CLOCK,
        // Workspace-wide: unlike `no-wall-clock` (which bans timing from
        // the trace path outright), this rule routes *all* timing through
        // the injectable `telemetry::clock::Clock` so tests can substitute
        // `ManualClock` anywhere — benches carry reasoned allow-files.
        scopes: &[""],
        excludes: &["rust/src/telemetry/clock"],
        summary: "direct `Instant::now()`/`SystemTime` outside `telemetry::clock`",
        hint: "inject a `telemetry::clock::Clock` (`MonotonicClock` in production, \
               `ManualClock` in tests) instead of reading the OS clock in place; \
               allow-file with a reason for standalone bench harnesses",
    },
    Rule {
        id: LINT_DIRECTIVE,
        scopes: &[""],
        excludes: &[],
        summary: "malformed `ktbo-lint:` suppression comment",
        hint: "write `// ktbo-lint: allow(<rule>): <reason>` — the reason is required",
    },
];

/// Look up a rule by id.
pub fn rule(id: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.id == id)
}

/// Does `rule_id` apply to the file at workspace-relative `path`?
/// Excludes win over scopes.
pub fn in_scope(rule_id: &str, path: &str) -> bool {
    match rule(rule_id) {
        Some(r) => {
            r.scopes.iter().any(|s| path.starts_with(s))
                && !r.excludes.iter().any(|s| path.starts_with(s))
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scopes_resolve() {
        assert!(in_scope(NO_PANIC_ON_WIRE, "rust/src/serve/server.rs"));
        assert!(!in_scope(NO_PANIC_ON_WIRE, "rust/src/bo/mod.rs"));
        assert!(in_scope(NO_HASH_ORDER, "rust/src/harness/orchestrator.rs"));
        assert!(!in_scope(NO_HASH_ORDER, "rust/src/util/cli.rs"));
        assert!(in_scope(STABLE_SORT_TIEBREAK, "rust/src/strategies/driver.rs"));
        assert!(in_scope(STABLE_SORT_TIEBREAK, "rust/src/space/view.rs"));
        assert!(!in_scope(STABLE_SORT_TIEBREAK, "rust/src/surrogate/forest.rs"));
        assert!(in_scope(LINT_DIRECTIVE, "anything/at/all.rs"));
        // The clock rule is workspace-wide minus its sanctioned module.
        assert!(in_scope(NO_UNTRACKED_CLOCK, "rust/src/harness/gp_bench.rs"));
        assert!(in_scope(NO_UNTRACKED_CLOCK, "rust/src/main.rs"));
        assert!(in_scope(NO_UNTRACKED_CLOCK, "lint/src/scan.rs"));
        assert!(!in_scope(NO_UNTRACKED_CLOCK, "rust/src/telemetry/clock.rs"));
        assert!(!in_scope(NO_UNTRACKED_CLOCK, "rust/src/telemetry/clock/impls.rs"));
    }

    #[test]
    fn every_rule_has_hint_and_summary() {
        for r in RULES {
            assert!(!r.hint.is_empty(), "{} lacks a hint", r.id);
            assert!(!r.summary.is_empty(), "{} lacks a summary", r.id);
            assert!(rule(r.id).is_some());
        }
    }
}
