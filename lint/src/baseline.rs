//! Grandfathered-violation baseline: `lint/baseline.json`.
//!
//! The baseline records, per `(rule, file)`, how many violations were
//! known when the entry was committed. The comparison is count-based:
//!
//! - current > recorded  → **fresh violations**, the run fails;
//! - current < recorded  → **stale entry**, a warning inviting a
//!   `--write-baseline` refresh (burn-down is progress, never an error);
//! - current == recorded → clean.
//!
//! Counting (rather than exact line matching) keeps the file stable
//! under unrelated edits that shift line numbers; recorded lines are
//! kept for humans reading the file, not for the comparison.

use crate::scan::Violation;
use ktbo::util::json::Json;
use ktbo::util::jsonparse;
use std::collections::BTreeMap;
use std::path::Path;

/// One grandfathered `(rule, file)` bucket.
#[derive(Clone, Debug)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    pub count: usize,
    /// Line numbers at the time the entry was recorded (informational).
    pub lines: Vec<u32>,
}

/// The committed baseline.
#[derive(Clone, Debug, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

/// Result of comparing a scan against the baseline.
#[derive(Debug, Default)]
pub struct Diff {
    /// Violations in `(rule, file)` buckets that exceed their recorded
    /// count. The whole bucket is listed — a count-based baseline can't
    /// tell old members from new ones once the count grows.
    pub fresh: Vec<Violation>,
    /// `(rule, file, recorded, current)` for buckets that shrank.
    pub stale: Vec<(String, String, usize, usize)>,
}

impl Baseline {
    pub fn empty() -> Baseline {
        Baseline::default()
    }

    pub fn load(path: &Path) -> Result<Baseline, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read baseline {}: {e}", path.display()))?;
        Baseline::from_json(&text)
    }

    pub fn from_json(text: &str) -> Result<Baseline, String> {
        let j = jsonparse::parse(text).map_err(|e| format!("baseline is not valid JSON: {e}"))?;
        let version = j.get("version").and_then(Json::as_f64).unwrap_or(0.0);
        if version != 1.0 {
            return Err(format!("unsupported baseline version {version} (expected 1)"));
        }
        let mut entries = Vec::new();
        for e in j.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
            let rule = e
                .get("rule")
                .and_then(Json::as_str)
                .ok_or("baseline entry missing `rule`")?
                .to_string();
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .ok_or("baseline entry missing `file`")?
                .to_string();
            let count = e
                .get("count")
                .and_then(Json::as_f64)
                .ok_or("baseline entry missing `count`")? as usize;
            let lines = e
                .get("lines")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_f64)
                .map(|x| x as u32)
                .collect();
            entries.push(BaselineEntry { rule, file, count, lines });
        }
        Ok(Baseline { entries })
    }

    /// Group a scan's violations into a fresh baseline.
    pub fn from_violations(violations: &[Violation]) -> Baseline {
        let mut buckets: BTreeMap<(String, String), Vec<u32>> = BTreeMap::new();
        for v in violations {
            buckets.entry((v.file.clone(), v.rule.clone())).or_default().push(v.line);
        }
        let entries = buckets
            .into_iter()
            .map(|((file, rule), mut lines)| {
                lines.sort_unstable();
                BaselineEntry { rule, file, count: lines.len(), lines }
            })
            .collect();
        Baseline { entries }
    }

    pub fn render(&self) -> String {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                Json::obj()
                    .set("rule", e.rule.as_str())
                    .set("file", e.file.as_str())
                    .set("count", e.count)
                    .set(
                        "lines",
                        Json::Arr(e.lines.iter().map(|&l| Json::Num(l as f64)).collect()),
                    )
            })
            .collect();
        Json::obj()
            .set("version", 1i64)
            .set("entries", Json::Arr(entries))
            .render_pretty()
    }

    fn count(&self, rule: &str, file: &str) -> usize {
        self.entries
            .iter()
            .filter(|e| e.rule == rule && e.file == file)
            .map(|e| e.count)
            .sum()
    }
}

/// Compare the current scan against the baseline.
pub fn diff(current: &[Violation], base: &Baseline) -> Diff {
    let mut buckets: BTreeMap<(String, String), Vec<&Violation>> = BTreeMap::new();
    for v in current {
        buckets.entry((v.file.clone(), v.rule.clone())).or_default().push(v);
    }
    let mut out = Diff::default();
    for ((file, rule), vs) in &buckets {
        let recorded = base.count(rule, file);
        if vs.len() > recorded {
            out.fresh.extend(vs.iter().map(|v| (*v).clone()));
        }
    }
    for e in &base.entries {
        let cur = buckets.get(&(e.file.clone(), e.rule.clone())).map_or(0, Vec::len);
        if cur < e.count {
            out.stale.push((e.rule.clone(), e.file.clone(), e.count, cur));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(rule: &str, file: &str, line: u32) -> Violation {
        Violation {
            rule: rule.into(),
            file: file.into(),
            line,
            message: "m".into(),
            excerpt: "e".into(),
        }
    }

    #[test]
    fn roundtrips_through_json() {
        let b = Baseline::from_violations(&[
            v("no-hash-order", "rust/src/a.rs", 3),
            v("no-hash-order", "rust/src/a.rs", 9),
            v("rng-discipline", "rust/src/b.rs", 1),
        ]);
        let b2 = Baseline::from_json(&b.render()).unwrap();
        assert_eq!(b2.entries.len(), 2);
        assert_eq!(b2.count("no-hash-order", "rust/src/a.rs"), 2);
        assert_eq!(b2.count("rng-discipline", "rust/src/b.rs"), 1);
    }

    #[test]
    fn growth_is_fresh_shrink_is_stale() {
        let base = Baseline::from_violations(&[
            v("no-hash-order", "rust/src/a.rs", 3),
            v("no-hash-order", "rust/src/a.rs", 9),
        ]);
        // Same count → clean.
        let d = diff(&[v("no-hash-order", "rust/src/a.rs", 4), v("no-hash-order", "rust/src/a.rs", 9)], &base);
        assert!(d.fresh.is_empty() && d.stale.is_empty());
        // One more → the whole bucket is fresh.
        let d = diff(
            &[
                v("no-hash-order", "rust/src/a.rs", 3),
                v("no-hash-order", "rust/src/a.rs", 9),
                v("no-hash-order", "rust/src/a.rs", 20),
            ],
            &base,
        );
        assert_eq!(d.fresh.len(), 3);
        // One fewer → stale warning, not an error.
        let d = diff(&[v("no-hash-order", "rust/src/a.rs", 3)], &base);
        assert!(d.fresh.is_empty());
        assert_eq!(d.stale, vec![("no-hash-order".into(), "rust/src/a.rs".into(), 2, 1)]);
    }

    #[test]
    fn rejects_wrong_version() {
        assert!(Baseline::from_json(r#"{"version": 2, "entries": []}"#).is_err());
        assert!(Baseline::from_json("not json").is_err());
    }
}
