//! # ktbo-lint — the workspace determinism auditor.
//!
//! Every result this repo reports — strategy rankings, bit-identical
//! traces across shard/thread counts, serve-vs-offline equivalence —
//! rests on determinism discipline that used to live in reviewers'
//! heads: seeded child RNG streams, no hash-order iteration on trace
//! paths, no wall-clock reads inside the optimizer, no panics on
//! wire-derived data. At 50+ source files that discipline needs to be
//! checkable by machine, not by diligence. This crate is that check.
//!
//! - [`rules`] — the five module-scoped rules plus the directive
//!   pseudo-rule, each with scopes and a fix hint.
//! - [`lexer`] — a dependency-free Rust lexer (the workspace vendors no
//!   `syn`); tokens + suppression directives.
//! - [`scan`] — test-code masking, token-pattern matching, suppression.
//! - [`baseline`] — the committed grandfathered-violation ledger;
//!   fresh violations fail, burn-down only warns.
//!
//! Run it as CI does:
//!
//! ```text
//! cargo run -p ktbo-lint -- --workspace --baseline lint/baseline.json
//! ```

pub mod baseline;
pub mod lexer;
pub mod rules;
pub mod scan;
