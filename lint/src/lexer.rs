//! A minimal Rust lexer — just enough structure for `ktbo-lint`.
//!
//! The workspace is intentionally dependency-free, so there is no `syn`
//! to lean on. The rules this tool enforces are all expressible over a
//! token stream (identifier sequences, punctuation adjacency), so a
//! hand-rolled lexer is sufficient — *provided* it gets the hard parts
//! of Rust's lexical grammar right, because a mis-lexed string literal
//! would turn prose into phantom violations. The tricky cases handled
//! here:
//!
//! - line and nested block comments (`/* /* */ */`);
//! - string, byte-string, and raw-string literals (`r#"…"#` with any
//!   number of hashes), including newlines inside them;
//! - the `'a` lifetime vs `'a'` char-literal ambiguity;
//! - numeric literals with underscores/suffixes (skipped as one token).
//!
//! Comments are not discarded blindly: line comments are scanned for
//! ktbo-lint suppression directives, which become [`Directive`]s.
//! (This file documents the marker without ever spelling the full
//! `marker + colon` sequence in a comment — the self-scan would treat
//! it as a malformed directive.)

/// One lexed token kind. Literal payloads are irrelevant to every rule,
/// so literals collapse to a single marker variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`HashMap`, `fn`, `unwrap`, …).
    Ident(String),
    /// Single punctuation character (`.`, `:`, `[`, …).
    Punct(char),
    /// String / char / byte / numeric literal.
    Lit,
    /// Lifetime such as `'a` (distinguished from a char literal).
    Life,
}

/// A token plus the 1-based line it starts on.
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

/// An inline suppression comment: the ktbo-lint marker followed by
/// `allow(<rule>): <reason>` or `allow-file(<rule>): <reason>`.
#[derive(Clone, Debug)]
pub struct Directive {
    pub rule: String,
    /// `allow-file` suppresses the rule for the whole file; `allow`
    /// only for the same line or the next line holding code.
    pub file_wide: bool,
    pub line: u32,
}

/// Result of lexing one file.
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub directives: Vec<Directive>,
    /// `(line, message)` for comments that carry the ktbo-lint marker
    /// but do not parse as a well-formed directive (missing reason,
    /// unknown verb, unbalanced parens). Reported as `lint-directive`
    /// findings so typos cannot silently disable a rule.
    pub malformed: Vec<(u32, String)>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens + directives. Never fails: unrecognized bytes
/// become `Punct` tokens, so a lexically odd file degrades to noise
/// rather than a crash.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut out = Lexed { tokens: Vec::new(), directives: Vec::new(), malformed: Vec::new() };

    while i < n {
        let c = b[i];
        // Whitespace.
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Comments.
        if c == '/' && i + 1 < n {
            if b[i + 1] == '/' {
                let start = i + 2;
                let mut j = start;
                while j < n && b[j] != '\n' {
                    j += 1;
                }
                let text: String = b[start..j].iter().collect();
                parse_directive(&text, line, &mut out);
                i = j;
                continue;
            }
            if b[i + 1] == '*' {
                // Nested block comment.
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if b[j] == '\n' {
                        line += 1;
                        j += 1;
                    } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                        depth += 1;
                        j += 2;
                    } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                i = j;
                continue;
            }
        }
        // Raw / byte string prefixes: r"…", r#"…"#, br"…", b"…", b'…'.
        if (c == 'r' || c == 'b') && i + 1 < n {
            let mut j = i + 1;
            if c == 'b' && j < n && b[j] == 'r' {
                j += 1;
            }
            let raw = c == 'r' || (c == 'b' && j > i + 1);
            if raw {
                let mut hashes = 0usize;
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    let tok_line = line;
                    j += 1;
                    'raw: while j < n {
                        if b[j] == '\n' {
                            line += 1;
                            j += 1;
                        } else if b[j] == '"' {
                            let mut k = j + 1;
                            let mut seen = 0usize;
                            while k < n && seen < hashes && b[k] == '#' {
                                seen += 1;
                                k += 1;
                            }
                            if seen == hashes {
                                j = k;
                                break 'raw;
                            }
                            j += 1;
                        } else {
                            j += 1;
                        }
                    }
                    out.tokens.push(Token { tok: Tok::Lit, line: tok_line });
                    i = j;
                    continue;
                }
            } else if c == 'b' && b[j] == '"' {
                let (nj, nl) = skip_string(&b, j, line);
                out.tokens.push(Token { tok: Tok::Lit, line });
                line = nl;
                i = nj;
                continue;
            } else if c == 'b' && b[j] == '\'' {
                let (nj, nl) = skip_char(&b, j, line);
                out.tokens.push(Token { tok: Tok::Lit, line });
                line = nl;
                i = nj;
                continue;
            }
            // Fall through: plain identifier starting with r/b.
        }
        // String literal.
        if c == '"' {
            let tok_line = line;
            let (nj, nl) = skip_string(&b, i, line);
            out.tokens.push(Token { tok: Tok::Lit, line: tok_line });
            line = nl;
            i = nj;
            continue;
        }
        // Char literal or lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                let (nj, nl) = skip_char(&b, i, line);
                out.tokens.push(Token { tok: Tok::Lit, line });
                line = nl;
                i = nj;
                continue;
            }
            if i + 1 < n && is_ident_start(b[i + 1]) {
                // `'a'` is a char literal; `'a` (no closing quote right
                // after the ident run) is a lifetime.
                let mut j = i + 1;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
                if j < n && b[j] == '\'' && j == i + 2 {
                    out.tokens.push(Token { tok: Tok::Lit, line });
                    i = j + 1;
                } else {
                    out.tokens.push(Token { tok: Tok::Life, line });
                    i = j;
                }
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' {
                // '(' , '.' etc.
                out.tokens.push(Token { tok: Tok::Lit, line });
                i += 3;
                continue;
            }
            out.tokens.push(Token { tok: Tok::Punct('\''), line });
            i += 1;
            continue;
        }
        // Numeric literal (suffixes and underscores ride along).
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && (is_ident_continue(b[j]) || b[j] == '.') {
                // A dot continues the literal only into a fraction digit:
                // `1..n` ranges and `x.0.method()` chains must not be
                // swallowed (the method ident has to surface for matching).
                if b[j] == '.' && !b.get(j + 1).is_some_and(|d| d.is_ascii_digit()) {
                    break;
                }
                j += 1;
            }
            out.tokens.push(Token { tok: Tok::Lit, line });
            i = j;
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            let id: String = b[i..j].iter().collect();
            out.tokens.push(Token { tok: Tok::Ident(id), line });
            i = j;
            continue;
        }
        out.tokens.push(Token { tok: Tok::Punct(c), line });
        i += 1;
    }
    out
}

/// Skip a `"…"` literal starting at the opening quote; returns
/// (index past the closing quote, updated line).
fn skip_string(b: &[char], start: usize, mut line: u32) -> (usize, u32) {
    let n = b.len();
    let mut j = start + 1;
    while j < n {
        match b[j] {
            '\\' => {
                // `\<newline>` line continuations still advance the line.
                if b.get(j + 1) == Some(&'\n') {
                    line += 1;
                }
                j += 2;
            }
            '\n' => {
                line += 1;
                j += 1;
            }
            '"' => return (j + 1, line),
            _ => j += 1,
        }
    }
    (n, line)
}

/// Skip a `'…'` char literal starting at the opening quote.
fn skip_char(b: &[char], start: usize, line: u32) -> (usize, u32) {
    let n = b.len();
    let mut j = start + 1;
    while j < n {
        match b[j] {
            '\\' => j += 2,
            '\'' => return (j + 1, line),
            '\n' => return (j, line), // unterminated; bail at EOL
            _ => j += 1,
        }
    }
    (n, line)
}

/// Recognize suppression directives inside a line comment's text.
fn parse_directive(text: &str, line: u32, out: &mut Lexed) {
    const MARKER: &str = "ktbo-lint:";
    let Some(pos) = text.find(MARKER) else {
        return;
    };
    let rest = text[pos + MARKER.len()..].trim_start();
    let (file_wide, after_verb) = if let Some(r) = rest.strip_prefix("allow-file(") {
        (true, r)
    } else if let Some(r) = rest.strip_prefix("allow(") {
        (false, r)
    } else {
        out.malformed.push((
            line,
            "unrecognized directive (expected `allow(<rule>): <reason>` \
             or `allow-file(<rule>): <reason>`)"
                .to_string(),
        ));
        return;
    };
    let Some(close) = after_verb.find(')') else {
        out.malformed.push((line, "unterminated rule name in directive".to_string()));
        return;
    };
    let rule = after_verb[..close].trim().to_string();
    if rule.is_empty() {
        out.malformed.push((line, "empty rule name in directive".to_string()));
        return;
    }
    let tail = after_verb[close + 1..].trim_start();
    let reason = tail.strip_prefix(':').map(str::trim).unwrap_or("");
    if reason.is_empty() {
        out.malformed.push((
            line,
            format!("allow({rule}) is missing a `: <reason>` justification"),
        ));
        return;
    }
    out.directives.push(Directive { rule, file_wide, line });
}
