//! The baseline lifecycle the CI gate depends on: an unrecorded
//! violation fails, a recorded one passes, a fixed one downgrades to a
//! stale warning — and the file round-trips through its JSON form.

use ktbo_lint::baseline::{diff, Baseline};
use ktbo_lint::scan::{scan_source, Violation};

const PATH: &str = "rust/src/harness/fixture.rs";

const ONE: &str = "use std::collections::HashMap;\npub fn a() {}\n";
const TWO: &str = "use std::collections::HashMap;\npub fn b() -> HashMap<u32, u32> {\n    panic!(\"x\")\n}\n";
const THREE: &str = "use std::collections::HashMap;\npub fn c() -> HashMap<u32, u32> {\n    HashMap::new()\n}\n";

fn violations(src: &str) -> Vec<Violation> {
    scan_source(PATH, src).violations
}

#[test]
fn unrecorded_violations_fail_the_run() {
    let two = violations(TWO);
    assert_eq!(two.len(), 2, "fixture should fire twice: {two:?}");
    let d = diff(&two, &Baseline::empty());
    assert_eq!(d.fresh.len(), 2, "no baseline → everything is fresh → exit 1");
}

#[test]
fn recorded_violations_pass_and_new_ones_fail_again() {
    let two = violations(TWO);
    let base = Baseline::from_violations(&two);

    // Recorded → clean.
    let d = diff(&two, &base);
    assert!(d.fresh.is_empty() && d.stale.is_empty(), "recorded counts must pass");

    // A freshly introduced violation in the same bucket → the run fails.
    // (Count-based buckets can't tell old members from new, so the whole
    // bucket is surfaced.)
    let three = violations(THREE);
    assert_eq!(three.len(), 3);
    let d = diff(&three, &base);
    assert_eq!(d.fresh.len(), 3, "bucket over its recorded count is fresh");

    // A violation in a bucket the baseline has never seen also fails.
    let foreign = violations(TWO)
        .into_iter()
        .map(|mut v| {
            v.file = "rust/src/serve/other.rs".to_string();
            v
        })
        .collect::<Vec<_>>();
    let d = diff(&foreign, &base);
    assert_eq!(d.fresh.len(), 2, "unknown (rule, file) bucket is fresh");
}

#[test]
fn burned_down_violations_warn_stale_but_pass() {
    let base = Baseline::from_violations(&violations(TWO));
    let one = violations(ONE);
    assert_eq!(one.len(), 1);
    let d = diff(&one, &base);
    assert!(d.fresh.is_empty(), "burn-down must never fail the run");
    assert_eq!(d.stale.len(), 1, "shrunk bucket warns so the baseline gets refreshed");
    let (rule, file, recorded, current) = &d.stale[0];
    assert_eq!((rule.as_str(), file.as_str(), *recorded, *current), ("no-hash-order", PATH, 2, 1));
}

#[test]
fn baseline_file_round_trips() {
    let two = violations(TWO);
    let base = Baseline::from_violations(&two);
    let reloaded = Baseline::from_json(&base.render()).expect("render must parse back");
    assert!(diff(&two, &reloaded).fresh.is_empty(), "round-trip must preserve counts");
    // Identical text on a second render: the file is regeneration-stable,
    // so `--write-baseline` produces no spurious diffs.
    assert_eq!(base.render(), Baseline::from_violations(&two).render());
}
