pub fn first_byte(buf: &[u8]) -> u8 {
    // ktbo-lint: allow(no-panic-on-wire): fixture — length is checked by the caller
    buf[0]
}
