// ktbo-lint: allow-file(lint-directive): directive errors must never be silenceable

// ktbo-lint: allow(no-wall-clock)
pub fn missing_reason() {}

// ktbo-lint: allow(no-such-rule): a perfectly believable reason
pub fn unknown_rule() {}
