pub fn read_field(line: &str) -> usize {
    let parts: Vec<&str> = line.split(',').collect();
    parts[0].parse().unwrap()
}

pub fn must(ok: bool) {
    if !ok {
        panic!("bad request");
    }
}
