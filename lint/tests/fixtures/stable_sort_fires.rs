pub fn rank(scores: &mut [(f64, usize)]) {
    scores.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
}
