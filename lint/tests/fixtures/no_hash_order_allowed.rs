// ktbo-lint: allow-file(no-hash-order): fixture — iteration order is never observed here
use std::collections::HashSet;

pub fn seen_set() -> HashSet<usize> {
    HashSet::new()
}
