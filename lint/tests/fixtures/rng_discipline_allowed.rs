pub fn root_stream(seed: u64) -> Rng {
    // ktbo-lint: allow(rng-discipline): fixture — owned root stream, seed carried by config
    Rng::new(seed)
}
