use std::time::Instant;

pub fn deadline() -> Instant {
    // ktbo-lint: allow(no-wall-clock): fixture — this is the sanctioned budget clock
    // ktbo-lint: allow(no-untracked-clock): fixture — budget clock wants wall semantics, not a `Clock`
    Instant::now()
}
