use std::time::Instant;

pub fn deadline() -> Instant {
    // ktbo-lint: allow(no-wall-clock): fixture — this is the sanctioned budget clock
    Instant::now()
}
