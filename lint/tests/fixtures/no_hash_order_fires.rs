use std::collections::HashMap;

pub fn lookup_table() -> HashMap<usize, f64> {
    HashMap::new()
}
