// ktbo-lint: allow-file(no-untracked-clock): fixture — standalone bench harness, wall time is informational
use std::time::Instant;

pub fn stamp_now() -> Instant {
    Instant::now()
}

pub fn epoch_read() {
    let _ = std::time::SystemTime::now();
}
