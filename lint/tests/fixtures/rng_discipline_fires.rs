pub fn ad_hoc_stream() -> u64 {
    let mut rng = Rng::new(42);
    rng.next()
}

pub fn os_entropy() -> f64 {
    let mut r = thread_rng();
    r.gen()
}
