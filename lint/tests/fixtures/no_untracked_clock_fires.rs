use std::time::Instant;

pub fn stamp_now() -> Instant {
    Instant::now()
}

pub fn epoch_read() {
    let _ = std::time::SystemTime::now();
}
