pub fn rank(keys: &mut [usize]) {
    // ktbo-lint: allow(stable-sort-tiebreak): fixture — keys are unique config indices
    keys.sort_unstable();
}
