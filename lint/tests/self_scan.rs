//! The gate itself, as a test: scan the real workspace against the
//! committed baseline. This is what CI runs via the `ktbo-lint` binary;
//! keeping it as a test means `cargo test --workspace` catches a fresh
//! determinism violation even on machines that never invoke the binary.

use ktbo_lint::baseline::{diff, Baseline};
use ktbo_lint::scan::scan_workspace;
use std::path::{Path, PathBuf};

fn workspace_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

#[test]
fn workspace_is_clean_against_committed_baseline() {
    let root = workspace_root();
    let ws = scan_workspace(&root).expect("workspace scan");
    assert!(ws.files_scanned > 50, "scan found only {} files — wrong root?", ws.files_scanned);
    let base = Baseline::load(&root.join("lint").join("baseline.json")).expect("baseline loads");
    let d = diff(&ws.violations, &base);
    let rendered: Vec<String> = d
        .fresh
        .iter()
        .map(|v| format!("{}:{} [{}] {}", v.file, v.line, v.rule, v.message))
        .collect();
    assert!(rendered.is_empty(), "fresh determinism violations:\n{}", rendered.join("\n"));
    assert!(
        ws.unused_allows.is_empty(),
        "stale allow directives (delete them): {:?}",
        ws.unused_allows
    );
    assert!(
        d.stale.is_empty(),
        "baseline is stale (refresh with --write-baseline): {:?}",
        d.stale
    );
}

#[test]
fn serve_layer_carries_zero_grandfathered_entries() {
    // The wire-facing layer is fully burned down: no grandfathered panic
    // paths, and none of its files appear in the baseline under any rule.
    let base = Baseline::load(&workspace_root().join("lint").join("baseline.json")).unwrap();
    for e in &base.entries {
        assert_ne!(e.rule, "no-panic-on-wire", "no grandfathered panics anywhere: {e:?}");
        assert!(
            !e.file.starts_with("rust/src/serve/"),
            "serve/ must stay at a zero-entry baseline: {e:?}"
        );
    }
}

#[test]
fn baseline_matches_write_baseline_output_format() {
    // The committed file is byte-identical to what `--write-baseline`
    // would regenerate from the current scan — no drift, no hand edits.
    let root = workspace_root();
    let ws = scan_workspace(&root).unwrap();
    let regenerated = Baseline::from_violations(&ws.violations).render();
    let committed = std::fs::read_to_string(root.join("lint").join("baseline.json")).unwrap();
    assert_eq!(committed, regenerated, "run ktbo-lint --write-baseline to refresh");
}
