//! Every rule has a firing fixture and an allow-suppressed twin under
//! `tests/fixtures/`. The fixtures are scanned with synthetic in-scope
//! paths (fixtures live outside the workspace's scanned roots, so they
//! never pollute the real scan).

use ktbo_lint::scan::{scan_source, FileScan};

fn scan(path: &str, src: &str) -> FileScan {
    scan_source(path, src)
}

fn findings(fs: &FileScan) -> Vec<(&str, u32)> {
    fs.violations.iter().map(|v| (v.rule.as_str(), v.line)).collect()
}

/// (fixture, synthetic scope path, expected (rule, line) findings).
/// Every `*_allowed` twin must scan clean with zero unused allows — the
/// directive both suppresses and counts as used.
const CASES: &[(&str, &str, &[(&str, u32)])] = &[
    (
        // On the trace path both clock rules fire per read site, sorted
        // (line, rule) — `no-untracked-clock` alphabetically first.
        include_str!("fixtures/no_wall_clock_fires.rs"),
        "rust/src/strategies/fixture.rs",
        &[
            ("no-untracked-clock", 4),
            ("no-wall-clock", 4),
            ("no-untracked-clock", 8),
            ("no-wall-clock", 8),
        ],
    ),
    (
        include_str!("fixtures/no_wall_clock_allowed.rs"),
        "rust/src/strategies/fixture.rs",
        &[],
    ),
    (
        // Outside the trace path only the workspace-wide clock rule fires.
        include_str!("fixtures/no_untracked_clock_fires.rs"),
        "rust/src/util/fixture.rs",
        &[("no-untracked-clock", 4), ("no-untracked-clock", 8)],
    ),
    (
        include_str!("fixtures/no_untracked_clock_allowed.rs"),
        "rust/src/util/fixture.rs",
        &[],
    ),
    (
        include_str!("fixtures/no_hash_order_fires.rs"),
        "rust/src/harness/fixture.rs",
        &[("no-hash-order", 1), ("no-hash-order", 3), ("no-hash-order", 4)],
    ),
    (
        include_str!("fixtures/no_hash_order_allowed.rs"),
        "rust/src/harness/fixture.rs",
        &[],
    ),
    (
        include_str!("fixtures/rng_discipline_fires.rs"),
        "rust/src/surrogate/fixture.rs",
        &[("rng-discipline", 2), ("rng-discipline", 7)],
    ),
    (
        include_str!("fixtures/rng_discipline_allowed.rs"),
        "rust/src/surrogate/fixture.rs",
        &[],
    ),
    (
        include_str!("fixtures/no_panic_on_wire_fires.rs"),
        "rust/src/serve/fixture.rs",
        // Line 3 carries both the indexing and the `.unwrap()` finding.
        &[("no-panic-on-wire", 3), ("no-panic-on-wire", 3), ("no-panic-on-wire", 8)],
    ),
    (
        include_str!("fixtures/no_panic_on_wire_allowed.rs"),
        "rust/src/serve/fixture.rs",
        &[],
    ),
    (
        include_str!("fixtures/stable_sort_fires.rs"),
        "rust/src/bo/fixture.rs",
        &[("stable-sort-tiebreak", 2)],
    ),
    (
        include_str!("fixtures/stable_sort_allowed.rs"),
        "rust/src/bo/fixture.rs",
        &[],
    ),
    (
        include_str!("fixtures/lint_directive_fires.rs"),
        // lint-directive applies everywhere, even out of every other scope;
        // the fixture's own allow-file(lint-directive) must not silence it.
        "rust/src/util/fixture.rs",
        &[("lint-directive", 3), ("lint-directive", 6)],
    ),
];

#[test]
fn every_rule_fires_and_its_allowed_twin_is_clean() {
    for (src, path, expected) in CASES {
        let fs = scan(path, src);
        assert_eq!(&findings(&fs), expected, "fixture at {path} mismatched");
        assert!(fs.unused_allows.is_empty(), "{path}: unused allows {:?}", fs.unused_allows);
    }
}

#[test]
fn out_of_scope_paths_are_exempt() {
    // The same banned constructs outside a rule's module scope: no findings
    // (util/ is deliberately unscoped for everything but lint-directive and
    // the workspace-wide no-untracked-clock, which is filtered here).
    for (src, _, expected) in CASES {
        if expected.iter().any(|(r, _)| *r == "lint-directive") {
            continue;
        }
        let fs = scan("rust/src/util/fixture.rs", src);
        let got: Vec<(&str, u32)> = findings(&fs)
            .into_iter()
            .filter(|(r, _)| *r != "no-untracked-clock")
            .collect();
        assert!(got.is_empty(), "util/ must be out of scope, got {got:?}");
    }
}

#[test]
fn test_gated_code_is_exempt() {
    let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn t() { let m: HashMap<u32, u32> = HashMap::new(); m.len(); }\n}\n";
    let fs = scan("rust/src/harness/fixture.rs", src);
    assert!(fs.violations.is_empty(), "cfg(test) items must be masked: {:?}", findings(&fs));

    let src = "#[test]\nfn check() {\n    let v = vec![1];\n    assert_eq!(v[0], 1);\n}\n";
    let fs = scan("rust/src/serve/fixture.rs", src);
    assert!(fs.violations.is_empty(), "#[test] fns must be masked: {:?}", findings(&fs));
}

#[test]
fn test_gated_mod_declarations_are_reported_upward() {
    let src = "#[cfg(test)]\nmod reference;\n\npub fn live() {}\n";
    let fs = scan("rust/src/strategies/mod.rs", src);
    assert_eq!(fs.test_gated_mods, vec!["reference".to_string()]);
    assert!(fs.violations.is_empty());
}

#[test]
fn dead_allow_on_shipping_code_is_reported() {
    let src = "pub fn clean() -> usize {\n    // ktbo-lint: allow(no-hash-order): nothing here actually fires\n    7\n}\n";
    let fs = scan("rust/src/harness/fixture.rs", src);
    assert!(fs.violations.is_empty());
    assert_eq!(fs.unused_allows, vec![("no-hash-order".to_string(), 2)]);
}

#[test]
fn allow_does_not_leak_past_its_target_line() {
    // The directive covers only the next code line; a second violation two
    // lines later must still fire.
    let src = "use std::collections::HashMap;\n";
    let prefixed = format!(
        "// ktbo-lint: allow(no-hash-order): first use is sanctioned\n{src}\npub fn second() -> HashMap<u32, u32> {{\n    HashMap::new()\n}}\n"
    );
    let fs = scan("rust/src/harness/fixture.rs", &prefixed);
    let got = findings(&fs);
    assert_eq!(
        got,
        vec![("no-hash-order", 4), ("no-hash-order", 5)],
        "only the use-line is suppressed"
    );
    assert!(fs.unused_allows.is_empty());
}
