//! Property-based tests of the coordinator invariants, using the in-tree
//! mini property-testing harness (`util::proptest`): random search spaces,
//! random objective tables, random budgets — the invariants must hold for
//! all of them.

use std::collections::HashSet;

use ktbo::bo::acquisition::{argmin_score, reduce_shard_argmins, score_chunk};
use ktbo::bo::{Acq, BoConfig, BoStrategy};
use ktbo::harness::metrics::{mean_deviation_factor, run_mae};
use ktbo::objective::{Eval, Objective, TableObjective};
use ktbo::space::{neighbors, Neighborhood, Param, Restriction, SearchSpace};
use ktbo::strategies::registry::by_name;
use ktbo::strategies::Strategy;
use ktbo::util::proptest::{check, Config};
use ktbo::util::rng::Rng;

/// A random space of 2–4 integer parameters with a random sum restriction,
/// plus a random objective table with a random invalid rate.
fn random_case(rng: &mut Rng) -> (TableObjective, u64) {
    let dims = 2 + rng.below(3);
    let params: Vec<Param> = (0..dims)
        .map(|d| {
            let k = 3 + rng.below(8) as i64;
            Param::ints(&format!("p{d}"), &(1..=k).collect::<Vec<_>>())
        })
        .collect();
    let modulus = 2 + rng.below(3) as i64;
    let restrictions = vec![Restriction::new("sum % m != 0", move |a| {
        let s: i64 = (0..dims).map(|d| a.i(&format!("p{d}"))).sum();
        s % modulus != 0
    })];
    let space = SearchSpace::build("prop", params, &restrictions);
    let invalid_rate = rng.f64() * 0.4;
    let table: Vec<Eval> = (0..space.len())
        .map(|i| {
            if rng.f64() < invalid_rate {
                if rng.chance(0.5) {
                    Eval::CompileError
                } else {
                    Eval::RuntimeError
                }
            } else {
                let p = space.point(i);
                let v: f64 = 1.0
                    + p.iter()
                        .map(|&x| {
                            let d = f64::from(x) - 0.5;
                            d * d
                        })
                        .sum::<f64>()
                    + rng.f64() * 0.1;
                Eval::Valid(v)
            }
        })
        .collect();
    let seed = rng.next_u64();
    (TableObjective::new(space, table), seed)
}

#[test]
fn prop_space_enumeration_is_sound() {
    check(
        "space-enumeration",
        &Config { cases: 30, ..Config::default() },
        random_case,
        |(obj, _)| {
            let s = obj.space();
            if s.is_empty() {
                return Ok(()); // empty restricted spaces are legal
            }
            for i in 0..s.len() {
                if s.index_of(&s.config(i)) != Some(i) {
                    return Err(format!("index_of roundtrip failed at {i}"));
                }
                if s.index_of_key(s.key(i)) != Some(i) {
                    return Err(format!("key index roundtrip failed at {i}"));
                }
                for &x in s.point(i) {
                    if !(0.0..=1.0).contains(&x) {
                        return Err(format!("coordinate {x} outside unit cube"));
                    }
                }
            }
            if s.len() > s.cartesian_size {
                return Err("restricted space larger than Cartesian".into());
            }
            Ok(())
        },
        |(obj, _)| format!("space of {} configs", obj.space().len()),
    );
}

#[test]
fn prop_neighbors_are_symmetric_and_in_space() {
    check(
        "neighbors-symmetric",
        &Config { cases: 15, ..Config::default() },
        random_case,
        |(obj, seed)| {
            let s = obj.space();
            if s.is_empty() {
                return Ok(());
            }
            let mut rng = Rng::new(*seed);
            for _ in 0..10.min(s.len()) {
                let i = rng.below(s.len());
                for kind in [Neighborhood::Hamming, Neighborhood::Adjacent] {
                    for j in neighbors(s, i, kind) {
                        if j >= s.len() {
                            return Err(format!("neighbor {j} out of range"));
                        }
                        if j == i {
                            return Err("self-neighbor".into());
                        }
                        // Symmetry: i ∈ N(j) ⟺ j ∈ N(i).
                        if !neighbors(s, j, kind).contains(&i) {
                            return Err(format!("asymmetric {kind:?} neighborhood {i}<->{j}"));
                        }
                    }
                }
            }
            Ok(())
        },
        |(obj, seed)| format!("space {} seed {seed:#x}", obj.space().len()),
    );
}

#[test]
fn prop_every_strategy_respects_budget_and_uniqueness() {
    // The coordinator's core state-management invariant: no strategy may
    // exceed the evaluation budget, and no strategy spends budget twice on
    // the same configuration (unique-evaluation semantics).
    let names =
        ["ei", "multi", "advanced_multi", "random", "simulated_annealing", "mls", "genetic_algorithm"];
    check(
        "budget-and-uniqueness",
        &Config { cases: 8, ..Config::default() },
        random_case,
        |(obj, seed)| {
            if obj.space().is_empty() {
                return Ok(());
            }
            let mut seeder = Rng::new(*seed);
            let budget = 10 + seeder.below(60);
            for name in names {
                let s = by_name(name).unwrap();
                let mut rng = Rng::new(*seed ^ 0xabc);
                let trace = s.run(obj, budget, &mut rng);
                if trace.len() > budget {
                    return Err(format!("{name} exceeded budget: {} > {budget}", trace.len()));
                }
                let idxs: HashSet<usize> = trace.records.iter().map(|(i, _)| *i).collect();
                if idxs.len() != trace.len() {
                    return Err(format!("{name} re-evaluated a configuration"));
                }
                if let Some(&bad) = idxs.iter().find(|&&i| i >= obj.space().len()) {
                    return Err(format!("{name} evaluated out-of-space index {bad}"));
                }
            }
            Ok(())
        },
        |(obj, seed)| format!("space {} seed {seed:#x}", obj.space().len()),
    );
}

#[test]
fn prop_best_curve_monotone_nonincreasing() {
    check(
        "best-curve-monotone",
        &Config { cases: 12, ..Config::default() },
        random_case,
        |(obj, seed)| {
            if obj.space().is_empty() {
                return Ok(());
            }
            for name in ["random", "genetic_algorithm", "advanced_multi"] {
                let s = by_name(name).unwrap();
                let mut rng = Rng::new(*seed);
                let curve = s.run(obj, 50, &mut rng).best_curve();
                for w in curve.windows(2) {
                    if w[1] > w[0] {
                        return Err(format!("{name}: best curve increased {} -> {}", w[0], w[1]));
                    }
                }
            }
            Ok(())
        },
        |(obj, seed)| format!("space {} seed {seed:#x}", obj.space().len()),
    );
}

#[test]
fn prop_bo_best_matches_table() {
    // §III-D2 consequence: the reported best must be a *valid* table entry
    // (invalid observations are never fitted nor reported).
    check(
        "bo-best-valid",
        &Config { cases: 8, ..Config::default() },
        random_case,
        |(obj, seed)| {
            if obj.space().is_empty() {
                return Ok(());
            }
            let mut cfg = BoConfig::single(Acq::Ei);
            cfg.pruning = false;
            cfg.init_samples = 8;
            let s = BoStrategy::new("ei", cfg);
            let mut rng = Rng::new(*seed);
            let trace = s.run(obj, 40, &mut rng);
            if let Some((idx, v)) = trace.best() {
                match obj.table()[idx] {
                    Eval::Valid(tv) if (tv - v).abs() < 1e-12 => {}
                    _ => return Err("best() does not match the table".into()),
                }
            }
            Ok(())
        },
        |(obj, seed)| format!("space {} seed {seed:#x}", obj.space().len()),
    );
}

#[test]
fn prop_mae_and_mdf_invariances() {
    check(
        "metric-invariances",
        &Config { cases: 40, ..Config::default() },
        |rng| {
            let n = 2 + rng.below(4);
            let k = 2 + rng.below(3);
            let mae: Vec<Vec<f64>> =
                (0..k).map(|_| (0..n).map(|_| 0.1 + rng.f64() * 10.0).collect()).collect();
            let scale = 0.5 + rng.f64() * 100.0;
            (mae, scale)
        },
        |(mae, scale)| {
            // MDF is invariant to per-kernel scaling.
            let base = mean_deviation_factor(mae);
            let scaled: Vec<Vec<f64>> =
                mae.iter().map(|row| row.iter().map(|v| v * scale).collect()).collect();
            let after = mean_deviation_factor(&scaled);
            for (a, b) in base.iter().zip(&after) {
                if (a.0 - b.0).abs() > 1e-9 {
                    return Err(format!("MDF not scale-invariant: {} vs {}", a.0, b.0));
                }
            }
            // MAE of a constant-at-minimum curve is 0.
            let curve = vec![3.5; 220];
            if run_mae(&curve, 3.5, 10.0).abs() > 1e-12 {
                return Err("MAE of optimal curve not zero".into());
            }
            Ok(())
        },
        |(mae, scale)| format!("{}x{} matrix, scale {scale}", mae.len(), mae[0].len()),
    );
}

#[test]
fn prop_fused_shard_scoring_matches_reference() {
    // The engine's fused per-shard score+argmin (score_chunk over a
    // partition + reduce_shard_argmins) must reproduce the reference
    // score/argmin_score composition for every AF on arbitrary inputs —
    // including all-masked and single-candidate cases, and for every
    // chunk size (1 ⇒ one shard per candidate, ≥ m ⇒ one shard total).
    check(
        "fused-score-argmin",
        &Config { cases: 150, ..Config::default() },
        |rng| {
            let m = 1 + rng.below(64);
            let mu: Vec<f64> = (0..m).map(|_| rng.normal() * 2.0).collect();
            let var: Vec<f64> = (0..m).map(|_| 1e-12 + rng.f64()).collect();
            let all_masked = rng.chance(0.15);
            let masked: Vec<bool> = (0..m).map(|_| all_masked || rng.chance(0.3)).collect();
            let f_best = rng.normal();
            let lambda = rng.f64() * 2.0;
            let chunk = 1 + rng.below(m + 4); // may exceed m: single shard
            (mu, var, masked, f_best, lambda, chunk)
        },
        |(mu, var, masked, f_best, lambda, chunk)| {
            let afs = [Acq::Ei, Acq::Poi, Acq::Lcb];
            let mut parts = Vec::new();
            let mut start = 0;
            while start < mu.len() {
                let end = (start + chunk).min(mu.len());
                parts.push(score_chunk(
                    &afs,
                    &mu[start..end],
                    &var[start..end],
                    &masked[start..end],
                    start,
                    *f_best,
                    *lambda,
                ));
                start = end;
            }
            let fused = reduce_shard_argmins(&parts, afs.len());
            for (i, acq) in afs.iter().enumerate() {
                let reference = argmin_score(*acq, mu, var, *f_best, *lambda, masked);
                if fused[i] != reference {
                    return Err(format!("{acq:?}: fused {:?} vs reference {:?}", fused[i], reference));
                }
            }
            Ok(())
        },
        |(mu, _, masked, f_best, lambda, chunk)| {
            format!(
                "m={} chunk={chunk} f_best={f_best} lambda={lambda} masked={masked:?}",
                mu.len()
            )
        },
    );
}

#[test]
fn prop_seeding_is_deterministic() {
    // Same seed → identical trace, for every strategy (reproducibility of
    // the experiment harness).
    check(
        "determinism",
        &Config { cases: 6, ..Config::default() },
        random_case,
        |(obj, seed)| {
            if obj.space().is_empty() {
                return Ok(());
            }
            for name in ["ei", "random", "simulated_annealing", "genetic_algorithm", "mls"] {
                let s = by_name(name).unwrap();
                let mut r1 = Rng::new(*seed);
                let mut r2 = Rng::new(*seed);
                let a = s.run(obj, 30, &mut r1);
                let b = s.run(obj, 30, &mut r2);
                let ia: Vec<usize> = a.records.iter().map(|(i, _)| *i).collect();
                let ib: Vec<usize> = b.records.iter().map(|(i, _)| *i).collect();
                if ia != ib {
                    return Err(format!("{name} is not deterministic under a fixed seed"));
                }
            }
            Ok(())
        },
        |(obj, seed)| format!("space {} seed {seed:#x}", obj.space().len()),
    );
}
