//! Full-stack integration: simulated spaces for every (kernel, GPU) pair,
//! every strategy, the §IV-A protocol at reduced scale, and the paper's
//! qualitative claims at small repeat counts.

use std::sync::Arc;

use ktbo::gpusim::device::Device;
use ktbo::gpusim::kernels::{all_kernels, kernel_by_name};
use ktbo::gpusim::SimulatedSpace;
use ktbo::harness::figures::objective_for;
use ktbo::harness::metrics::mean_deviation_factor;
use ktbo::harness::runner::{objective_id, repeats_for, run_comparison, run_strategy};
use ktbo::objective::{Objective, TableObjective};
use ktbo::strategies::registry::{all_names, by_name};
use ktbo::util::rng::Rng;

#[test]
fn every_kernel_builds_on_every_device() {
    for dev in Device::all() {
        for k in all_kernels() {
            let sim = SimulatedSpace::build(k.as_ref(), &dev);
            assert!(sim.space.len() > 1000, "{} on {} too small", k.name(), dev.name);
            let (idx, min) = sim.global_minimum();
            assert!(min > 0.0 && min.is_finite());
            assert!(idx < sim.space.len());
            // The minimum must beat the valid mean by a real margin —
            // otherwise tuning would be pointless.
            assert!(
                min < sim.valid_mean() * 0.8,
                "{} on {}: minimum {min} too close to mean {}",
                k.name(),
                dev.name,
                sim.valid_mean()
            );
        }
    }
}

#[test]
fn every_strategy_runs_on_every_kernel() {
    // One cheap smoke run per (strategy, kernel) on the Titan X.
    let dev = Device::gtx_titan_x();
    for kernel in ["gemm", "convolution", "pnpoly", "expdist", "adding"] {
        let obj = objective_for(kernel, &dev);
        for name in all_names() {
            let s = by_name(name).unwrap();
            let mut rng = Rng::new(1);
            let trace = s.run(obj.as_ref(), 40, &mut rng);
            assert!(!trace.is_empty(), "{name} produced no evaluations on {kernel}");
            assert!(trace.len() <= 40);
            assert!(trace.best().is_some(), "{name} found nothing valid on {kernel}");
        }
    }
}

#[test]
fn bo_beats_random_on_gemm() {
    // The paper's core claim at minimum viable scale: on GEMM/Titan X the
    // BO methods' MAE must beat random search decisively.
    let obj = objective_for("gemm", &Device::gtx_titan_x());
    let oid = objective_id("gemm", Device::gtx_titan_x().name);
    let bo = run_strategy(&obj, &oid, "ei", 220, 5, 7, 0);
    let rnd = run_strategy(&obj, &oid, "random", 220, 10, 7, 0);
    assert!(
        bo.mae.mean < rnd.mae.mean * 0.7,
        "EI MAE {} not clearly better than random {}",
        bo.mae.mean,
        rnd.mae.mean
    );
}

#[test]
fn advanced_multi_beats_random_across_kernels() {
    let dev = Device::gtx_titan_x();
    let mut mae = Vec::new();
    for kernel in ["gemm", "convolution"] {
        let obj = objective_for(kernel, &dev);
        let out =
            run_comparison(&obj, &objective_id(kernel, dev.name), &["advanced_multi", "random"], 220, 0.1, 3, 0);
        mae.push(out.iter().map(|o| o.mae.mean).collect::<Vec<_>>());
    }
    let mdf = mean_deviation_factor(&mae);
    assert!(
        mdf[0].0 < mdf[1].0,
        "advanced_multi MDF {} should beat random {}",
        mdf[0].0,
        mdf[1].0
    );
}

#[test]
fn framework_bo_struggles_on_restricted_gemm() {
    // §IV-D: on the heavily restricted GEMM space, constraint-blind
    // framework BO wastes many evaluations out of space.
    let obj = objective_for("gemm", &Device::rtx_2070_super());
    let s = by_name("bayesianoptimization").unwrap();
    let mut rng = Rng::new(5);
    let trace = s.run(obj.as_ref(), 100, &mut rng);
    let wasted = trace
        .records
        .iter()
        .filter(|(i, _)| *i == ktbo::strategies::OUT_OF_SPACE)
        .count();
    // GEMM keeps ~22% of its Cartesian product: the majority of blind
    // proposals must fail.
    assert!(
        wasted > trace.len() / 4,
        "expected heavy budget waste, got {wasted}/{}",
        trace.len()
    );
}

#[test]
fn table_objective_known_minimum_consistent() {
    let k = kernel_by_name("adding").unwrap();
    let sim = SimulatedSpace::build(k.as_ref(), &Device::a100());
    let (_, min) = sim.global_minimum();
    let obj = TableObjective::from_sim(sim);
    assert_eq!(obj.known_minimum(), Some(min));
}

#[test]
fn gp_hotpath_bench_smoke() {
    // The gp_hotpath bench binary is a thin CLI over harness::gp_bench;
    // running the smoke grid here keeps the bench from silently rotting.
    use ktbo::harness::gp_bench::{run_scenario, scenario_grid, to_json};
    let records: Vec<_> = scenario_grid(true).iter().map(run_scenario).collect();
    assert!(!records.is_empty());
    for r in &records {
        assert!(r.ms_per_iter.is_finite() && r.ms_per_iter >= 0.0, "bad timing in {:?}", r.scenario);
    }
    let doc = to_json(&records).render_pretty();
    assert!(doc.contains("\"bench\": \"gp_hotpath\""));
    assert!(doc.contains("fused_sharded") && doc.contains("baseline_serial"));
}

#[test]
fn space_build_bench_smoke() {
    // The space_build bench binary is a thin CLI over
    // harness::space_bench; running the smoke grid here keeps the bench
    // from silently rotting.
    use ktbo::harness::space_bench::{run_scenario, scenario_grid, to_json};
    let records: Vec<_> = scenario_grid(true).iter().map(run_scenario).collect();
    assert!(!records.is_empty());
    let first_digest = records[0].keys_digest;
    for r in &records {
        assert!(r.ms_per_build.is_finite() && r.ms_per_build >= 0.0, "bad timing in {:?}", r.scenario);
        assert!(r.configs > 0 && r.configs <= r.cartesian);
        assert_eq!(r.keys_digest, first_digest, "smoke scenarios build one identical space");
    }
    let doc = to_json(&records).render_pretty();
    assert!(doc.contains("\"bench\": \"space_build\""));
    assert!(doc.contains("keys_digest"));
}

#[test]
fn surrogate_fit_bench_smoke() {
    // The surrogate_fit bench binary is a thin CLI over
    // harness::surrogate_bench; running the smoke grid here keeps the
    // bench from silently rotting.
    use ktbo::harness::surrogate_bench::{run_scenario, scenario_grid, to_json};
    let records: Vec<_> = scenario_grid(true).iter().map(run_scenario).collect();
    assert!(!records.is_empty());
    for r in &records {
        assert!(
            r.ms_fit.is_finite() && r.ms_fit >= 0.0 && r.ms_predict.is_finite() && r.ms_predict >= 0.0,
            "bad timing in {:?}",
            r.scenario
        );
        assert!(r.configs > 0);
        // Determinism hook: the 1- and 4-thread runs of one model must
        // predict identical mean bits.
        let twin = records
            .iter()
            .find(|o| o.scenario.model == r.scenario.model && o.scenario.threads != r.scenario.threads)
            .expect("smoke grid pairs every model across thread counts");
        assert_eq!(r.mu_digest, twin.mu_digest, "{} prediction depends on threads", r.scenario.model);
    }
    let doc = to_json(&records).render_pretty();
    assert!(doc.contains("\"bench\": \"surrogate_fit\""));
    for model in ["gp", "rf", "et", "tpe"] {
        assert!(doc.contains(&format!("\"model\": \"{model}\"")), "{model} missing from the doc");
    }
}

#[test]
fn session_step_bench_smoke() {
    // The session_step bench binary is a thin CLI over
    // harness::session_bench; running the smoke grid here keeps the
    // bench from silently rotting.
    use ktbo::harness::session_bench::{run_scenario, scenario_grid, to_json};
    let records: Vec<_> = scenario_grid(true).iter().map(run_scenario).collect();
    assert!(!records.is_empty());
    for r in &records {
        assert!(r.ns_per_step.is_finite() && r.ns_per_step > 0.0, "bad timing in {:?}", r.scenario);
        assert!(r.evaluations > 0, "scenario {:?} timed nothing", r.scenario);
    }
    let doc = to_json(&records).render_pretty();
    assert!(doc.contains("\"bench\": \"session_step\""));
    assert!(doc.contains("\"mode\": \"inprocess\"") && doc.contains("\"mode\": \"served\""));
}

#[test]
fn space_scale_bench_smoke() {
    // The space_scale bench binary is a thin CLI over
    // harness::space_scale_bench; running the smoke grid here keeps the
    // bench — and its flatness assertion — from silently rotting.
    use ktbo::harness::space_scale_bench::{flatness_violation, run_scenario, scenario_grid, to_json};
    let records: Vec<_> = scenario_grid(true).iter().map(run_scenario).collect();
    assert!(!records.is_empty());
    for r in &records {
        assert!(r.us_per_suggestion.is_finite() && r.us_per_suggestion > 0.0, "bad timing in {:?}", r.scenario);
        assert_eq!(r.evaluations, r.scenario.budget, "scenario {:?} under-evaluated", r.scenario);
    }
    // The bench's acceptance predicate itself: per-suggestion probe work
    // bounded by the pool/dims cap at every size in the grid.
    assert_eq!(flatness_violation(&records), None);
    let doc = to_json(&records).render_pretty();
    assert!(doc.contains("\"bench\": \"space_scale\""));
    assert!(doc.contains("probes_per_suggestion"));
}

#[test]
fn surrogate_zoo_sweeps_all_kernels() {
    // Acceptance: bo_rf, bo_et, and tpe run end-to-end on all five
    // kernels via the orchestrated sweep, producing valid JSONL records
    // and MAE/MDF aggregates — the non-GP surrogates flow through
    // drive(), the sweep, and the metrics untouched.
    use ktbo::harness::orchestrator::{sweep, SweepSpec};
    let out = std::env::temp_dir().join("ktbo-int-surrogate-zoo").to_string_lossy().into_owned();
    let spec = SweepSpec {
        kernels: vec!["gemm".into(), "convolution".into(), "pnpoly".into(), "expdist".into(), "adding".into()],
        gpus: vec!["titanx".into()],
        strategies: vec!["bo_rf".into(), "bo_et".into(), "tpe".into()],
        budget: 25,
        repeat_scale: 0.02, // 3 repeats per cell
        seed: 13,
        threads: 2,
        out_dir: out.clone(),
        tag: "surrogate-zoo".into(),
        cache: true,
        fresh: true,
        space: None,
        fault_plan: None,
        fault_strategies: vec![],
        eval_timeout_ms: None,
        max_retries: 0,
    };
    let report = sweep(&spec).unwrap();
    assert_eq!(report.outcomes.len(), 5, "one outcome set per kernel");
    let mut mae_matrix: Vec<Vec<f64>> = Vec::new(); // kernel-major, strategy columns
    for ((kernel, _gpu), outs) in &report.outcomes {
        assert_eq!(outs.len(), 3, "{kernel}: all three surrogates must report");
        for o in outs {
            assert_eq!(o.mean_curve.len(), 25, "{kernel}/{}", o.name);
            assert!(o.mean_curve.iter().all(|v| v.is_finite()), "{kernel}/{}", o.name);
            assert!(o.mae.mean.is_finite() && o.mae.mean >= 0.0, "{kernel}/{} MAE", o.name);
            assert!(o.finals.iter().all(|v| v.is_finite()), "{kernel}/{}", o.name);
        }
        mae_matrix.push(outs.iter().map(|o| o.mae.mean).collect());
    }
    // MDF flows over the surrogate zoo exactly as over the paper zoo.
    let mdf = mean_deviation_factor(&mae_matrix);
    assert_eq!(mdf.len(), 3);
    // MDF normalizes by the per-kernel mean over strategies, so the
    // factors are positive and average to ~1 across the zoo.
    assert!(mdf.iter().all(|(v, _)| v.is_finite() && *v > 0.0), "bad MDF: {mdf:?}");
    let mdf_mean: f64 = mdf.iter().map(|(v, _)| v).sum::<f64>() / mdf.len() as f64;
    assert!((mdf_mean - 1.0).abs() < 1e-9, "MDF factors must average to 1: {mdf:?}");
    // The JSONL progress log carries every surrogate cell.
    let progress =
        std::fs::read_to_string(std::path::Path::new(&out).join("SWEEP_surrogate-zoo.jsonl")).unwrap();
    for s in ["bo_rf", "bo_et", "tpe"] {
        assert!(progress.contains(&format!("\"strategy\":\"{s}\"")), "{s} missing from JSONL");
    }
}

#[test]
fn json_space_files_match_their_hand_coded_twins() {
    // Acceptance: every shipped examples/spaces/<kernel>.json builds the
    // same restricted space (size and membership) as the kernel's
    // builder-defined spec. convolution.json encodes the GTX Titan X
    // flavour (its restrictions are device-dependent).
    use ktbo::space::SpaceSpec;
    let dev = Device::gtx_titan_x();
    for kernel in ["gemm", "convolution", "pnpoly", "expdist", "adding"] {
        let path = format!("{}/../examples/spaces/{kernel}.json", env!("CARGO_MANIFEST_DIR"));
        let spec = SpaceSpec::load(std::path::Path::new(&path))
            .unwrap_or_else(|e| panic!("{kernel}: {e}"));
        let from_file = spec.build();
        let hand_coded = kernel_by_name(kernel).unwrap().spec(&dev).build();
        assert_eq!(from_file.len(), hand_coded.len(), "{kernel}: restricted sizes differ");
        assert_eq!(from_file.cartesian_size, hand_coded.cartesian_size, "{kernel}");
        for i in (0..from_file.len()).step_by(199) {
            assert_eq!(from_file.config(i), hand_coded.config(i), "{kernel}: config {i} differs");
        }
    }
}

#[test]
fn bo_sequence_survives_thread_and_shard_sweep_on_simulated_space() {
    // Engine-level determinism on a real simulated kernel space (adding on
    // the A100): the full §III pipeline — pruning, contextual variance,
    // advanced multi — must produce one evaluation sequence for every
    // (shard, thread) configuration.
    use ktbo::bo::{BoConfig, BoStrategy};
    use ktbo::strategies::Strategy;
    let obj = objective_for("adding", &Device::a100());
    let seq = |shard_len: usize, threads: usize| -> Vec<usize> {
        let mut cfg = BoConfig::advanced_multi();
        cfg.shard_len = shard_len;
        cfg.threads = threads;
        let s = BoStrategy::new("advanced_multi", cfg);
        let mut rng = Rng::new(20210601);
        s.run(obj.as_ref(), 60, &mut rng).records.iter().map(|(i, _)| *i).collect()
    };
    let reference = seq(1 << 30, 1); // single shard, serial
    for &(sl, th) in &[(0, 8), (512, 2), (257, 4)] {
        assert_eq!(seq(sl, th), reference, "diverged at shard_len={sl} threads={th}");
    }
}

#[test]
fn comparison_runner_is_seed_stable() {
    let obj: Arc<TableObjective> = objective_for("adding", &Device::a100());
    let oid = objective_id("adding", Device::a100().name);
    let a = run_strategy(&obj, &oid, "multi", 100, 3, 42, 2);
    let b = run_strategy(&obj, &oid, "multi", 100, 3, 42, 4);
    assert_eq!(a.maes, b.maes, "results must not depend on thread count");
    let c = run_strategy(&obj, &oid, "multi", 100, 3, 43, 2);
    assert_ne!(a.maes, c.maes, "different seeds must differ");
    let d = run_strategy(&obj, "adding@somewhere-else", "multi", 100, 3, 42, 2);
    assert_ne!(a.maes, d.maes, "the objective id is part of the cell seed");
}

#[test]
fn batch_ask_with_target_budget_early_stops_on_simulated_kernel() {
    // End-to-end over the public ask/tell API: BO in batch mode (`multi`
    // proposes every per-AF argmin from the fused sweep — >1 suggestion
    // per step) driven under a non-feval budget (early stop on target
    // value) on a real simulated kernel space.
    use ktbo::bo::{BoConfig, BoStrategy};
    use ktbo::strategies::driver::{
        drive, Ask, Budget, DriveCtx, FevalBudget, Observation, SearchDriver, TargetBudget,
    };
    use ktbo::strategies::Strategy;
    use std::sync::{Arc as StdArc, Mutex};

    /// Wraps a driver to record every batch size it proposes.
    struct Spy {
        inner: Box<dyn SearchDriver>,
        batch_sizes: StdArc<Mutex<Vec<usize>>>,
    }
    impl SearchDriver for Spy {
        fn name(&self) -> String {
            self.inner.name()
        }
        fn memoize(&self) -> bool {
            self.inner.memoize()
        }
        fn ask(&mut self, ctx: &mut DriveCtx) -> Ask {
            let ask = self.inner.ask(ctx);
            if let Ask::Suggest(batch) = &ask {
                self.batch_sizes.lock().unwrap().push(batch.len());
            }
            ask
        }
        fn tell(&mut self, obs: Observation) {
            self.inner.tell(obs);
        }
    }

    let obj = objective_for("adding", &Device::a100());
    let global = obj.known_minimum().unwrap();
    let target = global * 1.5; // reachable well before 220 fevals

    let mut cfg = BoConfig::multi();
    cfg.batch_ask = true;
    let s = BoStrategy::new("multi-batch", cfg);
    let sizes = StdArc::new(Mutex::new(Vec::new()));
    let mut spy = Spy { inner: s.driver(obj.space()), batch_sizes: StdArc::clone(&sizes) };

    let budget = TargetBudget::new(target, Box::new(FevalBudget::new(220)));
    let mut rng = Rng::new(20210601);
    let trace = drive(&mut spy, obj.as_ref(), &budget, &mut rng);

    assert!(trace.best().unwrap().1 <= target, "target not reached");
    assert!(
        trace.len() < 220,
        "target budget must stop early, used all {} evaluations",
        trace.len()
    );
    // The first ask is the 20-point LHS batch; acquisition steps propose
    // one argmin per active AF — a real >1-suggestion step must appear
    // (2 or 3 distinct argmins under the `multi` portfolio).
    let sizes = sizes.lock().unwrap();
    assert!(
        sizes.iter().any(|&b| (2..=3).contains(&b)),
        "multi batch mode must propose >1 acquisition argmin per step at least once: {sizes:?}"
    );
    assert!(!budget.proceed(&trace), "budget must report the stop");
}

#[test]
fn stepwise_orchestration_matches_whole_run_comparison() {
    // The orchestrator's step-level interleaving on a simulated kernel
    // must agree with the classic whole-run comparison path exactly.
    use ktbo::harness::orchestrator::orchestrate_comparison_stepwise;
    let dev = Device::gtx_titan_x();
    let obj = objective_for("pnpoly", &dev);
    let oid = objective_id("pnpoly", dev.name);
    let stepwise = orchestrate_comparison_stepwise(&obj, &oid, &["random", "ei"], 50, 0.03, 9);
    for o in &stepwise {
        let reference = run_strategy(&obj, &oid, &o.name, 50, o.maes.len(), 9, 1);
        assert_eq!(o.mean_curve, reference.mean_curve, "{}", o.name);
        assert_eq!(o.maes, reference.maes, "{}", o.name);
    }
}

#[test]
fn smoke_sweep_is_bit_identical_to_serial_and_resumes() {
    // The `ktbo sweep --smoke` tier end to end: orchestrated cells must
    // reproduce the serial reference path bit-for-bit at several worker
    // counts, persist JSONL artifacts, and resume without re-running.
    use ktbo::harness::orchestrator::{sweep, SweepSpec};

    let out = std::env::temp_dir().join("ktbo-int-sweep").to_string_lossy().into_owned();
    let mut spec = SweepSpec::smoke(&out);
    spec.fresh = true;
    let dev = Device::a100();
    let obj = objective_for("adding", &dev);
    let oid = objective_id("adding", dev.name);

    let mut reports = Vec::new();
    for threads in [1usize, 4] {
        let mut s = spec.clone();
        s.threads = threads;
        s.tag = format!("smoke-int-{threads}");
        reports.push(sweep(&s).unwrap());
    }
    for report in &reports {
        assert_eq!(report.outcomes.len(), 1);
        assert!(report.failed_cells.is_empty(), "the smoke fault plan never crashes");
        let outs = &report.outcomes[0].1;
        assert_eq!(outs.len(), spec.strategies.len());
        for o in outs {
            let reference = run_strategy(
                &obj,
                &oid,
                &o.name,
                spec.budget,
                repeats_for(&o.name, spec.repeat_scale),
                spec.seed,
                1,
            );
            if o.name == "simulated_annealing" {
                // The smoke tier runs this strategy's cells under the
                // committed fault plan: they must diverge from the clean
                // serial path (injection bites) — thread-invariance is
                // asserted across the two reports below.
                assert_ne!(o.mean_curve, reference.mean_curve, "fault injection had no effect");
                continue;
            }
            assert_eq!(o.mean_curve, reference.mean_curve, "{} diverged from serial path", o.name);
            assert_eq!(o.maes, reference.maes, "{} MAEs diverged", o.name);
        }
    }
    // Faulted cells are part of the determinism contract too: identical
    // at 1 and 4 workers.
    let sa_1 = reports[0].outcomes[0].1.iter().find(|o| o.name == "simulated_annealing").unwrap();
    let sa_4 = reports[1].outcomes[0].1.iter().find(|o| o.name == "simulated_annealing").unwrap();
    assert_eq!(sa_1.mean_curve, sa_4.mean_curve, "faulted cells diverged across worker counts");
    assert_eq!(sa_1.maes, sa_4.maes);

    // Exactly the faulted cells carry the fault-accounting block.
    let progress_text = std::fs::read_to_string(
        std::path::Path::new(&out).join("SWEEP_smoke-int-1.jsonl"),
    )
    .unwrap();
    for line in progress_text.lines().filter(|l| l.contains("\"type\":\"cell\"")) {
        assert_eq!(
            line.contains("\"faults\""),
            line.contains("\"strategy\":\"simulated_annealing\""),
            "fault accounting on the wrong cells: {line}"
        );
    }

    // JSONL artifacts exist and are non-empty (what CI asserts).
    let progress = std::path::Path::new(&out).join("SWEEP_smoke-int-1.jsonl");
    let results = std::path::Path::new(&out).join("SWEEP_smoke-int-1.results.jsonl");
    assert!(std::fs::metadata(&progress).unwrap().len() > 0);
    assert!(std::fs::metadata(&results).unwrap().len() > 0);

    // Rerun under the same tag: every cell resumes, aggregates unchanged.
    let mut s = spec.clone();
    s.tag = "smoke-int-1".into();
    s.fresh = false;
    let resumed = sweep(&s).unwrap();
    assert_eq!(resumed.ran_cells, 0, "a completed sweep must resume fully");
    assert_eq!(resumed.resumed_cells, resumed.total_cells);
    assert_eq!(resumed.outcomes[0].1[0].mean_curve, reports[0].outcomes[0].1[0].mean_curve);
}

/// A small valid table to wrap in fault injectors.
fn soak_table(n: i64) -> Arc<dyn Objective> {
    use ktbo::space::Param;
    use ktbo::space::SearchSpace;
    let vals: Vec<i64> = (0..n).collect();
    let space = SearchSpace::build("soak", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
    let table = (0..space.len())
        .map(|i| {
            let p = space.point(i);
            ktbo::objective::Eval::Valid(1.0 + f64::from(p[0]) + f64::from(p[1]))
        })
        .collect();
    Arc::new(ktbo::objective::TableObjective::new(space, table))
}

#[test]
fn every_strategy_survives_an_all_transient_objective() {
    // Robustness soak: with a 100% transient fault rate nothing is ever
    // valid. Every registry strategy must terminate within budget without
    // panicking or hanging, and report no best.
    use ktbo::objective::faulty::{FaultPlan, FaultyObjective};
    let inner = soak_table(12);
    let plan = FaultPlan { transient_rate: 1.0, ..FaultPlan::quiet(0xA11) };
    for name in all_names() {
        let s = by_name(name).unwrap();
        let obj = FaultyObjective::new(Arc::clone(&inner), plan.clone());
        let mut rng = Rng::new(3);
        let trace = s.run(&obj, 15, &mut rng);
        assert!(trace.len() <= 15, "{name} overran its budget");
        assert!(trace.best().is_none(), "{name} reported a best with no valid evaluation");
    }
}

#[test]
fn every_strategy_survives_an_all_persistent_invalid_objective() {
    // Same soak for persistent failures: a table where every config
    // fails to compile.
    use ktbo::space::{Param, SearchSpace};
    let vals: Vec<i64> = (0..12).collect();
    let space = SearchSpace::build("dead", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
    let table = (0..space.len()).map(|_| ktbo::objective::Eval::CompileError).collect();
    let obj = ktbo::objective::TableObjective::new(space, table);
    for name in all_names() {
        let s = by_name(name).unwrap();
        let mut rng = Rng::new(4);
        let trace = s.run(&obj, 15, &mut rng);
        assert!(trace.len() <= 15, "{name} overran its budget");
        assert!(trace.best().is_none(), "{name} reported a best on an all-invalid table");
    }
}

#[test]
fn bo_under_fault_injection_survives_thread_and_shard_sweep() {
    // Determinism soak: a fixed fault plan must yield one evaluation
    // sequence — injected faults included — for every (shard, thread)
    // configuration of the BO engine, since fault decisions are pure
    // hashes of (plan seed, index, attempt).
    use ktbo::bo::{BoConfig, BoStrategy};
    use ktbo::objective::faulty::{FaultPlan, FaultyObjective};
    use ktbo::strategies::Strategy;
    let inner = soak_table(24);
    let plan = FaultPlan {
        transient_rate: 0.25,
        hang_rate: 0.1,
        flaky_rate: 0.2,
        flaky_sigma: 0.5,
        ..FaultPlan::quiet(0xF417)
    };
    let seq = |shard_len: usize, threads: usize| -> Vec<(usize, ktbo::objective::Eval)> {
        let mut cfg = BoConfig::advanced_multi();
        cfg.shard_len = shard_len;
        cfg.threads = threads;
        let s = BoStrategy::new("advanced_multi", cfg);
        // A fresh injector per run: its only state is per-index attempt
        // counters, which replay identically for identical runs.
        let obj = FaultyObjective::new(Arc::clone(&inner), plan.clone());
        let mut rng = Rng::new(20210601);
        s.run(&obj, 45, &mut rng).records
    };
    let reference = seq(1 << 30, 1);
    assert!(
        reference.iter().any(|(_, e)| !e.is_valid()),
        "the plan must actually inject faults for this test to mean anything"
    );
    for &(sl, th) in &[(0, 8), (64, 2)] {
        assert_eq!(seq(sl, th), reference, "diverged at shard_len={sl} threads={th}");
    }
}

#[test]
fn lazy_tune_completes_on_the_billion_scale_spec_without_enumeration() {
    // Acceptance (implicit spaces): `ktbo tune --space megakernel_1g.json`
    // — a constraint-pruned ≥10⁹-config Cartesian product — runs `tpe`
    // AND a GP pool-mode strategy (`ei`) to completion under a feval
    // budget through the exact layers the CLI wires: LazyView oracle,
    // SyntheticObjective, Strategy::lazy_driver, Session. No enumeration,
    // no tiles; per-suggestion constraint work stays pool-bounded.
    use ktbo::objective::synthetic::SyntheticObjective;
    use ktbo::space::view::{LazyView, SpaceView};
    use ktbo::space::SpaceSpec;
    use ktbo::strategies::{FevalBudget, Session};
    use ktbo::util::rng::fnv1a;

    let path = format!("{}/../examples/spaces/megakernel_1g.json", env!("CARGO_MANIFEST_DIR"));
    let spec = SpaceSpec::load(std::path::Path::new(&path)).expect("spec loads");
    assert!(
        spec.cartesian_size() >= 1_000_000_000,
        "spec must be billion-scale, got {}",
        spec.cartesian_size()
    );

    let budget = 30usize;
    let pool = 64usize;
    for strategy_name in ["tpe", "ei"] {
        let view = Arc::new(LazyView::from_spec(&spec).expect("lazy view builds"));
        let strat = by_name(strategy_name).unwrap();
        let driver = strat
            .lazy_driver(view.as_ref(), pool)
            .unwrap_or_else(|| panic!("{strategy_name} must be lazy-capable"));
        let obj: Arc<dyn Objective> =
            Arc::new(SyntheticObjective::new(Arc::clone(&view), fnv1a(&spec.name)));
        let mut session =
            Session::new(driver, obj, Box::new(FevalBudget::new(budget)), Rng::new(20260807));
        while session.step() {}
        let trace = session.into_trace();
        assert_eq!(trace.len(), budget, "{strategy_name}: budget must be spent in full");
        let (best_idx, best) = trace.best().expect("a valid config is found");
        assert!(best.is_finite() && best > 0.0);
        for &(idx, _) in &trace.records {
            assert!(
                view.contains_key(idx as u64),
                "{strategy_name}: proposed key {idx} violates the restrictions"
            );
        }
        assert!(view.contains_key(best_idx as u64));
        // Per-suggestion constraint probes bounded by pool mechanics, not
        // by the 10⁹ Cartesian size: each iteration draws ≤ pool
        // candidates (bounded rejection tries each) plus neighbor probes
        // of ≤3 incumbents. A generous static ceiling proves no sweep.
        let per_suggestion = view.probe_count() / budget as u64;
        assert!(
            per_suggestion < 200_000,
            "{strategy_name}: {per_suggestion} probes/suggestion looks like an enumeration"
        );
    }
}
