//! Integration: the XLA-compiled GP artifact (Layers 1+2, via PJRT) must
//! agree with the pure-Rust GP and drive the BO engine end-to-end.
//!
//! Requires `make artifacts`; tests skip (with a notice) when the artifact
//! directory is absent so `cargo test` works in a fresh checkout.

use std::sync::Arc;

use ktbo::bo::{Acq, Backend, BoConfig, BoStrategy};
use ktbo::gp::{CovFn, NativeSurrogate, Surrogate};
use ktbo::objective::{Eval, Objective, TableObjective};
use ktbo::runtime::{xla_backend, XlaContext, XlaSurrogate};
use ktbo::space::{Param, SearchSpace};
use ktbo::strategies::Strategy;
use ktbo::util::rng::Rng;

fn artifact_dir() -> Option<String> {
    let dir = std::env::var("KTBO_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    if std::path::Path::new(&dir).join("gp_fitpredict_n32_c4096.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts in {dir} — run `make artifacts`");
        None
    }
}

/// The artifact's lowering constants must match the Rust default config
/// (Matérn 3/2, lengthscale 1.5, noise 1e-6 — Table I CV defaults).
fn reference_cov() -> CovFn {
    CovFn::Matern32 { lengthscale: 1.5 }
}

#[test]
fn xla_surrogate_matches_native_gp() {
    let Some(dir) = artifact_dir() else { return };
    let ctx = XlaContext::load(&dir).expect("load artifacts");
    let mut xla = XlaSurrogate::new(ctx);
    let mut native = NativeSurrogate::new(reference_cov(), 1e-6);

    let mut rng = Rng::new(42);
    let dims = 6;
    let n = 23; // deliberately not a bucket size → exercises padding
    let x: Vec<f64> = (0..n * dims).map(|_| rng.f64()).collect();
    let y: Vec<f64> = (0..n).map(|_| rng.normal() * 2.0 + 7.0).collect();
    let m = 1000; // not a chunk multiple → exercises chunk tail
    let cand: Vec<f64> = (0..m * dims).map(|_| rng.f64()).collect();

    let (mut mu_x, mut var_x) = (vec![0.0; m], vec![0.0; m]);
    let (mut mu_n, mut var_n) = (vec![0.0; m], vec![0.0; m]);
    xla.fit_predict(&x, &y, dims, &cand, &mut mu_x, &mut var_x).expect("xla fit_predict");
    native.fit_predict(&x, &y, dims, &cand, &mut mu_n, &mut var_n).expect("native fit_predict");

    for j in 0..m {
        assert!(
            (mu_x[j] - mu_n[j]).abs() < 1e-3,
            "mu mismatch at {j}: xla {} vs native {}",
            mu_x[j],
            mu_n[j]
        );
        assert!(
            (var_x[j] - var_n[j]).abs() < 1e-3,
            "var mismatch at {j}: xla {} vs native {}",
            var_x[j],
            var_n[j]
        );
    }
}

#[test]
fn xla_backend_drives_bo_to_optimum() {
    let Some(dir) = artifact_dir() else { return };
    // A smooth bowl over a 25×25 grid: BO through the PJRT artifact must
    // find the global minimum just like the native backend.
    let vals: Vec<i64> = (0..25).collect();
    let space = SearchSpace::build("bowl", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
    let table: Vec<Eval> = (0..space.len())
        .map(|i| {
            let p = space.point(i);
            let (x, y) = (f64::from(p[0]), f64::from(p[1]));
            Eval::Valid(10.0 + 100.0 * ((x - 0.6).powi(2) + (y - 0.4).powi(2)))
        })
        .collect();
    let obj = TableObjective::new(space, table);

    let backend = xla_backend(&dir).expect("backend");
    let mut cfg = BoConfig::single(Acq::Ei);
    // The artifact bakes the CV-default covariance; keep configs aligned.
    cfg.cov = reference_cov();
    let strat = BoStrategy::with_backend("bo-xla", cfg, backend);
    let mut rng = Rng::new(3);
    let trace = strat.run(&obj, 60, &mut rng);
    let best = trace.best().expect("found something").1;
    let global = obj.known_minimum().unwrap();
    assert!(best < global * 1.05, "xla-backed BO best {best} vs global {global}");
}

#[test]
fn xla_and_native_backends_agree_on_trajectory() {
    let Some(dir) = artifact_dir() else { return };
    let vals: Vec<i64> = (0..20).collect();
    let space = SearchSpace::build("bowl2", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
    let table: Vec<Eval> = (0..space.len())
        .map(|i| {
            let p = space.point(i);
            let (x, y) = (f64::from(p[0]), f64::from(p[1]));
            Eval::Valid(1.0 + (x - 0.2).powi(2) + (y - 0.8).powi(2))
        })
        .collect();
    let obj = TableObjective::new(space, table);

    let mut cfg = BoConfig::single(Acq::Ei);
    cfg.cov = reference_cov();

    let native = BoStrategy::with_backend(
        "bo-native",
        cfg.clone(),
        Backend::OneShot(Arc::new(|c: &BoConfig| {
            Box::new(NativeSurrogate::new(c.cov, c.noise)) as Box<dyn Surrogate>
        })),
    );
    let xla = BoStrategy::with_backend("bo-xla", cfg, xla_backend(&dir).expect("backend"));

    let mut r1 = Rng::new(11);
    let mut r2 = Rng::new(11);
    let t_native = native.run(&obj, 40, &mut r1);
    let t_xla = xla.run(&obj, 40, &mut r2);
    // f32 vs f64 may reorder near-tie acquisition argmins late in the run;
    // the early trajectory and the outcome must agree.
    let a: Vec<usize> = t_native.records.iter().map(|(i, _)| *i).take(25).collect();
    let b: Vec<usize> = t_xla.records.iter().map(|(i, _)| *i).take(25).collect();
    assert_eq!(a, b, "early trajectories diverged");
    let (bn, bx) = (t_native.best().unwrap().1, t_xla.best().unwrap().1);
    assert!((bn - bx).abs() < 0.05, "outcomes differ: native {bn} xla {bx}");
}
