//! End-to-end suite for the `ktbo serve` daemon: wire-protocol behavior,
//! bit-identity of served sessions against offline `drive()`, the
//! N-thousand interleaved simulated-client stress test, and the
//! kill-and-restart persistence path (checkpoints + the bounded,
//! journal-backed EvalCache).

use std::sync::{Arc, Mutex};

use ktbo::gpusim::device::Device;
use ktbo::harness::figures::objective_for;
use ktbo::objective::evalcache::CACHE_SCHEMA_VERSION;
use ktbo::objective::Objective;
use ktbo::serve::checkpoint::{trace_from_json, SessionCheckpoint};
use ktbo::serve::{ServeOpts, SessionConfig, TuningServer};
use ktbo::strategies::registry::{all_names, by_name};
use ktbo::strategies::{drive, FevalBudget, Trace};
use ktbo::util::json::Json;
use ktbo::util::jsonparse;
use ktbo::util::pool::ShardPool;
use ktbo::util::rng::Rng;

fn resp(server: &TuningServer, line: &str) -> Json {
    jsonparse::parse(&server.handle_line(line)).expect("responses are valid JSON")
}

fn is_ok(j: &Json) -> bool {
    j.get("ok") == Some(&Json::Bool(true))
}

fn config_json(strategy: &str, budget: usize, seed: u64) -> String {
    format!(
        r#"{{"kernel":"adding","gpu":"a100","strategy":"{strategy}","budget":{budget},"seed":"0x{seed:x}"}}"#
    )
}

/// Drive one served session to completion against the shared `adding`
/// table, telling table values back, and return its final trace (read
/// from a checkpoint so the comparison covers the wire encoding too).
fn run_served(
    server: &TuningServer,
    name: &str,
    strategy: &str,
    budget: usize,
    seed: u64,
    obj: &dyn Objective,
) -> Trace {
    let create = format!(
        r#"{{"cmd":"create","session":"{name}","config":{}}}"#,
        config_json(strategy, budget, seed)
    );
    let r = resp(server, &create);
    assert!(is_ok(&r), "create failed: {r:?}");
    let ask = format!(r#"{{"cmd":"ask","session":"{name}"}}"#);
    let mut rng = Rng::new(999); // table objectives ignore the eval rng
    loop {
        let a = resp(server, &ask);
        assert!(is_ok(&a), "ask failed: {a:?}");
        match a.get("status").and_then(Json::as_str) {
            Some("eval") => {
                let idx = a.get("config_index").and_then(Json::as_f64).unwrap() as usize;
                let tell = match obj.evaluate(idx, &mut rng).value() {
                    Some(t) => format!(
                        r#"{{"cmd":"tell","session":"{name}","config_index":{idx},"time":{t}}}"#
                    ),
                    None => {
                        let label = obj.evaluate(idx, &mut rng).invalid_label().unwrap();
                        format!(
                            r#"{{"cmd":"tell","session":"{name}","config_index":{idx},"invalid":"{label}"}}"#
                        )
                    }
                };
                let t = resp(server, &tell);
                assert!(is_ok(&t), "tell failed: {t:?}");
            }
            Some("done") => break,
            other => panic!("unexpected ask status {other:?}"),
        }
    }
    let ck = resp(server, &format!(r#"{{"cmd":"checkpoint","session":"{name}"}}"#));
    assert!(is_ok(&ck), "checkpoint failed: {ck:?}");
    let trace = trace_from_json(ck.get("checkpoint").unwrap().get("trace").unwrap()).unwrap();
    let close = resp(server, &format!(r#"{{"cmd":"close","session":"{name}"}}"#));
    assert!(is_ok(&close), "close failed: {close:?}");
    trace
}

fn offline_trace(strategy: &str, budget: usize, seed: u64, obj: &dyn Objective) -> Trace {
    let mut driver = by_name(strategy).unwrap().driver(obj.space());
    let mut rng = Rng::new(seed);
    drive(driver.as_mut(), obj, &FevalBudget::new(budget), &mut rng)
}

/// Acceptance: every registry strategy, served over the protocol with
/// client-side evaluation, reproduces its offline `drive()` trace bit
/// for bit — through one shared server whose cross-session cache is
/// warm with other strategies' measurements.
#[test]
fn served_sessions_are_bit_identical_to_offline_drive_for_every_strategy() {
    let obj = objective_for("adding", &Device::a100());
    let server = TuningServer::new(ServeOpts::default()).unwrap();
    for (i, strategy) in all_names().iter().enumerate() {
        let (budget, seed) = (18usize, 40 + i as u64);
        let served =
            run_served(&server, &format!("s-{strategy}"), strategy, budget, seed, obj.as_ref());
        let offline = offline_trace(strategy, budget, seed, obj.as_ref());
        assert_eq!(
            served.records, offline.records,
            "{strategy}: served trace diverged from offline drive()"
        );
    }
}

/// Acceptance: thousands of interleaved simulated clients on the
/// orchestrator's ShardPool, against one server with a deliberately
/// small LRU cap (evictions while sessions are live), each bit-identical
/// to its offline run.
#[test]
fn thousands_of_interleaved_sessions_match_offline_traces() {
    const SESSIONS: usize = 2000;
    let obj = objective_for("adding", &Device::a100());
    let server = TuningServer::new(ServeOpts {
        cache_capacity: Some(256), // force evictions mid-run
        ..ServeOpts::default()
    })
    .unwrap();
    let strategies = ["random", "mls", "simulated_annealing", "ils"];
    let failures: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let pool = ShardPool::new(4);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..SESSIONS)
        .map(|i| {
            let (server, obj, failures) = (&server, &obj, &failures);
            Box::new(move || {
                let strategy = strategies[i % strategies.len()];
                let (budget, seed) = (10usize, 5000 + i as u64);
                let served = run_served(
                    server,
                    &format!("stress-{i}"),
                    strategy,
                    budget,
                    seed,
                    obj.as_ref(),
                );
                let offline = offline_trace(strategy, budget, seed, obj.as_ref());
                if served.records != offline.records {
                    failures.lock().unwrap().push(format!("session {i} ({strategy}) diverged"));
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.run(jobs);
    let failures = failures.into_inner().unwrap();
    assert!(failures.is_empty(), "{} of {SESSIONS} diverged: {:?}", failures.len(), &failures[..failures.len().min(5)]);
    let stats = server.cache().stats();
    assert!(stats.evictions > 0, "cap 256 under {SESSIONS} sessions must evict");
    assert!(server.cache().len() <= 256, "cache exceeded its LRU cap");
}

/// Acceptance: kill the server mid-run, restart over the same cache file
/// and checkpoint dir — sessions resume from their checkpoints, finish
/// bit-identically to uninterrupted offline runs, and the persistent
/// cache survives within its bound.
#[test]
fn restarted_server_resumes_checkpointed_sessions_and_cache_survives() {
    let dir = std::env::temp_dir().join("ktbo-serve-restart");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let opts = ServeOpts {
        cache_path: Some(dir.join("cache.jsonl")),
        cache_capacity: Some(128),
        checkpoint_dir: Some(dir.join("ckpt")),
    };
    let obj = objective_for("adding", &Device::a100());
    let sessions: &[(&str, &str, u64)] =
        &[("r1", "random", 71), ("r2", "mls", 72), ("r3", "ei", 73)];
    let budget = 14usize;

    // Phase 1: run each session partway, checkpoint, then drop the
    // server without closing anything (the crash). The shared cache can
    // satisfy some suggestions without a client ask (fetch_store costs
    // budget and records to the trace), so remember each checkpoint's
    // actual trace length rather than assuming tells == records.
    let mut checkpointed_len = std::collections::HashMap::new();
    {
        let server = TuningServer::new(opts.clone()).unwrap();
        let mut rng = Rng::new(999);
        for (name, strategy, seed) in sessions {
            let create = format!(
                r#"{{"cmd":"create","session":"{name}","config":{}}}"#,
                config_json(strategy, budget, *seed)
            );
            assert!(is_ok(&resp(&server, &create)));
            for _ in 0..5 {
                let a = resp(&server, &format!(r#"{{"cmd":"ask","session":"{name}"}}"#));
                if a.get("status").and_then(Json::as_str) != Some("eval") {
                    break; // cache hits drained the budget early
                }
                let idx = a.get("config_index").and_then(Json::as_f64).unwrap() as usize;
                let t = obj.evaluate(idx, &mut rng);
                let tell = match t.value() {
                    Some(v) => format!(
                        r#"{{"cmd":"tell","session":"{name}","config_index":{idx},"time":{v}}}"#
                    ),
                    None => format!(
                        r#"{{"cmd":"tell","session":"{name}","config_index":{idx},"invalid":"{}"}}"#,
                        t.invalid_label().unwrap()
                    ),
                };
                assert!(is_ok(&resp(&server, &tell)));
            }
            let ck = resp(&server, &format!(r#"{{"cmd":"checkpoint","session":"{name}"}}"#));
            assert!(is_ok(&ck));
            let trace =
                trace_from_json(ck.get("checkpoint").unwrap().get("trace").unwrap()).unwrap();
            assert!(!trace.records.is_empty(), "{name}: nothing recorded before the crash");
            checkpointed_len.insert(*name, trace.len());
        }
    }
    let journal = std::fs::read_to_string(dir.join("cache.jsonl")).unwrap();
    assert!(
        journal.starts_with(r#"{"type":"meta""#),
        "journal must start with a versioned meta line"
    );
    assert!(journal.contains(&format!(r#""schema_version":{CACHE_SCHEMA_VERSION}"#)));

    // Phase 2: a fresh server over the same state.
    let server = TuningServer::new(opts).unwrap();
    assert!(!server.cache().is_empty(), "persistent cache must reload from its journal");
    assert!(server.cache().len() <= 128, "reloaded cache exceeded its cap");
    for (name, strategy, seed) in sessions {
        // Server-side checkpoint file, no inline document.
        let r = resp(&server, &format!(r#"{{"cmd":"resume","session":"{name}"}}"#));
        assert!(is_ok(&r), "resume failed: {r:?}");
        assert_eq!(
            r.get("resumed_evaluations").and_then(Json::as_f64),
            Some(checkpointed_len[name] as f64),
            "{name}: resume must replay exactly the checkpointed trace"
        );
        // Finish the run.
        let ask = format!(r#"{{"cmd":"ask","session":"{name}"}}"#);
        let mut rng = Rng::new(999);
        loop {
            let a = resp(&server, &ask);
            match a.get("status").and_then(Json::as_str) {
                Some("eval") => {
                    let idx = a.get("config_index").and_then(Json::as_f64).unwrap() as usize;
                    let t = obj.evaluate(idx, &mut rng);
                    let tell = match t.value() {
                        Some(v) => format!(
                            r#"{{"cmd":"tell","session":"{name}","config_index":{idx},"time":{v}}}"#
                        ),
                        None => format!(
                            r#"{{"cmd":"tell","session":"{name}","config_index":{idx},"invalid":"{}"}}"#,
                            t.invalid_label().unwrap()
                        ),
                    };
                    assert!(is_ok(&resp(&server, &tell)));
                }
                Some("done") => break,
                other => panic!("unexpected status {other:?}"),
            }
        }
        let ck = resp(&server, &format!(r#"{{"cmd":"checkpoint","session":"{name}"}}"#));
        let trace =
            trace_from_json(ck.get("checkpoint").unwrap().get("trace").unwrap()).unwrap();
        let offline = offline_trace(strategy, budget, *seed, obj.as_ref());
        assert_eq!(
            trace.records, offline.records,
            "{name} ({strategy}): resumed run diverged from offline"
        );
    }
    assert!(server.cache().len() <= 128, "cache exceeded its cap after the resumed runs");
}

/// Satellite: a client that disconnects mid-`ask` (suggestion parked,
/// never told) loses nothing — over real TCP, a second connection asks
/// again, receives the same suggestion, and the finished run matches the
/// offline trace. Double-`tell` on one suggestion is rejected on the
/// wire, not re-recorded.
#[test]
fn tcp_mid_ask_disconnect_and_double_tell() {
    use ktbo::serve::client::{LineTransport, TcpLine};
    let Ok(listener) = std::net::TcpListener::bind("127.0.0.1:0") else {
        return; // sandboxed environment without loopback
    };
    let addr = listener.local_addr().unwrap().to_string();
    let server = Arc::new(TuningServer::new(ServeOpts::default()).unwrap());
    let accept = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || server.serve_tcp(listener))
    };
    let obj = objective_for("adding", &Device::a100());
    let (strategy, budget, seed) = ("mls", 12usize, 31u64);

    // Connection 1: create, ask, vanish without telling.
    let first_idx = {
        let mut c1 = TcpLine::connect(&addr).unwrap();
        let create = format!(
            r#"{{"cmd":"create","session":"tcp1","config":{}}}"#,
            config_json(strategy, budget, seed)
        );
        let r = jsonparse::parse(&c1.round_trip(&create).unwrap()).unwrap();
        assert!(is_ok(&r), "{r:?}");
        let a = jsonparse::parse(&c1.round_trip(r#"{"cmd":"ask","session":"tcp1"}"#).unwrap())
            .unwrap();
        a.get("config_index").and_then(Json::as_f64).unwrap() as usize
        // c1 drops here: mid-ask disconnect.
    };

    // Connection 2: the re-ask is idempotent, then finish the run.
    let mut c2 = TcpLine::connect(&addr).unwrap();
    let mut rng = Rng::new(999);
    let a = jsonparse::parse(&c2.round_trip(r#"{"cmd":"ask","session":"tcp1"}"#).unwrap()).unwrap();
    let idx = a.get("config_index").and_then(Json::as_f64).unwrap() as usize;
    assert_eq!(idx, first_idx, "reconnect must resurface the parked suggestion");
    let mut outstanding = Some(idx);
    while let Some(idx) = outstanding {
        let v = obj.evaluate(idx, &mut rng);
        let tell = match v.value() {
            Some(t) => {
                format!(r#"{{"cmd":"tell","session":"tcp1","config_index":{idx},"time":{t}}}"#)
            }
            None => format!(
                r#"{{"cmd":"tell","session":"tcp1","config_index":{idx},"invalid":"{}"}}"#,
                v.invalid_label().unwrap()
            ),
        };
        let t = jsonparse::parse(&c2.round_trip(&tell).unwrap()).unwrap();
        assert!(is_ok(&t), "{t:?}");
        // Double-tell: immediately repeating the same tell must fail and
        // must not grow the trace (verified against offline below).
        let dup = jsonparse::parse(&c2.round_trip(&tell).unwrap()).unwrap();
        assert!(!is_ok(&dup), "double tell was accepted: {dup:?}");
        let a =
            jsonparse::parse(&c2.round_trip(r#"{"cmd":"ask","session":"tcp1"}"#).unwrap()).unwrap();
        outstanding = match a.get("status").and_then(Json::as_str) {
            Some("eval") => Some(a.get("config_index").and_then(Json::as_f64).unwrap() as usize),
            _ => None,
        };
    }
    // A re-recorded double-tell or a suggestion lost to the disconnect
    // would both show up as a trace mismatch here.
    let ck = jsonparse::parse(&c2.round_trip(r#"{"cmd":"checkpoint","session":"tcp1"}"#).unwrap())
        .unwrap();
    assert!(is_ok(&ck), "{ck:?}");
    let trace = trace_from_json(ck.get("checkpoint").unwrap().get("trace").unwrap()).unwrap();
    let offline = offline_trace(strategy, budget, seed, obj.as_ref());
    assert_eq!(
        trace.records, offline.records,
        "served trace diverged despite disconnect + double-tell attempts"
    );

    let _ = c2.round_trip(r#"{"cmd":"shutdown"}"#);
    accept.join().unwrap().unwrap();
}

/// Satellite: the malformed-request soak. A deterministic corpus of
/// truncated, mangled, type-confused, and pathological request lines is
/// fired at a live server. Every single line must come back as one
/// parseable JSON response carrying an `ok` field — never a panic, never
/// silence — and a session created before the soak must afterwards
/// finish bit-identically to its offline run, proving garbage on the
/// wire can neither kill the daemon nor corrupt live session state.
#[test]
fn malformed_request_soak_never_kills_the_daemon() {
    let server = TuningServer::new(ServeOpts::default()).unwrap();
    let obj = objective_for("adding", &Device::a100());

    // A healthy session opened before the abuse starts.
    let create = format!(
        r#"{{"cmd":"create","session":"soak","config":{}}}"#,
        config_json("mls", 12, 77)
    );
    assert!(is_ok(&resp(&server, &create)));

    // Hand-picked pathological lines: wrong JSON types, unknown
    // commands/sessions/kernels/GPUs/strategies, missing and negative
    // fields, duplicate creates, control characters, non-JSON noise.
    let fixed: &[&str] = &[
        "",
        "   \t  ",
        "null",
        "42",
        "\"just a string\"",
        "[1,2,3]",
        "{}",
        r#"{"cmd":7}"#,
        r#"{"cmd":null}"#,
        r#"{"cmd":"no-such-cmd"}"#,
        r#"{"cmd":"ask"}"#,
        r#"{"cmd":"ask","session":42}"#,
        r#"{"cmd":"ask","session":"ghost"}"#,
        r#"{"cmd":"tell","session":"soak"}"#,
        r#"{"cmd":"tell","session":"soak","config_index":-3,"time":1.0}"#,
        r#"{"cmd":"tell","session":"soak","config_index":0,"time":"fast"}"#,
        r#"{"cmd":"tell","session":"soak","config_index":99999999,"time":0.5}"#,
        r#"{"cmd":"create","session":"soak","config":{"kernel":"adding","gpu":"a100","strategy":"random","budget":5,"seed":"0x7"}}"#,
        r#"{"cmd":"create","session":"../etc/passwd","config":{"kernel":"adding","gpu":"a100","strategy":"random","budget":5,"seed":"0x7"}}"#,
        r#"{"cmd":"create","session":"k1","config":{"kernel":"nope","gpu":"a100","strategy":"random","budget":5,"seed":"0x7"}}"#,
        r#"{"cmd":"create","session":"k2","config":{"kernel":"adding","gpu":"hal9000","strategy":"random","budget":5,"seed":"0x7"}}"#,
        r#"{"cmd":"create","session":"k3","config":{"kernel":"adding","gpu":"a100","strategy":"gradient_descent","budget":5,"seed":"0x7"}}"#,
        r#"{"cmd":"create","session":"k4","config":{"kernel":"adding","gpu":"a100","strategy":"random","budget":-5,"seed":"0x7"}}"#,
        r#"{"cmd":"create","session":"k5","config":"not an object"}"#,
        r#"{"cmd":"create","session":"k6"}"#,
        r#"{"cmd":"resume","session":"never-checkpointed"}"#,
        r#"{"cmd":"resume","session":"soak","checkpoint":{"type":"wrong"}}"#,
        r#"{"cmd":"checkpoint","session":"ghost"}"#,
        r#"{"cmd":"close","session":"ghost"}"#,
        "{\"cmd\":\"ask\",\"session\":\"soak\"\u{0}}",
        "{{{{{{{{",
        "\u{fffd}\u{fffd}\u{fffd}",
    ];
    let mut corpus: Vec<String> = fixed.iter().map(|s| s.to_string()).collect();

    // Every prefix truncation of real requests (simulates a connection
    // cut mid-line).
    let tell = r#"{"cmd":"tell","session":"soak","config_index":0,"time":0.5}"#;
    for base in [create.as_str(), r#"{"cmd":"ask","session":"soak"}"#, tell] {
        let chars: Vec<char> = base.chars().collect();
        for cut in 0..chars.len() {
            corpus.push(chars[..cut].iter().collect());
        }
    }

    // Seeded random mangles of a valid request: same corpus every run.
    // (Tells can't corrupt the session — with no outstanding ask they
    // are rejected; asks are idempotent until told.)
    let mut rng = Rng::with_stream(2026, 0x5041_11fe);
    let palette: Vec<char> = "{}[]\":,x0\\".chars().collect();
    for _ in 0..500 {
        let mut chars: Vec<char> = tell.chars().collect();
        for _ in 0..1 + rng.below(3) {
            let pos = rng.below(chars.len());
            chars[pos] = palette[rng.below(palette.len())];
        }
        corpus.push(chars.iter().collect());
    }

    // Parser-stressing floods: bracket/braces nesting that would
    // overflow a recursive-descent parser without its depth cap, and a
    // very long flat line.
    corpus.push("[".repeat(200_000));
    corpus.push("{\"a\":".repeat(200_000));
    corpus.push(format!("{{\"cmd\":\"ask\",\"session\":\"{}\"}}", "s".repeat(1 << 20)));

    for (i, line) in corpus.iter().enumerate() {
        let raw = server.handle_line(line);
        let j = jsonparse::parse(&raw)
            .unwrap_or_else(|e| panic!("corpus[{i}]: response is not JSON ({e}): {raw}"));
        let ok = j.get("ok").and_then(|v| match v {
            Json::Bool(b) => Some(*b),
            _ => None,
        });
        assert!(ok.is_some(), "corpus[{i}]: response lacks a boolean 'ok': {raw}");
        if ok == Some(false) {
            assert!(
                j.get("error").and_then(Json::as_str).is_some_and(|e| !e.is_empty()),
                "corpus[{i}]: error reply without an 'error' message: {raw}"
            );
        }
    }

    // The daemon is not only alive — the pre-soak session still finishes
    // bit-identically to offline, so no garbage leaked into its state.
    let mut rng = Rng::new(999);
    let ask = r#"{"cmd":"ask","session":"soak"}"#;
    loop {
        let a = resp(&server, ask);
        assert!(is_ok(&a), "post-soak ask failed: {a:?}");
        match a.get("status").and_then(Json::as_str) {
            Some("eval") => {
                let idx = a.get("config_index").and_then(Json::as_f64).unwrap() as usize;
                let t = obj.evaluate(idx, &mut rng);
                let tell = match t.value() {
                    Some(v) => format!(
                        r#"{{"cmd":"tell","session":"soak","config_index":{idx},"time":{v}}}"#
                    ),
                    None => format!(
                        r#"{{"cmd":"tell","session":"soak","config_index":{idx},"invalid":"{}"}}"#,
                        t.invalid_label().unwrap()
                    ),
                };
                assert!(is_ok(&resp(&server, &tell)));
            }
            Some("done") => break,
            other => panic!("unexpected post-soak status {other:?}"),
        }
    }
    let ck = resp(&server, r#"{"cmd":"checkpoint","session":"soak"}"#);
    let trace = trace_from_json(ck.get("checkpoint").unwrap().get("trace").unwrap()).unwrap();
    let offline = offline_trace("mls", 12, 77, obj.as_ref());
    assert_eq!(
        trace.records, offline.records,
        "soak corrupted the live session: served trace diverged from offline"
    );
}

/// Satellite regression: the committed version-less checkpoint fixture
/// (written before `schema_version` existed) must keep loading, and a
/// future version must be refused.
#[test]
fn legacy_versionless_checkpoint_fixture_loads() {
    let text = include_str!("data/legacy_checkpoint.json");
    assert!(!text.contains("schema_version"), "fixture must stay version-less");
    let ckpt = SessionCheckpoint::parse(text).unwrap();
    assert_eq!(
        (ckpt.config.kernel.as_str(), ckpt.config.strategy.as_str(), ckpt.config.budget),
        ("adding", "random", 20)
    );
    assert_eq!(ckpt.config.seed, 42);
    assert_eq!(ckpt.trace.len(), 3);
    assert_eq!(ckpt.trace.records[0].0, 3);

    // The same document stamped with a future version is refused.
    let future = text.replacen(
        r#""type":"session_checkpoint""#,
        r#""type":"session_checkpoint","schema_version":99"#,
        1,
    );
    let err = SessionCheckpoint::parse(&future).unwrap_err();
    assert!(err.contains("schema_version 99"), "{err}");

    // And a resumed session accepts the legacy trace as its prefix.
    let cfg = SessionConfig::from_json(&jsonparse::parse(text).unwrap().get("config").unwrap().clone())
        .unwrap();
    assert_eq!(cfg.gpu, "A100");
}
