//! End-to-end benches regenerating the paper's tables and figures at
//! bench scale (custom harness; one section per Table/Figure family).
//!
//!   cargo bench --bench paper_experiments                (quick: scale 0.1)
//!   KTBO_BENCH_SCALE=1.0 cargo bench --bench paper_experiments  (full §IV-A)
//!
//! Output: the same rows/series the paper reports (best-found curves at
//! checkpoints, MDF bars, Table II/III stats, Fig 4 match counts), wall
//! times per experiment, CSVs under results/bench/.

use std::time::Instant;

use ktbo::harness::figures as figs;
use ktbo::harness::Options;

fn main() {
    let scale: f64 = std::env::var("KTBO_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let opts = Options {
        repeat_scale: scale,
        seed: 20210601,
        threads: ktbo::util::pool::default_threads(),
        out_dir: "results/bench".into(),
    };
    std::fs::create_dir_all(&opts.out_dir).expect("out dir");
    println!("== paper experiment benches (repeat scale {scale}) ==\n");

    let mut total = 0.0;
    let mut section = |name: &str, body: &dyn Fn() -> String| {
        let t0 = Instant::now();
        let report = body();
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!("{report}");
        println!("--- {name}: {dt:.1}s ---\n");
    };

    section("Table I", &figs::table1);
    section("Table II", &figs::table2);
    section("Table III", &figs::table3);
    section("Fig 1 (Titan X)", &|| figs::fig1(&opts));
    section("Fig 2 (2070 Super)", &|| figs::fig2(&opts));
    section("Fig 3 (A100)", &|| figs::fig3(&opts));
    section("Fig 4 (match EI@220)", &|| figs::fig4(&opts));
    section("Fig 5 (frameworks)", &|| figs::fig5(&opts));
    section("Fig 6 (ExpDist)", &|| figs::fig6(&opts));
    section("Fig 7 (Adding)", &|| figs::fig7(&opts));
    section("§IV-F headline", &|| figs::headline(&opts));

    println!("== total bench wall time: {total:.1}s ==");
}
