//! Scale bench for implicit (lazy) spaces (custom harness — no criterion
//! in the offline vendor set).
//!
//! Runs the lazy tuning path — `LazyView` oracle, pool drivers, the
//! synthetic objective — over a family of spaces whose Cartesian size
//! grows from 512 to 5.12·10⁸ (unconstrained filler dimensions), and
//! asserts per-suggestion constraint work stays bounded by the
//! candidate-pool knob: flat in Cartesian size. Results are written to
//! `BENCH_space_scale.json` at the repo root (see EXPERIMENTS.md
//! §Space scale).
//!
//! Run: `cargo bench --bench space_scale` (or `scripts/bench.sh`).
//! Flags: `--smoke` (two sizes, seconds-scale), `--out PATH`.
//!
//! The timing/assertion logic lives in
//! `ktbo::harness::space_scale_bench`, which the test suite also
//! exercises — this binary cannot silently rot.

use ktbo::harness::space_scale_bench::{flatness_violation, run_scenario, scenario_grid, to_json};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    // Smoke runs must never clobber the tracked full-grid trajectory file.
    let default_name =
        if smoke { "BENCH_space_scale.smoke.json" } else { "BENCH_space_scale.json" };
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| format!("{}/../{default_name}", env!("CARGO_MANIFEST_DIR")));

    println!("== space_scale: lazy-view per-suggestion work vs Cartesian size ==");
    println!(
        "{:<10} {:>14} {:>6} {:>8} {:>8} {:>20} {:>18}",
        "strategy", "cartesian", "dims", "budget", "pool", "probes/suggestion", "us/suggestion"
    );
    let mut records = Vec::new();
    for sc in scenario_grid(smoke) {
        let r = run_scenario(&sc);
        println!(
            "{:<10} {:>14} {:>6} {:>8} {:>8} {:>20.1} {:>18.1}",
            r.scenario.strategy,
            r.cartesian,
            r.dims,
            r.scenario.budget,
            r.scenario.pool,
            r.probes_per_suggestion,
            r.us_per_suggestion
        );
        records.push(r);
    }

    if let Some(violation) = flatness_violation(&records) {
        eprintln!("FLATNESS VIOLATION: {violation}");
        std::process::exit(1);
    }
    println!("flatness: per-suggestion probe work bounded by the pool/dims cap at every size");

    let doc = to_json(&records).render_pretty();
    std::fs::write(&out, &doc).expect("write bench json");
    println!("wrote {out}");
}
