//! Micro-benchmarks of the optimizer's hot path (custom harness — no
//! criterion in the offline vendor set).
//!
//! Primary section: simulated BO loops over the sharded flat-tile GP with
//! fused acquisition scoring — the GEMM restricted space (17956
//! candidates) and a 200k-candidate space, at n ∈ {50, 120, 220} ×
//! threads ∈ {1, 4, 8}, against the seed-style serial baseline. Results
//! are written to `BENCH_gp_hotpath.json` at the repo root so the perf
//! trajectory is tracked across PRs (see EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench gp_hotpath` (or `scripts/bench.sh`).
//! Flags: `--smoke` (tiny grid), `--out PATH` (JSON destination).
//!
//! The loop logic lives in `ktbo::harness::gp_bench`, which the test
//! suite also exercises — this binary cannot silently rot.

use std::time::Instant;

use ktbo::gp::{CovFn, Gpr, NativeSurrogate, Surrogate};
use ktbo::harness::gp_bench::{run_scenario, scenario_grid, to_json};
use ktbo::util::rng::Rng;

const DIMS: usize = 15; // GEMM dimensionality
const M_CAND: usize = 17956; // GEMM restricted-space size

fn timeit<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warm-up.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<58} {:>10.3} ms/iter", per * 1e3);
    per
}

/// Reference one-shot backends over the GEMM-sized space — what
/// scikit-learn/Kernel Tuner pay per iteration, for context.
fn oneshot_reference_section() {
    let mut rng = Rng::new(1);
    let cov = CovFn::Matern32 { lengthscale: 1.5 };
    let cand: Vec<f64> = (0..M_CAND * DIMS).map(|_| rng.f64()).collect();
    println!("\n== one-shot reference backends: {M_CAND} candidates × {DIMS} dims ==");
    for &n in &[50usize, 220] {
        let x: Vec<f64> = (0..n * DIMS).map(|_| rng.f64()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut mu = vec![0.0; M_CAND];
        let mut var = vec![0.0; M_CAND];
        let iters = if n > 150 { 2 } else { 4 };
        timeit(&format!("batch Gpr fit+predict_into        (n={n})"), iters, || {
            let gp = Gpr::fit(cov, 1e-6, &x, DIMS, &y).unwrap();
            gp.predict_into(&cand, &mut mu, &mut var);
        });
        let mut nat = NativeSurrogate::new(cov, 1e-6);
        timeit(&format!("NativeSurrogate::fit_predict      (n={n})"), iters, || {
            nat.fit_predict(&x, &y, DIMS, &cand, &mut mu, &mut var).unwrap();
        });
    }
}

/// XLA artifact backend, when compiled in and artifacts exist.
#[cfg(feature = "xla-runtime")]
fn xla_section() {
    let mut rng = Rng::new(2);
    let cand: Vec<f64> = (0..M_CAND * DIMS).map(|_| rng.f64()).collect();
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&dir).join("gp_fitpredict_n256_c4096.hlo.txt").exists() {
        println!("\n== XLA artifact backend (PJRT CPU) ==");
        let backend = ktbo::runtime::XlaContext::load(&dir).expect("artifacts");
        let mut xla = ktbo::runtime::XlaSurrogate::new(backend);
        for &n in &[50usize, 220] {
            let x: Vec<f64> = (0..n * DIMS).map(|_| rng.f64()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut mu = vec![0.0; M_CAND];
            let mut var = vec![0.0; M_CAND];
            timeit(&format!("XlaSurrogate::fit_predict         (n={n})"), 2, || {
                xla.fit_predict(&x, &y, DIMS, &cand, &mut mu, &mut var).unwrap();
            });
        }
    } else {
        println!("(skipping XLA backend bench — run `make artifacts`)");
    }
}

#[cfg(not(feature = "xla-runtime"))]
fn xla_section() {
    println!("(XLA backend bench requires --features xla-runtime)");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    // Smoke runs must never clobber the tracked full-grid trajectory file.
    let default_name = if smoke { "BENCH_gp_hotpath.smoke.json" } else { "BENCH_gp_hotpath.json" };
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| format!("{}/../{default_name}", env!("CARGO_MANIFEST_DIR")));

    println!("== gp_hotpath: sharded flat-tile GP, fused acquisition scoring ==");
    println!("{:<18} {:>5} {:>8} {:>8} {:>10} {:>12}", "variant", "n", "m", "threads", "shard_len", "ms/iter");
    let mut records = Vec::new();
    for sc in scenario_grid(smoke) {
        let r = run_scenario(&sc);
        println!(
            "{:<18} {:>5} {:>8} {:>8} {:>10} {:>12.3}",
            sc.variant(),
            sc.n,
            sc.m,
            sc.threads,
            sc.shard_len,
            r.ms_per_iter
        );
        records.push(r);
    }

    // Speedup summary: fused@8 threads vs serial baseline, per (n, m).
    for base in records.iter().filter(|r| !r.scenario.fused) {
        if let Some(fused) = records
            .iter()
            .filter(|r| r.scenario.fused && r.scenario.threads >= 8 && r.scenario.n == base.scenario.n && r.scenario.m == base.scenario.m)
            .last()
        {
            println!(
                "speedup n={:<4} m={:<7}: {:.2}x (baseline {:.3} → fused {:.3} ms/iter)",
                base.scenario.n,
                base.scenario.m,
                base.ms_per_iter / fused.ms_per_iter.max(1e-12),
                base.ms_per_iter,
                fused.ms_per_iter
            );
        }
    }

    let doc = to_json(&records).render_pretty();
    std::fs::write(&out, &doc).expect("write bench json");
    println!("wrote {out}");

    if !smoke {
        oneshot_reference_section();
        xla_section();
    }
}
