//! Micro-benchmarks of the optimizer's hot path (custom harness — no
//! criterion in the offline vendor set): the exhaustive GP posterior over
//! a GEMM-sized candidate set, across the three surrogate backends, plus
//! acquisition scoring and one full BO iteration loop.
//!
//! Run: `cargo bench --bench gp_hotpath` (results land in
//! EXPERIMENTS.md §Perf).

use std::time::Instant;

use ktbo::bo::acquisition::{argmin_score, score};
use ktbo::bo::Acq;
use ktbo::gp::{CovFn, Gpr, IncrementalGp, NativeSurrogate, Surrogate};
use ktbo::util::rng::Rng;

const DIMS: usize = 15; // GEMM dimensionality
const M_CAND: usize = 17956; // GEMM restricted-space size

fn timeit<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // Warm-up.
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<58} {:>10.3} ms/iter", per * 1e3);
    per
}

fn main() {
    let mut rng = Rng::new(1);
    let cov = CovFn::Matern32 { lengthscale: 1.5 };
    let cand: Vec<f64> = (0..M_CAND * DIMS).map(|_| rng.f64()).collect();
    println!("== GP hot path: {M_CAND} candidates × {DIMS} dims ==");

    for &n in &[50usize, 120, 220] {
        let x: Vec<f64> = (0..n * DIMS).map(|_| rng.f64()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut mu = vec![0.0; M_CAND];
        let mut var = vec![0.0; M_CAND];

        // Batch (one-shot refit) — what scikit-learn/Kernel Tuner do.
        let iters = if n > 150 { 2 } else { 4 };
        timeit(&format!("batch Gpr fit+predict_into        (n={n})"), iters, || {
            let gp = Gpr::fit(cov, 1e-6, &x, DIMS, &y).unwrap();
            gp.predict_into(&cand, &mut mu, &mut var);
        });

        // Incremental (our optimized path): a full simulated BO loop —
        // n sequential (add observation, predict everything) iterations —
        // reported per iteration. This is exactly the engine's workload.
        let t0 = Instant::now();
        let mut inc = IncrementalGp::new(cov, 1e-6, cand.clone(), DIMS);
        for i in 0..n {
            inc.add(&x[i * DIMS..(i + 1) * DIMS]);
            inc.predict_into(&y[..i + 1], &mut mu, &mut var);
        }
        let per = t0.elapsed().as_secs_f64() / n as f64;
        println!(
            "{:<58} {:>10.3} ms/iter",
            format!("incremental add+predict, amortized (n={n})"),
            per * 1e3
        );

        // NativeSurrogate through the Surrogate trait (same as batch, with
        // the trait-object overhead the XLA backend also pays).
        let mut nat = NativeSurrogate::new(cov, 1e-6);
        timeit(&format!("NativeSurrogate::fit_predict      (n={n})"), iters, || {
            nat.fit_predict(&x, &y, DIMS, &cand, &mut mu, &mut var).unwrap();
        });

        // Acquisition scoring over the full candidate set.
        let masked = vec![false; M_CAND];
        timeit(&format!("EI argmin over candidates         (n={n})"), 20, || {
            let _ = argmin_score(Acq::Ei, &mu, &var, 0.0, 0.01, &masked);
        });
    }

    // XLA artifact backend, when available.
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&dir).join("gp_fitpredict_n256_c4096.hlo.txt").exists() {
        println!("== XLA artifact backend (PJRT CPU) ==");
        let backend = ktbo::runtime::XlaContext::load(&dir).expect("artifacts");
        let mut xla = ktbo::runtime::XlaSurrogate::new(backend);
        for &n in &[50usize, 220] {
            let x: Vec<f64> = (0..n * DIMS).map(|_| rng.f64()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut mu = vec![0.0; M_CAND];
            let mut var = vec![0.0; M_CAND];
            timeit(&format!("XlaSurrogate::fit_predict         (n={n})"), 2, || {
                xla.fit_predict(&x, &y, DIMS, &cand, &mut mu, &mut var).unwrap();
            });
        }
    } else {
        println!("(skipping XLA backend bench — run `make artifacts`)");
    }

    // Scalar acquisition-function throughput.
    let t = timeit("acquisition score() x 1e6", 5, || {
        let mut acc = 0.0;
        for i in 0..1_000_000 {
            acc += score(Acq::Ei, (i % 97) as f64 * 0.01, 0.5, 0.3, 0.01);
        }
        std::hint::black_box(acc);
    });
    println!("  = {:.1} ns per score", t * 1e3);
}

