//! Micro-benchmark of restricted-space enumeration (custom harness — no
//! criterion in the offline vendor set).
//!
//! Scenarios: the GEMM space (82944-point Cartesian → ~18k restricted via
//! the CLBlast divisibility DSL) and a ~200k synthetic grid, each built
//! serially and shard-parallel at 2/4/8 threads through the declarative
//! `SpaceSpec` path. Results are written to `BENCH_space_build.json` at
//! the repo root so the perf trajectory is tracked across PRs (see
//! EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench space_build` (or `scripts/bench.sh`).
//! Flags: `--smoke` (tiny grid), `--out PATH` (JSON destination).
//!
//! The build logic lives in `ktbo::harness::space_bench`, which the test
//! suite also exercises — this binary cannot silently rot.

use ktbo::harness::space_bench::{run_scenario, scenario_grid, to_json};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    // Smoke runs must never clobber the tracked full-grid trajectory file.
    let default_name = if smoke { "BENCH_space_build.smoke.json" } else { "BENCH_space_build.json" };
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| format!("{}/../{default_name}", env!("CARGO_MANIFEST_DIR")));

    println!("== space_build: constraint-propagating columnar enumeration (SpaceSpec) ==");
    println!(
        "{:<16} {:>8} {:>10} {:>10} {:>14} {:>18}",
        "space", "threads", "configs", "cartesian", "ms/build", "keys_digest"
    );
    let mut records = Vec::new();
    for sc in scenario_grid(smoke) {
        let r = run_scenario(&sc);
        println!(
            "{:<16} {:>8} {:>10} {:>10} {:>14.3} {:>18}",
            sc.space,
            sc.threads,
            r.configs,
            r.cartesian,
            r.ms_per_build,
            format!("{:016x}", r.keys_digest)
        );
        records.push(r);
    }

    // Speedup summary per space: best parallel vs the serial baseline.
    for base in records.iter().filter(|r| r.scenario.threads <= 1) {
        if let Some(best) = records
            .iter()
            .filter(|r| r.scenario.space == base.scenario.space && r.scenario.threads > 1)
            .min_by(|a, b| a.ms_per_build.partial_cmp(&b.ms_per_build).unwrap())
        {
            assert_eq!(
                base.keys_digest, best.keys_digest,
                "parallel build must enumerate the identical space"
            );
            println!(
                "speedup {:<14}: {:.2}x (serial {:.3} -> {} threads {:.3} ms/build)",
                base.scenario.space,
                base.ms_per_build / best.ms_per_build.max(1e-12),
                base.ms_per_build,
                best.scenario.threads,
                best.ms_per_build
            );
        }
    }

    let doc = to_json(&records).render_pretty();
    std::fs::write(&out, &doc).expect("write bench json");
    println!("wrote {out}");
}
