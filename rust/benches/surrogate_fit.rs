//! Micro-benchmark of the surrogate subsystem: full fit from the run's
//! observations + one sharded (mu, var) sweep over every candidate — the
//! per-iteration workload each [`ktbo::surrogate::Model`] adds to a BO
//! run (custom harness — no criterion in the offline vendor set).
//!
//! Scenarios: the GEMM restricted space (~18k candidates) and the ~200k
//! synthetic grid, at 50 and 220 observations, for the GP adapter, random
//! forest, extra trees, and TPE, serial and 8-thread. Results are written
//! to `BENCH_surrogate_fit.json` at the repo root so the perf trajectory
//! is tracked across PRs (see EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench surrogate_fit` (or `scripts/bench.sh`).
//! Flags: `--smoke` (tiny grid), `--out PATH` (JSON destination).
//!
//! The fit/predict logic lives in `ktbo::harness::surrogate_bench`, which
//! the test suite also exercises — this binary cannot silently rot.

use ktbo::harness::surrogate_bench::{run_scenario, scenario_grid, to_json};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    // Smoke runs must never clobber the tracked full-grid trajectory file.
    let default_name =
        if smoke { "BENCH_surrogate_fit.smoke.json" } else { "BENCH_surrogate_fit.json" };
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| format!("{}/../{default_name}", env!("CARGO_MANIFEST_DIR")));

    println!("== surrogate_fit: per-iteration fit + sharded (mu, var) sweep per Model ==");
    println!(
        "{:<16} {:>6} {:>7} {:>8} {:>10} {:>12} {:>12} {:>18}",
        "space", "model", "n_obs", "threads", "configs", "ms_fit", "ms_predict", "mu_digest"
    );
    let mut records = Vec::new();
    for sc in scenario_grid(smoke) {
        let r = run_scenario(&sc);
        println!(
            "{:<16} {:>6} {:>7} {:>8} {:>10} {:>12.3} {:>12.3} {:>18}",
            sc.space,
            sc.model,
            sc.n_obs,
            sc.threads,
            r.configs,
            r.ms_fit,
            r.ms_predict,
            format!("{:016x}", r.mu_digest)
        );
        records.push(r);
    }

    // Cross-check: within one (space, model, n_obs), every thread count
    // must predict identical mean bits — the subsystem's determinism
    // contract, asserted on the full grid too, not just the unit tests.
    for r in &records {
        if let Some(other) = records.iter().find(|o| {
            o.scenario.space == r.scenario.space
                && o.scenario.model == r.scenario.model
                && o.scenario.n_obs == r.scenario.n_obs
                && o.scenario.threads != r.scenario.threads
        }) {
            assert_eq!(
                r.mu_digest, other.mu_digest,
                "{}/{} prediction bits depend on the thread count",
                r.scenario.space, r.scenario.model
            );
        }
    }

    let doc = to_json(&records).render_pretty();
    std::fs::write(&out, &doc).expect("write bench json");
    println!("wrote {out}");
}
