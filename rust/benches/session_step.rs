//! Micro-benchmark of per-suggestion session latency (custom harness —
//! no criterion in the offline vendor set).
//!
//! Scenarios: random, mls, and the stateful ei driver run to budget over
//! the cheapest table objective, (a) as an in-process `Session::step`
//! loop — the pure engine cost — and (b) through the serve daemon's
//! `ask`/`tell` JSON request path via `TuningServer::handle_line` — the
//! full per-suggestion daemon overhead without socket noise. Results are
//! written to `BENCH_session_step.json` at the repo root so the perf
//! trajectory is tracked across PRs (see EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench session_step` (or `scripts/bench.sh`).
//! Flags: `--smoke` (tiny grid), `--out PATH` (JSON destination).
//!
//! The timing logic lives in `ktbo::harness::session_bench`, which the
//! test suite also exercises — this binary cannot silently rot.

use ktbo::harness::session_bench::{run_scenario, scenario_grid, to_json};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    // Smoke runs must never clobber the tracked full-grid trajectory file.
    let default_name =
        if smoke { "BENCH_session_step.smoke.json" } else { "BENCH_session_step.json" };
    let out = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| format!("{}/../{default_name}", env!("CARGO_MANIFEST_DIR")));

    println!("== session_step: owned-Session per-evaluation latency, engine vs daemon ==");
    println!(
        "{:<12} {:<10} {:>8} {:>12} {:>14} {:>14}",
        "mode", "strategy", "budget", "evaluations", "ns/step", "steps/s"
    );
    let mut records = Vec::new();
    for sc in scenario_grid(smoke) {
        let r = run_scenario(&sc);
        println!(
            "{:<12} {:<10} {:>8} {:>12} {:>14.0} {:>14.0}",
            sc.mode, sc.strategy, sc.budget, r.evaluations, r.ns_per_step, r.steps_per_s
        );
        records.push(r);
    }

    // Overhead summary: served vs in-process per (strategy, budget).
    for base in records.iter().filter(|r| r.scenario.mode == "inprocess") {
        if let Some(served) = records.iter().find(|r| {
            r.scenario.mode == "served"
                && r.scenario.strategy == base.scenario.strategy
                && r.scenario.budget == base.scenario.budget
        }) {
            println!(
                "daemon overhead {:<10}: {:.2}x ({:.0} -> {:.0} ns/step)",
                base.scenario.strategy,
                served.ns_per_step / base.ns_per_step.max(1e-12),
                base.ns_per_step,
                served.ns_per_step
            );
        }
    }

    let doc = to_json(&records).render_pretty();
    std::fs::write(&out, &doc).expect("write bench json");
    println!("wrote {out}");
}
