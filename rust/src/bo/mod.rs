//! The paper's Bayesian-Optimization search strategy (§III): config and
//! Table I defaults, basic acquisition functions, initial sampling,
//! acquisition meta-policies (`multi`, `advanced multi`), and the engine.

pub mod acquisition;
pub mod config;
pub mod engine;
pub mod multi;
pub mod pool;
pub mod sampling;

pub use config::{Acq, AcqPolicyKind, BoConfig, Exploration, InitialSampling};
pub use engine::{Backend, BoStrategy};
pub use pool::{PoolBoDriver, DEFAULT_POOL_SIZE};
