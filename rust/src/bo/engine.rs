//! The Bayesian-Optimization search strategy (§III): the paper's core
//! contribution, assembled from the search-space representation, the GP
//! surrogate, the initial sampler, the exploration schedule, and the
//! acquisition policy.
//!
//! Design decisions from the paper implemented here:
//! - the acquisition function is optimized *exhaustively* over the
//!   discrete, normalized, non-evaluated configurations only (§III-D);
//! - invalid configurations are marked visited but *never* fitted to the
//!   surrogate — no artificial observation values (§III-D2);
//! - initial sampling is LHS/maximin with random replacement of invalid
//!   draws (§III-E);
//! - the exploration factor λ is the contextual variance
//!   λ = (σ̄² / (μ_s / f(x⁺))) / σ̄_s²  (§III-F);
//! - optional pruning drops candidates adjacent to ≥2 observed-invalid
//!   configurations — resource-limit invalidity is locally correlated on
//!   GPUs (our reading of Table I's "Pruning: yes").

use std::sync::Arc;

use crate::bo::config::{BoConfig, Exploration, InitialSampling};
use crate::bo::multi::{make_policy, AcqPolicy};
use crate::bo::sampling::{lhs_points, maximin_lhs_points, random_untaken, snap_to_configs};
use crate::gp::{IncrementalGp, Surrogate};
use crate::objective::{Eval, Objective};
use crate::space::{neighbors, Neighborhood};
use crate::strategies::{Strategy, Trace};
use crate::util::linalg::{mean, std_dev};
use crate::util::rng::Rng;

/// Surrogate backend selection.
#[derive(Clone)]
pub enum Backend {
    /// Incremental in-process GP (default, fastest).
    Incremental,
    /// One-shot fit+predict backend per iteration — the interface shape of
    /// the XLA artifact (`runtime::XlaSurrogate`) and the reference
    /// `NativeSurrogate`.
    OneShot(Arc<dyn Fn(&BoConfig) -> Box<dyn Surrogate> + Send + Sync>),
}

/// The BO strategy.
pub struct BoStrategy {
    pub config: BoConfig,
    pub backend: Backend,
    pub label: String,
}

impl BoStrategy {
    pub fn new(label: &str, config: BoConfig) -> BoStrategy {
        BoStrategy { config, backend: Backend::Incremental, label: label.to_string() }
    }

    pub fn with_backend(label: &str, config: BoConfig, backend: Backend) -> BoStrategy {
        BoStrategy { config, backend, label: label.to_string() }
    }
}

struct RunState<'a> {
    obj: &'a dyn Objective,
    rng: &'a mut Rng,
    trace: Trace,
    visited: Vec<bool>,
    obs_idx: Vec<usize>,
    obs_y: Vec<f64>,
    max_fevals: usize,
}

impl<'a> RunState<'a> {
    fn budget_left(&self) -> bool {
        self.trace.len() < self.max_fevals
    }

    /// Evaluate a configuration, consuming budget. Returns the raw valid
    /// value if any.
    fn evaluate(&mut self, idx: usize) -> Option<f64> {
        debug_assert!(!self.visited[idx], "re-evaluating config {idx}");
        let e = self.obj.evaluate(idx, self.rng);
        self.trace.push(idx, e);
        self.visited[idx] = true;
        if let Eval::Valid(v) = e {
            self.obs_idx.push(idx);
            self.obs_y.push(v);
            Some(v)
        } else {
            None
        }
    }

    fn f_best(&self) -> f64 {
        self.obs_y.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

impl Strategy for BoStrategy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn run(&self, obj: &dyn Objective, max_fevals: usize, rng: &mut Rng) -> Trace {
        let cfg = &self.config;
        let space = obj.space();
        let m = space.len();
        let dims = space.dims();

        let mut st = RunState {
            obj,
            rng,
            trace: Trace::new(),
            visited: vec![false; m],
            obs_idx: Vec::new(),
            obs_y: Vec::new(),
            max_fevals,
        };

        // ---- Initial sampling (§III-E) ----
        let init_n = cfg.init_samples.min(max_fevals).min(m);
        let pts = match cfg.init_sampling {
            InitialSampling::Lhs => Some(lhs_points(init_n, dims, st.rng)),
            InitialSampling::Maximin => Some(maximin_lhs_points(init_n, dims, 16, st.rng)),
            InitialSampling::Random => None,
        };
        let mut newly_invalid: Vec<usize> = Vec::new();
        if let Some(pts) = pts {
            let mut taken = st.visited.clone();
            let idxs = snap_to_configs(&pts, space, &mut taken);
            for idx in idxs {
                if !st.budget_left() {
                    break;
                }
                if st.evaluate(idx).is_none() {
                    newly_invalid.push(idx);
                }
            }
        }
        // Replace invalid/missing draws with random samples until the
        // initial sample is complete (or budget/space is exhausted).
        while st.obs_y.len() < init_n && st.budget_left() {
            let mut taken = st.visited.clone();
            match random_untaken(space, &mut taken, st.rng) {
                Some(idx) => {
                    if st.evaluate(idx).is_none() {
                        newly_invalid.push(idx);
                    }
                }
                None => break,
            }
        }
        if st.obs_y.is_empty() {
            return st.trace; // nothing valid found at all
        }
        let mu_s = mean(&st.obs_y); // initial-sample mean (raw units)

        // ---- Surrogate state ----
        let mut inc = IncrementalGp::new(cfg.cov, cfg.noise, space.points().to_vec(), dims);
        let mut fed = 0usize; // observations already fed to the GP
        let mut oneshot = match &self.backend {
            Backend::Incremental => None,
            Backend::OneShot(f) => Some(f(cfg)),
        };

        let mut policy: Box<dyn AcqPolicy> = make_policy(cfg);
        let mut mu = vec![0.0; m];
        let mut var = vec![0.0; m];
        let mut masked = vec![false; m];
        // Pruning state: count of observed-invalid adjacent neighbors.
        let mut invalid_adj = vec![0u8; m];
        let mut sigma_s2: Option<f64> = None;

        // ---- Optimization loop ----
        while st.budget_left() {
            // Register invalids observed since the last iteration with the
            // pruning model (never with the surrogate).
            if cfg.pruning {
                for idx in newly_invalid.drain(..) {
                    for nb in neighbors(space, idx, Neighborhood::Adjacent) {
                        invalid_adj[nb] = invalid_adj[nb].saturating_add(1);
                    }
                }
            } else {
                newly_invalid.clear();
            }

            // z-normalize observations so AF scores and λ are scale-free.
            let y_mean = mean(&st.obs_y);
            let y_std = {
                let s = std_dev(&st.obs_y);
                if s > 1e-12 {
                    s
                } else {
                    1.0
                }
            };
            let y_z: Vec<f64> = st.obs_y.iter().map(|v| (v - y_mean) / y_std).collect();

            // Posterior over the whole space.
            match &mut oneshot {
                None => {
                    while fed < st.obs_idx.len() {
                        inc.add(space.point(st.obs_idx[fed]));
                        fed += 1;
                    }
                    inc.predict_into(&y_z, &mut mu, &mut var);
                }
                Some(s) => {
                    // One-shot backend: fit on observations, predict over
                    // non-visited candidates, scatter back.
                    let x: Vec<f64> = st.obs_idx.iter().flat_map(|&i| space.point(i).to_vec()).collect();
                    let cand_idx: Vec<usize> = (0..m).filter(|&i| !st.visited[i]).collect();
                    let cand: Vec<f64> = cand_idx.iter().flat_map(|&i| space.point(i).to_vec()).collect();
                    let mut cmu = vec![0.0; cand_idx.len()];
                    let mut cvar = vec![0.0; cand_idx.len()];
                    if s.fit_predict(&x, &y_z, dims, &cand, &mut cmu, &mut cvar).is_err() {
                        break;
                    }
                    mu.fill(f64::INFINITY);
                    var.fill(1e-12);
                    for (p, &i) in cand_idx.iter().enumerate() {
                        mu[i] = cmu[p];
                        var[i] = cvar[p];
                    }
                }
            }

            // Candidate mask: evaluated configs are out (§III-D); pruned
            // configs (≥2 invalid adjacent neighbors) are out while other
            // candidates remain.
            for i in 0..m {
                masked[i] = st.visited[i] || (cfg.pruning && invalid_adj[i] >= 2);
            }
            if masked.iter().all(|&x| x) {
                // Pruning ate everything: relax it.
                for i in 0..m {
                    masked[i] = st.visited[i];
                }
            }

            // Mean posterior variance over the candidates (for λ).
            let (mut var_sum, mut n_cand) = (0.0, 0usize);
            for i in 0..m {
                if !masked[i] {
                    var_sum += var[i];
                    n_cand += 1;
                }
            }
            if n_cand == 0 {
                break; // space exhausted
            }
            let sigma_bar2 = var_sum / n_cand as f64;
            let s_s2 = *sigma_s2.get_or_insert(sigma_bar2);

            // Exploration factor (§III-F).
            let f_best = st.f_best();
            let lambda = match cfg.exploration {
                Exploration::Constant(l) => l,
                Exploration::ContextualVariance => {
                    // λ = (σ̄² / (μ_s / f(x⁺))) / σ̄_s², clamped to [0, ∞).
                    let improvement = (mu_s / f_best).max(1e-12);
                    ((sigma_bar2 / improvement) / s_s2.max(1e-12)).max(0.0)
                }
            };

            let f_best_z = (f_best - y_mean) / y_std;
            let pick = policy.choose(&mu, &var, f_best_z, lambda, &masked);
            let idx = match pick {
                Some(i) => i,
                None => {
                    let mut taken = st.visited.clone();
                    match random_untaken(space, &mut taken, st.rng) {
                        Some(i) => i,
                        None => break,
                    }
                }
            };
            let value = st.evaluate(idx);
            if value.is_none() {
                newly_invalid.push(idx);
            }
            policy.observe(value, &st.obs_y);
        }
        st.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::config::{Acq, AcqPolicyKind};
    use crate::objective::TableObjective;
    use crate::space::{Param, SearchSpace};

    /// A smooth 2D bowl over a 30×30 grid with a known minimum.
    fn bowl() -> TableObjective {
        let vals: Vec<i64> = (0..30).collect();
        let space = SearchSpace::build("bowl", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
        let table: Vec<Eval> = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                let (dx, dy) = (p[0] - 0.7, p[1] - 0.3);
                Eval::Valid(10.0 + 100.0 * (dx * dx + dy * dy))
            })
            .collect();
        TableObjective::new(space, table)
    }

    /// A bowl where a quadrant is invalid.
    fn bowl_with_invalid() -> TableObjective {
        let vals: Vec<i64> = (0..30).collect();
        let space = SearchSpace::build("bowl-inv", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
        let table: Vec<Eval> = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                if p[0] > 0.8 && p[1] > 0.8 {
                    Eval::CompileError
                } else {
                    let (dx, dy) = (p[0] - 0.7, p[1] - 0.3);
                    Eval::Valid(10.0 + 100.0 * (dx * dx + dy * dy))
                }
            })
            .collect();
        TableObjective::new(space, table)
    }

    fn run_bo(cfg: BoConfig, obj: &TableObjective, seed: u64, budget: usize) -> Trace {
        let s = BoStrategy::new("bo", cfg);
        let mut rng = Rng::new(seed);
        s.run(obj, budget, &mut rng)
    }

    #[test]
    fn finds_bowl_minimum_quickly() {
        let obj = bowl();
        let t = run_bo(BoConfig::single(Acq::Ei), &obj, 42, 60);
        let best = t.best().unwrap().1;
        let global = obj.known_minimum().unwrap();
        assert!(best < global * 1.05, "best {best} vs global {global}");
    }

    #[test]
    fn beats_budget_sized_random_on_average() {
        let obj = bowl();
        let mut bo_wins = 0;
        for seed in 0..5u64 {
            let t = run_bo(BoConfig::single(Acq::Ei), &obj, seed, 50);
            let bo_best = t.best().unwrap().1;
            // Random baseline: 50 uniform draws.
            let mut rng = Rng::new(seed ^ 0xbeef);
            let mut rnd_best = f64::INFINITY;
            for _ in 0..50 {
                let i = rng.below(obj.space().len());
                if let Some(v) = obj.table()[i].value() {
                    rnd_best = rnd_best.min(v);
                }
            }
            if bo_best <= rnd_best {
                bo_wins += 1;
            }
        }
        assert!(bo_wins >= 4, "BO won only {bo_wins}/5 against random");
    }

    #[test]
    fn never_reevaluates_and_respects_budget() {
        let obj = bowl();
        for kind in [AcqPolicyKind::Single(Acq::Lcb), AcqPolicyKind::Multi, AcqPolicyKind::AdvancedMulti] {
            let mut cfg = BoConfig::single(Acq::Ei);
            cfg.acq = kind;
            let t = run_bo(cfg, &obj, 7, 80);
            assert_eq!(t.len(), 80);
            let idxs: Vec<usize> = t.records.iter().map(|(i, _)| *i).collect();
            let set: std::collections::HashSet<_> = idxs.iter().collect();
            assert_eq!(set.len(), idxs.len(), "configuration re-evaluated under {kind:?}");
        }
    }

    #[test]
    fn handles_invalid_region() {
        let obj = bowl_with_invalid();
        let t = run_bo(BoConfig::advanced_multi(), &obj, 11, 70);
        let best = t.best().unwrap().1;
        let global = obj.known_minimum().unwrap();
        assert!(best < global * 1.1, "best {best} vs {global}");
    }

    #[test]
    fn exhausts_tiny_space_without_panic() {
        let space = SearchSpace::build("tiny", vec![Param::ints("a", &[1, 2, 3, 4, 5])], &[]);
        let table: Vec<Eval> = (0..5).map(|i| Eval::Valid(i as f64)).collect();
        let obj = TableObjective::new(space, table);
        let t = run_bo(BoConfig::single(Acq::Ei), &obj, 3, 100);
        assert_eq!(t.len(), 5, "must stop when the space is exhausted");
        assert_eq!(t.best().unwrap().1, 0.0);
    }

    #[test]
    fn all_invalid_space_terminates() {
        let space = SearchSpace::build("dead", vec![Param::ints("a", &[1, 2, 3])], &[]);
        let obj = TableObjective::new(space, vec![Eval::CompileError; 3]);
        let t = run_bo(BoConfig::single(Acq::Ei), &obj, 5, 50);
        assert!(t.len() <= 3);
        assert!(t.best().is_none());
    }

    #[test]
    fn oneshot_backend_agrees_with_incremental() {
        use crate::gp::NativeSurrogate;
        let obj = bowl();
        let cfg = BoConfig::single(Acq::Ei);
        let inc = run_bo(cfg.clone(), &obj, 9, 45);
        let one = BoStrategy::with_backend(
            "bo-oneshot",
            cfg,
            Backend::OneShot(Arc::new(|c: &BoConfig| {
                Box::new(NativeSurrogate::new(c.cov, c.noise)) as Box<dyn Surrogate>
            })),
        );
        let mut rng = Rng::new(9);
        let t2 = one.run(&obj, 45, &mut rng);
        // Same RNG seed + same math ⇒ identical evaluation sequence.
        let a: Vec<usize> = inc.records.iter().map(|(i, _)| *i).collect();
        let b: Vec<usize> = t2.records.iter().map(|(i, _)| *i).collect();
        assert_eq!(a, b, "one-shot backend must reproduce the incremental path");
    }

    #[test]
    fn contextual_variance_lambda_shrinks_over_time() {
        // Indirect check: CV must not explode — run and ensure convergence
        // behaviour (best at end much better than best after init).
        let obj = bowl();
        let t = run_bo(BoConfig::single(Acq::Poi), &obj, 21, 100);
        let curve = t.best_curve();
        assert!(curve[99] <= curve[20]);
    }
}
