//! The Bayesian-Optimization search strategy (§III): the paper's core
//! contribution, assembled from the search-space representation, the GP
//! surrogate, the initial sampler, the exploration schedule, and the
//! acquisition policy.
//!
//! Design decisions from the paper implemented here:
//! - the acquisition function is optimized *exhaustively* over the
//!   discrete, normalized, non-evaluated configurations only (§III-D);
//! - invalid configurations are marked visited but *never* fitted to the
//!   surrogate — no artificial observation values (§III-D2);
//! - initial sampling is LHS/maximin with random replacement of invalid
//!   draws (§III-E);
//! - the exploration factor λ is the contextual variance
//!   λ = (σ̄² / (μ_s / f(x⁺))) / σ̄_s²  (§III-F);
//! - optional pruning drops candidates adjacent to ≥2 observed-invalid
//!   configurations — resource-limit invalidity is locally correlated on
//!   GPUs (our reading of Table I's "Pruning: yes").
//!
//! Since the ask/tell redesign the strategy is a stepwise [`BoDriver`]:
//! `ask` runs the surrogate update and the fused acquisition sweep, and
//! `tell` registers the observation (visited mask, surrogate feed queue,
//! pruning model, policy bookkeeping). The generic drive loop owns
//! evaluation, budgeting, and the trace. With `BoConfig::batch_ask` set,
//! `ask` returns *every* distinct per-acquisition argmin the fused sweep
//! already computed — the per-step batch that parallel evaluation and the
//! step-level orchestrator consume.
//!
//! Since the surrogate subsystem ([`crate::surrogate`]), the GP is one of
//! several surrogates: [`Backend::Model`] plugs any batch
//! [`Model`](crate::surrogate::Model) (tree ensembles, TPE, the GP
//! adapter) into the same loop — refit per iteration, swept
//! shard-parallel over the space's tiles, composed with every acquisition
//! policy, pruning, and batch ask unchanged.
//!
//! Hot-path organization (the per-iteration O(m) work over the whole
//! candidate set): one long-lived [`ShardPool`] serves the entire run, and
//! each iteration makes exactly two sharded sweeps —
//!
//! 1. **mask+λ fold** (`mask_var_fold`): candidate mask, posterior
//!    variance (from the GP's running Σ V², no posterior solve needed)
//!    and the Σvar/count reduction that feeds the contextual-variance λ,
//!    all in one O(m) pass with fixed-point partial sums;
//! 2. **fused predict+score** (`IncrementalGp::predict_scored`): the
//!    O(n·m) posterior sweep computes each shard's (mu, var) chunk and
//!    immediately arg-minimizes every acquisition function the policy
//!    [`wanted`](AcqPolicy::wanted) while the tile is hot — there is no
//!    separate full-space `argmin_score` scan anymore.
//!
//! Determinism: shard boundaries are fixed by the config (never by the
//! thread count), per-shard accumulation order is scheduling-independent,
//! argmin reductions tie-break on the lowest index, and the λ reduction
//! sums integers — so a run's evaluation sequence is bit-identical for
//! every `threads`/`shard_len` (enforced by the tests below), and the
//! ask/tell port replays the pre-redesign loop bit for bit (enforced by
//! the `strategies::legacy` equivalence suite).

use std::sync::Arc;

use crate::bo::acquisition::{reduce_shard_argmins, score_chunk, var_from_fp, var_to_fp};
use crate::bo::config::{Acq, AcqPolicyKind, BoConfig, Exploration, InitialSampling};
use crate::bo::multi::{make_policy, AcqPolicy};
use crate::bo::pool::PoolBoDriver;
use crate::bo::sampling::{lhs_points, maximin_lhs_points, random_untaken, snap_to_configs};
use crate::gp::{IncrementalGp, NativeSurrogate, Surrogate, DEFAULT_SHARD_LEN};
use crate::space::view::SpaceView;
use crate::space::{neighbors, Neighborhood, SearchSpace};
use crate::strategies::driver::{Ask, DriveCtx, Observation, SearchDriver};
use crate::strategies::Strategy;
use crate::telemetry::{EventKind, Phase};
use crate::surrogate::{
    predict_pass, FitCtx, ForestConfig, ForestPool, GpPool, Model, PoolModel, TpeConfig, TpePool,
};
use crate::util::linalg::{mean, std_dev};
use crate::util::pool::{nested_threads, ShardPool};

/// Surrogate backend selection.
#[derive(Clone)]
pub enum Backend {
    /// Incremental in-process GP (default, fastest).
    Incremental,
    /// One-shot fit+predict backend per iteration — the interface shape of
    /// the XLA artifact (`runtime::XlaSurrogate`) and the reference
    /// `NativeSurrogate`.
    OneShot(Arc<dyn Fn(&BoConfig) -> Box<dyn Surrogate> + Send + Sync>),
    /// A pluggable batch surrogate from the [`surrogate`](crate::surrogate)
    /// subsystem: refit from the run's observations each iteration, then
    /// swept shard-parallel over the space's normalized tiles into the
    /// same fused mask+λ fold and acquisition argmin as the GP hot path.
    /// Backs the registry's `bo_rf` / `bo_et` / `tpe` strategies; a
    /// [`GpModel`](crate::surrogate::GpModel) factory replays
    /// [`Backend::Incremental`] bit for bit.
    Model(Arc<dyn Fn(&BoConfig) -> Box<dyn Model> + Send + Sync>),
}

/// The BO strategy (a factory for [`BoDriver`]s).
pub struct BoStrategy {
    pub config: BoConfig,
    pub backend: Backend,
    pub label: String,
}

impl BoStrategy {
    pub fn new(label: &str, config: BoConfig) -> BoStrategy {
        BoStrategy { config, backend: Backend::Incremental, label: label.to_string() }
    }

    pub fn with_backend(label: &str, config: BoConfig, backend: Backend) -> BoStrategy {
        BoStrategy { config, backend, label: label.to_string() }
    }
}

impl Strategy for BoStrategy {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn driver(&self, space: &SearchSpace) -> Box<dyn SearchDriver> {
        let cfg = self.config.clone();
        let m = space.len();
        let dims = space.dims();
        // Shard boundaries depend only on the config; the worker count
        // caps at the shard count and, in auto mode, divides the machine
        // by any harness-level parallelism already running (35 concurrent
        // repeats must not each spawn a core-count pool). Neither setting
        // affects results.
        let shard_len = if cfg.shard_len == 0 { DEFAULT_SHARD_LEN } else { cfg.shard_len };
        let n_shards = (m + shard_len - 1) / shard_len;
        let pool_threads = match cfg.threads {
            0 => nested_threads().min(n_shards),
            t => t.min(n_shards),
        };
        let pool = ShardPool::new(pool_threads);
        let (oneshot, model) = match &self.backend {
            Backend::Incremental => (None, None),
            Backend::OneShot(f) => (Some(f(&cfg)), None),
            Backend::Model(f) => (None, Some(f(&cfg))),
        };
        // Zero-copy: the GP borrows the space's shard-aligned f32 tiles —
        // a refcount bump per run, no re-normalization. Only the
        // incremental backend owns one; one-shot/Model runs must not pay
        // its O(m) per-shard accumulators.
        let inc = if oneshot.is_none() && model.is_none() {
            Some(IncrementalGp::with_shard_len(cfg.cov, cfg.noise, space.norm_tiles(), dims, shard_len))
        } else {
            None
        };
        let policy = make_policy(&cfg);
        Box::new(BoDriver {
            label: self.label.clone(),
            cfg,
            oneshot,
            model,
            model_seeded: false,
            started: false,
            phase: BoPhase::Init,
            visited: vec![false; m],
            taken: vec![false; m],
            obs_idx: Vec::new(),
            obs_y: Vec::new(),
            newly_invalid: Vec::new(),
            init_n: 0,
            mu_s: 0.0,
            shard_len,
            pool,
            inc,
            fed: 0,
            policy,
            mu: vec![0.0; m],
            var: vec![0.0; m],
            masked: vec![false; m],
            invalid_adj: vec![0u8; m],
            sigma_s2: None,
            chosen: None,
        })
    }

    fn lazy_driver(
        &self,
        _view: &dyn SpaceView,
        pool_size: usize,
    ) -> Option<Box<dyn SearchDriver>> {
        let cfg = self.config.clone();
        let acq = match cfg.acq {
            AcqPolicyKind::Single(a) => a,
            // The multi policies lean on the fused whole-space sweep's
            // per-AF argmins; they stay eager-only for now.
            AcqPolicyKind::Multi | AcqPolicyKind::AdvancedMulti => return None,
        };
        // The pool surrogate mirrors the registry's eager backend for
        // this label; unrecognized labels fall back to the one-shot GP
        // (the same posterior the incremental sweep computes).
        let model: Box<dyn PoolModel> = match self.label.as_str() {
            "tpe" => Box::new(TpePool::new(TpeConfig::default())),
            "bo_rf" => Box::new(ForestPool::new(ForestConfig::random_forest())),
            "bo_et" => Box::new(ForestPool::new(ForestConfig::extra_trees())),
            _ => Box::new(GpPool::new(NativeSurrogate::new(cfg.cov, cfg.noise))),
        };
        Some(Box::new(PoolBoDriver::new(self.label.clone(), cfg, acq, model, pool_size)))
    }
}

enum BoPhase {
    /// Telling back the LHS/maximin initial batch.
    Init,
    /// Telling back a random replacement draw.
    TopUp,
    /// Telling back acquisition-chosen evaluations.
    Step,
}

/// The stepwise BO engine. All per-run state lives here; the drive loop
/// owns evaluation, budget, and trace.
pub struct BoDriver {
    label: String,
    cfg: BoConfig,
    oneshot: Option<Box<dyn Surrogate>>,
    /// Pluggable batch surrogate (`Backend::Model`); refit per iteration
    /// and swept shard-parallel, replacing the incremental GP entirely.
    model: Option<Box<dyn Model>>,
    /// The model's private RNG stream has been derived from the run RNG.
    model_seeded: bool,
    started: bool,
    phase: BoPhase,
    visited: Vec<bool>,
    /// Scratch mask reused by every snap/random-replacement draw: the
    /// samplers mark tentative picks in it, so it must start each draw as
    /// a copy of `visited` — a copy into this buffer instead of a fresh
    /// O(m) allocation per draw.
    taken: Vec<bool>,
    obs_idx: Vec<usize>,
    obs_y: Vec<f64>,
    /// Invalids observed since the last pruning-model update.
    newly_invalid: Vec<usize>,
    init_n: usize,
    /// Initial-sample mean (raw units), for the contextual-variance λ.
    mu_s: f64,
    shard_len: usize,
    pool: ShardPool,
    /// The fused-sweep GP — present exactly for `Backend::Incremental`
    /// (one-shot and Model backends bring their own surrogate state).
    inc: Option<IncrementalGp>,
    /// Observations already fed to the incremental GP.
    fed: usize,
    policy: Box<dyn AcqPolicy>,
    mu: Vec<f64>,
    var: Vec<f64>,
    masked: Vec<bool>,
    /// Pruning state: count of observed-invalid adjacent neighbors.
    invalid_adj: Vec<u8>,
    sigma_s2: Option<f64>,
    /// The policy's pick of the in-flight step (its tell feeds
    /// `AcqPolicy::observe`; batch-mode extras update only the run state).
    chosen: Option<usize>,
}

impl BoDriver {
    /// A uniformly random not-yet-visited configuration.
    fn random_unvisited(&mut self, ctx: &mut DriveCtx) -> Option<usize> {
        self.taken.copy_from_slice(&self.visited);
        random_untaken(ctx.space(), &mut self.taken, ctx.rng)
    }

    /// Replace invalid/missing initial draws with random samples until
    /// the initial sample is complete (or budget/space is exhausted),
    /// then hand over to the optimization loop.
    fn top_up(&mut self, ctx: &mut DriveCtx) -> Ask {
        if self.obs_y.len() < self.init_n && ctx.budget_left() {
            if let Some(idx) = self.random_unvisited(ctx) {
                self.phase = BoPhase::TopUp;
                return Ask::Suggest(vec![idx]);
            }
        }
        if self.obs_y.is_empty() {
            return Ask::Finished; // nothing valid found at all
        }
        self.mu_s = mean(&self.obs_y);
        self.phase = BoPhase::Step;
        self.step(ctx)
    }

    /// One optimization-loop iteration (§III): register invalids with the
    /// pruning model, update the surrogate, fold mask+λ, run the fused
    /// acquisition sweep, and propose the policy's pick (or, in batch
    /// mode, every distinct argmin).
    fn step(&mut self, ctx: &mut DriveCtx) -> Ask {
        if !ctx.budget_left() {
            return Ask::Finished;
        }
        let space = ctx.space();
        let m = space.len();
        let dims = space.dims();

        // Register invalids observed since the last iteration with the
        // pruning model (never with the surrogate).
        if self.cfg.pruning {
            for idx in self.newly_invalid.drain(..) {
                for nb in neighbors(space, idx, Neighborhood::Adjacent) {
                    self.invalid_adj[nb] = self.invalid_adj[nb].saturating_add(1);
                }
            }
        } else {
            self.newly_invalid.clear();
        }

        // z-normalize observations so AF scores and λ are scale-free.
        let y_mean = mean(&self.obs_y);
        let y_std = {
            let s = std_dev(&self.obs_y);
            if s > 1e-12 {
                s
            } else {
                1.0
            }
        };
        let y_z: Vec<f64> = self.obs_y.iter().map(|v| (v - y_mean) / y_std).collect();

        // Feed new observations to the surrogate. The incremental
        // backend defers its posterior sweep to the fused pass below; a
        // pluggable batch model refits and is swept shard-parallel here;
        // the one-shot backend must produce mu/var up front.
        let tel = ctx.telemetry();
        let step_no = ctx.fevals_used();
        let t_fit = tel.start();
        if let Some(model) = &mut self.model {
            if !self.model_seeded {
                // One deterministic split of the run stream, at a fixed
                // point of the run (the first surrogate fit): models that
                // need randomness (forest bootstraps) get a private child
                // stream; deterministic models leave the run RNG alone.
                model.seed(ctx.rng);
                self.model_seeded = true;
            }
            model.fit(&FitCtx {
                space,
                obs_idx: &self.obs_idx,
                y_z: &y_z,
                shard_len: self.shard_len,
                pool: &self.pool,
            });
            predict_pass(&**model, space, &self.pool, self.shard_len, &mut self.mu, &mut self.var);
        } else {
            match &mut self.oneshot {
                None => {
                    let inc = self.inc.as_mut().expect("incremental backend owns a GP");
                    while self.fed < self.obs_idx.len() {
                        inc.add_par(space.point(self.obs_idx[self.fed]), &self.pool);
                        self.fed += 1;
                    }
                }
                Some(s) => {
                    // One-shot backend: fit on observations, predict over
                    // non-visited candidates, scatter back. The Surrogate
                    // ABI is f64; widen the f32 tiles (exact conversion).
                    let widen =
                        |i: usize| space.point(i).iter().map(|&v| f64::from(v)).collect::<Vec<f64>>();
                    let x: Vec<f64> = self.obs_idx.iter().flat_map(|&i| widen(i)).collect();
                    let cand_idx: Vec<usize> = (0..m).filter(|&i| !self.visited[i]).collect();
                    let cand: Vec<f64> = cand_idx.iter().flat_map(|&i| widen(i)).collect();
                    let mut cmu = vec![0.0; cand_idx.len()];
                    let mut cvar = vec![0.0; cand_idx.len()];
                    if s.fit_predict(&x, &y_z, dims, &cand, &mut cmu, &mut cvar).is_err() {
                        return Ask::Finished;
                    }
                    self.mu.fill(f64::INFINITY);
                    self.var.fill(1e-12);
                    for (p, &i) in cand_idx.iter().enumerate() {
                        self.mu[i] = cmu[p];
                        self.var[i] = cvar[p];
                    }
                }
            }
        }
        tel.span(step_no, Phase::Fit, t_fit, self.obs_idx.len());

        // Candidate mask (§III-D: evaluated configs are out; pruned
        // configs — ≥2 invalid adjacent neighbors — are out while
        // other candidates remain) folded with the Σvar/count
        // reduction for λ into one sharded O(m) pass. The incremental
        // backend also materializes `var` here, straight from the
        // GP's running Σ V²; the one-shot and Model backends filled
        // `var` above, so the fold only masks and reduces it.
        let sq_chunks: Option<Vec<&[f64]>> =
            self.inc.as_ref().map(|inc| inc.sq_chunks().collect());
        let adj = if self.cfg.pruning { Some(&self.invalid_adj[..]) } else { None };
        let (mut var_fp, mut n_cand) = mask_var_fold(
            &self.pool,
            self.shard_len,
            &mut self.masked,
            &mut self.var,
            sq_chunks.as_deref(),
            &self.visited,
            adj,
        );
        if n_cand == 0 && self.cfg.pruning {
            // Pruning ate everything: relax it to visited-only.
            let relaxed = mask_var_fold(
                &self.pool,
                self.shard_len,
                &mut self.masked,
                &mut self.var,
                sq_chunks.as_deref(),
                &self.visited,
                None,
            );
            var_fp = relaxed.0;
            n_cand = relaxed.1;
        }
        if n_cand == 0 {
            return Ask::Finished; // space exhausted
        }
        let sigma_bar2 = var_from_fp(var_fp) / n_cand as f64;
        let s_s2 = *self.sigma_s2.get_or_insert(sigma_bar2);

        // Exploration factor (§III-F).
        let f_best = self.obs_y.iter().cloned().fold(f64::INFINITY, f64::min);
        let lambda = match self.cfg.exploration {
            Exploration::Constant(l) => l,
            Exploration::ContextualVariance => {
                // λ = (σ̄² / (μ_s / f(x⁺))) / σ̄_s², clamped to [0, ∞).
                let improvement = (self.mu_s / f_best).max(1e-12);
                ((sigma_bar2 / improvement) / s_s2.max(1e-12)).max(0.0)
            }
        };
        let f_best_z = (f_best - y_mean) / y_std;

        // Fused acquisition pass: one sweep computes every wanted AF's
        // exhaustive argmin (plus, for the incremental backend, the
        // posterior itself; one-shot/Model posteriors are already in
        // `mu`/`var`, so their sweep is the sharded score pass alone).
        let t_score = tel.start();
        let wanted = self.policy.wanted();
        let suggestions: Vec<Option<usize>> = if wanted.is_empty() {
            Vec::new()
        } else if let Some(inc) = &self.inc {
            let masked = &self.masked;
            let parts =
                inc.predict_scored(&y_z, &self.pool, &mut self.mu, &mut self.var, |start, mu_c, var_c| {
                    score_chunk(
                        &wanted,
                        mu_c,
                        var_c,
                        &masked[start..start + mu_c.len()],
                        start,
                        f_best_z,
                        lambda,
                    )
                });
            reduce_shard_argmins(&parts, wanted.len())
        } else {
            let parts = score_pass(
                &self.pool,
                self.shard_len,
                &wanted,
                &self.mu,
                &self.var,
                &self.masked,
                f_best_z,
                lambda,
            );
            reduce_shard_argmins(&parts, wanted.len())
        };
        tel.span(step_no, Phase::Score, t_score, wanted.len());

        let pick = self.policy.choose(&suggestions);
        if let Some(arm) = self.policy.chosen_arm() {
            tel.record(step_no, EventKind::AfChoice { arm });
        }

        if self.cfg.batch_ask {
            // Batch mode: the fused sweep already produced one argmin per
            // wanted acquisition function — propose all distinct ones.
            // The policy's bookkeeping advanced once (the `choose` above)
            // and its observe() will be routed to the chosen index only;
            // the extra evaluations enrich the surrogate via `tell`.
            if let Some(chosen) = pick {
                let mut batch: Vec<usize> = Vec::new();
                for s in suggestions.iter().flatten() {
                    if !batch.contains(s) {
                        batch.push(*s);
                    }
                }
                if !batch.contains(&chosen) {
                    batch.push(chosen);
                }
                self.chosen = Some(chosen);
                return Ask::Suggest(batch);
            }
            // Every AF fully masked: random fallback, as sequentially.
            return match self.random_unvisited(ctx) {
                Some(i) => {
                    self.chosen = Some(i);
                    Ask::Suggest(vec![i])
                }
                None => Ask::Finished,
            };
        }

        let idx = match pick {
            Some(i) => i,
            None => match self.random_unvisited(ctx) {
                Some(i) => i,
                None => return Ask::Finished,
            },
        };
        self.chosen = Some(idx);
        Ask::Suggest(vec![idx])
    }
}

impl SearchDriver for BoDriver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn ask(&mut self, ctx: &mut DriveCtx) -> Ask {
        if !self.started {
            // ---- Initial sampling (§III-E) ----
            self.started = true;
            let space = ctx.space();
            let m = space.len();
            let dims = space.dims();
            self.init_n = match ctx.max_fevals() {
                Some(b) => self.cfg.init_samples.min(b),
                None => self.cfg.init_samples,
            }
            .min(m);
            let pts = match self.cfg.init_sampling {
                InitialSampling::Lhs => Some(lhs_points(self.init_n, dims, ctx.rng)),
                InitialSampling::Maximin => Some(maximin_lhs_points(self.init_n, dims, 16, ctx.rng)),
                InitialSampling::Random => None,
            };
            if let Some(pts) = pts {
                self.taken.copy_from_slice(&self.visited);
                let idxs = snap_to_configs(&pts, space, &mut self.taken);
                self.phase = BoPhase::Init;
                if !idxs.is_empty() {
                    return Ask::Suggest(idxs);
                }
            }
            return self.top_up(ctx);
        }
        match self.phase {
            BoPhase::Init | BoPhase::TopUp => self.top_up(ctx),
            BoPhase::Step => self.step(ctx),
        }
    }

    fn tell(&mut self, obs: Observation) {
        debug_assert!(!obs.cached, "BO never re-proposes an evaluated config");
        self.visited[obs.idx] = true;
        let value = obs.eval.value();
        if let Some(v) = value {
            self.obs_idx.push(obs.idx);
            self.obs_y.push(v);
        } else if !obs.eval.is_transient() {
            // Persistent invalids feed the pruning model; transient faults
            // say nothing about the config or its neighborhood, so
            // learning them as invalid regions would poison pruning.
            self.newly_invalid.push(obs.idx);
        }
        if let BoPhase::Step = self.phase {
            if self.chosen == Some(obs.idx) {
                self.policy.observe(value, &self.obs_y);
            }
        }
    }
}

/// One sharded O(m) fold over the candidate set: writes the mask (visited
/// ∪ pruned), optionally materializes the posterior variance from the
/// GP's running Σ V² chunks, and reduces (Σ unmasked var, unmasked count).
/// Chunk boundaries are fixed by `chunk` and the variance sum uses
/// associative fixed-point arithmetic, so the result is bit-identical for
/// every partition and thread count.
#[allow(clippy::too_many_arguments)]
pub(crate) fn mask_var_fold(
    pool: &ShardPool,
    chunk: usize,
    masked: &mut [bool],
    var: &mut [f64],
    sq_chunks: Option<&[&[f64]]>,
    visited: &[bool],
    invalid_adj: Option<&[u8]>,
) -> (u128, usize) {
    let m = masked.len();
    let n_chunks = (m + chunk - 1) / chunk;
    let mut parts: Vec<(u128, usize)> = vec![(0, 0); n_chunks];
    {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = masked
            .chunks_mut(chunk)
            .zip(var.chunks_mut(chunk))
            .zip(visited.chunks(chunk))
            .zip(parts.iter_mut())
            .enumerate()
            .map(|(ci, (((mk, vr), vis), slot))| {
                let start = ci * chunk;
                let sq = sq_chunks.map(|s| s[ci]);
                let adj = invalid_adj.map(|a| &a[start..start + mk.len()]);
                Box::new(move || {
                    let mut fp = 0u128;
                    let mut n = 0usize;
                    for j in 0..mk.len() {
                        if let Some(sq) = sq {
                            vr[j] = (1.0 - sq[j]).max(1e-12);
                        }
                        let pruned = adj.map_or(false, |a| a[j] >= 2);
                        mk[j] = vis[j] || pruned;
                        if !mk[j] {
                            fp += var_to_fp(vr[j]);
                            n += 1;
                        }
                    }
                    *slot = (fp, n);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run(jobs);
    }
    let mut fp = 0u128;
    let mut n = 0usize;
    for (p, c) in parts {
        fp += p;
        n += c;
    }
    (fp, n)
}

/// Sharded acquisition argmin over precomputed (mu, var) arrays — the
/// one-shot/XLA backend's equivalent of the fused incremental pass.
#[allow(clippy::too_many_arguments)]
pub(crate) fn score_pass(
    pool: &ShardPool,
    chunk: usize,
    afs: &[Acq],
    mu: &[f64],
    var: &[f64],
    masked: &[bool],
    f_best: f64,
    lambda: f64,
) -> Vec<Vec<Option<(usize, f64)>>> {
    let m = masked.len();
    let n_chunks = (m + chunk - 1) / chunk;
    let mut parts: Vec<Vec<Option<(usize, f64)>>> = Vec::with_capacity(n_chunks);
    parts.resize_with(n_chunks, Vec::new);
    {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = parts
            .iter_mut()
            .enumerate()
            .map(|(ci, slot)| {
                let start = ci * chunk;
                let end = (start + chunk).min(m);
                Box::new(move || {
                    *slot = score_chunk(afs, &mu[start..end], &var[start..end], &masked[start..end], start, f_best, lambda);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run(jobs);
    }
    parts
}

/// The pre-redesign whole-loop implementation, kept verbatim as the
/// reference for the ask/tell equivalence suite (`strategies::legacy`).
#[cfg(test)]
pub(crate) mod legacy_engine {
    use super::*;
    use crate::objective::{Eval, Objective};
    use crate::strategies::Trace;
    use crate::util::rng::Rng;

    struct RunState<'a> {
        obj: &'a dyn Objective,
        rng: &'a mut Rng,
        trace: Trace,
        visited: Vec<bool>,
        taken: Vec<bool>,
        obs_idx: Vec<usize>,
        obs_y: Vec<f64>,
        max_fevals: usize,
    }

    impl<'a> RunState<'a> {
        fn budget_left(&self) -> bool {
            self.trace.len() < self.max_fevals
        }

        fn random_unvisited(&mut self, space: &SearchSpace) -> Option<usize> {
            self.taken.copy_from_slice(&self.visited);
            random_untaken(space, &mut self.taken, self.rng)
        }

        fn evaluate(&mut self, idx: usize) -> Option<f64> {
            debug_assert!(!self.visited[idx], "re-evaluating config {idx}");
            let e = self.obj.evaluate(idx, self.rng);
            self.trace.push(idx, e);
            self.visited[idx] = true;
            if let Eval::Valid(v) = e {
                self.obs_idx.push(idx);
                self.obs_y.push(v);
                Some(v)
            } else {
                None
            }
        }

        fn f_best(&self) -> f64 {
            self.obs_y.iter().cloned().fold(f64::INFINITY, f64::min)
        }
    }

    /// The original `BoStrategy::run` body, pre-ask/tell.
    pub fn run(strategy: &BoStrategy, obj: &dyn Objective, max_fevals: usize, rng: &mut Rng) -> Trace {
        let cfg = &strategy.config;
        let space = obj.space();
        let m = space.len();
        let dims = space.dims();

        let mut st = RunState {
            obj,
            rng,
            trace: Trace::new(),
            visited: vec![false; m],
            taken: vec![false; m],
            obs_idx: Vec::new(),
            obs_y: Vec::new(),
            max_fevals,
        };

        let init_n = cfg.init_samples.min(max_fevals).min(m);
        let pts = match cfg.init_sampling {
            InitialSampling::Lhs => Some(lhs_points(init_n, dims, st.rng)),
            InitialSampling::Maximin => Some(maximin_lhs_points(init_n, dims, 16, st.rng)),
            InitialSampling::Random => None,
        };
        let mut newly_invalid: Vec<usize> = Vec::new();
        if let Some(pts) = pts {
            st.taken.copy_from_slice(&st.visited);
            let idxs = snap_to_configs(&pts, space, &mut st.taken);
            for idx in idxs {
                if !st.budget_left() {
                    break;
                }
                if st.evaluate(idx).is_none() {
                    newly_invalid.push(idx);
                }
            }
        }
        while st.obs_y.len() < init_n && st.budget_left() {
            match st.random_unvisited(space) {
                Some(idx) => {
                    if st.evaluate(idx).is_none() {
                        newly_invalid.push(idx);
                    }
                }
                None => break,
            }
        }
        if st.obs_y.is_empty() {
            return st.trace;
        }
        let mu_s = mean(&st.obs_y);

        let shard_len = if cfg.shard_len == 0 { DEFAULT_SHARD_LEN } else { cfg.shard_len };
        let n_shards = (m + shard_len - 1) / shard_len;
        let pool_threads = match cfg.threads {
            0 => nested_threads().min(n_shards),
            t => t.min(n_shards),
        };
        let pool = ShardPool::new(pool_threads);
        let mut inc =
            IncrementalGp::with_shard_len(cfg.cov, cfg.noise, space.norm_tiles(), dims, shard_len);
        let mut fed = 0usize;
        let mut oneshot = match &strategy.backend {
            Backend::Incremental => None,
            Backend::OneShot(f) => Some(f(cfg)),
            // Model backends postdate the redesign: they were born on the
            // ask/tell API and have no pre-redesign loop to replay (their
            // GP flavor is pinned to this path via Backend::Incremental
            // in surrogate::tests instead).
            Backend::Model(_) => panic!("no legacy reference path for Model backends"),
        };

        let mut policy: Box<dyn AcqPolicy> = make_policy(cfg);
        let mut mu = vec![0.0; m];
        let mut var = vec![0.0; m];
        let mut masked = vec![false; m];
        let mut invalid_adj = vec![0u8; m];
        let mut sigma_s2: Option<f64> = None;

        while st.budget_left() {
            if cfg.pruning {
                for idx in newly_invalid.drain(..) {
                    for nb in neighbors(space, idx, Neighborhood::Adjacent) {
                        invalid_adj[nb] = invalid_adj[nb].saturating_add(1);
                    }
                }
            } else {
                newly_invalid.clear();
            }

            let y_mean = mean(&st.obs_y);
            let y_std = {
                let s = std_dev(&st.obs_y);
                if s > 1e-12 {
                    s
                } else {
                    1.0
                }
            };
            let y_z: Vec<f64> = st.obs_y.iter().map(|v| (v - y_mean) / y_std).collect();

            match &mut oneshot {
                None => {
                    while fed < st.obs_idx.len() {
                        inc.add_par(space.point(st.obs_idx[fed]), &pool);
                        fed += 1;
                    }
                }
                Some(s) => {
                    let widen =
                        |i: usize| space.point(i).iter().map(|&v| f64::from(v)).collect::<Vec<f64>>();
                    let x: Vec<f64> = st.obs_idx.iter().flat_map(|&i| widen(i)).collect();
                    let cand_idx: Vec<usize> = (0..m).filter(|&i| !st.visited[i]).collect();
                    let cand: Vec<f64> = cand_idx.iter().flat_map(|&i| widen(i)).collect();
                    let mut cmu = vec![0.0; cand_idx.len()];
                    let mut cvar = vec![0.0; cand_idx.len()];
                    if s.fit_predict(&x, &y_z, dims, &cand, &mut cmu, &mut cvar).is_err() {
                        break;
                    }
                    mu.fill(f64::INFINITY);
                    var.fill(1e-12);
                    for (p, &i) in cand_idx.iter().enumerate() {
                        mu[i] = cmu[p];
                        var[i] = cvar[p];
                    }
                }
            }

            let sq_chunks: Option<Vec<&[f64]>> =
                if oneshot.is_none() { Some(inc.sq_chunks().collect()) } else { None };
            let adj = if cfg.pruning { Some(&invalid_adj[..]) } else { None };
            let (mut var_fp, mut n_cand) =
                mask_var_fold(&pool, shard_len, &mut masked, &mut var, sq_chunks.as_deref(), &st.visited, adj);
            if n_cand == 0 && cfg.pruning {
                let relaxed =
                    mask_var_fold(&pool, shard_len, &mut masked, &mut var, sq_chunks.as_deref(), &st.visited, None);
                var_fp = relaxed.0;
                n_cand = relaxed.1;
            }
            if n_cand == 0 {
                break;
            }
            let sigma_bar2 = var_from_fp(var_fp) / n_cand as f64;
            let s_s2 = *sigma_s2.get_or_insert(sigma_bar2);

            let f_best = st.f_best();
            let lambda = match cfg.exploration {
                Exploration::Constant(l) => l,
                Exploration::ContextualVariance => {
                    let improvement = (mu_s / f_best).max(1e-12);
                    ((sigma_bar2 / improvement) / s_s2.max(1e-12)).max(0.0)
                }
            };
            let f_best_z = (f_best - y_mean) / y_std;

            let wanted = policy.wanted();
            let suggestions: Vec<Option<usize>> = if wanted.is_empty() {
                Vec::new()
            } else if oneshot.is_none() {
                let parts = inc.predict_scored(&y_z, &pool, &mut mu, &mut var, |start, mu_c, var_c| {
                    score_chunk(&wanted, mu_c, var_c, &masked[start..start + mu_c.len()], start, f_best_z, lambda)
                });
                reduce_shard_argmins(&parts, wanted.len())
            } else {
                let parts = score_pass(&pool, shard_len, &wanted, &mu, &var, &masked, f_best_z, lambda);
                reduce_shard_argmins(&parts, wanted.len())
            };

            let pick = policy.choose(&suggestions);
            let idx = match pick {
                Some(i) => i,
                None => match st.random_unvisited(space) {
                    Some(i) => i,
                    None => break,
                },
            };
            let value = st.evaluate(idx);
            if value.is_none() {
                newly_invalid.push(idx);
            }
            policy.observe(value, &st.obs_y);
        }
        st.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::config::{Acq, AcqPolicyKind};
    use crate::objective::{Eval, Objective, TableObjective};
    use crate::space::{Param, SearchSpace};
    use crate::strategies::driver::{drive, FevalBudget};
    use crate::strategies::Trace;
    use crate::util::rng::Rng;

    /// A smooth 2D bowl over a 30×30 grid with a known minimum.
    fn bowl() -> TableObjective {
        let vals: Vec<i64> = (0..30).collect();
        let space = SearchSpace::build("bowl", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
        let table: Vec<Eval> = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                let (dx, dy) = (f64::from(p[0]) - 0.7, f64::from(p[1]) - 0.3);
                Eval::Valid(10.0 + 100.0 * (dx * dx + dy * dy))
            })
            .collect();
        TableObjective::new(space, table)
    }

    /// A bowl where a quadrant is invalid.
    fn bowl_with_invalid() -> TableObjective {
        let vals: Vec<i64> = (0..30).collect();
        let space = SearchSpace::build("bowl-inv", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
        let table: Vec<Eval> = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                if p[0] > 0.8 && p[1] > 0.8 {
                    Eval::CompileError
                } else {
                    let (dx, dy) = (f64::from(p[0]) - 0.7, f64::from(p[1]) - 0.3);
                    Eval::Valid(10.0 + 100.0 * (dx * dx + dy * dy))
                }
            })
            .collect();
        TableObjective::new(space, table)
    }

    fn run_bo(cfg: BoConfig, obj: &TableObjective, seed: u64, budget: usize) -> Trace {
        let s = BoStrategy::new("bo", cfg);
        let mut rng = Rng::new(seed);
        s.run(obj, budget, &mut rng)
    }

    #[test]
    fn finds_bowl_minimum_quickly() {
        let obj = bowl();
        let t = run_bo(BoConfig::single(Acq::Ei), &obj, 42, 60);
        let best = t.best().unwrap().1;
        let global = obj.known_minimum().unwrap();
        assert!(best < global * 1.05, "best {best} vs global {global}");
    }

    #[test]
    fn beats_budget_sized_random_on_average() {
        let obj = bowl();
        let mut bo_wins = 0;
        for seed in 0..5u64 {
            let t = run_bo(BoConfig::single(Acq::Ei), &obj, seed, 50);
            let bo_best = t.best().unwrap().1;
            // Random baseline: 50 uniform draws.
            let mut rng = Rng::new(seed ^ 0xbeef);
            let mut rnd_best = f64::INFINITY;
            for _ in 0..50 {
                let i = rng.below(obj.space().len());
                if let Some(v) = obj.table()[i].value() {
                    rnd_best = rnd_best.min(v);
                }
            }
            if bo_best <= rnd_best {
                bo_wins += 1;
            }
        }
        assert!(bo_wins >= 4, "BO won only {bo_wins}/5 against random");
    }

    #[test]
    fn never_reevaluates_and_respects_budget() {
        let obj = bowl();
        for kind in [AcqPolicyKind::Single(Acq::Lcb), AcqPolicyKind::Multi, AcqPolicyKind::AdvancedMulti] {
            let mut cfg = BoConfig::single(Acq::Ei);
            cfg.acq = kind;
            let t = run_bo(cfg, &obj, 7, 80);
            assert_eq!(t.len(), 80);
            let idxs: Vec<usize> = t.records.iter().map(|(i, _)| *i).collect();
            let set: std::collections::HashSet<_> = idxs.iter().collect();
            assert_eq!(set.len(), idxs.len(), "configuration re-evaluated under {kind:?}");
        }
    }

    #[test]
    fn handles_invalid_region() {
        let obj = bowl_with_invalid();
        let t = run_bo(BoConfig::advanced_multi(), &obj, 11, 70);
        let best = t.best().unwrap().1;
        let global = obj.known_minimum().unwrap();
        assert!(best < global * 1.1, "best {best} vs {global}");
    }

    #[test]
    fn exhausts_tiny_space_without_panic() {
        let space = SearchSpace::build("tiny", vec![Param::ints("a", &[1, 2, 3, 4, 5])], &[]);
        let table: Vec<Eval> = (0..5).map(|i| Eval::Valid(i as f64)).collect();
        let obj = TableObjective::new(space, table);
        let t = run_bo(BoConfig::single(Acq::Ei), &obj, 3, 100);
        assert_eq!(t.len(), 5, "must stop when the space is exhausted");
        assert_eq!(t.best().unwrap().1, 0.0);
    }

    #[test]
    fn all_invalid_space_terminates() {
        let space = SearchSpace::build("dead", vec![Param::ints("a", &[1, 2, 3])], &[]);
        let obj = TableObjective::new(space, vec![Eval::CompileError; 3]);
        let t = run_bo(BoConfig::single(Acq::Ei), &obj, 5, 50);
        assert!(t.len() <= 3);
        assert!(t.best().is_none());
    }

    #[test]
    fn oneshot_backend_agrees_with_incremental() {
        use crate::gp::NativeSurrogate;
        let obj = bowl();
        let cfg = BoConfig::single(Acq::Ei);
        let inc = run_bo(cfg.clone(), &obj, 9, 45);
        let one = BoStrategy::with_backend(
            "bo-oneshot",
            cfg,
            Backend::OneShot(Arc::new(|c: &BoConfig| {
                Box::new(NativeSurrogate::new(c.cov, c.noise)) as Box<dyn Surrogate>
            })),
        );
        let mut rng = Rng::new(9);
        let t2 = one.run(&obj, 45, &mut rng);
        // Same RNG seed + same math ⇒ identical evaluation sequence.
        let a: Vec<usize> = inc.records.iter().map(|(i, _)| *i).collect();
        let b: Vec<usize> = t2.records.iter().map(|(i, _)| *i).collect();
        assert_eq!(a, b, "one-shot backend must reproduce the incremental path");
    }

    /// The PR-1 determinism criterion, now exercised through the ask/tell
    /// driver: the sharded hot path must reproduce the serial single-tile
    /// (seed-equivalent) evaluation sequence bit for bit, at every shard
    /// partition and thread count.
    #[test]
    fn evaluation_sequence_identical_across_shards_and_threads() {
        let obj = bowl_with_invalid(); // exercises pruning + invalid paths too
        let seq = |cfg_base: BoConfig, shard_len: usize, threads: usize| -> Vec<usize> {
            let mut cfg = cfg_base;
            cfg.shard_len = shard_len;
            cfg.threads = threads;
            let t = run_bo(cfg, &obj, 17, 80);
            t.records.iter().map(|(i, _)| *i).collect()
        };
        for base in [BoConfig::single(Acq::Ei), BoConfig::multi(), BoConfig::advanced_multi()] {
            // 900 candidates in one tile, zero worker threads: the serial
            // reference path.
            let reference = seq(base.clone(), 900, 1);
            assert_eq!(reference.len(), 80);
            for &(sl, th) in &[(450, 2), (113, 8), (64, 3), (0, 8), (900, 4)] {
                assert_eq!(
                    seq(base.clone(), sl, th),
                    reference,
                    "{:?}: sequence diverged at shard_len={sl} threads={th}",
                    base.acq
                );
            }
        }
    }

    #[test]
    fn contextual_variance_lambda_shrinks_over_time() {
        // Indirect check: CV must not explode — run and ensure convergence
        // behaviour (best at end much better than best after init).
        let obj = bowl();
        let t = run_bo(BoConfig::single(Acq::Poi), &obj, 21, 100);
        let curve = t.best_curve();
        assert!(curve[99] <= curve[20]);
    }

    /// Batch ask mode: each step proposes every distinct per-AF argmin
    /// from the fused sweep — a real >1 batch under the `multi` policy.
    #[test]
    fn batch_ask_proposes_multiple_suggestions_per_step() {
        use crate::strategies::driver::{Ask, DriveCtx, SearchDriver};
        let obj = bowl();
        let mut cfg = BoConfig::multi();
        cfg.batch_ask = true;
        let s = BoStrategy::new("multi-batch", cfg);
        let mut d = s.driver(obj.space());
        let budget = FevalBudget::new(80);
        let mut rng = Rng::new(13);

        // Hand-drive the loop so batch sizes are observable.
        let mut trace = Trace::new();
        let mut memo = crate::objective::evalcache::RunMemo::private();
        let mut saw_multi = false;
        let mut steps = 0;
        while trace.len() < 80 && steps < 200 {
            steps += 1;
            let batch = {
                let mut ctx = DriveCtx::probe(obj.space(), &mut rng, &trace, &memo, &budget);
                match d.ask(&mut ctx) {
                    Ask::Suggest(b) => b,
                    Ask::Finished => break,
                }
            };
            saw_multi |= batch.len() > 1;
            for idx in batch {
                if trace.len() >= 80 {
                    break;
                }
                let eval = obj.evaluate(idx, &mut rng);
                memo.record(idx, eval);
                trace.push(idx, eval);
                d.tell(crate::strategies::driver::Observation { idx, eval, cached: false });
            }
        }
        assert!(saw_multi, "multi policy in batch mode must batch >1 suggestion");
        // Batch mode still never re-evaluates and still optimizes.
        let idxs: std::collections::HashSet<usize> = trace.records.iter().map(|(i, _)| *i).collect();
        assert_eq!(idxs.len(), trace.len());
        let global = obj.known_minimum().unwrap();
        assert!(trace.best().unwrap().1 < global * 1.1);
    }

    /// Sequential (batch_ask=false) driver runs replay the legacy loop —
    /// spot check here; the full zoo suite lives in strategies::legacy.
    #[test]
    fn driver_path_replays_legacy_engine() {
        let obj = bowl_with_invalid();
        for cfg in [BoConfig::single(Acq::Ei), BoConfig::multi(), BoConfig::advanced_multi()] {
            let s = BoStrategy::new("bo", cfg);
            let mut r1 = Rng::new(23);
            let legacy = legacy_engine::run(&s, &obj, 70, &mut r1);
            let mut r2 = Rng::new(23);
            let mut d = s.driver(obj.space());
            let new = drive(d.as_mut(), &obj, &FevalBudget::new(70), &mut r2);
            assert_eq!(legacy.records, new.records, "{:?}", s.config.acq);
        }
    }
}
