//! Acquisition-function selection policies (§III-G): single AF, the
//! `multi` AF (duplicate-driven skipping), and the `advanced multi` AF
//! (discounted-observation-score-driven skipping and promotion).
//!
//! Both meta-strategies evaluate the basic AFs in a round-robin fashion,
//! optimizing *one* AF per function evaluation over the shared posterior
//! predictions (unlike GP-Hedge, which optimizes all of them every time).
//!
//! The interface is split so the engine can *fuse* acquisition scoring
//! into the posterior sweep: a policy first declares which basic AFs it
//! needs exhaustively arg-minimized this iteration ([`AcqPolicy::wanted`]),
//! the engine computes all of them in one sharded pass over the posterior,
//! and the policy then picks from the resulting suggestions
//! ([`AcqPolicy::choose`]) without ever touching the O(m) arrays itself.

use crate::bo::config::{Acq, BoConfig};
use crate::util::linalg::median;

/// Outcome bookkeeping interface of an acquisition policy.
pub trait AcqPolicy: Send {
    /// The basic AFs whose exhaustive argmins the engine must compute for
    /// this iteration, in order. Must not mutate state: the matching
    /// `choose` call advances the rotation.
    fn wanted(&self) -> Vec<Acq>;

    /// Pick a candidate position given one argmin suggestion per AF
    /// returned by the matching `wanted()` call (`suggestions[i]` ↔
    /// `wanted()[i]`; `None` = every candidate masked under that AF).
    /// Returns `None` when no AF has a suggestion.
    fn choose(&mut self, suggestions: &[Option<usize>]) -> Option<usize>;

    /// Report the *raw* observation produced by the last `choose`
    /// (`None` for an invalid configuration). `valid_so_far` holds all raw
    /// valid observations, for the median imputation of advanced multi.
    fn observe(&mut self, y: Option<f64>, valid_so_far: &[f64]);

    /// Currently active basic AFs (for logging/tests).
    fn active(&self) -> Vec<Acq>;

    /// Rotation position of the AF that made the last `choose` decision
    /// — telemetry reads this to record multi-AF arm selections. `None`
    /// for single-AF policies (no decision to report) and before the
    /// first choose.
    fn chosen_arm(&self) -> Option<usize> {
        None
    }
}

/// Discounted observation score: dos_t = Σᵢ oᵢ·γ^(t−i) — recent
/// observations weigh more. Lower is better under minimization.
#[derive(Clone, Debug, Default)]
pub struct Dos {
    value: f64,
    count: usize,
}

impl Dos {
    pub fn push(&mut self, obs: f64, discount: f64) {
        self.value = self.value * discount + obs;
        self.count += 1;
    }

    pub fn value(&self) -> f64 {
        self.value
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Mean-normalized view: dos divided by the discounted weight mass, so
    /// AFs with different observation counts compare fairly.
    pub fn normalized(&self, discount: f64) -> f64 {
        if self.count == 0 {
            return f64::INFINITY;
        }
        // Σ γ^(t-i) for i = 1..count.
        let mass = if (discount - 1.0).abs() < 1e-12 {
            self.count as f64
        } else {
            (1.0 - discount.powi(self.count as i32)) / (1.0 - discount)
        };
        self.value / mass
    }
}

/// Policy: one fixed acquisition function.
pub struct SinglePolicy {
    pub acq: Acq,
}

impl AcqPolicy for SinglePolicy {
    fn wanted(&self) -> Vec<Acq> {
        vec![self.acq]
    }

    fn choose(&mut self, suggestions: &[Option<usize>]) -> Option<usize> {
        suggestions.first().copied().flatten()
    }

    fn observe(&mut self, _y: Option<f64>, _valid: &[f64]) {}

    fn active(&self) -> Vec<Acq> {
        vec![self.acq]
    }
}

/// The `multi` acquisition function: skips AFs that repeatedly suggest the
/// same candidates as another AF; ties are broken by the discounted
/// observation score of each AF's own evaluations.
pub struct MultiPolicy {
    order: Vec<Acq>,
    active: Vec<bool>,
    dup_counts: Vec<usize>,
    dos: Vec<Dos>,
    rr: usize,
    last_chooser: Option<usize>,
    skip_threshold: usize,
    discount: f64,
}

impl MultiPolicy {
    pub fn new(cfg: &BoConfig) -> MultiPolicy {
        let order: Vec<Acq> = cfg.af_order.to_vec();
        let k = order.len();
        MultiPolicy {
            order,
            active: vec![true; k],
            dup_counts: vec![0; k],
            dos: vec![Dos::default(); k],
            rr: 0,
            last_chooser: None,
            skip_threshold: cfg.skip_threshold,
            discount: cfg.discount,
        }
    }

    fn next_active(&mut self) -> Option<usize> {
        let k = self.order.len();
        for _ in 0..k {
            let i = self.rr % k;
            self.rr += 1;
            if self.active[i] {
                return Some(i);
            }
        }
        None
    }
}

impl AcqPolicy for MultiPolicy {
    fn wanted(&self) -> Vec<Acq> {
        // Every active AF's suggestion is needed: duplicate detection
        // compares them pairwise. The engine fuses all of them into the
        // one posterior sweep, so this costs one pass regardless.
        self.order
            .iter()
            .zip(&self.active)
            .filter(|(_, a)| **a)
            .map(|(q, _)| *q)
            .collect()
    }

    fn choose(&mut self, fused: &[Option<usize>]) -> Option<usize> {
        // Scatter the fused suggestions (one per *active* AF, in order)
        // back onto rotation positions; inactive AFs get `None`, exactly
        // as when they were scored inline.
        let k = self.order.len();
        let mut suggestions: Vec<Option<usize>> = vec![None; k];
        let mut it = fused.iter();
        for (i, sug) in suggestions.iter_mut().enumerate() {
            if self.active[i] {
                *sug = it.next().copied().flatten();
            }
        }
        for i in 0..suggestions.len() {
            for j in i + 1..suggestions.len() {
                if let (Some(si), Some(sj)) = (suggestions[i], suggestions[j]) {
                    if si == sj {
                        self.dup_counts[i] += 1;
                        self.dup_counts[j] += 1;
                    }
                }
            }
        }
        // Conflict resolution: among AFs over the threshold, keep the one
        // with the best (lowest) discounted observation score.
        let over: Vec<usize> = (0..self.order.len())
            .filter(|&i| self.active[i] && self.dup_counts[i] > self.skip_threshold)
            .collect();
        if over.len() > 1 {
            let keep = *over
                .iter()
                .min_by(|&&a, &&b| {
                    self.dos[a]
                        .normalized(self.discount)
                        .partial_cmp(&self.dos[b].normalized(self.discount))
                        .unwrap()
                })
                .unwrap();
            for &i in &over {
                if i != keep {
                    self.active[i] = false;
                }
            }
            for c in self.dup_counts.iter_mut() {
                *c = 0;
            }
        }

        let chooser = self.next_active()?;
        self.last_chooser = Some(chooser);
        suggestions[chooser].or_else(|| {
            // The chooser had no suggestion (fully masked): fall back to
            // any other active AF's suggestion.
            suggestions.iter().flatten().next().copied()
        })
    }

    fn observe(&mut self, y: Option<f64>, _valid: &[f64]) {
        if let (Some(c), Some(v)) = (self.last_chooser, y) {
            self.dos[c].push(v, self.discount);
        }
    }

    fn active(&self) -> Vec<Acq> {
        self.order
            .iter()
            .zip(&self.active)
            .filter(|(_, a)| **a)
            .map(|(q, _)| *q)
            .collect()
    }

    fn chosen_arm(&self) -> Option<usize> {
        self.last_chooser
    }
}

/// The `advanced multi` acquisition function: judges AFs directly by their
/// discounted observation scores. An AF scoring worse than
/// (1 + improvement_factor)·mean for `skip_threshold` consecutive strikes
/// is dropped (and the others' strikes reset); one scoring better than
/// (1 − improvement_factor)·mean as often is promoted to sole AF.
pub struct AdvancedMultiPolicy {
    order: Vec<Acq>,
    active: Vec<bool>,
    dos: Vec<Dos>,
    bad_strikes: Vec<usize>,
    good_strikes: Vec<usize>,
    rr: usize,
    last_chooser: Option<usize>,
    skip_threshold: usize,
    improvement_factor: f64,
    discount: f64,
}

impl AdvancedMultiPolicy {
    pub fn new(cfg: &BoConfig) -> AdvancedMultiPolicy {
        let order: Vec<Acq> = cfg.af_order.to_vec();
        let k = order.len();
        AdvancedMultiPolicy {
            order,
            active: vec![true; k],
            dos: vec![Dos::default(); k],
            bad_strikes: vec![0; k],
            good_strikes: vec![0; k],
            rr: 0,
            last_chooser: None,
            skip_threshold: cfg.skip_threshold,
            improvement_factor: cfg.improvement_factor,
            discount: cfg.discount,
        }
    }

    fn n_active(&self) -> usize {
        self.active.iter().filter(|a| **a).count()
    }
}

impl AdvancedMultiPolicy {
    /// The AF the rotation will hand the next evaluation to, without
    /// advancing it (`wanted` must be side-effect free).
    fn peek_chooser(&self) -> Option<usize> {
        let k = self.order.len();
        (0..k).map(|d| (self.rr + d) % k).find(|&i| self.active[i])
    }
}

impl AcqPolicy for AdvancedMultiPolicy {
    fn wanted(&self) -> Vec<Acq> {
        // Unlike `multi`, only the rotation's current AF is optimized —
        // one argmin per evaluation, as in the paper.
        match self.peek_chooser() {
            Some(i) => vec![self.order[i]],
            None => Vec::new(),
        }
    }

    fn choose(&mut self, suggestions: &[Option<usize>]) -> Option<usize> {
        let k = self.order.len();
        let chooser = self.peek_chooser()?;
        self.rr = (chooser + 1) % k; // congruent to the pre-split rr walk
        self.last_chooser = Some(chooser);
        suggestions.first().copied().flatten()
    }

    fn observe(&mut self, y: Option<f64>, valid_so_far: &[f64]) {
        let Some(c) = self.last_chooser else { return };
        // Invalid observations are imputed with the median of the valid
        // observations, to avoid skewing the score (§III-G).
        let obs = y.unwrap_or_else(|| median(valid_so_far));
        if !obs.is_finite() {
            return; // no valid observations yet to impute from
        }
        self.dos[c].push(obs, self.discount);

        // Judge the chooser against the mean of active AFs' scores, once
        // every active AF has a score.
        let scores: Vec<(usize, f64)> = (0..self.order.len())
            .filter(|&i| self.active[i] && self.dos[i].count() > 0)
            .map(|i| (i, self.dos[i].normalized(self.discount)))
            .collect();
        if scores.len() < self.n_active() || scores.len() < 2 {
            return;
        }
        let mean: f64 = scores.iter().map(|(_, s)| s).sum::<f64>() / scores.len() as f64;
        let own = self.dos[c].normalized(self.discount);
        if own > mean * (1.0 + self.improvement_factor) {
            self.bad_strikes[c] += 1;
            if self.bad_strikes[c] >= self.skip_threshold && self.n_active() > 1 {
                self.active[c] = false;
                for i in 0..self.order.len() {
                    self.bad_strikes[i] = 0;
                    self.good_strikes[i] = 0;
                }
            }
        } else if own < mean * (1.0 - self.improvement_factor) {
            self.good_strikes[c] += 1;
            if self.good_strikes[c] >= self.skip_threshold {
                for i in 0..self.order.len() {
                    self.active[i] = i == c;
                }
            }
        } else {
            self.bad_strikes[c] = 0;
            self.good_strikes[c] = 0;
        }
    }

    fn active(&self) -> Vec<Acq> {
        self.order
            .iter()
            .zip(&self.active)
            .filter(|(_, a)| **a)
            .map(|(q, _)| *q)
            .collect()
    }

    fn chosen_arm(&self) -> Option<usize> {
        self.last_chooser
    }
}

/// Build the policy described by a config.
pub fn make_policy(cfg: &BoConfig) -> Box<dyn AcqPolicy> {
    match cfg.acq {
        crate::bo::config::AcqPolicyKind::Single(a) => Box::new(SinglePolicy { acq: a }),
        crate::bo::config::AcqPolicyKind::Multi => Box::new(MultiPolicy::new(cfg)),
        crate::bo::config::AcqPolicyKind::AdvancedMulti => Box::new(AdvancedMultiPolicy::new(cfg)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::acquisition::argmin_score;

    fn cfg() -> BoConfig {
        BoConfig::multi()
    }

    /// Drive a policy the way the engine does: ask for its wanted AFs,
    /// arg-minimize each with the reference scan, hand back the
    /// suggestions.
    fn choose_on(
        p: &mut dyn AcqPolicy,
        mu: &[f64],
        var: &[f64],
        f_best: f64,
        lambda: f64,
        masked: &[bool],
    ) -> Option<usize> {
        let wanted = p.wanted();
        let suggestions: Vec<Option<usize>> =
            wanted.iter().map(|a| argmin_score(*a, mu, var, f_best, lambda, masked)).collect();
        p.choose(&suggestions)
    }

    #[test]
    fn dos_discounts_recent_more() {
        let mut d = Dos::default();
        d.push(10.0, 0.5);
        d.push(2.0, 0.5);
        // dos = 10·0.5 + 2 = 7; mass = 1.5 → normalized ≈ 4.67 (closer to
        // the recent 2 than the plain mean 6 would be... well, weighted).
        assert!((d.value() - 7.0).abs() < 1e-12);
        assert!((d.normalized(0.5) - 7.0 / 1.5).abs() < 1e-12);
    }

    #[test]
    fn single_policy_tracks_argmin() {
        let mut p = SinglePolicy { acq: Acq::Lcb };
        let mu = [1.0, 0.2, 0.9];
        let var = [0.1, 0.1, 0.1];
        assert_eq!(p.wanted(), vec![Acq::Lcb]);
        let pick = choose_on(&mut p, &mu, &var, 1.0, 0.0, &[false, false, false]).unwrap();
        assert_eq!(pick, 1);
        assert_eq!(p.active(), vec![Acq::Lcb]);
    }

    #[test]
    fn multi_skips_duplicating_afs() {
        let mut p = MultiPolicy::new(&cfg());
        // Degenerate posterior where all AFs agree on candidate 0 forever:
        // after enough rounds only one AF must remain active.
        let mu = [0.0, 5.0, 5.0];
        let var = [1.0, 0.01, 0.01];
        for step in 0..30 {
            let pick = choose_on(&mut p, &mu, &var, 1.0, 0.1, &[false, false, false]).unwrap();
            assert_eq!(pick, 0);
            p.observe(Some(1.0 + step as f64 * 0.01), &[1.0]);
        }
        assert_eq!(p.active().len(), 1, "duplicating AFs must be skipped");
        // Once skipped, wanted() shrinks with the active set.
        assert_eq!(p.wanted().len(), 1);
    }

    #[test]
    fn multi_round_robins_while_disagreeing() {
        let mut p = MultiPolicy::new(&cfg());
        // POI prefers the near-certain tiny improvement (candidate 0);
        // EI and LCB prefer the larger expected improvement (candidate 1).
        let mu = [0.45, 0.2];
        let var = [0.0001, 0.0625];
        let picks: Vec<usize> = (0..5)
            .map(|_| {
                let c = choose_on(&mut p, &mu, &var, 0.5, 0.0, &[false, false]).unwrap();
                p.observe(Some(1.0), &[1.0]);
                c
            })
            .collect();
        let distinct: std::collections::HashSet<_> = picks.iter().collect();
        assert!(distinct.len() >= 2, "disagreeing AFs must alternate: {picks:?}");
        assert!(p.active().len() >= 2);
    }

    #[test]
    fn advanced_multi_wants_exactly_one_af_per_round() {
        let c = BoConfig::advanced_multi();
        let mut p = AdvancedMultiPolicy::new(&c);
        // The rotation must advance one AF per choose, matching af_order.
        let mut seen = Vec::new();
        for _ in 0..6 {
            let w = p.wanted();
            assert_eq!(w.len(), 1, "advanced multi optimizes one AF per evaluation");
            seen.push(w[0]);
            let _ = p.choose(&[Some(0)]);
            p.observe(Some(1.0), &[1.0]);
        }
        assert_eq!(&seen[..3], &c.af_order, "rotation must follow af_order");
        assert_eq!(&seen[3..], &c.af_order, "rotation must wrap");
    }

    #[test]
    fn advanced_multi_promotes_consistent_winner() {
        let c = BoConfig::advanced_multi();
        let mut p = AdvancedMultiPolicy::new(&c);
        let mu = [0.0, 2.0, 3.0];
        let var = [0.01, 0.01, 9.0];
        // Feed: whenever the chooser is EI (round-robin position 0) give an
        // excellent observation; others get poor ones.
        for step in 0..60 {
            if p.active().len() == 1 {
                break;
            }
            let _ = choose_on(&mut p, &mu, &var, 0.5, 1.0, &[false, false, false]);
            let is_ei_turn = step % p.order.len() == 0; // approximation of rr
            p.observe(Some(if is_ei_turn { 1.0 } else { 10.0 }), &[1.0]);
        }
        assert_eq!(p.active().len(), 1, "a consistently better AF must be promoted");
    }

    #[test]
    fn advanced_multi_imputes_invalid_with_median() {
        let c = BoConfig::advanced_multi();
        let mut p = AdvancedMultiPolicy::new(&c);
        let mu = [0.0];
        let var = [1.0];
        let _ = choose_on(&mut p, &mu, &var, 0.5, 0.1, &[false]);
        p.observe(None, &[2.0, 4.0, 6.0]); // median 4.0
        assert!((p.dos[0].value() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn policy_factory_dispatch() {
        assert_eq!(make_policy(&BoConfig::single(Acq::Ei)).active(), vec![Acq::Ei]);
        assert_eq!(make_policy(&BoConfig::multi()).active().len(), 3);
        assert_eq!(make_policy(&BoConfig::advanced_multi()).active().len(), 3);
    }
}
