//! Candidate-pool Bayesian optimization for implicit ([`SpaceView`])
//! spaces — the lazy-space acquisition arm.
//!
//! The eager [`BoDriver`](crate::bo::engine::BoDriver) optimizes its
//! acquisition function *exhaustively* over the enumerated space, which
//! is exactly the O(m)-per-iteration sweep a billion-scale space cannot
//! afford. [`PoolBoDriver`] replaces the sweep with a bounded candidate
//! pool rebuilt each iteration:
//!
//! 1. **global draws** — uniform valid configurations from the view's
//!    constraint-propagating sampler (the lazy analogue of the LHS
//!    space-filling draw: uniform over the valid set, deduplicated, never
//!    revisiting an observed key);
//! 2. **incumbent probes** — [`Neighborhood::Adjacent`] neighbor keys of
//!    the best few observations, so the pool always contains the local
//!    moves an exhaustive sweep would have ranked first.
//!
//! The pool is fitted/scored by a [`PoolModel`] and the acquisition
//! argmin (lowest packed key wins ties) is proposed. Per-suggestion work
//! is O(pool_size · dims + n_obs²) — independent of the Cartesian size,
//! which is what the `space_scale` bench asserts.
//!
//! # Determinism
//!
//! Pool draws come from a *private child stream* split once from the run
//! RNG at the first ask (tag `"POOL"`), mirroring the surrogate
//! [`seed`](PoolModel::seed) discipline: the proposal sequence is a pure
//! function of (seed, observation sequence), and the run stream itself
//! advances exactly once for the split plus once per model seed, keeping
//! eager-mode traces untouched by this module's existence.

use std::collections::BTreeSet;

use crate::bo::acquisition::score;
use crate::bo::config::{Acq, BoConfig, Exploration};
use crate::space::view::SpaceView;
use crate::space::Neighborhood;
use crate::strategies::driver::{Ask, DriveCtx, Observation, SearchDriver};
use crate::surrogate::PoolModel;
use crate::telemetry::Phase;
use crate::util::linalg::{mean, std_dev};
use crate::util::rng::Rng;

/// How many best-so-far observations seed neighborhood probes.
const INCUMBENT_PROBES: usize = 3;
/// Rejection-sampling attempts per wanted pool candidate.
const DRAW_TRIES_PER_CANDIDATE: usize = 8;
/// Default candidate-pool size when the session leaves it unset.
pub const DEFAULT_POOL_SIZE: usize = 512;

enum PoolPhase {
    /// Telling back the initial uniform-valid batch.
    Init,
    /// Telling back acquisition-chosen evaluations.
    Step,
}

/// Stepwise candidate-pool BO over any [`SpaceView`]. Holds no
/// space-sized state: observations and the visited set are keyed by
/// packed key, so a billion-scale lazy space costs the same memory as a
/// toy grid.
pub struct PoolBoDriver {
    label: String,
    cfg: BoConfig,
    acq: Acq,
    model: Box<dyn PoolModel>,
    model_seeded: bool,
    pool_size: usize,
    /// Private child stream for pool draws (split at first ask).
    pool_rng: Option<Rng>,
    started: bool,
    phase: PoolPhase,
    visited: BTreeSet<u64>,
    obs_keys: Vec<u64>,
    obs_y: Vec<f64>,
    init_n: usize,
    /// Initial-sample mean (raw units) for the contextual-variance λ.
    mu_s: Option<f64>,
    sigma_s2: Option<f64>,
    /// Scratch: neighbor-probe output buffer.
    nbuf: Vec<u64>,
}

impl PoolBoDriver {
    pub fn new(
        label: String,
        cfg: BoConfig,
        acq: Acq,
        model: Box<dyn PoolModel>,
        pool_size: usize,
    ) -> PoolBoDriver {
        PoolBoDriver {
            label,
            cfg,
            acq,
            model,
            model_seeded: false,
            pool_size: pool_size.max(1),
            pool_rng: None,
            started: false,
            phase: PoolPhase::Init,
            visited: BTreeSet::new(),
            obs_keys: Vec::new(),
            obs_y: Vec::new(),
            init_n: 0,
            mu_s: None,
            sigma_s2: None,
            nbuf: Vec::new(),
        }
    }

    /// Draw up to `want` distinct unvisited valid keys from the private
    /// pool stream into `into`. Bounded tries: an exhausted or
    /// ultra-constrained space yields fewer (possibly zero) draws.
    fn draw_unvisited(&mut self, view: &dyn SpaceView, want: usize, into: &mut BTreeSet<u64>) {
        let rng = self.pool_rng.as_mut().expect("pool stream split at first ask");
        let mut fresh = 0usize;
        for _ in 0..want.saturating_mul(DRAW_TRIES_PER_CANDIDATE) {
            if fresh >= want {
                break;
            }
            match view.sample_key(rng) {
                Some(k) if !self.visited.contains(&k) && into.insert(k) => fresh += 1,
                Some(_) => {}
                None => break, // sampler exhausted: no valid configs at all
            }
        }
    }

    /// One uniformly drawn unvisited key, or `None` if the draws dry up.
    fn random_unvisited(&mut self, view: &dyn SpaceView) -> Option<u64> {
        let mut one = BTreeSet::new();
        self.draw_unvisited(view, 1, &mut one);
        one.into_iter().next()
    }

    /// Build this iteration's candidate pool: global draws plus adjacent
    /// probes around the best `INCUMBENT_PROBES` observations.
    fn build_pool(&mut self, view: &dyn SpaceView) -> Vec<u64> {
        let mut pool: BTreeSet<u64> = BTreeSet::new();
        self.draw_unvisited(view, self.pool_size, &mut pool);

        // Incumbents: lowest observed value, ties by evaluation order.
        let mut order: Vec<usize> = (0..self.obs_y.len()).collect();
        order.sort_by(|&a, &b| {
            self.obs_y[a]
                .partial_cmp(&self.obs_y[b])
                .expect("observed values are finite")
                .then(a.cmp(&b))
        });
        let mut nbuf = std::mem::take(&mut self.nbuf);
        for &o in order.iter().take(INCUMBENT_PROBES) {
            view.neighbor_keys(self.obs_keys[o], Neighborhood::Adjacent, &mut nbuf);
            for &k in &nbuf {
                if !self.visited.contains(&k) {
                    pool.insert(k);
                }
            }
        }
        self.nbuf = nbuf;
        // Ascending key order: deterministic, and the argmin's first-wins
        // comparison then tie-breaks on the lowest packed key.
        pool.into_iter().collect()
    }

    /// One pool iteration: fit, score, propose the acquisition argmin.
    fn step(&mut self, ctx: &mut DriveCtx) -> Ask {
        if !ctx.budget_left() {
            return Ask::Finished;
        }
        let view = ctx.view();
        if self.obs_y.is_empty() {
            // Nothing valid observed yet: keep topping up uniformly.
            return match self.random_unvisited(view) {
                Some(k) => Ask::Suggest(vec![k as usize]),
                None => Ask::Finished,
            };
        }
        let mu_s = *self.mu_s.get_or_insert_with(|| mean(&self.obs_y));

        let tel = ctx.telemetry();
        let step_no = ctx.fevals_used();
        let t_pool = tel.start();
        let pool = self.build_pool(view);
        tel.span(step_no, Phase::PoolDraw, t_pool, pool.len());
        if pool.is_empty() {
            return Ask::Finished; // valid set exhausted (or sampler dry)
        }

        // z-normalize observations so AF scores and λ are scale-free.
        let y_mean = mean(&self.obs_y);
        let y_std = {
            let s = std_dev(&self.obs_y);
            if s > 1e-12 {
                s
            } else {
                1.0
            }
        };
        let y_z: Vec<f64> = self.obs_y.iter().map(|v| (v - y_mean) / y_std).collect();

        if !self.model_seeded {
            // Same discipline as the eager engine: one deterministic
            // split of the run stream at the first fit.
            self.model.seed(ctx.rng);
            self.model_seeded = true;
        }
        let mut mu = vec![0.0; pool.len()];
        let mut var = vec![0.0; pool.len()];
        let t_fit = tel.start();
        let fit = self.model.fit_predict(view, &self.obs_keys, &y_z, &pool, &mut mu, &mut var);
        tel.span(step_no, Phase::Fit, t_fit, self.obs_keys.len());
        if fit.is_err() {
            // Degenerate fit (singular GP): explore uniformly this step.
            return match self.random_unvisited(view) {
                Some(k) => Ask::Suggest(vec![k as usize]),
                None => Ask::Finished,
            };
        }

        // Exploration factor (§III-F) over the pool's posterior.
        let f_best = self.obs_y.iter().cloned().fold(f64::INFINITY, f64::min);
        let sigma_bar2 = mean(&var);
        let s_s2 = *self.sigma_s2.get_or_insert(sigma_bar2);
        let lambda = match self.cfg.exploration {
            Exploration::Constant(l) => l,
            Exploration::ContextualVariance => {
                let improvement = (mu_s / f_best).max(1e-12);
                ((sigma_bar2 / improvement) / s_s2.max(1e-12)).max(0.0)
            }
        };
        let f_best_z = (f_best - y_mean) / y_std;

        // Acquisition argmin; strict `<` keeps the first (lowest) key on
        // ties since the pool is in ascending key order.
        let t_score = tel.start();
        let mut best: Option<(f64, u64)> = None;
        for (j, &k) in pool.iter().enumerate() {
            let s = score(self.acq, mu[j], var[j], f_best_z, lambda);
            if best.map_or(true, |(b, _)| s < b) {
                best = Some((s, k));
            }
        }
        tel.span(step_no, Phase::Score, t_score, pool.len());
        match best {
            Some((_, k)) => Ask::Suggest(vec![k as usize]),
            None => Ask::Finished,
        }
    }
}

impl SearchDriver for PoolBoDriver {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn ask(&mut self, ctx: &mut DriveCtx) -> Ask {
        if !self.started {
            self.started = true;
            // Satellite guarantee: candidate pools come from a private
            // child stream, split exactly once at a fixed point of the
            // run (the first ask).
            self.pool_rng = Some(ctx.rng.split(0x504f_4f4c)); // "POOL"
            let view = ctx.view();
            self.init_n = match ctx.max_fevals() {
                Some(b) => self.cfg.init_samples.min(b),
                None => self.cfg.init_samples,
            }
            .max(1);
            let mut batch = BTreeSet::new();
            self.draw_unvisited(view, self.init_n, &mut batch);
            if batch.is_empty() {
                return Ask::Finished; // no valid configuration exists
            }
            self.phase = PoolPhase::Init;
            return Ask::Suggest(batch.into_iter().map(|k| k as usize).collect());
        }
        match self.phase {
            PoolPhase::Init => {
                if self.obs_y.len() < self.init_n && ctx.budget_left() {
                    if let Some(k) = self.random_unvisited(ctx.view()) {
                        return Ask::Suggest(vec![k as usize]);
                    }
                }
                self.phase = PoolPhase::Step;
                self.step(ctx)
            }
            PoolPhase::Step => self.step(ctx),
        }
    }

    fn tell(&mut self, obs: Observation) {
        let key = obs.idx as u64;
        self.visited.insert(key);
        if let Some(v) = obs.eval.value() {
            self.obs_keys.push(key);
            self.obs_y.push(v);
        }
        // Persistent invalids stay only in `visited`: never fitted, never
        // re-proposed. (No pruning model here — the adjacency counts the
        // eager engine keeps would be space-sized.)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::synthetic::SyntheticObjective;
    use crate::space::view::LazyView;
    use crate::space::{Expr, SpaceSpec};
    use crate::strategies::driver::{drive, FevalBudget};
    use crate::surrogate::{ForestPool, TpePool};
    use crate::surrogate::{ForestConfig, TpeConfig};
    use std::sync::Arc;

    fn lazy_view() -> Arc<LazyView> {
        let spec = SpaceSpec::new("pool-bo-toy")
            .ints("bx", &[8, 16, 32, 64])
            .ints("by", &[1, 2, 4, 8])
            .ints("tile", &[1, 2, 3, 4, 5])
            .bools("vec")
            .restrict(Expr::var("bx").mul(Expr::var("by")).le(Expr::lit(256)));
        Arc::new(LazyView::from_spec(&spec).expect("toy spec builds"))
    }

    fn driver_with(model: Box<dyn PoolModel>) -> PoolBoDriver {
        let mut cfg = BoConfig::single(Acq::Ei);
        cfg.init_samples = 6;
        PoolBoDriver::new("pool-test".into(), cfg, Acq::Ei, model, 32)
    }

    #[test]
    fn tpe_pool_run_completes_and_is_seed_deterministic() {
        let obj = SyntheticObjective::new(lazy_view(), 42).with_invalid_rate(0.1);
        let run = |seed: u64| {
            let mut d = driver_with(Box::new(TpePool::new(TpeConfig::default())));
            let mut rng = Rng::new(seed);
            drive(&mut d, &obj, &FevalBudget { max_fevals: 25 }, &mut rng)
        };
        let a = run(5);
        let b = run(5);
        let c = run(6);
        assert_eq!(a.records, b.records, "same seed must replay bit-identically");
        assert_ne!(a.records, c.records, "different seeds must explore differently");
        assert_eq!(a.records.len(), 25, "feval budget fully spent");
        // Every proposed index is a valid member key.
        let view = obj.lazy_view();
        for &(idx, _) in &a.records {
            assert!(view.idx_of_key(idx as u64).is_some(), "record {idx} not in space");
        }
    }

    #[test]
    fn forest_pool_run_completes_without_enumeration() {
        let view = lazy_view();
        let obj = SyntheticObjective::new(view.clone(), 7);
        let mut d = driver_with(Box::new(ForestPool::new(ForestConfig::extra_trees())));
        let mut rng = Rng::new(9);
        let trace = drive(&mut d, &obj, &FevalBudget { max_fevals: 20 }, &mut rng);
        assert_eq!(trace.records.len(), 20);
        // A run never re-proposes an observed key.
        let mut seen = BTreeSet::new();
        for &(idx, _) in &trace.records {
            assert!(seen.insert(idx), "key {idx} proposed twice");
        }
    }

    #[test]
    fn pool_work_is_bounded_by_the_pool_knob() {
        let view = lazy_view();
        let obj = SyntheticObjective::new(view.clone(), 3);
        let mut d = driver_with(Box::new(TpePool::new(TpeConfig::default())));
        let mut rng = Rng::new(1);
        let before = view.probe_count();
        drive(&mut d, &obj, &FevalBudget { max_fevals: 15 }, &mut rng);
        let probes = view.probe_count() - before;
        // 15 suggestions at pool 32 with rejection tries and neighbor
        // probes: comfortably under a fixed multiple of pool×budget —
        // and nowhere near the 640-config Cartesian sweep per step the
        // eager engine would do.
        assert!(probes > 0, "lazy run must answer through the oracle");
        assert!(
            probes < 15 * 32 * 64,
            "per-suggestion probe work must stay bounded by the pool size (got {probes})"
        );
    }

    /// THE telemetry acceptance invariant, lazy half (the eager half
    /// lives in `strategies::driver`): for every lazy-capable registry
    /// strategy, a recording telemetry handle leaves the evaluation
    /// trace bit-identical to a telemetry-off run.
    #[test]
    fn telemetry_on_vs_off_lazy_traces_bit_identical_registry_wide() {
        use crate::strategies::driver::{drive_with, DriveOpts};
        use crate::strategies::registry;
        use crate::telemetry::Telemetry;
        let view = lazy_view();
        let obj = SyntheticObjective::new(view.clone(), 42).with_invalid_rate(0.1);
        for name in registry::lazy_names() {
            let strat = registry::by_name(name).unwrap();
            let run = |telemetry: Telemetry| {
                let mut d = strat.lazy_driver(view.as_ref(), 32).expect("lazy-capable");
                let mut rng = Rng::new(7);
                let opts = DriveOpts { telemetry, ..DriveOpts::default() };
                drive_with(d.as_mut(), &obj, &FevalBudget { max_fevals: 15 }, &mut rng, opts)
            };
            let off = run(Telemetry::default());
            let tel = Telemetry::recording(crate::telemetry::DEFAULT_RING_CAPACITY);
            let on = run(tel.clone());
            assert_eq!(
                off.records, on.records,
                "{name}: recording telemetry changed the lazy evaluation trace"
            );
            assert!(!tel.is_empty(), "{name}: a recording lazy run must capture events");
        }
    }
}
