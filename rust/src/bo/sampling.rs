//! Initial sampling (§III-E): Latin Hypercube Sampling over the normalized
//! unit cube, snapped to the nearest not-yet-chosen configuration, with a
//! maximin variant (best of k LHS draws by minimum pairwise distance —
//! Table I's tuned default). Invalid draws are replaced by random samples
//! (the paper's combination that "avoids a skewed initial sample").

use crate::space::SearchSpace;
use crate::util::rng::Rng;

/// One Latin Hypercube Sample: `n` points in [0,1]^dims, one per stratum
/// per dimension.
pub fn lhs_points(n: usize, dims: usize, rng: &mut Rng) -> Vec<f64> {
    let mut out = vec![0.0; n * dims];
    let mut perm: Vec<usize> = (0..n).collect();
    for d in 0..dims {
        rng.shuffle(&mut perm);
        for (i, &p) in perm.iter().enumerate() {
            out[i * dims + d] = (p as f64 + rng.f64()) / n as f64;
        }
    }
    out
}

/// Minimum pairwise distance of a point set (maximin criterion).
pub fn min_pairwise_dist(points: &[f64], dims: usize) -> f64 {
    let n = points.len() / dims;
    let mut best = f64::INFINITY;
    for i in 0..n {
        for j in i + 1..n {
            let d: f64 = (0..dims)
                .map(|k| {
                    let diff = points[i * dims + k] - points[j * dims + k];
                    diff * diff
                })
                .sum();
            best = best.min(d);
        }
    }
    best.sqrt()
}

/// Maximin LHS: best of `k` LHS draws by minimum pairwise distance.
pub fn maximin_lhs_points(n: usize, dims: usize, k: usize, rng: &mut Rng) -> Vec<f64> {
    let mut best: Option<(f64, Vec<f64>)> = None;
    for _ in 0..k.max(1) {
        let pts = lhs_points(n, dims, rng);
        let score = min_pairwise_dist(&pts, dims);
        if best.as_ref().map_or(true, |(s, _)| score > *s) {
            best = Some((score, pts));
        }
    }
    best.unwrap().1
}

/// Nearest configuration (normalized coords) to one continuous point —
/// the snap used by the continuous-relaxation strategies (PSO, DE).
/// Linear scan: spaces are tens of thousands of points; candidate for
/// k-d acceleration if snapping ever became a hot path.
pub fn nearest_config(space: &SearchSpace, p: &[f64]) -> usize {
    let dims = space.dims();
    let pts = space.points(); // the space's f32 tiles, borrowed in place
    let mut best = (0usize, f64::INFINITY);
    for i in 0..space.len() {
        let q = &pts[i * dims..(i + 1) * dims];
        let d: f64 = p
            .iter()
            .zip(q)
            .map(|(a, &b)| {
                let d = a - f64::from(b);
                d * d
            })
            .sum();
        if d < best.1 {
            best = (i, d);
        }
    }
    best.0
}

/// Snap continuous points to distinct configurations: for each point, the
/// nearest configuration (normalized coords) not yet taken.
pub fn snap_to_configs(points: &[f64], space: &SearchSpace, taken: &mut Vec<bool>) -> Vec<usize> {
    let dims = space.dims();
    let n = points.len() / dims;
    let all = space.points();
    let mut out = Vec::with_capacity(n);
    for p in points.chunks_exact(dims) {
        let mut best: Option<(usize, f64)> = None;
        for idx in 0..space.len() {
            if taken[idx] {
                continue;
            }
            let q = &all[idx * dims..(idx + 1) * dims];
            let d: f64 = p
                .iter()
                .zip(q)
                .map(|(a, &b)| {
                    let d = a - f64::from(b);
                    d * d
                })
                .sum();
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((idx, d));
            }
        }
        if let Some((idx, _)) = best {
            taken[idx] = true;
            out.push(idx);
        }
    }
    out
}

/// A random not-yet-taken configuration (replacement sampling for invalid
/// draws). Returns `None` when the space is exhausted.
pub fn random_untaken(_space: &SearchSpace, taken: &mut [bool], rng: &mut Rng) -> Option<usize> {
    let remaining = taken.iter().filter(|t| !**t).count();
    if remaining == 0 {
        return None;
    }
    // Rejection sampling is fast while the space is mostly untaken; fall
    // back to an indexed draw when it gets crowded.
    if remaining * 4 > taken.len() {
        loop {
            let i = rng.below(taken.len());
            if !taken[i] {
                taken[i] = true;
                return Some(i);
            }
        }
    }
    let k = rng.below(remaining);
    let idx = taken
        .iter()
        .enumerate()
        .filter(|(_, t)| !**t)
        .nth(k)
        .map(|(i, _)| i)
        .expect("counted above");
    taken[idx] = true;
    Some(idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::Param;

    fn space() -> SearchSpace {
        SearchSpace::build(
            "toy",
            vec![
                Param::ints("a", &(0..20).collect::<Vec<_>>()),
                Param::ints("b", &(0..20).collect::<Vec<_>>()),
            ],
            &[],
        )
    }

    #[test]
    fn lhs_stratifies_each_dimension() {
        let mut rng = Rng::new(1);
        let n = 10;
        let pts = lhs_points(n, 3, &mut rng);
        for d in 0..3 {
            let mut strata = vec![false; n];
            for i in 0..n {
                let s = (pts[i * 3 + d] * n as f64) as usize;
                strata[s.min(n - 1)] = true;
            }
            assert!(strata.iter().all(|&s| s), "dimension {d} misses a stratum");
        }
    }

    #[test]
    fn maximin_at_least_as_spread_as_single_draw() {
        let mut r1 = Rng::new(2);
        let mut r2 = Rng::new(2);
        let single = lhs_points(8, 2, &mut r1);
        let multi = maximin_lhs_points(8, 2, 20, &mut r2);
        assert!(min_pairwise_dist(&multi, 2) >= min_pairwise_dist(&single, 2) - 1e-12);
    }

    #[test]
    fn snap_gives_distinct_configs() {
        let s = space();
        let mut rng = Rng::new(3);
        let pts = lhs_points(20, 2, &mut rng);
        let mut taken = vec![false; s.len()];
        let idxs = snap_to_configs(&pts, &s, &mut taken);
        assert_eq!(idxs.len(), 20);
        let set: std::collections::HashSet<_> = idxs.iter().collect();
        assert_eq!(set.len(), 20, "snapped configs must be distinct");
    }

    #[test]
    fn snap_prefers_nearby() {
        let s = space();
        let mut taken = vec![false; s.len()];
        // A point at the origin snaps to config (0,0).
        let idxs = snap_to_configs(&[0.0, 0.0], &s, &mut taken);
        assert_eq!(s.config(idxs[0]), vec![0u16, 0]);
    }

    #[test]
    fn random_untaken_exhausts() {
        let s = SearchSpace::build("tiny", vec![Param::ints("a", &[1, 2, 3])], &[]);
        let mut taken = vec![false; s.len()];
        let mut rng = Rng::new(4);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            seen.insert(random_untaken(&s, &mut taken, &mut rng).unwrap());
        }
        assert_eq!(seen.len(), 3);
        assert!(random_untaken(&s, &mut taken, &mut rng).is_none());
    }
}
