//! BO hyperparameters with the paper's tuned defaults (Table I).

use crate::gp::CovFn;

/// Which basic acquisition function scores candidates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Acq {
    /// Expected Improvement (minimization variant).
    Ei,
    /// Probability of Improvement.
    Poi,
    /// Lower Confidence Bound (minimization variant of UCB).
    Lcb,
}

impl Acq {
    pub fn name(&self) -> &'static str {
        match self {
            Acq::Ei => "ei",
            Acq::Poi => "poi",
            Acq::Lcb => "lcb",
        }
    }
}

/// Acquisition meta-strategy (§III-G).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcqPolicyKind {
    /// One fixed basic acquisition function.
    Single(Acq),
    /// Round-robin with duplicate-driven skipping ("multi").
    Multi,
    /// Round-robin with score-driven skipping/promotion ("advanced multi").
    AdvancedMulti,
}

/// Exploration-factor schedule for the acquisition functions (§III-F).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Exploration {
    /// Fixed hyperparameter λ.
    Constant(f64),
    /// The paper's contextual variance: λ = (σ̄² / (μ_s / f(x⁺))) / σ̄_s².
    ContextualVariance,
}

/// Initial-sampling flavor (§III-E).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitialSampling {
    Random,
    /// Latin Hypercube Sample with random replacement of invalid draws.
    Lhs,
    /// Best-of-k LHS by maximin pairwise distance (Table I default).
    Maximin,
}

/// Full BO configuration. Defaults = Table I.
#[derive(Clone, Debug)]
pub struct BoConfig {
    pub cov: CovFn,
    /// Observation noise added to the kernel diagonal.
    pub noise: f64,
    pub acq: AcqPolicyKind,
    pub exploration: Exploration,
    /// Basic-AF rotation order for multi/advanced multi.
    pub af_order: [Acq; 3],
    /// Initial sample size (the paper uses 20 of the 220-eval budget).
    pub init_samples: usize,
    pub init_sampling: InitialSampling,
    /// Duplicate/score strikes before an AF is skipped.
    pub skip_threshold: usize,
    /// Relative score margin for skip/promote in advanced multi.
    pub improvement_factor: f64,
    /// Discount factor of the discounted-observation score.
    pub discount: f64,
    /// Prune candidates that neighbor observed-invalid configurations.
    pub pruning: bool,
    /// Worker threads for the sharded GP hot path (0 = auto: one per
    /// available core, capped by the shard count; 1 = fully serial). The
    /// evaluation sequence is identical for every value — enforced by the
    /// engine's determinism tests.
    pub threads: usize,
    /// Candidates per GP shard tile (0 = auto: `gp::DEFAULT_SHARD_LEN`).
    /// Like `threads`, affects performance only, never results.
    pub shard_len: usize,
    /// Batch ask/tell mode: each driver step proposes *every* distinct
    /// per-acquisition argmin from the fused `predict_scored` sweep
    /// (instead of only the policy's pick), letting the drive loop
    /// evaluate a whole batch — in parallel on a `ShardPool` if it has
    /// one. Off by default: batch runs trade per-step surrogate updates
    /// for throughput, so their traces differ from the paper's
    /// sequential protocol.
    pub batch_ask: bool,
}

impl BoConfig {
    /// Table I defaults with a single acquisition function.
    pub fn single(acq: Acq) -> BoConfig {
        BoConfig {
            // Matérn ν=3/2 with lengthscale 1.5 under contextual variance
            // (Table I: "Covariance function lengthscale (CV): 3/2, 1.5").
            cov: CovFn::Matern32 { lengthscale: 1.5 },
            noise: 1e-6,
            acq: AcqPolicyKind::Single(acq),
            exploration: Exploration::ContextualVariance,
            af_order: [Acq::Ei, Acq::Poi, Acq::Lcb],
            init_samples: 20,
            init_sampling: InitialSampling::Maximin,
            skip_threshold: 5,
            improvement_factor: 0.1,
            discount: 0.65,
            pruning: true,
            threads: 0,
            shard_len: 0,
            batch_ask: false,
        }
    }

    /// Table I defaults for the `multi` meta-acquisition function.
    pub fn multi() -> BoConfig {
        BoConfig { acq: AcqPolicyKind::Multi, discount: 0.65, ..BoConfig::single(Acq::Ei) }
    }

    /// Table I defaults for `advanced multi`.
    pub fn advanced_multi() -> BoConfig {
        BoConfig { acq: AcqPolicyKind::AdvancedMulti, discount: 0.75, ..BoConfig::single(Acq::Ei) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_i_defaults() {
        let c = BoConfig::advanced_multi();
        assert_eq!(c.skip_threshold, 5);
        assert!((c.improvement_factor - 0.1).abs() < 1e-12);
        assert!((c.discount - 0.75).abs() < 1e-12);
        assert_eq!(c.init_samples, 20);
        assert_eq!(c.init_sampling, InitialSampling::Maximin);
        assert!(c.pruning);
        assert_eq!(c.af_order, [Acq::Ei, Acq::Poi, Acq::Lcb]);
        assert_eq!(c.exploration, Exploration::ContextualVariance);
        assert_eq!(c.cov.name(), "matern32");
        assert!((BoConfig::multi().discount - 0.65).abs() < 1e-12);
    }
}
