//! Basic acquisition functions (§III-C), minimization variants.
//!
//! Scores are "lower is better": the engine picks the arg-min over
//! candidates. Inputs are in *normalized observation units* (the engine
//! z-scores y before fitting), so the exploration factor λ is scale-free —
//! exactly the problem the paper's contextual variance solves for raw
//! observation scales.

use crate::bo::config::Acq;

/// Standard normal PDF.
#[inline]
pub fn phi(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Error function (Abramowitz & Stegun 7.1.26, |ε| ≤ 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
#[inline]
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Score one candidate under an acquisition function. `f_best` is the best
/// (lowest) observation so far; `lambda` the exploration factor.
#[inline]
pub fn score(acq: Acq, mu: f64, var: f64, f_best: f64, lambda: f64) -> f64 {
    let sigma = var.max(1e-12).sqrt();
    match acq {
        Acq::Ei => {
            // Minimization EI: E[max(f_best − g(x) − ξ, 0)], negated.
            let imp = f_best - mu - lambda;
            let z = imp / sigma;
            -(imp * norm_cdf(z) + sigma * phi(z))
        }
        Acq::Poi => {
            // P(g(x) ≤ f_best − ξ), negated.
            -norm_cdf((f_best - mu - lambda) / sigma)
        }
        Acq::Lcb => mu - lambda * sigma,
    }
}

/// "Is `s` a better (lower) score than the incumbent `b`?" — the one
/// comparison rule shared by the reference scan, the per-shard sweep, and
/// the cross-shard reduction. NaN never beats a non-NaN score (it acts as
/// +∞ with first-index tie-breaking), which makes the fold associative:
/// chunk-local argmins combined in ascending order give exactly the
/// global scan's answer for *any* partition, NaNs included.
#[inline]
fn better(s: f64, b: f64) -> bool {
    s < b || (b.is_nan() && !s.is_nan())
}

/// Arg-min of `score` over candidate predictions, skipping masked entries.
/// Returns the position within the candidate arrays.
///
/// This is the *reference* composition; the engine's hot path runs
/// [`score_chunk`] per shard + [`reduce_shard_argmins`] instead, which
/// reproduce it exactly (property-tested in `tests/properties.rs`).
pub fn argmin_score(acq: Acq, mu: &[f64], var: &[f64], f_best: f64, lambda: f64, masked: &[bool]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for i in 0..mu.len() {
        if masked[i] {
            continue;
        }
        let s = score(acq, mu[i], var[i], f_best, lambda);
        if best.map_or(true, |(_, b)| better(s, b)) {
            best = Some((i, s));
        }
    }
    best.map(|(i, _)| i)
}

/// One fused shard sweep: for each acquisition function in `afs`, the
/// running (global index, score) argmin over this chunk, skipping masked
/// candidates. `offset` is the chunk's first global candidate index.
/// Ascending scan with the shared `better` rule keeps the lowest index
/// on ties and rejects NaN scores; composed with
/// [`reduce_shard_argmins`] this reproduces [`argmin_score`] exactly for
/// any chunk partition.
pub fn score_chunk(
    afs: &[Acq],
    mu: &[f64],
    var: &[f64],
    masked: &[bool],
    offset: usize,
    f_best: f64,
    lambda: f64,
) -> Vec<Option<(usize, f64)>> {
    debug_assert!(mu.len() == var.len() && mu.len() == masked.len());
    let mut best: Vec<Option<(usize, f64)>> = vec![None; afs.len()];
    for j in 0..mu.len() {
        if masked[j] {
            continue;
        }
        for (a, b) in afs.iter().zip(best.iter_mut()) {
            let s = score(*a, mu[j], var[j], f_best, lambda);
            if b.map_or(true, |(_, bs)| better(s, bs)) {
                *b = Some((offset + j, s));
            }
        }
    }
    best
}

/// Reduce per-shard fused argmins (in ascending shard order) into one
/// global argmin per acquisition function. The shared `better` rule ⇒
/// lowest-index tie-breaking and NaN-as-+∞, independent of the shard
/// partition and thread count.
pub fn reduce_shard_argmins(shards: &[Vec<Option<(usize, f64)>>], n_afs: usize) -> Vec<Option<usize>> {
    let mut best: Vec<Option<(usize, f64)>> = vec![None; n_afs];
    for part in shards {
        debug_assert_eq!(part.len(), n_afs);
        for (b, p) in best.iter_mut().zip(part) {
            if let Some((idx, s)) = p {
                if b.map_or(true, |(_, bs)| better(*s, bs)) {
                    *b = Some((*idx, *s));
                }
            }
        }
    }
    best.into_iter().map(|b| b.map(|(i, _)| i)).collect()
}

/// Fixed-point scale (2⁶⁴) for the deterministic posterior-variance
/// reduction. Per-candidate variances convert to u128 fixed point so the
/// cross-shard sum is an *integer* sum — associative, hence bit-identical
/// for every shard partition and thread count (an f64 partial-sum tree
/// would shift with the shard boundaries). Resolution 2⁻⁶⁴ keeps ~2⁻²⁴
/// relative accuracy even at the 1e-12 variance floor — far below the
/// GP's jitter.
pub const VAR_FP_SCALE: f64 = 18446744073709551616.0; // 2^64

/// Convert one posterior variance to fixed point. Clamped to [0, 1e6] —
/// far beyond any sane GP posterior — so even a million-candidate sum
/// stays below 2¹²⁸.
#[inline]
pub fn var_to_fp(v: f64) -> u128 {
    (v.clamp(0.0, 1e6) * VAR_FP_SCALE) as u128
}

/// Fixed-point sum back to f64 (one deterministic rounding).
#[inline]
pub fn var_from_fp(sum: u128) -> f64 {
    sum as f64 / VAR_FP_SCALE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // erf(0)=0, erf(1)≈0.8427, erf(−1)≈−0.8427, erf(2)≈0.9953.
        assert!(erf(0.0).abs() < 1.5e-7); // A&S 7.1.26 approximation error
        assert!((erf(1.0) - 0.8427008).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427008).abs() < 1e-6);
        assert!((erf(2.0) - 0.9953223).abs() < 1e-6);
    }

    #[test]
    fn cdf_properties() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!(norm_cdf(-5.0) < 1e-5);
        assert!(norm_cdf(5.0) > 1.0 - 1e-5);
        // Symmetry.
        assert!((norm_cdf(1.3) + norm_cdf(-1.3) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn ei_prefers_lower_mean_same_variance() {
        let s_low = score(Acq::Ei, 0.5, 0.1, 1.0, 0.0);
        let s_high = score(Acq::Ei, 0.9, 0.1, 1.0, 0.0);
        assert!(s_low < s_high);
    }

    #[test]
    fn ei_prefers_higher_variance_same_mean() {
        let s_sure = score(Acq::Ei, 1.0, 0.01, 1.0, 0.0);
        let s_unsure = score(Acq::Ei, 1.0, 1.0, 1.0, 0.0);
        assert!(s_unsure < s_sure);
    }

    #[test]
    fn poi_is_probability_like() {
        let s = -score(Acq::Poi, 0.0, 1.0, 1.0, 0.0);
        assert!(s > 0.5 && s <= 1.0, "P(improve)={s}");
    }

    #[test]
    fn lcb_lambda_increases_exploration() {
        // With λ=0 LCB is pure exploitation (mean); candidate A (low mean,
        // low var) wins. With large λ candidate B (high var) wins.
        let a = (0.5, 0.01);
        let b = (0.8, 1.0);
        assert!(score(Acq::Lcb, a.0, a.1, 0.0, 0.0) < score(Acq::Lcb, b.0, b.1, 0.0, 0.0));
        assert!(score(Acq::Lcb, b.0, b.1, 0.0, 2.0) < score(Acq::Lcb, a.0, a.1, 0.0, 2.0));
    }

    #[test]
    fn argmin_respects_mask() {
        let mu = [0.1, 0.0, 0.5];
        let var = [0.1, 0.1, 0.1];
        let mask = [false, true, false];
        let i = argmin_score(Acq::Lcb, &mu, &var, 1.0, 0.0, &mask).unwrap();
        assert_eq!(i, 0, "index 1 is masked even though its score is best");
        assert!(argmin_score(Acq::Lcb, &mu, &var, 1.0, 0.0, &[true, true, true]).is_none());
    }

    #[test]
    fn chunked_argmin_matches_reference_and_breaks_ties_low() {
        let afs = [Acq::Ei, Acq::Poi, Acq::Lcb];
        // Deliberate exact tie between indices 1 and 4 (identical inputs).
        let mu = [0.9, 0.2, 0.7, 0.5, 0.2, 0.6];
        let var = [0.1, 0.3, 0.2, 0.1, 0.3, 0.4];
        let masked = [false, false, true, false, false, false];
        for chunk in 1..=mu.len() {
            let mut parts = Vec::new();
            let mut start = 0;
            while start < mu.len() {
                let end = (start + chunk).min(mu.len());
                parts.push(score_chunk(&afs, &mu[start..end], &var[start..end], &masked[start..end], start, 0.4, 0.05));
                start = end;
            }
            let fused = reduce_shard_argmins(&parts, afs.len());
            for (i, acq) in afs.iter().enumerate() {
                let reference = argmin_score(*acq, &mu, &var, 0.4, 0.05, &masked);
                assert_eq!(fused[i], reference, "{acq:?} diverged at chunk={chunk}");
            }
        }
    }

    #[test]
    fn nan_scores_never_shadow_finite_ones_under_any_partition() {
        // mu = +∞ makes EI's score NaN (-∞·0). Reference and every chunk
        // partition must agree on the finite winner, even when the NaN
        // lands first in a chunk.
        let afs = [Acq::Ei];
        let mu = [0.5, f64::INFINITY, 0.3, f64::INFINITY];
        let var = [0.1, 0.1, 0.1, 0.1];
        let masked = [false; 4];
        let reference = argmin_score(Acq::Ei, &mu, &var, 0.0, 0.0, &masked);
        assert_eq!(reference, Some(2), "finite best must win over NaN scores");
        for chunk in 1..=4 {
            let mut parts = Vec::new();
            let mut start = 0;
            while start < mu.len() {
                let end = (start + chunk).min(mu.len());
                parts.push(score_chunk(&afs, &mu[start..end], &var[start..end], &masked[start..end], start, 0.0, 0.0));
                start = end;
            }
            assert_eq!(reduce_shard_argmins(&parts, 1), vec![reference], "chunk={chunk}");
        }
        // All-NaN input: the first index is still reported (not None).
        let all_inf = [f64::INFINITY, f64::INFINITY];
        assert_eq!(argmin_score(Acq::Ei, &all_inf, &var[..2], 0.0, 0.0, &masked[..2]), Some(0));
    }

    #[test]
    fn chunked_argmin_all_masked_is_none() {
        let afs = [Acq::Ei];
        let parts = vec![
            score_chunk(&afs, &[1.0, 2.0], &[0.1, 0.1], &[true, true], 0, 0.0, 0.0),
            score_chunk(&afs, &[3.0], &[0.1], &[true], 2, 0.0, 0.0),
        ];
        assert_eq!(reduce_shard_argmins(&parts, 1), vec![None]);
    }

    #[test]
    fn var_fixed_point_roundtrip_and_associativity() {
        let vals = [1e-12, 0.25, 0.999999, 1.0, 2.0];
        for &v in &vals {
            let back = var_from_fp(var_to_fp(v));
            assert!((back - v).abs() <= v * 1e-9 + 1e-18, "{v} -> {back}");
        }
        // The whole point: the sum is independent of the partition.
        let seq: u128 = vals.iter().map(|&v| var_to_fp(v)).sum();
        let split = (var_to_fp(vals[0]) + var_to_fp(vals[1]))
            + (var_to_fp(vals[2]) + (var_to_fp(vals[3]) + var_to_fp(vals[4])));
        assert_eq!(seq, split);
        // Out-of-range inputs stay finite and deterministic.
        assert_eq!(var_to_fp(-1.0), 0);
        assert_eq!(var_to_fp(f64::NAN), 0);
        assert_eq!(var_to_fp(f64::INFINITY), var_to_fp(1e6));
    }
}
