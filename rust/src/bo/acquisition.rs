//! Basic acquisition functions (§III-C), minimization variants.
//!
//! Scores are "lower is better": the engine picks the arg-min over
//! candidates. Inputs are in *normalized observation units* (the engine
//! z-scores y before fitting), so the exploration factor λ is scale-free —
//! exactly the problem the paper's contextual variance solves for raw
//! observation scales.

use crate::bo::config::Acq;

/// Standard normal PDF.
#[inline]
pub fn phi(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Error function (Abramowitz & Stegun 7.1.26, |ε| ≤ 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
#[inline]
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Score one candidate under an acquisition function. `f_best` is the best
/// (lowest) observation so far; `lambda` the exploration factor.
#[inline]
pub fn score(acq: Acq, mu: f64, var: f64, f_best: f64, lambda: f64) -> f64 {
    let sigma = var.max(1e-12).sqrt();
    match acq {
        Acq::Ei => {
            // Minimization EI: E[max(f_best − g(x) − ξ, 0)], negated.
            let imp = f_best - mu - lambda;
            let z = imp / sigma;
            -(imp * norm_cdf(z) + sigma * phi(z))
        }
        Acq::Poi => {
            // P(g(x) ≤ f_best − ξ), negated.
            -norm_cdf((f_best - mu - lambda) / sigma)
        }
        Acq::Lcb => mu - lambda * sigma,
    }
}

/// Arg-min of `score` over candidate predictions, skipping masked entries.
/// Returns the position within the candidate arrays.
pub fn argmin_score(acq: Acq, mu: &[f64], var: &[f64], f_best: f64, lambda: f64, masked: &[bool]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for i in 0..mu.len() {
        if masked[i] {
            continue;
        }
        let s = score(acq, mu[i], var[i], f_best, lambda);
        if best.map_or(true, |(_, b)| s < b) {
            best = Some((i, s));
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // erf(0)=0, erf(1)≈0.8427, erf(−1)≈−0.8427, erf(2)≈0.9953.
        assert!(erf(0.0).abs() < 1.5e-7); // A&S 7.1.26 approximation error
        assert!((erf(1.0) - 0.8427008).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427008).abs() < 1e-6);
        assert!((erf(2.0) - 0.9953223).abs() < 1e-6);
    }

    #[test]
    fn cdf_properties() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!(norm_cdf(-5.0) < 1e-5);
        assert!(norm_cdf(5.0) > 1.0 - 1e-5);
        // Symmetry.
        assert!((norm_cdf(1.3) + norm_cdf(-1.3) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn ei_prefers_lower_mean_same_variance() {
        let s_low = score(Acq::Ei, 0.5, 0.1, 1.0, 0.0);
        let s_high = score(Acq::Ei, 0.9, 0.1, 1.0, 0.0);
        assert!(s_low < s_high);
    }

    #[test]
    fn ei_prefers_higher_variance_same_mean() {
        let s_sure = score(Acq::Ei, 1.0, 0.01, 1.0, 0.0);
        let s_unsure = score(Acq::Ei, 1.0, 1.0, 1.0, 0.0);
        assert!(s_unsure < s_sure);
    }

    #[test]
    fn poi_is_probability_like() {
        let s = -score(Acq::Poi, 0.0, 1.0, 1.0, 0.0);
        assert!(s > 0.5 && s <= 1.0, "P(improve)={s}");
    }

    #[test]
    fn lcb_lambda_increases_exploration() {
        // With λ=0 LCB is pure exploitation (mean); candidate A (low mean,
        // low var) wins. With large λ candidate B (high var) wins.
        let a = (0.5, 0.01);
        let b = (0.8, 1.0);
        assert!(score(Acq::Lcb, a.0, a.1, 0.0, 0.0) < score(Acq::Lcb, b.0, b.1, 0.0, 0.0));
        assert!(score(Acq::Lcb, b.0, b.1, 0.0, 2.0) < score(Acq::Lcb, a.0, a.1, 0.0, 2.0));
    }

    #[test]
    fn argmin_respects_mask() {
        let mu = [0.1, 0.0, 0.5];
        let var = [0.1, 0.1, 0.1];
        let mask = [false, true, false];
        let i = argmin_score(Acq::Lcb, &mu, &var, 1.0, 0.0, &mask).unwrap();
        assert_eq!(i, 0, "index 1 is masked even though its score is best");
        assert!(argmin_score(Acq::Lcb, &mu, &var, 1.0, 0.0, &[true, true, true]).is_none());
    }
}
