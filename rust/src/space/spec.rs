//! Declarative search-space specification: typed parameters plus
//! [`Expr`]-DSL restrictions, as *data*.
//!
//! A [`SpaceSpec`] is the serializable twin of a hand-coded
//! `(params, restrictions)` pair: it builds through a fluent builder API,
//! round-trips losslessly through JSON (`util::json` / `util::jsonparse`
//! — no serde in the vendor set), and materializes into a columnar
//! [`SearchSpace`] serially ([`SpaceSpec::build`]) or shard-parallel on a
//! [`ShardPool`] ([`SpaceSpec::build_par`]). Benchmark-suite practice
//! (arXiv:2210.01465, arXiv:2203.13577) runs many kernels × devices ×
//! spaces defined as files; this is the type those files parse into, and
//! what `ktbo sweep/tune --space <file.json>` consumes.
//!
//! # JSON schema
//!
//! ```json
//! {
//!   "name": "gemm",
//!   "params": [
//!     {"name": "MWG", "values": [16, 32, 64, 128]},
//!     {"name": "SA", "values": [false, true]},
//!     {"name": "method", "values": ["scan", "tree"]}
//!   ],
//!   "restrictions": [
//!     {"expr": {"op": "eq", "args": [
//!       {"op": "rem", "args": [{"var": "KWG"}, {"var": "KWI"}]},
//!       {"lit": 0}]}},
//!     {"name": "optional label", "expr": {"...": "..."}}
//!   ]
//! }
//! ```
//!
//! Values are numbers (integers stay integers, others parse as floats),
//! booleans, or strings (categoricals); value *order* is meaningful
//! (§III-D1 — normalization is linear in the index). See
//! [`Expr::to_json`] for the expression grammar.

use std::path::Path;

use crate::space::constraint::{Expr, Restriction};
use crate::space::param::{PValue, Param};
use crate::space::space::SearchSpace;
use crate::util::json::Json;
use crate::util::jsonparse;
use crate::util::pool::ShardPool;

/// One declared parameter: a name plus its ordered value domain.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub values: Vec<PValue>,
}

/// A named restriction: an optional label plus the predicate expression.
#[derive(Clone, Debug, PartialEq)]
pub struct RestrictionSpec {
    /// Display name; defaults to the expression's rendering.
    pub name: String,
    pub expr: Expr,
}

/// Declarative search-space specification (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct SpaceSpec {
    pub name: String,
    params: Vec<ParamSpec>,
    restrictions: Vec<RestrictionSpec>,
}

impl SpaceSpec {
    pub fn new(name: &str) -> SpaceSpec {
        SpaceSpec { name: name.to_string(), params: Vec::new(), restrictions: Vec::new() }
    }

    fn param(mut self, name: &str, values: Vec<PValue>) -> SpaceSpec {
        assert!(
            !self.params.iter().any(|p| p.name == name),
            "space '{}' declares parameter '{name}' twice",
            self.name
        );
        self.params.push(ParamSpec { name: name.to_string(), values });
        self
    }

    /// Integer parameter with the given ordered domain.
    pub fn ints(self, name: &str, values: &[i64]) -> SpaceSpec {
        self.param(name, values.iter().map(|&v| PValue::Int(v)).collect())
    }

    pub fn floats(self, name: &str, values: &[f64]) -> SpaceSpec {
        self.param(name, values.iter().map(|&v| PValue::Float(v)).collect())
    }

    /// Boolean parameter with domain `[false, true]`.
    pub fn bools(self, name: &str) -> SpaceSpec {
        self.param(name, vec![PValue::Bool(false), PValue::Bool(true)])
    }

    pub fn cats(self, name: &str, values: &[&'static str]) -> SpaceSpec {
        self.param(name, values.iter().map(|&v| PValue::Str(v)).collect())
    }

    /// Add a restriction named by the expression's rendering.
    pub fn restrict(mut self, e: Expr) -> SpaceSpec {
        self.restrictions.push(RestrictionSpec { name: e.to_string(), expr: e });
        self
    }

    /// Add a restriction with an explicit display name.
    pub fn restrict_named(mut self, name: &str, e: Expr) -> SpaceSpec {
        self.restrictions.push(RestrictionSpec { name: name.to_string(), expr: e });
        self
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn n_restrictions(&self) -> usize {
        self.restrictions.len()
    }

    /// Materialize the declared parameters.
    pub fn params(&self) -> Vec<Param> {
        self.params
            .iter()
            .map(|p| Param { name: p.name.clone(), values: p.values.clone() })
            .collect()
    }

    /// Materialize the declared restrictions (all expression-backed, so
    /// the enumerator can prune at the deepest bound prefix).
    pub fn restrictions(&self) -> Vec<Restriction> {
        self.restrictions
            .iter()
            .map(|r| Restriction::named_expr(&r.name, r.expr.clone()))
            .collect()
    }

    /// Enumerate the restricted space serially.
    pub fn build(&self) -> SearchSpace {
        SearchSpace::build(&self.name, self.params(), &self.restrictions())
    }

    /// Enumerate the restricted space shard-parallel on `pool`. The
    /// result — including config order — is bit-identical to [`build`](Self::build).
    pub fn build_par(&self, pool: &ShardPool) -> SearchSpace {
        SearchSpace::build_par(&self.name, self.params(), &self.restrictions(), pool)
    }

    pub fn to_json(&self) -> Json {
        let params: Vec<Json> = self
            .params
            .iter()
            .map(|p| {
                let values: Vec<Json> = p
                    .values
                    .iter()
                    .map(|v| match v {
                        PValue::Int(x) => {
                            assert!(
                                x.abs() <= crate::space::constraint::MAX_JSON_INT,
                                "parameter '{}': value {x} exceeds the JSON-exact integer range (±2^53)",
                                p.name
                            );
                            Json::Num(*x as f64)
                        }
                        PValue::Float(x) => Json::Num(*x),
                        PValue::Bool(b) => Json::Bool(*b),
                        PValue::Str(s) => Json::Str((*s).to_string()),
                    })
                    .collect();
                Json::obj().set("name", p.name.as_str()).set("values", Json::Arr(values))
            })
            .collect();
        let restrictions: Vec<Json> = self
            .restrictions
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                // The default name is derived from the expression; only a
                // custom label needs to be carried.
                if r.name != r.expr.to_string() {
                    o = o.set("name", r.name.as_str());
                }
                o.set("expr", r.expr.to_json())
            })
            .collect();
        Json::obj()
            .set("name", self.name.as_str())
            .set("params", Json::Arr(params))
            .set("restrictions", Json::Arr(restrictions))
    }

    pub fn from_json(j: &Json) -> Result<SpaceSpec, String> {
        reject_unknown_keys(j, "space spec", &["name", "params", "restrictions"])?;
        let name = require_str(j, "name", "space spec")?;
        let params_json = require_arr(j, "params", "space spec")?;
        if params_json.is_empty() {
            return Err("space spec declares no parameters ('params' is empty)".into());
        }
        let mut spec = SpaceSpec::new(name);
        for (pi, pj) in params_json.iter().enumerate() {
            let at = format!("params[{pi}]");
            reject_unknown_keys(pj, &at, &["name", "values"])?;
            let pname = require_str(pj, "name", &at)?;
            let values_json = require_arr(pj, "values", &at)?;
            if values_json.is_empty() {
                return Err(format!("{at}: parameter '{pname}' has an empty domain"));
            }
            let values: Vec<PValue> = values_json
                .iter()
                .map(|v| match v {
                    Json::Num(x) if *x == x.trunc() => {
                        if x.abs() > crate::space::constraint::MAX_JSON_INT as f64 {
                            return Err(format!(
                                "parameter '{pname}': value {x} exceeds the JSON-exact \
                                 integer range (±2^53)"
                            ));
                        }
                        Ok(PValue::Int(*x as i64))
                    }
                    Json::Num(x) => Ok(PValue::Float(*x)),
                    Json::Bool(b) => Ok(PValue::Bool(*b)),
                    // PValue::Str holds &'static str; spec strings get
                    // leaked once per load (bounded, same policy as the
                    // simulation-mode cache importer).
                    Json::Str(s) => Ok(PValue::Str(Box::leak(s.clone().into_boxed_str()))),
                    other => Err(format!(
                        "{at}: parameter '{pname}' has an unsupported value {} \
                         (expected number, bool, or string)",
                        other.render()
                    )),
                })
                .collect::<Result<_, _>>()?;
            if spec.params.iter().any(|p| p.name == pname) {
                return Err(format!("{at}: parameter '{pname}' declared twice"));
            }
            spec.params.push(ParamSpec { name: pname.to_string(), values });
        }
        if let Some(rs) = j.get("restrictions") {
            let rs = rs
                .as_arr()
                .ok_or_else(|| wrong_type_msg(rs, "restrictions", "space spec", "an array"))?;
            for (ri, rj) in rs.iter().enumerate() {
                let at = format!("restrictions[{ri}]");
                reject_unknown_keys(rj, &at, &["name", "expr"])?;
                let expr_json = rj.get("expr").ok_or_else(|| format!("{at}: missing 'expr'"))?;
                let expr = Expr::from_json(expr_json).map_err(|e| format!("{at}: {e}"))?;
                let name = rj
                    .get("name")
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .unwrap_or_else(|| expr.to_string());
                // Surface unknown-parameter typos at parse time, not
                // deep inside enumeration.
                let mut vars = Vec::new();
                expr.collect_vars(&mut vars);
                for v in &vars {
                    if !spec.params.iter().any(|p| &p.name == v) {
                        return Err(format!(
                            "{at}: restriction '{name}' references unknown parameter '{v}'"
                        ));
                    }
                }
                spec.restrictions.push(RestrictionSpec { name, expr });
            }
        }
        Ok(spec)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<SpaceSpec, String> {
        SpaceSpec::from_json(&jsonparse::parse(text)?)
    }

    /// Load from a `.json` file. Every error — unreadable file, truncated
    /// JSON, schema violation — names the file, so a failing
    /// `--space <file>` run points straight at the offending spec.
    pub fn load(path: &Path) -> Result<SpaceSpec, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        SpaceSpec::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Cartesian product of the declared domains (before restriction
    /// pruning), computed without enumerating anything — the number the
    /// session layer compares against the lazy-space cutoff. Saturates
    /// at `u128::MAX`.
    pub fn cartesian_size(&self) -> u128 {
        self.params.iter().fold(1u128, |acc, p| acc.saturating_mul(p.values.len() as u128))
    }
}

/// Error for a present-but-mistyped field, naming what was found.
fn wrong_type_msg(found: &Json, key: &str, ctx: &str, want: &str) -> String {
    let kind = match found {
        Json::Null => "null",
        Json::Bool(_) => "a bool",
        Json::Num(_) => "a number",
        Json::Str(_) => "a string",
        Json::Arr(_) => "an array",
        Json::Obj(_) => "an object",
    };
    format!("{ctx}: '{key}' must be {want}, got {kind}")
}

fn require_str<'j>(j: &'j Json, key: &str, ctx: &str) -> Result<&'j str, String> {
    match j.get(key) {
        None => Err(format!("{ctx}: missing '{key}'")),
        Some(v) => v.as_str().ok_or_else(|| wrong_type_msg(v, key, ctx, "a string")),
    }
}

fn require_arr<'j>(j: &'j Json, key: &str, ctx: &str) -> Result<&'j [Json], String> {
    match j.get(key) {
        None => Err(format!("{ctx}: missing '{key}'")),
        Some(v) => v.as_arr().ok_or_else(|| wrong_type_msg(v, key, ctx, "an array")),
    }
}

/// Reject misspelled/unknown keys instead of silently ignoring them — a
/// typo like `"restictions"` would otherwise drop every constraint and
/// quietly multiply the space.
fn reject_unknown_keys(j: &Json, ctx: &str, allowed: &[&str]) -> Result<(), String> {
    let Json::Obj(kv) = j else {
        return Err(format!("{ctx}: expected an object"));
    };
    for (k, _) in kv {
        if !allowed.contains(&k.as_str()) {
            return Err(format!(
                "{ctx}: unknown field '{k}' (allowed: {})",
                allowed.join(", ")
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::constraint::Expr;
    use crate::util::proptest::{check, Config};
    use crate::util::rng::Rng;

    fn toy_spec() -> SpaceSpec {
        SpaceSpec::new("toy")
            .ints("bx", &[16, 32, 64])
            .ints("tile", &[1, 2, 4, 8])
            .bools("pad")
            .restrict_named(
                "bx*tile<=128",
                Expr::var("bx").mul(Expr::var("tile")).le(Expr::lit(128)),
            )
    }

    #[test]
    fn builder_builds_the_hand_coded_space() {
        // Same space as space::tests::small_space: 18 of 24 survive.
        let s = toy_spec().build();
        assert_eq!(s.name, "toy");
        assert_eq!(s.cartesian_size, 24);
        assert_eq!(s.len(), 18);
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let spec = toy_spec();
        let text = spec.to_json().render_pretty();
        let parsed = SpaceSpec::parse(&text).unwrap();
        assert_eq!(parsed, spec);
        // And the parsed spec builds the identical space.
        let a = spec.build();
        let b = parsed.build();
        assert_eq!(a.len(), b.len());
        for i in 0..a.len() {
            assert_eq!(a.config(i), b.config(i));
        }
    }

    #[test]
    fn custom_restriction_names_survive_roundtrip() {
        let spec = toy_spec();
        let parsed = SpaceSpec::parse(&spec.to_json().render()).unwrap();
        assert_eq!(parsed.restrictions()[0].name, "bx*tile<=128");
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            r#"{"params": [{"name": "a", "values": [1]}]}"#,
            r#"{"name": "x", "params": []}"#,
            r#"{"name": "x", "params": [{"name": "a", "values": []}]}"#,
            r#"{"name": "x", "params": [{"name": "a", "values": [1]}, {"name": "a", "values": [2]}]}"#,
            r#"{"name": "x", "params": [{"name": "a", "values": [1]}], "restrictions": [{}]}"#,
            r#"{"name": "x", "params": [{"name": "a", "values": [1]}],
                "restrictions": [{"expr": {"op": "gt", "args": [{"var": "typo"}, {"lit": 0}]}}]}"#,
        ] {
            assert!(SpaceSpec::parse(bad).is_err(), "accepted {bad}");
        }
    }

    #[test]
    #[should_panic(expected = "twice")]
    fn builder_rejects_duplicate_params() {
        let _ = SpaceSpec::new("dup").ints("a", &[1]).ints("a", &[2]);
    }

    /// Errors name the offending key and position, not just "parse error".
    #[test]
    fn malformed_specs_report_key_and_path() {
        let cases: &[(&str, &str)] = &[
            // Wrong-typed fields.
            (r#"{"name": 7, "params": [{"name": "a", "values": [1]}]}"#, "'name' must be a string, got a number"),
            (r#"{"name": "x", "params": {"name": "a"}}"#, "'params' must be an array, got an object"),
            (r#"{"name": "x", "params": [{"name": "a", "values": 3}]}"#, "params[0]: 'values' must be an array, got a number"),
            (r#"{"name": "x", "params": [{"name": "a", "values": [1]}], "restrictions": true}"#, "'restrictions' must be an array, got a bool"),
            // Unknown fields are rejected, not silently dropped.
            (r#"{"name": "x", "params": [{"name": "a", "values": [1]}], "restictions": []}"#, "unknown field 'restictions'"),
            (r#"{"name": "x", "params": [{"name": "a", "values": [1], "vals": []}]}"#, "params[0]: unknown field 'vals'"),
            (r#"{"name": "x", "params": [{"name": "a", "values": [1]}], "restrictions": [{"exp": {"lit": 1}}]}"#, "restrictions[0]: unknown field 'exp'"),
            // Position context on deeper errors.
            (r#"{"name": "x", "params": [{"name": "a", "values": [1]}, {"values": [2]}]}"#, "params[1]: missing 'name'"),
            (r#"{"name": "x", "params": [{"name": "a", "values": [null]}]}"#, "params[0]: parameter 'a' has an unsupported value null"),
            (r#"{"name": "x", "params": [{"name": "a", "values": [1]}], "restrictions": [{"expr": {"op": "gt", "args": [{"var": "typo"}, {"lit": 0}]}}]}"#, "restrictions[0]: restriction"),
        ];
        for (text, want) in cases {
            let err = SpaceSpec::parse(text).expect_err(&format!("accepted {text}"));
            assert!(err.contains(want), "error for {text} must contain '{want}', got: {err}");
        }
    }

    /// Truncated / unreadable / malformed files all name the file.
    #[test]
    fn load_errors_name_the_file() {
        let dir = std::env::temp_dir().join("ktbo-specload-test");
        std::fs::create_dir_all(&dir).unwrap();

        let truncated = dir.join("truncated.json");
        std::fs::write(&truncated, r#"{"name": "x", "params": [{"name": "a", "va"#).unwrap();
        let err = SpaceSpec::load(&truncated).unwrap_err();
        assert!(err.contains("truncated.json"), "must name the file: {err}");

        let wrong = dir.join("wrong-typed.json");
        std::fs::write(&wrong, r#"{"name": "x", "params": [{"name": "a", "values": 3}]}"#).unwrap();
        let err = SpaceSpec::load(&wrong).unwrap_err();
        assert!(err.contains("wrong-typed.json") && err.contains("params[0]"), "{err}");

        let err = SpaceSpec::load(&dir.join("does-not-exist.json")).unwrap_err();
        assert!(err.contains("does-not-exist.json"), "{err}");
    }

    #[test]
    fn cartesian_size_without_building() {
        assert_eq!(toy_spec().cartesian_size(), 24);
        // A spec far beyond enumerability still answers instantly.
        let mut spec = SpaceSpec::new("huge");
        let vals: Vec<i64> = (0..1000).collect();
        for d in 0..5 {
            spec = spec.ints(&format!("p{d}"), &vals);
        }
        assert_eq!(spec.cartesian_size(), 10u128.pow(15));
    }

    #[test]
    fn mixed_value_types_roundtrip() {
        let spec = SpaceSpec::new("mixed")
            .ints("n", &[1, 2])
            .floats("scale", &[0.5, 1.25])
            .bools("flag")
            .cats("method", &["scan", "tree"])
            .restrict(Expr::streq("method", "tree").or(Expr::var("flag").eq(Expr::lit(0))));
        let parsed = SpaceSpec::parse(&spec.to_json().render()).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(parsed.build().len(), spec.build().len());
    }

    /// Random spec generator for the round-trip property.
    fn random_spec(rng: &mut Rng) -> SpaceSpec {
        let n_params = 1 + rng.below(4);
        let mut spec = SpaceSpec::new(&format!("prop-{}", rng.below(1000)));
        let mut int_params = Vec::new();
        for d in 0..n_params {
            let name = format!("p{d}");
            match rng.below(3) {
                0 => {
                    let k = 2 + rng.below(5) as i64;
                    spec = spec.ints(&name, &(1..=k).map(|v| v * (1 + rng.below(4) as i64)).collect::<Vec<_>>());
                    int_params.push(name);
                }
                1 => {
                    spec = spec.bools(&name);
                    int_params.push(name);
                }
                _ => {
                    // Non-integral values only: an integral float (1.0)
                    // renders as "1" and would parse back as an Int — the
                    // documented JSON coercion, not a round-trip defect.
                    spec = spec.floats(&name, &[0.25, 0.5, 2.75][..1 + rng.below(2)]);
                    int_params.push(name);
                }
            }
        }
        let n_restr = rng.below(3);
        for _ in 0..n_restr {
            let pick = |rng: &mut Rng, names: &[String]| Expr::var(&names[rng.below(names.len())]);
            let a = pick(rng, &int_params);
            let b = if rng.chance(0.5) { pick(rng, &int_params) } else { Expr::lit(rng.below(7) as i64) };
            let cmp = match rng.below(4) {
                0 => a.clone().mul(b.clone()).le(Expr::lit(64)),
                1 => a.clone().add(b.clone()).ne(Expr::lit(3)),
                2 => a.clone().rem(b.clone().add(Expr::lit(1))).eq(Expr::lit(0)),
                _ => a.clone().ge(b.clone()),
            };
            let e = if rng.chance(0.3) { cmp.or(pick(rng, &int_params).gt(Expr::lit(0))) } else { cmp };
            spec = if rng.chance(0.5) {
                spec.restrict(e)
            } else {
                spec.restrict_named(&format!("r{}", rng.below(100)), e)
            };
        }
        spec
    }

    #[test]
    fn prop_spec_json_roundtrips_losslessly() {
        check(
            "spec-json-roundtrip",
            &Config { cases: 60, ..Config::default() },
            random_spec,
            |spec| {
                let compact = SpaceSpec::parse(&spec.to_json().render())
                    .map_err(|e| format!("compact parse: {e}"))?;
                if &compact != spec {
                    return Err("compact render round-trip changed the spec".into());
                }
                let pretty = SpaceSpec::parse(&spec.to_json().render_pretty())
                    .map_err(|e| format!("pretty parse: {e}"))?;
                if &pretty != spec {
                    return Err("pretty render round-trip changed the spec".into());
                }
                Ok(())
            },
            |spec| format!("{} params, {} restrictions", spec.n_params(), spec.n_restrictions()),
        );
    }
}
