//! The enumerated, restricted, normalized search space (§III-D), stored
//! columnar.
//!
//! The paper's core representational choice: a *discrete* search space
//! where every parameter configuration is known up front, values are
//! normalized linearly per parameter, and the acquisition function is
//! optimized *exhaustively over the non-evaluated configurations only*.
//!
//! This module materializes that representation with a cache-friendly,
//! zero-copy layout:
//!
//! - **struct-of-arrays value indices** — one contiguous `Vec<u16>` column
//!   per dimension instead of row-wise `Vec<Vec<u16>>` configs (16× less
//!   pointer chasing, no per-config allocation);
//! - **packed mixed-radix keys** — each config folds into one `u64`
//!   (`key = Σ value_index[d] · stride[d]`, least-significant stride on
//!   the *last* dimension, so enumeration order is ascending-key order and
//!   a neighbor probe is one add/subtract away), with an alloc-free
//!   open-addressing [`index`](SearchSpace::index_of) replacing the old
//!   `HashMap<Vec<u16>, usize>` that cloned a `Vec` per lookup;
//! - **shard-aligned `f32` normalized tiles** — the normalized coordinate
//!   matrix is one `Arc<[f32]>` (row-major `len × dims`), so any
//!   contiguous candidate range is a contiguous tile slice; the GP hot
//!   path ([`IncrementalGp`](crate::gp::IncrementalGp)) and the samplers
//!   borrow it via [`norm_tiles`](SearchSpace::norm_tiles) without
//!   per-run re-normalization or copies;
//! - **constraint-propagating enumeration** — expression restrictions
//!   ([`Expr`](crate::space::constraint::Expr)) declare the dimensions
//!   they touch, so partial assignments are rejected at the deepest bound
//!   prefix instead of at the leaves, and the first dimension's value
//!   range fans out across a [`ShardPool`] ([`SearchSpace::build_par`]).
//!   Both paths visit values in odometer order, so the config ordering is
//!   identical to the seed-era serial odometer bit for bit (asserted by
//!   `gpusim::kernels` tests on all five paper kernels).

use std::sync::Arc;

use crate::space::constraint::{Assignment, Restriction, VarScope};
use crate::space::param::{PValue, Param};
use crate::util::pool::ShardPool;

/// A parameter configuration, as per-parameter value indices.
pub type Config = Vec<u16>;

/// Alloc-free open-addressing map from packed config key to position.
/// Linear probing over a power-of-two table at ≤ 50% load; lookups do no
/// hashing of heap data and no allocation (the old index hashed a
/// `Vec<u16>` clone per probe).
struct KeyIndex {
    /// (key, position) slots; `u32::MAX` position marks an empty slot.
    slots: Vec<(u64, u32)>,
    mask: usize,
}

const EMPTY_SLOT: u32 = u32::MAX;

#[inline]
fn key_hash(key: u64) -> usize {
    // Fibonacci multiplicative hash; high bits feed the mask.
    (key.wrapping_mul(0x9e37_79b9_7f4a_7c15) >> 29) as usize
}

impl KeyIndex {
    fn build(keys: &[u64]) -> KeyIndex {
        assert!(keys.len() < EMPTY_SLOT as usize, "space too large for a u32-position index");
        let cap = (keys.len().max(1) * 2).next_power_of_two();
        let mut idx = KeyIndex { slots: vec![(0, EMPTY_SLOT); cap], mask: cap - 1 };
        for (pos, &k) in keys.iter().enumerate() {
            idx.insert(k, pos as u32);
        }
        idx
    }

    fn insert(&mut self, key: u64, pos: u32) {
        let mut i = key_hash(key) & self.mask;
        loop {
            let (k, p) = self.slots[i];
            if p == EMPTY_SLOT || k == key {
                // Duplicate keys keep the last position (the old
                // HashMap-based index behaved the same on duplicate
                // configs from cache imports).
                self.slots[i] = (key, pos);
                return;
            }
            i = (i + 1) & self.mask;
        }
    }

    #[inline]
    fn get(&self, key: u64) -> Option<usize> {
        let mut i = key_hash(key) & self.mask;
        loop {
            let (k, p) = self.slots[i];
            if p == EMPTY_SLOT {
                return None;
            }
            if k == key {
                return Some(p as usize);
            }
            i = (i + 1) & self.mask;
        }
    }
}

pub struct SearchSpace {
    pub name: String,
    pub params: Vec<Param>,
    /// Struct-of-arrays value indices: `columns[d][i]` is config `i`'s
    /// value index in dimension `d`.
    columns: Vec<Vec<u16>>,
    len: usize,
    /// Mixed-radix strides: `strides[dims-1] == 1`, ascending towards
    /// dimension 0 (the odometer's most significant digit).
    strides: Vec<u64>,
    /// Packed key per config, in config order.
    keys: Vec<u64>,
    index: KeyIndex,
    /// Row-major `len × dims` normalized coordinates (the shard-aligned
    /// f32 tiles the GP borrows).
    norm: Arc<[f32]>,
    /// Size of the unrestricted Cartesian product.
    pub cartesian_size: usize,
}

/// Prefix view for constraint propagation: dimensions `>= bound` read as
/// unbound (`None`), failing any expression that touches them — which the
/// enumerator never asks, because restrictions are bucketed by their
/// deepest touched dimension.
struct PrefixScope<'a> {
    params: &'a [Param],
    cursor: &'a [u16],
    bound: usize,
}

impl VarScope for PrefixScope<'_> {
    fn int(&self, name: &str) -> Option<i64> {
        let d = self.params.iter().position(|p| p.name == name)?;
        if d >= self.bound {
            return None;
        }
        // Shared coercion: prefix pruning must agree with leaf checks.
        crate::space::constraint::pvalue_int(&self.params[d].values[self.cursor[d] as usize])
    }

    fn str_val(&self, name: &str) -> Option<&str> {
        let d = self.params.iter().position(|p| p.name == name)?;
        if d >= self.bound {
            return None;
        }
        match &self.params[d].values[self.cursor[d] as usize] {
            PValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Restrictions bucketed by check depth: entry `d` lists the restrictions
/// decidable once dimensions `0..=d` are bound. Expression restrictions
/// land at their deepest touched dimension (constraint propagation);
/// closures are opaque and land at the leaf. Shared with the lazy
/// [`view`](crate::space::view) backing, which prunes its sampling DFS
/// with the same buckets.
pub(crate) fn restriction_depths(params: &[Param], restrictions: &[Restriction]) -> Vec<Vec<usize>> {
    let dims = params.len();
    let mut at: Vec<Vec<usize>> = vec![Vec::new(); dims];
    for (ri, r) in restrictions.iter().enumerate() {
        let depth = match r.touched_dims(params) {
            Some(touched) => touched.last().copied().unwrap_or(0),
            None => dims - 1,
        };
        at[depth].push(ri);
    }
    at
}

/// Check every restriction bucketed at depth `bound - 1` against the
/// cursor prefix `cursor[..bound]`. Shared with the lazy
/// [`view`](crate::space::view) backing's sampling DFS.
pub(crate) fn prefix_passes(
    params: &[Param],
    restrictions: &[Restriction],
    checks: &[usize],
    cursor: &[u16],
    bound: usize,
) -> bool {
    if checks.is_empty() {
        return true;
    }
    let scope = PrefixScope { params, cursor, bound };
    for &ri in checks {
        let r = &restrictions[ri];
        let ok = match r.as_expr() {
            Some(e) => e.holds(&scope),
            None => {
                debug_assert_eq!(bound, params.len(), "closure restrictions check at the leaf");
                r.check(&Assignment::new(params, cursor))
            }
        };
        if !ok {
            return false;
        }
    }
    true
}

/// Depth-first enumeration over dimensions `depth..dims` (values ascend,
/// i.e. odometer order), appending surviving configs to `columns`.
fn dfs(
    params: &[Param],
    restrictions: &[Restriction],
    at: &[Vec<usize>],
    cursor: &mut [u16],
    depth: usize,
    columns: &mut [Vec<u16>],
) {
    let dims = params.len();
    for v in 0..params[depth].len() as u16 {
        cursor[depth] = v;
        if !prefix_passes(params, restrictions, &at[depth], cursor, depth + 1) {
            continue;
        }
        if depth + 1 == dims {
            for (d, col) in columns.iter_mut().enumerate() {
                col.push(cursor[d]);
            }
        } else {
            dfs(params, restrictions, at, cursor, depth + 1, columns);
        }
    }
}

/// Enumerate the restricted product into columns, optionally fanning the
/// first dimension's value range out across `pool`. Job boundaries follow
/// ascending dim-0 values and each job enumerates its subtree in odometer
/// order, so concatenation reproduces the serial order exactly.
fn enumerate_columns(
    params: &[Param],
    restrictions: &[Restriction],
    pool: Option<&ShardPool>,
) -> Vec<Vec<u16>> {
    let dims = params.len();
    let at = restriction_depths(params, restrictions);
    let radix0 = params[0].len();
    let workers = pool.map_or(0, ShardPool::threads);
    if workers == 0 || radix0 < 2 {
        let mut columns: Vec<Vec<u16>> = vec![Vec::new(); dims];
        let mut cursor = vec![0u16; dims];
        dfs(params, restrictions, &at, &mut cursor, 0, &mut columns);
        return columns;
    }

    // One job per dim-0 value chunk; ~4 chunks per worker keeps the pool
    // busy when restrictions make subtrees uneven.
    let n_jobs = (workers * 4).min(radix0);
    let mut parts: Vec<Vec<Vec<u16>>> = Vec::with_capacity(n_jobs);
    parts.resize_with(n_jobs, || vec![Vec::new(); dims]);
    {
        let at = &at;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = parts
            .iter_mut()
            .enumerate()
            .map(|(ji, slot)| {
                let lo = ji * radix0 / n_jobs;
                let hi = (ji + 1) * radix0 / n_jobs;
                Box::new(move || {
                    let mut cursor = vec![0u16; dims];
                    for v0 in lo..hi {
                        cursor[0] = v0 as u16;
                        if !prefix_passes(params, restrictions, &at[0], &cursor, 1) {
                            continue;
                        }
                        if dims == 1 {
                            slot[0].push(v0 as u16);
                        } else {
                            dfs(params, restrictions, at, &mut cursor, 1, slot);
                        }
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.expect("workers > 0").run(jobs);
    }
    let mut columns: Vec<Vec<u16>> =
        (0..dims).map(|d| Vec::with_capacity(parts.iter().map(|p| p[d].len()).sum())).collect();
    for part in parts {
        for (d, col) in part.into_iter().enumerate() {
            columns[d].extend(col);
        }
    }
    columns
}

impl SearchSpace {
    /// Enumerate the restricted Cartesian product serially.
    pub fn build(name: &str, params: Vec<Param>, restrictions: &[Restriction]) -> SearchSpace {
        Self::build_with(name, params, restrictions, None)
    }

    /// Enumerate the restricted Cartesian product shard-parallel on
    /// `pool`. Config order is bit-identical to [`build`](Self::build).
    pub fn build_par(
        name: &str,
        params: Vec<Param>,
        restrictions: &[Restriction],
        pool: &ShardPool,
    ) -> SearchSpace {
        Self::build_with(name, params, restrictions, Some(pool))
    }

    fn build_with(
        name: &str,
        params: Vec<Param>,
        restrictions: &[Restriction],
        pool: Option<&ShardPool>,
    ) -> SearchSpace {
        // The overflow check runs *before* enumeration: a wrapped product
        // would otherwise be noticed only after an unenumerable walk.
        let cartesian_size = Self::validate(name, &params);
        let columns = enumerate_columns(&params, restrictions, pool);
        Self::assemble(name, params, columns, cartesian_size)
    }

    /// Build from an explicit configuration list (simulation-mode cache
    /// import: the restrictions that produced the list are not replayed).
    pub fn from_configs(name: &str, params: Vec<Param>, configs: Vec<Config>) -> SearchSpace {
        let cartesian_size = Self::validate(name, &params);
        let dims = params.len();
        let mut columns: Vec<Vec<u16>> = (0..dims).map(|_| Vec::with_capacity(configs.len())).collect();
        for cfg in &configs {
            assert_eq!(cfg.len(), dims, "config arity mismatch");
            for (d, &vi) in cfg.iter().enumerate() {
                assert!((vi as usize) < params[d].len(), "value index out of range");
                columns[d].push(vi);
            }
        }
        Self::assemble(name, params, columns, cartesian_size)
    }

    /// Validate the parameter set and return the checked Cartesian size.
    /// Satellite fix: the seed-era `product()` silently wrapped on
    /// overflow; a spec large enough to wrap cannot be enumerated (or
    /// packed into u64 keys) anyway, so fail loudly and early.
    fn validate(name: &str, params: &[Param]) -> usize {
        assert!(!params.is_empty(), "space '{name}' has no parameters");
        let mut cartesian_size: usize = 1;
        for p in params {
            assert!(!p.is_empty(), "parameter {} has empty domain", p.name);
            assert!(p.len() < u16::MAX as usize);
            cartesian_size = cartesian_size.checked_mul(p.len()).unwrap_or_else(|| {
                panic!(
                    "space '{name}': Cartesian product overflows usize \
                     ({} parameters; restrict the domains before building)",
                    params.len()
                )
            });
        }
        cartesian_size
    }

    fn assemble(
        name: &str,
        params: Vec<Param>,
        columns: Vec<Vec<u16>>,
        cartesian_size: usize,
    ) -> SearchSpace {
        let dims = params.len();
        let len = columns[0].len();
        debug_assert!(columns.iter().all(|c| c.len() == len));

        // Mixed-radix strides (last dimension fastest — the odometer's
        // least significant digit — so enumeration order == key order).
        let mut strides = vec![1u64; dims];
        for d in (0..dims.saturating_sub(1)).rev() {
            strides[d] = strides[d + 1]
                .checked_mul(params[d + 1].len() as u64)
                .expect("stride fits u64: cartesian_size fits usize");
        }

        let keys: Vec<u64> = (0..len)
            .map(|i| {
                columns
                    .iter()
                    .zip(&strides)
                    .map(|(col, &s)| u64::from(col[i]) * s)
                    .sum()
            })
            .collect();
        let index = KeyIndex::build(&keys);

        let mut norm = Vec::with_capacity(len * dims);
        for i in 0..len {
            for (d, p) in params.iter().enumerate() {
                norm.push(p.norm(columns[d][i] as usize) as f32);
            }
        }

        SearchSpace {
            name: name.into(),
            params,
            columns,
            len,
            strides,
            keys,
            index,
            norm: norm.into(),
            cartesian_size,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn dims(&self) -> usize {
        self.params.len()
    }

    /// Config `i` as owned value indices (materialized from the columns).
    pub fn config(&self, i: usize) -> Config {
        self.columns.iter().map(|c| c[i]).collect()
    }

    /// Value index of config `i` in dimension `d` — the columnar
    /// fast path (no materialization).
    #[inline]
    pub fn value_index(&self, i: usize, d: usize) -> u16 {
        self.columns[d][i]
    }

    /// Packed mixed-radix key of config `i`.
    #[inline]
    pub fn key(&self, i: usize) -> u64 {
        self.keys[i]
    }

    /// Mixed-radix strides (`strides[dims-1] == 1`); a single-dimension
    /// move from key `k` is `k ± delta · strides[d]`.
    pub fn strides(&self) -> &[u64] {
        &self.strides
    }

    /// Pack explicit value indices into a key; `None` when any index is
    /// out of its dimension's radix.
    pub fn pack(&self, cfg: &[u16]) -> Option<u64> {
        if cfg.len() != self.dims() {
            return None;
        }
        let mut key = 0u64;
        for ((&vi, p), &s) in cfg.iter().zip(&self.params).zip(&self.strides) {
            if (vi as usize) >= p.len() {
                return None;
            }
            key += u64::from(vi) * s;
        }
        Some(key)
    }

    /// Normalized coordinates of config `i` (length = dims).
    pub fn point(&self, i: usize) -> &[f32] {
        let d = self.dims();
        &self.norm[i * d..(i + 1) * d]
    }

    /// The full normalized matrix, row-major `len × dims`.
    pub fn points(&self) -> &[f32] {
        &self.norm
    }

    /// Zero-copy handle to the normalized tiles: a refcount bump, not a
    /// matrix copy. Row-major layout means any contiguous candidate range
    /// `[start, end)` is the contiguous slice
    /// `tiles[start*dims .. end*dims]` — exactly the per-shard tile the
    /// sharded GP sweeps.
    pub fn norm_tiles(&self) -> Arc<[f32]> {
        Arc::clone(&self.norm)
    }

    pub fn index_of(&self, cfg: &[u16]) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        self.index.get(self.pack(cfg)?)
    }

    /// Position of the config with packed key `key`, if it survived the
    /// restrictions — the alloc-free probe the neighbor operators use.
    #[inline]
    pub fn index_of_key(&self, key: u64) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        self.index.get(key)
    }

    /// Typed assignment view of config `i` (borrows the columns — no
    /// materialization).
    pub fn assignment(&self, i: usize) -> Assignment<'_> {
        assert!(i < self.len);
        Assignment::from_columns(&self.params, &self.columns, i)
    }

    /// Value of parameter `d` in config `i`.
    pub fn value(&self, i: usize, d: usize) -> &PValue {
        &self.params[d].values[self.columns[d][i] as usize]
    }

    /// Human-readable rendering of config `i`.
    pub fn describe(&self, i: usize) -> String {
        self.params
            .iter()
            .enumerate()
            .map(|(d, p)| format!("{}={}", p.name, p.values[self.columns[d][i] as usize]))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Fraction of the Cartesian product that survives the restrictions.
    pub fn restriction_survival(&self) -> f64 {
        self.len as f64 / self.cartesian_size as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::constraint::{Expr, Restriction};
    use crate::space::testref::odometer_reference;

    fn small_space() -> SearchSpace {
        let params = vec![
            Param::ints("bx", &[16, 32, 64]),
            Param::ints("tile", &[1, 2, 4, 8]),
            Param::bools("pad"),
        ];
        let restr = vec![Restriction::new("bx*tile<=128", |a| a.i("bx") * a.i("tile") <= 128)];
        SearchSpace::build("toy", params, &restr)
    }

    fn small_space_dsl() -> SearchSpace {
        let params = vec![
            Param::ints("bx", &[16, 32, 64]),
            Param::ints("tile", &[1, 2, 4, 8]),
            Param::bools("pad"),
        ];
        let restr =
            vec![Restriction::expr(Expr::var("bx").mul(Expr::var("tile")).le(Expr::lit(128)))];
        SearchSpace::build("toy", params, &restr)
    }

    #[test]
    fn cartesian_and_restricted_sizes() {
        let s = small_space();
        assert_eq!(s.cartesian_size, 3 * 4 * 2);
        // Valid (bx,tile): 16×{1,2,4,8}, 32×{1,2,4}, 64×{1,2} = 9 pairs × 2 pad values.
        assert_eq!(s.len(), 18);
    }

    #[test]
    fn no_restrictions_gives_cartesian() {
        let params = vec![Param::ints("a", &[1, 2]), Param::ints("b", &[1, 2, 3])];
        let s = SearchSpace::build("free", params, &[]);
        assert_eq!(s.len(), 6);
        assert_eq!(s.cartesian_size, 6);
        assert!((s.restriction_survival() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_configs_satisfy_restrictions() {
        let s = small_space();
        for i in 0..s.len() {
            let a = s.assignment(i);
            assert!(a.i("bx") * a.i("tile") <= 128, "config {i} violates restriction");
        }
    }

    #[test]
    fn enumeration_matches_the_seed_odometer() {
        let params = vec![
            Param::ints("bx", &[16, 32, 64]),
            Param::ints("tile", &[1, 2, 4, 8]),
            Param::bools("pad"),
        ];
        let restr = vec![Restriction::new("bx*tile<=128", |a| a.i("bx") * a.i("tile") <= 128)];
        let expected = odometer_reference(&params, &restr);
        let s = small_space();
        assert_eq!(s.len(), expected.len());
        for (i, cfg) in expected.iter().enumerate() {
            assert_eq!(&s.config(i), cfg, "order diverged at {i}");
        }
        // Keys ascend exactly when enumeration is odometer-ordered.
        for i in 1..s.len() {
            assert!(s.key(i - 1) < s.key(i), "keys must ascend");
        }
    }

    #[test]
    fn dsl_restrictions_prune_to_the_same_space() {
        let a = small_space();
        let b = small_space_dsl();
        assert_eq!(a.len(), b.len());
        for i in 0..a.len() {
            assert_eq!(a.config(i), b.config(i));
        }
    }

    #[test]
    fn parallel_build_is_bit_identical_to_serial() {
        let params = || {
            vec![
                Param::ints("a", &(0..13).collect::<Vec<_>>()),
                Param::ints("b", &(0..11).collect::<Vec<_>>()),
                Param::ints("c", &(0..7).collect::<Vec<_>>()),
            ]
        };
        let restr = || {
            vec![
                Restriction::expr(
                    Expr::var("a").add(Expr::var("b")).rem(Expr::lit(3)).ne(Expr::lit(0)),
                ),
                Restriction::new("closure: a*c<=40", |x| x.i("a") * x.i("c") <= 40),
            ]
        };
        let serial = SearchSpace::build("par", params(), &restr());
        for threads in [2, 4, 8] {
            let pool = ShardPool::new(threads);
            let par = SearchSpace::build_par("par", params(), &restr(), &pool);
            assert_eq!(par.len(), serial.len(), "threads={threads}");
            for i in 0..serial.len() {
                assert_eq!(par.key(i), serial.key(i), "threads={threads} config {i}");
            }
            assert_eq!(par.points(), serial.points(), "threads={threads}");
        }
    }

    #[test]
    fn prefix_pruning_matches_leaf_checking() {
        // The same predicate as expression (pruned at depth of its deepest
        // var) and as closure (checked at the leaf) must yield the same
        // space — constraint propagation only skips work, never configs.
        let params = || {
            vec![
                Param::ints("x", &(1..=9).collect::<Vec<_>>()),
                Param::ints("y", &(1..=8).collect::<Vec<_>>()),
                Param::ints("z", &(1..=5).collect::<Vec<_>>()),
            ]
        };
        // Touches x,y only -> checked at depth 1, pruning z's subtree.
        let dsl = vec![Restriction::expr(Expr::var("x").mul(Expr::var("y")).le(Expr::lit(20)))];
        let closure = vec![Restriction::new("xy<=20", |a| a.i("x") * a.i("y") <= 20)];
        let a = SearchSpace::build("p", params(), &dsl);
        let b = SearchSpace::build("p", params(), &closure);
        assert_eq!(a.len(), b.len());
        for i in 0..a.len() {
            assert_eq!(a.config(i), b.config(i));
        }
    }

    #[test]
    fn index_roundtrips() {
        let s = small_space();
        for i in 0..s.len() {
            assert_eq!(s.index_of(&s.config(i)), Some(i));
            assert_eq!(s.index_of_key(s.key(i)), Some(i));
        }
        assert_eq!(s.index_of(&[2, 3, 0]), None); // 64*8 violates
        assert_eq!(s.index_of(&[0, 0, 7]), None, "out-of-radix index");
        assert_eq!(s.index_of(&[0, 0]), None, "arity mismatch");
    }

    #[test]
    fn packed_keys_are_mixed_radix() {
        let s = small_space();
        // strides: dims (3,4,2) -> [8, 2, 1].
        assert_eq!(s.strides(), &[8, 2, 1]);
        for i in 0..s.len() {
            let cfg = s.config(i);
            let expect =
                u64::from(cfg[0]) * 8 + u64::from(cfg[1]) * 2 + u64::from(cfg[2]);
            assert_eq!(s.key(i), expect);
            assert_eq!(s.pack(&cfg), Some(expect));
        }
        assert_eq!(s.pack(&[0, 9, 0]), None);
    }

    #[test]
    fn normalized_in_unit_cube() {
        let s = small_space();
        assert_eq!(s.points().len(), s.len() * s.dims());
        for i in 0..s.len() {
            for &x in s.point(i) {
                assert!((0.0..=1.0).contains(&x));
            }
        }
    }

    #[test]
    fn norm_tiles_are_zero_copy() {
        let s = small_space();
        let a = s.norm_tiles();
        let b = s.norm_tiles();
        assert!(Arc::ptr_eq(&a, &b), "tiles must share one allocation");
        assert_eq!(&a[..], s.points());
    }

    #[test]
    fn points_distinct() {
        let s = small_space();
        for i in 0..s.len() {
            for j in i + 1..s.len() {
                assert_ne!(s.point(i), s.point(j), "configs {i},{j} collide in normalized space");
            }
        }
    }

    #[test]
    fn describe_mentions_all_params() {
        let s = small_space();
        let d = s.describe(0);
        assert!(d.contains("bx=") && d.contains("tile=") && d.contains("pad="));
    }

    #[test]
    fn empty_restricted_space_is_legal() {
        let params = vec![Param::ints("a", &[1, 2])];
        let r = vec![Restriction::expr(Expr::var("a").gt(Expr::lit(10)))];
        let s = SearchSpace::build("void", params, &r);
        assert!(s.is_empty());
        assert_eq!(s.index_of(&[0]), None);
        assert_eq!(s.index_of_key(0), None);
    }

    /// Satellite regression: the seed-era `product()` wrapped silently on
    /// large specs; the checked build must fail with a clear message
    /// before attempting enumeration.
    #[test]
    #[should_panic(expected = "Cartesian product overflows usize")]
    fn cartesian_overflow_is_a_clear_error() {
        let vals: Vec<i64> = (0..8192).collect();
        let params: Vec<Param> =
            (0..5).map(|d| Param::ints(&format!("p{d}"), &vals)).collect();
        // 8192^5 = 2^65 — past usize on every supported target.
        let _ = SearchSpace::build("huge", params, &[]);
    }

    #[test]
    fn from_configs_preserves_order_and_index() {
        let params = vec![Param::ints("a", &[1, 2, 3]), Param::ints("b", &[1, 2])];
        let configs: Vec<Config> = vec![vec![2, 1], vec![0, 0], vec![1, 1]];
        let s = SearchSpace::from_configs("import", params, configs.clone());
        assert_eq!(s.len(), 3);
        for (i, cfg) in configs.iter().enumerate() {
            assert_eq!(&s.config(i), cfg);
            assert_eq!(s.index_of(cfg), Some(i));
        }
        assert_eq!(s.cartesian_size, 6);
    }
}
