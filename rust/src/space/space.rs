//! The enumerated, restricted, normalized search space (§III-D).
//!
//! The paper's core representational choice: a *discrete* search space
//! where every parameter configuration is known up front, values are
//! normalized linearly per parameter, and the acquisition function is
//! optimized *exhaustively over the non-evaluated configurations only*.
//! This module materializes that representation: the restricted Cartesian
//! product, the normalized coordinate matrix, and an index for O(1)
//! membership tests (needed by the neighbor operators of SA/MLS/GA).

use std::collections::HashMap;

use crate::space::constraint::{Assignment, Restriction};
use crate::space::param::{PValue, Param};

/// A parameter configuration, as per-parameter value indices.
pub type Config = Vec<u16>;

pub struct SearchSpace {
    pub name: String,
    pub params: Vec<Param>,
    /// All configurations that satisfy the restrictions.
    configs: Vec<Config>,
    /// Flattened row-major normalized coordinates: `configs.len() × dims`.
    norm: Vec<f64>,
    /// Config -> position in `configs`.
    index: HashMap<Config, usize>,
    /// Size of the unrestricted Cartesian product.
    pub cartesian_size: usize,
}

impl SearchSpace {
    /// Enumerate the restricted Cartesian product.
    pub fn build(name: &str, params: Vec<Param>, restrictions: &[Restriction]) -> SearchSpace {
        assert!(!params.is_empty());
        for p in &params {
            assert!(!p.is_empty(), "parameter {} has empty domain", p.name);
            assert!(p.len() < u16::MAX as usize);
        }
        let dims = params.len();
        let cartesian_size = params.iter().map(|p| p.len()).product();
        let mut configs = Vec::new();
        let mut cursor: Config = vec![0; dims];
        loop {
            let a = Assignment::new(&params, &cursor);
            if restrictions.iter().all(|r| r.check(&a)) {
                configs.push(cursor.clone());
            }
            // Odometer increment.
            let mut d = dims;
            loop {
                if d == 0 {
                    // Wrapped past the most significant digit: done.
                    let norm = Self::normalize(&params, &configs);
                    let index = configs.iter().cloned().zip(0..).collect();
                    return SearchSpace { name: name.into(), params, configs, norm, index, cartesian_size };
                }
                d -= 1;
                cursor[d] += 1;
                if (cursor[d] as usize) < params[d].len() {
                    break;
                }
                cursor[d] = 0;
            }
        }
    }

    /// Build from an explicit configuration list (simulation-mode cache
    /// import: the restrictions that produced the list are not replayed).
    pub fn from_configs(name: &str, params: Vec<Param>, configs: Vec<Config>) -> SearchSpace {
        let dims = params.len();
        for cfg in &configs {
            assert_eq!(cfg.len(), dims, "config arity mismatch");
            for (d, &vi) in cfg.iter().enumerate() {
                assert!((vi as usize) < params[d].len(), "value index out of range");
            }
        }
        let cartesian_size = params.iter().map(|p| p.len()).product();
        let norm = Self::normalize(&params, &configs);
        let index = configs.iter().cloned().zip(0..).collect();
        SearchSpace { name: name.into(), params, configs, norm, index, cartesian_size }
    }

    fn normalize(params: &[Param], configs: &[Config]) -> Vec<f64> {
        let dims = params.len();
        let mut norm = Vec::with_capacity(configs.len() * dims);
        for cfg in configs {
            for (d, &vi) in cfg.iter().enumerate() {
                norm.push(params[d].norm(vi as usize));
            }
        }
        norm
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    pub fn dims(&self) -> usize {
        self.params.len()
    }

    pub fn config(&self, i: usize) -> &Config {
        &self.configs[i]
    }

    /// Normalized coordinates of config `i` (length = dims).
    pub fn point(&self, i: usize) -> &[f64] {
        let d = self.dims();
        &self.norm[i * d..(i + 1) * d]
    }

    /// The full normalized matrix, row-major `len × dims`.
    pub fn points(&self) -> &[f64] {
        &self.norm
    }

    pub fn index_of(&self, cfg: &Config) -> Option<usize> {
        self.index.get(cfg).copied()
    }

    /// Typed assignment view of config `i`.
    pub fn assignment(&self, i: usize) -> Assignment<'_> {
        Assignment::new(&self.params, &self.configs[i])
    }

    /// Value of parameter `d` in config `i`.
    pub fn value(&self, i: usize, d: usize) -> &PValue {
        &self.params[d].values[self.configs[i][d] as usize]
    }

    /// Human-readable rendering of config `i`.
    pub fn describe(&self, i: usize) -> String {
        self.params
            .iter()
            .zip(self.configs[i].iter())
            .map(|(p, &vi)| format!("{}={}", p.name, p.values[vi as usize]))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Fraction of the Cartesian product that survives the restrictions.
    pub fn restriction_survival(&self) -> f64 {
        self.configs.len() as f64 / self.cartesian_size as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::constraint::Restriction;

    fn small_space() -> SearchSpace {
        let params = vec![
            Param::ints("bx", &[16, 32, 64]),
            Param::ints("tile", &[1, 2, 4, 8]),
            Param::bools("pad"),
        ];
        let restr = vec![Restriction::new("bx*tile<=128", |a| a.i("bx") * a.i("tile") <= 128)];
        SearchSpace::build("toy", params, &restr)
    }

    #[test]
    fn cartesian_and_restricted_sizes() {
        let s = small_space();
        assert_eq!(s.cartesian_size, 3 * 4 * 2);
        // Valid (bx,tile): 16×{1,2,4,8}, 32×{1,2,4}, 64×{1,2} = 9 pairs × 2 pad values.
        assert_eq!(s.len(), 18);
    }

    #[test]
    fn no_restrictions_gives_cartesian() {
        let params = vec![Param::ints("a", &[1, 2]), Param::ints("b", &[1, 2, 3])];
        let s = SearchSpace::build("free", params, &[]);
        assert_eq!(s.len(), 6);
        assert_eq!(s.cartesian_size, 6);
        assert!((s.restriction_survival() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn all_configs_satisfy_restrictions() {
        let s = small_space();
        for i in 0..s.len() {
            let a = s.assignment(i);
            assert!(a.i("bx") * a.i("tile") <= 128, "config {i} violates restriction");
        }
    }

    #[test]
    fn index_roundtrips() {
        let s = small_space();
        for i in 0..s.len() {
            assert_eq!(s.index_of(s.config(i)), Some(i));
        }
        assert_eq!(s.index_of(&vec![2, 3, 0]), None); // 64*8 violates
    }

    #[test]
    fn normalized_in_unit_cube() {
        let s = small_space();
        assert_eq!(s.points().len(), s.len() * s.dims());
        for i in 0..s.len() {
            for &x in s.point(i) {
                assert!((0.0..=1.0).contains(&x));
            }
        }
    }

    #[test]
    fn points_distinct() {
        let s = small_space();
        for i in 0..s.len() {
            for j in i + 1..s.len() {
                assert_ne!(s.point(i), s.point(j), "configs {i},{j} collide in normalized space");
            }
        }
    }

    #[test]
    fn describe_mentions_all_params() {
        let s = small_space();
        let d = s.describe(0);
        assert!(d.contains("bx=") && d.contains("tile=") && d.contains("pad="));
    }
}
