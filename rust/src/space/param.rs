//! Tunable parameters of mixed types (§III-D1 of the paper).
//!
//! GPU-kernel tunables mix integers (block sizes), non-linear integers
//! (powers of two), booleans (use shared memory?), and categoricals
//! (algorithm switches). A parameter is a *name* plus an ordered, finite
//! list of values; the user-given ordering is meaningful (the paper leaves
//! ordering responsibility with the user rather than one-hot/binary
//! encoding).

/// A single parameter value.
#[derive(Clone, Debug, PartialEq)]
pub enum PValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(&'static str),
}

impl PValue {
    /// Numeric view used by performance models; booleans map to 0/1,
    /// strings panic (models must match on `as_str` instead).
    pub fn as_f64(&self) -> f64 {
        match self {
            PValue::Int(x) => *x as f64,
            PValue::Float(x) => *x,
            PValue::Bool(b) => {
                if *b {
                    1.0
                } else {
                    0.0
                }
            }
            PValue::Str(s) => panic!("categorical value '{s}' has no numeric view"),
        }
    }

    pub fn as_i64(&self) -> i64 {
        match self {
            PValue::Int(x) => *x,
            PValue::Bool(b) => i64::from(*b),
            PValue::Float(x) => *x as i64,
            PValue::Str(s) => panic!("categorical value '{s}' has no integer view"),
        }
    }

    pub fn as_bool(&self) -> bool {
        match self {
            PValue::Bool(b) => *b,
            PValue::Int(x) => *x != 0,
            _ => panic!("value {self:?} has no boolean view"),
        }
    }

    pub fn as_str(&self) -> &str {
        match self {
            PValue::Str(s) => s,
            _ => panic!("value {self:?} is not categorical"),
        }
    }
}

impl std::fmt::Display for PValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PValue::Int(x) => write!(f, "{x}"),
            PValue::Float(x) => write!(f, "{x}"),
            PValue::Bool(b) => write!(f, "{b}"),
            PValue::Str(s) => write!(f, "{s}"),
        }
    }
}

/// A named tunable parameter with its ordered domain.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub values: Vec<PValue>,
}

impl Param {
    pub fn ints(name: &str, values: &[i64]) -> Param {
        Param { name: name.into(), values: values.iter().map(|&v| PValue::Int(v)).collect() }
    }

    pub fn bools(name: &str) -> Param {
        Param { name: name.into(), values: vec![PValue::Bool(false), PValue::Bool(true)] }
    }

    pub fn cats(name: &str, values: &[&'static str]) -> Param {
        Param { name: name.into(), values: values.iter().map(|&v| PValue::Str(v)).collect() }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Normalized coordinate of value index `i`: linear spacing by *index*
    /// (§III-D1 — linear normalization removes the distance distortion of
    /// non-linear domains like powers of two).
    pub fn norm(&self, i: usize) -> f64 {
        if self.values.len() <= 1 {
            0.0
        } else {
            i as f64 / (self.values.len() - 1) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_views() {
        assert_eq!(PValue::Int(8).as_f64(), 8.0);
        assert_eq!(PValue::Bool(true).as_f64(), 1.0);
        assert_eq!(PValue::Float(2.5).as_f64(), 2.5);
        assert!(PValue::Bool(true).as_bool());
        assert_eq!(PValue::Str("texture").as_str(), "texture");
    }

    #[test]
    #[should_panic]
    fn categorical_has_no_numeric_view() {
        let _ = PValue::Str("a").as_f64();
    }

    #[test]
    fn normalization_is_linear_in_index() {
        // Powers of two: indices normalize linearly, not by magnitude.
        let p = Param::ints("vw", &[1, 2, 4, 8]);
        assert_eq!(p.norm(0), 0.0);
        assert!((p.norm(1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((p.norm(2) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(p.norm(3), 1.0);
    }

    #[test]
    fn singleton_param_norm_zero() {
        let p = Param::ints("precision", &[32]);
        assert_eq!(p.norm(0), 0.0);
    }

    #[test]
    fn constructors() {
        assert_eq!(Param::bools("use_padding").len(), 2);
        assert_eq!(Param::cats("method", &["a", "b", "c"]).len(), 3);
    }
}
