//! Implicit spaces: the [`SpaceView`] abstraction over *how a space is
//! backed* — eagerly enumerated columns or a lazy constraint oracle.
//!
//! The paper's engine materializes every restricted configuration up
//! front (`SearchSpace`), which is exact and fast at GEMM's ~18k configs
//! but impossible at the 10⁹+-config spaces constraint-aware auto-tuning
//! targets (ROADMAP item 1; PAPERS.md arXiv:2606.28372). This module
//! splits "what the optimizer needs from a space" from "how the space is
//! stored":
//!
//! - [`SpaceView`] — the probe surface: uniform valid draws, packed-key
//!   membership, neighbor probes, per-key decode/normalize. Everything is
//!   phrased in the *same* per-dim `u16` encoding and mixed-radix `u64`
//!   packed keys the columnar space uses, so trace records, `KeyIndex`
//!   lookups and `neighbors.rs` probes keep their exact format.
//! - `impl SpaceView for SearchSpace` + [`EagerView`] — the enumerated
//!   backing. Bit-identical to pre-view behavior: every answer routes
//!   through the existing columnar structures.
//! - [`LazyView`] — never enumerates. Membership and neighbor probes
//!   decode the key and re-check the restriction set; uniform draws use
//!   rejection sampling over the Cartesian key range (exactly uniform
//!   over the valid set) with a randomized constraint-propagating DFS
//!   fallback that reuses the eager enumerator's deepest-touched-dim
//!   restriction buckets ([`restriction_depths`]) to prune dead prefixes.
//!
//! # Key ↔ index identity on the lazy path
//!
//! Mixed-radix packing is a *bijection* between configs of the full
//! Cartesian product and keys `0..cartesian_size`. The eager backing maps
//! keys to dense enumeration positions; the lazy backing has no positions,
//! so it uses the key itself as the trace/engine index
//! (`idx == key as usize`). Both directions are exposed via
//! [`SpaceView::idx_of_key`] / [`SpaceView::key_of_index`], which is all
//! the driver layer needs to stay backing-agnostic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::space::constraint::{Assignment, Restriction};
use crate::space::neighbors::Neighborhood;
use crate::space::param::Param;
use crate::space::space::{prefix_passes, restriction_depths, SearchSpace};
use crate::space::spec::SpaceSpec;
use crate::util::rng::Rng;

/// Uniform-draw attempts before [`LazyView::sample_key`] falls back from
/// exact rejection sampling to the propagating DFS. 64 keeps the exactly
/// uniform path overwhelmingly likely down to ~5% restriction survival.
const REJECTION_TRIES: usize = 64;

/// A space the optimizer can sample, probe, and score candidates from —
/// without promising anything about how (or whether) it is enumerated.
///
/// All keys are the same mixed-radix `u64` packing the columnar space
/// uses (`key = Σ value_index[d] · stride[d]`, last dimension fastest),
/// so a view can be swapped under the driver layer without changing trace
/// or wire formats.
pub trait SpaceView: Send + Sync {
    /// Space name (diagnostics and sweep metadata).
    fn name(&self) -> &str;

    /// Parameter definitions, in dimension order.
    fn params(&self) -> &[Param];

    /// Number of dimensions.
    fn dims(&self) -> usize {
        self.params().len()
    }

    /// Mixed-radix strides (`strides[dims-1] == 1`).
    fn strides(&self) -> &[u64];

    /// Size of the unrestricted Cartesian product.
    fn cartesian_size(&self) -> u64;

    /// `Some(valid count)` when the backing has enumerated the space,
    /// `None` when the valid count is unknown (lazy).
    fn size_hint(&self) -> Option<usize>;

    /// Does `key` decode to a restriction-satisfying config?
    fn contains_key(&self, key: u64) -> bool;

    /// Decode `key` into per-dimension value indices.
    /// `out.len()` must equal [`dims`](SpaceView::dims).
    fn decode_into(&self, key: u64, out: &mut [u16]);

    /// Normalized coordinates of `key`'s config (the same per-parameter
    /// linear normalization the eager tiles use).
    /// `out.len()` must equal [`dims`](SpaceView::dims).
    fn norm_point_into(&self, key: u64, out: &mut [f32]);

    /// Pack explicit value indices into a key; `None` when any index is
    /// out of its dimension's radix. Packing does **not** imply validity.
    fn pack(&self, cfg: &[u16]) -> Option<u64> {
        if cfg.len() != self.dims() {
            return None;
        }
        let mut key = 0u64;
        for ((&vi, p), &s) in cfg.iter().zip(self.params()).zip(self.strides()) {
            if (vi as usize) >= p.len() {
                return None;
            }
            key += u64::from(vi) * s;
        }
        Some(key)
    }

    /// One uniform draw over the valid set; `None` when the valid set is
    /// empty (or, for lazy backings, could not be certified non-empty).
    fn sample_key(&self, rng: &mut Rng) -> Option<u64>;

    /// Valid neighbor keys of `key` under `kind`, ascending, deduplicated.
    fn neighbor_keys(&self, key: u64, kind: Neighborhood, out: &mut Vec<u64>);

    /// Map a key to the engine/trace index, if the key is valid.
    /// Eager: the dense enumeration position. Lazy: the key itself.
    fn idx_of_key(&self, key: u64) -> Option<usize>;

    /// Inverse of [`idx_of_key`](SpaceView::idx_of_key) for in-range
    /// indices.
    fn key_of_index(&self, idx: usize) -> u64;

    /// Is `idx` a representable engine index for this view? (Eager: below
    /// the enumerated length. Lazy: below the Cartesian size — validity
    /// is a separate [`contains_key`](SpaceView::contains_key) question.)
    fn index_in_range(&self, idx: usize) -> bool;

    /// The enumerated backing, when there is one. Drivers that need whole
    /// columns (tiles, exhaustive sweeps) route through this and simply
    /// have no lazy mode.
    fn as_eager(&self) -> Option<&SearchSpace> {
        None
    }

    /// Constraint probes answered so far (lazy backings only; the
    /// `space_scale` bench asserts per-suggestion probe work stays
    /// bounded by the candidate-pool size).
    fn probe_count(&self) -> u64 {
        0
    }

    /// Human-readable rendering of `key`'s config.
    fn describe_key(&self, key: u64) -> String {
        let mut row = vec![0u16; self.dims()];
        self.decode_into(key, &mut row);
        self.params()
            .iter()
            .zip(&row)
            .map(|(p, &v)| format!("{}={}", p.name, p.values[v as usize]))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Shared mixed-radix decode: `out[d] = (key / stride[d]) mod radix[d]`.
#[inline]
fn decode_key(params: &[Param], strides: &[u64], key: u64, out: &mut [u16]) {
    debug_assert_eq!(out.len(), params.len());
    for (d, p) in params.iter().enumerate() {
        out[d] = ((key / strides[d]) % p.len() as u64) as u16;
    }
}

/// The enumerated columnar space *is* a view: every probe routes through
/// the existing `KeyIndex`/columns, so behavior is bit-identical to the
/// pre-view engine.
impl SpaceView for SearchSpace {
    fn name(&self) -> &str {
        &self.name
    }

    fn params(&self) -> &[Param] {
        &self.params
    }

    fn strides(&self) -> &[u64] {
        // Inherent method — resolves to the struct's accessor, not this
        // trait method.
        SearchSpace::strides(self)
    }

    fn cartesian_size(&self) -> u64 {
        self.cartesian_size as u64
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.len())
    }

    fn contains_key(&self, key: u64) -> bool {
        self.index_of_key(key).is_some()
    }

    fn decode_into(&self, key: u64, out: &mut [u16]) {
        decode_key(&self.params, SearchSpace::strides(self), key, out);
    }

    fn norm_point_into(&self, key: u64, out: &mut [f32]) {
        // Decode-and-normalize rather than a tile lookup: keys outside
        // the restricted set still have well-defined coordinates, which
        // the pool surrogates rely on.
        debug_assert_eq!(out.len(), self.dims());
        for (d, p) in self.params.iter().enumerate() {
            let vi = ((key / SearchSpace::strides(self)[d]) % p.len() as u64) as usize;
            out[d] = p.norm(vi) as f32;
        }
    }

    fn sample_key(&self, rng: &mut Rng) -> Option<u64> {
        if self.is_empty() {
            return None;
        }
        // Uniform over the enumerated valid set by construction.
        Some(self.key(rng.below(self.len())))
    }

    fn neighbor_keys(&self, key: u64, kind: Neighborhood, out: &mut Vec<u64>) {
        out.clear();
        if let Some(idx) = self.index_of_key(key) {
            out.extend(
                crate::space::neighbors::neighbors(self, idx, kind).into_iter().map(|j| self.key(j)),
            );
        }
        // ktbo-lint: allow(stable-sort-tiebreak): u64 keys are unique after dedup — no tie to break
        out.sort_unstable();
        out.dedup();
    }

    fn idx_of_key(&self, key: u64) -> Option<usize> {
        self.index_of_key(key)
    }

    fn key_of_index(&self, idx: usize) -> u64 {
        self.key(idx)
    }

    fn index_in_range(&self, idx: usize) -> bool {
        idx < self.len()
    }

    fn as_eager(&self) -> Option<&SearchSpace> {
        Some(self)
    }
}

/// Owning wrapper around an enumerated [`SearchSpace`] — the named eager
/// backing. Exists so call sites can hold `Arc<EagerView>` symmetric with
/// `Arc<LazyView>`; every probe delegates to the inner space, so a run
/// through an `EagerView` is bit-identical to a run on the bare space
/// (asserted by `eager_view_is_transparent` below and the registry-wide
/// equivalence test in `strategies::driver`).
pub struct EagerView {
    space: Arc<SearchSpace>,
}

impl EagerView {
    pub fn new(space: Arc<SearchSpace>) -> EagerView {
        EagerView { space }
    }

    pub fn space(&self) -> &Arc<SearchSpace> {
        &self.space
    }
}

impl SpaceView for EagerView {
    fn name(&self) -> &str {
        &self.space.name
    }

    fn params(&self) -> &[Param] {
        &self.space.params
    }

    fn strides(&self) -> &[u64] {
        SearchSpace::strides(&self.space)
    }

    fn cartesian_size(&self) -> u64 {
        self.space.cartesian_size as u64
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.space.len())
    }

    fn contains_key(&self, key: u64) -> bool {
        self.space.index_of_key(key).is_some()
    }

    fn decode_into(&self, key: u64, out: &mut [u16]) {
        self.space.decode_into(key, out);
    }

    fn norm_point_into(&self, key: u64, out: &mut [f32]) {
        self.space.norm_point_into(key, out);
    }

    fn sample_key(&self, rng: &mut Rng) -> Option<u64> {
        self.space.sample_key(rng)
    }

    fn neighbor_keys(&self, key: u64, kind: Neighborhood, out: &mut Vec<u64>) {
        self.space.neighbor_keys(key, kind, out);
    }

    fn idx_of_key(&self, key: u64) -> Option<usize> {
        self.space.index_of_key(key)
    }

    fn key_of_index(&self, idx: usize) -> u64 {
        self.space.key(idx)
    }

    fn index_in_range(&self, idx: usize) -> bool {
        idx < self.space.len()
    }

    fn as_eager(&self) -> Option<&SearchSpace> {
        Some(&self.space)
    }
}

/// The implicit backing: a constraint oracle over an *unenumerated*
/// Cartesian product. Holds only the parameter definitions, the
/// restriction set (with the eager enumerator's deepest-touched-dim
/// buckets), and the mixed-radix strides — O(dims) memory regardless of
/// Cartesian size.
pub struct LazyView {
    name: String,
    params: Vec<Param>,
    restrictions: Vec<Restriction>,
    /// Restrictions bucketed by deepest touched dimension (PR 4's `Expr`
    /// bucketing) — drives prefix pruning in the DFS sampling fallback.
    at: Vec<Vec<usize>>,
    strides: Vec<u64>,
    cartesian: u64,
    /// Constraint probes answered (membership checks + DFS prefix
    /// checks); the `space_scale` bench reads this to assert flat
    /// per-suggestion work.
    probes: AtomicU64,
}

impl LazyView {
    /// Build the oracle from a declarative spec without enumerating
    /// anything. Rejects spaces whose packed keys would not fit `u64`
    /// (the key packing must stay exact — wrapping would silently alias
    /// distinct configs).
    pub fn from_spec(spec: &SpaceSpec) -> Result<LazyView, String> {
        let params = spec.params();
        let restrictions = spec.restrictions();
        LazyView::from_parts(&spec.name, params, restrictions)
    }

    /// Build from explicit parts (tests and programmatic callers).
    pub fn from_parts(
        name: &str,
        params: Vec<Param>,
        restrictions: Vec<Restriction>,
    ) -> Result<LazyView, String> {
        if params.is_empty() {
            return Err(format!("space '{name}' has no parameters"));
        }
        let mut cartesian: u128 = 1;
        for p in &params {
            if p.is_empty() {
                return Err(format!("space '{name}': parameter '{}' has an empty domain", p.name));
            }
            if p.len() >= u16::MAX as usize {
                return Err(format!(
                    "space '{name}': parameter '{}' has {} values — beyond the u16 value-index radix",
                    p.name,
                    p.len()
                ));
            }
            cartesian *= p.len() as u128; // radix < 2^16, dims bounded: no u128 overflow
            if cartesian > u64::MAX as u128 {
                return Err(format!(
                    "space '{name}': packed keys overflow u64 (Cartesian size exceeds {}); \
                     restrict the domains — wrapping keys would alias distinct configs",
                    u64::MAX
                ));
            }
        }
        let dims = params.len();
        let mut strides = vec![1u64; dims];
        for d in (0..dims - 1).rev() {
            // Cannot overflow: strides[0] * radix[0] == cartesian ≤ u64::MAX.
            strides[d] = strides[d + 1] * params[d + 1].len() as u64;
        }
        let at = restriction_depths(&params, &restrictions);
        Ok(LazyView {
            name: name.to_string(),
            params,
            restrictions,
            at,
            strides,
            cartesian: cartesian as u64,
            probes: AtomicU64::new(0),
        })
    }

    /// Full-row restriction check (all restrictions, closure and expr).
    fn row_valid(&self, row: &[u16]) -> bool {
        self.probes.fetch_add(1, Ordering::Relaxed);
        let a = Assignment::new(&self.params, row);
        self.restrictions.iter().all(|r| r.check(&a))
    }

    /// Randomized constraint-propagating DFS: the eager enumerator's
    /// odometer with (a) values visited in a shuffled order per depth and
    /// (b) the same deepest-touched-dim prefix pruning. Finds a valid
    /// config iff one exists; not exactly uniform (used only when
    /// rejection sampling keeps missing, i.e. at extreme survival rates).
    fn sample_dfs(&self, rng: &mut Rng, cursor: &mut [u16], depth: usize) -> bool {
        let dims = self.params.len();
        let mut order: Vec<u16> = (0..self.params[depth].len() as u16).collect();
        rng.shuffle(&mut order);
        for v in order {
            cursor[depth] = v;
            self.probes.fetch_add(1, Ordering::Relaxed);
            if !prefix_passes(&self.params, &self.restrictions, &self.at[depth], cursor, depth + 1) {
                continue;
            }
            if depth + 1 == dims || self.sample_dfs(rng, cursor, depth + 1) {
                return true;
            }
        }
        false
    }
}

impl SpaceView for LazyView {
    fn name(&self) -> &str {
        &self.name
    }

    fn params(&self) -> &[Param] {
        &self.params
    }

    fn strides(&self) -> &[u64] {
        &self.strides
    }

    fn cartesian_size(&self) -> u64 {
        self.cartesian
    }

    fn size_hint(&self) -> Option<usize> {
        None
    }

    fn contains_key(&self, key: u64) -> bool {
        if key >= self.cartesian {
            return false;
        }
        let mut row = vec![0u16; self.params.len()];
        decode_key(&self.params, &self.strides, key, &mut row);
        self.row_valid(&row)
    }

    fn decode_into(&self, key: u64, out: &mut [u16]) {
        decode_key(&self.params, &self.strides, key, out);
    }

    fn norm_point_into(&self, key: u64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.params.len());
        for (d, p) in self.params.iter().enumerate() {
            let vi = ((key / self.strides[d]) % p.len() as u64) as usize;
            out[d] = p.norm(vi) as f32;
        }
    }

    fn sample_key(&self, rng: &mut Rng) -> Option<u64> {
        // Exactly uniform over the valid set: a uniform Cartesian key,
        // accepted iff it satisfies every restriction.
        let mut row = vec![0u16; self.params.len()];
        for _ in 0..REJECTION_TRIES {
            let key = rng.below(self.cartesian as usize) as u64;
            decode_key(&self.params, &self.strides, key, &mut row);
            if self.row_valid(&row) {
                return Some(key);
            }
        }
        // Survival too low for rejection — propagate constraints instead.
        if self.sample_dfs(rng, &mut row, 0) {
            return self.pack(&row);
        }
        None
    }

    fn neighbor_keys(&self, key: u64, kind: Neighborhood, out: &mut Vec<u64>) {
        out.clear();
        if key >= self.cartesian {
            return;
        }
        let dims = self.params.len();
        let mut row = vec![0u16; dims];
        decode_key(&self.params, &self.strides, key, &mut row);
        match kind {
            Neighborhood::Hamming => {
                // Configs differing in exactly one parameter (any value) —
                // mirrors `neighbors::hamming`, with membership answered
                // by the oracle instead of the key index.
                for d in 0..dims {
                    let orig = row[d];
                    let stride = self.strides[d];
                    for v in 0..self.params[d].len() as u16 {
                        if v == orig {
                            continue;
                        }
                        row[d] = v;
                        if self.row_valid(&row) {
                            out.push(
                                key - u64::from(orig) * stride + u64::from(v) * stride,
                            );
                        }
                    }
                    row[d] = orig;
                }
            }
            Neighborhood::Adjacent => {
                // ≤2-dimension ±1 moves — mirrors `neighbors::adjacent`.
                for d1 in 0..dims {
                    let c1 = row[d1];
                    for s1 in [-1i32, 1] {
                        let n1 = c1 as i32 + s1;
                        if n1 < 0 || n1 as usize >= self.params[d1].len() {
                            continue;
                        }
                        row[d1] = n1 as u16;
                        if self.row_valid(&row) {
                            out.push(self.pack(&row).expect("±1 step stays in radix"));
                        }
                        for d2 in d1 + 1..dims {
                            let c2 = row[d2];
                            for s2 in [-1i32, 1] {
                                let n2 = c2 as i32 + s2;
                                if n2 < 0 || n2 as usize >= self.params[d2].len() {
                                    continue;
                                }
                                row[d2] = n2 as u16;
                                if self.row_valid(&row) {
                                    out.push(self.pack(&row).expect("±1 step stays in radix"));
                                }
                            }
                            row[d2] = c2;
                        }
                        row[d1] = c1;
                    }
                }
            }
        }
        // ktbo-lint: allow(stable-sort-tiebreak): u64 keys are unique after dedup — no tie to break
        out.sort_unstable();
        out.dedup();
    }

    fn idx_of_key(&self, key: u64) -> Option<usize> {
        // The lazy engine index IS the key (mixed-radix packing is a
        // bijection over 0..cartesian_size).
        if self.contains_key(key) {
            Some(key as usize)
        } else {
            None
        }
    }

    fn key_of_index(&self, idx: usize) -> u64 {
        idx as u64
    }

    fn index_in_range(&self, idx: usize) -> bool {
        (idx as u64) < self.cartesian
    }

    fn probe_count(&self) -> u64 {
        self.probes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::constraint::Expr;
    use crate::space::neighbors::neighbors;

    /// A small restricted grid with corners and an irregular neighborhood.
    fn toy_spec() -> SpaceSpec {
        SpaceSpec::new("toy-view")
            .ints("bx", &[16, 32, 64])
            .ints("tile", &[1, 2, 4, 8])
            .bools("pad")
            .restrict(Expr::var("bx").mul(Expr::var("tile")).le(Expr::lit(128)))
    }

    fn both() -> (SearchSpace, LazyView) {
        let spec = toy_spec();
        (spec.build(), LazyView::from_spec(&spec).unwrap())
    }

    #[test]
    fn lazy_matches_eager_membership_over_the_whole_cartesian_range() {
        let (eager, lazy) = both();
        assert_eq!(lazy.cartesian_size(), eager.cartesian_size as u64);
        assert_eq!(SpaceView::strides(&lazy), SearchSpace::strides(&eager));
        for key in 0..lazy.cartesian_size() {
            assert_eq!(
                lazy.contains_key(key),
                eager.index_of_key(key).is_some(),
                "membership diverged at key {key}"
            );
        }
        assert!(!lazy.contains_key(lazy.cartesian_size()), "out-of-range key is not a member");
    }

    #[test]
    fn lazy_decode_and_norm_match_eager_columns() {
        let (eager, lazy) = both();
        let dims = eager.dims();
        let mut row = vec![0u16; dims];
        let mut norm = vec![0f32; dims];
        for i in 0..eager.len() {
            let key = eager.key(i);
            lazy.decode_into(key, &mut row);
            assert_eq!(row, eager.config(i), "decode diverged at {i}");
            lazy.norm_point_into(key, &mut norm);
            assert_eq!(&norm[..], eager.point(i), "normalization diverged at {i}");
            assert_eq!(lazy.pack(&row), Some(key), "pack must invert decode");
        }
    }

    /// Neighbor probes — including at space corners — brute-force-verified
    /// against the eager key index (satellite: packed-key edge cases).
    #[test]
    fn lazy_neighbor_probes_match_eager_at_every_config() {
        let (eager, lazy) = both();
        let mut lazy_out = Vec::new();
        let mut eager_out = Vec::new();
        for kind in [Neighborhood::Hamming, Neighborhood::Adjacent] {
            for i in 0..eager.len() {
                let key = eager.key(i);
                lazy.neighbor_keys(key, kind, &mut lazy_out);
                eager.neighbor_keys(key, kind, &mut eager_out);
                assert_eq!(lazy_out, eager_out, "{kind:?} neighbors diverged at config {i}");
                // And the eager view agrees with the index-space operator.
                let mut via_idx: Vec<u64> =
                    neighbors(&eager, i, kind).into_iter().map(|j| eager.key(j)).collect();
                via_idx.sort_unstable();
                assert_eq!(eager_out, via_idx);
            }
        }
    }

    #[test]
    fn sampling_is_uniform_valid_and_seed_deterministic() {
        let (eager, lazy) = both();
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..200 {
            let a = lazy.sample_key(&mut r1).expect("valid set is non-empty");
            let b = lazy.sample_key(&mut r2).expect("valid set is non-empty");
            assert_eq!(a, b, "sampling must be a pure function of the RNG stream");
            assert!(eager.index_of_key(a).is_some(), "sampled key {a} is not a valid config");
            seen.insert(a);
        }
        // 200 draws over 18 valid configs: rejection sampling covers the set.
        assert_eq!(seen.len(), eager.len(), "uniform draws must reach every valid config");
    }

    /// Extreme survival rates force the propagating-DFS fallback; draws
    /// must stay valid and deterministic.
    #[test]
    fn dfs_fallback_engages_at_extreme_survival() {
        let n: Vec<i64> = (0..500).collect();
        let spec = SpaceSpec::new("needle")
            .ints("a", &n)
            .ints("b", &n)
            .restrict(Expr::var("a").eq(Expr::var("b"))); // survival 1/500
        let lazy = LazyView::from_spec(&spec).unwrap();
        let mut rng = Rng::new(3);
        let mut row = vec![0u16; 2];
        for _ in 0..20 {
            let key = lazy.sample_key(&mut rng).expect("diagonal is non-empty");
            lazy.decode_into(key, &mut row);
            assert_eq!(row[0], row[1], "sampled config violates a==b");
        }
    }

    #[test]
    fn empty_valid_set_samples_none() {
        let spec = SpaceSpec::new("void")
            .ints("a", &[1, 2])
            .restrict(Expr::var("a").gt(Expr::lit(10)));
        let lazy = LazyView::from_spec(&spec).unwrap();
        let mut rng = Rng::new(1);
        assert_eq!(lazy.sample_key(&mut rng), None);
        assert!(!lazy.contains_key(0) && !lazy.contains_key(1));
    }

    /// Satellite: dims at the u16 radix boundary. 65534 values is the
    /// largest legal radix (value indices must stay below u16::MAX).
    #[test]
    fn u16_radix_boundary_round_trips() {
        let vals: Vec<i64> = (0..65534).collect();
        let spec = SpaceSpec::new("wide").ints("huge", &vals).ints("b", &[0, 1, 2]);
        let lazy = LazyView::from_spec(&spec).unwrap();
        assert_eq!(lazy.cartesian_size(), 65534 * 3);
        let corner = lazy.pack(&[65533, 2]).unwrap();
        assert_eq!(corner, lazy.cartesian_size() - 1);
        let mut row = vec![0u16; 2];
        lazy.decode_into(corner, &mut row);
        assert_eq!(row, vec![65533u16, 2]);
        assert!(lazy.contains_key(corner));

        let over: Vec<i64> = (0..65535).collect();
        let bad = SpaceSpec::new("over").ints("huge", &over);
        let err = LazyView::from_spec(&bad).unwrap_err();
        assert!(err.contains("u16 value-index radix"), "unexpected error: {err}");
    }

    /// Satellite: mixed-radix packs that nearly overflow u64 build fine;
    /// actual overflow is rejected with a clear error, never wrapped.
    #[test]
    fn key_overflow_is_rejected_not_wrapped() {
        // 65534^4 ≈ 0.9999 · 2^64 — fits (barely).
        let vals: Vec<i64> = (0..65534).collect();
        let mut near = SpaceSpec::new("near-max");
        for name in ["a", "b", "c", "d"] {
            near = near.ints(name, &vals);
        }
        let lazy = LazyView::from_spec(&near).unwrap();
        let expect = 65534u128.pow(4);
        assert_eq!(lazy.cartesian_size() as u128, expect);
        // The extreme corner key decodes exactly (no wrapping anywhere).
        let corner = lazy.pack(&[65533; 4]).unwrap();
        assert_eq!(corner, (expect - 1) as u64);
        let mut row = vec![0u16; 4];
        lazy.decode_into(corner, &mut row);
        assert_eq!(row, vec![65533u16; 4]);

        // One more dimension pushes past u64 — a clear error, not a wrap.
        let mut over = near;
        over = over.ints("e", &[0, 1, 2]);
        let err = LazyView::from_spec(&over).unwrap_err();
        assert!(err.contains("overflow u64"), "unexpected error: {err}");
    }

    #[test]
    fn eager_view_is_transparent() {
        let spec = toy_spec();
        let space = Arc::new(spec.build());
        let view = EagerView::new(Arc::clone(&space));
        assert_eq!(view.size_hint(), Some(space.len()));
        assert!(view.as_eager().is_some());
        let mut a = Vec::new();
        let mut b = Vec::new();
        for i in 0..space.len() {
            let key = space.key(i);
            assert_eq!(view.idx_of_key(key), Some(i));
            assert_eq!(view.key_of_index(i), key);
            for kind in [Neighborhood::Hamming, Neighborhood::Adjacent] {
                view.neighbor_keys(key, kind, &mut a);
                space.neighbor_keys(key, kind, &mut b);
                assert_eq!(a, b);
            }
        }
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        for _ in 0..50 {
            assert_eq!(view.sample_key(&mut r1), space.sample_key(&mut r2));
        }
    }

    #[test]
    fn lazy_index_identity_is_the_key_bijection() {
        let (eager, lazy) = both();
        for i in 0..eager.len() {
            let key = eager.key(i);
            assert_eq!(lazy.idx_of_key(key), Some(key as usize));
            assert_eq!(lazy.key_of_index(key as usize), key);
            assert!(lazy.index_in_range(key as usize));
        }
        assert!(!lazy.index_in_range(lazy.cartesian_size() as usize));
        // An in-Cartesian but restriction-invalid key has an index slot
        // but is not a member: 64*8 violates bx*tile<=128.
        let bad = lazy.pack(&[2, 3, 0]).unwrap();
        assert!(lazy.index_in_range(bad as usize));
        assert_eq!(lazy.idx_of_key(bad), None);
    }

    #[test]
    fn describe_and_probe_counter() {
        let (_, lazy) = both();
        let before = lazy.probe_count();
        assert!(lazy.contains_key(0));
        assert!(lazy.probe_count() > before, "membership must count a probe");
        let d = lazy.describe_key(0);
        assert!(d.contains("bx=16") && d.contains("tile=1") && d.contains("pad="), "{d}");
    }
}
