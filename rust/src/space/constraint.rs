//! Search-space restrictions (Kernel Tuner's `restrictions=`).
//!
//! A restriction is a named predicate over a full parameter assignment.
//! Restrictions model what the paper calls the *first stage* of invalidity
//! detection: checking individual-parameter / cross-parameter validity
//! against the programming-model specification *before* compile time.
//! Configurations failing a restriction are excluded from the search space
//! entirely (they are not "invalid configs" in the Table II sense — those
//! are discovered at compile/run time by the objective).
//!
//! Two predicate representations coexist:
//!
//! - [`Expr`] — a small serializable expression DSL (integer arithmetic,
//!   comparisons, short-circuit boolean operators) over parameter values by
//!   name. Expression restrictions declare which parameters they touch
//!   ([`Restriction::touched_dims`]), which is what lets the enumerator
//!   prune partial assignments at the deepest bound prefix, and they
//!   round-trip through JSON ([`Expr::to_json`]/[`Expr::from_json`]) so a
//!   whole space can be defined as data (see
//!   [`SpaceSpec`](crate::space::SpaceSpec)).
//! - bare closures ([`Restriction::new`]) — arbitrary Rust predicates,
//!   kept for tests and ad-hoc spaces. They cannot be serialized or pruned
//!   early; the enumerator checks them only on full assignments.

use crate::space::param::{PValue, Param};
use crate::util::json::Json;

/// Value lookup during expression evaluation: a full [`Assignment`], or
/// the enumerator's bound prefix of one.
pub trait VarScope {
    /// Integer view of the named parameter's current value (bools map to
    /// 0/1, floats truncate). `None` when the parameter is unbound in
    /// this scope or categorical.
    fn int(&self, name: &str) -> Option<i64>;

    /// Categorical view. `None` when unbound or not categorical.
    fn str_val(&self, name: &str) -> Option<&str>;
}

/// The one integer coercion every evaluation scope shares (bools 0/1,
/// floats truncate, categoricals unknown) — `pub(crate)` so the
/// enumerator's prefix scope cannot drift from full-assignment checks.
pub(crate) fn pvalue_int(v: &PValue) -> Option<i64> {
    match v {
        PValue::Int(x) => Some(*x),
        PValue::Bool(b) => Some(i64::from(*b)),
        PValue::Float(x) => Some(*x as i64),
        PValue::Str(_) => None,
    }
}

/// Largest integer magnitude that survives the f64-backed JSON layer
/// exactly (2^53). Serialization asserts and parsing rejects anything
/// beyond it, so precision loss is loud instead of silent.
pub(crate) const MAX_JSON_INT: i64 = 1 << 53;

/// How two configuration views can back an [`Assignment`]: a contiguous
/// row of value indices, or one row of a columnar [`SearchSpace`]
/// (struct-of-arrays storage has no contiguous row to borrow).
#[derive(Clone, Copy)]
enum IndexView<'a> {
    Row(&'a [u16]),
    Columns { columns: &'a [Vec<u16>], row: usize },
}

impl IndexView<'_> {
    #[inline]
    fn get(&self, d: usize) -> u16 {
        match self {
            IndexView::Row(r) => r[d],
            IndexView::Columns { columns, row } => columns[d][*row],
        }
    }
}

/// A typed view of one concrete parameter assignment, by name.
pub struct Assignment<'a> {
    params: &'a [Param],
    view: IndexView<'a>,
}

impl<'a> Assignment<'a> {
    pub fn new(params: &'a [Param], indices: &'a [u16]) -> Self {
        debug_assert_eq!(params.len(), indices.len());
        Assignment { params, view: IndexView::Row(indices) }
    }

    /// View of row `row` of columnar per-dimension index storage.
    pub fn from_columns(params: &'a [Param], columns: &'a [Vec<u16>], row: usize) -> Self {
        debug_assert_eq!(params.len(), columns.len());
        Assignment { params, view: IndexView::Columns { columns, row } }
    }

    fn pos(&self, name: &str) -> usize {
        self.params
            .iter()
            .position(|p| p.name == name)
            .unwrap_or_else(|| panic!("unknown parameter '{name}'"))
    }

    pub fn value(&self, name: &str) -> &PValue {
        let i = self.pos(name);
        &self.params[i].values[self.view.get(i) as usize]
    }

    /// Integer view (panics for categoricals).
    pub fn i(&self, name: &str) -> i64 {
        self.value(name).as_i64()
    }

    pub fn f(&self, name: &str) -> f64 {
        self.value(name).as_f64()
    }

    pub fn b(&self, name: &str) -> bool {
        self.value(name).as_bool()
    }

    pub fn s(&self, name: &str) -> &str {
        self.value(name).as_str()
    }
}

impl VarScope for Assignment<'_> {
    fn int(&self, name: &str) -> Option<i64> {
        let i = self.params.iter().position(|p| p.name == name)?;
        pvalue_int(&self.params[i].values[self.view.get(i) as usize])
    }

    fn str_val(&self, name: &str) -> Option<&str> {
        let i = self.params.iter().position(|p| p.name == name)?;
        match &self.params[i].values[self.view.get(i) as usize] {
            PValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Serializable restriction expression. Everything evaluates to an `i64`
/// (comparisons and boolean operators yield 0/1); a restriction holds iff
/// the expression evaluates to a non-zero value. Division/remainder by
/// zero, arithmetic overflow, and unbound or categorical `Var` reads
/// evaluate to "unknown", which fails the restriction — `And`/`Or`
/// short-circuit left to right, so guards like
/// `u == 0 || tile % u == 0` behave as written.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Lit(i64),
    /// Current value of a parameter, by name (bools read as 0/1).
    Var(String),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Mul(Box<Expr>, Box<Expr>),
    /// Integer (truncating) division.
    Div(Box<Expr>, Box<Expr>),
    Rem(Box<Expr>, Box<Expr>),
    Eq(Box<Expr>, Box<Expr>),
    Ne(Box<Expr>, Box<Expr>),
    Lt(Box<Expr>, Box<Expr>),
    Le(Box<Expr>, Box<Expr>),
    Gt(Box<Expr>, Box<Expr>),
    Ge(Box<Expr>, Box<Expr>),
    And(Vec<Expr>),
    Or(Vec<Expr>),
    Not(Box<Expr>),
    /// Categorical equality: parameter `.0`'s value equals string `.1`.
    StrEq(String, String),
}

impl Expr {
    pub fn lit(x: i64) -> Expr {
        Expr::Lit(x)
    }

    pub fn var(name: &str) -> Expr {
        Expr::Var(name.to_string())
    }

    pub fn streq(param: &str, value: &str) -> Expr {
        Expr::StrEq(param.to_string(), value.to_string())
    }

    /// Inherent arithmetic builders (callable without importing the ops
    /// traits; the `std::ops` impls below delegate here so `a * b` works
    /// too). Clippy's should_implement_trait is satisfied by those impls.
    #[allow(clippy::should_implement_trait)]
    pub fn add(self, o: Expr) -> Expr {
        Expr::Add(Box::new(self), Box::new(o))
    }

    #[allow(clippy::should_implement_trait)]
    pub fn sub(self, o: Expr) -> Expr {
        Expr::Sub(Box::new(self), Box::new(o))
    }

    #[allow(clippy::should_implement_trait)]
    pub fn mul(self, o: Expr) -> Expr {
        Expr::Mul(Box::new(self), Box::new(o))
    }

    /// Integer (truncating) division.
    #[allow(clippy::should_implement_trait)]
    pub fn div(self, o: Expr) -> Expr {
        Expr::Div(Box::new(self), Box::new(o))
    }

    #[allow(clippy::should_implement_trait)]
    pub fn rem(self, o: Expr) -> Expr {
        Expr::Rem(Box::new(self), Box::new(o))
    }

    #[allow(clippy::should_implement_trait)]
    pub fn not(self) -> Expr {
        Expr::Not(Box::new(self))
    }

    pub fn eq(self, o: Expr) -> Expr {
        Expr::Eq(Box::new(self), Box::new(o))
    }

    pub fn ne(self, o: Expr) -> Expr {
        Expr::Ne(Box::new(self), Box::new(o))
    }

    pub fn lt(self, o: Expr) -> Expr {
        Expr::Lt(Box::new(self), Box::new(o))
    }

    pub fn le(self, o: Expr) -> Expr {
        Expr::Le(Box::new(self), Box::new(o))
    }

    pub fn gt(self, o: Expr) -> Expr {
        Expr::Gt(Box::new(self), Box::new(o))
    }

    pub fn ge(self, o: Expr) -> Expr {
        Expr::Ge(Box::new(self), Box::new(o))
    }

    pub fn and(self, o: Expr) -> Expr {
        match self {
            Expr::And(mut xs) => {
                xs.push(o);
                Expr::And(xs)
            }
            s => Expr::And(vec![s, o]),
        }
    }

    pub fn or(self, o: Expr) -> Expr {
        match self {
            Expr::Or(mut xs) => {
                xs.push(o);
                Expr::Or(xs)
            }
            s => Expr::Or(vec![s, o]),
        }
    }

    /// Evaluate under `scope`; `None` means "unknown" (unbound variable,
    /// categorical integer read, division by zero, overflow) and fails
    /// the enclosing restriction.
    pub fn eval(&self, scope: &dyn VarScope) -> Option<i64> {
        match self {
            Expr::Lit(x) => Some(*x),
            Expr::Var(name) => scope.int(name),
            Expr::Add(a, b) => a.eval(scope)?.checked_add(b.eval(scope)?),
            Expr::Sub(a, b) => a.eval(scope)?.checked_sub(b.eval(scope)?),
            Expr::Mul(a, b) => a.eval(scope)?.checked_mul(b.eval(scope)?),
            Expr::Div(a, b) => a.eval(scope)?.checked_div(b.eval(scope)?),
            Expr::Rem(a, b) => a.eval(scope)?.checked_rem(b.eval(scope)?),
            Expr::Eq(a, b) => Some(i64::from(a.eval(scope)? == b.eval(scope)?)),
            Expr::Ne(a, b) => Some(i64::from(a.eval(scope)? != b.eval(scope)?)),
            Expr::Lt(a, b) => Some(i64::from(a.eval(scope)? < b.eval(scope)?)),
            Expr::Le(a, b) => Some(i64::from(a.eval(scope)? <= b.eval(scope)?)),
            Expr::Gt(a, b) => Some(i64::from(a.eval(scope)? > b.eval(scope)?)),
            Expr::Ge(a, b) => Some(i64::from(a.eval(scope)? >= b.eval(scope)?)),
            Expr::And(xs) => {
                for x in xs {
                    if x.eval(scope)? == 0 {
                        return Some(0);
                    }
                }
                Some(1)
            }
            Expr::Or(xs) => {
                for x in xs {
                    if x.eval(scope)? != 0 {
                        return Some(1);
                    }
                }
                Some(0)
            }
            Expr::Not(a) => Some(i64::from(a.eval(scope)? == 0)),
            Expr::StrEq(param, value) => Some(i64::from(scope.str_val(param)? == value)),
        }
    }

    /// Truthiness under `scope`; unknown counts as violated.
    pub fn holds(&self, scope: &dyn VarScope) -> bool {
        self.eval(scope).map_or(false, |v| v != 0)
    }

    /// Append every referenced parameter name (with duplicates) to `out`.
    pub fn collect_vars(&self, out: &mut Vec<String>) {
        match self {
            Expr::Lit(_) => {}
            Expr::Var(name) => out.push(name.clone()),
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Rem(a, b)
            | Expr::Eq(a, b)
            | Expr::Ne(a, b)
            | Expr::Lt(a, b)
            | Expr::Le(a, b)
            | Expr::Gt(a, b)
            | Expr::Ge(a, b) => {
                a.collect_vars(out);
                b.collect_vars(out);
            }
            Expr::And(xs) | Expr::Or(xs) => xs.iter().for_each(|x| x.collect_vars(out)),
            Expr::Not(a) => a.collect_vars(out),
            Expr::StrEq(param, _) => out.push(param.clone()),
        }
    }

    fn op_name(&self) -> &'static str {
        match self {
            Expr::Add(..) => "add",
            Expr::Sub(..) => "sub",
            Expr::Mul(..) => "mul",
            Expr::Div(..) => "div",
            Expr::Rem(..) => "rem",
            Expr::Eq(..) => "eq",
            Expr::Ne(..) => "ne",
            Expr::Lt(..) => "lt",
            Expr::Le(..) => "le",
            Expr::Gt(..) => "gt",
            Expr::Ge(..) => "ge",
            Expr::And(..) => "and",
            Expr::Or(..) => "or",
            Expr::Not(..) => "not",
            _ => unreachable!("op_name on a leaf"),
        }
    }

    /// JSON form: `{"lit": n}`, `{"var": "NAME"}`,
    /// `{"op": "<name>", "args": [...]}`, and
    /// `{"op": "streq", "param": "...", "value": "..."}`.
    pub fn to_json(&self) -> Json {
        match self {
            Expr::Lit(x) => {
                assert!(
                    x.abs() <= MAX_JSON_INT,
                    "literal {x} exceeds the JSON-exact integer range (±2^53)"
                );
                Json::obj().set("lit", *x)
            }
            Expr::Var(name) => Json::obj().set("var", name.as_str()),
            Expr::StrEq(param, value) => Json::obj()
                .set("op", "streq")
                .set("param", param.as_str())
                .set("value", value.as_str()),
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::Div(a, b)
            | Expr::Rem(a, b)
            | Expr::Eq(a, b)
            | Expr::Ne(a, b)
            | Expr::Lt(a, b)
            | Expr::Le(a, b)
            | Expr::Gt(a, b)
            | Expr::Ge(a, b) => Json::obj()
                .set("op", self.op_name())
                .set("args", Json::Arr(vec![a.to_json(), b.to_json()])),
            Expr::And(xs) | Expr::Or(xs) => Json::obj()
                .set("op", self.op_name())
                .set("args", Json::Arr(xs.iter().map(Expr::to_json).collect())),
            Expr::Not(a) => {
                Json::obj().set("op", "not").set("args", Json::Arr(vec![a.to_json()]))
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Expr, String> {
        if let Some(lit) = j.get("lit") {
            let x = lit.as_f64().ok_or("'lit' must be a number")?;
            if x != x.trunc() {
                return Err(format!("'lit' must be an integer, got {x}"));
            }
            if x.abs() > MAX_JSON_INT as f64 {
                return Err(format!("'lit' {x} exceeds the JSON-exact integer range (±2^53)"));
            }
            return Ok(Expr::Lit(x as i64));
        }
        if let Some(var) = j.get("var") {
            return Ok(Expr::var(var.as_str().ok_or("'var' must be a string")?));
        }
        let op = j.get("op").and_then(Json::as_str).ok_or("expression needs 'lit', 'var', or 'op'")?;
        if op == "streq" {
            let param = j.get("param").and_then(Json::as_str).ok_or("streq needs 'param'")?;
            let value = j.get("value").and_then(Json::as_str).ok_or("streq needs 'value'")?;
            return Ok(Expr::streq(param, value));
        }
        let args: Vec<Expr> = j
            .get("args")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("op '{op}' needs 'args'"))?
            .iter()
            .map(Expr::from_json)
            .collect::<Result<_, _>>()?;
        let binary = |op: &str, mut args: Vec<Expr>| -> Result<(Box<Expr>, Box<Expr>), String> {
            if args.len() != 2 {
                return Err(format!("op '{op}' takes exactly 2 args, got {}", args.len()));
            }
            let b = Box::new(args.pop().expect("len checked"));
            let a = Box::new(args.pop().expect("len checked"));
            Ok((a, b))
        };
        Ok(match op {
            "add" => binary(op, args).map(|(a, b)| Expr::Add(a, b))?,
            "sub" => binary(op, args).map(|(a, b)| Expr::Sub(a, b))?,
            "mul" => binary(op, args).map(|(a, b)| Expr::Mul(a, b))?,
            "div" => binary(op, args).map(|(a, b)| Expr::Div(a, b))?,
            "rem" | "mod" => binary(op, args).map(|(a, b)| Expr::Rem(a, b))?,
            "eq" => binary(op, args).map(|(a, b)| Expr::Eq(a, b))?,
            "ne" => binary(op, args).map(|(a, b)| Expr::Ne(a, b))?,
            "lt" => binary(op, args).map(|(a, b)| Expr::Lt(a, b))?,
            "le" => binary(op, args).map(|(a, b)| Expr::Le(a, b))?,
            "gt" => binary(op, args).map(|(a, b)| Expr::Gt(a, b))?,
            "ge" => binary(op, args).map(|(a, b)| Expr::Ge(a, b))?,
            "and" => {
                if args.len() < 2 {
                    return Err("'and' takes at least 2 args".into());
                }
                Expr::And(args)
            }
            "or" => {
                if args.len() < 2 {
                    return Err("'or' takes at least 2 args".into());
                }
                Expr::Or(args)
            }
            "not" => {
                if args.len() != 1 {
                    return Err("'not' takes exactly 1 arg".into());
                }
                Expr::Not(Box::new(args.into_iter().next().expect("len checked")))
            }
            other => return Err(format!("unknown expression op '{other}'")),
        })
    }
}

// Operator sugar (`a * b`, `!a`, …) delegating to the inherent builders.
impl std::ops::Add for Expr {
    type Output = Expr;
    fn add(self, o: Expr) -> Expr {
        Expr::add(self, o)
    }
}

impl std::ops::Sub for Expr {
    type Output = Expr;
    fn sub(self, o: Expr) -> Expr {
        Expr::sub(self, o)
    }
}

impl std::ops::Mul for Expr {
    type Output = Expr;
    fn mul(self, o: Expr) -> Expr {
        Expr::mul(self, o)
    }
}

impl std::ops::Div for Expr {
    type Output = Expr;
    fn div(self, o: Expr) -> Expr {
        Expr::div(self, o)
    }
}

impl std::ops::Rem for Expr {
    type Output = Expr;
    fn rem(self, o: Expr) -> Expr {
        Expr::rem(self, o)
    }
}

impl std::ops::Not for Expr {
    type Output = Expr;
    fn not(self) -> Expr {
        Expr::not(self)
    }
}

impl std::fmt::Display for Expr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Expr::Lit(x) => write!(f, "{x}"),
            Expr::Var(name) => write!(f, "{name}"),
            Expr::Add(a, b) => write!(f, "({a} + {b})"),
            Expr::Sub(a, b) => write!(f, "({a} - {b})"),
            Expr::Mul(a, b) => write!(f, "({a} * {b})"),
            Expr::Div(a, b) => write!(f, "({a} / {b})"),
            Expr::Rem(a, b) => write!(f, "({a} % {b})"),
            Expr::Eq(a, b) => write!(f, "({a} == {b})"),
            Expr::Ne(a, b) => write!(f, "({a} != {b})"),
            Expr::Lt(a, b) => write!(f, "({a} < {b})"),
            Expr::Le(a, b) => write!(f, "({a} <= {b})"),
            Expr::Gt(a, b) => write!(f, "({a} > {b})"),
            Expr::Ge(a, b) => write!(f, "({a} >= {b})"),
            Expr::And(xs) => {
                write!(f, "(")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, " && ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, ")")
            }
            Expr::Or(xs) => {
                write!(f, "(")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, " || ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, ")")
            }
            Expr::Not(a) => write!(f, "!{a}"),
            Expr::StrEq(p, v) => write!(f, "({p} == \"{v}\")"),
        }
    }
}

enum RestrictionKind {
    Pred(Box<dyn Fn(&Assignment) -> bool + Send + Sync>),
    Expr(Expr),
}

/// A named restriction predicate: a serializable [`Expr`] or a bare
/// closure (see the module docs for the trade-off).
pub struct Restriction {
    pub name: String,
    kind: RestrictionKind,
}

impl Restriction {
    pub fn new(name: &str, pred: impl Fn(&Assignment) -> bool + Send + Sync + 'static) -> Self {
        Restriction { name: name.into(), kind: RestrictionKind::Pred(Box::new(pred)) }
    }

    /// DSL-backed restriction, named by the expression's rendering.
    pub fn expr(e: Expr) -> Self {
        Restriction { name: e.to_string(), kind: RestrictionKind::Expr(e) }
    }

    pub fn named_expr(name: &str, e: Expr) -> Self {
        Restriction { name: name.into(), kind: RestrictionKind::Expr(e) }
    }

    pub fn check(&self, a: &Assignment) -> bool {
        match &self.kind {
            RestrictionKind::Pred(p) => p(a),
            RestrictionKind::Expr(e) => e.holds(a),
        }
    }

    /// The underlying expression, when this restriction is DSL-backed.
    pub fn as_expr(&self) -> Option<&Expr> {
        match &self.kind {
            RestrictionKind::Expr(e) => Some(e),
            RestrictionKind::Pred(_) => None,
        }
    }

    /// Dimension indices this restriction reads, when statically known
    /// (expression restrictions only — closures are opaque). Panics on a
    /// reference to a parameter that does not exist, surfacing typos at
    /// space-build time instead of silently never pruning.
    pub fn touched_dims(&self, params: &[Param]) -> Option<Vec<usize>> {
        let e = self.as_expr()?;
        let mut names = Vec::new();
        e.collect_vars(&mut names);
        let mut dims: Vec<usize> = names
            .iter()
            .map(|n| {
                params.iter().position(|p| &p.name == n).unwrap_or_else(|| {
                    panic!("restriction '{}' references unknown parameter '{n}'", self.name)
                })
            })
            .collect();
        // ktbo-lint: allow(stable-sort-tiebreak): usize dims are unique after dedup — no tie to break
        dims.sort_unstable();
        dims.dedup();
        Some(dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> Vec<Param> {
        vec![
            Param::ints("bx", &[16, 32, 64]),
            Param::ints("by", &[1, 2, 4]),
            Param::bools("pad"),
        ]
    }

    #[test]
    fn assignment_typed_access() {
        let ps = params();
        let idx = [2u16, 0, 1];
        let a = Assignment::new(&ps, &idx);
        assert_eq!(a.i("bx"), 64);
        assert_eq!(a.i("by"), 1);
        assert!(a.b("pad"));
        assert_eq!(a.f("bx"), 64.0);
    }

    #[test]
    fn assignment_from_columns_matches_row_view() {
        let ps = params();
        let columns = vec![vec![0u16, 2], vec![1u16, 0], vec![1u16, 0]];
        let a = Assignment::from_columns(&ps, &columns, 0);
        assert_eq!(a.i("bx"), 16);
        assert_eq!(a.i("by"), 2);
        assert!(a.b("pad"));
        let b = Assignment::from_columns(&ps, &columns, 1);
        assert_eq!(b.i("bx"), 64);
        assert!(!b.b("pad"));
    }

    #[test]
    #[should_panic(expected = "unknown parameter")]
    fn unknown_param_panics() {
        let ps = params();
        let idx = [0u16, 0, 0];
        Assignment::new(&ps, &idx).i("nope");
    }

    #[test]
    fn restriction_checks() {
        let ps = params();
        let r = Restriction::new("threads<=128", |a| a.i("bx") * a.i("by") <= 128);
        let ok = [1u16, 1, 0]; // 32*2 = 64
        let bad = [2u16, 2, 0]; // 64*4 = 256
        assert!(r.check(&Assignment::new(&ps, &ok)));
        assert!(!r.check(&Assignment::new(&ps, &bad)));
    }

    #[test]
    fn expr_restriction_matches_closure() {
        let ps = params();
        let closure = Restriction::new("t<=128", |a| a.i("bx") * a.i("by") <= 128);
        let dsl = Restriction::expr(Expr::var("bx").mul(Expr::var("by")).le(Expr::lit(128)));
        for bx in 0..3u16 {
            for by in 0..3u16 {
                for pad in 0..2u16 {
                    let idx = [bx, by, pad];
                    let a = Assignment::new(&ps, &idx);
                    assert_eq!(closure.check(&a), dsl.check(&a), "at {idx:?}");
                }
            }
        }
    }

    #[test]
    fn expr_booleans_read_as_01() {
        let ps = params();
        let padded = [0u16, 0, 1];
        let bare = [0u16, 0, 0];
        let e = Expr::var("pad").eq(Expr::lit(1));
        assert!(e.holds(&Assignment::new(&ps, &padded)));
        assert!(!e.holds(&Assignment::new(&ps, &bare)));
    }

    #[test]
    fn division_by_zero_fails_but_guards_short_circuit() {
        let ps = vec![Param::ints("u", &[0, 2]), Param::ints("t", &[4])];
        let bare_rem = Expr::var("t").rem(Expr::var("u")).eq(Expr::lit(0));
        let guarded = Expr::var("u").eq(Expr::lit(0)).or(bare_rem.clone());
        let zero = [0u16, 0];
        let two = [1u16, 0];
        assert!(!bare_rem.holds(&Assignment::new(&ps, &zero)), "t % 0 is unknown => violated");
        assert!(guarded.holds(&Assignment::new(&ps, &zero)), "guard short-circuits");
        assert!(guarded.holds(&Assignment::new(&ps, &two)), "4 % 2 == 0");
    }

    #[test]
    fn and_short_circuits_on_false() {
        let ps = vec![Param::ints("a", &[0, 1]), Param::ints("b", &[1])];
        // (a > 0) && (b % a == 0): with a == 0 the right side would be
        // unknown, but the left side already decides.
        let e = Expr::var("a")
            .gt(Expr::lit(0))
            .and(Expr::var("b").rem(Expr::var("a")).eq(Expr::lit(0)));
        assert_eq!(e.eval(&Assignment::new(&ps, &[0u16, 0])), Some(0));
        assert_eq!(e.eval(&Assignment::new(&ps, &[1u16, 0])), Some(1));
    }

    #[test]
    fn streq_matches_categoricals() {
        let ps = vec![Param::cats("method", &["scan", "tree"])];
        let e = Expr::streq("method", "tree");
        assert!(!e.holds(&Assignment::new(&ps, &[0u16])));
        assert!(e.holds(&Assignment::new(&ps, &[1u16])));
        // Integer reads of categoricals are unknown, not a panic.
        assert_eq!(Expr::var("method").eval(&Assignment::new(&ps, &[0u16])), None);
    }

    #[test]
    fn touched_dims_reported_for_exprs_only() {
        let ps = params();
        let dsl = Restriction::expr(Expr::var("pad").eq(Expr::lit(0)).or(Expr::var("bx").ge(Expr::lit(32))));
        assert_eq!(dsl.touched_dims(&ps), Some(vec![0, 2]));
        let closure = Restriction::new("opaque", |_| true);
        assert_eq!(closure.touched_dims(&ps), None);
    }

    #[test]
    #[should_panic(expected = "unknown parameter 'typo'")]
    fn touched_dims_rejects_unknown_names() {
        let ps = params();
        let r = Restriction::expr(Expr::var("typo").gt(Expr::lit(0)));
        let _ = r.touched_dims(&ps);
    }

    #[test]
    fn expr_json_roundtrip() {
        let exprs = [
            Expr::var("KWG").rem(Expr::var("KWI")).eq(Expr::lit(0)),
            Expr::var("a")
                .mul(Expr::var("b"))
                .div(Expr::var("c"))
                .gt(Expr::lit(0))
                .and(Expr::var("d").le(Expr::lit(1024)))
                .or(Expr::lit(1).ne(Expr::lit(2))),
            Expr::var("x").add(Expr::lit(-3)).sub(Expr::var("y")).lt(Expr::lit(7)),
            Expr::var("p").ge(Expr::lit(2)).not(),
            Expr::streq("method", "bit-trick"),
        ];
        for e in exprs {
            let text = e.to_json().render();
            let parsed = Expr::from_json(&crate::util::jsonparse::parse(&text).unwrap()).unwrap();
            assert_eq!(parsed, e, "round-trip failed for {e}");
        }
    }

    #[test]
    fn expr_json_rejects_malformed() {
        for bad in [
            r#"{"op":"mul","args":[{"lit":1}]}"#,
            r#"{"op":"warp","args":[{"lit":1},{"lit":2}]}"#,
            r#"{"lit":1.5}"#,
            r#"{"op":"not","args":[]}"#,
            r#"{"args":[]}"#,
            r#"{"lit":9007199254740994}"#, // past 2^53: not f64-exact
        ] {
            let j = crate::util::jsonparse::parse(bad).unwrap();
            assert!(Expr::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn display_renders_infix() {
        let e = Expr::var("MWG").rem(Expr::var("MDIMC").mul(Expr::var("VWM"))).eq(Expr::lit(0));
        assert_eq!(e.to_string(), "((MWG % (MDIMC * VWM)) == 0)");
    }
}
