//! Search-space restrictions (Kernel Tuner's `restrictions=`).
//!
//! A restriction is a named predicate over a full parameter assignment.
//! Restrictions model what the paper calls the *first stage* of invalidity
//! detection: checking individual-parameter / cross-parameter validity
//! against the programming-model specification *before* compile time.
//! Configurations failing a restriction are excluded from the search space
//! entirely (they are not "invalid configs" in the Table II sense — those
//! are discovered at compile/run time by the objective).

use crate::space::param::{PValue, Param};

/// A typed view of one concrete parameter assignment, by name.
pub struct Assignment<'a> {
    params: &'a [Param],
    indices: &'a [u16],
}

impl<'a> Assignment<'a> {
    pub fn new(params: &'a [Param], indices: &'a [u16]) -> Self {
        debug_assert_eq!(params.len(), indices.len());
        Assignment { params, indices }
    }

    fn pos(&self, name: &str) -> usize {
        self.params
            .iter()
            .position(|p| p.name == name)
            .unwrap_or_else(|| panic!("unknown parameter '{name}'"))
    }

    pub fn value(&self, name: &str) -> &PValue {
        let i = self.pos(name);
        &self.params[i].values[self.indices[i] as usize]
    }

    /// Integer view (panics for categoricals).
    pub fn i(&self, name: &str) -> i64 {
        self.value(name).as_i64()
    }

    pub fn f(&self, name: &str) -> f64 {
        self.value(name).as_f64()
    }

    pub fn b(&self, name: &str) -> bool {
        self.value(name).as_bool()
    }

    pub fn s(&self, name: &str) -> &str {
        self.value(name).as_str()
    }
}

/// A named restriction predicate.
pub struct Restriction {
    pub name: String,
    pub pred: Box<dyn Fn(&Assignment) -> bool + Send + Sync>,
}

impl Restriction {
    pub fn new(name: &str, pred: impl Fn(&Assignment) -> bool + Send + Sync + 'static) -> Self {
        Restriction { name: name.into(), pred: Box::new(pred) }
    }

    pub fn check(&self, a: &Assignment) -> bool {
        (self.pred)(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> Vec<Param> {
        vec![
            Param::ints("bx", &[16, 32, 64]),
            Param::ints("by", &[1, 2, 4]),
            Param::bools("pad"),
        ]
    }

    #[test]
    fn assignment_typed_access() {
        let ps = params();
        let idx = [2u16, 0, 1];
        let a = Assignment::new(&ps, &idx);
        assert_eq!(a.i("bx"), 64);
        assert_eq!(a.i("by"), 1);
        assert!(a.b("pad"));
        assert_eq!(a.f("bx"), 64.0);
    }

    #[test]
    #[should_panic(expected = "unknown parameter")]
    fn unknown_param_panics() {
        let ps = params();
        let idx = [0u16, 0, 0];
        Assignment::new(&ps, &idx).i("nope");
    }

    #[test]
    fn restriction_checks() {
        let ps = params();
        let r = Restriction::new("threads<=128", |a| a.i("bx") * a.i("by") <= 128);
        let ok = [1u16, 1, 0]; // 32*2 = 64
        let bad = [2u16, 2, 0]; // 64*4 = 256
        assert!(r.check(&Assignment::new(&ps, &ok)));
        assert!(!r.check(&Assignment::new(&ps, &bad)));
    }
}
