//! Search-space engine: tunable parameters, restrictions, enumeration,
//! normalization (§III-D), and neighborhood operators for the
//! local-search baselines.

pub mod constraint;
pub mod neighbors;
pub mod param;
#[allow(clippy::module_inception)]
pub mod space;

pub use constraint::{Assignment, Restriction};
pub use neighbors::{neighbors, Neighborhood};
pub use param::{PValue, Param};
pub use space::{Config, SearchSpace};
