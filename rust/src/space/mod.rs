//! Search-space engine: tunable parameters, a declarative serializable
//! space specification ([`SpaceSpec`]), restrictions (closures or the
//! [`Expr`] DSL), constraint-propagating enumeration (§III-D, serial or
//! shard-parallel), a columnar zero-copy [`SearchSpace`] core (packed
//! mixed-radix keys, alloc-free index, shard-aligned `f32` normalized
//! tiles), and key-probe neighborhood operators for the local-search
//! baselines.

pub mod constraint;
pub mod neighbors;
pub mod param;
#[allow(clippy::module_inception)]
pub mod space;
pub mod spec;
pub mod view;

pub use constraint::{Assignment, Expr, Restriction, VarScope};
pub use neighbors::{neighbors, Neighborhood};
pub use param::{PValue, Param};
pub use space::{Config, SearchSpace};
pub use spec::{ParamSpec, RestrictionSpec, SpaceSpec};
pub use view::{EagerView, LazyView, SpaceView};

/// Test support: the seed-era serial odometer enumerator, kept verbatim
/// as the single ordering/membership reference that both the space
/// tests and the kernel tests assert the columnar enumerator against.
#[cfg(test)]
pub(crate) mod testref {
    use crate::space::constraint::{Assignment, Restriction};
    use crate::space::param::Param;
    use crate::space::space::Config;

    pub(crate) fn odometer_reference(
        params: &[Param],
        restrictions: &[Restriction],
    ) -> Vec<Config> {
        let dims = params.len();
        let mut configs = Vec::new();
        let mut cursor: Config = vec![0; dims];
        loop {
            let a = Assignment::new(params, &cursor);
            if restrictions.iter().all(|r| r.check(&a)) {
                configs.push(cursor.clone());
            }
            let mut d = dims;
            loop {
                if d == 0 {
                    return configs;
                }
                d -= 1;
                cursor[d] += 1;
                if (cursor[d] as usize) < params[d].len() {
                    break;
                }
                cursor[d] = 0;
            }
        }
    }
}
