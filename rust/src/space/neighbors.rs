//! Neighborhood operators over the restricted space.
//!
//! The local-search baselines (MLS, SA) and the GA mutation operator walk
//! the space through neighborhoods, mirroring Kernel Tuner's
//! `get_neighbors` with its "Hamming" and "adjacent" strategies:
//!
//! - *Hamming*: configs differing in exactly one parameter (any value).
//! - *Adjacent*: configs where every parameter index moved by at most 1,
//!   and at least one moved.
//!
//! Probes run on packed mixed-radix keys: a one-dimension move from key
//! `k` is `k ± delta · stride[d]`, answered by the space's alloc-free key
//! index — no per-probe `Vec` clone or re-hash of a whole config (the
//! seed-era operators cloned and hashed a `Vec<u16>` per candidate).
//!
//! Restricted spaces make neighborhoods irregular — a Hamming move can
//! land outside the space — so all operators filter through the key index
//! and can therefore return fewer (or **zero**) neighbors; SA/MLS/ILS are
//! tested against fully isolated configs (see their `empty neighborhood`
//! tests and `isolated_configs_have_no_neighbors` below).

use crate::space::space::SearchSpace;

/// Neighborhood flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Neighborhood {
    Hamming,
    Adjacent,
}

/// All neighbors of `idx` under the given flavor, as space indices.
pub fn neighbors(space: &SearchSpace, idx: usize, kind: Neighborhood) -> Vec<usize> {
    match kind {
        Neighborhood::Hamming => hamming(space, idx),
        Neighborhood::Adjacent => adjacent(space, idx),
    }
}

/// Key after moving dimension `d` from value index `from` to `to`.
/// Exact for every valid pair: the subtraction cannot underflow the true
/// (mathematical) key, only the intermediate, so wrapping ops are used.
#[inline]
fn rekey(key: u64, stride: u64, from: u16, to: u16) -> u64 {
    key.wrapping_add(u64::from(to).wrapping_mul(stride))
        .wrapping_sub(u64::from(from).wrapping_mul(stride))
}

fn hamming(space: &SearchSpace, idx: usize) -> Vec<usize> {
    let base_key = space.key(idx);
    let mut out = Vec::new();
    for d in 0..space.dims() {
        let orig = space.value_index(idx, d);
        let stride = space.strides()[d];
        for v in 0..space.params[d].len() as u16 {
            if v == orig {
                continue;
            }
            if let Some(j) = space.index_of_key(rekey(base_key, stride, orig, v)) {
                out.push(j);
            }
        }
    }
    out
}

/// Key and new value index after a ±1 step in dimension `d`, or `None`
/// at the domain boundary.
#[inline]
fn step_key(space: &SearchSpace, key: u64, d: usize, cur: u16, delta: i32) -> Option<(u64, u16)> {
    let next = cur as i32 + delta;
    if next < 0 || next as usize >= space.params[d].len() {
        return None;
    }
    let next = next as u16;
    Some((rekey(key, space.strides()[d], cur, next), next))
}

fn adjacent(space: &SearchSpace, idx: usize) -> Vec<usize> {
    let base_key = space.key(idx);
    let dims = space.dims();
    let mut out = Vec::new();
    // Enumerate {-1, 0, +1}^dims deltas, skipping the zero delta. dims ≤ 15
    // so 3^dims can be large; restrict to deltas touching ≤ 2 params, which
    // matches Kernel Tuner's practical behaviour of small adjacent moves
    // while keeping enumeration cheap.
    for d1 in 0..dims {
        let cur1 = space.value_index(idx, d1);
        for s1 in [-1i32, 1] {
            let Some((k1, _)) = step_key(space, base_key, d1, cur1, s1) else { continue };
            if let Some(j) = space.index_of_key(k1) {
                out.push(j);
            }
            for d2 in d1 + 1..dims {
                let cur2 = space.value_index(idx, d2);
                for s2 in [-1i32, 1] {
                    if let Some((k2, _)) = step_key(space, k1, d2, cur2, s2) {
                        if let Some(j) = space.index_of_key(k2) {
                            out.push(j);
                        }
                    }
                }
            }
        }
    }
    // ktbo-lint: allow(stable-sort-tiebreak): usize indices are unique after dedup — no tie to break
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::constraint::{Expr, Restriction};
    use crate::space::param::Param;

    fn space() -> SearchSpace {
        let params = vec![Param::ints("a", &[1, 2, 3, 4]), Param::ints("b", &[10, 20, 30])];
        SearchSpace::build("toy", params, &[])
    }

    fn restricted() -> SearchSpace {
        let params = vec![Param::ints("a", &[1, 2, 3, 4]), Param::ints("b", &[10, 20, 30])];
        let r = vec![Restriction::new("a+b/10<=5", |x| x.i("a") + x.i("b") / 10 <= 5)];
        SearchSpace::build("toy-r", params, &r)
    }

    /// Every config isolated: y == 2x leaves no one-parameter move and no
    /// ±1 adjacent move inside the space.
    fn isolated() -> SearchSpace {
        let params = vec![
            Param::ints("x", &(0..5).collect::<Vec<_>>()),
            Param::ints("y", &(0..9).collect::<Vec<_>>()),
        ];
        let r = vec![Restriction::expr(Expr::var("y").eq(Expr::var("x").mul(Expr::lit(2))))];
        SearchSpace::build("iso", params, &r)
    }

    #[test]
    fn hamming_counts_in_free_space() {
        let s = space();
        let idx = s.index_of(&[0, 0]).unwrap();
        // (4-1) + (3-1) = 5 Hamming neighbors.
        assert_eq!(neighbors(&s, idx, Neighborhood::Hamming).len(), 5);
    }

    #[test]
    fn hamming_neighbors_differ_in_one_param() {
        let s = space();
        for i in 0..s.len() {
            for j in neighbors(&s, i, Neighborhood::Hamming) {
                let diff = s
                    .config(i)
                    .iter()
                    .zip(s.config(j))
                    .filter(|(x, y)| *x != y)
                    .count();
                assert_eq!(diff, 1);
            }
        }
    }

    #[test]
    fn adjacent_moves_bounded() {
        let s = space();
        for i in 0..s.len() {
            for j in neighbors(&s, i, Neighborhood::Adjacent) {
                assert_ne!(i, j);
                for (x, y) in s.config(i).iter().zip(s.config(j)) {
                    assert!((*x as i32 - y as i32).abs() <= 1);
                }
            }
        }
    }

    #[test]
    fn restricted_neighbors_stay_valid() {
        let s = restricted();
        for i in 0..s.len() {
            for kind in [Neighborhood::Hamming, Neighborhood::Adjacent] {
                for j in neighbors(&s, i, kind) {
                    assert!(j < s.len());
                    let a = s.assignment(j);
                    assert!(a.i("a") + a.i("b") / 10 <= 5);
                }
            }
        }
    }

    #[test]
    fn no_self_neighbor_no_dupes() {
        let s = space();
        for i in 0..s.len() {
            for kind in [Neighborhood::Hamming, Neighborhood::Adjacent] {
                let ns = neighbors(&s, i, kind);
                assert!(!ns.contains(&i));
                let mut sorted = ns.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), ns.len());
            }
        }
    }

    /// Key-probe results must equal what the seed-era clone-and-hash
    /// operators produced: brute-force over all config pairs.
    #[test]
    fn key_probes_match_brute_force() {
        for s in [space(), restricted()] {
            for i in 0..s.len() {
                let ci = s.config(i);
                let mut ham: Vec<usize> = Vec::new();
                let mut adj: Vec<usize> = Vec::new();
                for j in 0..s.len() {
                    if i == j {
                        continue;
                    }
                    let cj = s.config(j);
                    let diffs = ci.iter().zip(&cj).filter(|(a, b)| a != b).count();
                    if diffs == 1 {
                        ham.push(j);
                    }
                    if diffs >= 1
                        && diffs <= 2
                        && ci.iter().zip(&cj).all(|(a, b)| (*a as i32 - *b as i32).abs() <= 1)
                    {
                        adj.push(j);
                    }
                }
                let mut got_ham = neighbors(&s, i, Neighborhood::Hamming);
                got_ham.sort_unstable();
                assert_eq!(got_ham, ham, "{}: hamming mismatch at {i}", s.name);
                assert_eq!(neighbors(&s, i, Neighborhood::Adjacent), adj, "{}: adjacent mismatch at {i}", s.name);
            }
        }
    }

    /// Heavily restricted spaces can isolate configs entirely — the
    /// operators must report empty neighborhoods, not panic.
    #[test]
    fn isolated_configs_have_no_neighbors() {
        let s = isolated();
        assert_eq!(s.len(), 5, "one config per x value");
        for i in 0..s.len() {
            assert!(neighbors(&s, i, Neighborhood::Hamming).is_empty());
            assert!(neighbors(&s, i, Neighborhood::Adjacent).is_empty());
        }
    }
}
