//! Neighborhood operators over the restricted space.
//!
//! The local-search baselines (MLS, SA) and the GA mutation operator walk
//! the space through neighborhoods, mirroring Kernel Tuner's
//! `get_neighbors` with its "Hamming" and "adjacent" strategies:
//!
//! - *Hamming*: configs differing in exactly one parameter (any value).
//! - *Adjacent*: configs where every parameter index moved by at most 1,
//!   and at least one moved.
//!
//! Restricted spaces make neighborhoods irregular — a Hamming move can
//! land outside the space — so all operators filter through the space
//! index and can therefore return fewer (or zero) neighbors.

use crate::space::space::{Config, SearchSpace};

/// Neighborhood flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Neighborhood {
    Hamming,
    Adjacent,
}

/// All neighbors of `idx` under the given flavor, as space indices.
pub fn neighbors(space: &SearchSpace, idx: usize, kind: Neighborhood) -> Vec<usize> {
    match kind {
        Neighborhood::Hamming => hamming(space, idx),
        Neighborhood::Adjacent => adjacent(space, idx),
    }
}

fn hamming(space: &SearchSpace, idx: usize) -> Vec<usize> {
    let base = space.config(idx).clone();
    let mut out = Vec::new();
    for d in 0..space.dims() {
        let orig = base[d];
        let mut cand: Config = base.clone();
        for v in 0..space.params[d].len() as u16 {
            if v == orig {
                continue;
            }
            cand[d] = v;
            if let Some(j) = space.index_of(&cand) {
                out.push(j);
            }
        }
    }
    out
}

fn adjacent(space: &SearchSpace, idx: usize) -> Vec<usize> {
    let base = space.config(idx).clone();
    let dims = space.dims();
    let mut out = Vec::new();
    // Enumerate {-1, 0, +1}^dims deltas, skipping the zero delta. dims ≤ 15
    // so 3^dims can be large; restrict to deltas touching ≤ 2 params, which
    // matches Kernel Tuner's practical behaviour of small adjacent moves
    // while keeping enumeration cheap.
    for d1 in 0..dims {
        for s1 in [-1i32, 1] {
            let Some(c1) = step(&base, d1, s1, space) else { continue };
            if let Some(j) = space.index_of(&c1) {
                out.push(j);
            }
            for d2 in d1 + 1..dims {
                for s2 in [-1i32, 1] {
                    if let Some(c2) = step(&c1, d2, s2, space) {
                        if let Some(j) = space.index_of(&c2) {
                            out.push(j);
                        }
                    }
                }
            }
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

fn step(cfg: &Config, d: usize, delta: i32, space: &SearchSpace) -> Option<Config> {
    let cur = cfg[d] as i32;
    let next = cur + delta;
    if next < 0 || next as usize >= space.params[d].len() {
        return None;
    }
    let mut out = cfg.clone();
    out[d] = next as u16;
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::constraint::Restriction;
    use crate::space::param::Param;

    fn space() -> SearchSpace {
        let params = vec![Param::ints("a", &[1, 2, 3, 4]), Param::ints("b", &[10, 20, 30])];
        SearchSpace::build("toy", params, &[])
    }

    fn restricted() -> SearchSpace {
        let params = vec![Param::ints("a", &[1, 2, 3, 4]), Param::ints("b", &[10, 20, 30])];
        let r = vec![Restriction::new("a+b/10<=5", |x| x.i("a") + x.i("b") / 10 <= 5)];
        SearchSpace::build("toy-r", params, &r)
    }

    #[test]
    fn hamming_counts_in_free_space() {
        let s = space();
        let idx = s.index_of(&vec![0, 0]).unwrap();
        // (4-1) + (3-1) = 5 Hamming neighbors.
        assert_eq!(neighbors(&s, idx, Neighborhood::Hamming).len(), 5);
    }

    #[test]
    fn hamming_neighbors_differ_in_one_param() {
        let s = space();
        for i in 0..s.len() {
            for j in neighbors(&s, i, Neighborhood::Hamming) {
                let diff = s
                    .config(i)
                    .iter()
                    .zip(s.config(j))
                    .filter(|(x, y)| x != y)
                    .count();
                assert_eq!(diff, 1);
            }
        }
    }

    #[test]
    fn adjacent_moves_bounded() {
        let s = space();
        for i in 0..s.len() {
            for j in neighbors(&s, i, Neighborhood::Adjacent) {
                assert_ne!(i, j);
                for (x, y) in s.config(i).iter().zip(s.config(j)) {
                    assert!((*x as i32 - *y as i32).abs() <= 1);
                }
            }
        }
    }

    #[test]
    fn restricted_neighbors_stay_valid() {
        let s = restricted();
        for i in 0..s.len() {
            for kind in [Neighborhood::Hamming, Neighborhood::Adjacent] {
                for j in neighbors(&s, i, kind) {
                    assert!(j < s.len());
                    let a = s.assignment(j);
                    assert!(a.i("a") + a.i("b") / 10 <= 5);
                }
            }
        }
    }

    #[test]
    fn no_self_neighbor_no_dupes() {
        let s = space();
        for i in 0..s.len() {
            for kind in [Neighborhood::Hamming, Neighborhood::Adjacent] {
                let ns = neighbors(&s, i, kind);
                assert!(!ns.contains(&i));
                let mut sorted = ns.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), ns.len());
            }
        }
    }
}
