//! Process metrics: named counters, gauges, and fixed-bound histograms.
//!
//! Two usage patterns:
//!
//! - [`global()`] — the process-wide registry, for tallies that cross
//!   subsystem boundaries (the sweep orchestrator counts completed and
//!   failed cells there).
//! - An owned [`MetricsRegistry`] — the serve daemon embeds its own so
//!   the `metrics` wire verb reports *that daemon's* traffic, and
//!   parallel test servers don't bleed counts into each other.
//!
//! Snapshots render deterministically (`BTreeMap` name order,
//! insertion-order JSON) so wire replies and artifacts diff cleanly.
//! Nothing on the deterministic trace path reads a metric back.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

use crate::util::json::Json;

enum Metric {
    Counter(u64),
    Gauge(f64),
    Histogram { bounds: Vec<f64>, counts: Vec<u64>, count: u64, sum: f64 },
}

/// A named metric store. All methods take `&self`; lock poisoning is
/// recovered (metrics must never take a process down).
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

/// The process-wide registry.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: MetricsRegistry = MetricsRegistry::new();
    &GLOBAL
}

impl MetricsRegistry {
    pub const fn new() -> MetricsRegistry {
        MetricsRegistry { inner: Mutex::new(BTreeMap::new()) }
    }

    fn lock(&self) -> MutexGuard<'_, BTreeMap<String, Metric>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Add `delta` to counter `name` (created at zero on first use).
    /// A name already registered as another kind is left untouched —
    /// metrics never panic over a naming collision.
    pub fn counter(&self, name: &str, delta: u64) {
        let mut m = self.lock();
        if let Metric::Counter(v) = m.entry(name.to_string()).or_insert(Metric::Counter(0)) {
            *v += delta;
        }
    }

    /// Set gauge `name` to `value`.
    pub fn gauge(&self, name: &str, value: f64) {
        let mut m = self.lock();
        if let Metric::Gauge(v) = m.entry(name.to_string()).or_insert(Metric::Gauge(0.0)) {
            *v = value;
        }
    }

    /// Record `value` into histogram `name` with fixed bucket `bounds`
    /// (upper-inclusive, ascending; an implicit +inf bucket catches the
    /// rest). Bounds are fixed by the first call; later calls reuse
    /// them.
    pub fn observe(&self, name: &str, bounds: &[f64], value: f64) {
        let mut m = self.lock();
        let metric = m.entry(name.to_string()).or_insert_with(|| Metric::Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            count: 0,
            sum: 0.0,
        });
        if let Metric::Histogram { bounds, counts, count, sum } = metric {
            let slot = bounds.iter().position(|b| value <= *b).unwrap_or(bounds.len());
            counts[slot] += 1;
            *count += 1;
            *sum += value;
        }
    }

    /// Current value of counter `name` (zero when absent or not a
    /// counter) — the convenient form for tests and status folding.
    pub fn counter_value(&self, name: &str) -> u64 {
        match self.lock().get(name) {
            Some(Metric::Counter(v)) => *v,
            _ => 0,
        }
    }

    /// Sum of every counter whose name starts with `prefix`.
    pub fn counter_sum(&self, prefix: &str) -> u64 {
        self.lock()
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, m)| match m {
                Metric::Counter(v) => *v,
                _ => 0,
            })
            .sum()
    }

    /// Deterministic JSON snapshot: `{name: {type, ...}}` in name order.
    pub fn snapshot(&self) -> Json {
        let m = self.lock();
        let mut out = Json::obj();
        for (name, metric) in m.iter() {
            let body = match metric {
                Metric::Counter(v) => {
                    Json::obj().set("type", "counter").set("value", *v as usize)
                }
                Metric::Gauge(v) => Json::obj().set("type", "gauge").set("value", *v),
                Metric::Histogram { bounds, counts, count, sum } => {
                    let mut buckets = Vec::with_capacity(counts.len());
                    for (i, c) in counts.iter().enumerate() {
                        let le = bounds.get(i).map(|b| Json::Num(*b)).unwrap_or(Json::Null);
                        buckets.push(Json::obj().set("le", le).set("count", *c as usize));
                    }
                    Json::obj()
                        .set("type", "histogram")
                        .set("count", *count as usize)
                        .set("sum", *sum)
                        .set("buckets", Json::Arr(buckets))
                }
            };
            out = out.set(name, body);
        }
        out
    }
}

impl Default for MetricsRegistry {
    fn default() -> MetricsRegistry {
        MetricsRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot_in_name_order() {
        let r = MetricsRegistry::new();
        r.counter("b.two", 1);
        r.counter("a.one", 2);
        r.counter("b.two", 3);
        assert_eq!(r.counter_value("b.two"), 4);
        assert_eq!(r.counter_value("missing"), 0);
        assert_eq!(r.counter_sum("b."), 4);
        assert_eq!(r.counter_sum(""), 6);
        assert_eq!(
            r.snapshot().render(),
            r#"{"a.one":{"type":"counter","value":2},"b.two":{"type":"counter","value":4}}"#
        );
    }

    #[test]
    fn gauges_overwrite() {
        let r = MetricsRegistry::new();
        r.gauge("sessions", 2.0);
        r.gauge("sessions", 5.0);
        assert!(r.snapshot().render().contains(r#""sessions":{"type":"gauge","value":5}"#));
    }

    #[test]
    fn histograms_bucket_by_fixed_bounds() {
        let r = MetricsRegistry::new();
        let bounds = [0.001, 0.01, 0.1];
        for v in [0.0005, 0.002, 0.05, 3.0] {
            r.observe("latency", &bounds, v);
        }
        let s = r.snapshot().render();
        assert!(s.contains(r#""type":"histogram","count":4"#), "{s}");
        // One value per bucket, including the +inf overflow (le null).
        assert!(s.contains(r#"{"le":0.001,"count":1}"#), "{s}");
        assert!(s.contains(r#"{"le":null,"count":1}"#), "{s}");
    }

    #[test]
    fn kind_collisions_are_ignored_not_fatal() {
        let r = MetricsRegistry::new();
        r.counter("x", 1);
        r.gauge("x", 9.0);
        r.observe("x", &[1.0], 0.5);
        assert_eq!(r.counter_value("x"), 1);
    }
}
