//! `ktbo report` — render a telemetry JSONL export for humans.
//!
//! Input is the file the sweep orchestrator (or any exporter) writes: a
//! `{"type":"meta","kind":"telemetry","schema_version":N}` head line
//! followed by `{"type":"event",...}` lines, each optionally tagged
//! with cell coordinates (`kernel`/`gpu`/`strategy`/`rep`). Output per
//! cell: a per-phase time breakdown (span counts, total, mean) and the
//! time-to-solution curve — every step where the incumbent improved,
//! stamped with wall time relative to the cell's first event.

use std::collections::BTreeMap;

use super::TELEMETRY_SCHEMA_VERSION;
use crate::util::json::Json;
use crate::util::jsonparse;

/// Fixed display order for the phase table.
const PHASE_ORDER: &[&str] = &["ask", "eval", "fit", "predict", "score", "pool_draw"];

#[derive(Default)]
struct PhaseAgg {
    spans: u64,
    total_ns: u64,
    items: u64,
}

#[derive(Default)]
struct CellAgg {
    events: u64,
    first_t_ns: Option<u64>,
    phases: BTreeMap<String, PhaseAgg>,
    /// (t_ns, step, value) for valid observations, in arrival order.
    observes: Vec<(u64, usize, f64)>,
    invalid_observes: u64,
    cache_hits: u64,
    shared_hits: u64,
    /// Multi-AF arm → times chosen.
    af_choices: BTreeMap<usize, u64>,
    probes: Option<u64>,
    resilience: Option<String>,
}

fn fmt_ns(ns: u64) -> String {
    let ns = ns as f64;
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.1}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

fn cell_label(j: &Json) -> String {
    let field = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
    match (field("kernel"), field("gpu"), field("strategy")) {
        (Some(k), Some(g), Some(s)) => {
            let rep = j.get("rep").and_then(Json::as_f64).unwrap_or(0.0) as usize;
            format!("{k}/{g}/{s}#{rep}")
        }
        _ => field("cell").unwrap_or_else(|| "session".to_string()),
    }
}

/// Render a report from telemetry JSONL text.
pub fn render(text: &str) -> Result<String, String> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let head = lines.next().ok_or("telemetry file is empty")?;
    let meta = jsonparse::parse(head).map_err(|e| format!("telemetry meta line: {e}"))?;
    if meta.get("type").and_then(Json::as_str) != Some("meta")
        || meta.get("kind").and_then(Json::as_str) != Some("telemetry")
    {
        return Err("not a telemetry export: first line must be a telemetry meta record".into());
    }
    let version = meta
        .get("schema_version")
        .and_then(Json::as_f64)
        .ok_or("telemetry meta line lacks a schema_version")? as u64;
    if version > TELEMETRY_SCHEMA_VERSION {
        return Err(format!(
            "telemetry schema_version {version} is newer than this build understands \
             ({TELEMETRY_SCHEMA_VERSION})"
        ));
    }

    let mut cells: BTreeMap<String, CellAgg> = BTreeMap::new();
    let mut total_events = 0u64;
    for line in lines {
        let j = jsonparse::parse(line).map_err(|e| format!("telemetry event line: {e}"))?;
        if j.get("type").and_then(Json::as_str) != Some("event") {
            continue;
        }
        total_events += 1;
        let agg = cells.entry(cell_label(&j)).or_default();
        agg.events += 1;
        let t_ns = j.get("t_ns").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        agg.first_t_ns.get_or_insert(t_ns);
        let step = j.get("step").and_then(Json::as_f64).unwrap_or(0.0) as usize;
        match j.get("event").and_then(Json::as_str).unwrap_or("") {
            "span" => {
                let phase = j.get("phase").and_then(Json::as_str).unwrap_or("?").to_string();
                let p = agg.phases.entry(phase).or_default();
                p.spans += 1;
                p.total_ns += j.get("dur_ns").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                p.items += j.get("n").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            }
            "observe" => match j.get("value").and_then(Json::as_f64) {
                Some(v) if v.is_finite() => agg.observes.push((t_ns, step, v)),
                _ => agg.invalid_observes += 1,
            },
            "cache_hit" => agg.cache_hits += 1,
            "shared_hit" => agg.shared_hits += 1,
            "af_choice" => {
                let arm = j.get("arm").and_then(Json::as_f64).unwrap_or(0.0) as usize;
                *agg.af_choices.entry(arm).or_default() += 1;
            }
            "probes" => {
                agg.probes = Some(j.get("total").and_then(Json::as_f64).unwrap_or(0.0) as u64);
            }
            "resilience" => {
                agg.resilience = j.get("stats").map(Json::render);
            }
            _ => {}
        }
    }

    let mut out = format!(
        "telemetry report (schema v{version}): {total_events} events, {} cell(s)\n",
        cells.len()
    );
    for (label, agg) in &cells {
        out.push_str(&format!("\n== {label} ==\n"));
        let t0 = agg.first_t_ns.unwrap_or(0);
        if !agg.phases.is_empty() {
            out.push_str(&format!(
                "{:<10} {:>7} {:>10} {:>10} {:>8}\n",
                "phase", "spans", "total", "mean", "items"
            ));
            let known = PHASE_ORDER.iter().filter(|p| agg.phases.contains_key(**p)).copied();
            let extra = agg.phases.keys().map(String::as_str).filter(|p| !PHASE_ORDER.contains(p));
            for phase in known.chain(extra) {
                let p = &agg.phases[phase];
                let mean = if p.spans > 0 { p.total_ns / p.spans } else { 0 };
                out.push_str(&format!(
                    "{:<10} {:>7} {:>10} {:>10} {:>8}\n",
                    phase,
                    p.spans,
                    fmt_ns(p.total_ns),
                    fmt_ns(mean),
                    p.items
                ));
            }
        }
        let mut counters: Vec<String> = Vec::new();
        if agg.cache_hits > 0 {
            counters.push(format!("cache_hits={}", agg.cache_hits));
        }
        if agg.shared_hits > 0 {
            counters.push(format!("shared_hits={}", agg.shared_hits));
        }
        if agg.invalid_observes > 0 {
            counters.push(format!("invalid_observations={}", agg.invalid_observes));
        }
        for (arm, n) in &agg.af_choices {
            counters.push(format!("af_choice[{arm}]={n}"));
        }
        if let Some(p) = agg.probes {
            counters.push(format!("probes={p}"));
        }
        if !counters.is_empty() {
            out.push_str(&format!("counters: {}\n", counters.join(" ")));
        }
        if let Some(r) = &agg.resilience {
            out.push_str(&format!("resilience: {r}\n"));
        }
        // Time-to-solution: each strict improvement of the incumbent.
        let mut best = f64::INFINITY;
        let mut milestones: Vec<String> = Vec::new();
        for (t_ns, step, v) in &agg.observes {
            if *v < best {
                best = *v;
                milestones.push(format!(
                    "  step {:<5} +{:<10} best={:.4}",
                    step,
                    fmt_ns(t_ns.saturating_sub(t0)),
                    best
                ));
            }
        }
        if !milestones.is_empty() {
            out.push_str("time-to-solution:\n");
            for m in &milestones {
                out.push_str(m);
                out.push('\n');
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::{meta_record, Event, EventKind, Phase};
    use super::*;

    fn event_line(tag: &str, e: &Event) -> String {
        e.to_json_into(
            Json::obj()
                .set("type", "event")
                .set("kernel", "adding")
                .set("gpu", "A100")
                .set("strategy", tag)
                .set("rep", 0usize),
        )
        .render()
    }

    fn sample() -> String {
        let mut lines = vec![meta_record().render()];
        let ev = |t_ns, step, kind| Event { t_ns, step, kind };
        for e in [
            ev(100, 0, EventKind::Span { phase: Phase::Ask, dur_ns: 90, n: 1 }),
            ev(220, 0, EventKind::Span { phase: Phase::Eval, dur_ns: 100, n: 1 }),
            ev(230, 1, EventKind::Observe { idx: 4, value: 5.5 }),
            ev(300, 1, EventKind::Span { phase: Phase::Fit, dur_ns: 50, n: 8 }),
            ev(400, 1, EventKind::AfChoice { arm: 2 }),
            ev(430, 2, EventKind::Observe { idx: 9, value: 4.25 }),
            ev(500, 3, EventKind::Observe { idx: 2, value: f64::NAN }),
            ev(550, 3, EventKind::Observe { idx: 5, value: 9.0 }),
            ev(600, 3, EventKind::Probes { total: 17 }),
        ] {
            lines.push(event_line("ei", &e));
        }
        lines.join("\n") + "\n"
    }

    #[test]
    fn renders_phase_table_and_time_to_solution() {
        let r = render(&sample()).unwrap();
        assert!(r.contains("9 events, 1 cell(s)"), "{r}");
        assert!(r.contains("== adding/A100/ei#0 =="), "{r}");
        for marker in ["ask", "eval", "fit"] {
            assert!(r.contains(marker), "missing phase {marker}: {r}");
        }
        assert!(r.contains("af_choice[2]=1"), "{r}");
        assert!(r.contains("probes=17"), "{r}");
        assert!(r.contains("invalid_observations=1"), "{r}");
        assert!(r.contains("time-to-solution:"), "{r}");
        // Two improvements (5.5 then 4.25); 9.0 is not an improvement.
        assert!(r.contains("best=5.5000") && r.contains("best=4.2500"), "{r}");
        assert!(!r.contains("best=9.0000"), "{r}");
        // Milestone time is relative to the cell's first event (t0=100).
        assert!(r.contains("+130ns"), "{r}");
    }

    #[test]
    fn refuses_future_schema_and_non_telemetry_files() {
        let future = r#"{"type":"meta","kind":"telemetry","schema_version":99}"#;
        assert!(render(future).unwrap_err().contains("schema_version 99"));
        let sweep = r#"{"type":"meta","kind":"sweep","schema_version":1}"#;
        assert!(render(sweep).unwrap_err().contains("telemetry meta record"));
        assert!(render("").unwrap_err().contains("empty"));
    }

    #[test]
    fn untagged_events_group_as_session() {
        let text = format!(
            "{}\n{}\n",
            meta_record().render(),
            Event { t_ns: 10, step: 0, kind: EventKind::CacheHit { idx: 1 } }.to_json().render()
        );
        let r = render(&text).unwrap();
        assert!(r.contains("== session =="), "{r}");
        assert!(r.contains("cache_hits=1"), "{r}");
    }
}
