//! Determinism-safe instrumentation: per-session tracing, per-phase
//! timing, and process metrics.
//!
//! The paper's cost story — where does optimization time go: surrogate
//! fit/predict versus evaluation, pool draws versus scoring — needs
//! numbers, and this module is where they come from. Three pieces:
//!
//! - [`Telemetry`] / [`SessionTelemetry`]: a cheap cloneable handle to a
//!   per-session recorder of typed [`Event`]s (phase spans,
//!   observations, cache hits, acquisition choices, probe and
//!   resilience counters), buffered in a bounded ring and exportable as
//!   versioned JSONL next to the sweep records. The disabled handle
//!   ([`Telemetry::off`]) is a `None` — every recording call is a
//!   single branch, no allocation, no clock read.
//! - [`clock`]: the injectable [`clock::Clock`] trait. Real runs use
//!   [`clock::MonotonicClock`]; tests use [`clock::ManualClock`].
//!   Raw `Instant::now()` outside that module fails `ktbo-lint`'s
//!   `no-untracked-clock` rule.
//! - [`metrics`]: counters/gauges/histograms for the serve daemon's
//!   `metrics` wire verb and process-wide tallies.
//!
//! **The invariant** (asserted registry-wide in `strategies::driver`
//! and `harness::orchestrator` tests): telemetry on versus off produces
//! bit-identical evaluation traces and byte-identical sweep
//! `results.jsonl`. Instrumentation observes; it never touches an RNG
//! stream, an iteration order, or a record the trace path reads back.
//! Concretely: timestamps never cross back into strategy code, and
//! telemetry output lives in its own `*.telemetry.jsonl` file.

pub mod clock;
pub mod metrics;
pub mod report;

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::objective::resilient::ResilienceStats;
use crate::util::json::Json;
use clock::{Clock, MonotonicClock};

/// Schema version stamped on the meta line of every telemetry JSONL
/// export; readers refuse files from the future.
pub const TELEMETRY_SCHEMA_VERSION: u64 = 1;

/// Default bounded-ring capacity: generous for any single session
/// (a full-budget BO run emits a few events per evaluation) while
/// bounding a runaway emitter to a few MB.
pub const DEFAULT_RING_CAPACITY: usize = 65_536;

/// The instrumented phases of a session step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// The driver's whole `ask` — suggestion latency.
    Ask,
    /// One objective evaluation (in-process path).
    Eval,
    /// Surrogate fit / incremental update.
    Fit,
    /// Surrogate posterior prediction over the candidate tile.
    Predict,
    /// Acquisition scoring sweep (fused predict+score counts here too).
    Score,
    /// Lazy-mode candidate pool construction (global draws + neighbor
    /// probes through the constraint oracle).
    PoolDraw,
}

impl Phase {
    pub fn label(self) -> &'static str {
        match self {
            Phase::Ask => "ask",
            Phase::Eval => "eval",
            Phase::Fit => "fit",
            Phase::Predict => "predict",
            Phase::Score => "score",
            Phase::PoolDraw => "pool_draw",
        }
    }
}

/// What happened. Payloads are counters and ids only — nothing here is
/// ever read back by the trace path.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// A timed phase: `dur_ns` of wall time, covering `n` items
    /// (batch size, pool size, tile size — phase-dependent).
    Span { phase: Phase, dur_ns: u64, n: usize },
    /// A committed observation (the tell side), valid or not. `value`
    /// is NaN for invalid/timeout evaluations and renders as JSON
    /// null. Feeds time-to-solution curves in `ktbo report`.
    Observe { idx: usize, value: f64 },
    /// The session memo (eval-cache) answered without an evaluation.
    CacheHit { idx: usize },
    /// A concurrent session's in-flight result was reused.
    SharedHit { idx: usize },
    /// A multi-AF policy picked the suggestion from arm `arm`.
    AfChoice { arm: usize },
    /// Cumulative constraint-oracle probe count at this point
    /// (`SpaceView::probe_count`).
    Probes { total: u64 },
    /// Snapshot of the resilient evaluator's counters.
    Resilience(ResilienceStats),
}

/// One telemetry event: a monotonic timestamp, the evaluation-trace
/// step it belongs to, and the typed payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub t_ns: u64,
    /// Trace length when the event fired — ties events to evaluations
    /// without perturbing the trace itself.
    pub step: usize,
    pub kind: EventKind,
}

impl Event {
    /// Append this event's fields to a (possibly pre-tagged) JSON
    /// object — the sweep exporter prefixes cell coordinates, the
    /// session exporter passes a bare object.
    pub fn to_json_into(&self, base: Json) -> Json {
        let j = base.set("t_ns", self.t_ns as usize).set("step", self.step);
        match &self.kind {
            EventKind::Span { phase, dur_ns, n } => j
                .set("event", "span")
                .set("phase", phase.label())
                .set("dur_ns", *dur_ns as usize)
                .set("n", *n),
            EventKind::Observe { idx, value } => {
                j.set("event", "observe").set("idx", *idx).set("value", *value)
            }
            EventKind::CacheHit { idx } => j.set("event", "cache_hit").set("idx", *idx),
            EventKind::SharedHit { idx } => j.set("event", "shared_hit").set("idx", *idx),
            EventKind::AfChoice { arm } => j.set("event", "af_choice").set("arm", *arm),
            EventKind::Probes { total } => j.set("event", "probes").set("total", *total as usize),
            EventKind::Resilience(stats) => j.set("event", "resilience").set("stats", stats.to_json()),
        }
    }

    pub fn to_json(&self) -> Json {
        self.to_json_into(Json::obj().set("type", "event"))
    }
}

/// The meta line heading every telemetry JSONL export.
pub fn meta_record() -> Json {
    Json::obj()
        .set("type", "meta")
        .set("kind", "telemetry")
        .set("schema_version", TELEMETRY_SCHEMA_VERSION as usize)
}

/// Bounded event buffer: oldest events fall off, with a drop count so
/// exports can say so instead of silently truncating.
struct Ring {
    buf: VecDeque<Event>,
    cap: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, e: Event) {
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(e);
    }
}

/// The per-session recorder: a clock plus the bounded ring. Shared
/// through [`Telemetry`] handles; all methods take `&self`.
pub struct SessionTelemetry {
    clock: Arc<dyn Clock>,
    ring: Mutex<Ring>,
}

impl SessionTelemetry {
    fn ring(&self) -> MutexGuard<'_, Ring> {
        // A panic while holding this lock loses nothing we care about —
        // recover the buffer rather than poisoning telemetry forever.
        self.ring.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Cheap cloneable handle: `None` = disabled (every call is one branch,
/// no clock read), `Some` = shared recorder.
#[derive(Clone, Default)]
pub struct Telemetry(Option<Arc<SessionTelemetry>>);

/// The canonical disabled handle.
static OFF: Telemetry = Telemetry(None);

impl Telemetry {
    /// A disabled handle by reference — the default everywhere a
    /// borrowed `&Telemetry` is threaded through.
    pub fn off() -> &'static Telemetry {
        &OFF
    }

    /// A recording handle on the real monotonic clock.
    pub fn recording(capacity: usize) -> Telemetry {
        Telemetry::with_clock(Arc::new(MonotonicClock::new()), capacity)
    }

    /// A recording handle on an injected clock (tests).
    pub fn with_clock(clock: Arc<dyn Clock>, capacity: usize) -> Telemetry {
        Telemetry(Some(Arc::new(SessionTelemetry {
            clock,
            ring: Mutex::new(Ring { buf: VecDeque::new(), cap: capacity, dropped: 0 }),
        })))
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Open a span: the phase start timestamp, or 0 when disabled.
    /// Pair with [`Telemetry::span`].
    pub fn start(&self) -> u64 {
        match &self.0 {
            Some(t) => t.clock.now_ns(),
            None => 0,
        }
    }

    /// Close a span opened with [`Telemetry::start`]: records a
    /// [`EventKind::Span`] with the elapsed time and item count `n`.
    pub fn span(&self, step: usize, phase: Phase, t0_ns: u64, n: usize) {
        if let Some(t) = &self.0 {
            let now = t.clock.now_ns();
            t.ring().push(Event {
                t_ns: now,
                step,
                kind: EventKind::Span { phase, dur_ns: now.saturating_sub(t0_ns), n },
            });
        }
    }

    /// Record a non-span event, stamped with the current time.
    pub fn record(&self, step: usize, kind: EventKind) {
        if let Some(t) = &self.0 {
            let now = t.clock.now_ns();
            t.ring().push(Event { t_ns: now, step, kind });
        }
    }

    /// Snapshot of the buffered events, oldest first.
    pub fn events(&self) -> Vec<Event> {
        match &self.0 {
            Some(t) => t.ring().buf.iter().cloned().collect(),
            None => Vec::new(),
        }
    }

    /// Events lost to the ring bound so far.
    pub fn dropped(&self) -> u64 {
        match &self.0 {
            Some(t) => t.ring().dropped,
            None => 0,
        }
    }

    pub fn len(&self) -> usize {
        match &self.0 {
            Some(t) => t.ring().buf.len(),
            None => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render the buffer as JSONL event lines (no meta line — the
    /// exporter owns file framing), each tagged by `tag` first so cell
    /// coordinates lead the record.
    pub fn export_lines(&self, tag: impl Fn(Json) -> Json) -> Vec<String> {
        self.events().iter().map(|e| e.to_json_into(tag(Json::obj().set("type", "event"))).render()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::clock::ManualClock;
    use super::*;

    fn manual() -> (Arc<ManualClock>, Telemetry) {
        let clock = Arc::new(ManualClock::new());
        let tel = Telemetry::with_clock(Arc::clone(&clock) as Arc<dyn Clock>, 8);
        (clock, tel)
    }

    #[test]
    fn disabled_handle_records_nothing_and_reads_no_clock() {
        let off = Telemetry::off();
        assert!(!off.enabled());
        assert_eq!(off.start(), 0);
        off.span(0, Phase::Ask, 0, 1);
        off.record(0, EventKind::CacheHit { idx: 3 });
        assert!(off.events().is_empty());
        assert_eq!((off.len(), off.dropped()), (0, 0));
    }

    #[test]
    fn spans_measure_manual_time_and_nest() {
        let (clock, tel) = manual();
        let outer = tel.start();
        clock.advance(100);
        let inner = tel.start();
        clock.advance(40);
        tel.span(2, Phase::Fit, inner, 12);
        clock.advance(10);
        tel.span(2, Phase::Ask, outer, 1);
        let ev = tel.events();
        assert_eq!(ev.len(), 2);
        // Inner span closes first; both durations are exact.
        assert_eq!(ev[0].kind, EventKind::Span { phase: Phase::Fit, dur_ns: 40, n: 12 });
        assert_eq!(ev[0].t_ns, 140);
        assert_eq!(ev[1].kind, EventKind::Span { phase: Phase::Ask, dur_ns: 150, n: 1 });
        assert_eq!(ev[1].step, 2);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let (clock, tel) = manual();
        for i in 0..11usize {
            clock.advance(1);
            tel.record(i, EventKind::CacheHit { idx: i });
        }
        assert_eq!(tel.len(), 8, "capacity bounds the buffer");
        assert_eq!(tel.dropped(), 3);
        let ev = tel.events();
        // Oldest three fell off; the survivors are 3..=10 in order.
        assert_eq!(ev.first().unwrap().step, 3);
        assert_eq!(ev.last().unwrap().step, 10);
    }

    #[test]
    fn zero_capacity_ring_drops_everything() {
        let tel = Telemetry::with_clock(Arc::new(ManualClock::new()), 0);
        tel.record(0, EventKind::AfChoice { arm: 1 });
        assert!(tel.is_empty());
        assert_eq!(tel.dropped(), 1);
    }

    #[test]
    fn events_render_as_tagged_jsonl() {
        let (clock, tel) = manual();
        clock.advance(5);
        tel.record(1, EventKind::Observe { idx: 7, value: 2.5 });
        tel.record(1, EventKind::Observe { idx: 8, value: f64::NAN });
        tel.record(2, EventKind::Probes { total: 31 });
        let lines = tel.export_lines(|j| j.set("cell", "adding/a100"));
        assert_eq!(lines.len(), 3);
        assert_eq!(
            lines[0],
            r#"{"type":"event","cell":"adding/a100","t_ns":5,"step":1,"event":"observe","idx":7,"value":2.5}"#
        );
        assert!(lines[1].ends_with(r#""value":null}"#), "NaN renders as null: {}", lines[1]);
        assert!(lines[2].contains(r#""event":"probes","total":31"#));
        let meta = meta_record().render();
        assert!(meta.contains(r#""kind":"telemetry""#) && meta.contains("\"schema_version\":1"));
    }

    #[test]
    fn clones_share_one_ring() {
        let (_clock, tel) = manual();
        let other = tel.clone();
        other.record(0, EventKind::AfChoice { arm: 2 });
        assert_eq!(tel.len(), 1);
    }
}
