//! Injectable time sources for the telemetry layer.
//!
//! Every wall-clock read in the workspace funnels through the [`Clock`]
//! trait so that (a) tests drive timing-dependent code with a
//! [`ManualClock`] instead of sleeping, and (b) `ktbo-lint`'s
//! `no-untracked-clock` rule can ban raw `Instant::now()` /
//! `SystemTime` reads everywhere else. This file is the single module
//! excluded from that rule — the one place allowed to touch the OS
//! clock.
//!
//! Timestamps are monotonic nanoseconds relative to an arbitrary epoch
//! (clock construction for [`MonotonicClock`], zero for
//! [`ManualClock`]). They are *observability data only*: nothing on the
//! deterministic trace path may branch on them (see the telemetry
//! module docs for the invariant and the tests that pin it).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotonic nanosecond time source.
pub trait Clock: Send + Sync {
    /// Nanoseconds since this clock's epoch. Must be monotone
    /// non-decreasing across calls.
    fn now_ns(&self) -> u64;
}

/// The real thing: monotonic OS time relative to construction.
pub struct MonotonicClock {
    base: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { base: Instant::now() }
    }

    /// Seconds elapsed since the timestamp `t0_ns` (itself from this
    /// clock), for human-facing wall-time reporting.
    pub fn seconds_since(&self, t0_ns: u64) -> f64 {
        self.now_ns().saturating_sub(t0_ns) as f64 / 1e9
    }
}

impl Default for MonotonicClock {
    fn default() -> MonotonicClock {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        // u64 nanoseconds overflow after ~584 years of process uptime.
        self.base.elapsed().as_nanos() as u64
    }
}

/// A hand-cranked clock for tests: starts at zero, advances only when
/// told to. Shared freely (`Arc<ManualClock>`) between the test body
/// and the code under test.
#[derive(Default)]
pub struct ManualClock {
    now_ns: AtomicU64,
}

impl ManualClock {
    pub fn new() -> ManualClock {
        ManualClock::default()
    }

    /// Move time forward by `ns` nanoseconds.
    pub fn advance(&self, ns: u64) {
        self.now_ns.fetch_add(ns, Ordering::SeqCst);
    }

    /// Jump to an absolute timestamp. Monotonicity is the caller's
    /// contract — tests that rewind get the garbage they asked for.
    pub fn set(&self, ns: u64) {
        self.now_ns.store(ns, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.now_ns.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_advances_and_sets() {
        let c = ManualClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance(250);
        c.advance(50);
        assert_eq!(c.now_ns(), 300);
        c.set(1_000_000);
        assert_eq!(c.now_ns(), 1_000_000);
    }

    #[test]
    fn monotonic_clock_is_monotone() {
        let c = MonotonicClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
        assert!(c.seconds_since(a) >= 0.0);
    }
}
