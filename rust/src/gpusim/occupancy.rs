//! Resource accounting, validity staging, and the occupancy model.
//!
//! The paper (§III-D2) distinguishes three stages at which a configuration
//! can turn out invalid: (1) programming-model spec checks before
//! compilation — modeled as space restrictions; (2) compile errors —
//! modeled here as static resource overruns (shared memory per block,
//! registers per thread); (3) runtime errors — modeled as launch-time
//! resource overruns on the *actual device* (threads per block beyond the
//! device limit, zero achievable occupancy). This module implements stages
//! (2) and (3) plus the standard CUDA occupancy calculation used by the
//! timing models.

use crate::gpusim::device::Device;

/// Static + launch resources of one kernel configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct Resources {
    /// Threads per block requested by the configuration.
    pub threads_per_block: usize,
    /// Static shared memory per block (bytes).
    pub smem_bytes: usize,
    /// Registers per thread (estimated by the kernel model).
    pub regs_per_thread: usize,
    /// Number of blocks in the grid.
    pub grid_blocks: usize,
}

/// Outcome of validity staging.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Validity {
    Ok,
    /// Static resource overrun — the toolchain rejects the build.
    CompileError,
    /// Launch failure on the concrete device.
    RuntimeError,
}

/// Stage-2/3 validity checks for a configuration's resources on a device.
pub fn check_validity(r: &Resources, dev: &Device) -> Validity {
    // Stage 2 — compile time: static smem and register pressure.
    if r.smem_bytes > dev.smem_per_block {
        return Validity::CompileError;
    }
    if r.regs_per_thread > dev.max_regs_per_thread {
        return Validity::CompileError;
    }
    // Stage 3 — launch time on the device.
    if r.threads_per_block == 0 || r.threads_per_block > dev.max_threads_per_block {
        return Validity::RuntimeError;
    }
    if r.grid_blocks == 0 {
        return Validity::RuntimeError;
    }
    // Register file must accommodate at least one block.
    if r.regs_per_thread * r.threads_per_block > dev.regfile_per_sm {
        return Validity::RuntimeError;
    }
    if active_blocks_per_sm(r, dev) == 0 {
        return Validity::RuntimeError;
    }
    Validity::Ok
}

/// Number of thread blocks resident per SM (CUDA occupancy calculation,
/// warp-granular register allocation approximated at thread granularity).
pub fn active_blocks_per_sm(r: &Resources, dev: &Device) -> usize {
    if r.threads_per_block == 0 {
        return 0;
    }
    let by_threads = dev.max_threads_per_sm / r.threads_per_block;
    let by_blocks = dev.max_blocks_per_sm;
    let by_smem = if r.smem_bytes == 0 { usize::MAX } else { dev.smem_per_sm / r.smem_bytes };
    let regs_per_block = r.regs_per_thread.max(16) * r.threads_per_block;
    let by_regs = if regs_per_block == 0 { usize::MAX } else { dev.regfile_per_sm / regs_per_block };
    by_threads.min(by_blocks).min(by_smem).min(by_regs)
}

/// Achieved occupancy: resident threads / max resident threads, in [0, 1].
pub fn occupancy(r: &Resources, dev: &Device) -> f64 {
    let blocks = active_blocks_per_sm(r, dev);
    ((blocks * r.threads_per_block) as f64 / dev.max_threads_per_sm as f64).min(1.0)
}

/// Latency-hiding efficiency as a function of occupancy: saturating curve
/// with a knee — low occupancy cannot hide memory latency, but beyond
/// ~50% extra occupancy buys little (standard GPU folklore, and the reason
/// tuning block sizes matters).
pub fn occupancy_efficiency(occ: f64) -> f64 {
    let knee = 0.25;
    (occ / (occ + knee)).min(1.0) * (1.0 + knee)
}

/// Tail effect: when the grid does not evenly fill the SMs' capacity the
/// last wave runs underpopulated. Returns a multiplier ≥ 1 on time.
pub fn tail_effect(grid_blocks: usize, blocks_per_sm: usize, dev: &Device) -> f64 {
    if grid_blocks == 0 || blocks_per_sm == 0 {
        return 1.0;
    }
    let wave = dev.sm_count * blocks_per_sm;
    let waves = grid_blocks as f64 / wave as f64;
    let full = waves.floor();
    if waves <= 1.0 {
        // Single partial wave: time is that of a full wave.
        return 1.0 / waves.max(1.0 / wave as f64);
    }
    let frac = waves - full;
    if frac < 1e-9 {
        1.0
    } else {
        // Partial last wave takes a full wave's time.
        (full + 1.0) / waves
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Device {
        Device::gtx_titan_x()
    }

    fn res(threads: usize, smem: usize, regs: usize, blocks: usize) -> Resources {
        Resources { threads_per_block: threads, smem_bytes: smem, regs_per_thread: regs, grid_blocks: blocks }
    }

    #[test]
    fn valid_config_passes() {
        assert_eq!(check_validity(&res(256, 16 * 1024, 64, 1000), &dev()), Validity::Ok);
    }

    #[test]
    fn smem_overrun_is_compile_error() {
        assert_eq!(check_validity(&res(256, 49 * 1024, 32, 10), &dev()), Validity::CompileError);
    }

    #[test]
    fn register_overrun_is_compile_error() {
        assert_eq!(check_validity(&res(64, 0, 256, 10), &dev()), Validity::CompileError);
    }

    #[test]
    fn too_many_threads_is_runtime_error() {
        assert_eq!(check_validity(&res(2048, 0, 32, 10), &dev()), Validity::RuntimeError);
    }

    #[test]
    fn regfile_exhaustion_is_runtime_error() {
        // 1024 threads × 128 regs = 131072 > 65536.
        assert_eq!(check_validity(&res(1024, 0, 128, 10), &dev()), Validity::RuntimeError);
    }

    #[test]
    fn occupancy_basic() {
        // 256 threads, nothing else limiting: 2048/256 = 8 blocks, full occupancy.
        let r = res(256, 0, 16, 1000);
        assert_eq!(active_blocks_per_sm(&r, &dev()), 8);
        assert!((occupancy(&r, &dev()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn smem_limits_occupancy() {
        // 48 KiB static smem: only 2 blocks fit in 96 KiB/SM.
        let r = res(128, 48 * 1024, 16, 1000);
        assert_eq!(active_blocks_per_sm(&r, &dev()), 2);
        assert!(occupancy(&r, &dev()) < 0.2);
    }

    #[test]
    fn occupancy_efficiency_monotone_saturating() {
        let lo = occupancy_efficiency(0.1);
        let mid = occupancy_efficiency(0.5);
        let hi = occupancy_efficiency(1.0);
        assert!(lo < mid && mid < hi);
        assert!(hi <= 1.0 + 1e-9);
        assert!((occupancy_efficiency(1.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tail_effect_bounds() {
        let d = dev();
        // Exactly two full waves: no tail.
        assert!((tail_effect(2 * d.sm_count * 4, 4, &d) - 1.0).abs() < 1e-9);
        // 2.5 waves: 3 wave-times for 2.5 waves of work.
        let t = tail_effect((2.5 * (d.sm_count * 4) as f64) as usize, 4, &d);
        assert!(t > 1.0 && t <= 1.5);
    }
}
