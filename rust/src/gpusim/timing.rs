//! Roofline-style timing combinator + deterministic roughness.
//!
//! Every kernel model reduces a configuration to a `WorkEstimate`; this
//! module turns it into milliseconds on a device. The landscape properties
//! the paper's optimizer faces — rough, multimodal, discontinuous — come
//! from (a) discrete efficiency cliffs already in the models (bank
//! conflicts, divisibility, caching), (b) occupancy steps, and (c) a
//! deterministic per-(kernel, device, config) lognormal "roughness" term
//! standing in for all unmodeled microarchitectural interactions. The
//! roughness is *hashed*, not sampled: the simulated search space is a
//! fixed function, exactly like the paper's recorded spaces in simulation
//! mode.

use crate::gpusim::device::Device;
use crate::gpusim::occupancy::{active_blocks_per_sm, occupancy, occupancy_efficiency, tail_effect, Resources};
use crate::util::rng::hash_normal;

/// Work performed by one kernel configuration.
#[derive(Clone, Copy, Debug)]
pub struct WorkEstimate {
    /// Floating-point operations (fp32-equivalent; fp64 kernels scale by
    /// the device's fp64 ratio via `f64_flops`).
    pub flops: f64,
    /// fp64 operations (billed at the device fp64 rate).
    pub f64_flops: f64,
    /// DRAM traffic in bytes.
    pub dram_bytes: f64,
    /// Host↔device transfer bytes (0 for pure-GPU kernels).
    pub transfer_bytes: f64,
    /// Fraction of the transfer overlapped with compute, in [0,1].
    pub transfer_overlap: f64,
    /// Multiplicative compute-efficiency factor in (0, 1]: vectorization,
    /// unrolling, bank conflicts, divergence — kernel-model specific.
    pub compute_efficiency: f64,
    /// Multiplicative memory-efficiency factor in (0, 1]: coalescing,
    /// cache hit rates.
    pub memory_efficiency: f64,
}

impl Default for WorkEstimate {
    fn default() -> Self {
        WorkEstimate {
            flops: 0.0,
            f64_flops: 0.0,
            dram_bytes: 0.0,
            transfer_bytes: 0.0,
            transfer_overlap: 0.0,
            compute_efficiency: 1.0,
            memory_efficiency: 1.0,
        }
    }
}

/// Scale of the multiplicative lognormal roughness (sigma of log-time).
pub const ROUGHNESS_SIGMA: f64 = 0.08;

/// Deterministic execution-time model: roofline over compute and memory,
/// modulated by occupancy, tail effect, launch overhead, transfer
/// (partially overlapped), and hashed roughness.
pub fn execution_time_ms(work: &WorkEstimate, res: &Resources, dev: &Device, noise_key: u64) -> f64 {
    debug_assert!(work.compute_efficiency > 0.0 && work.compute_efficiency <= 1.0);
    debug_assert!(work.memory_efficiency > 0.0 && work.memory_efficiency <= 1.0);

    let compute_ms = work.flops / (dev.peak_gflops() * 1e6 * work.compute_efficiency)
        + work.f64_flops / (dev.peak_gflops_f64() * 1e6 * work.compute_efficiency);
    let mem_ms = work.dram_bytes / (dev.dram_gbs * 1e6 * work.memory_efficiency);

    let occ = occupancy(res, dev);
    let eff = occupancy_efficiency(occ).max(1e-3);
    let blocks_per_sm = active_blocks_per_sm(res, dev);
    let tail = tail_effect(res.grid_blocks, blocks_per_sm, dev);

    // Roofline with soft max: overlap is imperfect, so the slower side
    // dominates but the faster side still contributes a little.
    let roof = compute_ms.max(mem_ms) + 0.12 * compute_ms.min(mem_ms);
    let kernel_ms = roof * tail / eff + dev.launch_overhead_ms;

    let transfer_ms = work.transfer_bytes / (dev.pcie_gbs * 1e6);
    let exposed_transfer = transfer_ms * (1.0 - work.transfer_overlap)
        + (transfer_ms * work.transfer_overlap - kernel_ms).max(0.0);

    let base = kernel_ms + exposed_transfer;
    let rough = (ROUGHNESS_SIGMA * hash_normal(noise_key)).exp();
    base * rough
}

/// Key mixing for the roughness hash: kernel id, device, config index.
pub fn noise_key(kernel_id: u64, device_name: &str, config_key: u64) -> u64 {
    let mut h: u64 = kernel_id ^ 0x9e37_79b9_7f4a_7c15;
    for b in device_name.bytes() {
        h = h.rotate_left(7) ^ u64::from(b).wrapping_mul(0x100_0000_01b3);
    }
    h ^ config_key.wrapping_mul(0xd6e8_feb8_6659_fd93)
}

/// Fold a configuration (value indices) into a u64 key.
pub fn config_key(cfg: &[u16]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in cfg {
        h ^= u64::from(v).wrapping_add(0x9e37_79b9);
        h = h.wrapping_mul(0x1000_0000_01b3).rotate_left(13);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Device {
        Device::gtx_titan_x()
    }

    fn res() -> Resources {
        Resources { threads_per_block: 256, smem_bytes: 8192, regs_per_thread: 48, grid_blocks: 4096 }
    }

    #[test]
    fn deterministic() {
        let w = WorkEstimate { flops: 1e11, dram_bytes: 1e9, ..Default::default() };
        let a = execution_time_ms(&w, &res(), &dev(), 42);
        let b = execution_time_ms(&w, &res(), &dev(), 42);
        assert_eq!(a, b);
    }

    #[test]
    fn compute_bound_scales_with_flops() {
        let w1 = WorkEstimate { flops: 1e11, dram_bytes: 1e6, ..Default::default() };
        let w2 = WorkEstimate { flops: 2e11, dram_bytes: 1e6, ..Default::default() };
        let t1 = execution_time_ms(&w1, &res(), &dev(), 1);
        let t2 = execution_time_ms(&w2, &res(), &dev(), 1);
        assert!(t2 / t1 > 1.8 && t2 / t1 < 2.2, "ratio {}", t2 / t1);
    }

    #[test]
    fn memory_bound_scales_with_bytes() {
        let w1 = WorkEstimate { flops: 1e6, dram_bytes: 1e9, ..Default::default() };
        let w2 = WorkEstimate { flops: 1e6, dram_bytes: 3e9, ..Default::default() };
        let t1 = execution_time_ms(&w1, &res(), &dev(), 2);
        let t2 = execution_time_ms(&w2, &res(), &dev(), 2);
        assert!(t2 / t1 > 2.7 && t2 / t1 < 3.3);
    }

    #[test]
    fn lower_efficiency_is_slower() {
        let w_hi = WorkEstimate { flops: 1e11, compute_efficiency: 1.0, ..Default::default() };
        let w_lo = WorkEstimate { flops: 1e11, compute_efficiency: 0.5, ..Default::default() };
        assert!(execution_time_ms(&w_lo, &res(), &dev(), 3) > execution_time_ms(&w_hi, &res(), &dev(), 3));
    }

    #[test]
    fn unoverlapped_transfer_adds_time() {
        let w0 = WorkEstimate { flops: 1e10, ..Default::default() };
        let wt = WorkEstimate { flops: 1e10, transfer_bytes: 1e9, transfer_overlap: 0.0, ..Default::default() };
        let wo = WorkEstimate { flops: 1e10, transfer_bytes: 1e9, transfer_overlap: 0.9, ..Default::default() };
        let t0 = execution_time_ms(&w0, &res(), &dev(), 4);
        let tt = execution_time_ms(&wt, &res(), &dev(), 4);
        let to = execution_time_ms(&wo, &res(), &dev(), 4);
        assert!(tt > to && to > t0);
    }

    #[test]
    fn roughness_is_bounded() {
        // Lognormal with sigma 0.08: 6 sigma ≈ ×1.6; times differ by < 2×
        // across noise keys for identical work.
        let w = WorkEstimate { flops: 1e11, ..Default::default() };
        let ts: Vec<f64> = (0..1000).map(|k| execution_time_ms(&w, &res(), &dev(), k)).collect();
        let min = ts.iter().cloned().fold(f64::MAX, f64::min);
        let max = ts.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max / min < 2.0, "roughness spread {}", max / min);
    }

    #[test]
    fn config_key_distinguishes() {
        assert_ne!(config_key(&[0, 1, 2]), config_key(&[0, 2, 1]));
        assert_ne!(config_key(&[0]), config_key(&[0, 0]));
    }
}
