//! Adding — the RTE-RRTMGP diffuse-radiation transport kernel of [56].
//!
//! The paper's second *unseen* kernel (§IV-E, A100): computes transport of
//! diffuse radiation through a vertically layered atmosphere. Tunables:
//! 2D thread-block dimensions, a partial unroll factor for the 140-iteration
//! vertical loop, and a recompute-vs-store switch for a value produced in
//! the first loop and consumed in the second. Small space (~4.7k configs),
//! no invalid configurations.

use crate::gpusim::device::Device;
use crate::gpusim::kernels::KernelModel;
use crate::gpusim::occupancy::Resources;
use crate::gpusim::timing::WorkEstimate;
use crate::space::{Assignment, Expr, SpaceSpec};

/// Columns × gpoints of the atmosphere problem; 140 vertical layers.
pub const COLS: usize = 2048;
pub const GPOINTS: usize = 224;
pub const LAYERS: usize = 140;

#[derive(Default)]
pub struct Adding;

impl KernelModel for Adding {
    fn name(&self) -> &'static str {
        "adding"
    }

    fn id(&self) -> u64 {
        0xadd1_4c
    }

    fn spec(&self, _dev: &Device) -> SpaceSpec {
        let v = Expr::var;
        let l = Expr::lit;
        let threads = || v("block_size_x").mul(v("block_size_y"));
        // Divisors of 140 as unroll factors (0 = let the compiler choose),
        // matching the kernel's 140-iteration second loop.
        SpaceSpec::new("adding")
            .ints("block_size_x", &(2..=128).map(|i| i * 8).collect::<Vec<_>>())
            .ints("block_size_y", &[1, 2, 4, 7, 14, 28])
            .ints("loop_unroll_factor", &[0, 1, 2, 4, 5, 7, 10, 14, 20, 28, 35, 70, 140])
            .bools("recompute_denom")
            .restrict_named("threads <= 1024", threads().le(l(1024)))
            .restrict_named("threads >= 32", threads().ge(l(32)))
    }

    fn resources(&self, a: &Assignment, _dev: &Device) -> Resources {
        let (bsx, bsy) = (a.i("block_size_x") as usize, a.i("block_size_y") as usize);
        let unroll = a.i("loop_unroll_factor") as usize;
        // Unrolling the vertical loop inflates register use linearly but
        // mildly; storing (not recomputing) the denominator costs a couple
        // of registers of live state per layer chunk.
        let regs = 32 + unroll.min(35) / 2 + if a.b("recompute_denom") { 0 } else { 6 };
        Resources {
            threads_per_block: bsx * bsy,
            smem_bytes: 0,
            regs_per_thread: regs.min(255),
            grid_blocks: COLS.div_ceil(bsx) * GPOINTS.div_ceil(bsy),
        }
    }

    fn work(&self, a: &Assignment, _dev: &Device) -> WorkEstimate {
        let cells = (COLS * GPOINTS * LAYERS) as f64;
        let recompute = a.b("recompute_denom");
        // ~14 fp64 ops per cell per sweep; recomputing the denominator in
        // the second loop adds ~4 ops but removes a store+load round trip.
        let ops = if recompute { 18.0 } else { 14.0 };
        let f64_flops = cells * ops;

        // Layered state streamed per column: 6 fp64 fields up+down, plus
        // the stored denominator when not recomputing.
        let fields = if recompute { 6.0 } else { 8.0 };
        let dram_bytes = cells * fields * 8.0;

        let unroll = a.i("loop_unroll_factor");
        let unroll_eff: f64 = match unroll {
            0 => 0.9,
            1 => 0.86,
            2 => 0.92,
            4 | 5 | 7 => 0.985,
            10 | 14 | 20 => 1.0,
            28 | 35 => 0.97,
            _ => 0.9, // 70, 140: icache pressure
        };
        let bsx = a.f("block_size_x");
        let warp_eff: f64 = if (bsx as usize) % 32 == 0 { 1.0 } else { 0.85 };
        let compute_efficiency = (0.92 * unroll_eff * warp_eff).clamp(0.05, 1.0);
        // Column-major streaming coalesces when bsx spans a warp.
        let memory_efficiency = if (bsx as usize) % 32 == 0 { 0.95 } else { 0.7 };

        WorkEstimate { f64_flops, dram_bytes, compute_efficiency, memory_efficiency, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::occupancy::{check_validity, Validity};
    use crate::space::SearchSpace;

    #[test]
    fn space_size_near_paper() {
        let k = Adding;
        let dev = Device::a100();
        let s = SearchSpace::build("adding", k.params(), &k.restrictions(&dev));
        // Paper: 4654 configurations.
        assert!(s.len() > 3000 && s.len() < 7000, "size {}", s.len());
    }

    #[test]
    fn no_invalid_configs() {
        let k = Adding;
        let dev = Device::a100();
        let s = SearchSpace::build("adding", k.params(), &k.restrictions(&dev));
        for i in 0..s.len() {
            assert_eq!(check_validity(&k.resources(&s.assignment(i), &dev), &dev), Validity::Ok);
        }
    }

    #[test]
    fn recompute_tradeoff_present() {
        // Recompute: more flops, less traffic. Store: fewer flops, more
        // traffic. Both paths must differ in both axes.
        let k = Adding;
        let dev = Device::a100();
        let s = SearchSpace::build("adding", k.params(), &k.restrictions(&dev));
        let (mut w_re, mut w_st) = (None, None);
        for i in 0..s.len() {
            let a = s.assignment(i);
            if a.b("recompute_denom") {
                w_re.get_or_insert(k.work(&a, &dev));
            } else {
                w_st.get_or_insert(k.work(&a, &dev));
            }
        }
        let (re, st) = (w_re.unwrap(), w_st.unwrap());
        assert!(re.f64_flops > st.f64_flops);
        assert!(re.dram_bytes < st.dram_bytes);
    }

    #[test]
    fn unroll_changes_efficiency() {
        let k = Adding;
        let dev = Device::a100();
        let s = SearchSpace::build("adding", k.params(), &k.restrictions(&dev));
        let effs: std::collections::HashSet<u64> = (0..s.len())
            .map(|i| (k.work(&s.assignment(i), &dev).compute_efficiency * 1e6) as u64)
            .collect();
        assert!(effs.len() > 3, "unroll factors must differentiate efficiency");
    }
}
