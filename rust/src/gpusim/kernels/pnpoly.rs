//! PnPoly — the heterogeneous point-in-polygon kernel of [54].
//!
//! 20M points are tested against a 600-vertex polygon; host→device
//! transfers overlap with GPU compute, so transfer time is part of the
//! objective (§IV-A). Tunables: block size, per-thread tile, the
//! "between" comparison method, precomputed-slopes toggle, and the overall
//! algorithm switch. No spec-stage restrictions (the paper: "PnPoly has no
//! restrictions applied"), so the space is the full Cartesian product of
//! 8184 configurations; a few percent die at runtime from register-file
//! exhaustion at large block sizes — the paper's example of invalids that
//! only the actual device reveals.

use crate::gpusim::device::Device;
use crate::gpusim::kernels::KernelModel;
use crate::gpusim::occupancy::Resources;
use crate::gpusim::timing::WorkEstimate;
use crate::space::{Assignment, SpaceSpec};

pub const POINTS: usize = 20_000_000;
pub const VERTICES: usize = 600;

#[derive(Default)]
pub struct PnPoly;

impl KernelModel for PnPoly {
    fn name(&self) -> &'static str {
        "pnpoly"
    }

    fn id(&self) -> u64 {
        0x9019
    }

    fn spec(&self, _dev: &Device) -> SpaceSpec {
        // 31 × 11 × 4 × 2 × 3 = 8184 configurations (Table II); no
        // restrictions (the paper: "PnPoly has no restrictions applied").
        let block_sizes: Vec<i64> = (1..=31).map(|i| i * 32).collect();
        SpaceSpec::new("pnpoly")
            .ints("block_size_x", &block_sizes)
            .ints("tile_size", &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11])
            .ints("between_method", &[0, 1, 2, 3])
            .ints("use_precomputed_slopes", &[0, 1])
            .ints("use_method", &[0, 1, 2])
    }

    fn resources(&self, a: &Assignment, _dev: &Device) -> Resources {
        let bsx = a.i("block_size_x") as usize;
        let tile = a.i("tile_size") as usize;
        let method = a.i("use_method") as usize;
        // Register pressure grows with the per-thread tile and the more
        // elaborate methods; large blocks × heavy variants exhaust the
        // register file at launch (runtime invalids, ~4%).
        let regs = 26 + (tile * (4 + 2 * method) * 3) / 4 + 5 * a.i("use_precomputed_slopes") as usize;
        Resources {
            threads_per_block: bsx,
            smem_bytes: if a.i("use_method") == 2 { VERTICES * 8 } else { 0 },
            regs_per_thread: regs.min(255),
            grid_blocks: POINTS.div_ceil(bsx * tile),
        }
    }

    fn work(&self, a: &Assignment, _dev: &Device) -> WorkEstimate {
        let tile = a.f("tile_size");
        let between = a.i("between_method");
        let slopes = a.b("use_precomputed_slopes");
        let method = a.i("use_method");

        // Crossing-number test: each point visits every polygon edge.
        let ops_per_edge = match between {
            0 => 7.0, // two comparisons + select
            1 => 6.0, // multiplication trick
            2 => 5.5, // bit trick
            _ => 6.5, // mixed
        } + if slopes { 2.0 } else { 4.0 };
        let flops = POINTS as f64 * VERTICES as f64 * ops_per_edge
            * match method {
                0 => 1.0,  // full crossing test
                1 => 0.55, // bounding-box prefilter (fewer edges on average)
                _ => 0.62, // smem-staged vertices, slightly more setup
            };

        // Points streamed once; vertices negligible.
        let dram_bytes = (POINTS * 8) as f64 + (POINTS * 4) as f64 / tile.max(1.0);

        // Divergence: the prefilter diverges within warps; bigger tiles
        // amortize index math.
        let divergence = match method {
            1 => 0.8,
            _ => 0.97,
        };
        let ilp = (tile / 3.0).min(1.0).powf(0.25);
        let compute_efficiency = (0.92 * divergence * ilp).clamp(0.05, 1.0);

        // Host→device: x,y per point (fp32) up, bitmask down; the kernel
        // overlaps transfers with compute in `tile`-sized stages — deeper
        // tiling overlaps better.
        let transfer_bytes = (POINTS * 8 + POINTS) as f64;
        let transfer_overlap = (0.35 + 0.05 * tile).min(0.85);

        WorkEstimate {
            flops,
            dram_bytes,
            transfer_bytes,
            transfer_overlap,
            compute_efficiency,
            memory_efficiency: 0.95,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::occupancy::{check_validity, Validity};
    use crate::space::SearchSpace;

    #[test]
    fn space_is_full_cartesian_8184() {
        let k = PnPoly;
        let dev = Device::gtx_titan_x();
        let s = SearchSpace::build("pnpoly", k.params(), &k.restrictions(&dev));
        assert_eq!(s.len(), 8184, "paper Table II: 8184 configurations");
        assert_eq!(s.cartesian_size, 8184);
    }

    #[test]
    fn a_few_percent_runtime_invalid() {
        let k = PnPoly;
        for dev in Device::all() {
            let s = SearchSpace::build("pnpoly", k.params(), &k.restrictions(&dev));
            let mut runtime = 0usize;
            let mut compile = 0usize;
            for i in 0..s.len() {
                let a = s.assignment(i);
                match check_validity(&k.resources(&a, &dev), &dev) {
                    Validity::RuntimeError => runtime += 1,
                    Validity::CompileError => compile += 1,
                    Validity::Ok => {}
                }
            }
            let frac = (runtime + compile) as f64 / s.len() as f64;
            // Paper: 3.9% (Titan X), 3.5% (2070S), 3.9% (A100).
            assert!(frac > 0.005 && frac < 0.12, "{}: invalid fraction {frac}", dev.name);
            assert!(runtime > 0, "{}: PnPoly invalids must be runtime-stage", dev.name);
        }
    }

    #[test]
    fn transfer_dominates_on_titan_x() {
        // Paper: minimum 26.97 ms on Titan X ≈ PCIe transfer of 160 MB.
        let k = PnPoly;
        let dev = Device::gtx_titan_x();
        let s = SearchSpace::build("pnpoly", k.params(), &k.restrictions(&dev));
        let a = s.assignment(0);
        let w = k.work(&a, &dev);
        let transfer_ms = w.transfer_bytes / (dev.pcie_gbs * 1e6);
        assert!(transfer_ms > 20.0 && transfer_ms < 35.0, "transfer {transfer_ms} ms");
    }

    #[test]
    fn work_depends_on_method() {
        let k = PnPoly;
        let dev = Device::a100();
        let s = SearchSpace::build("pnpoly", k.params(), &k.restrictions(&dev));
        let mut flops: Vec<f64> = Vec::new();
        for i in 0..s.len() {
            flops.push(k.work(&s.assignment(i), &dev).flops);
        }
        flops.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(flops[0] < flops[flops.len() - 1] * 0.7, "methods must differentiate work");
    }
}
