//! GEMM — the CLBlast tunable OpenCL matrix-multiplication kernel [52].
//!
//! 15 tunable parameters describing the per-block tile (MWG×NWG×KWG), the
//! thread grid inside a block (MDIMC×NDIMC), the re-shaped load grids for
//! the shared-memory staging of A and B (MDIMA, NDIMB), vector widths
//! (VWM, VWN), loop unrolling (KWI), strided access toggles (STRM, STRN),
//! and shared-memory staging toggles (SA, SB). Value sets match the
//! Kernel Tuner CLBlast benchmark: Cartesian product 82944, restricted
//! space ≈ 18k, zero compile/runtime invalids (the CLBlast restrictions
//! are exactly the validity conditions — this is why Table II reports 0%
//! invalid for GEMM).

use crate::gpusim::device::Device;
use crate::gpusim::kernels::KernelModel;
use crate::gpusim::occupancy::Resources;
use crate::gpusim::timing::WorkEstimate;
use crate::space::{Assignment, Expr, SpaceSpec};

/// Problem size: C[M,N] = A[M,K] · B[K,N], single precision.
pub const M: usize = 4096;
pub const N: usize = 4096;
pub const K: usize = 4096;

#[derive(Default)]
pub struct Gemm;

impl KernelModel for Gemm {
    fn name(&self) -> &'static str {
        "gemm"
    }

    fn id(&self) -> u64 {
        0x6e33 // arbitrary stable tag
    }

    fn spec(&self, _dev: &Device) -> SpaceSpec {
        let v = Expr::var;
        let l = Expr::lit;
        // Divisibility of a work-group axis by a (grid × vector-width)
        // product: `axis % (grid * vw) == 0`.
        let tiles_exactly = |axis: &str, grid: &str, vw: &str| v(axis).rem(v(grid).mul(v(vw))).eq(l(0));
        // Loads-per-thread guard: `lpt = MDIMC*NDIMC / stage_grid` must be
        // positive and divide KWG. The `> 0` guard short-circuits exactly
        // like the seed closure's `lpta > 0 &&` did.
        let stages_exactly = |stage_grid: &str| {
            let lpt = || v("MDIMC").mul(v("NDIMC")).div(v(stage_grid));
            lpt().gt(l(0)).and(v("KWG").rem(lpt()).eq(l(0)))
        };
        // The CLBlast validity conditions (same as the Kernel Tuner GEMM
        // benchmark). Divisibility guarantees every thread has work and
        // the staging loads tile exactly.
        SpaceSpec::new("gemm")
            .ints("MWG", &[16, 32, 64, 128])
            .ints("NWG", &[16, 32, 64, 128])
            .ints("KWG", &[32])
            .ints("MDIMC", &[8, 16, 32])
            .ints("NDIMC", &[8, 16, 32])
            .ints("MDIMA", &[8, 16, 32])
            .ints("NDIMB", &[8, 16, 32])
            .ints("KWI", &[2])
            .ints("VWM", &[1, 2, 4, 8])
            .ints("VWN", &[1, 2, 4, 8])
            .ints("STRM", &[0])
            .ints("STRN", &[0])
            .ints("SA", &[0, 1])
            .ints("SB", &[0, 1])
            .ints("PRECISION", &[32])
            .restrict_named("KWG % KWI == 0", v("KWG").rem(v("KWI")).eq(l(0)))
            .restrict_named("MWG % (MDIMC * VWM) == 0", tiles_exactly("MWG", "MDIMC", "VWM"))
            .restrict_named("NWG % (NDIMC * VWN) == 0", tiles_exactly("NWG", "NDIMC", "VWN"))
            .restrict_named("MWG % (MDIMA * VWM) == 0", tiles_exactly("MWG", "MDIMA", "VWM"))
            .restrict_named("NWG % (NDIMB * VWN) == 0", tiles_exactly("NWG", "NDIMB", "VWN"))
            .restrict_named("KWG % (MDIMC*NDIMC/MDIMA) == 0", stages_exactly("MDIMA"))
            .restrict_named("KWG % (MDIMC*NDIMC/NDIMB) == 0", stages_exactly("NDIMB"))
    }

    fn resources(&self, a: &Assignment, _dev: &Device) -> Resources {
        let (mwg, nwg, kwg) = (a.i("MWG") as usize, a.i("NWG") as usize, a.i("KWG") as usize);
        let (mdimc, ndimc) = (a.i("MDIMC") as usize, a.i("NDIMC") as usize);
        let (vwm, vwn) = (a.i("VWM") as usize, a.i("VWN") as usize);
        let threads = mdimc * ndimc;
        let smem = (a.i("SA") as usize) * kwg * mwg * 4 + (a.i("SB") as usize) * kwg * nwg * 4;
        // Accumulator tile per thread + staging vectors + indices.
        let acc = (mwg / mdimc) * (nwg / ndimc);
        let regs = 18 + acc + 2 * (vwm + vwn);
        Resources {
            threads_per_block: threads,
            smem_bytes: smem,
            regs_per_thread: regs.min(255),
            grid_blocks: (M / mwg) * (N / nwg),
        }
    }

    fn work(&self, a: &Assignment, _dev: &Device) -> WorkEstimate {
        let (mwg, nwg) = (a.f("MWG"), a.f("NWG"));
        let (mdimc, ndimc) = (a.f("MDIMC"), a.f("NDIMC"));
        let (mdima, ndimb) = (a.f("MDIMA"), a.f("NDIMB"));
        let (vwm, vwn) = (a.i("VWM"), a.i("VWN"));
        let (sa, sb) = (a.b("SA"), a.b("SB"));

        let flops = 2.0 * (M as f64) * (N as f64) * (K as f64);

        // DRAM traffic: with shared-memory staging each A tile is read once
        // per block-column; without, L1/L2 caching recovers only part of
        // the reuse.
        let a_reuse = if sa { 1.0 } else { 1.9 };
        let b_reuse = if sb { 1.0 } else { 1.9 };
        let a_traffic = (M * K * 4) as f64 * (N as f64 / nwg) * a_reuse / (K as f64 / 32.0).max(1.0) * (K as f64 / 32.0).max(1.0) / (N as f64 / nwg); // simplify below
        let _ = a_traffic;
        // Cleaner derivation: every block (there are (M/MWG)·(N/NWG)) loads
        // an MWG×K strip of A and a K×NWG strip of B.
        let blocks_m = M as f64 / mwg;
        let blocks_n = N as f64 / nwg;
        let a_bytes = blocks_n * (M as f64) * (K as f64) * 4.0 * a_reuse;
        let b_bytes = blocks_m * (N as f64) * (K as f64) * 4.0 * b_reuse;
        let c_bytes = (M * N * 4) as f64;
        let dram_bytes = a_bytes + b_bytes + c_bytes;

        // Compute efficiency: vector width sweet spots, per-thread tile ILP,
        // staging-grid mismatch, smem path overhead.
        let vw_eff = |v: i64| match v {
            1 => 0.84,
            2 => 0.95,
            4 => 1.0,
            8 => 0.93,
            _ => 0.8,
        };
        let acc = (mwg / mdimc) * (nwg / ndimc);
        // ILP from the accumulator tile: too small starves the pipeline,
        // too large thrashes the register file.
        let ilp = (acc / 16.0).min(1.0).powf(0.35) * if acc > 128.0 { 0.85 } else { 1.0 };
        let stage_a = if (mdima - mdimc).abs() > 0.0 { 0.975 } else { 1.0 };
        let stage_b = if (ndimb - ndimc).abs() > 0.0 { 0.975 } else { 1.0 };
        let smem_overhead = match (sa, sb) {
            (true, true) => 0.97,
            (true, false) | (false, true) => 0.985,
            (false, false) => 1.0,
        };
        let compute_efficiency =
            (0.96 * vw_eff(vwm) * vw_eff(vwn) * ilp * stage_a * stage_b * smem_overhead).clamp(0.05, 1.0);

        // Memory efficiency: coalescing improves with vector width of the
        // global loads; staging through smem decouples the access pattern.
        let coalesce = |v: i64| 0.72 + 0.28 * ((v as f64).log2() / 3.0);
        let mem_a = if sa { 0.97 } else { coalesce(vwm) };
        let mem_b = if sb { 0.97 } else { coalesce(vwn) };
        let memory_efficiency = (0.5 * (mem_a + mem_b)).clamp(0.05, 1.0);

        WorkEstimate {
            flops,
            dram_bytes,
            compute_efficiency,
            memory_efficiency,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::occupancy::{check_validity, Validity};
    use crate::space::SearchSpace;

    fn space(dev: &Device) -> SearchSpace {
        let g = Gemm;
        SearchSpace::build("gemm", g.params(), &g.restrictions(dev))
    }

    #[test]
    fn cartesian_matches_paper() {
        let g = Gemm;
        let cart: usize = g.params().iter().map(|p| p.len()).product();
        assert_eq!(cart, 82944, "paper: Cartesian product of size 82944");
    }

    #[test]
    fn restricted_space_near_paper() {
        let dev = Device::gtx_titan_x();
        let s = space(&dev);
        // Paper: 17956. The exact count depends on CLBlast kernel-source
        // details; require the same order and document the actual number.
        assert!(s.len() > 10_000 && s.len() < 30_000, "restricted size {}", s.len());
    }

    #[test]
    fn no_invalid_configs_on_any_device() {
        // Table II/III: GEMM has 0 invalid configurations — restrictions
        // are exactly the validity conditions.
        let g = Gemm;
        for dev in Device::all() {
            let s = space(&dev);
            for i in 0..s.len() {
                let a = s.assignment(i);
                let r = g.resources(&a, &dev);
                assert_eq!(check_validity(&r, &dev), Validity::Ok, "config {}", s.describe(i));
            }
        }
    }

    #[test]
    fn work_is_sane() {
        let dev = Device::gtx_titan_x();
        let s = space(&dev);
        let g = Gemm;
        for i in (0..s.len()).step_by(997) {
            let a = s.assignment(i);
            let w = g.work(&a, &dev);
            assert!(w.flops > 1e11 && w.flops < 2e11);
            assert!(w.dram_bytes >= (M * N * 4) as f64);
            assert!(w.compute_efficiency > 0.0 && w.compute_efficiency <= 1.0);
            assert!(w.memory_efficiency > 0.0 && w.memory_efficiency <= 1.0);
        }
    }

    #[test]
    fn smem_only_when_staging_enabled() {
        let dev = Device::gtx_titan_x();
        let s = space(&dev);
        let g = Gemm;
        for i in (0..s.len()).step_by(313) {
            let a = s.assignment(i);
            let r = g.resources(&a, &dev);
            if !a.b("SA") && !a.b("SB") {
                assert_eq!(r.smem_bytes, 0);
            } else {
                assert!(r.smem_bytes > 0);
            }
        }
    }
}
