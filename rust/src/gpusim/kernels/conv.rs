//! 2D Convolution — the CUDA image-filtering kernel of [53].
//!
//! Tunables: thread-block dimensions, per-thread tile (work per thread),
//! shared-memory padding toggle (bank-conflict avoidance), and read-only
//! cache toggle. The Cartesian product is 18432 (matching the paper); the
//! spec-stage restriction keeps thread blocks within the programming
//! model, and a large share of the remaining configurations dies at
//! *compile time* from shared-memory overruns — this is the kernel the
//! paper uses to show high invalid fractions (38.5% on the Titan X).

use crate::gpusim::device::{Arch, Device};
use crate::gpusim::kernels::KernelModel;
use crate::gpusim::occupancy::Resources;
use crate::gpusim::timing::WorkEstimate;
use crate::space::{Assignment, Expr, SpaceSpec};

/// Image and filter dimensions (fp32).
pub const IMAGE_W: usize = 4096;
pub const IMAGE_H: usize = 4096;
pub const FILTER_W: usize = 15;
pub const FILTER_H: usize = 15;

#[derive(Default)]
pub struct Convolution;

fn smem_tile_bytes(a: &Assignment) -> usize {
    let tile_w = a.i("block_size_x") as usize * a.i("tile_size_x") as usize + FILTER_W - 1;
    let tile_h = a.i("block_size_y") as usize * a.i("tile_size_y") as usize + FILTER_H - 1;
    let pad = if a.b("use_padding") { 1 } else { 0 };
    (tile_w + pad) * tile_h * 4
}

impl KernelModel for Convolution {
    fn name(&self) -> &'static str {
        "convolution"
    }

    fn id(&self) -> u64 {
        0xc0_7f01
    }

    fn spec(&self, dev: &Device) -> SpaceSpec {
        let v = Expr::var;
        let l = Expr::lit;
        // Spec-stage checks. Kernel Tuner restrictions may consult device
        // properties, which is how the same kernel yields different space
        // sizes per GPU (Table II vs Table III): device numbers are
        // inlined into the expressions as literals, so the per-device
        // spec stays serializable.
        let max_threads = dev.max_threads_per_block as i64;
        let threads = || v("block_size_x").mul(v("block_size_y"));
        let mut spec = SpaceSpec::new("convolution")
            .ints("filter_width", &[FILTER_W as i64])
            .ints("filter_height", &[FILTER_H as i64])
            .ints("block_size_x", &[1, 2, 4, 8, 16, 32, 48, 64, 80, 96, 112, 128])
            .ints("block_size_y", &[1, 2, 4, 8, 16, 32])
            .ints("tile_size_x", &[1, 2, 3, 4, 5, 6, 7, 8])
            .ints("tile_size_y", &[1, 2, 3, 4, 5, 6, 7, 8])
            .bools("use_padding")
            .bools("read_only")
            .restrict_named(
                "32 <= threads <= max",
                threads().ge(l(32)).and(threads().le(l(max_threads))),
            );
        if dev.arch != Arch::Maxwell {
            // Post-Maxwell toolchains reject tiles beyond the unified
            // L1/shared capacity already at spec time (a device-property
            // restriction, hence the smaller space in Table III). The
            // expression mirrors `smem_tile_bytes` with the padding bool
            // read as 0/1.
            let tile_w = v("block_size_x")
                .mul(v("tile_size_x"))
                .add(l(FILTER_W as i64 - 1))
                .add(v("use_padding"));
            let tile_h = v("block_size_y").mul(v("tile_size_y")).add(l(FILTER_H as i64 - 1));
            spec = spec.restrict_named(
                "tile fits unified smem/L1",
                tile_w.mul(tile_h).mul(l(4)).le(l(112 * 1024)),
            );
        }
        spec
    }

    fn resources(&self, a: &Assignment, _dev: &Device) -> Resources {
        let (bsx, bsy) = (a.i("block_size_x") as usize, a.i("block_size_y") as usize);
        let (tsx, tsy) = (a.i("tile_size_x") as usize, a.i("tile_size_y") as usize);
        let regs = 22 + 2 * tsx * tsy + if a.b("read_only") { 2 } else { 0 };
        Resources {
            threads_per_block: bsx * bsy,
            smem_bytes: smem_tile_bytes(a),
            regs_per_thread: regs.min(255),
            grid_blocks: IMAGE_W.div_ceil(bsx * tsx) * IMAGE_H.div_ceil(bsy * tsy),
        }
    }

    fn work(&self, a: &Assignment, dev: &Device) -> WorkEstimate {
        let (bsx, bsy) = (a.f("block_size_x"), a.f("block_size_y"));
        let (tsx, tsy) = (a.f("tile_size_x"), a.f("tile_size_y"));

        let outputs = (IMAGE_W * IMAGE_H) as f64;
        let flops = 2.0 * (FILTER_W * FILTER_H) as f64 * outputs;

        // Input traffic: each block stages (bsx·tsx + fw−1)×(bsy·tsy + fh−1)
        // pixels for bsx·tsx × bsy·tsy outputs — halo overhead shrinks with
        // larger tiles.
        let tile_w = bsx * tsx;
        let tile_h = bsy * tsy;
        let halo = ((tile_w + (FILTER_W - 1) as f64) * (tile_h + (FILTER_H - 1) as f64)) / (tile_w * tile_h);
        let dram_bytes = outputs * 4.0 * halo + outputs * 4.0;

        // Compute efficiency: warp shape, per-thread ILP, bank conflicts.
        let warp_eff = if bsx < 32.0 { (bsx / 32.0).max(1.0 / 32.0) * 0.9 + 0.1 } else { 1.0 };
        let ilp = ((tsx * tsy) / 4.0).min(1.0).powf(0.3);
        // Shared-memory bank conflicts: stage rows whose stride is an odd
        // multiple of the bank count conflict unless padded.
        let row = tile_w + (FILTER_W - 1) as f64 + if a.b("use_padding") { 1.0 } else { 0.0 };
        let conflicts = if (row as usize) % 32 == 0 && !a.b("use_padding") { 0.72 } else { 1.0 };
        // Base calibrated against the paper's measured minima (Table II):
        // boundary handling + filter-coefficient broadcasts keep even the
        // best configuration well under peak.
        let compute_efficiency = (0.64 * warp_eff * ilp * conflicts).clamp(0.02, 1.0);

        // Memory efficiency: coalescing needs bsx a multiple of a warp;
        // the read-only (texture) path forgives misalignment.
        let ro = a.b("read_only");
        let base_coalesce: f64 = if (bsx as usize) % 32 == 0 {
            0.98
        } else if ro {
            0.9
        } else {
            0.62
        };
        let ro_bonus: f64 = if ro && dev.arch == Arch::Maxwell { 1.0 } else if ro { 0.98 } else { 0.94 };
        let memory_efficiency = (base_coalesce * ro_bonus).clamp(0.05, 1.0);

        WorkEstimate { flops, dram_bytes, compute_efficiency, memory_efficiency, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::occupancy::{check_validity, Validity};
    use crate::space::SearchSpace;

    #[test]
    fn cartesian_matches_paper() {
        let c = Convolution;
        let cart: usize = c.params().iter().map(|p| p.len()).product();
        assert_eq!(cart, 18432, "paper: Cartesian product of size 18432");
    }

    #[test]
    fn titan_x_space_has_many_compile_invalids() {
        let dev = Device::gtx_titan_x();
        let c = Convolution;
        let s = SearchSpace::build("conv", c.params(), &c.restrictions(&dev));
        let mut invalid = 0usize;
        for i in 0..s.len() {
            let a = s.assignment(i);
            if check_validity(&c.resources(&a, &dev), &dev) != Validity::Ok {
                invalid += 1;
            }
        }
        let frac = invalid as f64 / s.len() as f64;
        // Paper: 38.5% invalid on the Titan X. Require a similar regime.
        assert!(frac > 0.2 && frac < 0.55, "invalid fraction {frac} of {}", s.len());
    }

    #[test]
    fn newer_gpus_have_smaller_space() {
        let c = Convolution;
        let s_maxwell = SearchSpace::build("conv", c.params(), &c.restrictions(&Device::gtx_titan_x()));
        let s_turing = SearchSpace::build("conv", c.params(), &c.restrictions(&Device::rtx_2070_super()));
        // Paper: 9400 (Titan X) vs 7520 (2070S / A100).
        assert!(s_turing.len() < s_maxwell.len());
    }

    #[test]
    fn smem_grows_with_tiles() {
        let c = Convolution;
        let dev = Device::gtx_titan_x();
        let s = SearchSpace::build("conv", c.params(), &c.restrictions(&dev));
        let mut seen_big = false;
        for i in 0..s.len() {
            let a = s.assignment(i);
            let r = c.resources(&a, &dev);
            assert!(r.smem_bytes >= (FILTER_W - 1) * (FILTER_H - 1) * 4);
            seen_big |= r.smem_bytes > dev.smem_per_block;
        }
        assert!(seen_big, "some configs must exceed smem (compile invalids)");
    }

    #[test]
    fn grid_covers_image() {
        let c = Convolution;
        let dev = Device::a100();
        let s = SearchSpace::build("conv", c.params(), &c.restrictions(&dev));
        for i in (0..s.len()).step_by(173) {
            let a = s.assignment(i);
            let r = c.resources(&a, &dev);
            let per_block = (a.i("block_size_x") * a.i("tile_size_x") * a.i("block_size_y") * a.i("tile_size_y")) as usize;
            assert!(r.grid_blocks * per_block >= IMAGE_W * IMAGE_H);
        }
    }
}
