//! Analytical models of the paper's five tunable GPU kernels.
//!
//! Each model declares its search space as a declarative
//! [`SpaceSpec`](crate::space::SpaceSpec) — typed params plus
//! restriction-DSL expressions, the single source of truth that also
//! serializes to JSON (`examples/spaces/*.json` are these specs as
//! files) — and maps a configuration to launch resources (driving
//! compile-/run-time invalidity and occupancy) and to a `WorkEstimate`
//! (driving the roofline time). The parameter sets mirror the Kernel
//! Tuner benchmark kernels the paper uses; constants are calibrated so
//! space sizes, invalid fractions, and minima land near Table II/III
//! (exact values reported in EXPERIMENTS.md).

pub mod adding;
pub mod conv;
pub mod expdist;
pub mod gemm;
pub mod pnpoly;

use crate::gpusim::device::Device;
use crate::gpusim::occupancy::Resources;
use crate::gpusim::timing::WorkEstimate;
use crate::space::{Assignment, Param, Restriction, SpaceSpec};

/// An analytically modeled tunable GPU kernel.
pub trait KernelModel: Send + Sync {
    /// Kernel name as used by the CLI and the harness.
    fn name(&self) -> &'static str;

    /// Stable id mixed into the roughness hash.
    fn id(&self) -> u64;

    /// Declarative space spec: typed parameters plus restriction
    /// expressions. May depend on the device (Kernel Tuner restrictions
    /// can reference device properties — the device's numbers are inlined
    /// as literals, so the spec stays serializable).
    fn spec(&self, dev: &Device) -> SpaceSpec;

    /// Tunable parameters (device-independent, as in Kernel Tuner) —
    /// derived from the spec on a reference device.
    fn params(&self) -> Vec<Param> {
        self.spec(&Device::gtx_titan_x()).params()
    }

    /// Spec-stage restrictions for `dev`, derived from the spec.
    fn restrictions(&self, dev: &Device) -> Vec<Restriction> {
        self.spec(dev).restrictions()
    }

    /// Launch resources of a configuration.
    fn resources(&self, a: &Assignment, dev: &Device) -> Resources;

    /// Work estimate of a configuration.
    fn work(&self, a: &Assignment, dev: &Device) -> WorkEstimate;

    /// Transform raw kernel time into the tuning objective. Default:
    /// identity (minimize milliseconds). ExpDist overrides this with
    /// 10⁵ / GFLOP/s because its work depends on the configuration (§IV-E).
    fn objective(&self, time_ms: f64, _a: &Assignment, _dev: &Device) -> f64 {
        time_ms
    }
}

/// All five kernels, in the paper's order.
pub fn all_kernels() -> Vec<Box<dyn KernelModel>> {
    vec![
        Box::new(gemm::Gemm),
        Box::new(conv::Convolution),
        Box::new(pnpoly::PnPoly),
        Box::new(expdist::ExpDist),
        Box::new(adding::Adding),
    ]
}

/// Look a kernel up by CLI name.
pub fn kernel_by_name(name: &str) -> Option<Box<dyn KernelModel>> {
    match name.to_ascii_lowercase().as_str() {
        "gemm" => Some(Box::new(gemm::Gemm)),
        "convolution" | "conv" => Some(Box::new(conv::Convolution)),
        "pnpoly" => Some(Box::new(pnpoly::PnPoly)),
        "expdist" => Some(Box::new(expdist::ExpDist)),
        "adding" => Some(Box::new(adding::Adding)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::testref::odometer_reference;
    use crate::space::SearchSpace;

    /// Hand-written closure twins of every kernel's DSL restrictions —
    /// the seed-era predicates, verbatim.
    fn closure_restrictions(kernel: &str, dev: &Device) -> Vec<Restriction> {
        use crate::gpusim::device::Arch;
        match kernel {
            "gemm" => vec![
                Restriction::new("KWG % KWI == 0", |a| a.i("KWG") % a.i("KWI") == 0),
                Restriction::new("MWG % (MDIMC * VWM) == 0", |a| {
                    a.i("MWG") % (a.i("MDIMC") * a.i("VWM")) == 0
                }),
                Restriction::new("NWG % (NDIMC * VWN) == 0", |a| {
                    a.i("NWG") % (a.i("NDIMC") * a.i("VWN")) == 0
                }),
                Restriction::new("MWG % (MDIMA * VWM) == 0", |a| {
                    a.i("MWG") % (a.i("MDIMA") * a.i("VWM")) == 0
                }),
                Restriction::new("NWG % (NDIMB * VWN) == 0", |a| {
                    a.i("NWG") % (a.i("NDIMB") * a.i("VWN")) == 0
                }),
                Restriction::new("KWG % (MDIMC*NDIMC/MDIMA) == 0", |a| {
                    let lpta = (a.i("MDIMC") * a.i("NDIMC")) / a.i("MDIMA");
                    lpta > 0 && a.i("KWG") % lpta == 0
                }),
                Restriction::new("KWG % (MDIMC*NDIMC/NDIMB) == 0", |a| {
                    let lptb = (a.i("MDIMC") * a.i("NDIMC")) / a.i("NDIMB");
                    lptb > 0 && a.i("KWG") % lptb == 0
                }),
            ],
            "convolution" => {
                let max_threads = dev.max_threads_per_block as i64;
                let mut r = vec![Restriction::new("32 <= threads <= max", move |a| {
                    let t = a.i("block_size_x") * a.i("block_size_y");
                    (32..=max_threads).contains(&t)
                })];
                if dev.arch != Arch::Maxwell {
                    r.push(Restriction::new("tile fits unified smem/L1", |a| {
                        let tile_w = a.i("block_size_x") as usize * a.i("tile_size_x") as usize
                            + conv::FILTER_W
                            - 1;
                        let tile_h = a.i("block_size_y") as usize * a.i("tile_size_y") as usize
                            + conv::FILTER_H
                            - 1;
                        let pad = if a.b("use_padding") { 1 } else { 0 };
                        (tile_w + pad) * tile_h * 4 <= 112 * 1024
                    }));
                }
                r
            }
            "pnpoly" => Vec::new(),
            "expdist" => vec![
                Restriction::new("threads <= 1024", |a| {
                    a.i("block_size_x") * a.i("block_size_y") <= 1024
                }),
                Restriction::new("unroll divides tile", |a| {
                    let u = a.i("loop_unroll_factor_x");
                    u == 0 || a.i("tile_size_x") % u == 0
                }),
            ],
            "adding" => vec![
                Restriction::new("threads <= 1024", |a| {
                    a.i("block_size_x") * a.i("block_size_y") <= 1024
                }),
                Restriction::new("threads >= 32", |a| {
                    a.i("block_size_x") * a.i("block_size_y") >= 32
                }),
            ],
            other => panic!("no closure twin for kernel '{other}'"),
        }
    }

    /// Acceptance: the DSL restrictions keep every kernel's space — size
    /// *and* membership — identical to the seed-era closures, on a
    /// Maxwell and a post-Maxwell device (conv's restrictions differ).
    #[test]
    fn dsl_restrictions_match_closures_on_all_kernels() {
        for dev in [Device::gtx_titan_x(), Device::a100()] {
            for k in all_kernels() {
                let via_spec = k.spec(&dev).build();
                let via_closures = SearchSpace::build(
                    k.name(),
                    k.params(),
                    &closure_restrictions(k.name(), &dev),
                );
                assert_eq!(
                    via_spec.len(),
                    via_closures.len(),
                    "{} on {}: restricted sizes differ",
                    k.name(),
                    dev.name
                );
                for i in 0..via_spec.len() {
                    assert_eq!(
                        via_spec.key(i),
                        via_closures.key(i),
                        "{} on {}: config {i} differs",
                        k.name(),
                        dev.name
                    );
                }
            }
        }
    }

    /// Acceptance: the constraint-propagating columnar enumerator yields
    /// byte-identical config ordering to the seed odometer, on all five
    /// kernels.
    #[test]
    fn enumeration_matches_seed_odometer_on_all_kernels() {
        let dev = Device::gtx_titan_x();
        for k in all_kernels() {
            let expected = odometer_reference(&k.params(), &k.restrictions(&dev));
            let s = k.spec(&dev).build();
            assert_eq!(s.len(), expected.len(), "{}: size differs", k.name());
            for (i, cfg) in expected.iter().enumerate() {
                assert_eq!(&s.config(i), cfg, "{}: order diverged at {i}", k.name());
            }
        }
    }

    /// Parallel spec builds must reproduce the serial enumeration bit for
    /// bit on a real kernel space.
    #[test]
    fn parallel_kernel_space_build_is_bit_identical() {
        use crate::util::pool::ShardPool;
        let dev = Device::a100();
        let k = kernel_by_name("expdist").unwrap();
        let serial = k.spec(&dev).build();
        for threads in [2, 8] {
            let pool = ShardPool::new(threads);
            let par = k.spec(&dev).build_par(&pool);
            assert_eq!(par.len(), serial.len());
            for i in 0..serial.len() {
                assert_eq!(par.key(i), serial.key(i), "threads={threads} config {i}");
            }
        }
    }

    /// Every kernel's spec round-trips losslessly through JSON and the
    /// parsed twin builds the same restricted space.
    #[test]
    fn kernel_specs_roundtrip_through_json() {
        use crate::space::SpaceSpec;
        let dev = Device::gtx_titan_x();
        for k in all_kernels() {
            let spec = k.spec(&dev);
            let parsed = SpaceSpec::parse(&spec.to_json().render_pretty())
                .unwrap_or_else(|e| panic!("{}: {e}", k.name()));
            assert_eq!(parsed, spec, "{}: spec changed across JSON", k.name());
            assert_eq!(parsed.build().len(), spec.build().len(), "{}", k.name());
        }
    }
}
