//! Analytical models of the paper's five tunable GPU kernels.
//!
//! Each model declares its tunable parameters and spec-stage restrictions
//! (these define the search space, Table II/III "Configurations"), maps a
//! configuration to launch resources (driving compile-/run-time invalidity
//! and occupancy) and to a `WorkEstimate` (driving the roofline time).
//! The parameter sets mirror the Kernel Tuner benchmark kernels the paper
//! uses; constants are calibrated so space sizes, invalid fractions, and
//! minima land near Table II/III (exact values reported in
//! EXPERIMENTS.md).

pub mod adding;
pub mod conv;
pub mod expdist;
pub mod gemm;
pub mod pnpoly;

use crate::gpusim::device::Device;
use crate::gpusim::occupancy::Resources;
use crate::gpusim::timing::WorkEstimate;
use crate::space::{Assignment, Param, Restriction};

/// An analytically modeled tunable GPU kernel.
pub trait KernelModel: Send + Sync {
    /// Kernel name as used by the CLI and the harness.
    fn name(&self) -> &'static str;

    /// Stable id mixed into the roughness hash.
    fn id(&self) -> u64;

    /// Tunable parameters (device-independent, as in Kernel Tuner).
    fn params(&self) -> Vec<Param>;

    /// Spec-stage restrictions; may depend on the device (Kernel Tuner
    /// restrictions can reference device properties).
    fn restrictions(&self, dev: &Device) -> Vec<Restriction>;

    /// Launch resources of a configuration.
    fn resources(&self, a: &Assignment, dev: &Device) -> Resources;

    /// Work estimate of a configuration.
    fn work(&self, a: &Assignment, dev: &Device) -> WorkEstimate;

    /// Transform raw kernel time into the tuning objective. Default:
    /// identity (minimize milliseconds). ExpDist overrides this with
    /// 10⁵ / GFLOP/s because its work depends on the configuration (§IV-E).
    fn objective(&self, time_ms: f64, _a: &Assignment, _dev: &Device) -> f64 {
        time_ms
    }
}

/// All five kernels, in the paper's order.
pub fn all_kernels() -> Vec<Box<dyn KernelModel>> {
    vec![
        Box::new(gemm::Gemm::default()),
        Box::new(conv::Convolution::default()),
        Box::new(pnpoly::PnPoly::default()),
        Box::new(expdist::ExpDist::default()),
        Box::new(adding::Adding::default()),
    ]
}

/// Look a kernel up by CLI name.
pub fn kernel_by_name(name: &str) -> Option<Box<dyn KernelModel>> {
    match name.to_ascii_lowercase().as_str() {
        "gemm" => Some(Box::new(gemm::Gemm::default())),
        "convolution" | "conv" => Some(Box::new(conv::Convolution::default())),
        "pnpoly" => Some(Box::new(pnpoly::PnPoly::default())),
        "expdist" => Some(Box::new(expdist::ExpDist::default())),
        "adding" => Some(Box::new(adding::Adding::default())),
        _ => None,
    }
}
