//! ExpDist — double-precision Bhattacharyya-distance kernel of [55]
//! (template-free particle fusion in localization microscopy).
//!
//! This is the paper's first *unseen* kernel (§IV-E), run on the A100
//! only. Two properties matter for the reproduction: (1) it is fp64, so
//! the A100's 1:2 fp64 rate (vs 1:32 on consumer GPUs) shapes the
//! landscape; (2) the amount of work depends on the configuration, so the
//! objective is 10⁵ / (GFLOP/s) rather than raw time — optimizing time
//! would reward configurations that do the least work. Roughly half the
//! restricted space is invalid (50.8% in the paper) due to shared-memory
//! and register overruns from the 2D tiling.

use crate::gpusim::device::Device;
use crate::gpusim::kernels::KernelModel;
use crate::gpusim::occupancy::Resources;
use crate::gpusim::timing::WorkEstimate;
use crate::space::{Assignment, Expr, SpaceSpec};

/// Localization point-set sizes (model and template).
pub const N_A: usize = 2048;
pub const N_B: usize = 2048;

#[derive(Default)]
pub struct ExpDist;

fn useful_flops(a: &Assignment) -> f64 {
    // Each (i,j) pair evaluates an anisotropic Gaussian overlap: exp, two
    // divisions, ~20 fused ops.
    let pairs = (N_A * N_B) as f64;
    let unroll = a.f("loop_unroll_factor_x").max(1.0);
    // Unrolling removes loop overhead: fewer *total* instructions for the
    // same useful work; model as useful work constant.
    let _ = unroll;
    pairs * 26.0
}

impl KernelModel for ExpDist {
    fn name(&self) -> &'static str {
        "expdist"
    }

    fn id(&self) -> u64 {
        0xe84d
    }

    fn spec(&self, _dev: &Device) -> SpaceSpec {
        let v = Expr::var;
        let l = Expr::lit;
        // `unroll == 0` means "compiler default" and must short-circuit
        // the divisibility check (`% 0` is unknown and would reject).
        let unroll_divides = v("loop_unroll_factor_x")
            .eq(l(0))
            .or(v("tile_size_x").rem(v("loop_unroll_factor_x")).eq(l(0)));
        SpaceSpec::new("expdist")
            .ints("block_size_x", &[32, 64, 128, 256, 512, 1024])
            .ints("block_size_y", &[1, 2, 4, 8])
            .ints("tile_size_x", &[1, 2, 3, 4, 5, 6, 7, 8])
            .ints("tile_size_y", &[1, 2, 3, 4, 6, 8])
            .ints("loop_unroll_factor_x", &[0, 1, 2, 4])
            .ints("use_shared_mem", &[0, 1])
            .ints("n_y_blocks", &[1, 2, 4])
            .restrict_named(
                "threads <= 1024",
                v("block_size_x").mul(v("block_size_y")).le(l(1024)),
            )
            .restrict_named("unroll divides tile", unroll_divides)
    }

    fn resources(&self, a: &Assignment, _dev: &Device) -> Resources {
        let (bsx, bsy) = (a.i("block_size_x") as usize, a.i("block_size_y") as usize);
        let (tsx, tsy) = (a.i("tile_size_x") as usize, a.i("tile_size_y") as usize);
        // fp64 doubles register cost; 2D tiles hold a tsx×tsy accumulator
        // patch of doubles (2 regs each) plus staged coordinates and the
        // per-pair Gaussian intermediates.
        let regs = 34 + 6 * tsx * tsy + 6 * tsx + if a.b("use_shared_mem") { 8 } else { 0 };
        let smem = if a.b("use_shared_mem") {
            // Stage a tile of B points: (bsx·tsx) points × 5 doubles
            // (x, y, sx, sy, w).
            bsx * tsx * 5 * 8
        } else {
            0
        };
        Resources {
            threads_per_block: bsx * bsy,
            smem_bytes: smem,
            regs_per_thread: regs.min(300), // may exceed 255 → compile error
            grid_blocks: N_A.div_ceil(bsx * tsx).max(1) * a.i("n_y_blocks") as usize,
        }
    }

    fn work(&self, a: &Assignment, _dev: &Device) -> WorkEstimate {
        let flops = useful_flops(a);
        let (tsx, tsy) = (a.f("tile_size_x"), a.f("tile_size_y"));
        let unroll = a.i("loop_unroll_factor_x");
        let shared = a.b("use_shared_mem");

        // B-point traffic: re-read per block unless staged in smem.
        let reuse = if shared { 1.0 } else { 2.2 };
        let dram_bytes = (N_A + N_B) as f64 * 5.0 * 8.0 * reuse * (a.f("n_y_blocks")).max(1.0);

        let ilp = ((tsx * tsy) / 6.0).min(1.0).powf(0.3);
        let unroll_eff = match unroll {
            0 => 0.88, // compiler default
            1 => 0.9,
            2 => 0.97,
            4 => 1.0,
            _ => 0.9,
        };
        let compute_efficiency = (0.9 * ilp * unroll_eff).clamp(0.05, 1.0);

        WorkEstimate {
            flops: 0.0,
            f64_flops: flops, // fp64 kernel
            dram_bytes,
            compute_efficiency,
            memory_efficiency: if shared { 0.95 } else { 0.75 },
            ..Default::default()
        }
    }

    fn objective(&self, time_ms: f64, a: &Assignment, _dev: &Device) -> f64 {
        // §IV-E: 10⁵ / (GFLOP/s) — lower is better, work varies per config.
        let gflops = useful_flops(a) / 1e9;
        let gflop_per_s = gflops / (time_ms / 1e3);
        1e5 / gflop_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::occupancy::{check_validity, Validity};
    use crate::space::SearchSpace;

    #[test]
    fn space_size_near_paper() {
        let k = ExpDist;
        let dev = Device::a100();
        let s = SearchSpace::build("expdist", k.params(), &k.restrictions(&dev));
        // Paper: 14400 restricted configurations.
        assert!(s.len() > 5_000 && s.len() < 20_000, "size {}", s.len());
    }

    #[test]
    fn about_half_invalid_on_a100() {
        let k = ExpDist;
        let dev = Device::a100();
        let s = SearchSpace::build("expdist", k.params(), &k.restrictions(&dev));
        let invalid = (0..s.len())
            .filter(|&i| check_validity(&k.resources(&s.assignment(i), &dev), &dev) != Validity::Ok)
            .count();
        let frac = invalid as f64 / s.len() as f64;
        // Paper: 50.8% invalid.
        assert!(frac > 0.3 && frac < 0.7, "invalid fraction {frac}");
    }

    #[test]
    fn objective_rewards_throughput_not_low_work() {
        let k = ExpDist;
        let dev = Device::a100();
        let s = SearchSpace::build("expdist", k.params(), &k.restrictions(&dev));
        // Two configs with the same time but different useful work must have
        // different objective: more work per second = better (lower).
        let a0 = s.assignment(0);
        let o_fast = k.objective(10.0, &a0, &dev);
        let o_slow = k.objective(20.0, &a0, &dev);
        assert!(o_fast < o_slow);
    }

    #[test]
    fn fp64_work_billed_as_fp64() {
        let k = ExpDist;
        let dev = Device::a100();
        let s = SearchSpace::build("expdist", k.params(), &k.restrictions(&dev));
        let w = k.work(&s.assignment(0), &dev);
        assert_eq!(w.flops, 0.0);
        assert!(w.f64_flops > 0.0);
    }
}
