//! GPU performance-model simulator — the substrate that replaces real GPU
//! execution (see DESIGN.md §Substitutions). Mirrors the simulation mode
//! the paper itself contributes to Kernel Tuner: search strategies are
//! benchmarked against a fixed `(configuration) → time | invalid` table.

pub mod device;
pub mod kernels;
pub mod occupancy;
pub mod timing;

use crate::gpusim::device::Device;
use crate::gpusim::kernels::KernelModel;
use crate::gpusim::occupancy::{check_validity, Validity};
use crate::gpusim::timing::{config_key, execution_time_ms, noise_key};
use crate::objective::Eval;
use crate::space::SearchSpace;

/// A fully materialized simulated search space: the restricted space plus
/// the evaluation table (Kernel Tuner "simulation mode" cache).
pub struct SimulatedSpace {
    pub space: SearchSpace,
    pub table: Vec<Eval>,
    pub device_name: String,
    pub kernel_name: String,
}

impl SimulatedSpace {
    /// Build the space for a kernel on a device (through the kernel's
    /// declarative [`SpaceSpec`](crate::space::SpaceSpec)) and evaluate
    /// every configuration through the analytical model.
    pub fn build(kernel: &dyn KernelModel, dev: &Device) -> SimulatedSpace {
        Self::build_with_space(kernel, dev, kernel.spec(dev).build())
    }

    /// Evaluate an externally supplied space — e.g. one loaded from a
    /// `--space <file.json>` spec — through the kernel's analytical
    /// model. The space's parameters must carry the names the model
    /// reads (value sets and restrictions are free to differ from the
    /// kernel's built-in spec; that is the point).
    pub fn build_with_space(
        kernel: &dyn KernelModel,
        dev: &Device,
        space: SearchSpace,
    ) -> SimulatedSpace {
        let mut table = Vec::with_capacity(space.len());
        for i in 0..space.len() {
            let a = space.assignment(i);
            let res = kernel.resources(&a, dev);
            let eval = match check_validity(&res, dev) {
                Validity::CompileError => Eval::CompileError,
                Validity::RuntimeError => Eval::RuntimeError,
                Validity::Ok => {
                    let w = kernel.work(&a, dev);
                    let key = noise_key(kernel.id(), dev.name, config_key(&space.config(i)));
                    let t = execution_time_ms(&w, &res, dev, key);
                    Eval::Valid(kernel.objective(t, &a, dev))
                }
            };
            table.push(eval);
        }
        SimulatedSpace {
            space,
            table,
            device_name: dev.name.to_string(),
            kernel_name: kernel.name().to_string(),
        }
    }

    /// Number of invalid configurations (compile + runtime).
    pub fn invalid_count(&self) -> usize {
        self.table.iter().filter(|e| !matches!(e, Eval::Valid(_))).count()
    }

    /// Global minimum objective value and its index.
    pub fn global_minimum(&self) -> (usize, f64) {
        let mut best = (usize::MAX, f64::INFINITY);
        for (i, e) in self.table.iter().enumerate() {
            if let Eval::Valid(v) = e {
                if *v < best.1 {
                    best = (i, *v);
                }
            }
        }
        assert!(best.0 != usize::MAX, "space has no valid configuration");
        best
    }

    /// Mean of the valid objective values (useful for MDF context).
    pub fn valid_mean(&self) -> f64 {
        let vals: Vec<f64> = self.table.iter().filter_map(|e| e.value()).collect();
        crate::util::linalg::mean(&vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::kernels::kernel_by_name;

    #[test]
    fn gemm_titan_x_matches_table_ii_regime() {
        let k = kernel_by_name("gemm").unwrap();
        let sim = SimulatedSpace::build(k.as_ref(), &Device::gtx_titan_x());
        assert_eq!(sim.invalid_count(), 0, "Table II: GEMM 0% invalid");
        let (_, min) = sim.global_minimum();
        // Paper: 28.307 ms. Analytical model should land in the same regime.
        assert!(min > 15.0 && min < 60.0, "GEMM Titan X minimum {min} ms");
    }

    #[test]
    fn conv_minimum_regime() {
        let k = kernel_by_name("convolution").unwrap();
        let sim = SimulatedSpace::build(k.as_ref(), &Device::gtx_titan_x());
        let (_, min) = sim.global_minimum();
        // Paper: 1.625 ms on the Titan X.
        assert!(min > 0.5 && min < 5.0, "Conv Titan X minimum {min} ms");
        assert!(sim.invalid_count() > 0);
    }

    #[test]
    fn pnpoly_minimum_regime() {
        let k = kernel_by_name("pnpoly").unwrap();
        let sim = SimulatedSpace::build(k.as_ref(), &Device::gtx_titan_x());
        let (_, min) = sim.global_minimum();
        // Paper: 26.968 ms (transfer-bound).
        assert!(min > 10.0 && min < 60.0, "PnPoly Titan X minimum {min} ms");
    }

    #[test]
    fn devices_produce_different_tables() {
        let k = kernel_by_name("gemm").unwrap();
        let a = SimulatedSpace::build(k.as_ref(), &Device::gtx_titan_x());
        let b = SimulatedSpace::build(k.as_ref(), &Device::a100());
        let (ia, ma) = a.global_minimum();
        let (ib, mb) = b.global_minimum();
        assert!(mb < ma, "A100 must be faster at GEMM ({mb} vs {ma})");
        // Different devices generally shift the optimum location too.
        let _ = (ia, ib);
    }

    #[test]
    fn tables_are_deterministic() {
        let k = kernel_by_name("adding").unwrap();
        let a = SimulatedSpace::build(k.as_ref(), &Device::a100());
        let b = SimulatedSpace::build(k.as_ref(), &Device::a100());
        for (x, y) in a.table.iter().zip(&b.table) {
            assert_eq!(x.value(), y.value());
        }
    }
}
