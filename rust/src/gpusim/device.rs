//! GPU device models for the three GPUs the paper evaluates on.
//!
//! Substitution note (DESIGN.md): the paper timed real kernels on real
//! GPUs; this reproduction replaces execution with an analytical
//! performance model. The device description carries exactly the resources
//! that drive (a) occupancy, (b) resource-limit invalidity (the paper's
//! compile-/run-time invalid configurations), and (c) roofline throughput.
//! Numbers follow the public spec sheets cited in the paper ([49]–[51]).

/// GPU architecture generation; drives a few model details (shared-memory
/// bank width, transfer link generation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Maxwell,
    Turing,
    Ampere,
}

/// An analytical GPU device model.
#[derive(Clone, Debug)]
pub struct Device {
    pub name: &'static str,
    pub arch: Arch,
    pub sm_count: usize,
    pub cores_per_sm: usize,
    pub clock_ghz: f64,
    /// Programming-model limit on threads per block.
    pub max_threads_per_block: usize,
    pub max_threads_per_sm: usize,
    pub max_blocks_per_sm: usize,
    /// Static shared memory available to one block at compile time (bytes).
    pub smem_per_block: usize,
    /// Shared memory per SM available for occupancy (bytes).
    pub smem_per_sm: usize,
    /// Register file per SM (32-bit registers).
    pub regfile_per_sm: usize,
    /// Hardware cap on registers per thread.
    pub max_regs_per_thread: usize,
    /// DRAM bandwidth (GB/s).
    pub dram_gbs: f64,
    /// L2 cache size (KiB).
    pub l2_kib: usize,
    /// Host↔device transfer bandwidth (GB/s) — PCIe generation dependent.
    pub pcie_gbs: f64,
    /// fp64 throughput as a fraction of fp32.
    pub fp64_ratio: f64,
    /// Fixed kernel-launch overhead (ms).
    pub launch_overhead_ms: f64,
}

impl Device {
    /// Peak fp32 throughput in GFLOP/s (2 FLOPs per core per cycle: FMA).
    pub fn peak_gflops(&self) -> f64 {
        (self.sm_count * self.cores_per_sm) as f64 * self.clock_ghz * 2.0
    }

    pub fn peak_gflops_f64(&self) -> f64 {
        self.peak_gflops() * self.fp64_ratio
    }

    /// NVIDIA GTX Titan X (Maxwell, 2015) — the paper's primary GPU [49].
    pub fn gtx_titan_x() -> Device {
        Device {
            name: "GTX Titan X",
            arch: Arch::Maxwell,
            sm_count: 24,
            cores_per_sm: 128,
            clock_ghz: 1.075,
            max_threads_per_block: 1024,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 32,
            smem_per_block: 48 * 1024,
            smem_per_sm: 96 * 1024,
            regfile_per_sm: 65536,
            max_regs_per_thread: 255,
            dram_gbs: 336.6,
            l2_kib: 3072,
            pcie_gbs: 6.0, // PCIe 3.0 x16 effective
            fp64_ratio: 1.0 / 32.0,
            launch_overhead_ms: 0.006,
        }
    }

    /// NVIDIA RTX 2070 Super (Turing, 2019) [50].
    pub fn rtx_2070_super() -> Device {
        Device {
            name: "RTX 2070 Super",
            arch: Arch::Turing,
            sm_count: 40,
            cores_per_sm: 64,
            clock_ghz: 1.770,
            max_threads_per_block: 1024,
            max_threads_per_sm: 1024,
            max_blocks_per_sm: 16,
            smem_per_block: 48 * 1024,
            smem_per_sm: 64 * 1024,
            regfile_per_sm: 65536,
            max_regs_per_thread: 255,
            dram_gbs: 448.0,
            l2_kib: 4096,
            pcie_gbs: 11.0,
            fp64_ratio: 1.0 / 32.0,
            launch_overhead_ms: 0.005,
        }
    }

    /// NVIDIA A100 SXM4 40 GB (Ampere, 2020) [51].
    pub fn a100() -> Device {
        Device {
            name: "A100",
            arch: Arch::Ampere,
            sm_count: 108,
            cores_per_sm: 64,
            clock_ghz: 1.410,
            max_threads_per_block: 1024,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 32,
            smem_per_block: 48 * 1024,
            smem_per_sm: 164 * 1024,
            regfile_per_sm: 65536,
            max_regs_per_thread: 255,
            dram_gbs: 1555.0,
            l2_kib: 40 * 1024,
            // Effective host link in the paper's testbed: PnPoly's A100
            // minimum (13.09 ms) is *worse* than the 2070 Super's (12.33),
            // indicating a slower effective host↔device path than raw
            // PCIe 4.0 (SXM4 board behind a PCIe switch).
            pcie_gbs: 10.5,
            fp64_ratio: 0.5,
            launch_overhead_ms: 0.004,
        }
    }

    pub fn by_name(name: &str) -> Option<Device> {
        match name.to_ascii_lowercase().replace([' ', '-', '_'], "").as_str() {
            "gtxtitanx" | "titanx" | "maxwell" => Some(Device::gtx_titan_x()),
            "rtx2070super" | "2070super" | "2070s" | "turing" => Some(Device::rtx_2070_super()),
            "a100" | "ampere" => Some(Device::a100()),
            _ => None,
        }
    }

    pub fn all() -> Vec<Device> {
        vec![Device::gtx_titan_x(), Device::rtx_2070_super(), Device::a100()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_flops_match_spec_sheets() {
        // Spec-sheet fp32 peaks: Titan X ≈ 6.6 TF, 2070S ≈ 9.06 TF, A100 ≈ 19.5 TF.
        assert!((Device::gtx_titan_x().peak_gflops() - 6604.8).abs() < 10.0);
        assert!((Device::rtx_2070_super().peak_gflops() - 9062.4).abs() < 10.0);
        assert!((Device::a100().peak_gflops() - 19491.8).abs() < 20.0);
    }

    #[test]
    fn a100_fp64_is_half_rate() {
        let d = Device::a100();
        assert!((d.peak_gflops_f64() / d.peak_gflops() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Device::by_name("A100").unwrap().name, "A100");
        assert_eq!(Device::by_name("gtx-titan-x").unwrap().name, "GTX Titan X");
        assert_eq!(Device::by_name("2070s").unwrap().name, "RTX 2070 Super");
        assert!(Device::by_name("h100").is_none());
    }

    #[test]
    fn all_returns_three() {
        assert_eq!(Device::all().len(), 3);
    }
}
