//! Covariance (kernel) functions for the GP surrogate (§III-B).
//!
//! The paper selects the Matérn family with *fixed* lengthscale — rough
//! discrete landscapes break the usual marginal-likelihood lengthscale
//! fitting (the lengthscale collapses to the least smooth region), so the
//! hyperparameter table fixes ν=3/2 with l=2.0 (l=1.5 when the contextual
//! variance exploration factor is active). RBF and Rational Quadratic are
//! implemented for the ablation benches.

/// A stationary covariance function k(r) over Euclidean distance r.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CovFn {
    /// Matérn ν=3/2: (1 + √3 r/l)·exp(−√3 r/l) — rough, once-differentiable.
    Matern32 { lengthscale: f64 },
    /// Matérn ν=5/2: (1 + √5 r/l + 5r²/3l²)·exp(−√5 r/l).
    Matern52 { lengthscale: f64 },
    /// Squared exponential.
    Rbf { lengthscale: f64 },
    /// Scale mixture of RBFs.
    RationalQuadratic { lengthscale: f64, alpha: f64 },
}

impl CovFn {
    /// Covariance at distance r (unit signal variance).
    #[inline]
    pub fn eval(&self, r: f64) -> f64 {
        debug_assert!(r >= 0.0);
        match *self {
            CovFn::Matern32 { lengthscale } => {
                let s = 3f64.sqrt() * r / lengthscale;
                (1.0 + s) * (-s).exp()
            }
            CovFn::Matern52 { lengthscale } => {
                let s = 5f64.sqrt() * r / lengthscale;
                (1.0 + s + s * s / 3.0) * (-s).exp()
            }
            CovFn::Rbf { lengthscale } => (-0.5 * (r / lengthscale) * (r / lengthscale)).exp(),
            CovFn::RationalQuadratic { lengthscale, alpha } => {
                (1.0 + r * r / (2.0 * alpha * lengthscale * lengthscale)).powf(-alpha)
            }
        }
    }

    pub fn lengthscale(&self) -> f64 {
        match *self {
            CovFn::Matern32 { lengthscale }
            | CovFn::Matern52 { lengthscale }
            | CovFn::Rbf { lengthscale }
            | CovFn::RationalQuadratic { lengthscale, .. } => lengthscale,
        }
    }

    /// Short name for configs/CLI; parsed by `parse`.
    pub fn name(&self) -> &'static str {
        match self {
            CovFn::Matern32 { .. } => "matern32",
            CovFn::Matern52 { .. } => "matern52",
            CovFn::Rbf { .. } => "rbf",
            CovFn::RationalQuadratic { .. } => "rq",
        }
    }

    pub fn parse(name: &str, lengthscale: f64) -> Option<CovFn> {
        match name {
            "matern32" => Some(CovFn::Matern32 { lengthscale }),
            "matern52" => Some(CovFn::Matern52 { lengthscale }),
            "rbf" => Some(CovFn::Rbf { lengthscale }),
            "rq" => Some(CovFn::RationalQuadratic { lengthscale, alpha: 1.0 }),
            _ => None,
        }
    }
}

/// Euclidean distance between two points.
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// Euclidean distance between two f32-stored points, accumulated in f64 —
/// bit-identical to [`dist`] over the f64 images of the same coordinates
/// (f32 → f64 conversion is exact), which is what lets the GP consume the
/// search space's f32 normalized tiles directly.
#[inline]
pub fn dist32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = f64::from(x) - f64::from(y);
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    const COVS: [CovFn; 4] = [
        CovFn::Matern32 { lengthscale: 2.0 },
        CovFn::Matern52 { lengthscale: 0.8 },
        CovFn::Rbf { lengthscale: 1.0 },
        CovFn::RationalQuadratic { lengthscale: 1.0, alpha: 1.0 },
    ];

    #[test]
    fn unit_at_zero_distance() {
        for c in COVS {
            assert!((c.eval(0.0) - 1.0).abs() < 1e-12, "{c:?}");
        }
    }

    #[test]
    fn monotone_decreasing() {
        for c in COVS {
            let mut prev = c.eval(0.0);
            for i in 1..50 {
                let v = c.eval(i as f64 * 0.1);
                assert!(v < prev + 1e-15, "{c:?} not decreasing at r={}", i as f64 * 0.1);
                assert!(v > 0.0);
                prev = v;
            }
        }
    }

    #[test]
    fn matern32_matches_closed_form() {
        // k(r) = (1 + √3 r/l) exp(−√3 r/l), l = 2, r = 1.
        let c = CovFn::Matern32 { lengthscale: 2.0 };
        let s = 3f64.sqrt() / 2.0;
        assert!((c.eval(1.0) - (1.0 + s) * (-s).exp()).abs() < 1e-14);
    }

    #[test]
    fn matern52_smoother_than_matern32() {
        // At small r, ν=5/2 stays closer to 1 (smoother process).
        let m32 = CovFn::Matern32 { lengthscale: 1.0 };
        let m52 = CovFn::Matern52 { lengthscale: 1.0 };
        assert!(m52.eval(0.1) > m32.eval(0.1));
    }

    #[test]
    fn longer_lengthscale_is_smoother() {
        let short = CovFn::Matern32 { lengthscale: 0.5 };
        let long = CovFn::Matern32 { lengthscale: 3.0 };
        assert!(long.eval(1.0) > short.eval(1.0));
    }

    #[test]
    fn parse_roundtrip() {
        for c in COVS {
            let p = CovFn::parse(c.name(), c.lengthscale()).unwrap();
            assert_eq!(p.name(), c.name());
        }
        assert!(CovFn::parse("periodic", 1.0).is_none());
    }

    #[test]
    fn dist_euclidean() {
        assert!((dist(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(dist(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn dist32_matches_f64_image() {
        let a32 = [0.1f32, 0.7, 1.0 / 3.0];
        let b32 = [0.9f32, 0.2, 0.25];
        let a64: Vec<f64> = a32.iter().map(|&v| f64::from(v)).collect();
        let b64: Vec<f64> = b32.iter().map(|&v| f64::from(v)).collect();
        assert_eq!(dist32(&a32, &b32), dist(&a64, &b64));
    }
}
