//! Incrementally updated GP posterior over a *fixed* candidate set,
//! stored as a candidate-sharded flat-tile buffer.
//!
//! The paper's method predicts the posterior exhaustively over every
//! non-evaluated configuration at every iteration (§III-G). A naive refit
//! costs O(n²·m) per iteration (n observations, m configurations: a
//! triangular solve per candidate). Because BO only ever *appends*
//! observations, we maintain the Cholesky factor L and the solved
//! cross-covariance block V = L⁻¹·K(X, C) incrementally:
//!
//! - appending observation x adds one row to L (O(n²)) and one row to V
//!   (O(n·m)),
//! - posterior variance over all candidates is 1 − colsum(V²), maintained
//!   as a running accumulator (O(m) per append),
//! - posterior mean is Vᵀ·(L⁻¹ y_c), O(n·m) per query (y re-centering
//!   changes every iteration, so the mean is recomputed per query).
//!
//! Layout: V is partitioned over the *candidate* axis into fixed shards of
//! [`DEFAULT_SHARD_LEN`] columns. Each shard owns one contiguous tile
//! (row-major n×len f32), so both the append and the predict sweep walk
//! each tile front to back — L2-resident for the paper-scale n — and the
//! shards are embarrassingly parallel across a [`ShardPool`]. Shard
//! boundaries depend only on (m, shard_len), never on the thread count,
//! and no floating-point accumulation ever crosses a candidate column, so
//! results are **bit-identical for every shard partition and thread
//! count** (`sharding_is_bit_exact` below enforces this).
//!
//! V is f32: the sweeps are memory-bandwidth-bound over n·m elements, and
//! halving the traffic buys ~1.7× (EXPERIMENTS.md §Perf); the ~1e-7
//! relative rounding is far below the GP's own noise floor. The candidate
//! matrix is f32 too — an `Arc<[f32]>` borrowed zero-copy from the
//! search space's shard-aligned normalized tiles
//! ([`SearchSpace::norm_tiles`](crate::space::SearchSpace::norm_tiles)),
//! so constructing a GP per run is a refcount bump, not an O(m·dims)
//! re-normalization; covariances still accumulate in f64 (`dist32`).
//!
//! Same math as `Gpr`, ~n× faster per BO iteration; `Gpr` remains the
//! reference implementation and the tests cross-check the two.

use std::sync::Arc;

use crate::gp::cov::{dist32, CovFn};
use crate::util::pool::ShardPool;

/// Default candidates per shard tile. A full-budget tile (220 rows × 1024
/// columns × 4 B ≈ 0.9 MB) stays resident in a typical 1–2 MB L2 slice
/// for the whole add+predict sweep; 17956-candidate GEMM splits into 18
/// shards, a 200k-candidate space into ~196 — plenty of parallelism.
pub const DEFAULT_SHARD_LEN: usize = 1024;

/// One candidate shard: a contiguous slice of V plus its running column
/// sums of squares.
struct Shard {
    /// First (global) candidate index covered by this shard.
    start: usize,
    /// Number of candidates covered.
    len: usize,
    /// Flat tile of V restricted to this shard's candidates: row-major
    /// n×len, one row appended per observation.
    tile: Vec<f32>,
    /// Running Σᵢ V[i][j]² per local candidate j.
    sq: Vec<f64>,
}

impl Shard {
    /// Append one row of V: covariances of the new training point against
    /// this shard's candidates, forward-substituted through the shard's
    /// existing rows. Identical per-element operation order to the
    /// unsharded implementation, so the result does not depend on the
    /// partition.
    fn add_row(&mut self, cov: CovFn, point: &[f32], cand: &[f32], dims: usize, lrow: &[f64], inv_diag: f32) {
        let n = lrow.len() - 1;
        let len = self.len;
        debug_assert_eq!(self.tile.len(), n * len);
        self.tile.reserve(len);
        for j in 0..len {
            let c = &cand[(self.start + j) * dims..(self.start + j + 1) * dims];
            self.tile.push(cov.eval(dist32(point, c)) as f32);
        }
        let (prev, row) = self.tile.split_at_mut(n * len);
        for (r, lr) in lrow[..n].iter().enumerate() {
            if *lr == 0.0 {
                continue;
            }
            let lr32 = *lr as f32;
            let vr = &prev[r * len..(r + 1) * len];
            for (vj, vrj) in row.iter_mut().zip(vr) {
                *vj -= lr32 * vrj;
            }
        }
        for (vj, sqj) in row.iter_mut().zip(self.sq.iter_mut()) {
            *vj *= inv_diag;
            *sqj += f64::from(*vj) * f64::from(*vj);
        }
    }

    /// One posterior sweep over this shard: mean accumulated in f32 over
    /// the hot tile, mu/var written to the shard's chunk of the global
    /// buffers.
    fn predict_rows(&self, w: &[f64], y_mean: f64, mu: &mut [f64], var: &mut [f64]) {
        let len = self.len;
        debug_assert!(mu.len() == len && var.len() == len);
        let mut mu32 = vec![0.0f32; len];
        for (r, wr) in w.iter().enumerate() {
            if *wr == 0.0 {
                continue;
            }
            let wr32 = *wr as f32;
            let vr = &self.tile[r * len..(r + 1) * len];
            for (mj, vrj) in mu32.iter_mut().zip(vr) {
                *mj += wr32 * vrj;
            }
        }
        for (mj, m32) in mu.iter_mut().zip(&mu32) {
            *mj = y_mean + f64::from(*m32);
        }
        for (vj, sqj) in var.iter_mut().zip(&self.sq) {
            *vj = (1.0 - *sqj).max(1e-12);
        }
    }
}

pub struct IncrementalGp {
    cov: CovFn,
    noise: f64,
    dims: usize,
    /// Candidate matrix (row-major m×dims f32) — typically the search
    /// space's normalized tiles, borrowed zero-copy via
    /// [`SearchSpace::norm_tiles`](crate::space::SearchSpace::norm_tiles)
    /// (a refcount bump per run, no per-run re-normalization or copy).
    cand: Arc<[f32]>,
    m: usize,
    shard_len: usize,
    /// Training points appended so far (row-major n×dims).
    x: Vec<f32>,
    /// Rows of the lower-triangular Cholesky factor (row i has i+1 entries).
    l: Vec<Vec<f64>>,
    /// Candidate shards of V (fixed boundaries, ascending `start`).
    shards: Vec<Shard>,
}

impl IncrementalGp {
    pub fn new(cov: CovFn, noise: f64, cand: Arc<[f32]>, dims: usize) -> IncrementalGp {
        IncrementalGp::with_shard_len(cov, noise, cand, dims, DEFAULT_SHARD_LEN)
    }

    /// Explicit shard sizing — the engine passes its configured value,
    /// tests exercise degenerate partitions. Results are bit-identical for
    /// every `shard_len`; only performance changes.
    pub fn with_shard_len(cov: CovFn, noise: f64, cand: Arc<[f32]>, dims: usize, shard_len: usize) -> IncrementalGp {
        assert!(dims > 0 && cand.len() % dims == 0);
        assert!(shard_len > 0);
        let m = cand.len() / dims;
        let mut shards = Vec::with_capacity((m + shard_len - 1) / shard_len);
        let mut start = 0;
        while start < m {
            let len = shard_len.min(m - start);
            shards.push(Shard { start, len, tile: Vec::new(), sq: vec![0.0; len] });
            start += len;
        }
        IncrementalGp { cov, noise, dims, cand, m, shard_len, x: Vec::new(), l: Vec::new(), shards }
    }

    pub fn n_obs(&self) -> usize {
        self.l.len()
    }

    pub fn n_cand(&self) -> usize {
        self.m
    }

    pub fn shard_len(&self) -> usize {
        self.shard_len
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard running Σ V² chunks, in candidate order (chunk boundaries
    /// = the shard partition). Posterior variance of candidate j is
    /// `(1 − sq[j]).max(1e-12)` — available without a predict sweep, which
    /// is what lets the engine compute the exploration factor λ *before*
    /// the fused predict+score pass.
    pub fn sq_chunks(&self) -> impl Iterator<Item = &[f64]> + '_ {
        self.shards.iter().map(|s| s.sq.as_slice())
    }

    /// Append one training point (length = dims, f32 normalized
    /// coordinates — e.g. a row of the space's tiles), serially.
    pub fn add(&mut self, point: &[f32]) {
        self.add_with(point, None);
    }

    /// Append one training point, fanning the per-shard row append across
    /// the pool.
    pub fn add_par(&mut self, point: &[f32], pool: &ShardPool) {
        self.add_with(point, Some(pool));
    }

    fn add_with(&mut self, point: &[f32], pool: Option<&ShardPool>) {
        assert_eq!(point.len(), self.dims);
        let n = self.l.len();
        // New row of L: forward-substitute k(x_new, x_i) through existing rows.
        let mut lrow = Vec::with_capacity(n + 1);
        for i in 0..n {
            let k = self.cov.eval(dist32(point, &self.x[i * self.dims..(i + 1) * self.dims]));
            let s: f64 = (0..i).map(|r| lrow[r] * self.l[i][r]).sum();
            lrow.push((k - s) / self.l[i][i]);
        }
        let diag2 = (1.0 + self.noise - lrow.iter().map(|v| v * v).sum::<f64>()).max(1e-10);
        lrow.push(diag2.sqrt());
        let inv_diag = (1.0 / lrow[n]) as f32;

        let cov = self.cov;
        let dims = self.dims;
        let cand: &[f32] = &self.cand;
        let lrow_ref: &[f64] = &lrow;
        match pool {
            Some(pool) if pool.threads() > 0 && self.shards.len() > 1 => {
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
                    .shards
                    .iter_mut()
                    .map(|shard| {
                        Box::new(move || shard.add_row(cov, point, cand, dims, lrow_ref, inv_diag))
                            as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run(jobs);
            }
            _ => {
                for shard in self.shards.iter_mut() {
                    shard.add_row(cov, point, cand, dims, lrow_ref, inv_diag);
                }
            }
        }

        self.x.extend_from_slice(point);
        self.l.push(lrow);
    }

    /// w = L⁻¹ (y − ȳ).
    fn solve_w(&self, y: &[f64]) -> Vec<f64> {
        let n = self.l.len();
        assert_eq!(y.len(), n);
        let y_mean = crate::util::linalg::mean(y);
        let mut w = vec![0.0; n];
        for i in 0..n {
            let s: f64 = (0..i).map(|r| self.l[i][r] * w[r]).sum();
            w[i] = (y[i] - y_mean - s) / self.l[i][i];
        }
        w
    }

    /// The per-query solve shared by chunked prediction: mean weights
    /// `w = L⁻¹(y − ȳ)` and `ȳ`. Pair with
    /// [`predict_shard_into`](Self::predict_shard_into) to predict shard
    /// by shard — what the [`Model`](crate::surrogate::Model) adapter
    /// ([`GpModel`](crate::surrogate::GpModel)) uses to slot the GP into
    /// the engine's generic sharded sweep.
    pub fn mean_weights(&self, y: &[f64]) -> (Vec<f64>, f64) {
        (self.solve_w(y), crate::util::linalg::mean(y))
    }

    /// Predict the single shard whose first candidate is `start` (which
    /// must be a shard boundary; `mu`/`var` must be exactly the shard's
    /// length), given weights from [`mean_weights`](Self::mean_weights).
    /// Runs the same per-shard `predict_rows` as every other sweep, so
    /// the chunk is bit-identical
    /// to the matching slice of [`predict_into`](Self::predict_into).
    pub fn predict_shard_into(&self, start: usize, w: &[f64], y_mean: f64, mu: &mut [f64], var: &mut [f64]) {
        let si = start / self.shard_len;
        let shard = &self.shards[si];
        assert_eq!(shard.start, start, "start {start} is not a shard boundary");
        assert!(mu.len() == shard.len && var.len() == shard.len);
        shard.predict_rows(w, y_mean, mu, var);
    }

    /// Posterior mean and variance over all candidates given the raw
    /// observations `y` (same order as `add` calls). Observations are
    /// centered internally; outputs are in the units of `y`.
    pub fn predict_into(&self, y: &[f64], mu: &mut [f64], var: &mut [f64]) {
        assert!(mu.len() >= self.m && var.len() >= self.m);
        let w = self.solve_w(y);
        let y_mean = crate::util::linalg::mean(y);
        for shard in &self.shards {
            let (s, e) = (shard.start, shard.start + shard.len);
            shard.predict_rows(&w, y_mean, &mut mu[s..e], &mut var[s..e]);
        }
    }

    /// Fused posterior + acquisition sweep: each shard computes its
    /// (mu, var) chunk and immediately reduces it through `score` while
    /// the tile is hot, in parallel across the pool. `score` receives
    /// `(chunk start index, mu chunk, var chunk)` and must be pure —
    /// it runs concurrently. Returns the per-shard reductions in ascending
    /// shard order, so the caller's final reduction is deterministic
    /// regardless of scheduling.
    pub fn predict_scored<R, F>(
        &self,
        y: &[f64],
        pool: &ShardPool,
        mu: &mut [f64],
        var: &mut [f64],
        score: F,
    ) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &[f64], &[f64]) -> R + Sync,
    {
        assert!(mu.len() >= self.m && var.len() >= self.m);
        let w = self.solve_w(y);
        let y_mean = crate::util::linalg::mean(y);
        let mut out: Vec<Option<R>> = Vec::with_capacity(self.shards.len());
        out.resize_with(self.shards.len(), || None);
        {
            let wref: &[f64] = &w;
            let score = &score;
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
                .shards
                .iter()
                .zip(out.iter_mut())
                .zip(mu[..self.m].chunks_mut(self.shard_len).zip(var[..self.m].chunks_mut(self.shard_len)))
                .map(|((shard, slot), (mu_c, var_c))| {
                    Box::new(move || {
                        shard.predict_rows(wref, y_mean, mu_c, var_c);
                        *slot = Some(score(shard.start, &mu_c[..], &var_c[..]));
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run(jobs);
        }
        out.into_iter().map(|r| r.expect("shard job did not run")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::gpr::Gpr;
    use crate::util::rng::Rng;

    /// f64 image of an f32 point set (exact conversion) — the reference
    /// Gpr consumes the same coordinate values the tiles hold.
    fn to64(v: &[f32]) -> Vec<f64> {
        v.iter().map(|&x| f64::from(x)).collect()
    }

    #[test]
    fn matches_batch_gpr() {
        let mut rng = Rng::new(7);
        let dims = 3;
        let m = 50;
        let cand: Vec<f32> = (0..m * dims).map(|_| rng.f64() as f32).collect();
        let cov = CovFn::Matern32 { lengthscale: 1.5 };
        let noise = 1e-6;
        let mut inc = IncrementalGp::new(cov, noise, cand.clone().into(), dims);

        let n = 25;
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f64() as f32).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal() + 3.0).collect();
        for i in 0..n {
            inc.add(&x[i * dims..(i + 1) * dims]);
        }
        let mut mu_i = vec![0.0; m];
        let mut var_i = vec![0.0; m];
        inc.predict_into(&y, &mut mu_i, &mut var_i);

        let gpr = Gpr::fit(cov, noise, &to64(&x), dims, &y).unwrap();
        let (mu_b, var_b) = gpr.predict(&to64(&cand));
        for j in 0..m {
            assert!((mu_i[j] - mu_b[j]).abs() < 5e-4, "mu mismatch at {j}: {} vs {}", mu_i[j], mu_b[j]); // f32 V storage
            assert!((var_i[j] - var_b[j]).abs() < 5e-4, "var mismatch at {j}");
        }
    }

    #[test]
    fn matches_batch_after_every_append() {
        let mut rng = Rng::new(8);
        let dims = 2;
        let cand: Vec<f32> = (0..20 * dims).map(|_| rng.f64() as f32).collect();
        // Noise 1e-4 keeps K well-conditioned so the two algebraically
        // identical paths stay within float round-off of each other.
        let cov = CovFn::Matern52 { lengthscale: 0.8 };
        let mut inc = IncrementalGp::new(cov, 1e-4, cand.clone().into(), dims);
        let mut xs: Vec<f32> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for step in 0..12 {
            let p = [rng.f64() as f32, rng.f64() as f32];
            inc.add(&p);
            xs.extend_from_slice(&p);
            ys.push(rng.normal());
            let mut mu = vec![0.0; 20];
            let mut var = vec![0.0; 20];
            inc.predict_into(&ys, &mut mu, &mut var);
            let gpr = Gpr::fit(cov, 1e-4, &to64(&xs), dims, &ys).unwrap();
            let (mu_b, var_b) = gpr.predict(&to64(&cand));
            for j in 0..20 {
                assert!(
                    (mu[j] - mu_b[j]).abs() < 5e-4,
                    "step {step} mu[{j}]: {} vs {}",
                    mu[j],
                    mu_b[j]
                );
                assert!((var[j] - var_b[j]).abs() < 5e-4, "step {step} var[{j}]");
            }
        }
    }

    #[test]
    fn survives_duplicate_points() {
        let cov = CovFn::Matern32 { lengthscale: 1.0 };
        let mut inc = IncrementalGp::new(cov, 1e-8, vec![0.1f32, 0.9].into(), 1);
        inc.add(&[0.5]);
        inc.add(&[0.5]); // duplicate → clamped diagonal, no NaN
        let mut mu = vec![0.0; 2];
        let mut var = vec![0.0; 2];
        inc.predict_into(&[1.0, 1.2], &mut mu, &mut var);
        assert!(mu.iter().all(|v| v.is_finite()));
        assert!(var.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn prior_before_observations() {
        let cov = CovFn::Rbf { lengthscale: 1.0 };
        let inc = IncrementalGp::new(cov, 1e-6, vec![0.0f32, 0.5, 1.0].into(), 1);
        let mut mu = vec![9.0; 3];
        let mut var = vec![9.0; 3];
        inc.predict_into(&[], &mut mu, &mut var);
        assert_eq!(mu, vec![0.0; 3]);
        assert!(var.iter().all(|&v| (v - 1.0).abs() < 1e-12));
    }

    /// The tentpole determinism guarantee: every shard partition × thread
    /// count reproduces the single-tile serial posterior bit for bit.
    #[test]
    fn sharding_is_bit_exact() {
        let mut rng = Rng::new(21);
        let dims = 4;
        let m = 103;
        let n = 17;
        let cand: Vec<f32> = (0..m * dims).map(|_| rng.f64() as f32).collect();
        let x: Vec<f32> = (0..n * dims).map(|_| rng.f64() as f32).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let cov = CovFn::Matern32 { lengthscale: 1.2 };

        let run = |shard_len: usize, threads: usize| -> (Vec<f64>, Vec<f64>) {
            let pool = ShardPool::new(threads);
            let mut inc = IncrementalGp::with_shard_len(cov, 1e-6, cand.clone().into(), dims, shard_len);
            for i in 0..n {
                inc.add_par(&x[i * dims..(i + 1) * dims], &pool);
            }
            let mut mu = vec![0.0; m];
            let mut var = vec![0.0; m];
            inc.predict_into(&y, &mut mu, &mut var);
            (mu, var)
        };

        let (mu_ref, var_ref) = run(m, 1); // 1 shard, serial: the unsharded layout
        for &(sl, th) in &[((m + 1) / 2, 2), ((m + 7) / 8, 8), (13, 3), (1, 4)] {
            let (mu, var) = run(sl, th);
            assert_eq!(mu, mu_ref, "mu bits differ at shard_len={sl} threads={th}");
            assert_eq!(var, var_ref, "var bits differ at shard_len={sl} threads={th}");
        }
    }

    /// The fused sweep must hand the scorer exactly the chunks that
    /// `predict_into` writes, with correct global offsets.
    #[test]
    fn fused_sweep_sees_the_same_posterior() {
        let mut rng = Rng::new(33);
        let dims = 3;
        let m = 41;
        let n = 9;
        let cand: Vec<f32> = (0..m * dims).map(|_| rng.f64() as f32).collect();
        let cov = CovFn::Matern52 { lengthscale: 1.0 };
        let pool = ShardPool::new(4);
        let mut inc = IncrementalGp::with_shard_len(cov, 1e-6, cand.into(), dims, 7);
        let mut y = Vec::new();
        for _ in 0..n {
            let p: Vec<f32> = (0..dims).map(|_| rng.f64() as f32).collect();
            inc.add_par(&p, &pool);
            y.push(rng.normal());
        }

        let mut mu_a = vec![0.0; m];
        let mut var_a = vec![0.0; m];
        inc.predict_into(&y, &mut mu_a, &mut var_a);

        let mut mu_b = vec![0.0; m];
        let mut var_b = vec![0.0; m];
        let parts = inc.predict_scored(&y, &pool, &mut mu_b, &mut var_b, |start, mu_c, var_c| {
            (start, mu_c.to_vec(), var_c.to_vec())
        });
        assert_eq!(mu_a, mu_b);
        assert_eq!(var_a, var_b);
        assert_eq!(parts.len(), inc.n_shards());
        let mut covered = 0;
        for (start, mu_c, var_c) in parts {
            assert_eq!(start, covered, "shard results must arrive in candidate order");
            assert_eq!(mu_c, mu_a[start..start + mu_c.len()].to_vec());
            assert_eq!(var_c, var_a[start..start + var_c.len()].to_vec());
            covered += mu_c.len();
        }
        assert_eq!(covered, m);
    }

    /// Shard-by-shard prediction through cached mean weights must equal
    /// `predict_into` bit for bit — the contract the surrogate-subsystem
    /// GP adapter relies on.
    #[test]
    fn shard_chunked_prediction_matches_full_sweep() {
        let mut rng = Rng::new(77);
        let dims = 3;
        let m = 59;
        let cand: Vec<f32> = (0..m * dims).map(|_| rng.f64() as f32).collect();
        let mut inc =
            IncrementalGp::with_shard_len(CovFn::Matern32 { lengthscale: 1.1 }, 1e-6, cand.into(), dims, 8);
        let mut y = Vec::new();
        for _ in 0..7 {
            let p: Vec<f32> = (0..dims).map(|_| rng.f64() as f32).collect();
            inc.add(&p);
            y.push(rng.normal());
        }
        let mut mu_a = vec![0.0; m];
        let mut var_a = vec![0.0; m];
        inc.predict_into(&y, &mut mu_a, &mut var_a);

        let (w, y_mean) = inc.mean_weights(&y);
        let mut mu_b = vec![0.0; m];
        let mut var_b = vec![0.0; m];
        let mut start = 0;
        while start < m {
            let end = (start + 8).min(m);
            inc.predict_shard_into(start, &w, y_mean, &mut mu_b[start..end], &mut var_b[start..end]);
            start = end;
        }
        assert_eq!(mu_a, mu_b);
        assert_eq!(var_a, var_b);
    }

    /// sq_chunks must expose the same variances predict_into reports,
    /// chunked on the shard partition.
    #[test]
    fn sq_chunks_match_predicted_variance() {
        let mut rng = Rng::new(55);
        let dims = 2;
        let m = 23;
        let cand: Vec<f32> = (0..m * dims).map(|_| rng.f64() as f32).collect();
        let mut inc = IncrementalGp::with_shard_len(CovFn::Rbf { lengthscale: 0.7 }, 1e-6, cand.into(), dims, 6);
        for _ in 0..5 {
            let p = [rng.f64() as f32, rng.f64() as f32];
            inc.add(&p);
        }
        let y = vec![0.3, -0.1, 0.8, 0.0, 0.2];
        let mut mu = vec![0.0; m];
        let mut var = vec![0.0; m];
        inc.predict_into(&y, &mut mu, &mut var);
        let mut j = 0;
        for chunk in inc.sq_chunks() {
            for sq in chunk {
                assert_eq!(var[j], (1.0 - *sq).max(1e-12), "var/sq mismatch at {j}");
                j += 1;
            }
        }
        assert_eq!(j, m);
    }
}
