//! Incrementally updated GP posterior over a *fixed* candidate set.
//!
//! The paper's method predicts the posterior exhaustively over every
//! non-evaluated configuration at every iteration (§III-G). A naive refit
//! costs O(n²·m) per iteration (n observations, m configurations: a
//! triangular solve per candidate). Because BO only ever *appends*
//! observations, we maintain the Cholesky factor L and the solved
//! cross-covariance block V = L⁻¹·K(X, C) incrementally:
//!
//! - appending observation x adds one row to L (O(n²)) and one row to V
//!   (O(n·m)),
//! - posterior variance over all candidates is 1 − colsum(V²), maintained
//!   as a running accumulator (O(m) per append),
//! - posterior mean is Vᵀ·(L⁻¹ y_c), O(n·m) per query (y re-centering
//!   changes every iteration, so the mean is recomputed per query).
//!
//! Same math as `Gpr`, ~n× faster per BO iteration; `Gpr` remains the
//! reference implementation and the tests cross-check the two.

use crate::gp::cov::{dist, CovFn};

pub struct IncrementalGp {
    cov: CovFn,
    noise: f64,
    dims: usize,
    /// Candidate matrix (row-major m×dims) — typically the whole space.
    cand: Vec<f64>,
    m: usize,
    /// Training points appended so far (row-major n×dims).
    x: Vec<f64>,
    /// Rows of the lower-triangular Cholesky factor (row i has i+1 entries).
    l: Vec<Vec<f64>>,
    /// Rows of V = L⁻¹ K(X, C), each of length m. Stored in f32: the
    /// predict pass is memory-bandwidth-bound over n·m elements, and
    /// halving the traffic buys ~1.7× (EXPERIMENTS.md §Perf); the ~1e-7
    /// relative rounding is far below the GP's own noise floor.
    v: Vec<Vec<f32>>,
    /// Running Σᵢ V[i][j]² per candidate j.
    sq: Vec<f64>,
}

impl IncrementalGp {
    pub fn new(cov: CovFn, noise: f64, cand: Vec<f64>, dims: usize) -> IncrementalGp {
        assert!(dims > 0 && cand.len() % dims == 0);
        let m = cand.len() / dims;
        IncrementalGp { cov, noise, dims, cand, m, x: Vec::new(), l: Vec::new(), v: Vec::new(), sq: vec![0.0; m] }
    }

    pub fn n_obs(&self) -> usize {
        self.l.len()
    }

    pub fn n_cand(&self) -> usize {
        self.m
    }

    /// Append one training point (length = dims).
    pub fn add(&mut self, point: &[f64]) {
        assert_eq!(point.len(), self.dims);
        let n = self.l.len();
        // New row of L: forward-substitute k(x_new, x_i) through existing rows.
        let mut lrow = Vec::with_capacity(n + 1);
        for i in 0..n {
            let k = self.cov.eval(dist(point, &self.x[i * self.dims..(i + 1) * self.dims]));
            let s: f64 = (0..i).map(|r| lrow[r] * self.l[i][r]).sum();
            lrow.push((k - s) / self.l[i][i]);
        }
        let diag2 = (1.0 + self.noise - lrow.iter().map(|v| v * v).sum::<f64>()).max(1e-10);
        lrow.push(diag2.sqrt());

        // New row of V: (k(x_new, c_j) − Σ_r lrow[r]·V[r][j]) / diag.
        // All-f32 accumulation (see field comment): the subtraction chain
        // is ≤ n ≈ 220 terms, √n·ε₃₂ ≈ 1e-6 — below the jitter floor.
        let mut vrow = vec![0.0f32; self.m];
        for (j, vj) in vrow.iter_mut().enumerate() {
            *vj = self.cov.eval(dist(point, &self.cand[j * self.dims..(j + 1) * self.dims])) as f32;
        }
        for (r, lr) in lrow[..n].iter().enumerate() {
            if *lr == 0.0 {
                continue;
            }
            let lr32 = *lr as f32;
            let vr = &self.v[r];
            for (vj, vrj) in vrow.iter_mut().zip(vr) {
                *vj -= lr32 * vrj;
            }
        }
        let inv_diag = (1.0 / lrow[n]) as f32;
        for (vj, sqj) in vrow.iter_mut().zip(self.sq.iter_mut()) {
            *vj *= inv_diag;
            *sqj += f64::from(*vj) * f64::from(*vj);
        }

        self.x.extend_from_slice(point);
        self.l.push(lrow);
        self.v.push(vrow);
    }

    /// Posterior mean and variance over all candidates given the raw
    /// observations `y` (same order as `add` calls). Observations are
    /// centered internally; outputs are in the units of `y`.
    pub fn predict_into(&self, y: &[f64], mu: &mut [f64], var: &mut [f64]) {
        let n = self.l.len();
        assert_eq!(y.len(), n);
        assert!(mu.len() >= self.m && var.len() >= self.m);
        let y_mean = crate::util::linalg::mean(y);
        // w = L⁻¹ (y − ȳ).
        let mut w = vec![0.0; n];
        for i in 0..n {
            let s: f64 = (0..i).map(|r| self.l[i][r] * w[r]).sum();
            w[i] = (y[i] - y_mean - s) / self.l[i][i];
        }
        // Accumulate the mean in f32 (8-lane SIMD, no widening in the
        // inner loop); ~√n·ε₃₂ accumulation error ≪ GP noise floor.
        let mut mu32 = vec![0.0f32; self.m];
        for (r, wr) in w.iter().enumerate() {
            if *wr == 0.0 {
                continue;
            }
            let wr32 = *wr as f32;
            let vr = &self.v[r];
            for (mj, vrj) in mu32.iter_mut().zip(vr) {
                *mj += wr32 * vrj;
            }
        }
        for (mj, m32) in mu[..self.m].iter_mut().zip(&mu32) {
            *mj = y_mean + f64::from(*m32);
        }
        for j in 0..self.m {
            var[j] = (1.0 - self.sq[j]).max(1e-12);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::gpr::Gpr;
    use crate::util::rng::Rng;

    #[test]
    fn matches_batch_gpr() {
        let mut rng = Rng::new(7);
        let dims = 3;
        let m = 50;
        let cand: Vec<f64> = (0..m * dims).map(|_| rng.f64()).collect();
        let cov = CovFn::Matern32 { lengthscale: 1.5 };
        let noise = 1e-6;
        let mut inc = IncrementalGp::new(cov, noise, cand.clone(), dims);

        let n = 25;
        let x: Vec<f64> = (0..n * dims).map(|_| rng.f64()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal() + 3.0).collect();
        for i in 0..n {
            inc.add(&x[i * dims..(i + 1) * dims]);
        }
        let mut mu_i = vec![0.0; m];
        let mut var_i = vec![0.0; m];
        inc.predict_into(&y, &mut mu_i, &mut var_i);

        let gpr = Gpr::fit(cov, noise, &x, dims, &y).unwrap();
        let (mu_b, var_b) = gpr.predict(&cand);
        for j in 0..m {
            assert!((mu_i[j] - mu_b[j]).abs() < 5e-4, "mu mismatch at {j}: {} vs {}", mu_i[j], mu_b[j]); // f32 V storage
            assert!((var_i[j] - var_b[j]).abs() < 5e-4, "var mismatch at {j}");
        }
    }

    #[test]
    fn matches_batch_after_every_append() {
        let mut rng = Rng::new(8);
        let dims = 2;
        let cand: Vec<f64> = (0..20 * dims).map(|_| rng.f64()).collect();
        // Noise 1e-4 keeps K well-conditioned so the two algebraically
        // identical paths stay within float round-off of each other.
        let cov = CovFn::Matern52 { lengthscale: 0.8 };
        let mut inc = IncrementalGp::new(cov, 1e-4, cand.clone(), dims);
        let mut xs: Vec<f64> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for step in 0..12 {
            let p = [rng.f64(), rng.f64()];
            inc.add(&p);
            xs.extend_from_slice(&p);
            ys.push(rng.normal());
            let mut mu = vec![0.0; 20];
            let mut var = vec![0.0; 20];
            inc.predict_into(&ys, &mut mu, &mut var);
            let gpr = Gpr::fit(cov, 1e-4, &xs, dims, &ys).unwrap();
            let (mu_b, var_b) = gpr.predict(&cand);
            for j in 0..20 {
                assert!(
                    (mu[j] - mu_b[j]).abs() < 5e-4,
                    "step {step} mu[{j}]: {} vs {}",
                    mu[j],
                    mu_b[j]
                );
                assert!((var[j] - var_b[j]).abs() < 5e-4, "step {step} var[{j}]");
            }
        }
    }

    #[test]
    fn survives_duplicate_points() {
        let cov = CovFn::Matern32 { lengthscale: 1.0 };
        let mut inc = IncrementalGp::new(cov, 1e-8, vec![0.1, 0.9], 1);
        inc.add(&[0.5]);
        inc.add(&[0.5]); // duplicate → clamped diagonal, no NaN
        let mut mu = vec![0.0; 2];
        let mut var = vec![0.0; 2];
        inc.predict_into(&[1.0, 1.2], &mut mu, &mut var);
        assert!(mu.iter().all(|v| v.is_finite()));
        assert!(var.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn prior_before_observations() {
        let cov = CovFn::Rbf { lengthscale: 1.0 };
        let inc = IncrementalGp::new(cov, 1e-6, vec![0.0, 0.5, 1.0], 1);
        let mut mu = vec![9.0; 3];
        let mut var = vec![9.0; 3];
        inc.predict_into(&[], &mut mu, &mut var);
        assert_eq!(mu, vec![0.0; 3]);
        assert!(var.iter().all(|&v| (v - 1.0).abs() < 1e-12));
    }
}
