//! Gaussian-process substrate: covariance functions and regression
//! (§III-B), plus the `Surrogate` backend abstraction shared by the
//! pure-Rust implementation and the XLA-compiled artifact.

pub mod cov;
pub mod incremental;
pub mod gpr;

pub use cov::{dist, CovFn};
pub use incremental::{IncrementalGp, DEFAULT_SHARD_LEN};
pub use gpr::{Gpr, NativeSurrogate, Surrogate};
