//! Gaussian-process regression (the surrogate model, §III-B).
//!
//! Equivalent math to scikit-learn's `GaussianProcessRegressor` as the
//! paper uses it: zero-mean prior after centering the observations,
//! jittered Cholesky factorization of K + σ²I, posterior mean via α =
//! K⁻¹y, posterior variance via triangular solves. Lengthscales are fixed
//! (never optimized) per the paper's design.

use crate::gp::cov::{dist, CovFn};
use crate::util::linalg::{cho_solve, cholesky, mean, solve_lower, Mat};

/// Fitted GP model over row-major points (`n × dims`).
pub struct Gpr {
    pub cov: CovFn,
    pub noise: f64,
    dims: usize,
    x: Vec<f64>,
    n: usize,
    y_mean: f64,
    l: Mat,
    alpha: Vec<f64>,
}

impl Gpr {
    /// Fit on `n` training points `x` (row-major, `n*dims` long) with
    /// observations `y`.
    pub fn fit(cov: CovFn, noise: f64, x: &[f64], dims: usize, y: &[f64]) -> Result<Gpr, String> {
        let n = y.len();
        assert_eq!(x.len(), n * dims, "x shape mismatch");
        assert!(n > 0, "cannot fit GP on zero observations");
        let y_mean = mean(y);
        let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();

        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            k[(i, i)] = 1.0 + noise;
            for j in 0..i {
                let v = cov.eval(dist(&x[i * dims..(i + 1) * dims], &x[j * dims..(j + 1) * dims]));
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
        }
        let l = cholesky(&k, 1e-10)?;
        let alpha = cho_solve(&l, &yc);
        Ok(Gpr { cov, noise, dims, x: x.to_vec(), n, y_mean, l, alpha })
    }

    pub fn n_train(&self) -> usize {
        self.n
    }

    /// Posterior mean and variance at one point.
    pub fn predict_one(&self, p: &[f64]) -> (f64, f64) {
        let mut mu = [0.0];
        let mut var = [0.0];
        self.predict_into(p, &mut mu, &mut var);
        (mu[0], var[0])
    }

    /// Posterior mean and variance at `points` (row-major `m × dims`),
    /// written into the provided buffers. This is the optimizer's hot
    /// path: exhaustive prediction over every non-evaluated configuration
    /// (§III-G — "we exhaustively predict every discrete point in the
    /// model").
    pub fn predict_into(&self, points: &[f64], mu: &mut [f64], var: &mut [f64]) {
        let d = self.dims;
        let m = points.len() / d;
        assert_eq!(points.len(), m * d);
        assert!(mu.len() >= m && var.len() >= m);
        let mut ks = vec![0.0; self.n];
        for (pi, p) in points.chunks_exact(d).enumerate() {
            for (j, xj) in self.x.chunks_exact(d).enumerate() {
                ks[j] = self.cov.eval(dist(p, xj));
            }
            // mean = k*ᵀ α  (+ y mean added back)
            let m_c: f64 = ks.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
            // var = k(0) − ‖L⁻¹ k*‖²
            let v = solve_lower(&self.l, &ks);
            let reduction: f64 = v.iter().map(|x| x * x).sum();
            mu[pi] = m_c + self.y_mean;
            var[pi] = (1.0 - reduction).max(1e-12);
        }
    }

    /// Convenience allocation wrapper.
    pub fn predict(&self, points: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let m = points.len() / self.dims;
        let mut mu = vec![0.0; m];
        let mut var = vec![0.0; m];
        self.predict_into(points, &mut mu, &mut var);
        (mu, var)
    }
}

/// One-shot fit+predict interface shared by the native GP and the
/// XLA-compiled GP artifact (`runtime::XlaSurrogate`). One call per BO
/// iteration: fit on all observations, predict over all candidates.
pub trait Surrogate: Send {
    /// Fit on `(x, y)` (row-major `n×dims`) and predict into `mu`/`var`
    /// over `cand` (row-major `m×dims`).
    #[allow(clippy::too_many_arguments)]
    fn fit_predict(
        &mut self,
        x: &[f64],
        y: &[f64],
        dims: usize,
        cand: &[f64],
        mu: &mut [f64],
        var: &mut [f64],
    ) -> Result<(), String>;

    /// Human-readable backend name (for the perf benches).
    fn backend(&self) -> &'static str;
}

/// Pure-Rust surrogate backend.
pub struct NativeSurrogate {
    pub cov: CovFn,
    pub noise: f64,
}

impl NativeSurrogate {
    pub fn new(cov: CovFn, noise: f64) -> Self {
        NativeSurrogate { cov, noise }
    }
}

impl Surrogate for NativeSurrogate {
    fn fit_predict(
        &mut self,
        x: &[f64],
        y: &[f64],
        dims: usize,
        cand: &[f64],
        mu: &mut [f64],
        var: &mut [f64],
    ) -> Result<(), String> {
        let gpr = Gpr::fit(self.cov, self.noise, x, dims, y)?;
        gpr.predict_into(cand, mu, var);
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cov() -> CovFn {
        CovFn::Matern32 { lengthscale: 1.0 }
    }

    #[test]
    fn interpolates_training_points_with_small_noise() {
        let x = vec![0.0, 0.25, 0.5, 0.75, 1.0];
        let y: Vec<f64> = x.iter().map(|v: &f64| (v * 6.0).sin()).collect();
        let gp = Gpr::fit(cov(), 1e-8, &x, 1, &y).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            let (m, v) = gp.predict_one(&[*xi]);
            assert!((m - yi).abs() < 1e-4, "mean at train point: {m} vs {yi}");
            assert!(v < 1e-4, "variance at train point: {v}");
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let x = vec![0.4, 0.5, 0.6];
        let y = vec![1.0, 0.5, 1.0];
        let gp = Gpr::fit(cov(), 1e-6, &x, 1, &y).unwrap();
        let (_, v_near) = gp.predict_one(&[0.5]);
        let (_, v_far) = gp.predict_one(&[3.0]);
        assert!(v_far > v_near * 10.0);
        // Far from data, the prediction reverts to the observation mean.
        let (m_far, _) = gp.predict_one(&[50.0]);
        assert!((m_far - (1.0 + 0.5 + 1.0) / 3.0).abs() < 1e-6);
    }

    #[test]
    fn multidim_fit_predict() {
        let mut rng = Rng::new(5);
        let dims = 4;
        let n = 30;
        let x: Vec<f64> = (0..n * dims).map(|_| rng.f64()).collect();
        let y: Vec<f64> = x.chunks(dims).map(|p| p.iter().sum::<f64>()).collect();
        let gp = Gpr::fit(cov(), 1e-6, &x, dims, &y).unwrap();
        // Predict at a held-out point near training data: error bounded.
        let p = [0.5, 0.5, 0.5, 0.5];
        let (m, _) = gp.predict_one(&p);
        assert!((m - 2.0).abs() < 0.5, "mean {m}");
    }

    #[test]
    fn predict_into_matches_predict() {
        let x = vec![0.0, 0.5, 1.0];
        let y = vec![0.0, 1.0, 0.0];
        let gp = Gpr::fit(cov(), 1e-6, &x, 1, &y).unwrap();
        let pts: Vec<f64> = (0..11).map(|i| i as f64 / 10.0).collect();
        let (mu, var) = gp.predict(&pts);
        let mut mu2 = vec![0.0; 11];
        let mut var2 = vec![0.0; 11];
        gp.predict_into(&pts, &mut mu2, &mut var2);
        assert_eq!(mu, mu2);
        assert_eq!(var, var2);
    }

    #[test]
    fn native_surrogate_trait_roundtrip() {
        let mut s = NativeSurrogate::new(cov(), 1e-6);
        let x = vec![0.0, 1.0];
        let y = vec![2.0, 4.0];
        let cand = vec![0.5];
        let mut mu = vec![0.0];
        let mut var = vec![0.0];
        s.fit_predict(&x, &y, 1, &cand, &mut mu, &mut var).unwrap();
        assert!(mu[0] > 2.0 && mu[0] < 4.0);
        assert!(var[0] > 0.0);
        assert_eq!(s.backend(), "native");
    }

    #[test]
    fn duplicate_points_need_jitter_and_survive() {
        // Two identical training points make K singular without jitter.
        let x = vec![0.5, 0.5];
        let y = vec![1.0, 1.2];
        let gp = Gpr::fit(cov(), 1e-10, &x, 1, &y).unwrap();
        let (m, _) = gp.predict_one(&[0.5]);
        assert!((m - 1.1).abs() < 0.2);
    }

    #[test]
    fn variance_never_negative() {
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..40).map(|_| rng.f64()).collect();
        let y: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let gp = Gpr::fit(cov(), 1e-6, &x, 1, &y).unwrap();
        let pts: Vec<f64> = (0..1000).map(|i| i as f64 / 999.0).collect();
        let (_, var) = gp.predict(&pts);
        assert!(var.iter().all(|&v| v > 0.0));
    }
}
