//! PJRT runtime — loads the AOT-compiled GP fit+predict graph (authored in
//! JAX + Pallas, lowered to HLO text by `python/compile/aot.py`) and
//! serves it as a `Surrogate` backend for the BO engine. Python never runs
//! here: artifacts are compiled once at build time (`make artifacts`); the
//! Rust binary is self-contained afterwards.

pub mod artifacts;
pub mod surrogate;

pub use artifacts::{ArtifactSet, GpExecutable};
pub use surrogate::{xla_backend, XlaContext, XlaSurrogate};
