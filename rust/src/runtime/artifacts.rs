//! Artifact registry: discovers and compiles the AOT-lowered HLO graphs.
//!
//! Artifact naming contract with `python/compile/aot.py`:
//!   `gp_fitpredict_n{N}_c{C}.hlo.txt` — GP fit+predict for up to N
//!     (padded) observations and C (padded) candidates, D padded to 16.
//!   Inputs  (f32): x[N,16], yc[N] (centered, 0 on padding), mask[N],
//!                  cand[C,16]
//!   Outputs (f32 tuple): mu[C] (centered units), var[C]
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits serialized protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Dimension padding shared with the Python side.
pub const D_PAD: usize = 16;

/// One compiled GP executable for a given (N, C) padding bucket.
pub struct GpExecutable {
    pub n_obs: usize,
    pub n_cand: usize,
    pub exe: xla::PjRtLoadedExecutable,
}

/// All compiled buckets, plus the PJRT client that owns them.
pub struct ArtifactSet {
    pub client: xla::PjRtClient,
    /// Keyed by observation bucket N → executable (one C per N in v1).
    pub buckets: BTreeMap<usize, GpExecutable>,
}

/// Parse `gp_fitpredict_n{N}_c{C}.hlo.txt` → (N, C).
pub fn parse_artifact_name(name: &str) -> Option<(usize, usize)> {
    let stem = name.strip_suffix(".hlo.txt")?;
    let rest = stem.strip_prefix("gp_fitpredict_n")?;
    let (n_str, c_str) = rest.split_once("_c")?;
    Some((n_str.parse().ok()?, c_str.parse().ok()?))
}

impl ArtifactSet {
    /// Load and compile every GP artifact in `dir`.
    pub fn load(dir: &Path) -> Result<ArtifactSet, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT client: {e}"))?;
        let mut buckets = BTreeMap::new();
        let entries = std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some((n, c)) = parse_artifact_name(&name.to_string_lossy()) else { continue };
            let path: PathBuf = entry.path();
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| format!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| format!("compile {}: {e}", path.display()))?;
            buckets.insert(n, GpExecutable { n_obs: n, n_cand: c, exe });
        }
        if buckets.is_empty() {
            return Err(format!(
                "no gp_fitpredict_n*_c*.hlo.txt artifacts in {} — run `make artifacts`",
                dir.display()
            ));
        }
        Ok(ArtifactSet { client, buckets })
    }

    /// Smallest bucket that fits `n` observations.
    pub fn bucket_for(&self, n: usize) -> Option<&GpExecutable> {
        self.buckets.range(n..).next().map(|(_, e)| e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_artifact_names() {
        assert_eq!(parse_artifact_name("gp_fitpredict_n64_c4096.hlo.txt"), Some((64, 4096)));
        assert_eq!(parse_artifact_name("gp_fitpredict_n256_c4096.hlo.txt"), Some((256, 4096)));
        assert_eq!(parse_artifact_name("model.hlo.txt"), None);
        assert_eq!(parse_artifact_name("gp_fitpredict_nX_c1.hlo.txt"), None);
    }

    #[test]
    fn missing_dir_is_informative_error() {
        let err = match ArtifactSet::load(Path::new("/nonexistent-ktbo")) {
            Err(e) => e,
            Ok(_) => panic!("load must fail"),
        };
        assert!(err.contains("/nonexistent-ktbo"));
    }
}
