//! `XlaSurrogate`: the GP fit+predict hot path served by the AOT-compiled
//! XLA executable (Layers 1+2) through PJRT, behind the same `Surrogate`
//! interface as the pure-Rust backend. Inputs are padded to the artifact's
//! (N, C) bucket; candidates are processed in C-sized chunks.

use std::sync::{Arc, Mutex};

use crate::bo::{Backend, BoConfig};
use crate::gp::Surrogate;
use crate::runtime::artifacts::{ArtifactSet, D_PAD};
use crate::util::linalg::mean;

/// Shared, thread-safe artifact context (compilation happens once).
pub struct XlaContext {
    artifacts: Mutex<ArtifactSet>,
}

// SAFETY: the `xla` crate wraps PJRT handles in `Rc` + raw pointers and is
// therefore not auto-Send/Sync, but the underlying PJRT CPU client and
// loaded executables are thread-safe C++ objects, the `Rc`s never leave
// this module, and every access goes through the `Mutex` above —
// serializing all use of the handles.
unsafe impl Send for XlaContext {}
unsafe impl Sync for XlaContext {}

impl XlaContext {
    pub fn load(dir: &str) -> Result<Arc<XlaContext>, String> {
        let artifacts = ArtifactSet::load(std::path::Path::new(dir))?;
        Ok(Arc::new(XlaContext { artifacts: Mutex::new(artifacts) }))
    }

    pub fn bucket_sizes(&self) -> Vec<usize> {
        self.artifacts.lock().unwrap().buckets.keys().copied().collect()
    }
}

/// Per-run surrogate handle.
pub struct XlaSurrogate {
    ctx: Arc<XlaContext>,
}

impl XlaSurrogate {
    pub fn new(ctx: Arc<XlaContext>) -> XlaSurrogate {
        XlaSurrogate { ctx }
    }
}

impl Surrogate for XlaSurrogate {
    fn fit_predict(
        &mut self,
        x: &[f64],
        y: &[f64],
        dims: usize,
        cand: &[f64],
        mu: &mut [f64],
        var: &mut [f64],
    ) -> Result<(), String> {
        let n = y.len();
        assert_eq!(x.len(), n * dims);
        assert!(dims <= D_PAD, "dims {dims} exceeds artifact padding {D_PAD}");
        let m = cand.len() / dims;
        assert!(mu.len() >= m && var.len() >= m);

        let artifacts = self.ctx.artifacts.lock().unwrap();
        let exe = artifacts
            .bucket_for(n)
            .ok_or_else(|| format!("no artifact bucket for {n} observations"))?;
        let (n_pad, c_pad) = (exe.n_obs, exe.n_cand);

        // Pad observations. The graph expects centered y (zero-mean), zero
        // on padded rows, and a 1/0 mask.
        let y_mean = mean(y);
        let mut xf = vec![0.0f32; n_pad * D_PAD];
        for i in 0..n {
            for d in 0..dims {
                xf[i * D_PAD + d] = x[i * dims + d] as f32;
            }
        }
        let mut ycf = vec![0.0f32; n_pad];
        let mut maskf = vec![0.0f32; n_pad];
        for i in 0..n {
            ycf[i] = (y[i] - y_mean) as f32;
            maskf[i] = 1.0;
        }
        let x_lit = xla::Literal::vec1(&xf).reshape(&[n_pad as i64, D_PAD as i64]).map_err(es)?;
        let yc_lit = xla::Literal::vec1(&ycf);
        let mask_lit = xla::Literal::vec1(&maskf);

        // Candidate chunks: pad the tail chunk with copies of row 0 (valid
        // math, results discarded).
        let mut done = 0usize;
        while done < m {
            let take = (m - done).min(c_pad);
            let mut cf = vec![0.0f32; c_pad * D_PAD];
            for i in 0..take {
                for d in 0..dims {
                    cf[i * D_PAD + d] = cand[(done + i) * dims + d] as f32;
                }
            }
            let c_lit = xla::Literal::vec1(&cf).reshape(&[c_pad as i64, D_PAD as i64]).map_err(es)?;
            let result = exe
                .exe
                .execute::<xla::Literal>(&[x_lit.clone(), yc_lit.clone(), mask_lit.clone(), c_lit])
                .map_err(es)?[0][0]
                .to_literal_sync()
                .map_err(es)?;
            let (mu_l, var_l) = result.to_tuple2().map_err(es)?;
            let mu_v: Vec<f32> = mu_l.to_vec().map_err(es)?;
            let var_v: Vec<f32> = var_l.to_vec().map_err(es)?;
            for i in 0..take {
                mu[done + i] = mu_v[i] as f64 + y_mean;
                var[done + i] = (var_v[i] as f64).max(1e-12);
            }
            done += take;
        }
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "xla"
    }
}

fn es<E: std::fmt::Display>(e: E) -> String {
    e.to_string()
}

/// Build a BO `Backend` that serves the GP through the XLA artifacts.
pub fn xla_backend(artifact_dir: &str) -> Result<Backend, String> {
    let ctx = XlaContext::load(artifact_dir)?;
    Ok(Backend::OneShot(Arc::new(move |_cfg: &BoConfig| {
        Box::new(XlaSurrogate::new(Arc::clone(&ctx))) as Box<dyn Surrogate>
    })))
}
