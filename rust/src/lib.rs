//! # ktbo — Bayesian Optimization for auto-tuning GPU kernels
//!
//! Production-grade reproduction of Willemsen, van Nieuwpoort & van
//! Werkhoven, *"Bayesian Optimization for auto-tuning GPU kernels"* (2021)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the auto-tuning coordinator: search-space
//!   engine, GPU performance-model simulator, Gaussian-process surrogate,
//!   the paper's BO strategies (contextual variance, `multi`,
//!   `advanced multi`), the baseline strategy zoo, and the experiment
//!   harness that regenerates every table and figure.
//! - **Layer 2** — a JAX-authored GP fit+predict graph, AOT-lowered to HLO
//!   text at build time (`python/compile/model.py`).
//! - **Layer 1** — a Pallas kernel for the exhaustive GP posterior
//!   prediction hot spot (`python/compile/kernels/gp_predict.py`),
//!   executed from Rust through PJRT (`runtime`).
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod bo;
pub mod gp;
pub mod gpusim;
pub mod harness;
pub mod objective;
/// PJRT/XLA artifact backend — needs the vendored `xla` crate, so the
/// default build ships without it (see Cargo.toml `xla-runtime`).
#[cfg(feature = "xla-runtime")]
pub mod runtime;
pub mod serve;
pub mod space;
pub mod strategies;
/// Pluggable surrogate-model subsystem: the batch `Model` trait with GP,
/// tree-ensemble (random forest / extra trees), and TPE implementations.
pub mod surrogate;
/// Determinism-safe instrumentation: the injectable `Clock`, per-session
/// span tracing, the metrics registry, and `ktbo report` rendering.
pub mod telemetry;
pub mod util;
