//! Evaluation metrics from §IV-A: MAE against the global minimum over the
//! function-evaluation checkpoints 40, 60, …, 220, and the Mean Deviation
//! Factor (MDF) for cross-kernel comparison.

use crate::util::linalg::{mean, std_dev};

/// Checkpoints used by the paper: 20·i for i = 2..=11.
pub fn checkpoints() -> Vec<usize> {
    (2..=11).map(|i| 20 * i).collect()
}

/// Mean absolute error of a single run's best-found curve against the
/// global minimum: (1/10)·Σ_{i=2..11} |f(x⁺ at 20i) − f(x′)|.
///
/// Curves shorter than a checkpoint (space exhausted) contribute their
/// final value; checkpoints before the first valid observation contribute
/// `fallback` (mean valid value of the space — an uninformative prior).
pub fn run_mae(best_curve: &[f64], global_min: f64, fallback: f64) -> f64 {
    let cps = checkpoints();
    let mut total = 0.0;
    for cp in &cps {
        let v = if best_curve.is_empty() {
            fallback
        } else {
            let idx = (*cp - 1).min(best_curve.len() - 1);
            let b = best_curve[idx];
            if b.is_finite() {
                b
            } else {
                fallback
            }
        };
        total += (v - global_min).abs();
    }
    total / cps.len() as f64
}

/// Per-strategy aggregate over repeats.
#[derive(Clone, Debug)]
pub struct MaeStats {
    pub mean: f64,
    pub std: f64,
}

pub fn mae_stats(maes: &[f64]) -> MaeStats {
    MaeStats { mean: mean(maes), std: std_dev(maes) }
}

/// Mean Deviation Factor across kernels: for each kernel, each strategy's
/// mean MAE is divided by the mean (over strategies) of the kernel's mean
/// MAEs — removing the kernel's performance scale; the MDF is the mean of
/// these factors over kernels, with the std of the factors as the error
/// bar.
///
/// `mae[kernel][strategy]` must be rectangular. Returns `(mdf, std)` per
/// strategy.
pub fn mean_deviation_factor(mae: &[Vec<f64>]) -> Vec<(f64, f64)> {
    assert!(!mae.is_empty());
    let n_strat = mae[0].len();
    assert!(mae.iter().all(|row| row.len() == n_strat), "ragged MAE matrix");
    let mut factors: Vec<Vec<f64>> = vec![Vec::with_capacity(mae.len()); n_strat];
    for row in mae {
        let kernel_mean = mean(row);
        assert!(kernel_mean > 0.0, "degenerate kernel MAE row");
        for (s, &v) in row.iter().enumerate() {
            factors[s].push(v / kernel_mean);
        }
    }
    factors.iter().map(|f| (mean(f), std_dev(f))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoints_match_paper() {
        assert_eq!(checkpoints(), vec![40, 60, 80, 100, 120, 140, 160, 180, 200, 220]);
    }

    #[test]
    fn mae_of_perfect_run_is_zero() {
        let curve = vec![5.0; 220];
        assert_eq!(run_mae(&curve, 5.0, 100.0), 0.0);
    }

    #[test]
    fn mae_averages_checkpoints() {
        // Curve at 6.0 until eval 100, then 5.0: checkpoints 40..100 (the
        // checkpoint index is eval−1) give 1.0; later ones 0.0.
        let mut curve = vec![6.0; 220];
        for v in curve.iter_mut().skip(100) {
            *v = 5.0;
        }
        let mae = run_mae(&curve, 5.0, 100.0);
        // Checkpoints ≤ 100: 40, 60, 80, 100 → curve[idx≤99] = 6.0 → 4 of 10.
        assert!((mae - 0.4).abs() < 1e-12, "mae {mae}");
    }

    #[test]
    fn short_curves_extend_with_final_value() {
        let curve = vec![7.0; 50]; // space exhausted at 50 evals
        assert_eq!(run_mae(&curve, 5.0, 100.0), 2.0);
    }

    #[test]
    fn infinite_prefix_uses_fallback() {
        let mut curve = vec![f64::INFINITY; 220];
        for v in curve.iter_mut().skip(59) {
            *v = 5.0;
        }
        let mae = run_mae(&curve, 5.0, 15.0);
        // Checkpoint 40 hits the fallback (10.0 error); all others 0.
        assert!((mae - 1.0).abs() < 1e-12, "mae {mae}");
    }

    #[test]
    fn mdf_normalizes_scale() {
        // Two kernels with wildly different scales, same relative ranking:
        // strategy A twice as good as B on both → identical factors.
        let mae = vec![vec![1.0, 2.0], vec![100.0, 200.0]];
        let mdf = mean_deviation_factor(&mae);
        assert!((mdf[0].0 - 2.0 / 3.0).abs() < 1e-12);
        assert!((mdf[1].0 - 4.0 / 3.0).abs() < 1e-12);
        assert!(mdf[0].1 < 1e-12 && mdf[1].1 < 1e-12, "identical factors → zero std");
    }

    #[test]
    fn mdf_lower_is_better_ordering_preserved() {
        let mae = vec![vec![1.0, 5.0, 3.0], vec![2.0, 9.0, 4.0]];
        let mdf = mean_deviation_factor(&mae);
        assert!(mdf[0].0 < mdf[2].0 && mdf[2].0 < mdf[1].0);
    }
}
