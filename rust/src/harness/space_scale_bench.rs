//! Reusable core of the `space_scale` bench: per-suggestion work of the
//! lazy (implicit-space) tuning path as the Cartesian size grows across
//! ~5+ orders of magnitude, with machine-readable output
//! (`BENCH_space_scale.json` at the repo root).
//!
//! The claim under test is ROADMAP item 1's acceptance: on a
//! [`LazyView`], per-suggestion cost is bounded by the candidate-pool
//! knob (plus an O(dims²) neighborhood term), **never** by the Cartesian
//! size — no enumeration, no whole-space tiles. The bench measures
//! constraint probes per suggestion (deterministic) and wall time per
//! suggestion (informational) over a family of spaces that differ only
//! in unconstrained filler dimensions, then checks every record against
//! [`probe_cap`], a function of pool size and dimension count alone.
//!
//! The bench binary (`benches/space_scale.rs`) is a thin CLI over these
//! functions, and the test suite runs a tiny smoke grid through the same
//! code (`space_scale_bench_smoke` in `tests/integration.rs`) — so the
//! bench logic compiles and runs on every `cargo test` and cannot
//! silently rot.

use std::sync::Arc;
// ktbo-lint: allow-file(no-untracked-clock): standalone bench harness — wall
// time is informational output here, never on the trace path.
use std::time::Instant;

use crate::objective::synthetic::SyntheticObjective;
use crate::objective::Objective;
use crate::space::view::{LazyView, SpaceView};
use crate::space::{Expr, SpaceSpec};
use crate::strategies::registry::by_name;
use crate::strategies::{FevalBudget, Session};
use crate::util::json::Json;
use crate::util::rng::{fnv1a, Rng};

/// One scale scenario: `strategy` driven lazily over the scaled space
/// with `filler_dims` unconstrained 10-value dimensions appended.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub strategy: &'static str,
    pub filler_dims: usize,
    pub budget: usize,
    pub pool: usize,
}

/// Outcome of one scenario.
#[derive(Clone, Debug)]
pub struct Record {
    pub scenario: Scenario,
    pub cartesian: u64,
    pub dims: usize,
    pub evaluations: usize,
    /// Constraint probes per suggestion — the deterministic work metric
    /// the flatness assertion runs on.
    pub probes_per_suggestion: f64,
    /// Wall time per suggestion (informational; not asserted).
    pub us_per_suggestion: f64,
}

/// The scaled space family: a constrained 3-dim core (512-config
/// Cartesian, bx·by ≤ 256 pruning) plus `filler_dims` unconstrained
/// 10-value dimensions. Restriction survival is identical at every
/// scale, so any growth in per-suggestion work is attributable to size,
/// not to a harder constraint set.
pub fn scaled_spec(filler_dims: usize) -> SpaceSpec {
    let mut spec = SpaceSpec::new(&format!("scale-f{filler_dims}"))
        .ints("bx", &[1, 2, 4, 8, 16, 32, 64, 128])
        .ints("by", &[1, 2, 4, 8, 16, 32, 64, 128])
        .ints("tile", &[1, 2, 3, 4, 5, 6, 7, 8])
        .restrict(Expr::var("bx").mul(Expr::var("by")).le(Expr::lit(256)));
    let vals: Vec<i64> = (0..10).collect();
    for d in 0..filler_dims {
        spec = spec.ints(&format!("f{d}"), &vals);
    }
    spec
}

/// The per-suggestion probe ceiling: pool draws (≤ pool candidates ×
/// the bounded per-draw rejection budget) plus the incumbent
/// neighborhoods (≤ 3 incumbents × a full Adjacent scan, 2·dims one-dim
/// moves + 4·dims² two-dim pairs) plus slack for the initial batch. A
/// function of the pool knob and the dimension count ONLY — if probe
/// work ever scales with Cartesian size, this cap breaks loudly.
pub fn probe_cap(pool: usize, dims: usize) -> f64 {
    let d = dims as f64;
    (pool * 512) as f64 + 3.0 * (2.0 * d + 4.0 * d * d) + 512.0
}

/// Run one scenario: lazy view, pool driver, synthetic objective, full
/// session loop under a feval budget.
pub fn run_scenario(sc: &Scenario) -> Record {
    let spec = scaled_spec(sc.filler_dims);
    let view = Arc::new(LazyView::from_spec(&spec).expect("scaled spec builds"));
    let cartesian = view.cartesian_size();
    let dims = view.dims();
    let strategy = by_name(sc.strategy).expect("bench strategy registered");
    let driver =
        strategy.lazy_driver(view.as_ref(), sc.pool).expect("bench strategy is lazy-capable");
    let obj: Arc<dyn Objective> =
        Arc::new(SyntheticObjective::new(Arc::clone(&view), fnv1a(&spec.name)));
    let t0 = Instant::now();
    let mut session =
        Session::new(driver, obj, Box::new(FevalBudget::new(sc.budget)), Rng::new(0x5CA1E));
    while session.step() {}
    let total_s = t0.elapsed().as_secs_f64();
    let evaluations = session.into_trace().len();
    let n = evaluations.max(1) as f64;
    Record {
        scenario: sc.clone(),
        cartesian,
        dims,
        evaluations,
        probes_per_suggestion: view.probe_count() as f64 / n,
        us_per_suggestion: total_s * 1e6 / n,
    }
}

/// The bench grid. Full: TPE (the first-wired lazy strategy) across
/// filler depths 0..=6 — Cartesian 512 up to 5.12·10⁸, a 10⁶× spread —
/// plus the GP pool path at the extremes. Smoke: TPE at the two ends of
/// a 10⁴× spread, seconds-scale.
pub fn scenario_grid(smoke: bool) -> Vec<Scenario> {
    if smoke {
        return vec![
            Scenario { strategy: "tpe", filler_dims: 0, budget: 15, pool: 32 },
            Scenario { strategy: "tpe", filler_dims: 4, budget: 15, pool: 32 },
        ];
    }
    let mut grid = Vec::new();
    for filler_dims in 0..=6 {
        grid.push(Scenario { strategy: "tpe", filler_dims, budget: 40, pool: 64 });
    }
    for filler_dims in [0, 6] {
        grid.push(Scenario { strategy: "ei", filler_dims, budget: 25, pool: 64 });
    }
    grid
}

/// The bench's acceptance check. `None` means every record's probe work
/// sits under its pool/dims cap and the grid actually spans the claimed
/// size range; `Some(reason)` is a failure to surface. Kept here (not in
/// the binary) so the test-suite smoke asserts the exact same predicate.
pub fn flatness_violation(records: &[Record]) -> Option<String> {
    let min = records.iter().map(|r| r.cartesian).min()?;
    let max = records.iter().map(|r| r.cartesian).max()?;
    if (max / min.max(1)) < 10_000 {
        return Some(format!(
            "grid spans only {min}..{max} Cartesian — too narrow to claim flatness"
        ));
    }
    for r in records {
        let cap = probe_cap(r.scenario.pool, r.dims);
        if r.probes_per_suggestion > cap {
            return Some(format!(
                "{} at Cartesian {}: {:.0} probes/suggestion exceeds the pool/dims cap {:.0} — \
                 per-suggestion work is scaling with space size",
                r.scenario.strategy, r.cartesian, r.probes_per_suggestion, cap
            ));
        }
    }
    None
}

/// Render records as the `BENCH_space_scale.json` document.
pub fn to_json(records: &[Record]) -> Json {
    let rows: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj()
                .set("strategy", r.scenario.strategy)
                .set("filler_dims", r.scenario.filler_dims)
                .set("cartesian", format!("{}", r.cartesian))
                .set("dims", r.dims)
                .set("budget", r.scenario.budget)
                .set("pool", r.scenario.pool)
                .set("evaluations", r.evaluations)
                .set("probes_per_suggestion", r.probes_per_suggestion)
                .set("us_per_suggestion", r.us_per_suggestion)
        })
        .collect();
    Json::obj()
        .set("bench", "space_scale")
        .set("unit", "probes_per_suggestion")
        .set(
            "description",
            "lazy-view per-suggestion constraint work vs Cartesian size: bounded by the candidate pool, flat across orders of magnitude",
        )
        .set("records", Json::Arr(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The end-to-end smoke of the grid + flatness predicate + JSON
    // serialization lives in tests/integration.rs
    // (space_scale_bench_smoke) — one copy only.

    #[test]
    fn scaled_spec_sizes_grow_by_tens() {
        assert_eq!(scaled_spec(0).cartesian_size(), 512);
        assert_eq!(scaled_spec(3).cartesian_size(), 512_000);
        let v = LazyView::from_spec(&scaled_spec(2)).unwrap();
        assert_eq!(v.cartesian_size(), 51_200);
        assert_eq!(v.dims(), 5);
    }

    #[test]
    fn flatness_predicate_rejects_sweeps_and_narrow_grids() {
        let rec = |cartesian: u64, probes: f64| Record {
            scenario: Scenario { strategy: "tpe", filler_dims: 0, budget: 10, pool: 32 },
            cartesian,
            dims: 3,
            evaluations: 10,
            probes_per_suggestion: probes,
            us_per_suggestion: 1.0,
        };
        // A record whose probe work looks like an enumeration must fail.
        let bad = vec![rec(512, 100.0), rec(51_200_000, 5_000_000.0)];
        assert!(flatness_violation(&bad).unwrap().contains("exceeds"));
        // A single-size grid can't claim flatness.
        let narrow = vec![rec(512, 100.0)];
        assert!(flatness_violation(&narrow).unwrap().contains("narrow"));
        // Pool-bounded work across a wide spread passes.
        let good = vec![rec(512, 100.0), rec(51_200_000, 300.0)];
        assert_eq!(flatness_violation(&good), None);
    }
}
