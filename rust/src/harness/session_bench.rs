//! Reusable core of the `session_step` bench: per-step latency of the
//! owned [`Session`] engine, in-process vs through the serve daemon's
//! request path, with machine-readable output
//! (`BENCH_session_step.json` at the repo root).
//!
//! The bench binary (`benches/session_step.rs`) is a thin CLI over these
//! functions, and the test suite runs a tiny smoke grid through the same
//! code (`session_step_bench_smoke` in `tests/integration.rs`) — so the
//! bench logic compiles and runs on every `cargo test` and can never
//! silently rot. Two modes per strategy:
//!
//! - **inprocess** — `Session::step` loops over a table objective: the
//!   pure engine cost (driver ask/tell, memo, budget, trace);
//! - **served** — the same run driven through
//!   [`TuningServer::handle_line`] as `ask`/`tell` JSON lines, measuring
//!   the daemon's full per-suggestion overhead (parse, session lookup,
//!   response render) without socket noise.

use std::sync::Arc;
// ktbo-lint: allow-file(no-untracked-clock): standalone bench harness — wall
// time is informational output here, never on the trace path.
use std::time::Instant;

use crate::gpusim::device::Device;
use crate::harness::figures::objective_for;
use crate::objective::Objective;
use crate::serve::{ServeOpts, TuningServer};
use crate::strategies::registry::by_name;
use crate::strategies::{FevalBudget, Session};
use crate::util::json::Json;
use crate::util::jsonparse;
use crate::util::rng::Rng;

/// One latency scenario: `strategy` run to a budget of `budget`
/// evaluations, `iters` times, in `mode` ("inprocess" or "served").
#[derive(Clone, Debug)]
pub struct Scenario {
    pub mode: &'static str,
    pub strategy: &'static str,
    pub budget: usize,
    pub iters: usize,
}

/// Timing outcome of one scenario.
#[derive(Clone, Debug)]
pub struct Record {
    pub scenario: Scenario,
    /// Total evaluations timed across all iterations.
    pub evaluations: usize,
    pub ns_per_step: f64,
    pub steps_per_s: f64,
}

/// All scenarios share the cheapest (kernel, GPU) objective so the table
/// lookup contributes nothing and the engine/daemon overhead dominates.
fn bench_objective() -> Arc<dyn Objective> {
    objective_for("adding", &Device::a100()) as Arc<dyn Objective>
}

fn run_inprocess(sc: &Scenario) -> (usize, f64) {
    let obj = bench_objective();
    let strategy = by_name(sc.strategy).expect("bench strategy registered");
    let mut evals = 0usize;
    let t0 = Instant::now();
    for rep in 0..sc.iters {
        let mut session = Session::new(
            strategy.driver(obj.space()),
            Arc::clone(&obj),
            Box::new(FevalBudget::new(sc.budget)),
            Rng::new(0xBE7C + rep as u64),
        );
        while session.step() {}
        evals += session.trace().len();
    }
    (evals, t0.elapsed().as_secs_f64())
}

fn run_served(sc: &Scenario) -> (usize, f64) {
    let obj = bench_objective();
    let mut eval_rng = Rng::new(1);
    let mut evals = 0usize;
    let t0 = Instant::now();
    for rep in 0..sc.iters {
        // Fresh server per repetition: a shared cache would satisfy later
        // repetitions' suggestions without asking the client, so the
        // request path under measurement would quietly shrink.
        let server = TuningServer::new(ServeOpts::default()).expect("in-memory server");
        let name = format!("bench-{rep}");
        let create = format!(
            r#"{{"cmd":"create","session":"{name}","config":{{"kernel":"adding","gpu":"a100","strategy":"{}","budget":{},"seed":"0x{:x}"}}}}"#,
            sc.strategy,
            sc.budget,
            0xBE7C + rep as u64
        );
        let resp = jsonparse::parse(&server.handle_line(&create)).expect("valid response");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "create failed: {resp:?}");
        let ask = format!(r#"{{"cmd":"ask","session":"{name}"}}"#);
        loop {
            let a = jsonparse::parse(&server.handle_line(&ask)).expect("valid response");
            match a.get("status").and_then(Json::as_str) {
                Some("eval") => {
                    let idx =
                        a.get("config_index").and_then(Json::as_f64).expect("config_index") as usize;
                    let tell = match obj.evaluate(idx, &mut eval_rng).value() {
                        Some(t) => format!(
                            r#"{{"cmd":"tell","session":"{name}","config_index":{idx},"time":{t}}}"#
                        ),
                        None => format!(
                            r#"{{"cmd":"tell","session":"{name}","config_index":{idx},"invalid":"compile"}}"#
                        ),
                    };
                    server.handle_line(&tell);
                    evals += 1;
                }
                _ => break,
            }
        }
        server.handle_line(&format!(r#"{{"cmd":"close","session":"{name}"}}"#));
    }
    (evals, t0.elapsed().as_secs_f64())
}

/// Run one scenario and report per-step latency.
pub fn run_scenario(sc: &Scenario) -> Record {
    let (evaluations, total_s) = match sc.mode {
        "inprocess" => run_inprocess(sc),
        "served" => run_served(sc),
        other => panic!("unknown bench mode '{other}'"),
    };
    let per = total_s / evaluations.max(1) as f64;
    Record {
        scenario: sc.clone(),
        evaluations,
        ns_per_step: per * 1e9,
        steps_per_s: if per > 0.0 { 1.0 / per } else { f64::INFINITY },
    }
}

/// The bench grid: cheap random, batch mls, and the stateful BO driver,
/// each in-process and served.
pub fn scenario_grid(smoke: bool) -> Vec<Scenario> {
    if smoke {
        return vec![
            Scenario { mode: "inprocess", strategy: "random", budget: 40, iters: 2 },
            Scenario { mode: "served", strategy: "random", budget: 40, iters: 2 },
            Scenario { mode: "inprocess", strategy: "ei", budget: 12, iters: 1 },
        ];
    }
    let mut grid = Vec::new();
    for mode in ["inprocess", "served"] {
        grid.push(Scenario { mode, strategy: "random", budget: 200, iters: 10 });
        grid.push(Scenario { mode, strategy: "mls", budget: 200, iters: 10 });
        grid.push(Scenario { mode, strategy: "ei", budget: 60, iters: 3 });
    }
    grid
}

/// Render records as the `BENCH_session_step.json` document.
pub fn to_json(records: &[Record]) -> Json {
    let rows: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj()
                .set("mode", r.scenario.mode)
                .set("strategy", r.scenario.strategy)
                .set("budget", r.scenario.budget)
                .set("evaluations", r.evaluations)
                .set("ns_per_step", r.ns_per_step)
                .set("steps_per_s", r.steps_per_s)
        })
        .collect();
    Json::obj()
        .set("bench", "session_step")
        .set("unit", "ns_per_step")
        .set(
            "description",
            "owned-Session per-evaluation latency: in-process step loop vs the serve daemon's ask/tell request path",
        )
        .set("records", Json::Arr(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The end-to-end smoke of the grid + JSON serialization lives in
    // tests/integration.rs (session_step_bench_smoke) — one copy only.

    /// The served path must record exactly the budgeted evaluations —
    /// anything else means the protocol loop dropped or double-counted.
    #[test]
    fn served_mode_counts_budgeted_evaluations() {
        let r = run_scenario(&Scenario { mode: "served", strategy: "random", budget: 7, iters: 2 });
        assert_eq!(r.evaluations, 14);
        assert!(r.ns_per_step > 0.0);
    }

    #[test]
    fn inprocess_mode_counts_budgeted_evaluations() {
        let r =
            run_scenario(&Scenario { mode: "inprocess", strategy: "random", budget: 7, iters: 2 });
        assert_eq!(r.evaluations, 14);
    }
}
