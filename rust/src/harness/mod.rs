//! Experiment harness: metrics (§IV-A), the threaded runner, the
//! concurrent sweep orchestrator, and drivers regenerating every table
//! and figure of the paper.

pub mod figures;
pub mod gp_bench;
pub mod hypertune;
pub mod metrics;
pub mod orchestrator;
pub mod runner;
pub mod session_bench;
pub mod space_bench;
pub mod space_scale_bench;
pub mod surrogate_bench;

pub use figures::Options;
pub use orchestrator::{sweep, SweepReport, SweepSpec};
pub use runner::{
    objective_id, run_comparison, run_strategy, StrategyOutcome, BUDGET, REPEATS, REPEATS_RANDOM,
};
