//! §III-H — hyperparameter tuning of the BO implementation itself.
//!
//! The paper tuned the hyperparameters of the initial sampling, surrogate
//! model, and acquisition functions on the Table II kernels (GTX Titan X)
//! and reported the optimum as Table I. This driver reproduces that
//! process: a full grid over the BO design space, each cell scored by MDF
//! across GEMM + Convolution + PnPoly, reported best-first.
//!
//! ```text
//! ktbo hypertune --repeat-scale 0.2 --top 15
//! ```

use std::sync::Arc;

use crate::bo::{Acq, AcqPolicyKind, BoConfig, BoStrategy, Exploration, InitialSampling};
use crate::gp::CovFn;
use crate::gpusim::device::Device;
use crate::harness::figures::{objective_for, Options};
use crate::harness::metrics::{mean_deviation_factor, run_mae};
use crate::harness::runner::{repeats_for, BUDGET};
use crate::objective::Objective;
use crate::strategies::Strategy;
use crate::util::csv::{fnum, CsvWriter};
use crate::util::linalg::mean;
use crate::util::pool::run_parallel;
use crate::util::rng::Rng;

/// One grid cell.
#[derive(Clone)]
pub struct Cell {
    pub label: String,
    pub config: BoConfig,
}

/// The §III-H search grid (the axes Table I reports).
pub fn grid() -> Vec<Cell> {
    let covs: Vec<(&str, CovFn)> = vec![
        ("m32/1.5", CovFn::Matern32 { lengthscale: 1.5 }),
        ("m32/2.0", CovFn::Matern32 { lengthscale: 2.0 }),
        ("m52/0.8", CovFn::Matern52 { lengthscale: 0.8 }),
        ("m52/1.5", CovFn::Matern52 { lengthscale: 1.5 }),
        ("rbf/1.0", CovFn::Rbf { lengthscale: 1.0 }),
    ];
    let explorations: Vec<(&str, Exploration)> = vec![
        ("CV", Exploration::ContextualVariance),
        ("c0.01", Exploration::Constant(0.01)),
        ("c0.1", Exploration::Constant(0.1)),
    ];
    let acqs: Vec<(&str, AcqPolicyKind)> = vec![
        ("advmulti", AcqPolicyKind::AdvancedMulti),
        ("multi", AcqPolicyKind::Multi),
        ("ei", AcqPolicyKind::Single(Acq::Ei)),
    ];
    let samplings: Vec<(&str, InitialSampling)> =
        vec![("maximin", InitialSampling::Maximin), ("lhs", InitialSampling::Lhs)];

    let mut out = Vec::new();
    for (cn, cov) in &covs {
        for (en, expl) in &explorations {
            for (an, acq) in &acqs {
                for (sn, samp) in &samplings {
                    let mut config = match acq {
                        AcqPolicyKind::AdvancedMulti => BoConfig::advanced_multi(),
                        AcqPolicyKind::Multi => BoConfig::multi(),
                        AcqPolicyKind::Single(a) => BoConfig::single(*a),
                    };
                    config.cov = *cov;
                    config.exploration = *expl;
                    config.init_sampling = *samp;
                    out.push(Cell { label: format!("{an}|{cn}|{en}|{sn}"), config });
                }
            }
        }
    }
    out
}

/// Run the grid; returns the report text and writes hypertune.csv.
pub fn hypertune(opts: &Options, top: usize) -> String {
    let dev = Device::gtx_titan_x();
    let kernels = ["gemm", "convolution", "pnpoly"];
    let cells = grid();
    let reps = repeats_for("ei", opts.repeat_scale).min(9);

    // MAE matrix: kernels × cells.
    let mut mae_matrix: Vec<Vec<f64>> = Vec::new();
    for kernel in kernels {
        let obj = objective_for(kernel, &dev);
        let global = obj.known_minimum().unwrap();
        let fallback = crate::harness::runner::fallback_value(&obj);
        let jobs: Vec<_> = cells
            .iter()
            .enumerate()
            .map(|(ci, cell)| {
                let obj = Arc::clone(&obj);
                let config = cell.config.clone();
                let seed = opts.seed;
                move || {
                    let s = BoStrategy::new("ht", config);
                    let maes: Vec<f64> = (0..reps)
                        .map(|rep| {
                            let mut seeder = Rng::with_stream(seed ^ 0x47, (ci * 1000 + rep) as u64 + 1);
                            let mut rng = seeder.split(rep as u64);
                            let t = s.run(obj.as_ref(), BUDGET, &mut rng);
                            run_mae(&t.best_curve(), global, fallback)
                        })
                        .collect();
                    mean(&maes)
                }
            })
            .collect();
        mae_matrix.push(run_parallel(jobs, opts.threads));
    }

    let mdf = mean_deviation_factor(&mae_matrix);
    let mut order: Vec<usize> = (0..cells.len()).collect();
    order.sort_by(|&a, &b| mdf[a].0.partial_cmp(&mdf[b].0).unwrap());

    let mut report = format!(
        "### §III-H hyperparameter tuning: {} grid cells × {} kernels × {reps} repeats (Titan X)\n",
        cells.len(),
        kernels.len()
    );
    report += &format!("{:<34} {:>8} {:>8}   (acq|cov/ls|explore|init)\n", "cell", "MDF", "±std");
    let mut w = CsvWriter::new(&["rank", "cell", "mdf", "std", "mae_gemm", "mae_conv", "mae_pnpoly"]);
    for (rank, &i) in order.iter().enumerate() {
        if rank < top {
            report += &format!("{:<34} {:>8.3} {:>8.3}\n", cells[i].label, mdf[i].0, mdf[i].1);
        }
        w.row(&[
            (rank + 1).to_string(),
            cells[i].label.clone(),
            fnum(mdf[i].0),
            fnum(mdf[i].1),
            fnum(mae_matrix[0][i]),
            fnum(mae_matrix[1][i]),
            fnum(mae_matrix[2][i]),
        ]);
    }
    w.write_to(&std::path::Path::new(&opts.out_dir).join("hypertune.csv")).expect("csv");
    report += &format!("\nbest cell: {} — compare against Table I defaults\n", cells[order[0]].label);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_all_axes() {
        let g = grid();
        assert_eq!(g.len(), 5 * 3 * 3 * 2);
        // Every label unique.
        let set: std::collections::HashSet<_> = g.iter().map(|c| c.label.clone()).collect();
        assert_eq!(set.len(), g.len());
        // Table I's winning cell is in the grid.
        assert!(g.iter().any(|c| c.label == "advmulti|m32/1.5|CV|maximin"));
    }
}
