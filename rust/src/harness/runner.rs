//! Experiment runner: executes (strategy × repeat) jobs across threads
//! with deterministic per-job seeding and aggregates best-found curves and
//! MAE statistics (§IV-A protocol: 220 evaluations, 35 repeats, 100 for
//! random search).

use std::sync::Arc;

use crate::harness::metrics::{mae_stats, run_mae, MaeStats};
use crate::objective::{Objective, TableObjective};
use crate::strategies::registry::by_name;
use crate::util::pool::run_parallel;
use crate::util::rng::Rng;

/// §IV-A defaults.
pub const BUDGET: usize = 220;
pub const REPEATS: usize = 35;
pub const REPEATS_RANDOM: usize = 100;

/// Repeats for a strategy under a global scale factor (for quick runs).
pub fn repeats_for(strategy: &str, scale: f64) -> usize {
    let base = if strategy == "random" { REPEATS_RANDOM } else { REPEATS };
    ((base as f64 * scale).round() as usize).max(3)
}

/// Aggregated outcome of one strategy on one objective.
#[derive(Clone, Debug)]
pub struct StrategyOutcome {
    pub name: String,
    /// Mean best-found value after each evaluation (over repeats);
    /// entries before any valid observation are the fallback value.
    pub mean_curve: Vec<f64>,
    /// Per-repeat MAE values.
    pub maes: Vec<f64>,
    pub mae: MaeStats,
    /// Per-repeat final best values.
    pub finals: Vec<f64>,
}

/// Run one strategy `repeats` times on a shared objective.
pub fn run_strategy(
    obj: &Arc<TableObjective>,
    strategy: &str,
    budget: usize,
    repeats: usize,
    base_seed: u64,
    threads: usize,
) -> StrategyOutcome {
    let global_min = obj.known_minimum().expect("table objective knows its minimum");
    let fallback = {
        let vals: Vec<f64> = obj.table().iter().filter_map(|e| e.value()).collect();
        crate::util::linalg::mean(&vals)
    };

    let jobs: Vec<_> = (0..repeats)
        .map(|rep| {
            let obj = Arc::clone(obj);
            let name = strategy.to_string();
            move || {
                let s = by_name(&name).unwrap_or_else(|| panic!("unknown strategy {name}"));
                // Deterministic independent stream per (strategy, repeat).
                let mut seeder = Rng::with_stream(base_seed, fxhash(&name));
                let mut rng = seeder.split(rep as u64 + 1);
                let trace = s.run(obj.as_ref(), budget, &mut rng);
                trace.best_curve()
            }
        })
        .collect();
    let curves = run_parallel(jobs, threads);

    // Aggregate: mean curve (finite-ified), per-repeat MAE, finals.
    let mut mean_curve = vec![0.0; budget];
    for c in &curves {
        for i in 0..budget {
            let v = if c.is_empty() {
                fallback
            } else {
                let x = c[i.min(c.len() - 1)];
                if x.is_finite() {
                    x
                } else {
                    fallback
                }
            };
            mean_curve[i] += v;
        }
    }
    for v in mean_curve.iter_mut() {
        *v /= curves.len() as f64;
    }
    let maes: Vec<f64> = curves.iter().map(|c| run_mae(c, global_min, fallback)).collect();
    let finals: Vec<f64> = curves
        .iter()
        .map(|c| c.last().copied().filter(|v| v.is_finite()).unwrap_or(fallback))
        .collect();
    StrategyOutcome { name: strategy.to_string(), mean_curve, mae: mae_stats(&maes), maes, finals }
}

/// Run a whole comparison (several strategies on one objective).
pub fn run_comparison(
    obj: &Arc<TableObjective>,
    strategies: &[&str],
    budget: usize,
    repeat_scale: f64,
    base_seed: u64,
    threads: usize,
) -> Vec<StrategyOutcome> {
    strategies
        .iter()
        .map(|s| run_strategy(obj, s, budget, repeats_for(s, repeat_scale), base_seed, threads))
        .collect()
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::Eval;
    use crate::space::{Param, SearchSpace};

    fn toy_obj() -> Arc<TableObjective> {
        let vals: Vec<i64> = (0..40).collect();
        let space = SearchSpace::build("toy", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
        let table: Vec<Eval> = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                Eval::Valid(2.0 + (p[0] - 0.3).powi(2) + (p[1] - 0.6).powi(2))
            })
            .collect();
        Arc::new(TableObjective::new(space, table))
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Both the harness pool (outer) and the BO engine's shard pool
        // (inner, exercised by "ei") must leave results untouched by
        // parallelism.
        let obj = toy_obj();
        for strategy in ["random", "ei"] {
            let a = run_strategy(&obj, strategy, 60, 5, 99, 1);
            let b = run_strategy(&obj, strategy, 60, 5, 99, 4);
            assert_eq!(a.mean_curve, b.mean_curve, "{strategy}: parallelism must not change results");
            assert_eq!(a.maes, b.maes, "{strategy}: parallelism must not change MAEs");
        }
    }

    #[test]
    fn outcomes_have_expected_shapes() {
        let obj = toy_obj();
        let out = run_comparison(&obj, &["random", "mls"], 60, 0.1, 1, 2);
        assert_eq!(out.len(), 2);
        for o in &out {
            assert_eq!(o.mean_curve.len(), 60);
            assert!(o.maes.len() >= 3);
            assert!(o.mae.mean >= 0.0);
            // Mean curve is non-increasing (best-so-far).
            for w in o.mean_curve.windows(2) {
                assert!(w[1] <= w[0] + 1e-9);
            }
        }
    }

    #[test]
    fn repeats_for_scales() {
        assert_eq!(repeats_for("random", 1.0), 100);
        assert_eq!(repeats_for("ei", 1.0), 35);
        assert_eq!(repeats_for("ei", 0.1), 4);
        assert_eq!(repeats_for("ei", 0.01), 3); // floor
    }
}
