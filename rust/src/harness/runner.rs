//! Experiment runner: executes (strategy × repeat) jobs across threads
//! with deterministic per-cell seeding and aggregates best-found curves and
//! MAE statistics (§IV-A protocol: 220 evaluations, 35 repeats, 100 for
//! random search).
//!
//! Seeding contract (shared with `harness::orchestrator`): every
//! (objective, strategy, repeat) cell owns one RNG stream derived by
//! [`cell_rng`] from the experiment's base seed. The serial reference path
//! ([`run_strategy`]) and the concurrent sweep orchestrator draw from the
//! *same* streams, so a cell's evaluation sequence is bit-identical no
//! matter which path — or how many workers — executes it.

use std::sync::Arc;

use crate::harness::metrics::{mae_stats, run_mae, MaeStats};
use crate::objective::{Objective, TableObjective};
use crate::strategies::registry::by_name;
use crate::util::pool::run_parallel;
use crate::util::rng::{fnv1a, Rng};

/// §IV-A defaults.
pub const BUDGET: usize = 220;
pub const REPEATS: usize = 35;
pub const REPEATS_RANDOM: usize = 100;

/// Repeats for a strategy under a global scale factor (for quick runs).
pub fn repeats_for(strategy: &str, scale: f64) -> usize {
    let base = if strategy == "random" { REPEATS_RANDOM } else { REPEATS };
    ((base as f64 * scale).round() as usize).max(3)
}

/// Canonical objective id for a (kernel, device) pair — the string every
/// seeding and caching layer keys on. Figures, the sweep orchestrator, and
/// the CLI must all build ids through this function or cells would seed
/// differently between the serial and orchestrated paths.
pub fn objective_id(kernel: &str, device: &str) -> String {
    format!("{kernel}@{device}")
}

/// Deterministic RNG stream id for one (objective, strategy, repeat) cell.
/// Depends on all three coordinates: two cells sharing a strategy but not
/// an objective (or vice versa) get independent streams.
pub fn cell_stream(objective_id: &str, strategy: &str, rep: usize) -> u64 {
    fnv1a(objective_id).rotate_left(23)
        ^ fnv1a(strategy)
        ^ (rep as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// The RNG a session uses for its whole tuning run: base seed selects the
/// experiment, the cell stream selects the orbit.
pub fn cell_rng(base_seed: u64, objective_id: &str, strategy: &str, rep: usize) -> Rng {
    let mut seeder = Rng::with_stream(base_seed, cell_stream(objective_id, strategy, rep));
    seeder.split(rep as u64 + 1)
}

/// Mean valid value of a table objective — the uninformative fallback used
/// for curve points before the first valid observation.
pub fn fallback_value(obj: &TableObjective) -> f64 {
    let vals: Vec<f64> = obj.table().iter().filter_map(|e| e.value()).collect();
    crate::util::linalg::mean(&vals)
}

/// Aggregated outcome of one strategy on one objective.
#[derive(Clone, Debug)]
pub struct StrategyOutcome {
    pub name: String,
    /// Mean best-found value after each evaluation (over repeats);
    /// entries before any valid observation are the fallback value.
    pub mean_curve: Vec<f64>,
    /// Per-repeat MAE values.
    pub maes: Vec<f64>,
    pub mae: MaeStats,
    /// Per-repeat final best values.
    pub finals: Vec<f64>,
}

/// Fold per-repeat best-found curves into a [`StrategyOutcome`]: mean
/// curve (finite-ified), per-repeat MAE, finals. The single aggregation
/// used by both the serial runner and the sweep orchestrator — keeping it
/// in one place is what makes their outcomes comparable bit-for-bit.
pub fn aggregate_outcome(
    name: &str,
    curves: &[Vec<f64>],
    budget: usize,
    global_min: f64,
    fallback: f64,
) -> StrategyOutcome {
    let mut mean_curve = vec![0.0; budget];
    for c in curves {
        for i in 0..budget {
            let v = if c.is_empty() {
                fallback
            } else {
                let x = c[i.min(c.len() - 1)];
                if x.is_finite() {
                    x
                } else {
                    fallback
                }
            };
            mean_curve[i] += v;
        }
    }
    for v in mean_curve.iter_mut() {
        *v /= curves.len() as f64;
    }
    let maes: Vec<f64> = curves.iter().map(|c| run_mae(c, global_min, fallback)).collect();
    let finals: Vec<f64> = curves
        .iter()
        .map(|c| c.last().copied().filter(|v| v.is_finite()).unwrap_or(fallback))
        .collect();
    StrategyOutcome { name: name.to_string(), mean_curve, mae: mae_stats(&maes), maes, finals }
}

/// Run one strategy `repeats` times on a shared objective — the serial
/// reference path (per-repeat jobs on a fresh `run_parallel` pool).
/// `obj_id` feeds the per-cell seeding; use [`objective_id`] for
/// (kernel, device) objectives so results line up with sweep records.
pub fn run_strategy(
    obj: &Arc<TableObjective>,
    obj_id: &str,
    strategy: &str,
    budget: usize,
    repeats: usize,
    base_seed: u64,
    threads: usize,
) -> StrategyOutcome {
    let global_min = obj.known_minimum().expect("table objective knows its minimum");
    let fallback = fallback_value(obj);

    // Resolve the strategy once, before any job runs: an unknown name
    // fails here instead of panicking inside a worker mid-batch.
    let resolved: Arc<dyn crate::strategies::Strategy> = Arc::from(
        by_name(strategy).unwrap_or_else(|| panic!("unknown strategy {strategy}")),
    );
    let jobs: Vec<_> = (0..repeats)
        .map(|rep| {
            let obj = Arc::clone(obj);
            let s = Arc::clone(&resolved);
            let name = strategy.to_string();
            let oid = obj_id.to_string();
            move || {
                // Deterministic independent stream per (objective, strategy, repeat).
                let mut rng = cell_rng(base_seed, &oid, &name, rep);
                let trace = s.run(obj.as_ref(), budget, &mut rng);
                trace.best_curve()
            }
        })
        .collect();
    let curves = run_parallel(jobs, threads);
    aggregate_outcome(strategy, &curves, budget, global_min, fallback)
}

/// Run a whole comparison (several strategies on one objective).
///
/// Since the sweep-orchestrator refactor this interleaves all
/// (strategy, repeat) cells on one shared [`ShardPool`](crate::util::pool::ShardPool)
/// instead of finishing each strategy before starting the next — the tail
/// repeats of a slow strategy no longer serialize the whole comparison.
/// Results are bit-identical to running [`run_strategy`] per strategy.
pub fn run_comparison(
    obj: &Arc<TableObjective>,
    obj_id: &str,
    strategies: &[&str],
    budget: usize,
    repeat_scale: f64,
    base_seed: u64,
    threads: usize,
) -> Vec<StrategyOutcome> {
    let pool = crate::util::pool::ShardPool::new(threads);
    crate::harness::orchestrator::orchestrate_comparison(
        obj,
        obj_id,
        strategies,
        budget,
        repeat_scale,
        base_seed,
        &pool,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::Eval;
    use crate::space::{Param, SearchSpace};

    fn toy_obj() -> Arc<TableObjective> {
        let vals: Vec<i64> = (0..40).collect();
        let space = SearchSpace::build("toy", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
        let table: Vec<Eval> = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                let (x, y) = (f64::from(p[0]), f64::from(p[1]));
                Eval::Valid(2.0 + (x - 0.3).powi(2) + (y - 0.6).powi(2))
            })
            .collect();
        Arc::new(TableObjective::new(space, table))
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Both the harness pool (outer) and the BO engine's shard pool
        // (inner, exercised by "ei") must leave results untouched by
        // parallelism.
        let obj = toy_obj();
        for strategy in ["random", "ei"] {
            let a = run_strategy(&obj, "toy", strategy, 60, 5, 99, 1);
            let b = run_strategy(&obj, "toy", strategy, 60, 5, 99, 4);
            assert_eq!(a.mean_curve, b.mean_curve, "{strategy}: parallelism must not change results");
            assert_eq!(a.maes, b.maes, "{strategy}: parallelism must not change MAEs");
        }
    }

    #[test]
    fn outcomes_have_expected_shapes() {
        let obj = toy_obj();
        let out = run_comparison(&obj, "toy", &["random", "mls"], 60, 0.1, 1, 2);
        assert_eq!(out.len(), 2);
        for o in &out {
            assert_eq!(o.mean_curve.len(), 60);
            assert!(o.maes.len() >= 3);
            assert!(o.mae.mean >= 0.0);
            // Mean curve is non-increasing (best-so-far).
            for w in o.mean_curve.windows(2) {
                assert!(w[1] <= w[0] + 1e-9);
            }
        }
    }

    #[test]
    fn repeats_for_scales() {
        assert_eq!(repeats_for("random", 1.0), 100);
        assert_eq!(repeats_for("ei", 1.0), 35);
        assert_eq!(repeats_for("ei", 0.1), 4);
        assert_eq!(repeats_for("ei", 0.01), 3); // floor
    }

    #[test]
    fn cell_streams_depend_on_every_coordinate() {
        let base = cell_stream("gemm@GTX Titan X", "ei", 0);
        assert_ne!(cell_stream("gemm@A100", "ei", 0), base, "objective must matter");
        assert_ne!(cell_stream("gemm@GTX Titan X", "random", 0), base, "strategy must matter");
        assert_ne!(cell_stream("gemm@GTX Titan X", "ei", 1), base, "repeat must matter");
        assert_eq!(cell_stream("gemm@GTX Titan X", "ei", 0), base, "but streams are stable");
    }

    #[test]
    fn seeding_separates_objectives() {
        // The pre-orchestrator seeding hashed only the strategy name, so
        // two different objectives replayed identical evaluation index
        // sequences. Cell seeding must break that correlation.
        let mut a = cell_rng(7, "gemm@GTX Titan X", "random", 0);
        let mut b = cell_rng(7, "convolution@GTX Titan X", "random", 0);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "objective-distinct cells must draw independent streams");
    }

    #[test]
    fn aggregate_handles_short_empty_and_infinite_curves() {
        // Short curves extend with their final value; empty and infinite
        // entries fall back to the mean valid value.
        let out = aggregate_outcome(
            "x",
            &[vec![4.0, 2.0], vec![], vec![f64::INFINITY, 6.0]],
            3,
            1.0,
            10.0,
        );
        assert_eq!(out.mean_curve, vec![(4.0 + 10.0 + 10.0) / 3.0, 6.0, 6.0]);
        assert_eq!(out.finals, vec![2.0, 10.0, 6.0]);
        assert_eq!(out.maes.len(), 3);
    }
}
