//! Reusable core of the `space_build` bench: timed enumeration of
//! restricted search spaces through the declarative [`SpaceSpec`] path,
//! serial vs shard-parallel, with machine-readable output
//! (`BENCH_space_build.json` at the repo root).
//!
//! The bench binary (`benches/space_build.rs`) is a thin CLI over these
//! functions, and the test suite runs a tiny smoke grid through the same
//! code (`space_build_bench_smoke` in `tests/integration.rs`) — so the
//! bench logic compiles and runs on every `cargo test` and can never
//! silently rot. Two scenarios:
//!
//! - **gemm** — the paper's heaviest space: 82944-point Cartesian product
//!   restricted to ~18k by the seven CLBlast divisibility conditions;
//! - **synthetic** — a 241920-point Cartesian grid whose modular-sum
//!   restriction keeps ~207k configs, the 200k-candidate scale the
//!   gp_hotpath bench and the ROADMAP's sweep scenarios target.

// ktbo-lint: allow-file(no-untracked-clock): standalone bench harness — wall
// time is informational output here, never on the trace path.
use std::time::Instant;

use crate::gpusim::device::Device;
use crate::gpusim::kernels::kernel_by_name;
use crate::space::{Expr, SpaceSpec};
use crate::util::json::Json;
use crate::util::pool::ShardPool;

/// One space-build scenario: a named spec built with `threads` workers
/// (0/1 = the serial path).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub space: &'static str,
    pub threads: usize,
    pub iters: usize,
}

/// Timing outcome of one scenario.
#[derive(Clone, Debug)]
pub struct Record {
    pub scenario: Scenario,
    /// Restricted size of the built space.
    pub configs: usize,
    pub cartesian: usize,
    pub ms_per_build: f64,
    /// Order-sensitive digest of the packed keys — equal digests across
    /// thread counts ⇒ identical spaces in identical order (the
    /// determinism hook for tests; also lands in the JSON).
    pub keys_digest: u64,
}

/// The named bench spaces shared by the `space_build` and `surrogate_fit`
/// benches: `"gemm"` (the paper's heaviest restricted space),
/// `"synthetic200k"` (the ~200k-candidate grid), `"smoke"` (seconds-scale).
pub fn spec_for(space: &str) -> SpaceSpec {
    match space {
        "gemm" => kernel_by_name("gemm").expect("gemm registered").spec(&Device::gtx_titan_x()),
        // 18 × 14 × 12 × 10 × 8 = 241920 Cartesian; the mod-7 restriction
        // keeps ~6/7 of it → ~207k restricted (the "200k grid" scale the
        // gp_hotpath bench also targets).
        "synthetic200k" => SpaceSpec::new("synthetic200k")
            .ints("a", &(1..=18).collect::<Vec<_>>())
            .ints("b", &(1..=14).collect::<Vec<_>>())
            .ints("c", &(1..=12).collect::<Vec<_>>())
            .ints("d", &(1..=10).collect::<Vec<_>>())
            .ints("e", &(1..=8).collect::<Vec<_>>())
            .restrict(
                Expr::var("a")
                    .add(Expr::var("b"))
                    .add(Expr::var("c"))
                    .rem(Expr::lit(7))
                    .ne(Expr::lit(0)),
            ),
        // Smoke tier: seconds-scale, still restricted.
        "smoke" => SpaceSpec::new("smoke")
            .ints("a", &(1..=12).collect::<Vec<_>>())
            .ints("b", &(1..=10).collect::<Vec<_>>())
            .ints("c", &(1..=8).collect::<Vec<_>>())
            .restrict(Expr::var("a").mul(Expr::var("b")).le(Expr::lit(60))),
        other => panic!("unknown bench space '{other}'"),
    }
}

/// Build the scenario's space `iters` times and report the mean.
pub fn run_scenario(sc: &Scenario) -> Record {
    let spec = spec_for(sc.space);
    let pool = ShardPool::new(sc.threads);
    let build = || if pool.threads() > 0 { spec.build_par(&pool) } else { spec.build() };
    let warm = build(); // warm-up + metadata
    let t0 = Instant::now();
    for _ in 0..sc.iters {
        std::hint::black_box(build());
    }
    let total_s = t0.elapsed().as_secs_f64();
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    for i in 0..warm.len() {
        digest = (digest ^ warm.key(i)).wrapping_mul(0x1000_0000_01b3);
    }
    Record {
        scenario: sc.clone(),
        configs: warm.len(),
        cartesian: warm.cartesian_size,
        ms_per_build: total_s * 1e3 / sc.iters.max(1) as f64,
        keys_digest: digest,
    }
}

/// The bench grid: both spaces, serial baseline plus a thread sweep.
pub fn scenario_grid(smoke: bool) -> Vec<Scenario> {
    if smoke {
        return vec![
            Scenario { space: "smoke", threads: 1, iters: 2 },
            Scenario { space: "smoke", threads: 4, iters: 2 },
        ];
    }
    let mut grid = Vec::new();
    for space in ["gemm", "synthetic200k"] {
        for threads in [1usize, 2, 4, 8] {
            grid.push(Scenario { space, threads, iters: 5 });
        }
    }
    grid
}

/// Render records as the `BENCH_space_build.json` document (diffable:
/// insertion-ordered keys, one record per scenario).
pub fn to_json(records: &[Record]) -> Json {
    let rows: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj()
                .set("space", r.scenario.space)
                .set("threads", r.scenario.threads)
                .set("configs", r.configs)
                .set("cartesian", r.cartesian)
                .set("ms_per_build", r.ms_per_build)
                .set("keys_digest", format!("{:016x}", r.keys_digest))
        })
        .collect();
    Json::obj()
        .set("bench", "space_build")
        .set("unit", "ms_per_build")
        .set(
            "description",
            "constraint-propagating columnar enumeration via SpaceSpec, serial vs ShardPool-parallel",
        )
        .set("records", Json::Arr(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The end-to-end smoke of the grid + JSON serialization lives in
    // tests/integration.rs (space_build_bench_smoke) — one copy only.

    /// Builds must be partition-independent: every thread count digests
    /// to the serial keys.
    #[test]
    fn build_digest_is_thread_count_independent() {
        let digest = |threads: usize| {
            run_scenario(&Scenario { space: "smoke", threads, iters: 1 }).keys_digest
        };
        let reference = digest(1);
        assert_eq!(digest(2), reference);
        assert_eq!(digest(8), reference);
    }

    #[test]
    fn gemm_scenario_matches_paper_scale() {
        let r = run_scenario(&Scenario { space: "gemm", threads: 2, iters: 1 });
        assert_eq!(r.cartesian, 82944, "paper: GEMM Cartesian 82944");
        assert!(r.configs > 10_000 && r.configs < 30_000, "restricted {}", r.configs);
    }
}
