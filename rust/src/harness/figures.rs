//! Experiment drivers that regenerate every table and figure of the
//! paper's evaluation (see DESIGN.md per-experiment index). Each driver
//! returns a human-readable report (ASCII plots + tables) and writes CSV
//! series under the output directory.

use std::path::Path;
use std::sync::Arc;

use crate::gpusim::device::Device;
use crate::gpusim::kernels::kernel_by_name;
use crate::gpusim::SimulatedSpace;
use crate::harness::metrics::mean_deviation_factor;
use crate::harness::runner::{
    fallback_value, objective_id, repeats_for, run_comparison, run_strategy, StrategyOutcome, BUDGET,
};
use crate::objective::{Objective, TableObjective};
use crate::strategies::registry::{by_name, framework_methods, kernel_tuner_methods, our_methods};
use crate::util::csv::{fnum, CsvWriter};
use crate::util::plot::{bar_chart, line_plot, Series};
use crate::util::rng::Rng;

/// Shared experiment options.
#[derive(Clone)]
pub struct Options {
    pub repeat_scale: f64,
    pub seed: u64,
    pub threads: usize,
    pub out_dir: String,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            repeat_scale: 1.0,
            seed: 20210601,
            threads: crate::util::pool::default_threads(),
            out_dir: "results".into(),
        }
    }
}

/// Build the simulation-mode objective for (kernel, device).
pub fn objective_for(kernel: &str, dev: &Device) -> Arc<TableObjective> {
    let k = kernel_by_name(kernel).unwrap_or_else(|| panic!("unknown kernel {kernel}"));
    Arc::new(TableObjective::from_sim(SimulatedSpace::build(k.as_ref(), dev)))
}

fn write_curves_csv(path: &Path, kernel: &str, outcomes: &[StrategyOutcome]) {
    let mut w = CsvWriter::new(&["kernel", "strategy", "evaluation", "mean_best"]);
    for o in outcomes {
        for (i, v) in o.mean_curve.iter().enumerate() {
            w.row(&[kernel.into(), o.name.clone(), (i + 1).to_string(), fnum(*v)]);
        }
    }
    w.write_to(path).expect("write curves csv");
}

fn write_mdf_csv(path: &Path, strategies: &[&str], mdf: &[(f64, f64)]) {
    let mut w = CsvWriter::new(&["strategy", "mdf", "std"]);
    for (s, (m, sd)) in strategies.iter().zip(mdf) {
        w.row(&[s.to_string(), fnum(*m), fnum(*sd)]);
    }
    w.write_to(path).expect("write mdf csv");
}

/// Generic "Fig 1/2/3/5-style" experiment: best-found-vs-evaluations per
/// kernel plus an MDF bar chart across kernels.
pub fn fig_comparison(
    tag: &str,
    dev: &Device,
    kernels: &[&str],
    strategies: &[&str],
    opts: &Options,
) -> String {
    let mut report = format!("### {tag}: {} — strategies: {:?}\n", dev.name, strategies);
    let mut mae_matrix: Vec<Vec<f64>> = Vec::new();
    for kernel in kernels {
        let obj = objective_for(kernel, dev);
        let obj_id = objective_id(kernel, dev.name);
        let outcomes =
            run_comparison(&obj, &obj_id, strategies, BUDGET, opts.repeat_scale, opts.seed, opts.threads);
        let min = obj.known_minimum().unwrap();
        write_curves_csv(
            &Path::new(&opts.out_dir).join(format!("{tag}_{kernel}_curves.csv")),
            kernel,
            &outcomes,
        );
        // Plot from evaluation 20 (end of initial sampling), like the paper.
        let series: Vec<Series> = outcomes
            .iter()
            .map(|o| Series {
                name: o.name.clone(),
                points: o
                    .mean_curve
                    .iter()
                    .enumerate()
                    .skip(19)
                    .step_by(5)
                    .map(|(i, v)| ((i + 1) as f64, *v))
                    .collect(),
            })
            .collect();
        report += &line_plot(
            &format!("{tag} {kernel} on {} (global min {min:.3})", dev.name),
            "function evaluations",
            "best found",
            &series,
            72,
            18,
        );
        report += &format!(
            "MAE (mean±std over repeats): {}\n\n",
            outcomes
                .iter()
                .map(|o| format!("{}={:.4}±{:.4}", o.name, o.mae.mean, o.mae.std))
                .collect::<Vec<_>>()
                .join("  ")
        );
        mae_matrix.push(outcomes.iter().map(|o| o.mae.mean).collect());
    }
    // MDF bar chart across the kernels of this figure.
    let mdf = mean_deviation_factor(&mae_matrix);
    write_mdf_csv(&Path::new(&opts.out_dir).join(format!("{tag}_mdf.csv")), strategies, &mdf);
    let entries: Vec<(String, f64, f64)> = strategies
        .iter()
        .zip(&mdf)
        .map(|(s, (m, sd))| (s.to_string(), *m, *sd))
        .collect();
    report += &bar_chart(&format!("{tag} mean deviation factors ({})", dev.name), &entries, 46);
    report
}

/// Strategy set of Figs 1–3: ours + the Kernel Tuner competitors.
pub fn default_strategies() -> Vec<&'static str> {
    let mut v = our_methods();
    v.extend(kernel_tuner_methods());
    v
}

pub fn fig1(opts: &Options) -> String {
    fig_comparison("fig1", &Device::gtx_titan_x(), &["gemm", "convolution", "pnpoly"], &default_strategies(), opts)
}

pub fn fig2(opts: &Options) -> String {
    fig_comparison("fig2", &Device::rtx_2070_super(), &["gemm", "convolution", "pnpoly"], &default_strategies(), opts)
}

pub fn fig3(opts: &Options) -> String {
    fig_comparison("fig3", &Device::a100(), &["gemm", "convolution", "pnpoly"], &default_strategies(), opts)
}

/// Fig 5: comparison with the external BO frameworks on the RTX 2070 Super.
pub fn fig5(opts: &Options) -> String {
    let mut strategies = our_methods();
    strategies.push("random");
    strategies.extend(framework_methods());
    fig_comparison("fig5", &Device::rtx_2070_super(), &["gemm", "convolution", "pnpoly"], &strategies, opts)
}

/// Fig 6/7: unseen kernels on the A100.
pub fn fig6(opts: &Options) -> String {
    fig_comparison("fig6", &Device::a100(), &["expdist"], &default_strategies(), opts)
}

pub fn fig7(opts: &Options) -> String {
    fig_comparison("fig7", &Device::a100(), &["adding"], &default_strategies(), opts)
}

/// Fig 4: how many unique evaluations the other strategies need to match
/// EI's best at 220 evaluations (GEMM, GTX Titan X; cap 1020).
pub fn fig4(opts: &Options) -> String {
    const CAP: usize = 1020;
    let dev = Device::gtx_titan_x();
    let obj = objective_for("gemm", &dev);
    let reps = repeats_for("ei", opts.repeat_scale);

    // Target: EI's mean best at 220.
    let ei = run_strategy(&obj, &objective_id("gemm", dev.name), "ei", BUDGET, reps, opts.seed, opts.threads);
    let target = ei.mean_curve[BUDGET - 1];

    let mut report = format!("### fig4: evaluations to match EI@220 (target {target:.3} ms) on GEMM / {}\n", dev.name);
    let mut w = CsvWriter::new(&["strategy", "mean_evals_to_match", "matched_fraction"]);
    w.row(&["ei".into(), BUDGET.to_string(), "1".into()]);
    for strat in ["mls", "genetic_algorithm", "simulated_annealing", "random"] {
        let n_rep = repeats_for(strat, opts.repeat_scale);
        let jobs: Vec<_> = (0..n_rep)
            .map(|rep| {
                let obj = Arc::clone(&obj);
                let name = strat.to_string();
                let seed = opts.seed;
                move || {
                    let s = by_name(&name).unwrap();
                    let mut seeder = Rng::with_stream(seed ^ 0xf16_4, rep as u64 + 1);
                    let mut rng = seeder.split(rep as u64 + 1);
                    let trace = s.run(obj.as_ref(), CAP, &mut rng);
                    let curve = trace.best_curve();
                    curve.iter().position(|v| *v <= target).map(|i| i + 1)
                }
            })
            .collect();
        let firsts = crate::util::pool::run_parallel(jobs, opts.threads);
        let matched: Vec<usize> = firsts.iter().flatten().copied().collect();
        let frac = matched.len() as f64 / n_rep as f64;
        // Unmatched runs count as the cap (lower bound on the true cost).
        let mean_evals: f64 =
            (matched.iter().sum::<usize>() + (n_rep - matched.len()) * CAP) as f64 / n_rep as f64;
        report += &format!("  {strat:<22} mean evals {:7.1}  (matched {:.0}%)\n", mean_evals, frac * 100.0);
        w.row(&[strat.into(), fnum(mean_evals), fnum(frac)]);
    }
    w.write_to(&Path::new(&opts.out_dir).join("fig4_match_ei.csv")).expect("csv");
    report
}

/// Tables II & III: search-space statistics per kernel and GPU.
pub fn table_spaces(devices: &[Device], kernels: &[&str]) -> String {
    let mut out = String::from(
        "| GPU | Kernel | Cartesian | Restricted | Invalid | Invalid % | Minimum |\n|---|---|---|---|---|---|---|\n",
    );
    for dev in devices {
        for kernel in kernels {
            let k = kernel_by_name(kernel).unwrap();
            let sim = SimulatedSpace::build(k.as_ref(), dev);
            let inv = sim.invalid_count();
            let (_, min) = sim.global_minimum();
            out += &format!(
                "| {} | {} | {} | {} | {} | {:.1}% | {:.3} |\n",
                dev.name,
                kernel,
                sim.space.cartesian_size,
                sim.space.len(),
                inv,
                100.0 * inv as f64 / sim.space.len() as f64,
                min
            );
        }
    }
    out
}

pub fn table2() -> String {
    format!(
        "### Table II: kernel specifications on the GTX Titan X\n{}",
        table_spaces(&[Device::gtx_titan_x()], &["gemm", "convolution", "pnpoly"])
    )
}

pub fn table3() -> String {
    format!(
        "### Table III: kernel details per GPU\n{}",
        table_spaces(
            &[Device::rtx_2070_super(), Device::a100()],
            &["gemm", "convolution", "pnpoly", "expdist", "adding"],
        )
    )
}

/// Table I: the tuned hyperparameter defaults.
pub fn table1() -> String {
    let c = crate::bo::BoConfig::advanced_multi();
    let mut s = String::from("### Table I: hyperparameter defaults (as implemented)\n");
    s += &format!("| Covariance function, lengthscale | {} l={} |\n", c.cov.name(), c.cov.lengthscale());
    s += "| Exploration factor | contextual variance (CV) |\n";
    s += &format!("| Skip threshold | {} |\n", c.skip_threshold);
    s += "| Order of acquisition functions | (ei, poi, lcb) |\n";
    s += &format!("| Required improvement factor | {} |\n", c.improvement_factor);
    s += &format!(
        "| Discount factor | {} (multi), {} (advanced multi) |\n",
        crate::bo::BoConfig::multi().discount,
        c.discount
    );
    s += "| Initial sampling | maximin LHS |\n";
    s += &format!("| Pruning | {} |\n", if c.pruning { "yes" } else { "no" });
    s += "| Acquisition functions | advanced multi, multi, EI |\n";
    s
}

/// Ablation study backing Table I's hyperparameter choices: vary one
/// design axis of the BO config at a time (covariance function,
/// exploration factor, initial sampling, pruning) and report MDF across
/// GEMM + Convolution on the Titan X. Not a figure in the paper, but the
/// experiment behind its Table I (the paper tuned these on the Table II
/// kernels/GPU).
pub fn ablation(opts: &Options) -> String {
    use crate::bo::{Acq, BoConfig, BoStrategy, Exploration, InitialSampling};
    use crate::gp::CovFn;
    use crate::strategies::Strategy;
    use crate::util::rng::Rng;

    let dev = Device::gtx_titan_x();
    let kernels = ["gemm", "convolution"];
    let variants: Vec<(String, BoConfig)> = {
        let base = BoConfig::advanced_multi();
        let mut v: Vec<(String, BoConfig)> = Vec::new();
        v.push(("base (Table I)".into(), base.clone()));
        for (name, cov) in [
            ("cov=matern32 l=2.0", CovFn::Matern32 { lengthscale: 2.0 }),
            ("cov=matern52 l=0.8", CovFn::Matern52 { lengthscale: 0.8 }),
            ("cov=rbf l=1.0", CovFn::Rbf { lengthscale: 1.0 }),
            ("cov=rq l=1.0", CovFn::RationalQuadratic { lengthscale: 1.0, alpha: 1.0 }),
        ] {
            v.push((name.into(), BoConfig { cov, ..base.clone() }));
        }
        for (name, e) in [
            ("explore=const 0.01", Exploration::Constant(0.01)),
            ("explore=const 0.1", Exploration::Constant(0.1)),
            ("explore=const 1.0", Exploration::Constant(1.0)),
        ] {
            v.push((name.into(), BoConfig { exploration: e, ..base.clone() }));
        }
        for (name, s) in [
            ("init=lhs", InitialSampling::Lhs),
            ("init=random", InitialSampling::Random),
        ] {
            v.push((name.into(), BoConfig { init_sampling: s, ..base.clone() }));
        }
        v.push(("pruning=off".into(), BoConfig { pruning: false, ..base.clone() }));
        v.push(("acq=single EI".into(), BoConfig::single(Acq::Ei)));
        v.push(("acq=multi".into(), BoConfig::multi()));
        v
    };

    let reps = repeats_for("ei", opts.repeat_scale);
    let mut mae_matrix: Vec<Vec<f64>> = Vec::new();
    for kernel in kernels {
        let obj = objective_for(kernel, &dev);
        let global = obj.known_minimum().unwrap();
        let fallback = fallback_value(&obj);
        let mut row = Vec::new();
        for (name, cfg) in &variants {
            let jobs: Vec<_> = (0..reps)
                .map(|rep| {
                    let obj = Arc::clone(&obj);
                    let cfg = cfg.clone();
                    let name = name.clone();
                    let seed = opts.seed;
                    move || {
                        let s = BoStrategy::new(&name, cfg);
                        let mut seeder = Rng::with_stream(seed, rep as u64 + 77);
                        let mut rng = seeder.split(rep as u64);
                        let t = s.run(obj.as_ref(), BUDGET, &mut rng);
                        crate::harness::metrics::run_mae(&t.best_curve(), global, fallback)
                    }
                })
                .collect();
            let maes = crate::util::pool::run_parallel(jobs, opts.threads);
            row.push(crate::util::linalg::mean(&maes));
        }
        mae_matrix.push(row);
    }
    let mdf = mean_deviation_factor(&mae_matrix);
    let mut report = String::from("### ablation: Table I design choices (GEMM + Convolution, Titan X)\n");
    let mut w = CsvWriter::new(&["variant", "mdf", "std", "mae_gemm", "mae_conv"]);
    for (i, (name, _)) in variants.iter().enumerate() {
        report += &format!(
            "  {name:<22} MDF {:.3} ±{:.3}   (MAE gemm {:.3}, conv {:.3})\n",
            mdf[i].0, mdf[i].1, mae_matrix[0][i], mae_matrix[1][i]
        );
        w.row(&[name.clone(), fnum(mdf[i].0), fnum(mdf[i].1), fnum(mae_matrix[0][i]), fnum(mae_matrix[1][i])]);
    }
    w.write_to(&Path::new(&opts.out_dir).join("ablation.csv")).expect("csv");
    report
}

/// Extended comparison: the full strategy pool including the Kernel Tuner
/// strategies the paper screened out (PSO, DE, ILS) and discrete GP-Hedge
/// (§III-G's explicit contrast to `multi`/`advanced multi`).
pub fn extended(opts: &Options) -> String {
    let mut strategies = default_strategies();
    strategies.extend(crate::strategies::registry::extended_methods());
    fig_comparison("extended", &Device::gtx_titan_x(), &["convolution", "pnpoly"], &strategies, opts)
}

/// Noise-robustness experiment: simulation mode replays noiseless means,
/// but live tuning observes noisy measurements. Kernel Tuner averages
/// `iterations` runs per configuration; this experiment sweeps the
/// residual noise level and checks which strategies degrade.
pub fn noise(opts: &Options) -> String {
    use crate::objective::NoisyObjective;
    use crate::strategies::registry::by_name;
    use crate::util::rng::Rng;

    let dev = Device::gtx_titan_x();
    let kernel = "convolution";
    let strategies = ["advanced_multi", "ei", "genetic_algorithm", "mls", "random"];
    let sigmas = [0.0, 0.05, 0.15, 0.30];
    let reps = repeats_for("ei", opts.repeat_scale);

    let base = objective_for(kernel, &dev);
    let global = base.known_minimum().unwrap();
    let fallback = fallback_value(&base);

    let mut report = format!("### noise robustness: {kernel} on {} (MAE vs measurement noise σ)\n", dev.name);
    let mut w = CsvWriter::new(&["strategy", "sigma", "mae_mean", "mae_std"]);
    report += &format!("{:<22}", "strategy");
    for s in sigmas {
        report += &format!(" σ={s:<8}");
    }
    report += "\n";
    for strat in strategies {
        report += &format!("{strat:<22}");
        for sigma in sigmas {
            let jobs: Vec<_> = (0..reps)
                .map(|rep| {
                    let dev = dev.clone();
                    let seed = opts.seed;
                    let name = strat.to_string();
                    move || {
                        // Each job rebuilds the (cheap) table and wraps it
                        // with noise; measurement noise is seeded per repeat.
                        let k = crate::gpusim::kernels::kernel_by_name("convolution").unwrap();
                        let sim = crate::gpusim::SimulatedSpace::build(k.as_ref(), &dev);
                        let noisy = NoisyObjective::new(
                            crate::objective::TableObjective::from_sim(sim),
                            sigma,
                            1,
                        );
                        let s = by_name(&name).unwrap();
                        let mut seeder = Rng::with_stream(seed ^ 0x401_5e, rep as u64 + 1);
                        let mut rng = seeder.split(rep as u64);
                        let trace = s.run(&noisy, BUDGET, &mut rng);
                        // Score by TRUE values: look the evaluated configs
                        // up in the noiseless table (the tuner's reported
                        // best may be optimistic under noise).
                        let mut best = f64::INFINITY;
                        let base2 = objective_for("convolution", &dev);
                        let curve: Vec<f64> = trace
                            .records
                            .iter()
                            .map(|(i, e)| {
                                if e.is_valid() {
                                    if let Some(tv) = base2.table()[*i].value() {
                                        best = best.min(tv);
                                    }
                                }
                                best
                            })
                            .collect();
                        crate::harness::metrics::run_mae(&curve, global, fallback)
                    }
                })
                .collect();
            let maes = crate::util::pool::run_parallel(jobs, opts.threads);
            let m = crate::util::linalg::mean(&maes);
            let sd = crate::util::linalg::std_dev(&maes);
            report += &format!(" {m:<9.3}");
            w.row(&[strat.into(), fnum(sigma), fnum(m), fnum(sd)]);
        }
        report += "\n";
    }
    w.write_to(&Path::new(&opts.out_dir).join("noise.csv")).expect("csv");
    report
}

/// §IV-F headline numbers: advanced multi vs GA / SA, per GPU and average.
pub fn headline(opts: &Options) -> String {
    let strategies = default_strategies();
    let am_pos = strategies.iter().position(|s| *s == "advanced_multi").unwrap();
    let ga_pos = strategies.iter().position(|s| *s == "genetic_algorithm").unwrap();
    let sa_pos = strategies.iter().position(|s| *s == "simulated_annealing").unwrap();

    let mut report = String::from("### §IV-F headline: advanced multi vs best competitors\n");
    let mut improvements_ga = Vec::new();
    let mut improvements_sa = Vec::new();
    let setups: Vec<(&str, Device, Vec<&str>)> = vec![
        ("GTX Titan X", Device::gtx_titan_x(), vec!["gemm", "convolution", "pnpoly"]),
        ("RTX 2070 Super", Device::rtx_2070_super(), vec!["gemm", "convolution", "pnpoly"]),
        ("A100", Device::a100(), vec!["gemm", "convolution", "pnpoly", "expdist", "adding"]),
    ];
    for (name, dev, kernels) in setups {
        let mut mae_matrix = Vec::new();
        for k in &kernels {
            let obj = objective_for(k, &dev);
            let obj_id = objective_id(k, dev.name);
            let outcomes =
                run_comparison(&obj, &obj_id, &strategies, BUDGET, opts.repeat_scale, opts.seed, opts.threads);
            mae_matrix.push(outcomes.iter().map(|o| o.mae.mean).collect::<Vec<f64>>());
        }
        let mdf = mean_deviation_factor(&mae_matrix);
        let vs_ga = 100.0 * (1.0 - mdf[am_pos].0 / mdf[ga_pos].0);
        let vs_sa = 100.0 * (1.0 - mdf[am_pos].0 / mdf[sa_pos].0);
        improvements_ga.push(vs_ga);
        improvements_sa.push(vs_sa);
        report += &format!(
            "  {name:<16} adv-multi MDF {:.3} | GA {:.3} (+{vs_ga:.1}%) | SA {:.3} (+{vs_sa:.1}%)\n",
            mdf[am_pos].0, mdf[ga_pos].0, mdf[sa_pos].0
        );
    }
    let avg_ga = improvements_ga.iter().sum::<f64>() / improvements_ga.len() as f64;
    let avg_sa = improvements_sa.iter().sum::<f64>() / improvements_sa.len() as f64;
    report += &format!(
        "  average: vs GA +{avg_ga:.1}% (paper: 49.7%), vs SA +{avg_sa:.1}% (paper: 75%)\n"
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> Options {
        Options {
            repeat_scale: 0.02, // 3 repeats
            seed: 7,
            threads: 2,
            out_dir: std::env::temp_dir().join("ktbo-figtest").to_string_lossy().into_owned(),
        }
    }

    #[test]
    fn table1_lists_all_hyperparameters() {
        let t = table1();
        for key in ["lengthscale", "Skip threshold", "improvement factor", "Discount", "maximin", "Pruning"] {
            assert!(t.contains(key), "missing {key}");
        }
    }

    #[test]
    fn table2_has_three_kernels() {
        let t = table2();
        assert!(t.contains("gemm") && t.contains("convolution") && t.contains("pnpoly"));
        assert!(t.contains("GTX Titan X"));
    }

    #[test]
    fn small_fig_runs_end_to_end() {
        // Adding on the A100 is the smallest space; a 3-repeat run of two
        // cheap strategies exercises the full driver.
        let opts = quick_opts();
        let r = fig_comparison("figtest", &Device::a100(), &["adding"], &["random", "mls"], &opts);
        assert!(r.contains("mean deviation factors"));
        assert!(r.contains("MAE"));
        let csv = std::path::Path::new(&opts.out_dir).join("figtest_adding_curves.csv");
        assert!(csv.exists());
    }
}
