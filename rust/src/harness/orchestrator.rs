//! Concurrent multi-session sweep orchestrator for the full evaluation
//! matrix.
//!
//! The paper's headline comparison is a big matrix — every strategy,
//! repeated tens of times, on every (kernel, GPU) objective. The seed
//! harness executed that matrix strictly serially per strategy, idling
//! most of the machine whenever one strategy's tail repeats were still
//! running. This module treats each (kernel, device, strategy, repeat)
//! cell as an independent *session* and schedules all sessions of a sweep
//! onto one shared [`ShardPool`]: cells from different strategies and
//! objectives interleave freely, so the pool stays saturated until the
//! whole matrix drains.
//!
//! Three invariants make concurrency safe here:
//!
//! 1. **Seeding** — every cell's RNG comes from
//!    [`runner::cell_rng`](crate::harness::runner::cell_rng), a pure
//!    function of (base seed, objective id, strategy, repeat). Scheduling
//!    order, worker count, and cache state cannot touch it, so a cell's
//!    evaluation sequence is bit-identical to the serial reference path.
//! 2. **Aggregation** — per-cell curves are folded through the same
//!    [`runner::aggregate_outcome`] as the serial path, in a fixed
//!    (objective, strategy, repeat) order regardless of completion order.
//! 3. **Persistence** — each finished cell appends one JSONL record
//!    (`SWEEP_<tag>.jsonl`) carrying its coordinates, seeds, and raw
//!    best-found curve. Floats round-trip exactly (shortest-repr render,
//!    `str::parse::<f64>` read back; `null` ⇔ `+∞`), so a resumed sweep
//!    reuses completed cells without perturbing aggregate results.
//!
//! Sessions of one objective share a cross-session
//! [`EvalCache`](crate::objective::evalcache::EvalCache) keyed by
//! (objective id, config index) — table-backed objectives are evaluated
//! once per sweep rather than once per session.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::Write as _;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::gpusim::device::Device;
use crate::gpusim::kernels::kernel_by_name;
use crate::harness::figures::objective_for;
use crate::harness::runner::{
    aggregate_outcome, cell_rng, cell_stream, fallback_value, objective_id, repeats_for,
    StrategyOutcome,
};
use crate::objective::evalcache::{CachedObjective, EvalCache};
use crate::objective::faulty::{FaultPlan, FaultyObjective};
use crate::objective::resilient::{ResilienceConfig, ResilientEvaluator};
use crate::objective::{Objective, TableObjective};
use crate::strategies::registry::{by_name, unknown_strategy_message};
use crate::strategies::Strategy;
use crate::telemetry::clock::{Clock, MonotonicClock};
use crate::telemetry::{metrics, EventKind, Telemetry, DEFAULT_RING_CAPACITY};
use crate::util::json::Json;
use crate::util::jsonparse;
use crate::util::pool::{enter_harness_workers, ShardPool};

/// Coordinates of one session in the evaluation matrix. `Ord` because
/// resume sets live in ordered maps — iteration order is part of the
/// byte-stability contract on sweep artifacts.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct CellKey {
    pub kernel: String,
    /// Canonical device name (`Device::name`), not a CLI alias.
    pub gpu: String,
    pub strategy: String,
    pub rep: usize,
}

/// What a sweep executes and where it records itself.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub kernels: Vec<String>,
    /// Device names or aliases (resolved through [`Device::by_name`]).
    pub gpus: Vec<String>,
    pub strategies: Vec<String>,
    pub budget: usize,
    pub repeat_scale: f64,
    pub seed: u64,
    pub threads: usize,
    pub out_dir: String,
    /// Names the JSONL files: `SWEEP_<tag>.jsonl` (progress) and
    /// `SWEEP_<tag>.results.jsonl` (aggregates).
    pub tag: String,
    /// Share one cross-session evaluation cache across all sessions.
    pub cache: bool,
    /// Discard an existing progress file instead of resuming from it.
    pub fresh: bool,
    /// Path to a declarative `SpaceSpec` JSON file replacing the kernel's
    /// built-in space (`ktbo sweep --space file.json`). Requires a
    /// single-kernel matrix — the spec's parameter names must match what
    /// that kernel's analytical model reads.
    pub space: Option<String>,
    /// Path to a [`FaultPlan`] JSON file (`ktbo sweep --fault-plan`).
    /// Cells of the strategies in `fault_strategies` evaluate through a
    /// [`FaultyObjective`] seeded per cell (plan seed ⊕ cell stream), so
    /// injected faults are deterministic at every thread count. `None` =
    /// no injection.
    pub fault_plan: Option<String>,
    /// Which strategies run faulted when `fault_plan` is set (canonical
    /// names or aliases). Empty = every strategy in the matrix.
    pub fault_strategies: Vec<String>,
    /// Per-evaluation deadline for every cell, in milliseconds
    /// (`--eval-timeout-ms`). `None` = no watchdog. Note the watchdog
    /// splits a child RNG per attempt, so timed cells trace differently
    /// from unwatched ones — the meta record guards resume mixing.
    pub eval_timeout_ms: Option<u64>,
    /// Transient-failure retries per evaluation (`--max-retries`).
    pub max_retries: u32,
    /// Capture per-cell telemetry (`ktbo sweep --telemetry`): phase
    /// spans and events land in `SWEEP_<tag>.telemetry.jsonl`, tagged
    /// with cell coordinates. Observation-only — evaluation traces and
    /// `results.jsonl` are byte-identical with it on or off (asserted in
    /// tests), which is also why the flag is *not* part of the meta
    /// record's resume-compatibility check.
    pub telemetry: bool,
}

impl SweepSpec {
    pub fn progress_path(&self) -> PathBuf {
        Path::new(&self.out_dir).join(format!("SWEEP_{}.jsonl", self.tag))
    }

    pub fn results_path(&self) -> PathBuf {
        Path::new(&self.out_dir).join(format!("SWEEP_{}.results.jsonl", self.tag))
    }

    pub fn telemetry_path(&self) -> PathBuf {
        Path::new(&self.out_dir).join(format!("SWEEP_{}.telemetry.jsonl", self.tag))
    }

    /// The CI tier: a seconds-scale matrix that still exercises multiple
    /// cells, the BO engine, a non-GP surrogate (`bo_rf` — so the
    /// pluggable-Model path is exercised on every push), the cache, the
    /// JSONL plumbing, and — via the `simulated_annealing` cells run under
    /// the committed `examples/faults/smoke.json` plan — the fault
    /// injection and resilience layers with isolated-failure accounting.
    pub fn smoke(out_dir: &str) -> SweepSpec {
        SweepSpec {
            kernels: vec!["adding".into()],
            gpus: vec!["a100".into()],
            strategies: vec![
                "random".into(),
                "mls".into(),
                "ei".into(),
                "bo_rf".into(),
                "sa".into(),
            ],
            budget: 60,
            repeat_scale: 0.02,
            seed: 20210601,
            threads: crate::util::pool::default_threads(),
            out_dir: out_dir.into(),
            tag: "smoke".into(),
            cache: true,
            fresh: false,
            space: None,
            fault_plan: Some("examples/faults/smoke.json".into()),
            fault_strategies: vec!["simulated_annealing".into()],
            eval_timeout_ms: None,
            max_retries: 2,
            telemetry: false,
        }
    }
}

/// Everything a finished sweep reports back.
pub struct SweepReport {
    /// Aggregates per (kernel, canonical gpu), strategies in spec order —
    /// the exact [`StrategyOutcome`]s the serial path would produce.
    pub outcomes: Vec<((String, String), Vec<StrategyOutcome>)>,
    pub total_cells: usize,
    /// Cells skipped because the progress file already carried them.
    pub resumed_cells: usize,
    pub ran_cells: usize,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub wall_s: f64,
    /// Cells that panicked (or were otherwise crash-isolated), with the
    /// panic message. Recorded as `"outcome":"failed"` in the progress
    /// JSONL — curve-less, so a `--fresh`-less resume re-attempts exactly
    /// these cells. Failed cells are excluded from aggregates.
    pub failed_cells: Vec<(CellKey, String)>,
    /// Human-readable digest (printed by `ktbo sweep`).
    pub summary: String,
}

/// One schedulable session: a cell plus the objective it evaluates.
struct SessionJob {
    key: CellKey,
    obj_id: String,
    /// Resolved once before any worker runs — a bad name fails in the
    /// caller, never as a panic inside the pool mid-batch.
    strategy_impl: Arc<dyn Strategy>,
    eval_obj: Arc<dyn Objective>,
    /// Fault-injection handle for a faulted cell, kept for accounting.
    faulty: Option<Arc<FaultyObjective>>,
    /// Resilience-layer handle, kept for accounting.
    resilient: Option<Arc<ResilientEvaluator>>,
}

/// How one session ended.
enum CellResult {
    Done(Vec<f64>),
    /// The cell panicked; the sweep goes on without it.
    Failed(String),
}

/// Append-only JSONL progress log, shared across pool workers.
struct SweepLog {
    file: Mutex<std::fs::File>,
    /// First write/flush error, if any — workers can't propagate, so the
    /// sweep checks this after the batch and refuses to report success
    /// with a silently incomplete resume log.
    error: Mutex<Option<String>>,
}

impl SweepLog {
    /// `torn_tail` says the existing file ends mid-line (the caller has
    /// already read it for resume): terminate that line so appended
    /// records stay line-separated — the torn record itself is
    /// unparseable either way and gets skipped on the next load.
    fn open(path: &Path, spec: &SweepSpec, torn_tail: bool) -> Result<SweepLog, String> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).map_err(|e| format!("create {}: {e}", parent.display()))?;
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| format!("open {}: {e}", path.display()))?;
        let log = SweepLog { file: Mutex::new(file), error: Mutex::new(None) };
        if torn_tail {
            let mut f = log.file.lock().unwrap();
            if let Err(e) = f.write_all(b"\n").and_then(|()| f.flush()) {
                // A failed repair would glue the next record onto the torn
                // fragment, corrupting both — refuse to start.
                return Err(format!("write {}: {e}", path.display()));
            }
        }
        let empty = log.file.lock().unwrap().metadata().map(|m| m.len() == 0).unwrap_or(false);
        if empty {
            log.append(&meta_record(spec));
        }
        if let Some(e) = log.take_error() {
            return Err(format!("write {}: {e}", path.display()));
        }
        Ok(log)
    }

    /// One record per line, flushed immediately so an interrupted sweep
    /// loses at most the cell being written.
    fn append(&self, record: &Json) {
        let mut f = self.file.lock().unwrap();
        let result = writeln!(f, "{}", record.render()).and_then(|()| f.flush());
        if let Err(e) = result {
            let mut slot = self.error.lock().unwrap();
            if slot.is_none() {
                *slot = Some(e.to_string());
            }
        }
    }

    fn take_error(&self) -> Option<String> {
        self.error.lock().unwrap().take()
    }
}

/// Progress-file schema version stamped into every meta record. Files
/// written before versioning (no `schema_version` key) still load; a
/// file stamped with a *different* version is refused.
pub const SWEEP_SCHEMA_VERSION: u64 = 1;

fn hex_u64(x: u64) -> String {
    format!("0x{x:016x}")
}

fn parse_hex_u64(s: &str) -> Option<u64> {
    u64::from_str_radix(s.strip_prefix("0x")?, 16).ok()
}

fn meta_record(spec: &SweepSpec) -> Json {
    let opt_str = |o: &Option<String>| match o {
        Some(s) => Json::Str(s.clone()),
        None => Json::Null,
    };
    Json::obj()
        .set("type", "meta")
        .set("schema_version", SWEEP_SCHEMA_VERSION as usize)
        .set("tag", spec.tag.as_str())
        .set("seed", hex_u64(spec.seed))
        .set("budget", spec.budget)
        .set("repeat_scale", spec.repeat_scale)
        .set("space", opt_str(&spec.space))
        .set("fault_plan", opt_str(&spec.fault_plan))
        .set(
            "eval_timeout_ms",
            match spec.eval_timeout_ms {
                Some(ms) => Json::Num(ms as f64),
                None => Json::Null,
            },
        )
        .set("max_retries", spec.max_retries as usize)
}

fn cell_record(
    key: &CellKey,
    obj_id: &str,
    base_seed: u64,
    budget: usize,
    probes: u64,
    curve: &[f64],
) -> Json {
    Json::obj()
        .set("type", "cell")
        .set("kernel", key.kernel.as_str())
        .set("gpu", key.gpu.as_str())
        .set("strategy", key.strategy.as_str())
        .set("rep", key.rep)
        .set("objective", obj_id)
        .set("seed", hex_u64(base_seed))
        .set("stream", hex_u64(cell_stream(obj_id, &key.strategy, key.rep)))
        .set("budget", budget)
        // Cumulative constraint-oracle probes the cell's view answered
        // (deterministically 0 for enumerated spaces).
        .set("probes", probes as usize)
        .set("curve", Json::Arr(curve.iter().map(|&v| Json::Num(v)).collect()))
}

/// Record for a crash-isolated cell: same coordinates, no `"curve"` —
/// `load_progress` only resumes records with a parseable curve, so a
/// failed cell is re-attempted by the next `--fresh`-less run.
fn failed_cell_record(
    key: &CellKey,
    obj_id: &str,
    base_seed: u64,
    budget: usize,
    error: &str,
) -> Json {
    Json::obj()
        .set("type", "cell")
        .set("kernel", key.kernel.as_str())
        .set("gpu", key.gpu.as_str())
        .set("strategy", key.strategy.as_str())
        .set("rep", key.rep)
        .set("objective", obj_id)
        .set("seed", hex_u64(base_seed))
        .set("stream", hex_u64(cell_stream(obj_id, &key.strategy, key.rep)))
        .set("budget", budget)
        .set("outcome", "failed")
        .set("error", error)
}

/// Read completed cells back from a progress file's text (`path` is for
/// error messages only — the caller reads the file once). Torn lines from
/// an interrupted writer are skipped (a truncated JSON record cannot
/// parse as a complete one); every intact record is kept. Errors if the
/// file's meta line is incompatible with `spec` — resuming under
/// different seeds/budgets would silently mix incomparable curves.
fn load_progress(text: &str, path: &Path, spec: &SweepSpec) -> Result<BTreeMap<CellKey, Vec<f64>>, String> {
    let mut completed = BTreeMap::new();
    let mut meta_seen = false;
    let mut saw_content = false;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        saw_content = true;
        let Ok(record) = jsonparse::parse(line) else {
            continue; // torn record from an interrupted run
        };
        match record.get("type").and_then(Json::as_str) {
            Some("meta") => {
                // Version-less meta records (pre-versioning sweeps) are
                // legacy-compatible; an explicit mismatch is refused.
                if let Some(v) = record.get("schema_version").and_then(Json::as_f64) {
                    if v as u64 != SWEEP_SCHEMA_VERSION {
                        return Err(format!(
                            "{} was written with sweep schema_version {} but this build \
                             writes {SWEEP_SCHEMA_VERSION}; pass --fresh to discard it",
                            path.display(),
                            v as u64
                        ));
                    }
                }
                let seed = record.get("seed").and_then(Json::as_str).and_then(parse_hex_u64);
                let budget = record.get("budget").and_then(Json::as_f64);
                let scale = record.get("repeat_scale").and_then(Json::as_f64);
                let space = record.get("space").and_then(Json::as_str).map(str::to_string);
                // Fault/resilience keys are absent from pre-fault-layer
                // files; absent parses as the disabled default, so those
                // files stay resumable by a sweep that injects nothing.
                let fault_plan =
                    record.get("fault_plan").and_then(Json::as_str).map(str::to_string);
                let timeout = record
                    .get("eval_timeout_ms")
                    .and_then(Json::as_f64)
                    .map(|ms| ms as u64);
                let retries = record
                    .get("max_retries")
                    .and_then(Json::as_f64)
                    .map(|r| r as u32)
                    .unwrap_or(0);
                if seed != Some(spec.seed)
                    || budget != Some(spec.budget as f64)
                    || scale != Some(spec.repeat_scale)
                    || space != spec.space
                    || fault_plan != spec.fault_plan
                    || timeout != spec.eval_timeout_ms
                    || retries != spec.max_retries
                {
                    return Err(format!(
                        "{} was written by an incompatible sweep (seed/budget/repeat-scale/space/\
                         fault-plan/timeout/retries differ); pass --fresh to discard it",
                        path.display()
                    ));
                }
                meta_seen = true;
            }
            Some("cell") => {
                let (Some(kernel), Some(gpu), Some(strategy), Some(rep)) = (
                    record.get("kernel").and_then(Json::as_str),
                    record.get("gpu").and_then(Json::as_str),
                    record.get("strategy").and_then(Json::as_str),
                    record.get("rep").and_then(Json::as_f64),
                ) else {
                    continue;
                };
                let Some(curve_json) = record.get("curve").and_then(Json::as_arr) else {
                    continue;
                };
                let mut curve = Vec::with_capacity(curve_json.len());
                let mut ok = true;
                for v in curve_json {
                    match v {
                        Json::Num(x) => curve.push(*x),
                        // +∞ (pre-first-valid-observation prefix) has no
                        // JSON number form; the writer emits null.
                        Json::Null => curve.push(f64::INFINITY),
                        _ => {
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok {
                    continue;
                }
                completed.insert(
                    CellKey {
                        kernel: kernel.to_string(),
                        gpu: gpu.to_string(),
                        strategy: strategy.to_string(),
                        rep: rep as usize,
                    },
                    curve,
                );
            }
            _ => {}
        }
    }
    // A non-empty file with no intact meta record has lost the seed/
    // budget guard (e.g. killed while writing the very first line) —
    // resuming its cells could silently mix incomparable sweeps.
    if saw_content && !meta_seen {
        return Err(format!(
            "{} has no intact meta record, so its cells cannot be validated for \
             compatibility; pass --fresh to discard it",
            path.display()
        ));
    }
    Ok(completed)
}

/// Render a caught panic payload (the two shapes `panic!` produces).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".into())
}

/// Execute sessions on the shared pool. Cells present in `completed` are
/// skipped (their stored curves are reused verbatim); every freshly run
/// cell appends a progress record. Each cell body runs under
/// `catch_unwind`: a panicking cell becomes [`CellResult::Failed`] (and a
/// curve-less `"outcome":"failed"` progress record) while every other cell
/// keeps running — the crash stays inside its cell. Returns results in
/// `jobs` order — the deterministic aggregation order — regardless of
/// which worker finished which cell when.
fn run_sessions(
    jobs: &[SessionJob],
    budget: usize,
    base_seed: u64,
    pool: &ShardPool,
    completed: &BTreeMap<CellKey, Vec<f64>>,
    log: Option<&SweepLog>,
    telemetry: bool,
) -> Vec<(CellResult, Vec<String>)> {
    // Nested consumers (the BO engine's auto thread mode) divide the
    // machine by the session workers running above them.
    let _scope = enter_harness_workers(pool.threads());
    let mut slots: Vec<Option<(CellResult, Vec<String>)>> = jobs
        .iter()
        .map(|j| completed.get(&j.key).cloned().map(|c| (CellResult::Done(c), Vec::new())))
        .collect();
    let batch: Vec<Box<dyn FnOnce() + Send + '_>> = slots
        .iter_mut()
        .zip(jobs)
        .filter(|(slot, _)| slot.is_none())
        .map(|(slot, job)| {
            Box::new(move || {
                let tel = if telemetry {
                    Telemetry::recording(DEFAULT_RING_CAPACITY)
                } else {
                    Telemetry::default()
                };
                let run = catch_unwind(AssertUnwindSafe(|| {
                    let mut rng =
                        cell_rng(base_seed, &job.obj_id, &job.key.strategy, job.key.rep);
                    let trace = job.strategy_impl.run_with(
                        job.eval_obj.as_ref(),
                        budget,
                        &mut rng,
                        tel.clone(),
                    );
                    trace.best_curve()
                }));
                *slot = Some(match run {
                    Ok(curve) => {
                        let probes = job.eval_obj.view().probe_count();
                        if tel.enabled() {
                            tel.record(curve.len(), EventKind::Probes { total: probes });
                            if let Some(r) = &job.resilient {
                                tel.record(curve.len(), EventKind::Resilience(r.stats()));
                            }
                        }
                        if let Some(log) = log {
                            let mut rec = cell_record(
                                &job.key, &job.obj_id, base_seed, budget, probes, &curve,
                            );
                            if let (Some(f), Some(r)) = (&job.faulty, &job.resilient) {
                                rec = rec.set(
                                    "faults",
                                    Json::obj()
                                        .set("injected", f.stats().to_json())
                                        .set("resilience", r.stats().to_json()),
                                );
                            }
                            log.append(&rec);
                        }
                        let lines = if tel.enabled() {
                            let key = &job.key;
                            tel.export_lines(|j| {
                                j.set("kernel", key.kernel.as_str())
                                    .set("gpu", key.gpu.as_str())
                                    .set("strategy", key.strategy.as_str())
                                    .set("rep", key.rep)
                            })
                        } else {
                            Vec::new()
                        };
                        (CellResult::Done(curve), lines)
                    }
                    Err(payload) => {
                        let msg = panic_message(payload.as_ref());
                        if let Some(log) = log {
                            log.append(&failed_cell_record(
                                &job.key, &job.obj_id, base_seed, budget, &msg,
                            ));
                        }
                        (CellResult::Failed(msg), Vec::new())
                    }
                });
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.run(batch);
    slots.into_iter().map(|s| s.expect("session produced no result")).collect()
}

/// One schedulable objective: the cell-key coordinates plus what sessions
/// actually evaluate (the table itself, or its cache-wrapped view).
struct ObjEntry {
    kernel: String,
    gpu: String,
    obj_id: String,
    eval: Arc<dyn Objective>,
}

/// Build the repeat-major session list for `objectives × strategies`:
/// repeat 0 of every cell first, then repeat 1, … — expensive strategies'
/// cells spread across the whole batch instead of clustering at the tail.
/// Returns the jobs plus each job's (objective, strategy) indices, in the
/// deterministic order aggregation regroups by.
fn build_session_jobs(
    objectives: &[ObjEntry],
    strategies: &[&str],
    repeat_scale: f64,
) -> (Vec<SessionJob>, Vec<(usize, usize)>) {
    // One resolved implementation per strategy, shared by its cells.
    // Callers validate names first; an unresolved name fails here, on the
    // caller's thread, before any cell has burned compute.
    let impls: Vec<Arc<dyn Strategy>> = strategies
        .iter()
        .map(|s| {
            Arc::from(by_name(s).unwrap_or_else(|| panic!("{}", unknown_strategy_message(s))))
        })
        .collect();
    let reps: Vec<usize> = strategies.iter().map(|s| repeats_for(s, repeat_scale)).collect();
    let max_reps = reps.iter().copied().max().unwrap_or(0);
    let mut jobs = Vec::new();
    let mut coords = Vec::new();
    for rep in 0..max_reps {
        for (oi, entry) in objectives.iter().enumerate() {
            for (si, strategy) in strategies.iter().enumerate() {
                if rep < reps[si] {
                    jobs.push(SessionJob {
                        key: CellKey {
                            kernel: entry.kernel.clone(),
                            gpu: entry.gpu.clone(),
                            strategy: strategy.to_string(),
                            rep,
                        },
                        obj_id: entry.obj_id.clone(),
                        strategy_impl: Arc::clone(&impls[si]),
                        eval_obj: Arc::clone(&entry.eval),
                        faulty: None,
                        resilient: None,
                    });
                    coords.push((oi, si));
                }
            }
        }
    }
    (jobs, coords)
}

/// Orchestrated replacement for the serial strategy-by-strategy
/// comparison: all (strategy, repeat) cells of one objective interleave on
/// the shared pool. Backs [`runner::run_comparison`](crate::harness::runner::run_comparison).
pub fn orchestrate_comparison(
    obj: &Arc<TableObjective>,
    obj_id: &str,
    strategies: &[&str],
    budget: usize,
    repeat_scale: f64,
    base_seed: u64,
    pool: &ShardPool,
) -> Vec<StrategyOutcome> {
    // A bare comparison has no (kernel, gpu) axis; its cell keys reuse the
    // objective id as the kernel coordinate (nothing resumes through them
    // — progress logging is sweep-only).
    let entries = [ObjEntry {
        kernel: obj_id.to_string(),
        gpu: String::new(),
        obj_id: obj_id.to_string(),
        eval: Arc::clone(obj) as Arc<dyn Objective>,
    }];
    let (jobs, coords) = build_session_jobs(&entries, strategies, repeat_scale);
    let results = run_sessions(&jobs, budget, base_seed, pool, &BTreeMap::new(), None, false);

    let global_min = obj.known_minimum().expect("table objective knows its minimum");
    let fallback = fallback_value(obj);
    let mut grouped: Vec<Vec<Vec<f64>>> = strategies.iter().map(|_| Vec::new()).collect();
    for ((_oi, si), (result, _tel)) in coords.into_iter().zip(results) {
        match result {
            // Job order is rep-ascending per strategy.
            CellResult::Done(curve) => grouped[si].push(curve),
            // A bare comparison has no sweep log to isolate failures
            // into — surface the cell's panic as the call's panic, as the
            // pre-isolation path did.
            CellResult::Failed(msg) => panic!("comparison cell failed: {msg}"),
        }
    }
    strategies
        .iter()
        .zip(&grouped)
        .map(|(s, curves)| aggregate_outcome(s, curves, budget, global_min, fallback))
        .collect()
}

/// Step-level orchestration of one objective's comparison matrix: every
/// (strategy, repeat) cell is an owned ask/tell
/// [`Session`](crate::strategies::driver::Session) and all cells
/// advance in lockstep, one drive-loop step per scheduling round — the
/// finest interleaving the stepwise Strategy API allows (whole-run
/// interleaving is [`orchestrate_comparison`]). Because each session owns
/// its driver, budget, and RNG stream, the interleaving cannot perturb
/// any cell's trace: outcomes are bit-identical to the whole-run path
/// (asserted below), while a scheduler gains per-step control — progress
/// reporting, fair sharing, and mid-cell checkpoint/resume via
/// [`checkpoint`](crate::strategies::driver::Session::checkpoint) /
/// [`resume`](crate::strategies::driver::Session::resume). The serve
/// daemon ([`crate::serve`]) multiplexes the same owned sessions in
/// external-evaluation mode.
pub fn orchestrate_comparison_stepwise(
    obj: &Arc<TableObjective>,
    obj_id: &str,
    strategies: &[&str],
    budget: usize,
    repeat_scale: f64,
    base_seed: u64,
) -> Vec<StrategyOutcome> {
    use crate::strategies::driver::{interleave, FevalBudget, Session};

    let reps: Vec<usize> = strategies.iter().map(|s| repeats_for(s, repeat_scale)).collect();
    let max_reps = reps.iter().copied().max().unwrap_or(0);
    let objective: Arc<dyn Objective> = Arc::clone(obj) as Arc<dyn Objective>;
    // Every cell's driver is built (and held) up front — a BO cell owns
    // its surrogate state for the whole interleave. Register
    // full-machine harness workers so auto-threaded drivers size their
    // nested shard pools to ~1 thread instead of each spawning a
    // core-count pool (results are thread-count-independent either way).
    let _nested = enter_harness_workers(crate::util::pool::default_threads());
    // Resolve every strategy before building any session state.
    let impls: Vec<Box<dyn Strategy>> = strategies
        .iter()
        .map(|s| by_name(s).unwrap_or_else(|| panic!("{}", unknown_strategy_message(s))))
        .collect();
    let mut sessions: Vec<Session> = Vec::new();
    let mut coords: Vec<usize> = Vec::new();
    // Repeat-major, mirroring build_session_jobs' deterministic order.
    for rep in 0..max_reps {
        for (si, strategy) in strategies.iter().enumerate() {
            if rep < reps[si] {
                let s = &impls[si];
                sessions.push(Session::new(
                    s.driver(obj.space()),
                    Arc::clone(&objective),
                    Box::new(FevalBudget::new(budget)),
                    cell_rng(base_seed, obj_id, strategy, rep),
                ));
                coords.push(si);
            }
        }
    }
    let traces = interleave(&mut sessions);

    let global_min = obj.known_minimum().expect("table objective knows its minimum");
    let fallback = fallback_value(obj);
    let mut grouped: Vec<Vec<Vec<f64>>> = strategies.iter().map(|_| Vec::new()).collect();
    for (si, trace) in coords.into_iter().zip(traces) {
        grouped[si].push(trace.best_curve());
    }
    strategies
        .iter()
        .zip(&grouped)
        .map(|(s, curves)| aggregate_outcome(s, curves, budget, global_min, fallback))
        .collect()
}

/// Run the full (kernels × gpus × strategies × repeats) matrix: build the
/// objectives, schedule every cell on one shared pool, persist/resume
/// through `SWEEP_<tag>.jsonl`, and aggregate per (kernel, gpu) exactly as
/// the serial path would.
pub fn sweep(spec: &SweepSpec) -> Result<SweepReport, String> {
    // Validate the whole matrix before doing any work. Kernel and GPU
    // names are canonicalized through their registries and the axes
    // deduplicated: seeds, cell keys, and JSONL records must not depend
    // on which alias the CLI used, and a repeated entry must not run (or
    // be reported) twice.
    let mut kernels: Vec<&'static str> = Vec::new();
    for k in &spec.kernels {
        let canon = kernel_by_name(k).map(|m| m.name()).ok_or_else(|| format!("unknown kernel '{k}'"))?;
        if !kernels.contains(&canon) {
            kernels.push(canon); // aliases dedup to one cell set
        }
    }
    let mut devices: Vec<Device> = Vec::new();
    for g in &spec.gpus {
        let dev = Device::by_name(g).ok_or_else(|| format!("unknown GPU '{g}'"))?;
        if !devices.iter().any(|d| d.name == dev.name) {
            devices.push(dev);
        }
    }
    let mut strategies: Vec<String> = Vec::new();
    for s in &spec.strategies {
        // Strategy::name() maps alias spellings (sa, ga, skopt, de) to
        // the canonical registry name, like the kernel/GPU axes above.
        // Fail fast with the full registry listing — an unknown
        // `--strategies` entry must not require a source dig to resolve.
        let canon = by_name(s).ok_or_else(|| unknown_strategy_message(s))?.name();
        if !strategies.contains(&canon) {
            strategies.push(canon);
        }
    }
    if kernels.is_empty() || devices.is_empty() || strategies.is_empty() {
        return Err("empty sweep matrix (no kernels, gpus, or strategies)".into());
    }
    // A space file replaces exactly one kernel's built-in space: its
    // parameter names are the contract with that kernel's model.
    let space_spec = match &spec.space {
        Some(path) => {
            if kernels.len() != 1 {
                return Err(format!(
                    "--space requires exactly one kernel in the matrix, got {:?}",
                    kernels
                ));
            }
            Some(
                crate::space::SpaceSpec::load(Path::new(path))
                    .map_err(|e| format!("space file {path}: {e}"))?,
            )
        }
        None => None,
    };
    // Fault injection: load the committed plan and canonicalize the
    // faulted-strategy subset before any cell runs, so a typo fails the
    // sweep up front instead of mid-matrix.
    let fault_plan = match &spec.fault_plan {
        Some(path) => {
            // Plans are committed repo-root-relative; fall back to the
            // parent directory so `cargo test` (cwd rust/) finds them too.
            let p = Path::new(path);
            let resolved = if p.exists() { p.to_path_buf() } else { Path::new("..").join(p) };
            Some(FaultPlan::load(&resolved).map_err(|e| format!("fault plan {path}: {e}"))?)
        }
        None => None,
    };
    if fault_plan.is_none() && !spec.fault_strategies.is_empty() {
        return Err("fault_strategies set without a fault_plan".into());
    }
    let mut fault_strategies: Vec<String> = Vec::new();
    for s in &spec.fault_strategies {
        let canon = by_name(s).ok_or_else(|| unknown_strategy_message(s))?.name();
        if !strategies.contains(&canon) {
            return Err(format!("fault strategy '{canon}' is not in the sweep matrix"));
        }
        if !fault_strategies.contains(&canon) {
            fault_strategies.push(canon);
        }
    }
    if fault_plan.is_some() && fault_strategies.is_empty() {
        // An empty subset under a plan faults the whole matrix.
        fault_strategies = strategies.clone();
    }
    std::fs::create_dir_all(&spec.out_dir).map_err(|e| format!("create {}: {e}", spec.out_dir))?;

    let wall_clock = MonotonicClock::new();
    let t0_ns = wall_clock.now_ns();

    // One objective per (kernel, gpu); sessions share it through an Arc,
    // optionally behind the cross-session eval cache. `tables` keeps the
    // unwrapped objectives for aggregation metadata (minimum, fallback).
    let cache = Arc::new(EvalCache::new());
    let mut objectives: Vec<ObjEntry> = Vec::new();
    let mut tables: Vec<Arc<TableObjective>> = Vec::new();
    for dev in &devices {
        for kernel in &kernels {
            let (table, obj_id) = match &space_spec {
                Some(sp) => {
                    let k = kernel_by_name(kernel).expect("validated above");
                    let sim =
                        crate::gpusim::SimulatedSpace::build_with_space(k.as_ref(), dev, sp.build());
                    // The file-defined space is a different objective:
                    // its id carries the space name so seeds, cache keys,
                    // and sweep records never mix with the built-in space.
                    let obj_id = format!("{}#space:{}", objective_id(kernel, dev.name), sp.name);
                    (Arc::new(TableObjective::from_sim(sim)), obj_id)
                }
                None => (objective_for(kernel, dev), objective_id(kernel, dev.name)),
            };
            let eval: Arc<dyn Objective> = if spec.cache {
                Arc::new(CachedObjective::new(
                    Arc::clone(&table) as Arc<dyn Objective>,
                    Arc::clone(&cache),
                    &obj_id,
                ))
            } else {
                Arc::clone(&table) as Arc<dyn Objective>
            };
            objectives.push(ObjEntry {
                kernel: kernel.to_string(),
                gpu: dev.name.to_string(),
                obj_id,
                eval,
            });
            tables.push(table);
        }
    }

    // Flatten the matrix, repeat-major, so the pool interleaves cells of
    // every objective and strategy from the start.
    let strategy_refs: Vec<&str> = strategies.iter().map(String::as_str).collect();
    let (mut jobs, coords) = build_session_jobs(&objectives, &strategy_refs, spec.repeat_scale);

    // Resilience applies to every cell; faulted cells add quarantine so
    // injected persistent offenders stop burning retries. `sleep: false`
    // keeps backoff accounting deterministic without stalling the pool.
    let base_cfg = ResilienceConfig {
        deadline: spec.eval_timeout_ms.map(Duration::from_millis),
        max_retries: spec.max_retries,
        sleep: false,
        ..ResilienceConfig::default()
    };
    if let Some(plan) = &fault_plan {
        let faulted_cfg = ResilienceConfig { quarantine_after: 3, ..base_cfg.clone() };
        for (job, (oi, _si)) in jobs.iter_mut().zip(&coords) {
            if !fault_strategies.contains(&job.key.strategy) {
                continue;
            }
            // Each cell re-seeds the plan with its own stream so fault
            // patterns are independent per cell yet invariant to thread
            // count and resume order.
            let cell_plan = plan
                .with_seed(plan.seed ^ cell_stream(&job.obj_id, &job.key.strategy, job.key.rep));
            // Faults wrap the raw table — outside the shared eval cache —
            // so injected failures never leak into other cells.
            let faulty = Arc::new(FaultyObjective::new(
                Arc::clone(&tables[*oi]) as Arc<dyn Objective>,
                cell_plan,
            ));
            let resilient = Arc::new(ResilientEvaluator::new(
                Arc::clone(&faulty) as Arc<dyn Objective>,
                faulted_cfg.clone(),
            ));
            job.eval_obj = Arc::clone(&resilient) as Arc<dyn Objective>;
            job.faulty = Some(faulty);
            job.resilient = Some(resilient);
        }
    }
    if !base_cfg.is_passthrough() {
        for job in jobs.iter_mut() {
            if job.resilient.is_some() {
                continue; // faulted cells already carry their wrapper
            }
            let resilient =
                Arc::new(ResilientEvaluator::new(Arc::clone(&job.eval_obj), base_cfg.clone()));
            job.eval_obj = Arc::clone(&resilient) as Arc<dyn Objective>;
        }
    }

    // Resume: reuse completed cells from an existing progress file (read
    // once; its trailing-newline state feeds the log's torn-tail repair).
    let progress_path = spec.progress_path();
    if spec.fresh && progress_path.exists() {
        std::fs::remove_file(&progress_path)
            .map_err(|e| format!("remove {}: {e}", progress_path.display()))?;
    }
    let (completed, torn_tail) = if progress_path.exists() {
        let text = std::fs::read_to_string(&progress_path)
            .map_err(|e| format!("read {}: {e}", progress_path.display()))?;
        let torn = !text.is_empty() && !text.ends_with('\n');
        (load_progress(&text, &progress_path, spec)?, torn)
    } else {
        (BTreeMap::new(), false)
    };
    let log = SweepLog::open(&progress_path, spec, torn_tail)?;

    let resumed_cells = jobs.iter().filter(|j| completed.contains_key(&j.key)).count();
    let total_cells = jobs.len();

    let pool = ShardPool::new(spec.threads);
    let results = run_sessions(
        &jobs,
        spec.budget,
        spec.seed,
        &pool,
        &completed,
        Some(&log),
        spec.telemetry,
    );
    if let Some(e) = log.take_error() {
        // The cells ran, but the resume log lost records (disk full,
        // unwritable dir): reporting success would let a later resume
        // silently re-run or mix cells. Intact records remain usable.
        return Err(format!(
            "progress log {} lost records mid-sweep ({e}); rerun to resume from the intact prefix",
            progress_path.display()
        ));
    }

    // Aggregate in fixed (objective, strategy, repeat) order. Failed
    // cells (crash-isolated panics) are listed, not aggregated — their
    // records carry no curve, so a later resume re-attempts exactly them.
    let mut grouped: Vec<Vec<Vec<Vec<f64>>>> = objectives
        .iter()
        .map(|_| strategies.iter().map(|_| Vec::new()).collect())
        .collect();
    let mut failed_cells: Vec<(CellKey, String)> = Vec::new();
    let mut tel_lines: Vec<String> = Vec::new();
    for (((oi, si), (result, cell_tel)), job) in coords.into_iter().zip(results).zip(&jobs) {
        tel_lines.extend(cell_tel);
        match result {
            CellResult::Done(curve) => grouped[oi][si].push(curve),
            CellResult::Failed(msg) => failed_cells.push((job.key.clone(), msg)),
        }
    }
    metrics::global().counter(
        "sweep.cells.completed",
        (total_cells - resumed_cells - failed_cells.len()) as u64,
    );
    metrics::global().counter("sweep.cells.failed", failed_cells.len() as u64);

    // Telemetry export: meta line plus every cell's tagged events, in
    // deterministic jobs order (rewritten whole each run — events from
    // cells resumed out of the progress file were never re-captured).
    if spec.telemetry {
        let tel_path = spec.telemetry_path();
        let mut text = crate::telemetry::meta_record().render();
        text.push('\n');
        for line in &tel_lines {
            text.push_str(line);
            text.push('\n');
        }
        std::fs::write(&tel_path, &text)
            .map_err(|e| format!("write {}: {e}", tel_path.display()))?;
    }
    let outcomes: Vec<((String, String), Vec<StrategyOutcome>)> = objectives
        .iter()
        .enumerate()
        .map(|(oi, entry)| {
            let global_min = tables[oi].known_minimum().expect("table objective knows its minimum");
            let fallback = fallback_value(&tables[oi]);
            let per_strategy: Vec<StrategyOutcome> = strategies
                .iter()
                .enumerate()
                .map(|(si, s)| {
                    aggregate_outcome(s, &grouped[oi][si], spec.budget, global_min, fallback)
                })
                .collect();
            ((entry.kernel.clone(), entry.gpu.clone()), per_strategy)
        })
        .collect();

    let cache_stats = cache.stats();
    let (cache_hits, cache_misses) = (cache_stats.hits, cache_stats.misses);
    let wall_s = wall_clock.seconds_since(t0_ns);

    // Machine-readable aggregates (rewritten whole each run).
    let results_path = spec.results_path();
    let mut results = meta_record(spec).render();
    results.push('\n');
    for ((kernel, gpu), outs) in &outcomes {
        for o in outs {
            let record = Json::obj()
                .set("type", "outcome")
                .set("kernel", kernel.as_str())
                .set("gpu", gpu.as_str())
                .set("strategy", o.name.as_str())
                .set("repeats", o.maes.len())
                .set("mae_mean", o.mae.mean)
                .set("mae_std", o.mae.std)
                .set(
                    "final_best_mean",
                    crate::util::linalg::mean(&o.finals),
                )
                .set("mean_curve", Json::Arr(o.mean_curve.iter().map(|&v| Json::Num(v)).collect()));
            results.push_str(&record.render());
            results.push('\n');
        }
    }
    std::fs::write(&results_path, &results)
        .map_err(|e| format!("write {}: {e}", results_path.display()))?;

    // Human-readable digest.
    let mut summary = format!(
        "### sweep '{}': {} kernel(s) × {} GPU(s) × {} strategie(s), budget {}, repeat-scale {}\n",
        spec.tag,
        kernels.len(),
        devices.len(),
        strategies.len(),
        spec.budget,
        spec.repeat_scale
    );
    let _ = writeln!(
        summary,
        "cells: {total_cells} total, {resumed_cells} resumed, {} ran | threads {} | wall {wall_s:.2}s",
        total_cells - resumed_cells,
        spec.threads
    );
    if let Some(path) = &spec.fault_plan {
        let _ = writeln!(
            summary,
            "fault injection: plan {path} on [{}] | timeout {:?} | retries {}",
            fault_strategies.join(", "),
            spec.eval_timeout_ms,
            spec.max_retries
        );
    }
    if !failed_cells.is_empty() {
        let _ = writeln!(summary, "failed cells ({}): will re-run on resume", failed_cells.len());
        for (key, msg) in &failed_cells {
            let _ = writeln!(
                summary,
                "  {}/{}/{} rep {}: {msg}",
                key.kernel, key.gpu, key.strategy, key.rep
            );
        }
    }
    let _ = writeln!(
        summary,
        "eval cache: {}",
        if spec.cache {
            format!(
                "{cache_hits} hits / {cache_misses} misses / {} evictions",
                cache_stats.evictions
            )
        } else {
            "disabled".to_string()
        }
    );
    if spec.cache {
        for (obj_id, s) in cache.objective_stats() {
            let _ = writeln!(
                summary,
                "  {obj_id}: {} hits / {} misses / {} evictions",
                s.hits, s.misses, s.evictions
            );
        }
    }
    for ((kernel, gpu), outs) in &outcomes {
        let _ = writeln!(summary, "{kernel} @ {gpu}:");
        for o in outs {
            let _ = writeln!(
                summary,
                "  {:<22} reps {:>3}  MAE {:.4} ±{:.4}  final {:.4}",
                o.name,
                o.maes.len(),
                o.mae.mean,
                o.mae.std,
                crate::util::linalg::mean(&o.finals)
            );
        }
    }
    let _ = writeln!(summary, "progress: {}", progress_path.display());
    let _ = writeln!(summary, "results:  {}", results_path.display());
    if spec.telemetry {
        let _ = writeln!(
            summary,
            "telemetry: {} ({} events; render with `ktbo report`)",
            spec.telemetry_path().display(),
            tel_lines.len()
        );
    }

    Ok(SweepReport {
        outcomes,
        total_cells,
        resumed_cells,
        ran_cells: total_cells - resumed_cells,
        failed_cells,
        cache_hits,
        cache_misses,
        wall_s,
        summary,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::runner::run_strategy;

    fn temp_out(dir: &str) -> String {
        std::env::temp_dir().join(dir).to_string_lossy().into_owned()
    }

    /// 2 strategies × 3 repeats on the cheapest (kernel, GPU) pair.
    fn small_spec(dir: &str, tag: &str) -> SweepSpec {
        SweepSpec {
            kernels: vec!["adding".into()],
            gpus: vec!["a100".into()],
            strategies: vec!["random".into(), "mls".into()],
            budget: 40,
            repeat_scale: 0.03,
            seed: 11,
            threads: 2,
            out_dir: temp_out(dir),
            tag: tag.into(),
            cache: true,
            fresh: true,
            space: None,
            fault_plan: None,
            fault_strategies: vec![],
            eval_timeout_ms: None,
            max_retries: 0,
            telemetry: false,
        }
    }

    /// Write a fault plan to a temp file and return its path.
    fn write_plan(dir: &str, name: &str, plan: &FaultPlan) -> String {
        let d = temp_out(dir);
        std::fs::create_dir_all(&d).unwrap();
        let path = format!("{d}/{name}");
        std::fs::write(&path, format!("{}\n", plan.to_json().render())).unwrap();
        path
    }

    /// Acceptance: `sweep --space examples/spaces/<kernel>.json` runs end
    /// to end, and the file-defined twin restricts to the same size as
    /// the hand-coded space.
    #[test]
    fn sweep_runs_on_a_json_space_file() {
        let path = format!("{}/../examples/spaces/adding.json", env!("CARGO_MANIFEST_DIR"));
        let spec_json = crate::space::SpaceSpec::load(std::path::Path::new(&path)).unwrap();
        let dev = Device::a100();
        let hand_coded = kernel_by_name("adding").unwrap().spec(&dev).build();
        assert_eq!(
            spec_json.build().len(),
            hand_coded.len(),
            "JSON twin must restrict to the hand-coded size"
        );

        let mut spec = small_spec("ktbo-orch-space", "space-file");
        spec.strategies = vec!["random".into()];
        spec.budget = 20;
        spec.space = Some(path);
        let report = sweep(&spec).unwrap();
        assert!(report.ran_cells > 0);
        assert_eq!(report.outcomes.len(), 1);
        for o in &report.outcomes[0].1 {
            assert_eq!(o.mean_curve.len(), 20);
            assert!(o.mean_curve.iter().all(|v| v.is_finite()));
        }

        // Resume guard: the same tag without --space must be refused.
        let mut mixed = spec.clone();
        mixed.fresh = false;
        mixed.space = None;
        let err = sweep(&mixed).unwrap_err();
        assert!(err.contains("--fresh"), "unexpected error: {err}");

        // Multi-kernel matrices cannot take a single space file.
        let mut multi = spec.clone();
        multi.kernels = vec!["adding".into(), "gemm".into()];
        multi.tag = "space-multi".into();
        assert!(sweep(&multi).unwrap_err().contains("exactly one kernel"));
    }

    #[test]
    fn sweep_matches_serial_reference_across_worker_counts() {
        // The acceptance invariant: orchestrated curves are bit-identical
        // to the serial reference path at every thread count, with the
        // cache on or off.
        let dev = Device::a100();
        let obj = objective_for("adding", &dev);
        let oid = objective_id("adding", dev.name);
        let serial: Vec<StrategyOutcome> = ["random", "mls"]
            .iter()
            .map(|s| run_strategy(&obj, &oid, s, 40, 3, 11, 1))
            .collect();

        for (threads, cache) in [(1, true), (2, true), (8, true), (2, false)] {
            let mut spec = small_spec("ktbo-orch-eq", &format!("eq-{threads}-{cache}"));
            spec.threads = threads;
            spec.cache = cache;
            let report = sweep(&spec).unwrap();
            assert_eq!(report.total_cells, 6);
            assert_eq!(report.ran_cells, 6);
            let outs = &report.outcomes[0].1;
            for (o, s) in outs.iter().zip(&serial) {
                assert_eq!(o.name, s.name);
                assert_eq!(
                    o.mean_curve, s.mean_curve,
                    "threads={threads} cache={cache}: curves must be bit-identical"
                );
                assert_eq!(o.maes, s.maes, "threads={threads} cache={cache}");
                assert_eq!(o.finals, s.finals, "threads={threads} cache={cache}");
            }
        }
    }

    #[test]
    fn orchestrated_comparison_equals_per_strategy_runs() {
        let dev = Device::a100();
        let obj = objective_for("adding", &dev);
        let oid = objective_id("adding", dev.name);
        let pool = ShardPool::new(4);
        let outs = orchestrate_comparison(&obj, &oid, &["random", "mls"], 40, 0.03, 5, &pool);
        for o in &outs {
            let reference = run_strategy(&obj, &oid, &o.name, 40, o.maes.len(), 5, 1);
            assert_eq!(o.mean_curve, reference.mean_curve, "{}", o.name);
            assert_eq!(o.maes, reference.maes, "{}", o.name);
        }
    }

    #[test]
    fn stepwise_interleaving_is_bit_identical_to_whole_run_cells() {
        // Step-level interleaving (the finest the ask/tell API allows)
        // must reproduce the whole-run reference path exactly — including
        // a BO strategy whose driver holds GP/pool state across steps.
        let dev = Device::a100();
        let obj = objective_for("adding", &dev);
        let oid = objective_id("adding", dev.name);
        let strategies = ["random", "mls", "ei"];
        let stepwise = orchestrate_comparison_stepwise(&obj, &oid, &strategies, 40, 0.03, 11);
        for o in &stepwise {
            let reference = run_strategy(&obj, &oid, &o.name, 40, o.maes.len(), 11, 1);
            assert_eq!(o.mean_curve, reference.mean_curve, "{}", o.name);
            assert_eq!(o.maes, reference.maes, "{}", o.name);
            assert_eq!(o.finals, reference.finals, "{}", o.name);
        }
    }

    #[test]
    fn mid_cell_checkpoint_resume_is_bit_identical() {
        // Interrupt a cell mid-run, snapshot its trace, rebuild the
        // session from the snapshot, finish it — the final trace must be
        // bit-identical to the uninterrupted run. Covers a batch driver
        // (mls) and the stateful BO driver (ei).
        use crate::strategies::driver::{FevalBudget, Session};
        let dev = Device::a100();
        let obj = objective_for("adding", &dev);
        let objective: Arc<dyn Objective> = Arc::clone(&obj) as Arc<dyn Objective>;
        let oid = objective_id("adding", dev.name);
        for strategy in ["mls", "ei"] {
            let s = by_name(strategy).unwrap();
            let budget = 45usize;
            let make_rng = || cell_rng(7, &oid, strategy, 0);

            let full = {
                let mut sess = Session::new(
                    s.driver(obj.space()),
                    Arc::clone(&objective),
                    Box::new(FevalBudget::new(budget)),
                    make_rng(),
                );
                while sess.step() {}
                sess.into_trace()
            };

            for interrupt_after in [9usize, 30] {
                let mut first = Session::new(
                    s.driver(obj.space()),
                    Arc::clone(&objective),
                    Box::new(FevalBudget::new(budget)),
                    make_rng(),
                );
                for _ in 0..interrupt_after {
                    if !first.step() {
                        break;
                    }
                }
                let ckpt = first.checkpoint();
                assert!(ckpt.len() < full.len(), "{strategy}: interrupt landed past the end");
                let mut resumed = Session::resume(
                    s.driver(obj.space()),
                    Arc::clone(&objective),
                    Box::new(FevalBudget::new(budget)),
                    make_rng(),
                    ckpt,
                );
                while resumed.step() {}
                assert_eq!(
                    resumed.trace().records,
                    full.records,
                    "{strategy}: resume after {interrupt_after} steps diverged"
                );
            }
        }
    }

    #[test]
    fn resume_skips_exactly_the_completed_cells() {
        let spec = small_spec("ktbo-orch-resume", "resume");
        let first = sweep(&spec).unwrap();
        assert_eq!((first.total_cells, first.resumed_cells, first.ran_cells), (6, 0, 6));

        // Keep the meta line and the first two completed cells, then add a
        // torn partial record as an interrupted writer would leave behind.
        let path = spec.progress_path();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 7, "meta + 6 cells");
        let mut kept = lines[..3].join("\n");
        kept.push_str("\n{\"type\":\"cel");
        std::fs::write(&path, kept).unwrap();

        let mut resumed_spec = spec.clone();
        resumed_spec.fresh = false;
        let second = sweep(&resumed_spec).unwrap();
        assert_eq!(second.resumed_cells, 2, "exactly the two intact records resume");
        assert_eq!(second.ran_cells, 4);
        for (a, b) in first.outcomes[0].1.iter().zip(&second.outcomes[0].1) {
            assert_eq!(a.mean_curve, b.mean_curve, "resume must not change aggregates");
            assert_eq!(a.maes, b.maes);
        }
        // A third run resumes everything.
        let third = sweep(&resumed_spec).unwrap();
        assert_eq!((third.resumed_cells, third.ran_cells), (6, 0));
        assert_eq!(third.outcomes[0].1[0].mean_curve, first.outcomes[0].1[0].mean_curve);
    }

    #[test]
    fn incompatible_progress_file_is_rejected() {
        let spec = small_spec("ktbo-orch-meta", "meta");
        sweep(&spec).unwrap();
        let mut other = spec.clone();
        other.fresh = false;
        other.seed = 12;
        let err = sweep(&other).unwrap_err();
        assert!(err.contains("--fresh"), "unexpected error: {err}");
        // --fresh discards and reruns.
        other.fresh = true;
        assert_eq!(sweep(&other).unwrap().ran_cells, 6);

        // A file whose meta record was torn away entirely cannot be
        // validated — resuming it must be refused, not silently accepted.
        std::fs::write(spec.progress_path(), "{\"type\":\"cel").unwrap();
        let mut no_meta = spec.clone();
        no_meta.fresh = false;
        let err = sweep(&no_meta).unwrap_err();
        assert!(err.contains("meta"), "unexpected error: {err}");
    }

    #[test]
    fn mismatched_progress_schema_version_is_refused_and_legacy_accepted() {
        let spec = small_spec("ktbo-orch-schema", "schema");
        sweep(&spec).unwrap();
        let path = spec.progress_path();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            text.lines().next().unwrap().contains("\"schema_version\""),
            "meta record must carry a schema version"
        );

        // A future schema version must be refused with a clear message.
        let bumped = text.replacen(
            &format!("\"schema_version\":{SWEEP_SCHEMA_VERSION}"),
            "\"schema_version\":99",
            1,
        );
        assert_ne!(bumped, text, "replacen must have found the version field");
        std::fs::write(&path, &bumped).unwrap();
        let mut resume = spec.clone();
        resume.fresh = false;
        let err = sweep(&resume).unwrap_err();
        assert!(err.contains("schema_version 99"), "unexpected error: {err}");
        assert!(err.contains("--fresh"), "must tell the user the way out: {err}");

        // A version-less legacy meta line still resumes cleanly.
        let legacy = text.replacen(&format!("\"schema_version\":{SWEEP_SCHEMA_VERSION},"), "", 1);
        assert_ne!(legacy, text);
        std::fs::write(&path, &legacy).unwrap();
        let report = sweep(&resume).unwrap();
        assert_eq!((report.resumed_cells, report.ran_cells), (6, 0));
    }

    #[test]
    fn kernel_aliases_canonicalize_in_keys_and_seeds() {
        // `conv` and `convolution` must be the same cell: same canonical
        // key in records, same seeds, bit-identical curves.
        let mut spec = small_spec("ktbo-orch-alias", "alias");
        spec.kernels = vec!["conv".into()];
        spec.strategies = vec!["random".into()];
        spec.budget = 20;
        let report = sweep(&spec).unwrap();
        let (kernel, _gpu) = &report.outcomes[0].0;
        assert_eq!(kernel, "convolution");

        let mut canon = spec.clone();
        canon.kernels = vec!["convolution".into()];
        canon.tag = "alias-canon".into();
        let canon_report = sweep(&canon).unwrap();
        assert_eq!(
            report.outcomes[0].1[0].mean_curve, canon_report.outcomes[0].1[0].mean_curve,
            "alias spelling must not change cell seeds"
        );

        // Alias + canonical spellings collapse to one cell set on every
        // axis instead of running and reporting twice; strategy aliases
        // canonicalize through Strategy::name().
        let mut dup = spec.clone();
        dup.kernels = vec!["conv".into(), "convolution".into()];
        dup.strategies = vec!["sa".into(), "simulated_annealing".into()];
        dup.tag = "alias-dup".into();
        let dup_report = sweep(&dup).unwrap();
        assert_eq!(dup_report.outcomes.len(), 1, "duplicate kernels must not double-report");
        assert_eq!(dup_report.outcomes[0].1.len(), 1, "duplicate strategies must not double-run");
        assert_eq!(dup_report.outcomes[0].1[0].name, "simulated_annealing");
        assert_eq!(dup_report.total_cells, report.total_cells);
    }

    #[test]
    fn unknown_matrix_entries_error_before_running() {
        let mut spec = small_spec("ktbo-orch-bad", "bad");
        spec.strategies = vec!["warp_drive".into()];
        let err = sweep(&spec).unwrap_err();
        assert!(err.contains("warp_drive"));
        // The fail-fast satellite: the error lists the registry, so the
        // CLI user never needs a source dig (covers `ktbo sweep
        // --strategies` end to end; `ktbo tune` shares the same message).
        for known in ["advanced_multi", "bo_rf", "tpe", "random"] {
            assert!(err.contains(known), "error must list '{known}': {err}");
        }
        let mut spec = small_spec("ktbo-orch-bad", "bad2");
        spec.gpus = vec!["h100".into()];
        assert!(sweep(&spec).unwrap_err().contains("h100"));
    }

    /// Determinism of the surrogate zoo through the *orchestrated* path:
    /// bo_rf/bo_et/tpe cells swept on 1/2/8 workers must be bit-identical
    /// to the serial per-strategy reference (the satellite acceptance
    /// criterion at the sweep level; engine-level shard/thread sweeps
    /// live in surrogate::tests).
    #[test]
    fn surrogate_sweep_cells_bit_identical_across_worker_counts() {
        let dev = Device::a100();
        let obj = objective_for("adding", &dev);
        let oid = objective_id("adding", dev.name);
        let strategies = ["bo_rf", "bo_et", "tpe"];
        let serial: Vec<StrategyOutcome> =
            strategies.iter().map(|s| run_strategy(&obj, &oid, s, 30, 3, 11, 1)).collect();
        for threads in [1usize, 2, 8] {
            let mut spec = small_spec("ktbo-orch-sur", &format!("sur-{threads}"));
            spec.strategies = strategies.iter().map(|s| s.to_string()).collect();
            spec.budget = 30;
            spec.threads = threads;
            let report = sweep(&spec).unwrap();
            assert_eq!(report.total_cells, 9);
            for (o, s) in report.outcomes[0].1.iter().zip(&serial) {
                assert_eq!(o.name, s.name);
                assert_eq!(
                    o.mean_curve, s.mean_curve,
                    "{} diverged at {threads} workers",
                    o.name
                );
                assert_eq!(o.maes, s.maes, "{} MAEs diverged at {threads} workers", o.name);
            }
        }
    }

    /// Satellite: the machine-readable sweep artifact is byte-stable.
    /// Two fresh runs of the same spec into different out dirs, on
    /// parallel workers, must write identical `results.jsonl` bytes, and
    /// the human digest may differ only in wall time and output paths.
    /// The BTreeMap-ordered trace path makes this a guarantee rather
    /// than a scheduling coincidence.
    #[test]
    fn sweep_results_are_byte_identical_across_runs() {
        let mut texts = Vec::new();
        let mut summaries = Vec::new();
        for run in ["run-a", "run-b"] {
            let mut spec = small_spec(&format!("ktbo-orch-bytes-{run}"), "bytes");
            spec.threads = 2;
            // Cache hit/miss tallies depend on worker interleaving, so the
            // digest's cache lines are the one legitimately racy section;
            // disable them to pin everything else exactly.
            spec.cache = false;
            let report = sweep(&spec).unwrap();
            texts.push(std::fs::read_to_string(spec.results_path()).unwrap());
            summaries.push(report.summary);
        }
        assert_eq!(texts[0], texts[1], "results.jsonl must be byte-identical across runs");
        // Drop the two path lines, truncate the wall-time suffix; every
        // remaining byte must match.
        let stable = |s: &str| {
            s.lines()
                .filter(|l| !l.starts_with("progress:") && !l.starts_with("results:"))
                .map(|l| l.split(" | wall ").next().unwrap())
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(
            stable(&summaries[0]),
            stable(&summaries[1]),
            "summary differs beyond wall time and paths"
        );
        assert!(summaries[0].contains(" | wall "), "wall-time marker moved; update the filter");
    }

    #[test]
    fn infinity_round_trips_through_progress_records() {
        let key = CellKey {
            kernel: "k".into(),
            gpu: "g".into(),
            strategy: "s".into(),
            rep: 0,
        };
        let curve = vec![f64::INFINITY, f64::INFINITY, 3.25, 1.0 / 3.0];
        let line = cell_record(&key, "k@g", 7, 4, 0, &curve).render();
        let parsed = jsonparse::parse(&line).unwrap();
        let back: Vec<f64> = parsed
            .get("curve")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| match v {
                Json::Null => f64::INFINITY,
                other => other.as_f64().unwrap(),
            })
            .collect();
        assert_eq!(back.len(), 4);
        assert!(back[0].is_infinite() && back[1].is_infinite());
        assert_eq!(back[2].to_bits(), curve[2].to_bits());
        assert_eq!(back[3].to_bits(), curve[3].to_bits(), "shortest-repr floats round-trip exactly");
    }

    /// Satellite regression: the cell-record byte layout is a
    /// resume-compat surface. `"probes"` is the only field the telemetry
    /// work added, and it sits between `"budget"` and `"curve"`; every
    /// other byte must match the pre-telemetry layout exactly.
    #[test]
    fn cell_record_field_layout_is_pinned() {
        let key = CellKey { kernel: "k".into(), gpu: "g".into(), strategy: "s".into(), rep: 2 };
        let line = cell_record(&key, "k@g", 7, 4, 0, &[1.0, 0.5]).render();
        let expected = format!(
            "{{\"type\":\"cell\",\"kernel\":\"k\",\"gpu\":\"g\",\"strategy\":\"s\",\
             \"rep\":2,\"objective\":\"k@g\",\"seed\":\"{}\",\"stream\":\"{}\",\
             \"budget\":4,\"probes\":0,\"curve\":[1,0.5]}}",
            hex_u64(7),
            hex_u64(cell_stream("k@g", "s", 2)),
        );
        assert_eq!(line, expected, "cell-record byte layout drifted");
    }

    /// Tentpole acceptance: telemetry is strictly observational at the
    /// sweep level too — `results.jsonl` and every progress record are
    /// byte-identical with recording on or off, the telemetry export is
    /// non-empty and schema-versioned, and `ktbo report` renders it.
    #[test]
    fn sweep_telemetry_on_vs_off_results_are_byte_identical() {
        let mut artifacts = Vec::new();
        for (run, telemetry) in [("off", false), ("on", true)] {
            let mut spec = small_spec(&format!("ktbo-orch-tel-{run}"), "tel");
            spec.threads = 2;
            spec.telemetry = telemetry;
            let report = sweep(&spec).unwrap();
            let results = std::fs::read_to_string(spec.results_path()).unwrap();
            let progress = std::fs::read_to_string(spec.progress_path()).unwrap();
            artifacts.push((results, progress, report.summary, spec));
        }
        assert_eq!(artifacts[0].0, artifacts[1].0, "results.jsonl must not see telemetry");
        assert_eq!(artifacts[0].1, artifacts[1].1, "progress records must not see telemetry");
        assert!(
            !artifacts[0].2.contains("telemetry:"),
            "summary must not mention telemetry when off"
        );
        assert!(artifacts[1].2.contains("telemetry:"), "summary must point at the export");

        let tel_path = artifacts[1].3.telemetry_path();
        let text = std::fs::read_to_string(&tel_path).unwrap();
        let head = text.lines().next().expect("telemetry export must be non-empty");
        assert!(
            head.contains("\"schema_version\"") && head.contains("\"telemetry\""),
            "export must open with the versioned meta record, got: {head}"
        );
        assert!(text.lines().count() > 1, "export must carry events, not just the meta line");
        let rendered = crate::telemetry::report::render(&text).expect("report renders the export");
        assert!(rendered.contains("adding/a100/random#0"), "per-cell section missing:\n{rendered}");
        assert!(rendered.contains("ask"), "phase breakdown missing:\n{rendered}");
    }

    /// Tentpole acceptance: a crashing cell is isolated — listed in the
    /// report and recorded curve-less — and a `--fresh`-less resume
    /// re-attempts exactly the failed cells while reusing the rest.
    #[test]
    fn crashed_cells_are_isolated_recorded_and_rerun_on_resume() {
        let plan = FaultPlan { crash_after: Some(0), ..FaultPlan::quiet(0xC4A5) };
        let mut spec = small_spec("ktbo-orch-crash", "crash");
        spec.fault_plan = Some(write_plan("ktbo-orch-crash", "crash.json", &plan));
        spec.fault_strategies = vec!["mls".into()];

        let report = sweep(&spec).expect("a crashing cell must not fail the sweep");
        assert_eq!(report.total_cells, 6);
        assert_eq!(report.failed_cells.len(), 3, "every mls repeat crashes");
        for (key, msg) in &report.failed_cells {
            assert_eq!(key.strategy, "mls");
            assert!(msg.contains("injected crash"), "unexpected panic message: {msg}");
        }
        assert!(report.summary.contains("failed cells (3)"));

        // The crash never leaks into the non-faulted strategy's cells.
        let dev = Device::a100();
        let obj = objective_for("adding", &dev);
        let oid = objective_id("adding", dev.name);
        let reference = run_strategy(&obj, &oid, "random", 40, 3, 11, 1);
        assert_eq!(report.outcomes[0].1[0].mean_curve, reference.mean_curve);

        // Failed cells are recorded, but without a curve.
        let text = std::fs::read_to_string(spec.progress_path()).unwrap();
        let failed_lines: Vec<&str> =
            text.lines().filter(|l| l.contains("\"outcome\":\"failed\"")).collect();
        assert_eq!(failed_lines.len(), 3);
        for line in &failed_lines {
            assert!(line.contains("\"strategy\":\"mls\""));
            assert!(!line.contains("\"curve\""), "failed records must stay curve-less");
        }

        // Resume: the 3 completed random cells are reused, the 3 failed
        // mls cells are re-attempted (and, same plan, fail again).
        let mut resumed = spec.clone();
        resumed.fresh = false;
        let second = sweep(&resumed).unwrap();
        assert_eq!((second.resumed_cells, second.ran_cells), (3, 3));
        assert_eq!(second.failed_cells.len(), 3);
        assert_eq!(second.outcomes[0].1[0].mean_curve, reference.mean_curve);
    }

    /// Fault injection is part of the cell's deterministic identity: a
    /// fixed plan yields bit-identical faulted curves at every worker
    /// count, non-faulted cells stay bit-identical to the serial
    /// reference, and faulted records carry the accounting block.
    #[test]
    fn faulted_cells_are_bit_identical_across_worker_counts() {
        let plan = FaultPlan {
            transient_rate: 0.3,
            hang_rate: 0.1,
            flaky_rate: 0.2,
            flaky_sigma: 0.4,
            ..FaultPlan::quiet(0x5EED)
        };
        let path = write_plan("ktbo-orch-det", "det.json", &plan);
        let dev = Device::a100();
        let obj = objective_for("adding", &dev);
        let oid = objective_id("adding", dev.name);
        let clean_mls = run_strategy(&obj, &oid, "mls", 40, 3, 11, 1);
        let clean_random = run_strategy(&obj, &oid, "random", 40, 3, 11, 1);

        let mut baseline: Option<Vec<StrategyOutcome>> = None;
        for threads in [1usize, 4] {
            let mut spec = small_spec("ktbo-orch-det", &format!("det-{threads}"));
            spec.threads = threads;
            spec.fault_plan = Some(path.clone());
            spec.fault_strategies = vec!["mls".into()];
            spec.max_retries = 2;
            let report = sweep(&spec).unwrap();
            assert!(report.failed_cells.is_empty(), "this plan never crashes");
            let outs = &report.outcomes[0].1;
            // Non-faulted cells are untouched by the injection layer.
            assert_eq!(outs[0].mean_curve, clean_random.mean_curve, "threads={threads}");
            // Faulted cells actually diverge from the clean run...
            assert_ne!(outs[1].mean_curve, clean_mls.mean_curve, "injection must bite");
            // ...but are identical at every worker count.
            match &baseline {
                None => baseline = Some(outs.clone()),
                Some(b) => {
                    assert_eq!(outs[1].mean_curve, b[1].mean_curve, "fault injection must be thread-invariant");
                    assert_eq!(outs[1].maes, b[1].maes);
                }
            }
            let text = std::fs::read_to_string(spec.progress_path()).unwrap();
            for line in text.lines().filter(|l| l.contains("\"type\":\"cell\"")) {
                let faulted = line.contains("\"strategy\":\"mls\"");
                assert_eq!(
                    line.contains("\"faults\""),
                    faulted,
                    "exactly the faulted cells carry accounting: {line}"
                );
                if faulted {
                    assert!(line.contains("\"injected\"") && line.contains("\"resilience\""));
                }
            }
        }
    }

    #[test]
    fn fault_spec_validation_fails_fast() {
        // Subset without a plan.
        let mut spec = small_spec("ktbo-orch-fval", "fval");
        spec.fault_strategies = vec!["mls".into()];
        assert!(sweep(&spec).unwrap_err().contains("without a fault_plan"));

        let plan = FaultPlan::quiet(1);
        let path = write_plan("ktbo-orch-fval", "quiet.json", &plan);
        // Faulted strategy not in the matrix.
        let mut spec = small_spec("ktbo-orch-fval", "fval2");
        spec.fault_plan = Some(path.clone());
        spec.fault_strategies = vec!["ei".into()];
        assert!(sweep(&spec).unwrap_err().contains("not in the sweep matrix"));
        // Unknown faulted strategy lists the registry.
        let mut spec = small_spec("ktbo-orch-fval", "fval3");
        spec.fault_plan = Some(path);
        spec.fault_strategies = vec!["warp_drive".into()];
        assert!(sweep(&spec).unwrap_err().contains("warp_drive"));
        // Missing plan file.
        let mut spec = small_spec("ktbo-orch-fval", "fval4");
        spec.fault_plan = Some("/nonexistent/plan.json".into());
        assert!(sweep(&spec).unwrap_err().contains("fault plan"));
    }

    /// Satellite: mid-cell checkpoint/resume stays bit-identical when the
    /// objective injects hangs (recorded as `Timeout` evaluations). Each
    /// session gets a fresh `FaultyObjective` under the same plan, so the
    /// injected schedule — a pure function of (plan seed, index, attempt)
    /// — replays identically through the resume.
    #[test]
    fn mid_cell_checkpoint_resume_survives_injected_hangs() {
        use crate::strategies::driver::{FevalBudget, Session};
        let dev = Device::a100();
        let table = objective_for("adding", &dev);
        let oid = objective_id("adding", dev.name);
        let plan = FaultPlan { hang_rate: 0.25, transient_rate: 0.15, ..FaultPlan::quiet(0xAB1E) };
        let faulted = || {
            Arc::new(FaultyObjective::new(Arc::clone(&table) as Arc<dyn Objective>, plan.clone()))
                as Arc<dyn Objective>
        };
        for strategy in ["mls", "ei"] {
            let s = by_name(strategy).unwrap();
            let budget = 45usize;
            let make_rng = || cell_rng(7, &oid, strategy, 0);

            let full = {
                let mut sess = Session::new(
                    s.driver(table.space()),
                    faulted(),
                    Box::new(FevalBudget::new(budget)),
                    make_rng(),
                );
                while sess.step() {}
                sess.into_trace()
            };
            assert!(
                full.records.iter().any(|r| r.1 == crate::objective::Eval::Timeout),
                "{strategy}: the hang lane must have fired for this test to mean anything"
            );

            let ckpt = {
                let mut first = Session::new(
                    s.driver(table.space()),
                    faulted(),
                    Box::new(FevalBudget::new(budget)),
                    make_rng(),
                );
                for _ in 0..12 {
                    if !first.step() {
                        break;
                    }
                }
                first.checkpoint()
            };
            assert!(ckpt.len() < full.len(), "{strategy}: interrupt landed past the end");

            let mut resumed = Session::resume(
                s.driver(table.space()),
                faulted(),
                Box::new(FevalBudget::new(budget)),
                make_rng(),
                ckpt,
            );
            while resumed.step() {}
            assert_eq!(
                resumed.trace().records,
                full.records,
                "{strategy}: resume under injected hangs diverged"
            );
        }
    }
}
