//! Reusable core of the `surrogate_fit` bench: fit+predict wall time per
//! surrogate [`Model`] over paper-scale candidate sets, with
//! machine-readable output (`BENCH_surrogate_fit.json` at the repo root).
//!
//! The bench binary (`benches/surrogate_fit.rs`) is a thin CLI over these
//! functions, and the test suite runs a tiny smoke grid through the same
//! code (`surrogate_fit_bench_smoke` in `tests/integration.rs`) — so the
//! bench logic compiles and runs on every `cargo test` and can never
//! silently rot.
//!
//! Scenarios: the GEMM restricted space (~18k candidates) and the ~200k
//! synthetic grid from the `space_build` bench, each fit at the paper's
//! observation counts (50 and the full 220 budget) and predicted over the
//! whole candidate set through the engine's sharded
//! [`predict_pass`](crate::surrogate::predict_pass) — the exact
//! per-iteration workload each surrogate adds to a BO run. Models: the
//! incremental GP adapter, random forest, extra trees, and TPE.

// ktbo-lint: allow-file(no-untracked-clock): standalone bench harness — wall
// time is informational output here, never on the trace path.
use std::time::Instant;

use crate::gp::DEFAULT_SHARD_LEN;
use crate::harness::space_bench::spec_for;
use crate::space::SearchSpace;
use crate::surrogate::{
    predict_pass, FitCtx, ForestConfig, ForestModel, GpModel, Model, TpeConfig, TpeModel,
};
use crate::util::json::Json;
use crate::util::pool::ShardPool;
use crate::util::rng::{hash_normal, Rng};

/// One fit+predict scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// A `space_bench::spec_for` name (`gemm`, `synthetic200k`, `smoke`).
    pub space: &'static str,
    /// A surrogate name (`gp`, `rf`, `et`, `tpe`).
    pub model: &'static str,
    /// Observations fit (sampled deterministically from the space).
    pub n_obs: usize,
    /// Worker threads for the sharded predict pass.
    pub threads: usize,
    /// Fit+predict repetitions timed.
    pub iters: usize,
}

/// Timing outcome of one scenario.
#[derive(Clone, Debug)]
pub struct Record {
    pub scenario: Scenario,
    /// Candidates predicted per iteration.
    pub configs: usize,
    pub ms_fit: f64,
    pub ms_predict: f64,
    /// Order-sensitive digest of the predicted mean bits — equal digests
    /// across thread counts ⇒ bit-identical predictions (the determinism
    /// hook for tests; also lands in the JSON).
    pub mu_digest: u64,
}

/// Instantiate a bench surrogate by name, matching the registry's
/// configurations (the GP derives its covariance/noise from the same
/// Table-I `BoConfig` the registry strategies run).
pub fn model_by_name(name: &str) -> Box<dyn Model> {
    match name {
        "gp" => Box::new(GpModel::from_config(&crate::bo::BoConfig::single(crate::bo::Acq::Ei))),
        "rf" => Box::new(ForestModel::new(ForestConfig::random_forest())),
        "et" => Box::new(ForestModel::new(ForestConfig::extra_trees())),
        "tpe" => Box::new(TpeModel::new(TpeConfig::default())),
        other => panic!("unknown bench surrogate '{other}'"),
    }
}

/// Deterministic synthetic observations: `n` distinct configurations with
/// a smooth-plus-rough target derived from hashed coordinates (no
/// objective evaluation — this bench times the surrogate alone).
fn observations(space: &SearchSpace, n: usize) -> (Vec<usize>, Vec<f64>) {
    let m = space.len();
    let mut rng = Rng::new(0x5355_5252); // fixed: scenarios must be comparable
    let obs_idx = rng.sample_indices(m, n.min(m));
    let y: Vec<f64> = obs_idx
        .iter()
        .map(|&i| {
            let p = space.point(i);
            let smooth: f64 = p.iter().map(|&v| (f64::from(v) - 0.4).powi(2)).sum();
            smooth + 0.1 * hash_normal(i as u64)
        })
        .collect();
    (obs_idx, y)
}

/// Time `iters` fit+predict rounds of one scenario.
pub fn run_scenario(sc: &Scenario) -> Record {
    let space = spec_for(sc.space).build();
    let m = space.len();
    let pool = ShardPool::new(sc.threads);
    let (obs_idx, y_z) = observations(&space, sc.n_obs);
    let shard_len = DEFAULT_SHARD_LEN;
    let mut mu = vec![0.0; m];
    let mut var = vec![0.0; m];

    let mut fit_s = 0.0;
    let mut predict_s = 0.0;
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    for _ in 0..sc.iters.max(1) {
        // Fresh model per iteration: the bench measures a *full* fit, the
        // worst case of a refit-per-step surrogate (the GP adapter's
        // incremental appends make its repeat fits cheaper in-run).
        let mut model = model_by_name(sc.model);
        let mut seed_rng = Rng::new(7);
        model.seed(&mut seed_rng);
        let t0 = Instant::now();
        model.fit(&FitCtx { space: &space, obs_idx: &obs_idx, y_z: &y_z, shard_len, pool: &pool });
        fit_s += t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        predict_pass(model.as_ref(), &space, &pool, shard_len, &mut mu, &mut var);
        predict_s += t1.elapsed().as_secs_f64();
        digest = 0xcbf2_9ce4_8422_2325u64;
        for v in &mu {
            digest = (digest ^ v.to_bits()).wrapping_mul(0x1000_0000_01b3);
        }
    }
    std::hint::black_box((&mu, &var));
    let iters = sc.iters.max(1) as f64;
    Record {
        scenario: sc.clone(),
        configs: m,
        ms_fit: fit_s * 1e3 / iters,
        ms_predict: predict_s * 1e3 / iters,
        mu_digest: digest,
    }
}

/// The bench grid. `smoke` shrinks it to sub-second sizes for the test
/// suite; the full grid covers GEMM (~18k) and the ~200k synthetic grid
/// at n ∈ {50, 220} observations, serial and 8-thread predict passes.
pub fn scenario_grid(smoke: bool) -> Vec<Scenario> {
    let models = ["gp", "rf", "et", "tpe"];
    if smoke {
        return models
            .iter()
            .flat_map(|&model| {
                [1usize, 4].into_iter().map(move |threads| Scenario {
                    space: "smoke",
                    model,
                    n_obs: 25,
                    threads,
                    iters: 1,
                })
            })
            .collect();
    }
    let mut grid = Vec::new();
    for space in ["gemm", "synthetic200k"] {
        for &model in &models {
            for n_obs in [50usize, 220] {
                for threads in [1usize, 8] {
                    grid.push(Scenario { space, model, n_obs, threads, iters: 3 });
                }
            }
        }
    }
    grid
}

/// Render records as the `BENCH_surrogate_fit.json` document (diffable:
/// insertion-ordered keys, one record per scenario).
pub fn to_json(records: &[Record]) -> Json {
    let rows: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj()
                .set("space", r.scenario.space)
                .set("model", r.scenario.model)
                .set("n_obs", r.scenario.n_obs)
                .set("threads", r.scenario.threads)
                .set("configs", r.configs)
                .set("ms_fit", r.ms_fit)
                .set("ms_predict", r.ms_predict)
                .set("mu_digest", format!("{:016x}", r.mu_digest))
        })
        .collect();
    Json::obj()
        .set("bench", "surrogate_fit")
        .set("unit", "ms_fit + ms_predict")
        .set(
            "description",
            "per-iteration surrogate workload: full fit from n_obs observations + sharded (mu, var) sweep over every candidate",
        )
        .set("records", Json::Arr(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The end-to-end smoke of the grid + JSON serialization lives in
    // tests/integration.rs (surrogate_fit_bench_smoke) — one copy only.

    /// Predictions must be partition-independent: every thread count
    /// digests to the serial mean bits, for every model.
    #[test]
    fn predictions_are_thread_count_independent() {
        for model in ["gp", "rf", "et", "tpe"] {
            let digest = |threads: usize| {
                run_scenario(&Scenario { space: "smoke", model, n_obs: 20, threads, iters: 1 })
                    .mu_digest
            };
            let reference = digest(1);
            assert_eq!(digest(2), reference, "{model} diverged at 2 threads");
            assert_eq!(digest(8), reference, "{model} diverged at 8 threads");
        }
    }
}
