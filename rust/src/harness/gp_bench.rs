//! Reusable core of the `gp_hotpath` bench: a simulated BO-iteration loop
//! over the sharded GP hot path, timed per iteration, with
//! machine-readable output (`BENCH_gp_hotpath.json` at the repo root).
//!
//! The bench binary (`benches/gp_hotpath.rs`) is a thin CLI over these
//! functions, and the test suite runs a tiny smoke grid through the same
//! code (`gp_hotpath_bench_smoke` in `tests/integration.rs`) — so the
//! bench logic compiles and runs on every `cargo test` and can never
//! silently rot.
//!
//! Two variants per scenario:
//! - `baseline_serial` — the seed hot path: serial incremental
//!   add + predict, then a *separate* full-space mask scan, variance
//!   reduction, and acquisition argmin scan.
//! - `fused_sharded` — this PR's engine path: pooled shard-parallel add,
//!   one folded mask+variance pass, and the fused predict+score sweep.

// ktbo-lint: allow-file(no-untracked-clock): standalone bench harness — wall
// time is informational output here, never on the trace path.
use std::time::Instant;

use crate::bo::acquisition::{argmin_score, reduce_shard_argmins, score_chunk, var_from_fp};
use crate::bo::engine::mask_var_fold;
use crate::bo::Acq;
use crate::gp::{CovFn, IncrementalGp, DEFAULT_SHARD_LEN};
use crate::util::json::Json;
use crate::util::pool::ShardPool;
use crate::util::rng::Rng;

/// One hot-path scenario: `n` simulated BO iterations over `m` candidates.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub n: usize,
    pub m: usize,
    pub dims: usize,
    pub threads: usize,
    pub shard_len: usize,
    /// Engine-style fused path vs the seed-style separate passes.
    pub fused: bool,
}

impl Scenario {
    pub fn variant(&self) -> &'static str {
        if self.fused {
            "fused_sharded"
        } else {
            "baseline_serial"
        }
    }
}

/// Timing outcome of one scenario.
#[derive(Clone, Debug)]
pub struct Record {
    pub scenario: Scenario,
    pub ms_per_iter: f64,
    pub total_s: f64,
    /// Order-sensitive digest of the per-iteration argmin picks — equal
    /// digests ⇒ identical simulated trajectories (the determinism hook
    /// for tests; also lands in the JSON so perf runs are comparable).
    pub picks_digest: u64,
}

/// Run one simulated BO loop: every iteration appends one observation to
/// the GP, rebuilds the candidate mask + mean posterior variance, and
/// arg-minimizes EI over all non-evaluated candidates — exactly the
/// engine's per-iteration O(m)/O(n·m) workload, without objective noise.
pub fn run_scenario(sc: &Scenario) -> Record {
    let mut rng = Rng::new(0x9e37_79b9);
    let cand: Vec<f32> = (0..sc.m * sc.dims).map(|_| rng.f64() as f32).collect();
    let x: Vec<f32> = (0..sc.n * sc.dims).map(|_| rng.f64() as f32).collect();
    let y: Vec<f64> = (0..sc.n).map(|_| rng.normal()).collect();
    let cov = CovFn::Matern32 { lengthscale: 1.5 };

    let pool = ShardPool::new(if sc.fused { sc.threads } else { 1 });
    let shard_len = if sc.fused { sc.shard_len.max(1) } else { sc.m.max(1) };
    let mut inc = IncrementalGp::with_shard_len(cov, 1e-6, cand.into(), sc.dims, shard_len);
    let mut mu = vec![0.0; sc.m];
    let mut var = vec![0.0; sc.m];
    let mut masked = vec![false; sc.m];
    let mut visited = vec![false; sc.m];
    let afs = [Acq::Ei];
    let mut digest = 0xcbf2_9ce4_8422_2325u64;

    let t0 = Instant::now();
    for i in 0..sc.n {
        inc.add_par(&x[i * sc.dims..(i + 1) * sc.dims], &pool);
        let yw = &y[..i + 1];
        let f_best = yw.iter().cloned().fold(f64::INFINITY, f64::min);
        let pick = if sc.fused {
            // Engine path: folded mask+var pass, then fused predict+score.
            let sq_chunks: Vec<&[f64]> = inc.sq_chunks().collect();
            let (var_fp, n_cand) =
                mask_var_fold(&pool, inc.shard_len(), &mut masked, &mut var, Some(&sq_chunks[..]), &visited, None);
            let lambda = 0.01 * var_from_fp(var_fp) / n_cand.max(1) as f64;
            let parts = inc.predict_scored(yw, &pool, &mut mu, &mut var, |start, mu_c, var_c| {
                score_chunk(&afs, mu_c, var_c, &masked[start..start + mu_c.len()], start, f_best, lambda)
            });
            reduce_shard_argmins(&parts, afs.len())[0]
        } else {
            // Seed path: serial predict, then separate mask scan, variance
            // reduction, and argmin scan — three extra O(m) passes.
            inc.predict_into(yw, &mut mu, &mut var);
            for j in 0..sc.m {
                masked[j] = visited[j];
            }
            let (mut var_sum, mut n_cand) = (0.0, 0usize);
            for j in 0..sc.m {
                if !masked[j] {
                    var_sum += var[j];
                    n_cand += 1;
                }
            }
            let lambda = 0.01 * var_sum / n_cand.max(1) as f64;
            argmin_score(Acq::Ei, &mu, &var, f_best, lambda, &masked)
        };
        if let Some(p) = pick {
            visited[p] = true;
            digest = (digest ^ p as u64).wrapping_mul(0x1000_0000_01b3);
        }
    }
    let total_s = t0.elapsed().as_secs_f64();
    std::hint::black_box(digest);
    Record {
        scenario: sc.clone(),
        ms_per_iter: total_s * 1e3 / sc.n.max(1) as f64,
        total_s,
        picks_digest: digest,
    }
}

/// The bench grid. `smoke` shrinks it to sub-second sizes for the test
/// suite; the full grid covers the GEMM restricted space (17956) and a
/// 200k-candidate space at n ∈ {50, 120, 220} × threads ∈ {1, 4, 8},
/// plus the serial seed-style baseline for the before/after ratio.
pub fn scenario_grid(smoke: bool) -> Vec<Scenario> {
    if smoke {
        return vec![
            Scenario { n: 6, m: 160, dims: 4, threads: 1, shard_len: 160, fused: false },
            Scenario { n: 6, m: 160, dims: 4, threads: 2, shard_len: 37, fused: true },
            Scenario { n: 6, m: 160, dims: 4, threads: 4, shard_len: 16, fused: true },
        ];
    }
    let mut grid = Vec::new();
    for &m in &[17956usize, 200_000] {
        for &n in &[50usize, 120, 220] {
            grid.push(Scenario { n, m, dims: 15, threads: 1, shard_len: DEFAULT_SHARD_LEN, fused: false });
            for &threads in &[1usize, 4, 8] {
                grid.push(Scenario { n, m, dims: 15, threads, shard_len: DEFAULT_SHARD_LEN, fused: true });
            }
        }
    }
    grid
}

/// Render records as the `BENCH_gp_hotpath.json` document tracked from
/// this PR onward (append-friendly, diffable: insertion-ordered keys).
pub fn to_json(records: &[Record]) -> Json {
    let rows: Vec<Json> = records
        .iter()
        .map(|r| {
            Json::obj()
                .set("variant", r.scenario.variant())
                .set("n", r.scenario.n)
                .set("m", r.scenario.m)
                .set("dims", r.scenario.dims)
                .set("threads", r.scenario.threads)
                .set("shard_len", r.scenario.shard_len)
                .set("ms_per_iter", r.ms_per_iter)
                .set("total_s", r.total_s)
                .set("picks_digest", format!("{:016x}", r.picks_digest))
        })
        .collect();
    Json::obj()
        .set("bench", "gp_hotpath")
        .set("unit", "ms_per_iter")
        .set("description", "simulated BO loop: per-iteration GP append + mask/var fold + exhaustive EI argmin")
        .set("records", Json::Arr(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The end-to-end smoke of the grid + JSON serialization lives in
    // tests/integration.rs (gp_hotpath_bench_smoke) — one copy only.

    /// The fused path must walk an identical trajectory for every shard
    /// partition and thread count (same inputs via the fixed RNG seed).
    #[test]
    fn fused_trajectory_is_partition_independent() {
        let digest = |threads: usize, shard_len: usize| -> u64 {
            run_scenario(&Scenario { n: 8, m: 120, dims: 3, threads, shard_len, fused: true }).picks_digest
        };
        let reference = digest(1, 120);
        assert_eq!(digest(2, 60), reference);
        assert_eq!(digest(4, 13), reference);
        assert_eq!(digest(8, 1), reference);
    }
}
