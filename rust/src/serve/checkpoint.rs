//! Versioned session checkpoints.
//!
//! A checkpoint is the session's trace (the whole externally visible run
//! state — see `Session::checkpoint`) plus the [`SessionConfig`] that
//! rebuilds driver, budget, and RNG deterministically. Serialized as one
//! JSON document with a `schema_version` field: mismatched versions are
//! refused with a clear message, while version-less documents from
//! pre-versioning builds still load (see `tests/data/legacy_checkpoint.json`).

use crate::objective::Eval;
use crate::serve::config::SessionConfig;
use crate::strategies::{Trace, OUT_OF_SPACE};
use crate::util::json::Json;
use crate::util::jsonparse;

/// Version of the checkpoint document layout this build reads and writes.
pub const SCHEMA_VERSION: u64 = 1;

/// A resumable snapshot of one tuning session.
#[derive(Clone, Debug)]
pub struct SessionCheckpoint {
    pub config: SessionConfig,
    pub trace: Trace,
}

impl SessionCheckpoint {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("type", "session_checkpoint")
            .set("schema_version", SCHEMA_VERSION as usize)
            .set("config", self.config.to_json())
            .set("trace", trace_to_json(&self.trace))
    }

    pub fn from_json(j: &Json) -> Result<SessionCheckpoint, String> {
        if j.get("type").and_then(Json::as_str) != Some("session_checkpoint") {
            return Err("not a session checkpoint (missing type field)".into());
        }
        // Version-less documents predate versioning and use layout v1.
        if let Some(v) = j.get("schema_version").and_then(Json::as_f64) {
            if v as u64 != SCHEMA_VERSION {
                return Err(format!(
                    "checkpoint has schema_version {} but this build reads {SCHEMA_VERSION}; \
                     re-create the session or use a matching build",
                    v as u64
                ));
            }
        }
        let config = SessionConfig::from_json(
            j.get("config").ok_or("checkpoint is missing 'config'")?,
        )?;
        let trace =
            trace_from_json(j.get("trace").ok_or("checkpoint is missing 'trace'")?)?;
        Ok(SessionCheckpoint { config, trace })
    }

    pub fn parse(text: &str) -> Result<SessionCheckpoint, String> {
        SessionCheckpoint::from_json(&jsonparse::parse(text)?)
    }
}

/// Trace records as a JSON array. `OUT_OF_SPACE` (a sentinel at
/// `usize::MAX`) is written as index `-1` so documents stay readable.
pub fn trace_to_json(trace: &Trace) -> Json {
    Json::Arr(
        trace
            .records
            .iter()
            .map(|(idx, e)| {
                let j = Json::obj().set(
                    "idx",
                    if *idx == OUT_OF_SPACE { Json::Num(-1.0) } else { Json::Num(*idx as f64) },
                );
                match e.value() {
                    Some(t) => j.set("time", t),
                    // Non-valid evals always carry a label; fall back to
                    // "runtime" rather than panic inside checkpoint writes.
                    None => j.set("invalid", e.invalid_label().unwrap_or("runtime")),
                }
            })
            .collect(),
    )
}

pub fn trace_from_json(j: &Json) -> Result<Trace, String> {
    let arr = j.as_arr().ok_or("trace must be an array")?;
    let mut trace = Trace::new();
    for rec in arr {
        let raw = rec.get("idx").and_then(Json::as_f64).ok_or("trace record missing 'idx'")?;
        let idx = if raw < 0.0 { OUT_OF_SPACE } else { raw as usize };
        let eval = match rec.get("time").and_then(Json::as_f64) {
            Some(t) => Eval::Valid(t),
            None => {
                let label = rec
                    .get("invalid")
                    .and_then(Json::as_str)
                    .ok_or("trace record needs 'time' or 'invalid'")?;
                Eval::from_invalid_label(label)
            }
        };
        trace.push(idx, eval);
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::FaultKind;

    fn config() -> SessionConfig {
        SessionConfig {
            kernel: "adding".into(),
            gpu: "a100".into(),
            strategy: "random".into(),
            budget: 20,
            seed: 7,
            space: None,
            eval_timeout_ms: None,
            max_retries: 0,
            fault_plan: None,
        }
        .validate()
        .unwrap()
    }

    #[test]
    fn checkpoint_round_trips_every_eval_kind() {
        let mut trace = Trace::new();
        trace.push(3, Eval::Valid(1.25));
        trace.push(OUT_OF_SPACE, Eval::RuntimeError);
        trace.push(9, Eval::CompileError);
        trace.push(4, Eval::Timeout);
        trace.push(5, Eval::Transient(FaultKind::DeviceError));
        let ckpt = SessionCheckpoint { config: config(), trace };
        let back = SessionCheckpoint::parse(&ckpt.to_json().render()).unwrap();
        assert_eq!(back.config, ckpt.config);
        assert_eq!(back.trace.records, ckpt.trace.records);
    }

    #[test]
    fn mismatched_schema_version_is_refused() {
        let text = SessionCheckpoint { config: config(), trace: Trace::new() }
            .to_json()
            .render()
            .replace("\"schema_version\":1", "\"schema_version\":99");
        let err = SessionCheckpoint::parse(&text).unwrap_err();
        assert!(err.contains("schema_version 99"), "{err}");
    }

    #[test]
    fn versionless_legacy_document_loads() {
        let text = SessionCheckpoint { config: config(), trace: Trace::new() }
            .to_json()
            .render()
            .replace("\"schema_version\":1,", "");
        let ckpt = SessionCheckpoint::parse(&text).unwrap();
        assert_eq!(ckpt.config.kernel, "adding");
    }
}
