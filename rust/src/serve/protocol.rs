//! The serve daemon's wire protocol: JSON lines over a socket.
//!
//! One request per line, one response line per request — trivially
//! scriptable from `nc`, and framing-free. Every request carries a
//! `cmd` field; session-scoped commands name their session:
//!
//! ```text
//! {"cmd":"create","session":"s1","config":{"kernel":"adding","gpu":"a100",...}}
//! {"cmd":"ask","session":"s1"}
//! {"cmd":"tell","session":"s1","config_index":412,"time":1.532}
//! {"cmd":"tell","session":"s1","config_index":9,"invalid":"compile"}
//! {"cmd":"checkpoint","session":"s1"}
//! {"cmd":"resume","session":"s1","checkpoint":{...}}
//! {"cmd":"close","session":"s1"}
//! {"cmd":"status"}
//! {"cmd":"metrics"}
//! {"cmd":"shutdown"}
//! ```
//!
//! Responses always carry `"ok"`: `{"ok":true,...}` on success,
//! `{"ok":false,"error":"..."}` on failure. A failed request never kills
//! the connection — clients read the error and continue.

use crate::objective::Eval;
use crate::util::json::Json;
use crate::util::jsonparse;

/// A parsed protocol request.
#[derive(Clone, Debug)]
pub enum Request {
    Create { session: String, config: Json },
    Ask { session: String },
    Tell { session: String, idx: usize, eval: Eval },
    Checkpoint { session: String },
    /// Rebuild a session from a checkpoint document — inline if given,
    /// otherwise from the server's checkpoint directory.
    Resume { session: String, checkpoint: Option<Json> },
    Close { session: String },
    Status,
    /// Snapshot of the daemon's owned metrics registry (per-verb request
    /// counters, error and session tallies).
    Metrics,
    Shutdown,
}

impl Request {
    /// The wire verb, for per-verb request counters.
    pub fn verb(&self) -> &'static str {
        match self {
            Request::Create { .. } => "create",
            Request::Ask { .. } => "ask",
            Request::Tell { .. } => "tell",
            Request::Checkpoint { .. } => "checkpoint",
            Request::Resume { .. } => "resume",
            Request::Close { .. } => "close",
            Request::Status => "status",
            Request::Metrics => "metrics",
            Request::Shutdown => "shutdown",
        }
    }
}

/// Parse one request line.
pub fn parse(line: &str) -> Result<Request, String> {
    let j = jsonparse::parse(line)?;
    let cmd = j.get("cmd").and_then(Json::as_str).ok_or("request is missing 'cmd'")?;
    let session = || -> Result<String, String> {
        j.get("session")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("'{cmd}' needs a 'session' field"))
    };
    match cmd {
        "create" => Ok(Request::Create {
            session: session()?,
            config: j.get("config").cloned().ok_or("'create' needs a 'config' object")?,
        }),
        "ask" => Ok(Request::Ask { session: session()? }),
        "tell" => {
            let idx = j
                .get("config_index")
                .and_then(Json::as_f64)
                .filter(|v| *v >= 0.0)
                .ok_or("'tell' needs a non-negative 'config_index'")? as usize;
            let eval = match j.get("time").and_then(Json::as_f64) {
                Some(t) => Eval::Valid(t),
                None => {
                    let label = j
                        .get("invalid")
                        .and_then(Json::as_str)
                        .ok_or("'tell' needs 'time' (a number) or 'invalid' (a label)")?;
                    Eval::from_invalid_label(label)
                }
            };
            Ok(Request::Tell { session: session()?, idx, eval })
        }
        "checkpoint" => Ok(Request::Checkpoint { session: session()? }),
        "resume" => {
            Ok(Request::Resume { session: session()?, checkpoint: j.get("checkpoint").cloned() })
        }
        "close" => Ok(Request::Close { session: session()? }),
        "status" => Ok(Request::Status),
        "metrics" => Ok(Request::Metrics),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(format!(
            "unknown command '{other}' (expected create/ask/tell/checkpoint/resume/close/status/metrics/shutdown)"
        )),
    }
}

/// Start a success response.
pub fn ok() -> Json {
    Json::obj().set("ok", true)
}

/// A rendered error response line.
pub fn err(msg: &str) -> String {
    Json::obj().set("ok", false).set("error", msg).render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_command() {
        assert!(matches!(
            parse(r#"{"cmd":"create","session":"s","config":{}}"#).unwrap(),
            Request::Create { .. }
        ));
        assert!(matches!(parse(r#"{"cmd":"ask","session":"s"}"#).unwrap(), Request::Ask { .. }));
        match parse(r#"{"cmd":"tell","session":"s","config_index":3,"time":2.5}"#).unwrap() {
            Request::Tell { idx, eval, .. } => {
                assert_eq!((idx, eval), (3, Eval::Valid(2.5)));
            }
            other => panic!("{other:?}"),
        }
        match parse(r#"{"cmd":"tell","session":"s","config_index":4,"invalid":"timeout"}"#).unwrap()
        {
            Request::Tell { eval, .. } => assert_eq!(eval, Eval::Timeout),
            other => panic!("{other:?}"),
        }
        assert!(matches!(parse(r#"{"cmd":"status"}"#).unwrap(), Request::Status));
        assert!(matches!(parse(r#"{"cmd":"metrics"}"#).unwrap(), Request::Metrics));
        assert_eq!(parse(r#"{"cmd":"metrics"}"#).unwrap().verb(), "metrics");
        assert!(matches!(parse(r#"{"cmd":"shutdown"}"#).unwrap(), Request::Shutdown));
        assert!(matches!(
            parse(r#"{"cmd":"resume","session":"s"}"#).unwrap(),
            Request::Resume { checkpoint: None, .. }
        ));
    }

    #[test]
    fn malformed_requests_are_descriptive() {
        assert!(parse("not json").is_err());
        assert!(parse(r#"{"cmd":"ask"}"#).unwrap_err().contains("session"));
        assert!(parse(r#"{"cmd":"tell","session":"s","config_index":-1,"time":1.0}"#)
            .unwrap_err()
            .contains("config_index"));
        assert!(parse(r#"{"cmd":"warp"}"#).unwrap_err().contains("unknown command"));
        assert!(err("boom").contains("\"ok\":false"));
    }
}
