//! Scripted client for the serve protocol.
//!
//! The daemon owns models and suggestions; the client owns measurement.
//! [`run_session`] drives one session to completion over any
//! [`LineTransport`]: `create` → (`ask` → evaluate locally → `tell`)* →
//! `done` → `close`. The evaluation side is built from the same
//! [`SessionConfig`] the server received, so in simulation mode a served
//! run reproduces `ktbo tune` bit for bit.
//!
//! Two transports: [`TcpLine`] speaks JSON lines over a socket (the
//! `ktbo client` subcommand); [`InProcess`] calls
//! [`TuningServer::handle_line`] directly, which is what lets the stress
//! suite drive thousands of clients on a thread pool without sockets.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use crate::serve::config::SessionConfig;
use crate::serve::server::TuningServer;
use crate::util::json::Json;
use crate::util::jsonparse;
use crate::util::rng::Rng;

/// One request line in, one response line out.
pub trait LineTransport {
    fn round_trip(&mut self, line: &str) -> Result<String, String>;
}

/// JSON lines over TCP.
pub struct TcpLine {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpLine {
    pub fn connect(addr: &str) -> Result<TcpLine, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("cannot connect to {addr}: {e}"))?;
        let reader = BufReader::new(
            stream.try_clone().map_err(|e| format!("cannot clone stream: {e}"))?,
        );
        Ok(TcpLine { reader, writer: stream })
    }
}

impl LineTransport for TcpLine {
    fn round_trip(&mut self, line: &str) -> Result<String, String> {
        writeln!(self.writer, "{line}").map_err(|e| format!("send failed: {e}"))?;
        self.writer.flush().map_err(|e| format!("send failed: {e}"))?;
        let mut resp = String::new();
        match self.reader.read_line(&mut resp) {
            Ok(0) => Err("server closed the connection".into()),
            Ok(_) => Ok(resp.trim_end().to_string()),
            Err(e) => Err(format!("receive failed: {e}")),
        }
    }
}

/// Direct calls into an in-process server — the simulated-client path.
pub struct InProcess(pub Arc<TuningServer>);

impl LineTransport for InProcess {
    fn round_trip(&mut self, line: &str) -> Result<String, String> {
        Ok(self.0.handle_line(line))
    }
}

/// Result of one completed served session.
#[derive(Clone, Debug)]
pub struct ClientOutcome {
    pub session: String,
    pub evaluations: usize,
    pub best: Option<f64>,
    pub best_index: Option<usize>,
}

fn expect_ok(t: &mut dyn LineTransport, line: &str) -> Result<Json, String> {
    let resp = t.round_trip(line)?;
    let j = jsonparse::parse(&resp)?;
    if j.get("ok") != Some(&Json::Bool(true)) {
        return Err(j
            .get("error")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("request failed: {resp}")));
    }
    Ok(j)
}

/// Drive one session to completion, evaluating suggestions locally
/// against the config's objective (simulation mode). `resume` continues
/// an existing server-side checkpoint instead of creating the session.
pub fn run_session(
    t: &mut dyn LineTransport,
    name: &str,
    cfg: &SessionConfig,
    resume: bool,
) -> Result<ClientOutcome, String> {
    let built = cfg.build_objective()?;
    // Table objectives ignore the eval RNG, so any stream works; keep it
    // deterministic anyway for the fault-injection wrappers.
    // ktbo-lint: allow(rng-discipline): client-side eval root stream — seeded by the SessionConfig like the offline harness
    let mut rng = Rng::with_stream(cfg.seed, 0x5e55_1014);
    let open = if resume {
        Json::obj().set("cmd", "resume").set("session", name)
    } else {
        Json::obj().set("cmd", "create").set("session", name).set("config", cfg.to_json())
    };
    expect_ok(t, &open.render())?;
    let ask = Json::obj().set("cmd", "ask").set("session", name).render();
    loop {
        let a = expect_ok(t, &ask)?;
        match a.get("status").and_then(Json::as_str) {
            Some("eval") => {
                let idx = a
                    .get("config_index")
                    .and_then(Json::as_f64)
                    .ok_or("'eval' response without config_index")? as usize;
                let eval = built.run.evaluate(idx, &mut rng);
                let tell = Json::obj()
                    .set("cmd", "tell")
                    .set("session", name)
                    .set("config_index", idx);
                let tell = match eval.value() {
                    Some(v) => tell.set("time", v),
                    // Non-valid evals carry a label; default to "runtime"
                    // instead of panicking mid-protocol.
                    None => tell.set("invalid", eval.invalid_label().unwrap_or("runtime")),
                };
                expect_ok(t, &tell.render())?;
            }
            Some("done") => {
                let close =
                    Json::obj().set("cmd", "close").set("session", name).render();
                let c = expect_ok(t, &close)?;
                return Ok(ClientOutcome {
                    session: name.to_string(),
                    evaluations: c.get("evaluations").and_then(Json::as_f64).unwrap_or(0.0)
                        as usize,
                    best: c.get("best").and_then(Json::as_f64),
                    best_index: c.get("best_index").and_then(Json::as_f64).map(|v| v as usize),
                });
            }
            other => return Err(format!("unexpected ask status {other:?}")),
        }
    }
}
