//! Tuning-as-a-service: the `ktbo serve` daemon and its wire protocol.
//!
//! The paper's loop — propose a configuration, measure it on a GPU, feed
//! the result back — is naturally separable: the optimizer (surrogate
//! state, budgets, suggestion logic) can live in a long-running daemon
//! while measurements arrive from clients. This module is that daemon:
//!
//! - [`config`] — [`SessionConfig`], the serializable "what run is this"
//!   record shared by `ktbo tune`, the wire protocol, and checkpoints.
//! - [`protocol`] — JSON-lines request/response framing
//!   (`create`/`ask`/`tell`/`checkpoint`/`resume`/`close`/`status`/`shutdown`).
//! - [`server`] — [`TuningServer`]: thousands of concurrent owned
//!   [`Session`](crate::strategies::Session)s over one shared, persistent,
//!   LRU-bounded [`EvalCache`](crate::objective::evalcache::EvalCache).
//! - [`checkpoint`] — versioned session snapshots (config + trace);
//!   resume replays the trace through a fresh driver.
//! - [`client`] — a scripted client that evaluates suggestions locally
//!   (simulation mode), used by `ktbo client`, the CI smoke, and the
//!   N-thousand-session stress tests.
//!
//! Served runs are bit-identical to offline [`drive`](crate::strategies::drive):
//! sessions park fresh suggestions without drawing RNG, table objectives
//! ignore the eval RNG, and budget accounting is shared with the in-process
//! engine — so the daemon adds distribution, not behavior.
//!
//! A wire-facing module must never bring the daemon down on bad input, so
//! unwrap/expect are compiler-denied here on top of ktbo-lint's
//! no-panic-on-wire rule (tests are exempt; they panic on purpose).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod checkpoint;
pub mod client;
pub mod config;
pub mod protocol;
pub mod server;

pub use config::SessionConfig;
pub use server::{ServeOpts, TuningServer};
