//! The tuning daemon: many concurrent sessions, one shared cache.
//!
//! [`TuningServer`] is socket-free at its core — [`TuningServer::handle_line`]
//! maps one request line to one response line, so the whole protocol is
//! exercisable in-process (the stress tests drive thousands of scripted
//! clients through it on a [`ShardPool`](crate::util::pool::ShardPool)
//! without a single socket). [`TuningServer::serve_tcp`] is a thin
//! thread-per-connection wrapper over the same entry point.
//!
//! Concurrency model: the session map is a mutex around `Arc<Mutex<Slot>>`
//! handles — the map lock is held only to look up or insert a handle, so
//! requests against different sessions proceed in parallel while two
//! clients racing the *same* session serialize on its slot lock.
//!
//! State across restarts: the shared [`EvalCache`] persists measurements
//! (JSONL journal, bounded by an LRU cap), and `checkpoint` requests
//! snapshot sessions to `<dir>/<session>.json`; after a crash, `resume`
//! rebuilds each session from its checkpoint by trace replay and the
//! cache warm-starts from its journal.

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use crate::harness::runner::objective_id;
use crate::objective::evalcache::{EvalCache, RunMemo};
use crate::serve::checkpoint::SessionCheckpoint;
use crate::serve::config::SessionConfig;
use crate::serve::protocol::{self, Request};
use crate::space::SearchSpace;
use crate::strategies::registry::{by_name, unknown_strategy_message};
use crate::strategies::{FevalBudget, Session, SessionNeed, SessionOpts, SessionTarget, Trace};
use crate::telemetry::metrics::MetricsRegistry;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Daemon configuration.
#[derive(Clone, Debug, Default)]
pub struct ServeOpts {
    /// JSONL journal backing the shared eval cache; `None` = in-memory.
    pub cache_path: Option<PathBuf>,
    /// LRU cap on cached evaluations; `None` = unbounded.
    pub cache_capacity: Option<usize>,
    /// Directory for `checkpoint`/`resume` snapshots; `None` disables
    /// server-side persistence (inline checkpoints still work).
    pub checkpoint_dir: Option<PathBuf>,
}

/// One live session and the config that rebuilds it.
struct Slot {
    config: SessionConfig,
    obj_id: String,
    session: Session,
}

/// A multiplexing tuning server over owned [`Session`]s.
pub struct TuningServer {
    opts: ServeOpts,
    cache: Arc<EvalCache>,
    sessions: Mutex<BTreeMap<String, Arc<Mutex<Slot>>>>,
    /// Built spaces (and their objective ids) keyed by the config's
    /// (kernel, gpu, space-file) triple — thousands of sessions on one
    /// kernel share one space instead of re-enumerating it per `create`.
    spaces: Mutex<BTreeMap<String, (Arc<SearchSpace>, String)>>,
    /// Every objective id a `create`/`resume` has named — including ones
    /// whose create was *refused* (e.g. lazy-mode configs) — so `status`
    /// reports per-objective cache stats uniformly, zeros included,
    /// instead of only the objectives the cache happened to touch.
    tracked_objectives: Mutex<BTreeSet<String>>,
    /// Owned registry (not [`crate::telemetry::metrics::global`]): the
    /// `metrics` verb reports *this daemon's* traffic, and parallel test
    /// servers don't bleed counts into each other.
    metrics: MetricsRegistry,
    shutdown: AtomicBool,
}

/// Lock acquisition that outlives panics: a poisoned mutex means some
/// earlier request died mid-update, and the daemon's contract is to keep
/// answering rather than cascade the crash — so recover the inner guard.
/// (Map state stays structurally valid: both maps are only mutated by
/// single `insert`/`remove` calls.)
fn relock<T>(lock: &Mutex<T>) -> MutexGuard<'_, T> {
    lock.lock().unwrap_or_else(PoisonError::into_inner)
}

impl TuningServer {
    pub fn new(opts: ServeOpts) -> Result<TuningServer, String> {
        let cache = match &opts.cache_path {
            Some(path) => EvalCache::persistent(path, opts.cache_capacity)?,
            None => EvalCache::bounded(opts.cache_capacity),
        };
        if let Some(dir) = &opts.checkpoint_dir {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("cannot create checkpoint dir {}: {e}", dir.display()))?;
        }
        Ok(TuningServer {
            opts,
            cache: Arc::new(cache),
            sessions: Mutex::new(BTreeMap::new()),
            spaces: Mutex::new(BTreeMap::new()),
            tracked_objectives: Mutex::new(BTreeSet::new()),
            metrics: MetricsRegistry::new(),
            shutdown: AtomicBool::new(false),
        })
    }

    pub fn cache(&self) -> &Arc<EvalCache> {
        &self.cache
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Handle one request line, producing one response line (no trailing
    /// newline). Never panics on malformed input — errors come back as
    /// `{"ok":false,"error":...}`.
    pub fn handle_line(&self, line: &str) -> String {
        match protocol::parse(line) {
            Ok(req) => {
                self.metrics.counter(&format!("serve.requests.{}", req.verb()), 1);
                match self.handle(req) {
                    Ok(resp) => resp.render(),
                    Err(e) => {
                        self.metrics.counter("serve.errors", 1);
                        protocol::err(&e)
                    }
                }
            }
            Err(e) => {
                self.metrics.counter("serve.requests.invalid", 1);
                self.metrics.counter("serve.errors", 1);
                protocol::err(&e)
            }
        }
    }

    fn handle(&self, req: Request) -> Result<Json, String> {
        match req {
            Request::Create { session, config } => {
                let cfg = SessionConfig::from_json(&config)?;
                self.create(&session, cfg, None)
            }
            Request::Ask { session } => self.with_slot(&session, |slot| {
                Ok(match slot.session.next_ask() {
                    SessionNeed::Eval(idx) => protocol::ok()
                        .set("status", "eval")
                        .set("config_index", idx)
                        .set("config", slot.session.space().describe(idx)),
                    SessionNeed::Done => done_response(slot),
                })
            }),
            Request::Tell { session, idx, eval } => self.with_slot(&session, |slot| {
                match slot.session.tell(idx, eval) {
                    Ok(()) => Ok(protocol::ok()
                        .set("status", "recorded")
                        .set("evaluations", slot.session.trace().len())),
                    Err(e) => Err(e.to_string()),
                }
            }),
            Request::Checkpoint { session } => {
                let doc = self.with_slot(&session, |slot| {
                    let ckpt = SessionCheckpoint {
                        config: slot.config.clone(),
                        trace: slot.session.checkpoint(),
                    };
                    Ok(ckpt.to_json())
                })?;
                if let Some(dir) = &self.opts.checkpoint_dir {
                    let path = dir.join(format!("{session}.json"));
                    std::fs::write(&path, doc.render())
                        .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
                }
                Ok(protocol::ok().set("checkpoint", doc))
            }
            Request::Resume { session, checkpoint } => {
                let ckpt = match checkpoint {
                    Some(j) => SessionCheckpoint::from_json(&j)?,
                    None => {
                        let dir = self.opts.checkpoint_dir.as_ref().ok_or(
                            "no inline checkpoint and the server has no --checkpoint-dir",
                        )?;
                        let path = dir.join(format!("{session}.json"));
                        let text = std::fs::read_to_string(&path)
                            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
                        SessionCheckpoint::parse(&text)?
                    }
                };
                self.create(&session, ckpt.config, Some(ckpt.trace))
            }
            Request::Close { session } => {
                let slot = relock(&self.sessions)
                    .remove(&session)
                    .ok_or_else(|| format!("no session named '{session}'"))?;
                let slot = relock(&slot);
                Ok(done_response(&slot).set("closed", true))
            }
            Request::Status => Ok(self.status()),
            Request::Metrics => Ok(protocol::ok().set("metrics", self.metrics.snapshot())),
            Request::Shutdown => {
                self.shutdown.store(true, Ordering::SeqCst);
                Ok(protocol::ok().set("shutting_down", true))
            }
        }
    }

    /// Build (or rebuild, when `resume_from` is set) a session slot.
    fn create(
        &self,
        name: &str,
        cfg: SessionConfig,
        resume_from: Option<Trace>,
    ) -> Result<Json, String> {
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || "._-".contains(c))
            || name.contains("..")
        {
            return Err(format!(
                "session name '{name}' is invalid (use letters, digits, '.', '_', '-')"
            ));
        }
        let built = {
            // Building a space enumerates the full restricted Cartesian
            // product, so it happens once per distinct triple; holding the
            // lock across the build just serializes the rare cold creates.
            let key = format!("{}|{}|{}", cfg.kernel, cfg.gpu, cfg.space.as_deref().unwrap_or(""));
            let mut spaces = relock(&self.spaces);
            match spaces.get(&key) {
                Some((space, obj_id)) => Ok((Arc::clone(space), obj_id.clone())),
                None => cfg.build_space().map(|(space, obj_id)| {
                    spaces.insert(key, (Arc::clone(&space), obj_id.clone()));
                    (space, obj_id)
                }),
            }
        };
        let (space, obj_id) = match built {
            Ok(v) => v,
            Err(e) => {
                // A refused create (the daemon is eager-only, so a
                // lazy-mode config lands here) still registers the
                // objective it named: `status` then reports its cache
                // stats — zeros — uniformly with live sessions. The base
                // id is used because a refusal happens before any space
                // file is loaded.
                if let Ok(dev) = cfg.device() {
                    self.track_objective(objective_id(&cfg.kernel, dev.name));
                }
                return Err(e);
            }
        };
        self.track_objective(obj_id.clone());
        // `validate` already canonicalized the name, but the daemon never
        // trusts that enough to panic on wire-derived data.
        let driver = by_name(&cfg.strategy)
            .ok_or_else(|| unknown_strategy_message(&cfg.strategy))?
            .driver(&space);
        let resumed = resume_from.as_ref().map(Trace::len);
        let session = Session::build(
            driver,
            SessionTarget::External(Arc::clone(&space)),
            Box::new(FevalBudget::new(cfg.budget)),
            // ktbo-lint: allow(rng-discipline): session root stream — the seed is owned by SessionConfig, matching offline `drive`
            Rng::new(cfg.seed),
            SessionOpts {
                memo: Some(RunMemo::shared(Arc::clone(&self.cache), &obj_id)),
                resume_from,
            },
        );
        let slot = Slot { config: cfg, obj_id, session };
        let mut sessions = relock(&self.sessions);
        if sessions.contains_key(name) {
            return Err(format!("session '{name}' already exists"));
        }
        let resp = protocol::ok()
            .set("session", name)
            .set("strategy", slot.config.strategy.as_str())
            .set("objective", slot.obj_id.as_str())
            .set("space_size", space.len())
            .set("budget", slot.config.budget);
        let resp = match resumed {
            Some(n) => resp.set("resumed_evaluations", n),
            None => resp,
        };
        sessions.insert(name.to_string(), Arc::new(Mutex::new(slot)));
        self.metrics.counter("serve.sessions.created", 1);
        Ok(resp)
    }

    fn track_objective(&self, id: String) {
        relock(&self.tracked_objectives).insert(id);
    }

    fn with_slot<F>(&self, name: &str, f: F) -> Result<Json, String>
    where
        F: FnOnce(&mut Slot) -> Result<Json, String>,
    {
        let slot = {
            let sessions = relock(&self.sessions);
            Arc::clone(sessions.get(name).ok_or_else(|| format!("no session named '{name}'"))?)
        };
        let mut slot = relock(&slot);
        f(&mut slot)
    }

    /// The `status` response: live-session count, global and
    /// per-objective cache effectiveness, and a folded metrics summary.
    ///
    /// The per-objective section is the *union* of objectives any create
    /// named (refused ones included) and objectives the cache has seen,
    /// in name order; ids without cache activity report zeros rather
    /// than disappearing, so clients can poll one shape uniformly.
    fn status(&self) -> Json {
        let s = self.cache.stats();
        let mut ids: BTreeSet<String> = relock(&self.tracked_objectives).clone();
        for (id, _) in self.cache.objective_stats() {
            ids.insert(id);
        }
        let mut per_obj = Json::obj();
        for id in &ids {
            let os = self.cache.stats_for(id).unwrap_or_default();
            per_obj = per_obj.set(
                id,
                Json::obj()
                    .set("hits", os.hits as usize)
                    .set("misses", os.misses as usize)
                    .set("evictions", os.evictions as usize),
            );
        }
        protocol::ok()
            .set("sessions", relock(&self.sessions).len())
            .set(
                "cache",
                Json::obj()
                    .set("entries", self.cache.len())
                    .set("hits", s.hits as usize)
                    .set("misses", s.misses as usize)
                    .set("evictions", s.evictions as usize),
            )
            .set("objectives", per_obj)
            .set(
                "metrics",
                Json::obj()
                    .set("requests", self.metrics.counter_sum("serve.requests.") as usize)
                    .set("errors", self.metrics.counter_value("serve.errors") as usize)
                    .set(
                        "sessions_created",
                        self.metrics.counter_value("serve.sessions.created") as usize,
                    ),
            )
    }

    /// Accept loop: thread-per-connection, JSON lines in, JSON lines out.
    /// Returns after a `shutdown` request has been honored (in-flight
    /// connections are detached; the caller usually exits the process).
    pub fn serve_tcp(self: Arc<Self>, listener: TcpListener) -> std::io::Result<()> {
        listener.set_nonblocking(true)?;
        loop {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    let server = Arc::clone(&self);
                    std::thread::spawn(move || serve_conn(&server, stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if self.is_shutdown() {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e),
            }
        }
        // Best-effort journal compaction so a restart replays a minimal
        // file instead of the full append history.
        let _ = self.cache.compact();
        Ok(())
    }
}

/// Session summary used by terminal `ask` responses and `close`.
fn done_response(slot: &Slot) -> Json {
    let trace = slot.session.trace();
    let resp = protocol::ok()
        .set("status", "done")
        .set("evaluations", trace.len())
        .set("objective", slot.obj_id.as_str());
    match trace.best() {
        Some((idx, val)) => resp
            .set("best_index", idx)
            .set("best", val)
            .set("best_config", slot.session.space().describe(idx)),
        None => resp.set("best", Json::Null),
    }
}

fn serve_conn(server: &Arc<TuningServer>, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut writer = stream;
    for line in BufReader::new(read_half).lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let resp = server.handle_line(&line);
        if writeln!(writer, "{resp}").and_then(|_| writer.flush()).is_err() {
            break;
        }
        if server.is_shutdown() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::jsonparse;

    fn server() -> TuningServer {
        TuningServer::new(ServeOpts::default()).unwrap()
    }

    fn req(server: &TuningServer, line: &str) -> Json {
        jsonparse::parse(&server.handle_line(line)).expect("responses are valid JSON")
    }

    fn ok(j: &Json) -> bool {
        j.get("ok") == Some(&Json::Bool(true))
    }

    const CREATE: &str = r#"{"cmd":"create","session":"s1","config":{"kernel":"adding","gpu":"a100","strategy":"random","budget":5,"seed":"0x7"}}"#;

    #[test]
    fn create_ask_tell_runs_a_session_to_completion() {
        let srv = server();
        let r = req(&srv, CREATE);
        assert!(ok(&r), "{r:?}");
        assert_eq!(r.get("strategy").and_then(Json::as_str), Some("random"));
        loop {
            let a = req(&srv, r#"{"cmd":"ask","session":"s1"}"#);
            assert!(ok(&a), "{a:?}");
            match a.get("status").and_then(Json::as_str) {
                Some("eval") => {
                    let idx = a.get("config_index").and_then(Json::as_f64).unwrap() as usize;
                    let t = req(
                        &srv,
                        &format!(
                            r#"{{"cmd":"tell","session":"s1","config_index":{idx},"time":{}}}"#,
                            1.0 + idx as f64 * 0.001
                        ),
                    );
                    assert!(ok(&t), "{t:?}");
                }
                Some("done") => {
                    assert_eq!(a.get("evaluations").and_then(Json::as_f64), Some(5.0));
                    break;
                }
                other => panic!("unexpected status {other:?}"),
            }
        }
        let c = req(&srv, r#"{"cmd":"close","session":"s1"}"#);
        assert!(ok(&c) && c.get("closed") == Some(&Json::Bool(true)), "{c:?}");
        let gone = req(&srv, r#"{"cmd":"ask","session":"s1"}"#);
        assert!(!ok(&gone));
    }

    #[test]
    fn duplicate_and_invalid_session_names_are_rejected() {
        let srv = server();
        assert!(ok(&req(&srv, CREATE)));
        let dup = req(&srv, CREATE);
        assert!(!ok(&dup), "{dup:?}");
        let bad = req(
            &srv,
            r#"{"cmd":"create","session":"../etc/passwd","config":{"kernel":"adding","gpu":"a100","strategy":"random","budget":5,"seed":"0x7"}}"#,
        );
        assert!(!ok(&bad), "{bad:?}");
    }

    #[test]
    fn unknown_strategy_is_rejected_through_the_registry_path() {
        let srv = server();
        let r = req(
            &srv,
            r#"{"cmd":"create","session":"s1","config":{"kernel":"adding","gpu":"a100","strategy":"bayes","budget":5,"seed":"0x7"}}"#,
        );
        assert!(!ok(&r));
        let msg = r.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.contains("unknown strategy"), "{msg}");
    }

    #[test]
    fn status_reports_sessions_and_per_objective_cache_stats() {
        let srv = server();
        assert!(ok(&req(&srv, CREATE)));
        let a = req(&srv, r#"{"cmd":"ask","session":"s1"}"#);
        let idx = a.get("config_index").and_then(Json::as_f64).unwrap() as usize;
        req(&srv, &format!(r#"{{"cmd":"tell","session":"s1","config_index":{idx},"time":2.0}}"#));
        let s = req(&srv, r#"{"cmd":"status"}"#);
        assert_eq!(s.get("sessions").and_then(Json::as_f64), Some(1.0));
        let per_obj = s.get("objectives").unwrap();
        let adding = per_obj.get("adding@A100").expect("per-objective stats present");
        assert_eq!(adding.get("misses").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn status_includes_refused_lazy_creates_with_zero_cache_stats() {
        // Satellite: the per-objective section is uniform — a create the
        // daemon refused (lazy mode is eager-only) still registers its
        // objective, reported with zero stats, alongside live sessions.
        let srv = server();
        let refused = req(
            &srv,
            r#"{"cmd":"create","session":"lz","config":{"kernel":"gemm","gpu":"a100","strategy":"tpe","budget":5,"seed":"0x7","lazy_space":true}}"#,
        );
        assert!(!ok(&refused), "{refused:?}");
        assert!(refused.get("error").and_then(Json::as_str).unwrap().contains("eager-only"));
        assert!(ok(&req(&srv, CREATE)));
        let s = req(&srv, r#"{"cmd":"status"}"#);
        assert_eq!(s.get("sessions").and_then(Json::as_f64), Some(1.0), "{s:?}");
        let per_obj = s.get("objectives").unwrap();
        let refused_obj = per_obj.get("gemm@A100").expect("refused objective still listed");
        for field in ["hits", "misses", "evictions"] {
            assert_eq!(refused_obj.get(field).and_then(Json::as_f64), Some(0.0), "{field}");
        }
        assert!(per_obj.get("adding@A100").is_some(), "live session's objective listed");
    }

    #[test]
    fn metrics_verb_reports_per_verb_counters_and_status_folds_them() {
        let srv = server();
        assert!(ok(&req(&srv, CREATE)));
        assert!(!ok(&req(&srv, r#"{"cmd":"ask","session":"ghost"}"#)));
        assert!(!ok(&req(&srv, "not json")));
        let m = req(&srv, r#"{"cmd":"metrics"}"#);
        assert!(ok(&m), "{m:?}");
        let snap = m.get("metrics").expect("metrics snapshot present");
        let counter = |name: &str| {
            snap.get(name)
                .and_then(|c| c.get("value"))
                .and_then(Json::as_f64)
                .unwrap_or_default()
        };
        assert_eq!(counter("serve.requests.create"), 1.0, "{snap:?}");
        assert_eq!(counter("serve.requests.ask"), 1.0);
        assert_eq!(counter("serve.requests.invalid"), 1.0);
        assert_eq!(counter("serve.requests.metrics"), 1.0);
        assert_eq!(counter("serve.errors"), 2.0, "ghost ask + malformed line");
        assert_eq!(counter("serve.sessions.created"), 1.0);
        let s = req(&srv, r#"{"cmd":"status"}"#);
        let folded = s.get("metrics").expect("status folds a metrics summary");
        // create + ask + invalid + metrics + this status = 5 requests.
        assert_eq!(folded.get("requests").and_then(Json::as_f64), Some(5.0), "{folded:?}");
        assert_eq!(folded.get("errors").and_then(Json::as_f64), Some(2.0));
        assert_eq!(folded.get("sessions_created").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn double_tell_is_rejected_not_rerecorded() {
        let srv = server();
        assert!(ok(&req(&srv, CREATE)));
        let a = req(&srv, r#"{"cmd":"ask","session":"s1"}"#);
        let idx = a.get("config_index").and_then(Json::as_f64).unwrap() as usize;
        let tell = format!(r#"{{"cmd":"tell","session":"s1","config_index":{idx},"time":2.0}}"#);
        assert!(ok(&req(&srv, &tell)));
        let second = req(&srv, &tell);
        assert!(!ok(&second), "{second:?}");
        let msg = second.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.contains("no ask is outstanding"), "{msg}");
        // The trace recorded exactly one evaluation.
        let s = req(&srv, r#"{"cmd":"checkpoint","session":"s1"}"#);
        let trace = s.get("checkpoint").unwrap().get("trace").unwrap().as_arr().unwrap();
        assert_eq!(trace.len(), 1);
    }

    #[test]
    fn inline_checkpoint_resume_continues_the_run() {
        let srv = server();
        assert!(ok(&req(&srv, CREATE)));
        // Two evals, checkpoint, close, resume under a new server.
        for _ in 0..2 {
            let a = req(&srv, r#"{"cmd":"ask","session":"s1"}"#);
            let idx = a.get("config_index").and_then(Json::as_f64).unwrap() as usize;
            req(
                &srv,
                &format!(r#"{{"cmd":"tell","session":"s1","config_index":{idx},"time":2.0}}"#),
            );
        }
        let ck = req(&srv, r#"{"cmd":"checkpoint","session":"s1"}"#);
        let doc = ck.get("checkpoint").unwrap().clone();
        let srv2 = server();
        let resume = Json::obj()
            .set("cmd", "resume")
            .set("session", "s1")
            .set("checkpoint", doc)
            .render();
        let r = req(&srv2, &resume);
        assert!(ok(&r), "{r:?}");
        assert_eq!(r.get("resumed_evaluations").and_then(Json::as_f64), Some(2.0));
    }
}
