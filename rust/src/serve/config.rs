//! Serializable per-run tuning configuration.
//!
//! One [`SessionConfig`] is the single source of truth for "what run is
//! this": kernel, device, strategy, budget, seed, optional declarative
//! space file, and the fault/resilience knobs. `ktbo tune` builds one
//! from CLI flags, the serve daemon parses one from the wire's `create`
//! request, and checkpoints embed one so a resumed session rebuilds the
//! exact run — all three go through [`SessionConfig::validate`], which
//! canonicalizes names and rejects unknown strategies through the
//! registry's suggestion path.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::gpusim::device::Device;
use crate::gpusim::kernels::kernel_by_name;
use crate::gpusim::SimulatedSpace;
use crate::harness::runner::objective_id;
use crate::objective::faulty::{FaultPlan, FaultyObjective};
use crate::objective::resilient::{ResilienceConfig, ResilientEvaluator};
use crate::objective::{Objective, TableObjective};
use crate::space::{SearchSpace, SpaceSpec};
use crate::strategies::registry::{by_name, unknown_strategy_message};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Everything that defines one tuning run, in wire-serializable form.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionConfig {
    pub kernel: String,
    pub gpu: String,
    pub strategy: String,
    pub budget: usize,
    pub seed: u64,
    /// Optional declarative SpaceSpec JSON file replacing the kernel's
    /// built-in space (server-side path).
    pub space: Option<String>,
    pub eval_timeout_ms: Option<u64>,
    pub max_retries: u32,
    /// Optional deterministic fault-injection plan file.
    pub fault_plan: Option<String>,
    /// Implicit-space mode: `Some(true)` forces the lazy [`SpaceView`]
    /// path, `Some(false)` forbids it, `None` (default) lets `ktbo tune`
    /// pick by the documented Cartesian-size cutoff. The serve daemon is
    /// eager-only and rejects `Some(true)` at build time.
    ///
    /// [`SpaceView`]: crate::space::view::SpaceView
    pub lazy_space: Option<bool>,
    /// Candidate-pool size per lazy-mode suggestion (`None` = the
    /// engine default, [`crate::bo::DEFAULT_POOL_SIZE`]).
    pub pool_size: Option<usize>,
}

impl SessionConfig {
    /// Shared `--eval-timeout-ms` parsing for every CLI entry point.
    pub fn parse_eval_timeout(args: &Args) -> Result<Option<u64>, String> {
        match args.get("eval-timeout-ms") {
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|_| format!("--eval-timeout-ms must be an integer, got '{v}'")),
            None => Ok(None),
        }
    }

    /// Build from CLI flags. The caller resolves where kernel/gpu come
    /// from (`ktbo tune` takes them as positionals, `ktbo client` as
    /// flags); the knob flags are shared verbatim.
    pub fn from_args(args: &Args, kernel: &str, gpu: &str) -> Result<SessionConfig, String> {
        SessionConfig {
            kernel: kernel.to_string(),
            gpu: gpu.to_string(),
            strategy: args.str_or("strategy", "advanced_multi"),
            budget: args.usize_or("budget", 220),
            seed: args.u64_or("seed", 42),
            space: args.get("space").map(str::to_string),
            eval_timeout_ms: SessionConfig::parse_eval_timeout(args)?,
            max_retries: args.usize_or("max-retries", 0) as u32,
            fault_plan: args.get("fault-plan").map(str::to_string),
            lazy_space: if args.has("lazy-space") { Some(args.flag("lazy-space")) } else { None },
            pool_size: args
                .get("pool-size")
                .map(|v| {
                    v.parse::<usize>()
                        .map_err(|_| format!("--pool-size must be a positive integer, got '{v}'"))
                })
                .transpose()?,
        }
        .validate()
    }

    /// Canonicalize names against the registries and reject anything
    /// unknown. Every construction path funnels through here, so the
    /// server, the CLI, and checkpoints agree on what is valid.
    pub fn validate(mut self) -> Result<SessionConfig, String> {
        self.strategy = by_name(&self.strategy)
            .ok_or_else(|| unknown_strategy_message(&self.strategy))?
            .name();
        self.kernel = kernel_by_name(&self.kernel)
            .ok_or_else(|| format!("unknown kernel '{}'", self.kernel))?
            .name()
            .to_string();
        self.gpu = Device::by_name(&self.gpu)
            .ok_or_else(|| format!("unknown GPU '{}'", self.gpu))?
            .name
            .to_string();
        if self.budget == 0 {
            return Err("budget must be positive".into());
        }
        if self.pool_size == Some(0) {
            return Err("pool_size must be positive".into());
        }
        if self.lazy_space == Some(true) {
            let name = &self.strategy;
            if !crate::strategies::registry::lazy_names().contains(&name.as_str()) {
                return Err(format!(
                    "strategy '{name}' requires an enumerated space and cannot run with \
                     lazy_space=true (lazy-capable strategies: {})",
                    crate::strategies::registry::lazy_names().join(", ")
                ));
            }
        }
        Ok(self)
    }

    /// Serve-side guard: the daemon's session machinery keys caches and
    /// checkpoints on enumerated indices, so it refuses lazy mode rather
    /// than silently materializing a huge space.
    fn require_eager(&self, what: &str) -> Result<(), String> {
        if self.lazy_space == Some(true) {
            return Err(format!(
                "{what} is eager-only: lazy_space=true is not supported here \
                 (run `ktbo tune --lazy-space` locally instead)"
            ));
        }
        Ok(())
    }

    /// Resolve the device. `validate` canonicalized the name, but configs
    /// also arrive straight off the wire and out of checkpoint files, so
    /// this re-resolves instead of panicking on a stale or forged name.
    pub fn device(&self) -> Result<Device, String> {
        Device::by_name(&self.gpu).ok_or_else(|| format!("unknown GPU '{}'", self.gpu))
    }

    /// The search space this run tunes over plus its cache/objective id.
    /// Table values are not needed — this is the daemon-side half, where
    /// measurements arrive from clients.
    pub fn build_space(&self) -> Result<(Arc<SearchSpace>, String), String> {
        self.require_eager("the serve daemon")?;
        let dev = self.device()?;
        let base_id = objective_id(&self.kernel, dev.name);
        match &self.space {
            None => {
                let k = kernel_by_name(&self.kernel)
                    .ok_or_else(|| format!("unknown kernel '{}'", self.kernel))?;
                Ok((Arc::new(k.spec(&dev).build()), base_id))
            }
            Some(path) => {
                let spec = SpaceSpec::load(Path::new(path))?;
                let id = format!("{base_id}#space:{}", spec.name);
                Ok((Arc::new(spec.build()), id))
            }
        }
    }

    /// The client-side half: a concrete objective (simulation mode),
    /// wrapped in the configured fault/resilience layers.
    pub fn build_objective(&self) -> Result<BuiltObjective, String> {
        self.require_eager("the table-objective build path")?;
        let dev = self.device()?;
        let table = match &self.space {
            None => crate::harness::figures::objective_for(&self.kernel, &dev),
            Some(path) => {
                let spec = SpaceSpec::load(Path::new(path))?;
                let k = kernel_by_name(&self.kernel)
                    .ok_or_else(|| format!("unknown kernel '{}'", self.kernel))?;
                Arc::new(TableObjective::from_sim(SimulatedSpace::build_with_space(
                    k.as_ref(),
                    &dev,
                    spec.build(),
                )))
            }
        };
        self.wrap_table(table)
    }

    /// Apply the fault-injection and resilience layers to a table
    /// objective (shared by `build_objective` and `ktbo tune`'s
    /// cache-file path, which fixes the table differently).
    pub fn wrap_table(&self, table: Arc<TableObjective>) -> Result<BuiltObjective, String> {
        let faulty = match &self.fault_plan {
            Some(path) => {
                let plan = FaultPlan::load(Path::new(path))?;
                Some(Arc::new(FaultyObjective::new(
                    Arc::clone(&table) as Arc<dyn Objective>,
                    plan,
                )))
            }
            None => None,
        };
        let eval_obj: Arc<dyn Objective> = match &faulty {
            Some(f) => Arc::clone(f) as Arc<dyn Objective>,
            None => Arc::clone(&table) as Arc<dyn Objective>,
        };
        let res_cfg = ResilienceConfig {
            deadline: self.eval_timeout_ms.map(Duration::from_millis),
            max_retries: self.max_retries,
            ..ResilienceConfig::default()
        };
        let resilient = if res_cfg.is_passthrough() {
            None
        } else {
            Some(Arc::new(ResilientEvaluator::new(Arc::clone(&eval_obj), res_cfg)))
        };
        let run: Arc<dyn Objective> = match &resilient {
            Some(r) => Arc::clone(r) as Arc<dyn Objective>,
            None => eval_obj,
        };
        Ok(BuiltObjective { table, run, faulty, resilient })
    }

    pub fn to_json(&self) -> Json {
        let opt_str = |o: &Option<String>| match o {
            Some(s) => Json::Str(s.clone()),
            None => Json::Null,
        };
        let mut j = Json::obj()
            .set("kernel", self.kernel.as_str())
            .set("gpu", self.gpu.as_str())
            .set("strategy", self.strategy.as_str())
            .set("budget", self.budget)
            // Hex string: Json numbers are f64 and would silently round
            // seeds above 2^53.
            .set("seed", format!("0x{:016x}", self.seed))
            .set("space", opt_str(&self.space))
            .set("max_retries", self.max_retries as usize)
            .set("fault_plan", opt_str(&self.fault_plan));
        if let Some(ms) = self.eval_timeout_ms {
            j = j.set("eval_timeout_ms", ms as usize);
        }
        // Lazy knobs are omitted when unset so configs written before
        // implicit spaces existed stay byte-identical on re-render.
        if let Some(b) = self.lazy_space {
            j = j.set("lazy_space", b);
        }
        if let Some(p) = self.pool_size {
            j = j.set("pool_size", p);
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<SessionConfig, String> {
        let s = |key: &str| -> Result<String, String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("config is missing '{key}'"))
        };
        let seed_str = s("seed")?;
        let seed = u64::from_str_radix(seed_str.strip_prefix("0x").unwrap_or(&seed_str), 16)
            .map_err(|_| format!("config seed '{seed_str}' is not a hex integer"))?;
        let opt_s = |key: &str| j.get(key).and_then(Json::as_str).map(str::to_string);
        SessionConfig {
            kernel: s("kernel")?,
            gpu: s("gpu")?,
            strategy: s("strategy")?,
            budget: j
                .get("budget")
                .and_then(Json::as_f64)
                .ok_or("config is missing 'budget'")? as usize,
            seed,
            space: opt_s("space"),
            eval_timeout_ms: j.get("eval_timeout_ms").and_then(Json::as_f64).map(|v| v as u64),
            max_retries: j.get("max_retries").and_then(Json::as_f64).unwrap_or(0.0) as u32,
            fault_plan: opt_s("fault_plan"),
            lazy_space: j.get("lazy_space").and_then(Json::as_bool),
            pool_size: j.get("pool_size").and_then(Json::as_f64).map(|v| v as usize),
        }
        .validate()
    }
}

/// The evaluation stack a config builds client-side: the raw table plus
/// the (optionally) fault-injected, resilience-wrapped objective runs go
/// through, with handles kept for stats reporting.
pub struct BuiltObjective {
    pub table: Arc<TableObjective>,
    pub run: Arc<dyn Objective>,
    pub faulty: Option<Arc<FaultyObjective>>,
    pub resilient: Option<Arc<ResilientEvaluator>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> SessionConfig {
        SessionConfig {
            kernel: "adding".into(),
            gpu: "a100".into(),
            strategy: "random".into(),
            budget: 20,
            seed: 7,
            space: None,
            eval_timeout_ms: None,
            max_retries: 0,
            fault_plan: None,
            lazy_space: None,
            pool_size: None,
        }
    }

    #[test]
    fn round_trips_through_json() {
        let cfg = SessionConfig {
            seed: 0xDEAD_BEEF_0000_0001,
            eval_timeout_ms: Some(250),
            max_retries: 2,
            ..base()
        }
        .validate()
        .unwrap();
        let back = SessionConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // The lazy knobs round-trip too (on a lazy-capable strategy).
        let lazy = SessionConfig {
            strategy: "tpe".into(),
            lazy_space: Some(true),
            pool_size: Some(128),
            ..base()
        }
        .validate()
        .unwrap();
        let back = SessionConfig::from_json(&lazy.to_json()).unwrap();
        assert_eq!(back, lazy);
    }

    #[test]
    fn lazy_knobs_are_validated_and_serve_side_refuses_lazy() {
        let err = SessionConfig { pool_size: Some(0), ..base() }.validate().unwrap_err();
        assert!(err.contains("pool_size"), "{err}");
        // An eager-only strategy cannot be forced lazy.
        let err = SessionConfig {
            strategy: "advanced_multi".into(),
            lazy_space: Some(true),
            ..base()
        }
        .validate()
        .unwrap_err();
        assert!(err.contains("enumerated"), "{err}");
        assert!(err.contains("tpe"), "must list lazy-capable strategies: {err}");
        // The daemon-side builders refuse lazy mode outright.
        let cfg = SessionConfig { strategy: "tpe".into(), lazy_space: Some(true), ..base() }
            .validate()
            .unwrap();
        let err = cfg.build_space().unwrap_err();
        assert!(err.contains("eager-only"), "{err}");
        let err = cfg.build_objective().unwrap_err();
        assert!(err.contains("eager-only"), "{err}");
    }

    #[test]
    fn validate_canonicalizes_aliases() {
        let cfg = SessionConfig { kernel: "conv".into(), strategy: "ei".into(), ..base() }
            .validate()
            .unwrap();
        assert_eq!(cfg.kernel, "convolution");
    }

    #[test]
    fn unknown_names_are_rejected_with_suggestions() {
        let err = SessionConfig { strategy: "bayesopt".into(), ..base() }.validate().unwrap_err();
        assert!(err.contains("unknown strategy"), "{err}");
        let err = SessionConfig { kernel: "nope".into(), ..base() }.validate().unwrap_err();
        assert!(err.contains("unknown kernel"), "{err}");
        let err = SessionConfig { gpu: "h100".into(), ..base() }.validate().unwrap_err();
        assert!(err.contains("unknown GPU"), "{err}");
        let err = SessionConfig { budget: 0, ..base() }.validate().unwrap_err();
        assert!(err.contains("budget"), "{err}");
    }

    #[test]
    fn build_space_matches_the_objective_side() {
        let cfg = base().validate().unwrap();
        let (space, id) = cfg.build_space().unwrap();
        assert_eq!(id, "adding@A100");
        let built = cfg.build_objective().unwrap();
        assert_eq!(space.len(), built.table.space().len());
        assert!(built.faulty.is_none());
        assert!(built.resilient.is_none());
    }

    #[test]
    fn resilience_knobs_wrap_the_objective() {
        let cfg =
            SessionConfig { eval_timeout_ms: Some(100), max_retries: 1, ..base() }.validate().unwrap();
        let built = cfg.build_objective().unwrap();
        assert!(built.resilient.is_some());
    }
}
