//! Minimal thread pools for the coordinator (no rayon/tokio in the vendor
//! set).
//!
//! Two tiers with different lifecycles:
//!
//! - [`run_parallel`] spawns fresh threads per call and returns results in
//!   submission order — fine for the experiment harness, where each job is
//!   a whole tuning run and the spawn cost amortizes over seconds.
//! - [`ShardPool`] is a *long-lived* worker pool for the BO engine's
//!   sharded GP hot path: one pool lives across all (~220) iterations of
//!   a run, so the per-iteration cost is a condvar wake, not a thread
//!   spawn. Jobs are borrowed closures (a scoped API): `run` blocks until
//!   every job finished, which is what makes handing out `&mut` shard
//!   state to workers sound.
//!
//! Determinism: neither pool reorders *results*. `run_parallel` collects
//! by submission index; `ShardPool::run` writes through per-job captured
//! slots, so reductions happen on the caller's side in a fixed order
//! regardless of which worker ran which shard when.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

/// Harness workers currently alive (incremented for the duration of each
/// multi-threaded `run_parallel` call). Nested consumers — the BO engine's
/// auto thread mode — divide the machine by this so 35 concurrent repeats
/// don't each spawn a core-count shard pool on top of the core-count
/// harness pool.
static ACTIVE_HARNESS_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// RAII registration of `n` harness-level workers. Held internally by
/// `run_parallel` and by the sweep orchestrator while its sessions run on
/// a [`ShardPool`], so nested consumers (the BO engine's auto thread
/// mode) see the outer parallelism through [`nested_threads`] either way.
pub struct HarnessWorkersGuard(usize);

impl Drop for HarnessWorkersGuard {
    fn drop(&mut self) {
        ACTIVE_HARNESS_WORKERS.fetch_sub(self.0, Ordering::Relaxed);
    }
}

/// Register `workers` harness-level workers for the guard's lifetime.
/// Registering 0 is a no-op guard (serial callers may pass their worker
/// count straight through).
pub fn enter_harness_workers(workers: usize) -> HarnessWorkersGuard {
    ACTIVE_HARNESS_WORKERS.fetch_add(workers, Ordering::Relaxed);
    HarnessWorkersGuard(workers)
}

/// Threads a nested parallel stage should use so the whole process stays
/// near one thread per core: the machine divided by the harness workers
/// currently running (at least 1). Purely a performance heuristic — shard
/// results are thread-count-independent by construction.
pub fn nested_threads() -> usize {
    let outer = ACTIVE_HARNESS_WORKERS.load(Ordering::Relaxed);
    (default_threads() / outer.max(1)).max(1)
}

/// Run `jobs` across up to `threads` workers, returning results in the
/// original order.
pub fn run_parallel<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let threads = threads.max(1).min(jobs.len().max(1));
    if threads <= 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let _nesting = enter_harness_workers(threads);
    let n = jobs.len();
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut handles = Vec::with_capacity(threads);
    for _ in 0..threads {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = queue.lock().unwrap().pop();
            match job {
                Some((idx, f)) => {
                    let out = f();
                    if tx.send((idx, out)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (idx, val) in rx {
        slots[idx] = Some(val);
    }
    for h in handles {
        h.join().expect("worker panicked");
    }
    slots.into_iter().map(|s| s.expect("missing result")).collect()
}

/// Default parallelism for the harness.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// A job handed to the pool: boxed so shards of different closures mix,
/// lifetime-erased inside `run` (see the SAFETY note there).
type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    /// Slots for the current batch; workers `take()` them by index.
    jobs: Vec<Option<Job>>,
    /// Next job index to hand out.
    next: usize,
    /// Jobs finished so far in this batch.
    completed: usize,
    /// Jobs in this batch.
    total: usize,
    /// A job panicked (re-raised on the caller after the batch drains).
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new batch.
    work_cv: Condvar,
    /// The caller waits here for batch completion.
    done_cv: Condvar,
}

fn worker_loop(shared: &PoolShared) {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        if st.next < st.jobs.len() {
            let idx = st.next;
            st.next += 1;
            let job = st.jobs[idx].take().expect("job taken twice");
            drop(st);
            let ok = catch_unwind(AssertUnwindSafe(job)).is_ok();
            st = shared.state.lock().unwrap();
            if !ok {
                st.panicked = true;
            }
            st.completed += 1;
            if st.completed == st.total {
                shared.done_cv.notify_all();
            }
        } else {
            st = shared.work_cv.wait(st).unwrap();
        }
    }
}

/// Long-lived worker pool for the sharded GP hot path. Construct once per
/// BO run (or per bench scenario); `run` one batch of shard jobs per pass.
///
/// `threads <= 1` spawns no workers at all — `run` then executes inline on
/// the caller, so serial configurations pay zero synchronization.
pub struct ShardPool {
    shared: Arc<PoolShared>,
    /// Serializes concurrent `run` calls (the state machine holds one
    /// batch at a time).
    submit: Mutex<()>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ShardPool {
    pub fn new(threads: usize) -> ShardPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                jobs: Vec::new(),
                next: 0,
                completed: 0,
                total: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let n_workers = if threads <= 1 { 0 } else { threads };
        let workers = (0..n_workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        ShardPool { shared, submit: Mutex::new(()), workers }
    }

    /// Worker-thread count (0 means `run` executes inline).
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Execute every job, blocking until all have finished. Jobs may
    /// borrow from the caller's stack: the blocking guarantee bounds their
    /// lifetime. Worker panics are re-raised here after the batch drains.
    pub fn run<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if self.workers.is_empty() || jobs.len() <= 1 {
            for job in jobs {
                job();
            }
            return;
        }
        let _guard = self.submit.lock().unwrap();
        let total = jobs.len();
        // SAFETY: `run` does not return until `completed == total`, i.e.
        // until every job has been consumed and finished, so no job (or
        // anything it borrows with lifetime 'a) is referenced after this
        // call. The transmute erases only the lifetime; Box<dyn ...> has
        // the same layout on both sides.
        let jobs: Vec<Job> = jobs
            .into_iter()
            .map(|j| unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(j) })
            .collect();
        let mut st = self.shared.state.lock().unwrap();
        st.jobs = jobs.into_iter().map(Some).collect();
        st.next = 0;
        st.completed = 0;
        st.total = total;
        st.panicked = false;
        drop(st);
        self.shared.work_cv.notify_all();
        let mut st = self.shared.state.lock().unwrap();
        while st.completed < st.total {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.jobs.clear();
        st.next = 0;
        st.completed = 0;
        st.total = 0;
        let panicked = st.panicked;
        st.panicked = false;
        drop(st);
        drop(_guard);
        if panicked {
            panic!("ShardPool worker job panicked");
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..57).map(|i| move || i * 2).collect();
        let out = run_parallel(jobs, 8);
        assert_eq!(out, (0..57).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let jobs: Vec<_> = (0..5).map(|i| move || i + 1).collect();
        assert_eq!(run_parallel(jobs, 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(run_parallel(jobs, 4).is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let jobs: Vec<_> = (0..2).map(|i| move || i).collect();
        assert_eq!(run_parallel(jobs, 64), vec![0, 1]);
    }

    fn shard_jobs(out: &mut [u64]) -> Vec<Box<dyn FnOnce() + Send + '_>> {
        out.iter_mut()
            .enumerate()
            .map(|(i, slot)| {
                Box::new(move || {
                    *slot = (i as u64 + 1) * 3;
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect()
    }

    #[test]
    fn shard_pool_runs_borrowed_jobs() {
        let pool = ShardPool::new(4);
        assert_eq!(pool.threads(), 4);
        let mut out = vec![0u64; 37];
        pool.run(shard_jobs(&mut out));
        assert_eq!(out, (0..37).map(|i| (i + 1) * 3).collect::<Vec<_>>());
    }

    #[test]
    fn shard_pool_serial_fallback() {
        let pool = ShardPool::new(1);
        assert_eq!(pool.threads(), 0);
        let mut out = vec![0u64; 5];
        pool.run(shard_jobs(&mut out));
        assert_eq!(out, vec![3, 6, 9, 12, 15]);
    }

    #[test]
    fn shard_pool_reusable_across_batches() {
        let pool = ShardPool::new(3);
        for round in 1..=20u64 {
            let mut out = vec![0u64; 11];
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .iter_mut()
                .map(|slot| {
                    Box::new(move || {
                        *slot = round;
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run(jobs);
            assert!(out.iter().all(|&v| v == round), "round {round}: {out:?}");
        }
    }

    #[test]
    fn nested_threads_stays_within_the_machine() {
        // Loose bounds only: other tests may run harness pools concurrently.
        assert!(nested_threads() >= 1);
        let jobs: Vec<_> = (0..4).map(|_| nested_threads).collect();
        let inner = run_parallel(jobs, 4);
        assert!(inner.iter().all(|&t| (1..=default_threads()).contains(&t)));
    }

    #[test]
    fn shard_pool_empty_batch_is_noop() {
        let pool = ShardPool::new(2);
        pool.run(Vec::new());
    }

    #[test]
    fn shard_pool_propagates_panics() {
        let pool = ShardPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|i| {
                    Box::new(move || {
                        if i == 2 {
                            panic!("boom");
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run(jobs);
        }));
        assert!(caught.is_err(), "worker panic must surface on the caller");
        // The pool must stay usable after a panicked batch.
        let mut out = vec![0u64; 6];
        pool.run(shard_jobs(&mut out));
        assert_eq!(out, vec![3, 6, 9, 12, 15, 18]);
    }
}
