//! Minimal scoped thread pool for the experiment harness (no rayon/tokio
//! in the vendor set). Work items are closures producing `T`; results are
//! returned in submission order so repeated experiments stay deterministic
//! regardless of scheduling.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `jobs` across up to `threads` workers, returning results in the
/// original order.
pub fn run_parallel<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let threads = threads.max(1).min(jobs.len().max(1));
    if threads <= 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let n = jobs.len();
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut handles = Vec::with_capacity(threads);
    for _ in 0..threads {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = queue.lock().unwrap().pop();
            match job {
                Some((idx, f)) => {
                    let out = f();
                    if tx.send((idx, out)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (idx, val) in rx {
        slots[idx] = Some(val);
    }
    for h in handles {
        h.join().expect("worker panicked");
    }
    slots.into_iter().map(|s| s.expect("missing result")).collect()
}

/// Default parallelism for the harness.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..57).map(|i| move || i * 2).collect();
        let out = run_parallel(jobs, 8);
        assert_eq!(out, (0..57).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let jobs: Vec<_> = (0..5).map(|i| move || i + 1).collect();
        assert_eq!(run_parallel(jobs, 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(run_parallel(jobs, 4).is_empty());
    }

    #[test]
    fn more_threads_than_jobs() {
        let jobs: Vec<_> = (0..2).map(|i| move || i).collect();
        assert_eq!(run_parallel(jobs, 64), vec![0, 1]);
    }
}
