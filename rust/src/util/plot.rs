//! ASCII plotting for terminal output of the paper's figures.
//!
//! The harness writes CSV for real plotting, but prints an ASCII rendition
//! so `ktbo experiment figN` is self-contained in a terminal.

/// A named series of (x, y) points.
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Render multiple series on one canvas. Each series gets a distinct glyph.
pub fn line_plot(title: &str, xlabel: &str, ylabel: &str, series: &[Series], width: usize, height: usize) -> String {
    const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&', '$', '~'];
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).filter(|(x, y)| x.is_finite() && y.is_finite()).collect();
    if all.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut xmin, mut xmax, mut ymin, mut ymax) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for &(x, y) in &all {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut canvas = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in &s.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            canvas[row][cx.min(width - 1)] = glyph;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!("y: {ylabel}  [{ymin:.4} .. {ymax:.4}]\n"));
    for row in &canvas {
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("x: {xlabel}  [{xmin:.1} .. {xmax:.1}]\n"));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", GLYPHS[si % GLYPHS.len()], s.name));
    }
    out
}

/// Horizontal bar chart with error bars, for the MDF figures.
pub fn bar_chart(title: &str, entries: &[(String, f64, f64)], width: usize) -> String {
    let vmax = entries.iter().map(|e| e.1 + e.2).fold(0.0f64, f64::max).max(1e-12);
    let name_w = entries.iter().map(|e| e.0.len()).max().unwrap_or(4).max(4);
    let mut out = format!("== {title} ==\n");
    for (name, val, err) in entries {
        let bar = ((val / vmax) * width as f64).round() as usize;
        let errpos = (((val + err) / vmax) * width as f64).round() as usize;
        let mut line = "█".repeat(bar);
        if errpos > bar {
            line.push_str(&"─".repeat(errpos - bar - 1));
            line.push('|');
        }
        out.push_str(&format!("{name:>name_w$} | {line} {val:.3} ±{err:.3}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plot_contains_series_glyphs() {
        let s = vec![
            Series { name: "a".into(), points: vec![(0.0, 0.0), (1.0, 1.0)] },
            Series { name: "b".into(), points: vec![(0.0, 1.0), (1.0, 0.0)] },
        ];
        let p = line_plot("t", "x", "y", &s, 20, 10);
        assert!(p.contains('*') && p.contains('o'));
        assert!(p.contains("a") && p.contains("b"));
    }

    #[test]
    fn empty_plot_safe() {
        let p = line_plot("t", "x", "y", &[], 20, 10);
        assert!(p.contains("no data"));
    }

    #[test]
    fn bars_scale() {
        let b = bar_chart("mdf", &[("ga".into(), 1.0, 0.1), ("ei".into(), 0.5, 0.05)], 40);
        assert!(b.contains("ga"));
        assert!(b.lines().count() >= 3);
    }
}
