//! Minimal JSON emitter (the vendor set has no serde facade crate).
//!
//! Only what the harness needs: objects, arrays, numbers, strings, bools.
//! Output is deterministic (insertion order preserved) so experiment
//! artifacts diff cleanly between runs.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert into an object (panics if not an object).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(kv) => kv.push((key.to_string(), val.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn render_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    // JSON has no NaN/Inf; emit null like python's json with allow_nan=False alternatives.
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    item.write(out, indent + 1, pretty);
                }
                if pretty && !items.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !kv.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "gemm")
            .set("size", 17956usize)
            .set("invalid", 0.0)
            .set("series", vec![1.0, 2.5, 3.0])
            .set("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"gemm","size":17956,"invalid":0,"series":[1,2.5,3],"ok":true}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn pretty_roundtrips_structure() {
        let j = Json::obj().set("a", vec![1.0]).set("b", Json::obj().set("c", false));
        let p = j.render_pretty();
        assert!(p.contains("\"a\": ["));
        assert!(p.contains("\"c\": false"));
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }
}
