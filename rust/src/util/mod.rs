//! Hand-rolled substrates: the offline vendor set carries only `xla` and
//! its transitive dependencies, so randomness, linear algebra, JSON/CSV,
//! CLI parsing, thread pooling, plotting, and property testing are all
//! implemented here from scratch.

pub mod cli;
pub mod csv;
pub mod json;
pub mod jsonparse;
pub mod linalg;
pub mod plot;
pub mod pool;
pub mod proptest;
pub mod rng;
