//! CSV emission for experiment artifacts (no external csv crate vendored).

use std::io::Write;
use std::path::Path;

/// Accumulates rows and writes RFC-4180-ish CSV (quotes fields containing
/// commas/quotes/newlines).
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        CsvWriter { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.header.len(), "csv row arity mismatch");
        self.rows.push(fields.to_vec());
    }

    /// Convenience: a row of display-ables.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&v);
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&join_csv(&self.header));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&join_csv(r));
            out.push('\n');
        }
        out
    }

    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render().as_bytes())
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

fn join_csv(fields: &[String]) -> String {
    fields.iter().map(|f| escape_field(f)).collect::<Vec<_>>().join(",")
}

fn escape_field(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

/// Format a float with enough precision for plotting but stable output.
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return "nan".into();
    }
    if x == x.trunc() && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_with_header() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into(), "x,y".into()]);
        w.row(&["2".into(), "he said \"hi\"".into()]);
        let s = w.render();
        assert_eq!(s, "a,b\n1,\"x,y\"\n2,\"he said \"\"hi\"\"\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["only-one".into()]);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(3.0), "3");
        assert_eq!(fnum(3.25), "3.250000");
        assert_eq!(fnum(f64::NAN), "nan");
    }
}
