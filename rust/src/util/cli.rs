//! Tiny CLI argument parser (no `clap` in the vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    flags: HashMap<String, String>,
    seen: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                    a.seen.push(k.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    a.flags.insert(rest.to_string(), argv[i + 1].clone());
                    a.seen.push(rest.to_string());
                    i += 1;
                } else {
                    a.flags.insert(rest.to_string(), "true".to_string());
                    a.seen.push(rest.to_string());
                }
            } else {
                a.positionals.push(arg.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&sv(&["tune", "gemm", "--gpu", "a100", "--repeats=5", "--verbose"]));
        assert_eq!(a.positionals, vec!["tune", "gemm"]);
        assert_eq!(a.get("gpu"), Some("a100"));
        assert_eq!(a.usize_or("repeats", 1), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]));
        assert_eq!(a.str_or("strategy", "ei"), "ei");
        assert_eq!(a.f64_or("noise", 0.1), 0.1);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(&sv(&["--dry-run", "--out", "x.csv"]));
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("out"), Some("x.csv"));
    }
}
