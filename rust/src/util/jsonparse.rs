//! Minimal recursive-descent JSON parser (companion to `util::json`'s
//! emitter; the vendor set has no serde). Supports the full JSON grammar
//! minus exotic number forms; parses into `util::json::Json`.

use crate::util::json::Json;

/// Maximum container nesting the parser accepts. Each level costs one
/// stack frame of recursive descent, and serve feeds wire input here
/// verbatim — without a cap, a line of a few hundred thousand `[` bytes
/// overflows the stack and aborts the whole daemon. 128 is far deeper
/// than any document this workspace emits.
const MAX_DEPTH: usize = 128;

pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        self.i += 1;
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i - 1))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} levels at byte {}", self.i));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            kv.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(kv)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i - 1)),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i - 1)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u escape")?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or("bad hex in \\u escape")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err("bad escape".into()),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 from the raw input.
                    let start = self.i - 1;
                    let width = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let s = std::str::from_utf8(&self.b[start..start + width])
                        .map_err(|_| "invalid utf-8")?;
                    out.push_str(s);
                    self.i = start + width;
                }
                None => return Err("eof in string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }
}

/// Convenience accessors on parsed values.
impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": false}], "c": "x,y"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x,y"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn roundtrips_with_emitter() {
        let original = Json::obj()
            .set("name", "gemm")
            .set("sizes", vec![1.0, 2.5, -3.0])
            .set("nested", Json::obj().set("ok", true).set("s", "a\"b"))
            .set("none", Json::Null);
        let text = original.render();
        let parsed = parse(&text).unwrap();
        assert_eq!(parsed, original);
        // And pretty output parses to the same value.
        assert_eq!(parse(&original.render_pretty()).unwrap(), original);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn bounded_nesting_depth() {
        // Reasonable nesting parses; a bracket flood is refused with an
        // error instead of recursing until the stack overflows.
        let deep_ok = format!("{}0{}", "[".repeat(64), "]".repeat(64));
        assert!(parse(&deep_ok).is_ok());
        let flood = "[".repeat(100_000);
        assert!(parse(&flood).unwrap_err().contains("nesting"));
        let obj_flood = "{\"a\":".repeat(100_000);
        assert!(parse(&obj_flood).unwrap_err().contains("nesting"));
    }

    #[test]
    fn unicode_passthrough() {
        let j = parse("\"Matérn ν=3/2\"").unwrap();
        assert_eq!(j.as_str(), Some("Matérn ν=3/2"));
        assert_eq!(parse("\"\\u00e9\"").unwrap().as_str(), Some("é"));
    }
}
