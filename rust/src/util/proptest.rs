//! Miniature property-based testing harness (no `proptest` crate in the
//! vendor set). Provides random-case generation with seed reporting and
//! greedy input shrinking for integer-vector cases — enough to express the
//! coordinator invariants (routing, batching, state) as properties.

use crate::util::rng::{fnv1a, Rng};

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Fixed default seed: CI-deterministic. Override via KTBO_PROP_SEED.
        let seed = std::env::var("KTBO_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5eed);
        Config { cases: 64, seed, max_shrink: 200 }
    }
}

/// Run a property over generated values. On failure, attempts shrinking via
/// the `shrink` callback and panics with the minimal failing case rendered
/// through `show`.
pub fn check<T, G, P, S>(name: &str, cfg: &Config, mut gen: G, mut prop: P, show: S)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> String,
{
    let mut rng = Rng::new(cfg.seed ^ fnv1a(name));
    for case in 0..cfg.cases {
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed on case {case} (seed {:#x}): {msg}\ninput: {}",
                cfg.seed,
                show(&value)
            );
        }
    }
}

/// Like `check`, but with shrinking: `shrinks(t)` proposes smaller variants.
pub fn check_shrink<T, G, P, S, H>(name: &str, cfg: &Config, mut gen: G, mut prop: P, shrinks: H, show: S)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    H: Fn(&T) -> Vec<T>,
    S: Fn(&T) -> String,
    T: Clone,
{
    let mut rng = Rng::new(cfg.seed ^ fnv1a(name));
    for case in 0..cfg.cases {
        let value = gen(&mut rng);
        if let Err(first_msg) = prop(&value) {
            // Greedy shrink: repeatedly take the first smaller variant that
            // still fails.
            let mut best = value.clone();
            let mut msg = first_msg;
            let mut budget = cfg.max_shrink;
            'outer: while budget > 0 {
                for cand in shrinks(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed on case {case} (seed {:#x}): {msg}\nminimal input: {}",
                cfg.seed,
                show(&best)
            );
        }
    }
}

/// Standard shrinker for Vec<usize>: drop elements, halve elements.
pub fn shrink_vec_usize(v: &Vec<usize>) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    for i in 0..v.len() {
        let mut w = v.clone();
        w.remove(i);
        out.push(w);
    }
    for i in 0..v.len() {
        if v[i] > 0 {
            let mut w = v.clone();
            w[i] /= 2;
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-nonneg",
            &Config::default(),
            |rng| (0..8).map(|_| rng.below(100)).collect::<Vec<usize>>(),
            |v| {
                if v.iter().sum::<usize>() < usize::MAX {
                    Ok(())
                } else {
                    Err("overflow".into())
                }
            },
            |v| format!("{v:?}"),
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check(
            "always-fails",
            &Config { cases: 1, ..Config::default() },
            |rng| rng.below(10),
            |_| Err("nope".into()),
            |v| format!("{v}"),
        );
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Property "no element >= 50" fails; the shrunk case should be small.
        let caught = std::panic::catch_unwind(|| {
            check_shrink(
                "shrinks",
                &Config { cases: 20, ..Config::default() },
                |rng| (0..10).map(|_| rng.below(100)).collect::<Vec<usize>>(),
                |v| {
                    if v.iter().all(|&x| x < 50) {
                        Ok(())
                    } else {
                        Err("elem >= 50".into())
                    }
                },
                shrink_vec_usize,
                |v| format!("{v:?}"),
            )
        });
        let err = caught.expect_err("should fail");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        // Minimal failing input is a single element in [50, 100).
        assert!(msg.contains("minimal input: [") && msg.matches(',').count() == 0, "{msg}");
    }
}
