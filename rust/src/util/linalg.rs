//! Dense linear algebra for the Gaussian-process substrate.
//!
//! The vendor set has no `nalgebra`/`ndarray`, so we implement the small
//! set of kernels a GP needs: row-major matrices, Cholesky with adaptive
//! jitter, triangular solves (single and multi-RHS), and matmul. Sizes are
//! small (≤ ~224 training points), so clarity beats blocking; the one hot
//! loop (posterior over ~18k candidates) lives in `gp::gpr` and the XLA
//! artifact, not here.

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// C = A · B.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj order: streams B rows, accumulates into the output row.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = out.row_mut(i);
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// y = A · x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix, with adaptive diagonal jitter: if factorization fails, jitter
/// is multiplied by 10 and retried (standard GP practice — scikit-learn
/// does the same under `alpha`).
pub fn cholesky(a: &Mat, base_jitter: f64) -> Result<Mat, String> {
    assert_eq!(a.rows, a.cols, "cholesky needs square matrix");
    let n = a.rows;
    let mut jitter = base_jitter;
    'attempt: for _ in 0..8 {
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                if i == j {
                    sum += jitter;
                }
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 || !sum.is_finite() {
                        jitter = if jitter == 0.0 { 1e-10 } else { jitter * 10.0 };
                        continue 'attempt;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        return Ok(l);
    }
    Err(format!("cholesky failed even with jitter {jitter}"))
}

/// Solve L·x = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in 0..n {
        let row = l.row(i);
        let s = dot(&row[..i], &x[..i]);
        x[i] = (x[i] - s) / row[i];
    }
    x
}

/// Solve Lᵀ·x = b for lower-triangular L (back substitution).
pub fn solve_lower_t(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve (L Lᵀ) x = b given the Cholesky factor L.
pub fn cho_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    solve_lower_t(l, &solve_lower(l, b))
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts; fine at harness scale).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        // A = B·Bᵀ + n·I is SPD.
        let mut b = Mat::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = random_spd(6, &mut rng);
        let i = Mat::identity(6);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Rng::new(2);
        for n in [1usize, 2, 5, 20, 64] {
            let a = random_spd(n, &mut rng);
            let l = cholesky(&a, 0.0).unwrap();
            let recon = l.matmul(&l.transpose());
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (recon[(i, j)] - a[(i, j)]).abs() < 1e-8 * (1.0 + a[(i, j)].abs()),
                        "mismatch at ({i},{j}) for n={n}"
                    );
                }
            }
        }
    }

    #[test]
    fn cholesky_jitters_near_singular() {
        // Rank-deficient matrix: needs jitter, must not error.
        let a = Mat::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        let l = cholesky(&a, 1e-10).unwrap();
        assert!(l[(0, 0)] > 0.0 && l[(1, 1)] > 0.0);
    }

    #[test]
    fn solves_invert() {
        let mut rng = Rng::new(3);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let l = cholesky(&a, 0.0).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x = cho_solve(&l, &b);
        let ax = a.matvec(&x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn lower_solves_consistent() {
        let mut rng = Rng::new(4);
        let a = random_spd(8, &mut rng);
        let l = cholesky(&a, 0.0).unwrap();
        let b: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let y = solve_lower(&l, &b);
        let ly = l.matvec(&y);
        for (u, v) in ly.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
        let z = solve_lower_t(&l, &b);
        let ltz = l.transpose().matvec(&z);
        for (u, v) in ltz.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn stats_helpers() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }
}
