//! Deterministic pseudo-random number generation.
//!
//! The vendored dependency set has no `rand` crate, so we implement a
//! PCG-XSH-RR 64/32 generator (O'Neill 2014) from scratch. It is fast,
//! statistically solid for simulation workloads, and — crucially for the
//! experiment harness — *splittable*: every (experiment, strategy, repeat)
//! tuple derives an independent stream, so runs are reproducible regardless
//! of thread scheduling.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed and a stream id.
    ///
    /// Different `stream` values yield independent sequences even for the
    /// same seed (the increment selects the LCG orbit).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator; used to give each repeat of
    /// an experiment its own stream.
    pub fn split(&mut self, tag: u64) -> Rng {
        let seed = (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32());
        Rng::with_stream(seed ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller (polar form avoided: branchless
    /// trig form is fine at this call volume).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0, 1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // For small k relative to n, rejection sampling over a set is
        // cheaper than materializing 0..n.
        if k * 8 < n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let idx = self.below(n);
                if seen.insert(idx) {
                    out.push(idx);
                }
            }
            out
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                all.swap(i, j);
            }
            all.truncate(k);
            all
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// FNV-1a over a string: stable 64-bit name hashing for stream-id
/// derivation (per-cell RNG streams in the harness, property-test seeds).
/// Deterministic across processes and platforms — never use a
/// `RandomState`-seeded hasher for anything that feeds an RNG stream.
#[inline]
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Stateless 64-bit mix (splitmix64 finalizer). Used by the GPU simulator
/// to derive deterministic per-configuration "roughness" so the simulated
/// search space is identical across processes and runs.
#[inline]
pub fn hash64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Map a hash to a uniform f64 in [0, 1).
#[inline]
pub fn hash_unit(x: u64) -> f64 {
    (hash64(x) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Deterministic standard normal derived from two hashed lanes.
pub fn hash_normal(x: u64) -> f64 {
    let u1 = (1.0 - hash_unit(x)).max(f64::MIN_POSITIVE);
    let u2 = hash_unit(x ^ 0xabcd_ef01_2345_6789);
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::with_stream(42, 1);
        let mut b = Rng::with_stream(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be effectively independent");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            let expect = n / 5;
            assert!((c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for &(n, k) in &[(10usize, 10usize), (1000, 5), (100, 60)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn hash_unit_range_and_determinism() {
        for i in 0..1000u64 {
            let u = hash_unit(i);
            assert!((0.0..1.0).contains(&u));
            assert_eq!(u, hash_unit(i));
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(23);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let x = r.range_inclusive(-3, 3);
            assert!((-3..=3).contains(&x));
            lo_seen |= x == -3;
            hi_seen |= x == 3;
        }
        assert!(lo_seen && hi_seen);
    }
}
