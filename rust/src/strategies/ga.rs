//! Genetic Algorithm, following Kernel Tuner's defaults: population of 20,
//! two-point crossover, per-gene mutation, rank-weighted selection.
//! Offspring violating the space restrictions are repaired by mutation or
//! replaced by random configurations; invalid (compile/runtime) members
//! get infinite fitness but their evaluation costs budget.
//!
//! Ask/tell port: a generation is built entirely (selection, crossover,
//! mutation, legalization — all the RNG work) before any member is
//! evaluated, exactly as the legacy loop did — so each generation is one
//! batch `ask`, with `tell` filling the fitness vector in member order.

use crate::objective::Eval;
use crate::space::{Config, SearchSpace};
use crate::strategies::driver::{Ask, DriveCtx, Observation, SearchDriver};
use crate::strategies::Strategy;
use crate::util::rng::Rng;

pub struct GeneticAlgorithm {
    pub pop_size: usize,
    pub mutation_rate: f64,
}

impl Default for GeneticAlgorithm {
    fn default() -> Self {
        GeneticAlgorithm { pop_size: 20, mutation_rate: 0.1 }
    }
}

impl GeneticAlgorithm {
    pub(crate) fn random_config(space: &SearchSpace, rng: &mut Rng) -> usize {
        rng.below(space.len())
    }

    /// Two-point crossover in parameter space; returns the child's value
    /// indices (may violate restrictions).
    pub(crate) fn crossover(a: &Config, b: &Config, rng: &mut Rng) -> Config {
        let d = a.len();
        if d < 2 {
            return a.clone();
        }
        let mut p1 = rng.below(d);
        let mut p2 = rng.below(d);
        if p1 > p2 {
            std::mem::swap(&mut p1, &mut p2);
        }
        let mut child = a.clone();
        child[p1..=p2].copy_from_slice(&b[p1..=p2]);
        child
    }

    pub(crate) fn mutate(space: &SearchSpace, cfg: &mut Config, rate: f64, rng: &mut Rng) {
        for (d, v) in cfg.iter_mut().enumerate() {
            if rng.chance(rate) {
                *v = rng.below(space.params[d].len()) as u16;
            }
        }
    }

    /// Map a (possibly restriction-violating) genome to a space index:
    /// try as-is, then a few mutation repairs, then give up to random.
    pub(crate) fn legalize(space: &SearchSpace, mut cfg: Config, rng: &mut Rng) -> usize {
        for _ in 0..10 {
            if let Some(idx) = space.index_of(&cfg) {
                return idx;
            }
            Self::mutate(space, &mut cfg, 0.3, rng);
        }
        Self::random_config(space, rng)
    }
}

impl Strategy for GeneticAlgorithm {
    fn name(&self) -> String {
        "genetic_algorithm".into()
    }

    fn driver(&self, _space: &SearchSpace) -> Box<dyn SearchDriver> {
        Box::new(GaDriver {
            pop_size: self.pop_size,
            mutation_rate: self.mutation_rate,
            started: false,
            pop: Vec::new(),
            fitness: Vec::new(),
        })
    }
}

pub struct GaDriver {
    pop_size: usize,
    mutation_rate: f64,
    started: bool,
    pop: Vec<usize>,
    fitness: Vec<f64>,
}

impl SearchDriver for GaDriver {
    fn name(&self) -> String {
        "genetic_algorithm".into()
    }

    fn ask(&mut self, ctx: &mut DriveCtx) -> Ask {
        let space = ctx.space();
        let n = space.len();
        if !self.started {
            // Initial population: all draws up front, then one batch.
            self.started = true;
            self.pop =
                (0..self.pop_size).map(|_| GeneticAlgorithm::random_config(space, ctx.rng)).collect();
            self.fitness.clear();
            return Ask::Suggest(self.pop.clone());
        }
        // The previous generation's batch has been told back in order.
        self.fitness.resize(self.pop.len(), f64::INFINITY);

        if !ctx.budget_left() || ctx.n_seen() >= n {
            return Ask::Finished;
        }

        // Rank-weighted parent selection (lower objective = fitter).
        let mut order: Vec<usize> = (0..self.pop.len()).collect();
        order.sort_by(|&a, &b| self.fitness[a].partial_cmp(&self.fitness[b]).unwrap());
        let pop = &self.pop;
        let pick_parent = |rng: &mut Rng| -> usize {
            // Linear rank weights: rank 0 (best) weight n, rank n−1 weight 1.
            let n = order.len();
            let total = n * (n + 1) / 2;
            let mut ticket = rng.below(total);
            for (rank, &i) in order.iter().enumerate() {
                let w = n - rank;
                if ticket < w {
                    return pop[i];
                }
                ticket -= w;
            }
            pop[order[0]]
        };

        // Next generation (elitism: keep the best).
        let elite = pop[order[0]];
        let mut next: Vec<usize> = vec![elite];
        while next.len() < self.pop_size {
            let pa = space.config(pick_parent(ctx.rng));
            let pb = space.config(pick_parent(ctx.rng));
            let mut child = GeneticAlgorithm::crossover(&pa, &pb, ctx.rng);
            GeneticAlgorithm::mutate(space, &mut child, self.mutation_rate, ctx.rng);
            next.push(GeneticAlgorithm::legalize(space, child, ctx.rng));
        }
        self.pop = next;
        self.fitness.clear();
        Ask::Suggest(self.pop.clone())
    }

    fn tell(&mut self, obs: Observation) {
        match obs.eval {
            Eval::Valid(v) => self.fitness.push(v),
            _ => self.fitness.push(f64::INFINITY),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{Objective, TableObjective};
    use crate::space::{Param, Restriction};
    use crate::util::rng::Rng;

    fn constrained_bowl() -> TableObjective {
        let vals: Vec<i64> = (0..16).collect();
        let space = SearchSpace::build(
            "cb",
            vec![Param::ints("x", &vals), Param::ints("y", &vals)],
            &[Restriction::new("x+y even", |a| (a.i("x") + a.i("y")) % 2 == 0)],
        );
        let table = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                let (x, y) = (f64::from(p[0]), f64::from(p[1]));
                Eval::Valid(1.0 + (x - 0.4).powi(2) + (y - 0.6).powi(2))
            })
            .collect();
        TableObjective::new(space, table)
    }

    #[test]
    fn improves_and_respects_restrictions() {
        let o = constrained_bowl();
        let mut rng = Rng::new(7);
        let t = GeneticAlgorithm::default().run(&o, 100, &mut rng);
        assert!(t.len() <= 100);
        let best = t.best().unwrap().1;
        assert!(best < 1.05, "best {best}");
        // Every record is a real space index (legalized).
        for (i, _) in &t.records {
            assert!(*i < o.space().len());
        }
    }

    #[test]
    fn crossover_produces_mix() {
        let mut rng = Rng::new(8);
        let a: Config = vec![0, 0, 0, 0, 0, 0];
        let b: Config = vec![1, 1, 1, 1, 1, 1];
        let mut saw_mix = false;
        for _ in 0..50 {
            let c = GeneticAlgorithm::crossover(&a, &b, &mut rng);
            if c.iter().any(|&x| x == 0) && c.iter().any(|&x| x == 1) {
                saw_mix = true;
            }
        }
        assert!(saw_mix);
    }

    #[test]
    fn unique_budget_semantics() {
        let o = constrained_bowl();
        let mut rng = Rng::new(9);
        let t = GeneticAlgorithm::default().run(&o, 50, &mut rng);
        let set: std::collections::HashSet<_> = t.records.iter().map(|(i, _)| i).collect();
        assert_eq!(set.len(), t.len(), "revisits must not consume budget");
    }
}
