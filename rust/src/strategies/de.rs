//! Differential Evolution (DE/rand/1/bin) over the discrete normalized
//! space — another Kernel Tuner strategy for the extended comparison.
//! Trial vectors are built in the continuous cube and snapped to the
//! nearest restricted configuration; unique-evaluation budget semantics.
//!
//! Ask/tell port: the initial population's agents are all drawn before
//! any evaluation (one batch ask), but each generation interleaves trial
//! construction with evaluation (trial i+1's RNG draws come after trial
//! i's result), so trials are single-suggestion asks to keep the RNG
//! stream — and therefore the trace — bit-identical to the legacy loop.

use crate::bo::sampling::nearest_config as snap;
use crate::space::SearchSpace;
use crate::strategies::driver::{Ask, DriveCtx, Observation, SearchDriver};
use crate::strategies::Strategy;

pub struct DifferentialEvolution {
    pub pop_size: usize,
    /// Differential weight F.
    pub f: f64,
    /// Crossover probability CR.
    pub cr: f64,
}

impl Default for DifferentialEvolution {
    fn default() -> Self {
        DifferentialEvolution { pop_size: 20, f: 0.8, cr: 0.9 }
    }
}

impl Strategy for DifferentialEvolution {
    fn name(&self) -> String {
        "differential_evolution".into()
    }

    fn driver(&self, _space: &SearchSpace) -> Box<dyn SearchDriver> {
        Box::new(DeDriver {
            pop_size: self.pop_size,
            f: self.f,
            cr: self.cr,
            started: false,
            in_init: true,
            pop: Vec::new(),
            fit: Vec::new(),
            i: 0,
            trial: Vec::new(),
            improved: false,
            stale: 0,
            pending: None,
        })
    }
}

pub struct DeDriver {
    pop_size: usize,
    f: f64,
    cr: f64,
    started: bool,
    /// Telling back the initial-population batch (vs a generation trial).
    in_init: bool,
    /// Continuous agents.
    pop: Vec<Vec<f64>>,
    fit: Vec<f64>,
    /// Current trial index within the generation.
    i: usize,
    /// The in-flight trial vector.
    trial: Vec<f64>,
    improved: bool,
    stale: usize,
    pending: Option<Observation>,
}

impl DeDriver {
    /// Generation loop top: stop conditions, then the first trial.
    fn begin_generation(&mut self, ctx: &mut DriveCtx) -> Ask {
        if !ctx.budget_left() || ctx.n_seen() >= ctx.space().len() {
            return Ask::Finished;
        }
        self.improved = false;
        self.next_trial(ctx)
    }

    /// Build trial `self.i` (DE/rand/1/bin) and propose its snap.
    fn next_trial(&mut self, ctx: &mut DriveCtx) -> Ask {
        let dims = ctx.space().dims();
        let i = self.i;
        // Three distinct agents a, b, c ≠ i.
        let mut picks = [0usize; 3];
        for slot in 0..3 {
            loop {
                let c = ctx.rng.below(self.pop_size);
                if c != i && !picks[..slot].contains(&c) {
                    picks[slot] = c;
                    break;
                }
            }
        }
        let (a, b, c) = (picks[0], picks[1], picks[2]);
        // Binomial crossover of the mutant v = a + F (b − c).
        let jrand = ctx.rng.below(dims);
        let mut trial = self.pop[i].clone();
        for d in 0..dims {
            if d == jrand || ctx.rng.chance(self.cr) {
                trial[d] =
                    (self.pop[a][d] + self.f * (self.pop[b][d] - self.pop[c][d])).clamp(0.0, 1.0);
            }
        }
        let idx = snap(ctx.space(), &trial);
        self.trial = trial;
        Ask::Suggest(vec![idx])
    }
}

impl SearchDriver for DeDriver {
    fn name(&self) -> String {
        "differential_evolution".into()
    }

    fn ask(&mut self, ctx: &mut DriveCtx) -> Ask {
        let dims = ctx.space().dims();
        if !self.started {
            // Population of continuous agents, all drawn up front; their
            // snapped indices form the initial batch.
            self.started = true;
            self.pop = (0..self.pop_size)
                .map(|_| (0..dims).map(|_| ctx.rng.f64()).collect())
                .collect();
            let idxs: Vec<usize> = self.pop.iter().map(|a| snap(ctx.space(), a)).collect();
            return Ask::Suggest(idxs);
        }
        if self.in_init {
            // Initial batch fully told back.
            self.in_init = false;
            self.fit.resize(self.pop_size, f64::INFINITY);
            self.i = 0;
            return self.begin_generation(ctx);
        }
        let Some(obs) = self.pending.take() else {
            return Ask::Finished;
        };
        // Selection for trial i.
        let tv = obs.eval.value().unwrap_or(f64::INFINITY);
        if tv < self.fit[self.i] {
            self.pop[self.i] = self.trial.clone();
            self.fit[self.i] = tv;
            self.improved = true;
        }
        if !obs.cached {
            self.stale = 0;
        } else {
            self.stale += 1;
        }
        self.i += 1;
        if self.i < self.pop_size {
            return self.next_trial(ctx);
        }
        // Generation done.
        if !self.improved && self.stale > 2 * self.pop_size {
            // Converged population re-proposing memoized configs: restart
            // the worst half to keep the search alive.
            let mut order: Vec<usize> = (0..self.pop_size).collect();
            order.sort_by(|&x, &y| self.fit[y].partial_cmp(&self.fit[x]).unwrap());
            for &k in order.iter().take(self.pop_size / 2) {
                self.pop[k] = (0..dims).map(|_| ctx.rng.f64()).collect();
                self.fit[k] = f64::INFINITY;
            }
            self.stale = 0;
        }
        self.i = 0;
        self.begin_generation(ctx)
    }

    fn tell(&mut self, obs: Observation) {
        if self.in_init {
            self.fit.push(obs.eval.value().unwrap_or(f64::INFINITY));
        } else {
            self.pending = Some(obs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{Eval, TableObjective};
    use crate::space::Param;
    use crate::util::rng::Rng;

    fn rastrigin_like() -> TableObjective {
        // Mildly multimodal 2D surface.
        let vals: Vec<i64> = (0..24).collect();
        let space = SearchSpace::build("r", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
        let table = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                let (x, y) = (f64::from(p[0]), f64::from(p[1]));
                let base = (x - 0.25).powi(2) + (y - 0.75).powi(2);
                let ripple = 0.02 * ((x * 20.0).sin() + (y * 20.0).cos());
                Eval::Valid(1.0 + base + ripple + 0.04)
            })
            .collect();
        TableObjective::new(space, table)
    }

    #[test]
    fn optimizes_multimodal_surface() {
        let o = rastrigin_like();
        let mut rng = Rng::new(8);
        let t = DifferentialEvolution::default().run(&o, 150, &mut rng);
        let global = {
            let mut m = f64::INFINITY;
            for e in o.table() {
                if let Some(v) = e.value() {
                    m = m.min(v);
                }
            }
            m
        };
        assert!(t.best().unwrap().1 < global + 0.05, "best {}", t.best().unwrap().1);
    }

    #[test]
    fn budget_and_uniqueness() {
        let o = rastrigin_like();
        let mut rng = Rng::new(9);
        let t = DifferentialEvolution::default().run(&o, 60, &mut rng);
        assert!(t.len() <= 60);
        let set: std::collections::HashSet<_> = t.records.iter().map(|(i, _)| i).collect();
        assert_eq!(set.len(), t.len());
    }

    #[test]
    fn exhausts_tiny_space() {
        let space = SearchSpace::build("t", vec![Param::ints("a", &(0..5).collect::<Vec<_>>())], &[]);
        let table = (0..5).map(|i| Eval::Valid((5 - i) as f64)).collect();
        let o = TableObjective::new(space, table);
        let mut rng = Rng::new(10);
        let t = DifferentialEvolution::default().run(&o, 200, &mut rng);
        assert_eq!(t.len(), 5);
        assert_eq!(t.best().unwrap().1, 1.0);
    }
}
