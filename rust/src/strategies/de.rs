//! Differential Evolution (DE/rand/1/bin) over the discrete normalized
//! space — another Kernel Tuner strategy for the extended comparison.
//! Trial vectors are built in the continuous cube and snapped to the
//! nearest restricted configuration; unique-evaluation budget semantics.

use crate::objective::Objective;
use crate::strategies::{CachedEvaluator, Strategy, Trace};
use crate::util::rng::Rng;

pub struct DifferentialEvolution {
    pub pop_size: usize,
    /// Differential weight F.
    pub f: f64,
    /// Crossover probability CR.
    pub cr: f64,
}

impl Default for DifferentialEvolution {
    fn default() -> Self {
        DifferentialEvolution { pop_size: 20, f: 0.8, cr: 0.9 }
    }
}

fn snap(space: &crate::space::SearchSpace, p: &[f64]) -> usize {
    let dims = space.dims();
    let pts = space.points();
    let mut best = (0usize, f64::INFINITY);
    for i in 0..space.len() {
        let q = &pts[i * dims..(i + 1) * dims];
        let d: f64 = p.iter().zip(q).map(|(a, b)| (a - b) * (a - b)).sum();
        if d < best.1 {
            best = (i, d);
        }
    }
    best.0
}

impl Strategy for DifferentialEvolution {
    fn name(&self) -> String {
        "differential_evolution".into()
    }

    fn run(&self, obj: &dyn Objective, max_fevals: usize, rng: &mut Rng) -> Trace {
        let space = obj.space();
        let dims = space.dims();
        let mut ev = CachedEvaluator::new(obj, max_fevals);

        // Population of continuous agents with their evaluated fitness.
        let mut pop: Vec<Vec<f64>> =
            (0..self.pop_size).map(|_| (0..dims).map(|_| rng.f64()).collect()).collect();
        let mut fit: Vec<f64> = Vec::with_capacity(self.pop_size);
        for agent in &pop {
            let Some(e) = ev.eval(snap(space, agent), rng) else { break };
            fit.push(e.value().unwrap_or(f64::INFINITY));
        }
        fit.resize(self.pop_size, f64::INFINITY);

        let mut stale = 0usize;
        while ev.budget_left() && ev.n_seen() < space.len() {
            let mut improved = false;
            for i in 0..self.pop_size {
                // Three distinct agents a, b, c ≠ i.
                let mut picks = [0usize; 3];
                for slot in 0..3 {
                    loop {
                        let c = rng.below(self.pop_size);
                        if c != i && !picks[..slot].contains(&c) {
                            picks[slot] = c;
                            break;
                        }
                    }
                }
                let (a, b, c) = (picks[0], picks[1], picks[2]);
                // Binomial crossover of the mutant v = a + F (b − c).
                let jrand = rng.below(dims);
                let mut trial = pop[i].clone();
                for d in 0..dims {
                    if d == jrand || rng.chance(self.cr) {
                        trial[d] = (pop[a][d] + self.f * (pop[b][d] - pop[c][d])).clamp(0.0, 1.0);
                    }
                }
                let before = ev.n_seen();
                let Some(e) = ev.eval(snap(space, &trial), rng) else { return ev.into_trace() };
                let tv = e.value().unwrap_or(f64::INFINITY);
                if tv < fit[i] {
                    pop[i] = trial;
                    fit[i] = tv;
                    improved = true;
                }
                if ev.n_seen() > before {
                    stale = 0;
                } else {
                    stale += 1;
                }
            }
            if !improved && stale > 2 * self.pop_size {
                // Converged population re-proposing cached configs: restart
                // the worst half to keep the search alive.
                let mut order: Vec<usize> = (0..self.pop_size).collect();
                order.sort_by(|&x, &y| fit[y].partial_cmp(&fit[x]).unwrap());
                for &k in order.iter().take(self.pop_size / 2) {
                    pop[k] = (0..dims).map(|_| rng.f64()).collect();
                    fit[k] = f64::INFINITY;
                }
                stale = 0;
            }
        }
        ev.into_trace()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{Eval, TableObjective};
    use crate::space::{Param, SearchSpace};

    fn rastrigin_like() -> TableObjective {
        // Mildly multimodal 2D surface.
        let vals: Vec<i64> = (0..24).collect();
        let space = SearchSpace::build("r", vec![Param::ints("x", &vals), Param::ints("y", &vals)], &[]);
        let table = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                let base = (p[0] - 0.25).powi(2) + (p[1] - 0.75).powi(2);
                let ripple = 0.02 * ((p[0] * 20.0).sin() + (p[1] * 20.0).cos());
                Eval::Valid(1.0 + base + ripple + 0.04)
            })
            .collect();
        TableObjective::new(space, table)
    }

    #[test]
    fn optimizes_multimodal_surface() {
        let o = rastrigin_like();
        let mut rng = Rng::new(8);
        let t = DifferentialEvolution::default().run(&o, 150, &mut rng);
        let global = {
            let mut m = f64::INFINITY;
            for e in o.table() {
                if let Some(v) = e.value() {
                    m = m.min(v);
                }
            }
            m
        };
        assert!(t.best().unwrap().1 < global + 0.05, "best {}", t.best().unwrap().1);
    }

    #[test]
    fn budget_and_uniqueness() {
        let o = rastrigin_like();
        let mut rng = Rng::new(9);
        let t = DifferentialEvolution::default().run(&o, 60, &mut rng);
        assert!(t.len() <= 60);
        let set: std::collections::HashSet<_> = t.records.iter().map(|(i, _)| i).collect();
        assert_eq!(set.len(), t.len());
    }

    #[test]
    fn exhausts_tiny_space() {
        let space = SearchSpace::build("t", vec![Param::ints("a", &(0..5).collect::<Vec<_>>())], &[]);
        let table = (0..5).map(|i| Eval::Valid((5 - i) as f64)).collect();
        let o = TableObjective::new(space, table);
        let mut rng = Rng::new(10);
        let t = DifferentialEvolution::default().run(&o, 200, &mut rng);
        assert_eq!(t.len(), 5);
        assert_eq!(t.best().unwrap().1, 1.0);
    }
}
