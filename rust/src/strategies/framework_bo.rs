//! Emulations of the two external BO frameworks the paper compares against
//! (§IV-D), reproducing exactly the properties the paper attributes their
//! poor performance to:
//!
//! - **BayesianOptimization** defaults: UCB(κ=2.576) on a continuous
//!   surrogate (Matérn ν=5/2), acquisition optimized continuously and
//!   *snapped* to the nearest grid point;
//! - **scikit-optimize** defaults: GP-Hedge portfolio of EI/PI/LCB with
//!   ξ=0.01, κ=1.96.
//!
//! Neither framework can express search-space restrictions, so they
//! operate over the full Cartesian product: proposals that land outside
//! the restricted space fail (wasting budget, recorded under
//! `OUT_OF_SPACE`), invalid observations are registered with a penalty
//! value (distorting the surrogate — §III-D2 explains why that hurts), and
//! snapping can re-propose already-evaluated configurations (duplicates
//! also waste budget).
//!
//! Ask/tell port: the driver opts out of memoization
//! (`memoize() == false`) so duplicate proposals re-evaluate and consume
//! budget, and proposes `OUT_OF_SPACE` for restriction violations — the
//! drive loop records those as failed evaluations, exactly like the
//! legacy `register` closure did.

use crate::bo::acquisition::score;
use crate::bo::config::Acq;
use crate::gp::{CovFn, Gpr};
use crate::objective::Eval;
use crate::space::{Config, SearchSpace};
use crate::strategies::driver::{Ask, DriveCtx, Observation, SearchDriver};
use crate::strategies::{Strategy, OUT_OF_SPACE};
use crate::util::linalg::{mean, std_dev};
use crate::util::rng::Rng;

/// Which framework defaults to emulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Framework {
    /// fmfn/BayesianOptimization: UCB κ=2.576.
    BayesianOptimization,
    /// scikit-optimize: GP-Hedge (EI, PI, LCB), ξ=0.01, κ=1.96.
    ScikitOptimize,
}

pub struct FrameworkBo {
    pub framework: Framework,
    pub init_samples: usize,
    /// Candidate pool size emulating the continuous acquisition optimizer
    /// (random starts + local refinement in the real packages).
    pub acq_candidates: usize,
}

impl FrameworkBo {
    pub fn new(framework: Framework) -> FrameworkBo {
        FrameworkBo { framework, init_samples: 20, acq_candidates: 1024 }
    }

    /// Random configuration of the *unrestricted* Cartesian product.
    pub(crate) fn random_cartesian(space: &SearchSpace, rng: &mut Rng) -> Config {
        space.params.iter().map(|p| rng.below(p.len()) as u16).collect()
    }

    /// Normalized coordinates of a Cartesian config.
    pub(crate) fn coords(space: &SearchSpace, cfg: &Config) -> Vec<f64> {
        cfg.iter().zip(&space.params).map(|(&vi, p)| p.norm(vi as usize)).collect()
    }

    fn strategy_name(framework: Framework) -> String {
        match framework {
            Framework::BayesianOptimization => "bayesianoptimization".into(),
            Framework::ScikitOptimize => "scikit-optimize".into(),
        }
    }
}

impl Strategy for FrameworkBo {
    fn name(&self) -> String {
        Self::strategy_name(self.framework)
    }

    fn driver(&self, _space: &SearchSpace) -> Box<dyn SearchDriver> {
        Box::new(FrameworkBoDriver {
            framework: self.framework,
            init_samples: self.init_samples,
            acq_candidates: self.acq_candidates,
            started: false,
            init_left: 0,
            xs: Vec::new(),
            ys: Vec::new(),
            worst_valid: 1.0,
            gains: [0.0; 3],
            hedge_eta: 1.0,
            pending_coords: Vec::new(),
        })
    }
}

pub struct FrameworkBoDriver {
    framework: Framework,
    init_samples: usize,
    acq_candidates: usize,
    started: bool,
    /// Initial random-design proposals still to make.
    init_left: usize,
    /// Observation store: coordinates + (possibly penalized) values.
    xs: Vec<f64>,
    ys: Vec<f64>,
    worst_valid: f64,
    gains: [f64; 3],
    hedge_eta: f64,
    /// Coordinates of the in-flight proposal (registered at tell time,
    /// whether or not it landed inside the restricted space).
    pending_coords: Vec<f64>,
}

impl FrameworkBoDriver {
    /// Propose `cfg`: its in-space index, or `OUT_OF_SPACE` when the
    /// restriction-blind draw violates the space.
    fn propose(&mut self, space: &SearchSpace, cfg: &Config) -> Ask {
        self.pending_coords = FrameworkBo::coords(space, cfg);
        match space.index_of(cfg) {
            Some(idx) => Ask::Suggest(vec![idx]),
            None => Ask::Suggest(vec![OUT_OF_SPACE]),
        }
    }

    /// One surrogate-guided iteration.
    fn step(&mut self, ctx: &mut DriveCtx) -> Ask {
        if !ctx.budget_left() {
            return Ask::Finished;
        }
        let space = ctx.space();
        let dims = space.dims();
        // z-score observations (both packages normalize y).
        let y_mean = mean(&self.ys);
        let y_std = {
            let s = std_dev(&self.ys);
            if s > 1e-12 {
                s
            } else {
                1.0
            }
        };
        let yz: Vec<f64> = self.ys.iter().map(|v| (v - y_mean) / y_std).collect();
        let f_best = yz.iter().cloned().fold(f64::INFINITY, f64::min);

        let cov = CovFn::Matern52 { lengthscale: 1.0 };
        let Ok(gp) = Gpr::fit(cov, 1e-6, &self.xs, dims, &yz) else {
            return Ask::Finished;
        };

        // Candidate pool from the Cartesian product (the continuous
        // optimizer explores the box; snapping happens at evaluation).
        let cands: Vec<Config> =
            (0..self.acq_candidates).map(|_| FrameworkBo::random_cartesian(space, ctx.rng)).collect();
        let coords: Vec<f64> = cands.iter().flat_map(|c| FrameworkBo::coords(space, c)).collect();
        let (mu, var) = gp.predict(&coords);

        let argmin_for = |acq: Acq, lambda: f64| -> usize {
            let mut best = (0usize, f64::INFINITY);
            for i in 0..cands.len() {
                let s = score(acq, mu[i], var[i], f_best, lambda);
                if s < best.1 {
                    best = (i, s);
                }
            }
            best.0
        };

        let chosen = match self.framework {
            Framework::BayesianOptimization => argmin_for(Acq::Lcb, 2.576),
            Framework::ScikitOptimize => {
                // GP-Hedge: propose with each AF, draw by softmax(η·g).
                let props =
                    [argmin_for(Acq::Ei, 0.01), argmin_for(Acq::Poi, 0.01), argmin_for(Acq::Lcb, 1.96)];
                let mx = self.gains.iter().cloned().fold(f64::MIN, f64::max);
                let ws: Vec<f64> =
                    self.gains.iter().map(|g| ((g - mx) * self.hedge_eta).exp()).collect();
                let total: f64 = ws.iter().sum();
                let mut ticket = ctx.rng.f64() * total;
                let mut pick = 2;
                for (i, w) in ws.iter().enumerate() {
                    if ticket < *w {
                        pick = i;
                        break;
                    }
                    ticket -= w;
                }
                // Hedge reward: negative posterior mean at each proposal.
                for i in 0..3 {
                    self.gains[i] += -mu[props[i]];
                }
                props[pick]
            }
        };
        let cfg = cands[chosen].clone();
        self.propose(space, &cfg)
    }
}

impl SearchDriver for FrameworkBoDriver {
    fn name(&self) -> String {
        FrameworkBo::strategy_name(self.framework)
    }

    /// The real packages do not dedupe: snapped duplicates re-evaluate
    /// and consume budget.
    fn memoize(&self) -> bool {
        false
    }

    fn ask(&mut self, ctx: &mut DriveCtx) -> Ask {
        if !self.started {
            // Initial random design over the Cartesian product.
            self.started = true;
            self.init_left = self.init_samples.min(ctx.max_fevals().unwrap_or(self.init_samples));
        }
        if self.init_left > 0 {
            self.init_left -= 1;
            let cfg = FrameworkBo::random_cartesian(ctx.space(), ctx.rng);
            return self.propose(ctx.space(), &cfg);
        }
        self.step(ctx)
    }

    fn tell(&mut self, obs: Observation) {
        // The legacy `register` closure: valid values observed as-is,
        // invalid and out-of-space attempts as the worst-valid penalty
        // (the packages have no invalid concept; users register a
        // penalty observation).
        let y = match obs.eval {
            Eval::Valid(v) => {
                self.worst_valid = self.worst_valid.max(v);
                v
            }
            _ => self.worst_valid,
        };
        self.xs.extend_from_slice(&self.pending_coords);
        self.ys.push(y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::TableObjective;
    use crate::space::{Param, Restriction};
    use crate::util::rng::Rng;

    fn restricted_obj() -> TableObjective {
        // Heavy restriction: only x+y ≤ 10 survives → many proposals land
        // outside, like GEMM/Convolution in the paper.
        let vals: Vec<i64> = (0..16).collect();
        let space = SearchSpace::build(
            "r",
            vec![Param::ints("x", &vals), Param::ints("y", &vals)],
            &[Restriction::new("sum", |a| a.i("x") + a.i("y") <= 10)],
        );
        let table = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                Eval::Valid(1.0 + f64::from(p[0]) + f64::from(p[1]))
            })
            .collect();
        TableObjective::new(space, table)
    }

    #[test]
    fn wastes_budget_on_out_of_space_proposals() {
        let o = restricted_obj();
        let mut rng = Rng::new(1);
        let t = FrameworkBo::new(Framework::BayesianOptimization).run(&o, 60, &mut rng);
        assert_eq!(t.len(), 60);
        let wasted = t.records.iter().filter(|(i, _)| *i == OUT_OF_SPACE).count();
        assert!(wasted > 0, "constraint-blind proposals must sometimes fail");
    }

    #[test]
    fn still_optimizes_something() {
        let o = restricted_obj();
        for fw in [Framework::BayesianOptimization, Framework::ScikitOptimize] {
            let mut rng = Rng::new(2);
            let t = FrameworkBo::new(fw).run(&o, 80, &mut rng);
            let best = t.best().unwrap().1;
            assert!(best < 6.0, "{fw:?} best {best}");
        }
    }

    #[test]
    fn may_duplicate_evaluations() {
        // Tiny space: snapping must eventually re-propose evaluated points,
        // and the emulation (like the real packages) does not dedupe.
        let space = SearchSpace::build("tiny", vec![Param::ints("a", &[1, 2, 3])], &[]);
        let o = TableObjective::new(space, vec![Eval::Valid(3.0), Eval::Valid(1.0), Eval::Valid(2.0)]);
        let mut rng = Rng::new(3);
        let t = FrameworkBo::new(Framework::BayesianOptimization).run(&o, 30, &mut rng);
        assert_eq!(t.len(), 30, "duplicates consume budget");
    }
}
