//! Emulations of the two external BO frameworks the paper compares against
//! (§IV-D), reproducing exactly the properties the paper attributes their
//! poor performance to:
//!
//! - **BayesianOptimization** defaults: UCB(κ=2.576) on a continuous
//!   surrogate (Matérn ν=5/2), acquisition optimized continuously and
//!   *snapped* to the nearest grid point;
//! - **scikit-optimize** defaults: GP-Hedge portfolio of EI/PI/LCB with
//!   ξ=0.01, κ=1.96.
//!
//! Neither framework can express search-space restrictions, so they
//! operate over the full Cartesian product: proposals that land outside
//! the restricted space fail (wasting budget, recorded under
//! `OUT_OF_SPACE`), invalid observations are registered with a penalty
//! value (distorting the surrogate — §III-D2 explains why that hurts), and
//! snapping can re-propose already-evaluated configurations (duplicates
//! also waste budget).

use crate::bo::acquisition::score;
use crate::bo::config::Acq;
use crate::gp::{CovFn, Gpr};
use crate::objective::{Eval, Objective};
use crate::space::{Config, SearchSpace};
use crate::strategies::{Strategy, Trace, OUT_OF_SPACE};
use crate::util::linalg::{mean, std_dev};
use crate::util::rng::Rng;

/// Which framework defaults to emulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Framework {
    /// fmfn/BayesianOptimization: UCB κ=2.576.
    BayesianOptimization,
    /// scikit-optimize: GP-Hedge (EI, PI, LCB), ξ=0.01, κ=1.96.
    ScikitOptimize,
}

pub struct FrameworkBo {
    pub framework: Framework,
    pub init_samples: usize,
    /// Candidate pool size emulating the continuous acquisition optimizer
    /// (random starts + local refinement in the real packages).
    pub acq_candidates: usize,
}

impl FrameworkBo {
    pub fn new(framework: Framework) -> FrameworkBo {
        FrameworkBo { framework, init_samples: 20, acq_candidates: 1024 }
    }

    /// Random configuration of the *unrestricted* Cartesian product.
    fn random_cartesian(space: &SearchSpace, rng: &mut Rng) -> Config {
        space.params.iter().map(|p| rng.below(p.len()) as u16).collect()
    }

    /// Normalized coordinates of a Cartesian config.
    fn coords(space: &SearchSpace, cfg: &Config) -> Vec<f64> {
        cfg.iter().zip(&space.params).map(|(&vi, p)| p.norm(vi as usize)).collect()
    }
}

impl Strategy for FrameworkBo {
    fn name(&self) -> String {
        match self.framework {
            Framework::BayesianOptimization => "bayesianoptimization".into(),
            Framework::ScikitOptimize => "scikit-optimize".into(),
        }
    }

    fn run(&self, obj: &dyn Objective, max_fevals: usize, rng: &mut Rng) -> Trace {
        let space = obj.space();
        let dims = space.dims();
        let mut trace = Trace::new();
        // Observation store: coordinates + (possibly penalized) values.
        let mut xs: Vec<f64> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        let mut worst_valid = 1.0f64;

        let register = |cfg: &Config,
                            trace: &mut Trace,
                            xs: &mut Vec<f64>,
                            ys: &mut Vec<f64>,
                            worst_valid: &mut f64,
                            rng: &mut Rng| {
            let coords = Self::coords(space, cfg);
            let y = match space.index_of(cfg) {
                Some(idx) => {
                    let e = obj.evaluate(idx, rng);
                    trace.push(idx, e);
                    match e {
                        Eval::Valid(v) => {
                            *worst_valid = worst_valid.max(v);
                            v
                        }
                        // The packages have no invalid concept: users
                        // register a penalty observation.
                        _ => *worst_valid,
                    }
                }
                None => {
                    // Restriction violation: the attempt fails before
                    // producing a measurement but still costs an evaluation.
                    trace.push(OUT_OF_SPACE, Eval::CompileError);
                    *worst_valid
                }
            };
            xs.extend_from_slice(&coords);
            ys.push(y);
        };

        // Initial random design over the Cartesian product.
        for _ in 0..self.init_samples.min(max_fevals) {
            let cfg = Self::random_cartesian(space, rng);
            register(&cfg, &mut trace, &mut xs, &mut ys, &mut worst_valid, rng);
        }

        // GP-Hedge state.
        let mut gains = [0.0f64; 3];
        let hedge_eta = 1.0;

        while trace.len() < max_fevals {
            // z-score observations (both packages normalize y).
            let y_mean = mean(&ys);
            let y_std = {
                let s = std_dev(&ys);
                if s > 1e-12 {
                    s
                } else {
                    1.0
                }
            };
            let yz: Vec<f64> = ys.iter().map(|v| (v - y_mean) / y_std).collect();
            let f_best = yz.iter().cloned().fold(f64::INFINITY, f64::min);

            let cov = CovFn::Matern52 { lengthscale: 1.0 };
            let Ok(gp) = Gpr::fit(cov, 1e-6, &xs, dims, &yz) else { break };

            // Candidate pool from the Cartesian product (the continuous
            // optimizer explores the box; snapping happens at evaluation).
            let cands: Vec<Config> = (0..self.acq_candidates).map(|_| Self::random_cartesian(space, rng)).collect();
            let coords: Vec<f64> = cands.iter().flat_map(|c| Self::coords(space, c)).collect();
            let (mu, var) = gp.predict(&coords);

            let argmin_for = |acq: Acq, lambda: f64| -> usize {
                let mut best = (0usize, f64::INFINITY);
                for i in 0..cands.len() {
                    let s = score(acq, mu[i], var[i], f_best, lambda);
                    if s < best.1 {
                        best = (i, s);
                    }
                }
                best.0
            };

            let chosen = match self.framework {
                Framework::BayesianOptimization => argmin_for(Acq::Lcb, 2.576),
                Framework::ScikitOptimize => {
                    // GP-Hedge: propose with each AF, draw by softmax(η·g).
                    let props = [argmin_for(Acq::Ei, 0.01), argmin_for(Acq::Poi, 0.01), argmin_for(Acq::Lcb, 1.96)];
                    let mx = gains.iter().cloned().fold(f64::MIN, f64::max);
                    let ws: Vec<f64> = gains.iter().map(|g| ((g - mx) * hedge_eta).exp()).collect();
                    let total: f64 = ws.iter().sum();
                    let mut ticket = rng.f64() * total;
                    let mut pick = 2;
                    for (i, w) in ws.iter().enumerate() {
                        if ticket < *w {
                            pick = i;
                            break;
                        }
                        ticket -= w;
                    }
                    // Hedge reward: negative posterior mean at each proposal.
                    for i in 0..3 {
                        gains[i] += -mu[props[i]];
                    }
                    props[pick]
                }
            };
            register(&cands[chosen], &mut trace, &mut xs, &mut ys, &mut worst_valid, rng);
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::TableObjective;
    use crate::space::{Param, Restriction};

    fn restricted_obj() -> TableObjective {
        // Heavy restriction: only x+y ≤ 10 survives → many proposals land
        // outside, like GEMM/Convolution in the paper.
        let vals: Vec<i64> = (0..16).collect();
        let space = SearchSpace::build(
            "r",
            vec![Param::ints("x", &vals), Param::ints("y", &vals)],
            &[Restriction::new("sum", |a| a.i("x") + a.i("y") <= 10)],
        );
        let table = (0..space.len())
            .map(|i| {
                let p = space.point(i);
                Eval::Valid(1.0 + p[0] + p[1])
            })
            .collect();
        TableObjective::new(space, table)
    }

    #[test]
    fn wastes_budget_on_out_of_space_proposals() {
        let o = restricted_obj();
        let mut rng = Rng::new(1);
        let t = FrameworkBo::new(Framework::BayesianOptimization).run(&o, 60, &mut rng);
        assert_eq!(t.len(), 60);
        let wasted = t.records.iter().filter(|(i, _)| *i == OUT_OF_SPACE).count();
        assert!(wasted > 0, "constraint-blind proposals must sometimes fail");
    }

    #[test]
    fn still_optimizes_something() {
        let o = restricted_obj();
        for fw in [Framework::BayesianOptimization, Framework::ScikitOptimize] {
            let mut rng = Rng::new(2);
            let t = FrameworkBo::new(fw).run(&o, 80, &mut rng);
            let best = t.best().unwrap().1;
            assert!(best < 6.0, "{fw:?} best {best}");
        }
    }

    #[test]
    fn may_duplicate_evaluations() {
        // Tiny space: snapping must eventually re-propose evaluated points,
        // and the emulation (like the real packages) does not dedupe.
        let space = SearchSpace::build("tiny", vec![Param::ints("a", &[1, 2, 3])], &[]);
        let o = TableObjective::new(space, vec![Eval::Valid(3.0), Eval::Valid(1.0), Eval::Valid(2.0)]);
        let mut rng = Rng::new(3);
        let t = FrameworkBo::new(Framework::BayesianOptimization).run(&o, 30, &mut rng);
        assert_eq!(t.len(), 30, "duplicates consume budget");
    }
}
